"""L2 model checks: shapes, loss descent, transfer-learning freezing."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import model


def blobs(key, n, dim, classes):
    """Class-conditional gaussian blobs (fast synthetic data)."""
    kc, kx = jax.random.split(key)
    centers = jax.random.normal(kc, (classes, dim)) * 2.0
    labels = jnp.arange(n) % classes
    x = centers[labels] + 0.3 * jax.random.normal(kx, (n, dim))
    y = jax.nn.one_hot(labels, classes)
    return x.astype(jnp.float32), y.astype(jnp.float32), labels


def test_mlp_shapes_and_loss_decreases():
    key = jax.random.PRNGKey(0)
    dims = (16, 12, 8, 4)
    params = model.mlp_init(key, dims)
    x, y, labels = blobs(key, 32, 16, 4)
    step = jax.jit(lambda p, x, y: model.mlp_train_step(p, x, y, jnp.float32(0.5)))
    loss0 = None
    for i in range(30):
        *params, loss = step(list(params), x, y)
        if loss0 is None:
            loss0 = loss
    assert float(loss) < float(loss0), (loss0, loss)
    preds = jnp.argmax(model.mlp_forward(list(params), x), -1)
    acc = float((preds == labels).mean())
    assert acc > 0.5, acc


def test_cnn_transfer_freezes_convs():
    cfg = model.cnn_config("mnist")
    key = jax.random.PRNGKey(1)
    params = model.cnn_init(key, cfg)
    x = jax.random.normal(key, (2, 1, 28, 28), jnp.float32)
    y = jax.nn.one_hot(jnp.array([0, 1]), cfg["classes"]).astype(jnp.float32)
    out = model.cnn_transfer_step(params, x, y, jnp.float32(0.1))
    new_params, _loss = list(out[:-1]), out[-1]
    np.testing.assert_array_equal(np.asarray(new_params[0]), np.asarray(params[0]))
    np.testing.assert_array_equal(np.asarray(new_params[1]), np.asarray(params[1]))
    assert not np.array_equal(np.asarray(new_params[2]), np.asarray(params[2]))


def test_cnn_forward_shape():
    cfg = model.cnn_config("mnist")
    params = model.cnn_init(jax.random.PRNGKey(2), cfg)
    x = jnp.zeros((3, 1, 28, 28), jnp.float32)
    logits = model.cnn_forward(params, x)
    assert logits.shape == (3, cfg["classes"])
