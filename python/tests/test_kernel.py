"""L1 kernel correctness: Pallas vs pure references, hypothesis sweeps."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ntt_mac as nm
from compile.kernels import quant_matmul as qm
from compile.kernels import ref


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 96),
    n=st.integers(1, 160),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref_shapes(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jnp.round(jax.random.uniform(k1, (m, k), jnp.float32, -127, 127))
    w = jnp.round(jax.random.uniform(k2, (k, n), jnp.float32, -127, 127))
    got = qm.matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_matmul_gradients_flow_through_kernel():
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 3), jnp.float32)
    g = jax.grad(lambda w: jnp.sum(qm.matmul(x, w) ** 2))(w)
    # d/dw sum((x@w)^2) = 2 xᵀ (x@w): each entry = 2·4·8 = 64
    np.testing.assert_allclose(np.asarray(g), np.full((8, 3), 64.0), rtol=1e-6)


def test_quantize_q8_matches_ref_and_is_pow2():
    x = np.linspace(-3.7, 9.1, 101).astype(np.float32)
    got = np.asarray(qm.quantize_q8(jnp.asarray(x)))
    want = ref.quantize_q8_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # quantized values are integers times a power-of-two scale
    amax = np.max(np.abs(x))
    e = np.ceil(np.log2(amax / 127.0))
    ints = got * 2.0 ** (-e)
    np.testing.assert_allclose(ints, np.round(ints), atol=1e-4)


def test_quantize_q8_ste_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(qm.quantize_q8(x) * 3.0))(jnp.ones((5,)))
    np.testing.assert_allclose(np.asarray(g), 3.0)


@settings(max_examples=10, deadline=None)
@given(
    batch=st.integers(1, 8),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31),
    p=st.sampled_from([469762049, 1811939329, 2013265921]),
)
def test_ntt_mac_matches_exact_reference(batch, n, seed, p):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, p, (batch, n), dtype=np.uint64)
    b = rng.integers(0, p, (batch, n), dtype=np.uint64)
    acc = rng.integers(0, p, (batch, n), dtype=np.uint64)
    got = np.asarray(nm.ntt_mac(jnp.asarray(a), jnp.asarray(b), jnp.asarray(acc), p=p))
    want = ref.ntt_mac_ref(a, b, acc, p)
    np.testing.assert_array_equal(got, want)


def test_ntt_mac_wraps_at_modulus_boundary():
    p = 469762049
    a = jnp.full((1, 4), p - 1, jnp.uint64)
    b = jnp.full((1, 4), p - 1, jnp.uint64)
    acc = jnp.full((1, 4), p - 1, jnp.uint64)
    got = np.asarray(nm.ntt_mac(a, b, acc, p=p))
    want = (pow(p - 1, 2, p) + p - 1) % p
    assert (got == want).all()
