"""L2: the paper's models as quantized JAX training graphs.

These graphs carry the *plaintext-domain* side of the paper's evaluation:
Figures 7/8 train all networks in the plaintext domain ("where all networks
are trained in the plaintext domain") with SWALP 8-bit quantization, and the
transfer-learning pipeline pre-trains the CNN feature extractor on a public
source dataset. Every FC layer multiplies through the L1 Pallas kernel
(kernels.quant_matmul); convs use lax.conv (XLA) with quantized weights.

Lowered once by aot.py to HLO text; the Rust coordinator executes the
artifacts via PJRT (runtime/) — python never runs at request time.
"""

import jax
import jax.numpy as jnp

from .kernels.quant_matmul import linear_q8, quantize_q8

# ---------------------------------------------------------------------------
# MLP (paper §5.2: 784-128-32-10)
# ---------------------------------------------------------------------------

MLP_DIMS = (784, 128, 32, 10)


def mlp_init(key, dims=MLP_DIMS):
    params = []
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (dims[i], dims[i + 1]), jnp.float32)
        params.append(w * (2.0 / dims[i]) ** 0.5)
    return params


def mlp_forward(params, x):
    h = x
    for i, w in enumerate(params):
        h = linear_q8(h, w)
        if i + 1 < len(params):
            h = quantize_q8(jax.nn.relu(h))
    return h


def quadratic_loss(logits, y_onehot):
    # the paper's quadratic loss (Eq. 6 derivative): probabilities via a
    # squashing of the logits, L2 against one-hot
    d = jax.nn.sigmoid(logits)
    return 0.5 * jnp.mean(jnp.sum((d - y_onehot) ** 2, axis=-1))


def mlp_loss(params, x, y_onehot):
    return quadratic_loss(mlp_forward(params, x), y_onehot)


def mlp_train_step(params, x, y_onehot, lr):
    loss, grads = jax.value_and_grad(mlp_loss)(params, x, y_onehot)
    new_params = [w - lr * g for w, g in zip(params, grads)]
    return tuple(new_params) + (loss,)


def mlp_infer(params, x):
    return (jnp.argmax(mlp_forward(params, x), axis=-1).astype(jnp.int32),)


# ---------------------------------------------------------------------------
# CNN (paper §5.2): conv(k3) → BN-lite → ReLU → pool ×2 → FC → FC
# ---------------------------------------------------------------------------


def cnn_config(dataset):
    if dataset == "mnist":
        return dict(in_ch=1, c1=6, c2=16, hw=28, fc1_in=16 * 5 * 5, fc1=84, classes=10)
    if dataset == "cancer":
        return dict(in_ch=3, c1=64, c2=96, hw=28, fc1_in=96 * 5 * 5, fc1=128, classes=7)
    raise ValueError(dataset)


def cnn_init(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    conv1 = jax.random.normal(k1, (cfg["c1"], cfg["in_ch"], 3, 3), jnp.float32) * 0.3
    conv2 = jax.random.normal(k2, (cfg["c2"], cfg["c1"], 3, 3), jnp.float32) * 0.15
    fc1 = jax.random.normal(k3, (cfg["fc1_in"], cfg["fc1"]), jnp.float32) * (2.0 / cfg["fc1_in"]) ** 0.5
    fc2 = jax.random.normal(k4, (cfg["fc1"], cfg["classes"]), jnp.float32) * 0.1
    return [conv1, conv2, fc1, fc2]


def _conv(x, w):
    # NCHW, OIHW, valid padding, stride 1 — matches nn/conv.rs
    return jax.lax.conv_general_dilated(x, quantize_q8(w), (1, 1), "VALID")


def _pool(x):
    return jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID") / 4.0


def cnn_forward(params, x):
    conv1, conv2, fc1, fc2 = params
    h = _pool(quantize_q8(jax.nn.relu(_conv(x, conv1))))
    h = _pool(quantize_q8(jax.nn.relu(_conv(h, conv2))))
    h = h.reshape(h.shape[0], -1)
    # scale-invariant feature normalization: divide by the (stop-gradient)
    # max-abs — the float analogue of the encrypted pipeline's power-of-two
    # activation shift, which likewise renormalizes to 8-bit regardless of
    # how large the (possibly frozen, pre-trained) conv features grow.
    h = h / jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(h)), 1e-8))
    h = quantize_q8(jax.nn.relu(linear_q8(h, fc1)))
    h = h / jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(h)), 1e-8))
    return linear_q8(h, fc2)


def cnn_loss(params, x, y_onehot):
    return quadratic_loss(cnn_forward(params, x), y_onehot)


def cnn_pretrain_step(params, x, y_onehot, lr):
    """Source-dataset pre-training: all parameters update."""
    loss, grads = jax.value_and_grad(cnn_loss)(params, x, y_onehot)
    new_params = [w - lr * g for w, g in zip(params, grads)]
    return tuple(new_params) + (loss,)


def cnn_transfer_step(params, x, y_onehot, lr):
    """Transfer learning (paper §4.3): conv weights frozen, FC head trains."""
    loss, grads = jax.value_and_grad(cnn_loss)(params, x, y_onehot)
    new_params = [
        params[0],
        params[1],
        params[2] - lr * grads[2],
        params[3] - lr * grads[3],
    ]
    return tuple(new_params) + (loss,)


def cnn_infer(params, x):
    return (jnp.argmax(cnn_forward(params, x), axis=-1).astype(jnp.int32),)
