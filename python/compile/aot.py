"""AOT lowering: JAX/Pallas → HLO *text* artifacts for the Rust runtime.

HLO text (not `.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md). Lowered with
return_tuple=True; the Rust side unpacks with decompose_tuple().

Artifacts (shapes fixed at lowering; batch = 60, the paper's mini-batch):
  mlp_train_step / mlp_infer            — 784-128-32-10 quantized MLP
  cnn_pretrain_step_{mnist,cancer}      — full CNN training (source data)
  cnn_transfer_step_{mnist,cancer}      — frozen-conv transfer steps
  cnn_infer_{mnist,cancer}
  ntt_mac                               — batched modular MAC kernel (8×256)
  quant_matmul                          — standalone kernel (60×784 × 784×128)
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # u64 for ntt_mac

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ntt_mac as nm
from .kernels import quant_matmul as qm

BATCH = 60


def to_hlo_text(fn, *example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def mlp_specs():
    params = [spec((i, o)) for i, o in zip(model.MLP_DIMS[:-1], model.MLP_DIMS[1:])]
    x = spec((BATCH, model.MLP_DIMS[0]))
    y = spec((BATCH, model.MLP_DIMS[-1]))
    return params, x, y


def cnn_specs(dataset):
    cfg = model.cnn_config(dataset)
    params = [
        spec((cfg["c1"], cfg["in_ch"], 3, 3)),
        spec((cfg["c2"], cfg["c1"], 3, 3)),
        spec((cfg["fc1_in"], cfg["fc1"])),
        spec((cfg["fc1"], cfg["classes"])),
    ]
    x = spec((BATCH, cfg["in_ch"], cfg["hw"], cfg["hw"]))
    y = spec((BATCH, cfg["classes"]))
    return params, x, y


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-cancer", action="store_true", help="faster CI builds")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    def emit(name, fn, *specs_):
        text = to_hlo_text(fn, *specs_)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name}: {len(text)} chars")

    lr = spec((), jnp.float32)

    params, x, y = mlp_specs()
    emit("mlp_train_step", model.mlp_train_step, params, x, y, lr)
    emit("mlp_infer", model.mlp_infer, params, x)

    datasets = ["mnist"] if args.skip_cancer else ["mnist", "cancer"]
    for ds in datasets:
        params, x, y = cnn_specs(ds)
        emit(f"cnn_pretrain_step_{ds}", model.cnn_pretrain_step, params, x, y, lr)
        emit(f"cnn_transfer_step_{ds}", model.cnn_transfer_step, params, x, y, lr)
        emit(f"cnn_infer_{ds}", model.cnn_infer, params, x)

    # standalone kernels
    u64 = jnp.uint64
    emit("ntt_mac", lambda a, b, c: (nm.ntt_mac(a, b, c),),
         spec((8, 256), u64), spec((8, 256), u64), spec((8, 256), u64))
    emit("quant_matmul", lambda a, b: (qm.matmul(a, b),),
         spec((BATCH, 784)), spec((784, 128)))


if __name__ == "__main__":
    main()
