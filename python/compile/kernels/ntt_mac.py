"""L1 Pallas kernel: batched NTT-domain modular multiply-accumulate.

This is the inner loop of every BGV MultCC/MultCP: with operands kept in the
NTT domain, a ciphertext MAC is a pointwise `acc = (acc + a·b) mod p` over
RNS residue vectors. The Rust coordinator can offload a whole FC layer's
batched MACs as one PJRT call on this kernel (the `ablations` bench compares
it against the native Rust NTT path).

Values are u64 residues of primes p < 2^32, so `a·b` fits u64 exactly
(needs `jax_enable_x64`; aot.py and the tests set it before import).
On a real TPU this is a VPU (not MXU) kernel; the BlockSpec pipelines
HBM↔VMEM over the batch dimension (DESIGN.md §7).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default prime for the standalone artifact (7·2^26 + 1, the first limb of
# the MAC profile's RNS basis).
DEFAULT_P = 469762049


def _mac_kernel(a_ref, b_ref, acc_ref, o_ref, *, p):
    a = a_ref[...]
    b = b_ref[...]
    acc = acc_ref[...]
    prod = (a * b) % p  # a,b < 2^32 → product < 2^64: exact in u64
    o_ref[...] = (acc + prod) % p


@functools.partial(jax.jit, static_argnames=("p",))
def ntt_mac(a, b, acc, p=DEFAULT_P):
    """(acc + a*b) mod p, element-wise over (BATCH, N) u64 arrays."""
    assert a.shape == b.shape == acc.shape
    batch, n = a.shape
    return pl.pallas_call(
        functools.partial(_mac_kernel, p=p),
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.uint64),
        interpret=True,
    )(a.astype(jnp.uint64), b.astype(jnp.uint64), acc.astype(jnp.uint64))
