"""Pure-jnp/numpy correctness oracles for the Pallas kernels."""

import jax.numpy as jnp
import numpy as np


def matmul_ref(x, w):
    """Oracle for quant_matmul.matmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def quantize_q8_ref(x):
    """Oracle for quant_matmul.quantize_q8 (forward values only)."""
    x = np.asarray(x, dtype=np.float64)
    amax = max(np.max(np.abs(x)), 1e-8)
    e = np.ceil(np.log2(amax / 127.0))
    scale = 2.0 ** (-e)
    return np.clip(np.round(x * scale), -127, 127) / scale


def ntt_mac_ref(a, b, acc, p):
    """Oracle for ntt_mac (exact integer arithmetic via python ints)."""
    a = np.asarray(a, dtype=np.uint64)
    b = np.asarray(b, dtype=np.uint64)
    acc = np.asarray(acc, dtype=np.uint64)
    out = np.empty_like(a)
    flat_a, flat_b, flat_c = a.ravel(), b.ravel(), acc.ravel()
    flat_o = out.ravel()
    for i in range(flat_a.size):
        flat_o[i] = (int(flat_c[i]) + int(flat_a[i]) * int(flat_b[i])) % p
    return out
