"""L1 Pallas kernel: SWALP-style 8-bit quantized matmul.

The paper quantizes inputs, weights and activations to 8 bits (SWALP, §5.2);
every FC layer of the L2 training graphs multiplies an int8-quantized
activation matrix by an int8-quantized weight matrix and accumulates in
wide precision — the exact analogue of the BGV MAC path on the encrypted
side. This kernel is the MXU-shaped hot spot: operands are pre-quantized
(held as f32 for the systolic array; values are integers in [-127, 127]),
blocked for VMEM via BlockSpec, and accumulation is exact (|acc| <
127·127·K < 2^24 ≪ f32's 2^24 integer range for K ≤ 1024; K = 784 here).

A `jax.custom_vjp` routes the backward pass through the same kernel
(dx = g·Wᵀ, dW = xᵀ·g), so autodiff over the training graphs never leaves
the Pallas path. interpret=True everywhere: CPU-PJRT execution (real-TPU
lowering would emit a Mosaic custom-call; see DESIGN.md §2.5).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    # One (bm, K) × (K, bn) tile product; K is kept whole per block (the
    # layer widths here are ≤ 2352, comfortably within VMEM budgets).
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _block(m, bm):
    return m if m < bm else bm


@functools.partial(jax.jit, static_argnames=())
def _matmul_pallas(x, w):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn = _block(m, 32), _block(n, 128)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def matmul(x, w):
    """`x @ w` through the Pallas kernel, differentiable."""
    return _matmul_pallas(x, w)


def _matmul_fwd(x, w):
    return _matmul_pallas(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = _matmul_pallas(g, w.T)
    dw = _matmul_pallas(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def quantize_q8(x):
    """SWALP power-of-two quantization to signed 8-bit, straight-through
    estimator for gradients. Returns values already rescaled back (i.e. the
    quantization *error* is applied, the scale is not carried separately)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    e = jnp.ceil(jnp.log2(amax / 127.0))
    scale = jnp.exp2(-e)
    q = jnp.clip(jnp.round(x * scale), -127, 127) / scale
    # straight-through: forward q, backward identity
    return x + jax.lax.stop_gradient(q - x)


def linear_q8(x, w):
    """A quantized linear layer: q8(x) @ q8(w) via the Pallas kernel."""
    return matmul(quantize_q8(x), quantize_q8(w))
