//! Switch conformance harness, part 2: proof that the scratch-backed
//! scheme-switch paths perform ZERO heap allocations per switched lane —
//! the extract side (`SampleExtract` + RNS→torus rescale + LWE key switch
//! via `extract_lane_into`/`switch_into`) and the repack side (the packing
//! functional key switch via `pack_into`) — at the paper's lane counts
//! (mini-batch 60 for the MLP, 32-lane groups for the CNN-shaped sweep).
//!
//! Counting-allocator harness in the `zero_alloc.rs` / `zero_alloc_bgv.rs`
//! mould: warm the scratch once, then every further lane must not touch the
//! allocator at all. This file holds exactly ONE test so no concurrent test
//! can pollute the counter (each integration-test file is its own process).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_switch_extract_and_repack_are_allocation_free() {
    use glyph::bgv::{BgvContext, BgvParams, BgvSecretKey, Plaintext};
    use glyph::math::GlyphRng;
    use glyph::switch::{LweExtractor, Repacker, SwitchScratch, VALUE_POS};
    use glyph::tfhe::{LweCiphertext, LweKey, TfheParams, TrlweCiphertext, TrlweKey};

    let ctx = BgvContext::new(BgvParams::test_params());
    let mut rng = GlyphRng::new(31339);
    let sk = BgvSecretKey::generate(&ctx, &mut rng);
    let ext_params = TfheParams::test_extract_params();
    let lwe_key = LweKey::generate_binary(ext_params.n, &mut rng);
    let gate_ring = TrlweKey::generate(TfheParams::test_params().big_n, &mut rng);
    let extractor = LweExtractor::generate(&sk, &lwe_key, &ext_params, &mut rng);
    let repacker = Repacker::generate(&gate_ring, &sk, &mut rng);

    // Paper lane counts: the MLP trains on mini-batches of 60 (so a value
    // ciphertext crosses with 60 lanes); the CNN sweep packs 32-lane groups.
    let mlp_lanes = 60usize;
    let cnn_lanes = 32usize;

    // ---- extract side -------------------------------------------------------
    let vals: Vec<i64> = (0..mlp_lanes as i64).map(|i| (i % 200) - 100).collect();
    let pt = Plaintext::encode_batch(&vals, &ctx.params);
    let ct = sk.encrypt(&pt, &mut rng);
    let prepared = extractor.prepare_msb(&ct);
    let n = ctx.params.n;
    let mut scratch = SwitchScratch::new();
    let mut out_lwe = LweCiphertext::trivial(0, ext_params.n);
    // warm-up sizes the dim-N workspace
    extractor.extract_lane_into(&prepared, 0, scratch.lwe_n(n), &mut out_lwe);

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for lane in 0..mlp_lanes {
        extractor.extract_lane_into(&prepared, lane, scratch.lwe_n(n), &mut out_lwe);
        std::hint::black_box(out_lwe.b);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state lane extraction allocated {} times over {mlp_lanes} lanes",
        after - before
    );

    // ---- repack side --------------------------------------------------------
    // real encryptions under the gate ring's extracted key, so every
    // decomposition digit is live and the full FFT accumulate path runs
    let ext_key = gate_ring.extracted_lwe_key();
    let mut mk_lanes = |count: usize| -> Vec<LweCiphertext> {
        (0..count)
            .map(|i| {
                LweCiphertext::encrypt(((i as i64 - 8) << VALUE_POS) as u32, &ext_key, 1e-9, &mut rng)
            })
            .collect()
    };
    let mlp_group = mk_lanes(mlp_lanes);
    let cnn_group = mk_lanes(cnn_lanes);
    let mlp_positions: Vec<usize> = (0..mlp_lanes).collect();
    let cnn_positions: Vec<usize> = (0..cnn_lanes).rev().collect(); // reversed packing
    let mut packed = TrlweCiphertext::zero(ctx.params.n);
    // warm-up sizes the repack accumulators
    repacker.pksk.pack_into(&mlp_group, &mlp_positions, &mut scratch.repack, &mut packed);

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    repacker.pksk.pack_into(&mlp_group, &mlp_positions, &mut scratch.repack, &mut packed);
    std::hint::black_box(packed.b[0]);
    repacker.pksk.pack_into(&cnn_group, &cnn_positions, &mut scratch.repack, &mut packed);
    std::hint::black_box(packed.b[0]);
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state repack allocated {} times over {} packed lanes",
        after - before,
        mlp_lanes + cnn_lanes
    );
}
