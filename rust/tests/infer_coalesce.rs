//! Coalesced batch-group inference conformance (ROADMAP item 5 serving):
//!
//! * Differential: lane-compatible jobs scored together in one widened
//!   engine batch must produce logits/predictions **byte-identical** to
//!   each job scored solo — coalescing is a throughput lever, never an
//!   accuracy or determinism lever.
//! * Ragged tails: a sample count that does not divide the batch is scored
//!   through occupancy masks; reported image counts are *real* images, and
//!   the decoded rows match a solo run at any other batch width.
//! * Attribution: each member's live op share equals its predicted share
//!   exactly (modulo the documented unpredicted ops), and the shares are
//!   split from one shared counter delta.
//! * Isolation: a cancelled member vacates its slots without perturbing
//!   the surviving members; lane-incompatible jobs are refused up front.

use glyph::coordinator::OpSnapshot;
use glyph::nn::engine::EngineProfile;
use glyph::serve::metrics::UNPREDICTED_OPS;
use glyph::serve::{
    run_infer_group, run_infer_job, InferOutcome, InferResult, InferSpec, JobBackend, JobHandle,
    JobState, JobStatus,
};
use std::sync::atomic::Ordering;

fn spec(tenant: &str, seed: u64, batch: u64, samples: u64) -> InferSpec {
    let mut s = InferSpec::small_clear(tenant, seed);
    s.batch = batch;
    s.samples = samples;
    s.coalesce = true;
    s
}

/// Score one spec solo (group of one) and return its result + final status.
fn solo(spec: &InferSpec, id: u64) -> (InferResult, JobStatus) {
    let handle = JobHandle::new_infer(id, spec.clone());
    match run_infer_job(&handle, None).expect("solo inference run failed") {
        InferOutcome::Completed(r) => (r, handle.status()),
        InferOutcome::Cancelled => panic!("solo run reported cancelled without a cancel request"),
    }
}

fn assert_live_matches_predicted(st: &JobStatus) {
    let diff = st.live_ops.diff_ignoring(&st.predicted_ops, &UNPREDICTED_OPS);
    assert!(
        diff.is_empty(),
        "job {} live op share drifted from its predicted share: {}",
        st.id,
        OpSnapshot::render_diff(&diff)
    );
}

fn assert_same_scores(case: &str, coalesced: &InferResult, solo: &InferResult) {
    assert_eq!(coalesced.logits_digest, solo.logits_digest, "{case}: logits diverged");
    assert_eq!(
        coalesced.predictions_digest, solo.predictions_digest,
        "{case}: predictions diverged"
    );
    assert_eq!(coalesced.accuracy, solo.accuracy, "{case}: accuracy diverged");
    assert_eq!(coalesced.images, solo.images, "{case}: image counts diverged");
    assert_eq!(coalesced.batches, solo.batches, "{case}: batch counts diverged");
}

#[test]
fn coalesced_clear_scores_are_byte_identical_to_solo() {
    // Two tenants in one lane, with different sample counts so the shorter
    // member finishes first and vacates its window mid-group.
    let a = spec("alice", 7, 2, 6);
    let b = spec("bob", 7, 2, 4);
    let (solo_a, _) = solo(&a, 101);
    let (solo_b, _) = solo(&b, 102);

    let ha = JobHandle::new_infer(1, a);
    let hb = JobHandle::new_infer(2, b);
    let (outcomes, stats) =
        run_infer_group(&[&ha, &hb], None, 42).expect("coalesced group run failed");
    assert_eq!(outcomes.len(), 2);

    for (handle, reference) in [(&ha, &solo_a), (&hb, &solo_b)] {
        let (id, outcome) = outcomes.iter().find(|(id, _)| *id == handle.id).unwrap();
        let InferOutcome::Completed(result) = outcome else {
            panic!("member {id} did not complete")
        };
        assert_same_scores("coalesced vs solo", result, reference);

        let st = handle.status();
        assert_eq!(st.state, JobState::Completed);
        assert_eq!(st.group, 42, "coalesced member must record its batch group");
        assert_eq!(st.images, result.images, "status images must match the result");
        assert_eq!(st.live_ops, result.ops, "status live ops must match the result");
        assert_live_matches_predicted(&st);
    }

    // 6+4 real images over 3 passes of width 4: the last pass runs alice
    // alone, so 2 of 12 slots are vacant.
    assert_eq!(stats.passes, 3);
    assert_eq!(stats.total_slots, 12);
    assert_eq!(stats.filled_slots, 10);
    assert_eq!(stats.images, 10);
}

#[test]
fn ragged_final_batch_reports_real_images_and_matches_other_widths() {
    // 5 samples at batch 2: three chunks, the last half-filled. Reported
    // counts must be the real 5 images, not batches × batch = 6.
    let ragged = spec("carol", 11, 2, 5);
    let (result, st) = solo(&ragged, 201);
    assert_eq!(result.images, 5, "padding slots must not count as scored images");
    assert_eq!(result.batches, 3, "the ragged tail is still a scored chunk");
    assert_eq!(st.images, 5);
    assert_eq!(st.step, 3);
    assert_eq!(st.total_steps, 3);
    assert_live_matches_predicted(&st);

    // Slot independence: the same 5 samples scored in one batch-5 pass
    // decode to the same rows, so the digests are width-invariant.
    let wide = spec("carol", 11, 5, 5);
    let (wide_result, _) = solo(&wide, 202);
    assert_eq!(
        result.logits_digest, wide_result.logits_digest,
        "logits must not depend on the batch width they were scored at"
    );
    assert_eq!(result.predictions_digest, wide_result.predictions_digest);
    assert_eq!(result.accuracy, wide_result.accuracy);
    assert_eq!(wide_result.images, 5);
    assert_eq!(wide_result.batches, 1);
}

#[test]
fn coalesced_fhe_scores_are_byte_identical_to_solo() {
    // Real FHE at Test-profile parameters: encryption noise differs
    // between the solo and coalesced paths, but BGV decryption is exact,
    // so the decoded logit rows — and therefore the digests — must agree.
    let mut a = spec("alice", 13, 1, 2);
    a.backend = JobBackend::Fhe;
    a.profile = EngineProfile::Test;
    a.dims = vec![8, 4, 3];
    let mut b = a.clone();
    b.tenant = "bob".into();

    let (solo_a, _) = solo(&a, 301);
    let (solo_b, _) = solo(&b, 302);

    let ha = JobHandle::new_infer(1, a);
    let hb = JobHandle::new_infer(2, b);
    let (outcomes, stats) =
        run_infer_group(&[&ha, &hb], None, 9).expect("coalesced FHE group run failed");
    for (handle, reference) in [(&ha, &solo_a), (&hb, &solo_b)] {
        let (_, outcome) = outcomes.iter().find(|(id, _)| *id == handle.id).unwrap();
        let InferOutcome::Completed(result) = outcome else {
            panic!("FHE member {} did not complete", handle.id)
        };
        assert_same_scores("coalesced vs solo (FHE)", result, reference);
        assert_live_matches_predicted(&handle.status());
    }
    assert_eq!(stats.filled_slots, stats.total_slots, "both members fill every pass");
}

#[test]
fn packed_coalesced_scores_match_solo_packed() {
    // The cross-sample SIMD layout composes with coalescing: the group
    // packs at width members × batch, with a masked ragged tail.
    let mut a = spec("alice", 17, 2, 4);
    a.packed = true;
    let mut b = spec("bob", 17, 2, 3);
    b.packed = true;

    let (solo_a, _) = solo(&a, 401);
    let (solo_b, _) = solo(&b, 402);

    let ha = JobHandle::new_infer(1, a);
    let hb = JobHandle::new_infer(2, b);
    let (outcomes, _) =
        run_infer_group(&[&ha, &hb], None, 5).expect("packed coalesced group run failed");
    for (handle, reference) in [(&ha, &solo_a), (&hb, &solo_b)] {
        let (_, outcome) = outcomes.iter().find(|(id, _)| *id == handle.id).unwrap();
        let InferOutcome::Completed(result) = outcome else {
            panic!("packed member {} did not complete", handle.id)
        };
        assert_same_scores("packed coalesced vs solo", result, reference);
        assert_live_matches_predicted(&handle.status());
    }
}

#[test]
fn cancelled_member_vacates_without_perturbing_the_survivor() {
    let a = spec("alice", 23, 2, 4);
    let b = spec("bob", 23, 2, 4);
    let (solo_a, _) = solo(&a, 501);

    let ha = JobHandle::new_infer(1, a);
    let hb = JobHandle::new_infer(2, b);
    hb.cancel.store(true, Ordering::Relaxed);
    let (outcomes, stats) =
        run_infer_group(&[&ha, &hb], None, 6).expect("group with a cancelled member failed");

    let (_, outcome_b) = outcomes.iter().find(|(id, _)| *id == 2).unwrap();
    assert!(matches!(outcome_b, InferOutcome::Cancelled), "cancelled member must not complete");
    assert_eq!(hb.status().state, JobState::Cancelled);

    let (_, outcome_a) = outcomes.iter().find(|(id, _)| *id == 1).unwrap();
    let InferOutcome::Completed(result_a) = outcome_a else {
        panic!("surviving member did not complete")
    };
    assert_same_scores("survivor vs solo", result_a, &solo_a);
    assert_eq!(ha.status().state, JobState::Completed);
    assert_live_matches_predicted(&ha.status());

    // bob never occupied a slot: 2 passes × width 4, alice's half filled
    assert_eq!(stats.total_slots, 8);
    assert_eq!(stats.filled_slots, 4);
}

#[test]
fn lane_incompatible_jobs_are_refused() {
    let a = spec("alice", 29, 2, 4);
    let mut b = spec("bob", 29, 2, 4);
    b.dims = vec![16, 4, 4];

    let ha = JobHandle::new_infer(1, a);
    let hb = JobHandle::new_infer(2, b);
    let err = run_infer_group(&[&ha, &hb], None, 3)
        .err()
        .expect("jobs with different shapes must not share a batch group");
    let msg = err.to_string();
    assert!(msg.contains("lane"), "error must name the lane mismatch: {msg}");
}
