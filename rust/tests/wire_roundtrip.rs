//! Wire-format conformance (PR 7): every [`WireCodec`] type must round-trip
//! bit-identically, reject truncated/corrupted/foreign/future-versioned
//! bytes with a descriptive [`WireError`] (never a panic), and keep its
//! byte layout pinned by the golden fixture in `tests/data/wire_golden.hex`
//! — any unintentional format drift breaks CI loudly.

use glyph::bgv::ciphertext::BgvCiphertext;
use glyph::bgv::params::BgvParams;
use glyph::coordinator::metrics::OpSnapshot;
use glyph::math::GlyphRng;
use glyph::nn::backend::{ClearCt, Codec, Ct};
use glyph::nn::engine::{ClientKeys, EngineProfile, FheState, GlyphEngine};
use glyph::nn::tensor::PackedLayout;
use glyph::serve::job::{compiled_plan, weights_digest};
use glyph::serve::{
    InferResult, InferSpec, JobBackend, JobKind, JobResult, JobSpec, JobState, JobStatus, Request,
    Response,
};
use glyph::tfhe::lwe::LweCiphertext;
use glyph::tfhe::params::TfheParams;
use glyph::train::{GlyphMlp, MlpConfig};
use glyph::wire::{fnv1a64, Checkpoint, WireCodec, WireError, CHECKSUM_LEN, HEADER_LEN};

fn to_hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn from_hex(s: &str) -> Vec<u8> {
    (0..s.len() / 2).map(|i| u8::from_str_radix(&s[2 * i..2 * i + 2], 16).unwrap()).collect()
}

/// Round-trip `v` through its wire frame and require bit identity on
/// re-encode (the strongest equality the codecs promise: decode followed by
/// encode reproduces the exact input bytes).
fn assert_reencode<T: WireCodec>(v: &T, ctx: &T::Ctx, what: &str) -> T {
    let bytes = v.to_wire();
    let back = T::from_wire(&bytes, ctx).unwrap_or_else(|e| panic!("{what}: decode failed: {e}"));
    assert_eq!(back.to_wire(), bytes, "{what}: re-encode is not bit-identical");
    back
}

/// Overwrite one byte and refresh the trailing checksum, so the tampered
/// field — not the checksum — is what decode trips over.
fn patched(mut bytes: Vec<u8>, idx: usize, val: u8) -> Vec<u8> {
    bytes[idx] = val;
    let at = bytes.len() - CHECKSUM_LEN;
    let sum = fnv1a64(&bytes[..at]);
    bytes[at..].copy_from_slice(&sum.to_le_bytes());
    bytes
}

fn sample_spec() -> JobSpec {
    JobSpec::small_clear("golden", 7)
}

fn sample_status() -> JobStatus {
    JobStatus {
        id: 3,
        tenant: "acme".into(),
        kind: JobKind::Train,
        state: JobState::Running,
        epoch: 1,
        step: 9,
        total_steps: 16,
        checkpoints: 2,
        resumes: 1,
        live_ops: OpSnapshot { mult_cc: 40, add_cc: 41, relin: 5, ..Default::default() },
        predicted_ops: OpSnapshot { mult_cc: 40, add_cc: 41, ..Default::default() },
        images: 0,
        seconds: 0.0,
        group: 0,
        message: String::new(),
    }
}

fn sample_infer_spec() -> InferSpec {
    let mut spec = InferSpec::small_clear("acme", 31);
    spec.model_job = 12;
    spec.coalesce = true;
    spec
}

fn sample_infer_result() -> InferResult {
    InferResult {
        id: 13,
        images: 16,
        batches: 4,
        seconds: 0.75,
        accuracy: 0.8125,
        ops: OpSnapshot { mult_cp: 320, switch_b2t: 64, ..Default::default() },
        logits_digest: 0xfeed_face_0042_4242,
        predictions_digest: 0x1357_9bdf_0246_8ace,
    }
}

fn sample_result() -> JobResult {
    JobResult {
        id: 3,
        steps: 16,
        seconds: 1.25,
        accuracy: 0.5,
        ops: OpSnapshot { mult_cc: 640, ..Default::default() },
        weights_digest: 0xdead_beef_cafe_f00d,
        logits_digest: 0x0123_4567_89ab_cdef,
        resumes: 1,
    }
}

#[test]
fn self_contained_types_roundtrip_bit_identically() {
    let bgv = BgvParams { n: 8, primes: vec![97, 193], t: 16, sigma: 3.2, prime_align: 2 };
    let back = assert_reencode(&bgv, &(), "BgvParams");
    assert_eq!((back.n, back.primes, back.t), (8, vec![97, 193], 16));
    assert_reencode(&BgvParams::test_params(), &(), "BgvParams::test_params");

    let back = assert_reencode(&TfheParams::test_params(), &(), "TfheParams");
    assert_eq!((back.n, back.big_n), (64, 512));
    assert_reencode(&TfheParams::default_params(), &(), "TfheParams::default_params");

    let snap = OpSnapshot { mult_cc: 1, repack_lanes: 13, ..Default::default() };
    assert_eq!(assert_reencode(&snap, &(), "OpSnapshot"), snap);

    let rng = GlyphRng::from_state([1, 2, 3, u64::MAX]);
    let back = assert_reencode(&rng, &(), "GlyphRng");
    assert_eq!(back.state(), rng.state());

    let ct = ClearCt { n: 8, t: 256, coeffs: vec![0, 1, 2, 255] };
    assert_eq!(assert_reencode(&ct, &(), "ClearCt"), ct);

    let lwe = LweCiphertext { a: vec![1, 2, 3], b: 0xdead_beef };
    let back = assert_reencode(&lwe, &(), "LweCiphertext");
    assert_eq!((back.a, back.b), (vec![1, 2, 3], 0xdead_beef));

    assert_eq!(assert_reencode(&sample_spec(), &(), "JobSpec"), sample_spec());
    assert_reencode(&sample_status(), &(), "JobStatus");
    assert_eq!(assert_reencode(&sample_result(), &(), "JobResult"), sample_result());

    // the inference workload's frames (PR: forward-only inference)
    assert_eq!(
        assert_reencode(&sample_infer_spec(), &(), "InferSpec"),
        sample_infer_spec()
    );
    assert_eq!(
        assert_reencode(&sample_infer_result(), &(), "InferResult"),
        sample_infer_result()
    );
    let infer_status = JobStatus {
        kind: JobKind::Infer,
        images: 16,
        seconds: 0.75,
        group: 5,
        ..sample_status()
    };
    let back = assert_reencode(&infer_status, &(), "JobStatus (infer)");
    assert_eq!(back.kind, JobKind::Infer);
    assert_eq!(back.images, 16);
    assert_eq!(back.group, 5);
    let back = assert_reencode(&sample_infer_spec(), &(), "InferSpec (coalesce)");
    assert!(back.coalesce && !back.packed);

    // packed-layout metadata: dense, sparse-occupancy and partial-batch
    let dense = PackedLayout::for_ring(8, 256).unwrap();
    assert_eq!(assert_reencode(&dense, &(), "PackedLayout (dense)"), dense);
    let sparse =
        PackedLayout::for_ring(4, 64).unwrap().with_occupancy(vec![true, false, true, false]);
    assert_eq!(assert_reencode(&sparse, &(), "PackedLayout (sparse)"), sparse);
    let partial = PackedLayout::for_ring(3, 32).unwrap().with_occupancy(vec![true, true, false]);
    assert_eq!(assert_reencode(&partial, &(), "PackedLayout (partial batch)"), partial);

    // a compiled plan (the checkpoint binds to its hash)
    let plan = compiled_plan(&sample_spec()).expect("spec compiles");
    assert!(!plan.steps.is_empty());
    assert_reencode(&plan, &(), "Plan");

    // every protocol message variant
    let requests = [
        Request::Submit(sample_spec()),
        Request::Status { id: 1 },
        Request::Cancel { id: 2 },
        Request::FetchResult { id: 3 },
        Request::Metrics,
        Request::Ping,
        Request::Shutdown,
        Request::SubmitInfer(sample_infer_spec()),
    ];
    for req in &requests {
        assert_reencode(req, &(), "Request");
    }
    let responses = [
        Response::Submitted { id: 1 },
        Response::Status(sample_status()),
        Response::Cancelled { id: 2 },
        Response::Result(sample_result()),
        Response::Metrics("glyph_uptime_seconds 1\n".into()),
        Response::Pong,
        Response::ShuttingDown,
        Response::Error("unknown job 9".into()),
        Response::InferResult(sample_infer_result()),
    ];
    for resp in &responses {
        assert_reencode(resp, &(), "Response");
    }
}

#[test]
fn key_material_and_ciphertexts_roundtrip() {
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 20260807);

    // ClientKeys are structural: coefficients + RNG cursor survive verbatim.
    let ck_back = assert_reencode(&client, &(), "ClientKeys");
    assert_eq!(ck_back.bgv_sk.s_coeffs, client.bgv_sk.s_coeffs);
    assert_eq!(ck_back.rng.state(), client.rng.state());

    // FheState is regenerative: params + seed + cursors rebuild the exact
    // evaluator, including the derived client key.
    let state = engine.fhe();
    let state_back = assert_reencode(state, &(), "FheState");
    assert_eq!(state_back.seed, state.seed);
    assert_eq!(state_back.auth.rng_state(), state.auth.rng_state());
    assert_eq!(state_back.auth.refresh_count(), state.auth.refresh_count());
    assert_eq!(
        state_back.client_keys().bgv_sk.s_coeffs,
        state.client_keys().bgv_sk.s_coeffs,
        "regenerated secret key must match"
    );

    // A real encrypted ciphertext survives both as a bare BgvCiphertext
    // (BgvContext ctx) and as a Ct (GlyphEngine ctx), and still decrypts.
    let values = [17i64, -9];
    let ct = client.encrypt_batch(&values, 0);
    let bgv_back = assert_reencode(ct.fhe(), engine.fhe().ctx.as_ref(), "BgvCiphertext");
    assert_eq!(
        client.decrypt_batch(&Ct::Fhe(bgv_back), 2, 0),
        values.to_vec(),
        "decoded ciphertext must decrypt to the original batch"
    );
    let ct_back = assert_reencode(&ct, &engine, "Ct::Fhe");
    assert_eq!(client.decrypt_batch(&ct_back, 2, 0), values.to_vec());

    // Clear-backend Ct under a clear engine.
    let (clear_engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
    let cct = codec.encrypt_batch(&values, 0);
    let cct_back = assert_reencode(&cct, &clear_engine, "Ct::Clear");
    assert_eq!(codec.decrypt_batch(&cct_back, 2, 0), values.to_vec());

    // An FHE ciphertext must not decode on a clear-backend engine.
    let err = Ct::from_wire(&ct.to_wire(), &clear_engine).unwrap_err();
    assert!(matches!(err, WireError::Malformed(_)), "got {err:?}");
}

#[test]
fn checkpoint_roundtrip_restores_byte_identical_weights() {
    let config = || MlpConfig::for_dims(vec![6, 5, 3], EngineProfile::Test.frac_bits(), 3);
    let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
    let mut rng = GlyphRng::new(11);
    let mlp = GlyphMlp::new_random(config(), &mut codec, &mut rng, &engine).unwrap();
    engine.counter.bump(&engine.counter.mult_cc, 123);

    let ckpt = Checkpoint::capture(&mlp.net, &engine, 77, 1, 9, 0.5, None).unwrap();
    let back = assert_reencode(&ckpt, &engine, "Checkpoint");
    assert_eq!((back.job_seed, back.epoch, back.step), (77, 1, 9));
    assert_eq!(back.ops.mult_cc, 123);

    // Restore into a *differently initialized* net of the same shape: the
    // weights and counters must come back byte-identical to the source.
    let (engine2, mut codec2) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
    let mut rng2 = GlyphRng::new(999);
    let mut mlp2 = GlyphMlp::new_random(config(), &mut codec2, &mut rng2, &engine2).unwrap();
    assert_ne!(weights_digest(&mlp2.net), weights_digest(&mlp.net));
    back.restore(&mut mlp2.net, &engine2).unwrap();
    assert_eq!(weights_digest(&mlp2.net), weights_digest(&mlp.net));
    assert_eq!(engine2.counter.snapshot(), engine.counter.snapshot());

    // A checkpoint refuses to restore under a different compiled plan.
    let other = MlpConfig::for_dims(vec![6, 4, 3], EngineProfile::Test.frac_bits(), 3);
    let mut rng3 = GlyphRng::new(11);
    let mut mlp3 = GlyphMlp::new_random(other, &mut codec2, &mut rng3, &engine2).unwrap();
    assert!(back.restore(&mut mlp3.net, &engine2).is_err());
}

#[test]
fn damaged_frames_error_descriptively_never_panic() {
    let bytes = sample_spec().to_wire();

    // truncation at every prefix length
    for cut in 0..bytes.len() {
        assert!(JobSpec::from_wire(&bytes[..cut], &()).is_err(), "cut at {cut} must error");
    }

    // foreign magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    assert!(matches!(JobSpec::from_wire(&bad, &()), Err(WireError::BadMagic { .. })));

    // a frame of another type
    assert!(matches!(
        JobResult::from_wire(&bytes, &()),
        Err(WireError::WrongTag { expected: _, found: _ })
    ));

    // future format version (checksum refreshed so the version check fires)
    let vbump = patched(bytes.clone(), 8, 0x77);
    assert!(matches!(
        JobSpec::from_wire(&vbump, &()),
        Err(WireError::UnsupportedVersion { found: 0x77, .. })
    ));

    // trailing junk
    let mut long = bytes.clone();
    long.push(0);
    assert!(matches!(JobSpec::from_wire(&long, &()), Err(WireError::BadLength { .. })));

    // single flipped body bit → checksum catches it
    let mut corrupt = bytes.clone();
    corrupt[HEADER_LEN + 3] ^= 0x10;
    assert!(matches!(JobSpec::from_wire(&corrupt, &()), Err(WireError::ChecksumMismatch { .. })));

    // infer frames ride the same header/checksum machinery
    let ibytes = sample_infer_spec().to_wire();
    for cut in 0..ibytes.len() {
        assert!(InferSpec::from_wire(&ibytes[..cut], &()).is_err(), "cut at {cut} must error");
    }
    assert!(matches!(InferResult::from_wire(&ibytes, &()), Err(WireError::WrongTag { .. })));

    // structurally valid frame, semantically bad contents
    let ping = Request::Ping.to_wire();
    let bad_variant = patched(ping, HEADER_LEN, 99);
    assert!(matches!(Request::from_wire(&bad_variant, &()), Err(WireError::Malformed(_))));

    let bad_ct = ClearCt { n: 8, t: 16, coeffs: vec![0, 300] };
    assert!(matches!(ClearCt::from_wire(&bad_ct.to_wire(), &()), Err(WireError::Malformed(_))));

    // packed layouts with broken invariants must not decode: a stride that
    // cannot isolate the cross-sample spread, and a mask of the wrong width
    let understrided = PackedLayout { batch: 8, stride: 4, feats_per_ct: 2, occupancy: None };
    assert!(matches!(
        PackedLayout::from_wire(&understrided.to_wire(), &()),
        Err(WireError::Malformed(_))
    ));
    let short_mask =
        PackedLayout { batch: 4, stride: 8, feats_per_ct: 2, occupancy: Some(vec![true]) };
    assert!(matches!(
        PackedLayout::from_wire(&short_mask.to_wire(), &()),
        Err(WireError::Malformed(_))
    ));
}

/// The values pinned by `tests/data/wire_golden.hex`, in file order.
fn golden_values() -> Vec<(&'static str, Vec<u8>)> {
    vec![
        (
            "bgv_params",
            BgvParams { n: 8, primes: vec![97, 193], t: 16, sigma: 3.2, prime_align: 2 }.to_wire(),
        ),
        ("tfhe_params", TfheParams::test_params().to_wire()),
        (
            "op_snapshot",
            OpSnapshot::from_fields(
                OpSnapshot::default().fields().iter().zip(1u64..).map(|(&(n, _), v)| (n, v)),
            )
            .unwrap()
            .to_wire(),
        ),
        (
            "glyph_rng",
            GlyphRng::from_state([
                0x0123_4567_89ab_cdef,
                0x1122_3344_5566_7788,
                0xdead_beef_cafe_babe,
                0x0f1e_2d3c_4b5a_6978,
            ])
            .to_wire(),
        ),
        ("clear_ct", ClearCt { n: 8, t: 256, coeffs: vec![0, 1, 2, 255] }.to_wire()),
        ("lwe_ct", LweCiphertext { a: vec![1, 2, 3], b: 0xdead_beef }.to_wire()),
        ("job_spec", sample_spec().to_wire()),
        ("request_ping", Request::Ping.to_wire()),
        ("response_pong", Response::Pong.to_wire()),
    ]
}

/// The values pinned by `tests/data/packing_golden.hex`, in file order:
/// the PackedLayout frame (dense + sparse occupancy) and the
/// `pack_columns` coefficient placement, frozen through ClearCt blocks.
fn packing_golden_values() -> Vec<(&'static str, Vec<u8>)> {
    let dense = PackedLayout::for_ring(8, 256).unwrap();
    let small = PackedLayout::for_ring(2, 16).unwrap(); // stride 4, F = 2
    let sparse = small.clone().with_occupancy(vec![true, false]);
    let cols = vec![vec![1i64, 2], vec![3, 4], vec![5, 6]];
    let blocks: Vec<Vec<u8>> = small
        .pack_columns(&cols, 16)
        .iter()
        .map(|coeffs| {
            ClearCt {
                n: 16,
                t: 256,
                coeffs: coeffs.iter().map(|&v| v.rem_euclid(256) as u64).collect(),
            }
            .to_wire()
        })
        .collect();
    vec![
        ("packed_layout_dense", dense.to_wire()),
        ("packed_layout_sparse", sparse.to_wire()),
        ("packed_block0", blocks[0].clone()),
        ("packed_block1", blocks[1].clone()),
    ]
}

#[test]
fn packing_golden_fixture_locks_layout_bytes_and_slot_placement() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/packing_golden.hex");
    let live = packing_golden_values();

    if std::env::var("GLYPH_BLESS_GOLDEN").as_deref() == Ok("1") {
        let mut out = String::from(
            "# Golden wire fixtures for the cross-sample SIMD packing layer:\n\
             # `<name> <hex of WireCodec::to_wire()>`. Pins both the PackedLayout frame\n\
             # format (tag PKLY) and the pack_columns coefficient placement (feature j,\n\
             # sample b at (j mod F)\u{b7}stride + b) through a ClearCt block. Any byte drift\n\
             # is a format break; bump the frame VERSION and re-bless with\n\
             # GLYPH_BLESS_GOLDEN=1 cargo test --test wire_roundtrip.\n",
        );
        for (name, bytes) in &live {
            out.push_str(&format!("{name} {}\n", to_hex(bytes)));
        }
        std::fs::write(path, out).unwrap();
        eprintln!("[blessed {path}]");
        return;
    }

    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("missing golden fixture {path}: {e}"));
    let mut pinned = std::collections::HashMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, hex) = line.split_once(' ').expect("fixture line is `<name> <hex>`");
        pinned.insert(name.to_string(), hex.to_string());
    }
    assert_eq!(pinned.len(), live.len(), "fixture entry count drifted");
    for (name, bytes) in &live {
        let want = pinned.get(*name).unwrap_or_else(|| panic!("fixture has no entry {name}"));
        let got = to_hex(bytes);
        assert_eq!(
            &got, want,
            "packing wire format of {name} drifted from the golden fixture — if \
             intentional, bump the frame VERSION and re-bless with GLYPH_BLESS_GOLDEN=1"
        );
    }
    // and the pinned layout bytes still decode to the live geometry
    let dense = PackedLayout::from_wire(&from_hex(&pinned["packed_layout_dense"]), &()).unwrap();
    assert_eq!((dense.batch, dense.stride, dense.feats_per_ct), (8, 16, 8));
    assert_eq!(dense.occupancy, None);
    let sparse = PackedLayout::from_wire(&from_hex(&pinned["packed_layout_sparse"]), &()).unwrap();
    assert_eq!((sparse.batch, sparse.stride, sparse.feats_per_ct), (2, 4, 2));
    assert_eq!(sparse.occupancy, Some(vec![true, false]));
}

#[test]
fn golden_fixture_locks_the_byte_format() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/wire_golden.hex");
    let live = golden_values();

    if std::env::var("GLYPH_BLESS_GOLDEN").as_deref() == Ok("1") {
        let mut out = String::from(
            "# Golden wire-format fixtures: `<name> <hex of WireCodec::to_wire()>`.\n\
             # Any byte drift here is a format break; bump the frame VERSION and\n\
             # re-bless with GLYPH_BLESS_GOLDEN=1 cargo test --test wire_roundtrip.\n",
        );
        for (name, bytes) in &live {
            out.push_str(&format!("{name} {}\n", to_hex(bytes)));
        }
        std::fs::write(path, out).unwrap();
        eprintln!("[blessed {path}]");
        return;
    }

    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("missing golden fixture {path}: {e}"));
    let mut pinned = std::collections::HashMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, hex) = line.split_once(' ').expect("fixture line is `<name> <hex>`");
        pinned.insert(name.to_string(), hex.to_string());
    }
    assert_eq!(pinned.len(), live.len(), "fixture entry count drifted");
    for (name, bytes) in &live {
        let want = pinned.get(*name).unwrap_or_else(|| panic!("fixture has no entry {name}"));
        let got = to_hex(bytes);
        assert_eq!(
            &got, want,
            "wire format of {name} drifted from the golden fixture — if intentional, \
             bump the frame VERSION and re-bless with GLYPH_BLESS_GOLDEN=1"
        );
        // and the pinned bytes still decode (backward readability)
        match *name {
            "job_spec" => {
                assert_eq!(JobSpec::from_wire(&from_hex(want), &()).unwrap(), sample_spec());
            }
            "op_snapshot" => {
                let s = OpSnapshot::from_wire(&from_hex(want), &()).unwrap();
                assert_eq!(s.mult_cc, 1);
                assert_eq!(s.repack_lanes, 13);
            }
            _ => {}
        }
    }
}
