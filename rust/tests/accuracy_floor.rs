//! Epoch-scale accuracy floors on the clear backend — the paper's headline
//! *accuracy* claims, continuously testable in CI because the clear mirror
//! runs full epochs in seconds while computing exactly what the encrypted
//! pipeline would decrypt to (on grid-aligned crossings, with identical
//! quantization/rounding everywhere).
//!
//! Three scenarios, each fixed-seed and bounded well under 30 s:
//!   1. `synthetic_digits`: 2 clear epochs of a Glyph MLP beat a recorded
//!      accuracy floor and the untrained network by a wide margin;
//!   2. an MNIST subset (the IDX loader's deterministic synthetic fallback
//!      in this environment) through the `Trainer` epoch loop;
//!   3. the paper's qualitative FHESGD-vs-Glyph claim: at an equal SGD-step
//!      budget (the mirror of equal wall-time — FHESGD's per-sample cost is
//!      orders of magnitude higher, Table 2 vs 3), the Glyph pipeline
//!      reaches far higher test accuracy than the batch-1 sigmoid-TLU
//!      baseline.
//!
//! Hyperparameters were recorded from clear-backend sweeps (EXPERIMENTS.md
//! §Backends & accuracy reproduction); floors leave generous slack under
//! the recorded values so dataset-generator rounding can never flake CI.

use glyph::math::GlyphRng;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::train::{FhesgdMlp, GlyphMlp, MlpConfig, Trainer};

/// The recorded robust configuration: 196 evenly-sampled pixels, one
/// 64-wide ReLU hidden layer, 8-bit softmax, grad_shift 12 (≈ the paper's
/// shift schedule scaled to the test topology).
fn digits_config(hidden: usize) -> MlpConfig {
    MlpConfig {
        dims: vec![196, hidden, 10],
        act_shifts: vec![8, 8],
        err_shifts: vec![8, 8],
        grad_shift: 12,
        softmax_bits: 8,
    }
}

fn build_trainer(config: MlpConfig, net_seed: u64, engine: &GlyphEngine, codec: &mut glyph::nn::backend::ClearCodec) -> Trainer {
    let classes = *config.dims.last().unwrap();
    let mut rng = GlyphRng::new(net_seed);
    let mlp = GlyphMlp::new_random(config, codec, &mut rng, engine).expect("config builds");
    Trainer::new(mlp.net, classes)
}

#[test]
fn clear_training_beats_accuracy_floor_on_synthetic_digits() {
    let batch = 8;
    let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Default, batch);
    let mut trainer = build_trainer(digits_config(64), 7, &engine, &mut codec);
    let train = glyph::data::synthetic_digits(256, 5, "digits-train");
    let test = glyph::data::synthetic_digits(128, 99, "digits-test");
    let untrained = trainer.evaluate(&test, 128, &engine, &mut codec).unwrap();
    for _ in 0..2 {
        trainer.train_epoch(&train, &engine, &mut codec).unwrap();
    }
    let acc = trainer.evaluate(&test, 128, &engine, &mut codec).unwrap();
    // recorded: ≈0.81 at this seed; chance is 0.10
    assert!(acc >= 0.55, "digits accuracy {acc:.3} under the 0.55 floor");
    assert!(
        acc >= untrained + 0.2,
        "training must add ≥0.2 accuracy over the untrained net ({untrained:.3} → {acc:.3})"
    );
}

#[test]
fn clear_training_beats_accuracy_floor_on_mnist_subset() {
    let batch = 8;
    let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Default, batch);
    let mut trainer = build_trainer(digits_config(64), 7, &engine, &mut codec);
    // loads real IDX files when present; deterministic synthetic fallback
    // otherwise (data module docs)
    let train = glyph::data::mnist(true, 256, 11);
    let test = glyph::data::mnist(false, 128, 131);
    let mut stats = None;
    for _ in 0..2 {
        stats = Some(trainer.train_epoch(&train, &engine, &mut codec).unwrap());
    }
    let stats = stats.unwrap();
    assert_eq!(stats.samples, 256);
    let acc = trainer.evaluate(&test, 128, &engine, &mut codec).unwrap();
    // recorded: ≈0.83 at this seed on the synthetic fallback
    assert!(acc >= 0.55, "MNIST-subset accuracy {acc:.3} under the 0.55 floor");
}

#[test]
fn glyph_beats_fhesgd_at_equal_step_budget() {
    let steps = 64usize;
    let train = glyph::data::synthetic_digits(512, 5, "ordering-train");
    let test = glyph::data::synthetic_digits(128, 99, "ordering-test");

    // Glyph: 64 mini-batch steps at batch 8
    let (engine_g, mut codec_g) = GlyphEngine::setup_clear(EngineProfile::Default, 8);
    let mut glyph_trainer = build_trainer(digits_config(32), 7, &engine_g, &mut codec_g);
    glyph_trainer.train_steps(&train, steps, &engine_g, &mut codec_g).unwrap();
    let glyph_acc = glyph_trainer.evaluate(&test, 128, &engine_g, &mut codec_g).unwrap();

    // FHESGD baseline: 64 single-sample steps (its packing is batch-1; the
    // per-step homomorphic cost is orders of magnitude higher — Table 2)
    let (engine_b, mut codec_b) = GlyphEngine::setup_clear(EngineProfile::Default, 1);
    let mut rng = GlyphRng::new(7);
    let baseline = FhesgdMlp::new_random(
        vec![196, 32, 10],
        vec![8, 8],
        12,
        8,
        &mut codec_b,
        &mut rng,
        &engine_b,
        true,
    )
    .expect("baseline builds");
    let mut fhesgd_trainer = Trainer::new(baseline.net, 10);
    fhesgd_trainer.train_steps(&train, steps, &engine_b, &mut codec_b).unwrap();
    let fhesgd_acc = fhesgd_trainer.evaluate(&test, 128, &engine_b, &mut codec_b).unwrap();

    // recorded: ≈0.62 vs ≈0.12 — the paper's qualitative ordering
    assert!(
        glyph_acc >= fhesgd_acc + 0.15,
        "Glyph ({glyph_acc:.3}) must clearly beat FHESGD ({fhesgd_acc:.3}) at an equal step budget"
    );
    assert!(glyph_acc >= 0.40, "Glyph at 64 steps should pass 0.40, got {glyph_acc:.3}");
}
