//! Bit-exactness of the zero-allocation PBS pipeline against the retained
//! reference path: for fixed RNG seeds, the scratch-based external product,
//! CMUX chain, blind rotation, sign bootstrap and every batched fan-out
//! must produce *identical* ciphertexts (same u32 coefficients, not merely
//! close phases) — the scratch rewrite reorders no floating-point op.

use glyph::math::GlyphRng;
use glyph::tfhe::bootstrap::TestPoly;
use glyph::tfhe::lwe::{LweCiphertext, LweKey};
use glyph::tfhe::params::TfheParams;
use glyph::tfhe::scratch::PbsScratch;
use glyph::tfhe::tgsw::TrgswCiphertext;
use glyph::tfhe::tlwe::{TrlweCiphertext, TrlweKey};
use glyph::tfhe::{BootstrapKey, TfheCloudKey, MU_BIT};

fn assert_trlwe_eq(a: &TrlweCiphertext, b: &TrlweCiphertext, what: &str) {
    assert_eq!(a.a, b.a, "{what}: a-component differs");
    assert_eq!(a.b, b.b, "{what}: b-component differs");
}

fn assert_lwe_eq(a: &LweCiphertext, b: &LweCiphertext, what: &str) {
    assert_eq!(a.a, b.a, "{what}: mask differs");
    assert_eq!(a.b, b.b, "{what}: body differs");
}

#[test]
fn external_product_scratch_is_bit_exact() {
    let params = TfheParams::test_params();
    let mut rng = GlyphRng::new(9001);
    let key = TrlweKey::generate(params.big_n, &mut rng);
    let msg: Vec<u32> = (0..params.big_n).map(|_| rng.torus32()).collect();
    let c = TrlweCiphertext::encrypt(&msg, &key, params.alpha_rlwe, &mut rng);
    let mut scratch = PbsScratch::new();
    for bit in [0i32, 1] {
        let g = TrgswCiphertext::encrypt_scalar(bit, &key, &params, &mut rng);
        let reference = g.external_product(&c, &key.fft);
        let fast = g.external_product_scratch(&c, &key.fft, &mut scratch);
        assert_trlwe_eq(&fast, &reference, "external product");
    }
}

#[test]
fn cmux_chain_is_bit_exact() {
    // A 16-step CMUX chain (a mini blind rotation) through cmux_into must
    // track the reference cmux exactly at every step.
    let params = TfheParams::test_params();
    let mut rng = GlyphRng::new(9002);
    let key = TrlweKey::generate(params.big_n, &mut rng);
    let n = params.big_n;
    let msg: Vec<u32> = vec![1u32 << 29; n];
    let mut ref_acc = TrlweCiphertext::trivial(&msg);
    let mut fast_acc = TrlweCiphertext::trivial(&msg);
    let mut scratch = PbsScratch::new();
    for step in 0..16 {
        let bit = (step % 2) as i32;
        let g = TrgswCiphertext::encrypt_scalar(bit, &key, &params, &mut rng);
        let rotated = ref_acc.rotate(step + 1);
        ref_acc = g.cmux(&rotated, &ref_acc, &key.fft);

        let fast_rotated = fast_acc.rotate(step + 1);
        let ring = scratch.ring(n);
        let mut out = TrlweCiphertext::zero(n);
        g.cmux_into(
            &fast_rotated,
            &fast_acc,
            &key.fft,
            &mut ring.dig,
            &mut ring.fft_lane,
            &mut ring.acc_a,
            &mut ring.acc_b,
            &mut ring.diff,
            &mut out,
        );
        fast_acc = out;
        assert_trlwe_eq(&fast_acc, &ref_acc, "cmux chain step");
    }
}

#[test]
fn blind_rotation_and_sign_bootstrap_are_bit_exact() {
    let params = TfheParams::test_params();
    let mut rng = GlyphRng::new(9003);
    let lwe_key = LweKey::generate_binary(params.n, &mut rng);
    let trlwe_key = TrlweKey::generate(params.big_n, &mut rng);
    let bk = BootstrapKey::generate(&lwe_key, &trlwe_key, &params, &mut rng);
    let tv = TestPoly::constant(params.big_n, 1 << 29);
    let mut scratch = PbsScratch::new();
    for msg in [1u32 << 29, 1u32 << 30, (1u32 << 29).wrapping_neg(), 0x1234_5678] {
        let ct = LweCiphertext::encrypt(msg, &lwe_key, params.alpha_lwe, &mut rng);
        let reference = bk.blind_rotate_reference(&ct, &tv);
        let fast = bk.blind_rotate_scratch(&ct, &tv, &mut scratch).clone();
        assert_trlwe_eq(&fast, &reference, "blind rotation");
        // the public bootstrap entry points ride the scratch path
        assert_lwe_eq(&bk.bootstrap(&ct, &tv), &reference.sample_extract(0), "bootstrap");
        assert_lwe_eq(&bk.bootstrap_sign(&ct, 1 << 29), &reference.sample_extract(0), "sign bootstrap");
    }
}

#[test]
fn batched_fan_outs_match_sequential_loops() {
    let params = TfheParams::test_params();
    let mut rng = GlyphRng::new(9004);
    let lwe_key = LweKey::generate_binary(params.n, &mut rng);
    let trlwe_key = TrlweKey::generate(params.big_n, &mut rng);
    let ck = TfheCloudKey::generate(&lwe_key, &trlwe_key, &params, &mut rng);
    let tv = TestPoly::constant(params.big_n, MU_BIT.wrapping_neg());
    let inputs: Vec<LweCiphertext> = (0..12)
        .map(|i| LweCiphertext::encrypt((i as u32) << 27, &lwe_key, params.alpha_lwe, &mut rng))
        .collect();

    // pbs_many == per-item pbs, in order
    let batched = ck.pbs_many(inputs.clone(), &tv);
    for (i, (b, lin)) in batched.iter().zip(&inputs).enumerate() {
        assert_lwe_eq(b, &ck.pbs(lin, &tv), &format!("pbs_many[{i}]"));
    }

    // pbs_raw_many == per-item pbs_raw
    let batched_raw = ck.pbs_raw_many(inputs.clone(), &tv);
    for (i, (b, lin)) in batched_raw.iter().zip(&inputs).enumerate() {
        assert_lwe_eq(b, &ck.pbs_raw(lin, &tv), &format!("pbs_raw_many[{i}]"));
    }

    // and_weighted_raw_many == per-item and_weighted_raw
    let jobs: Vec<(&LweCiphertext, &LweCiphertext, u32)> = inputs
        .iter()
        .enumerate()
        .map(|(i, c)| (c, &inputs[(i + 1) % inputs.len()], 24 + (i as u32 % 8)))
        .collect();
    let batched_w = ck.and_weighted_raw_many(&jobs);
    for (i, (b, &(c1, c2, pos))) in batched_w.iter().zip(&jobs).enumerate() {
        assert_lwe_eq(b, &ck.and_weighted_raw(c1, c2, pos), &format!("and_weighted_raw_many[{i}]"));
    }

    // and_many == per-item and
    let pairs: Vec<(&LweCiphertext, &LweCiphertext)> =
        inputs.iter().zip(inputs.iter().rev()).collect();
    let batched_and = ck.and_many(&pairs);
    for (i, (b, &(c1, c2))) in batched_and.iter().zip(&pairs).enumerate() {
        assert_lwe_eq(b, &ck.and(c1, c2), &format!("and_many[{i}]"));
    }

    // bootstrap_many == per-item bootstrap
    let batched_bk = ck.bk.bootstrap_many(inputs.clone(), &tv);
    for (i, (b, lin)) in batched_bk.iter().zip(&inputs).enumerate() {
        assert_lwe_eq(b, &ck.bk.bootstrap(lin, &tv), &format!("bootstrap_many[{i}]"));
    }
}
