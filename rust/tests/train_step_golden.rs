//! Switch conformance harness, part 3: end-to-end golden test of the
//! switch-engine refactor. One fixed-seed `Network::train_step` on the
//! paper-shaped 3-FC-layer MLP (reduced widths, ReLU hiddens, Figure-4
//! softmax head — the exact unit mix of the paper's Table-3 pipeline) is
//! run twice from identical keys and weights:
//!
//! * once on the retained **serial** switch path (`engine.serial_switch`,
//!   the pre-refactor per-ciphertext / per-lane reference — this is where
//!   the golden values are captured), and
//! * once on the batched scratch **engine** (`switch_down_many` /
//!   `switch_up_many`, the default).
//!
//! The decrypted forward logits and the decrypted post-step weights (hence
//! the weight *deltas* — both runs start from byte-identical weights) must
//! be byte-identical between the two runs: every fan-out job is
//! deterministic and independent, and the refresh authority's RNG draws
//! happen in the same order on both paths — the refactor may not move a
//! single bit of the training computation.

use glyph::math::GlyphRng;
use glyph::nn::engine::{ClientKeys, EngineProfile, GlyphEngine};
use glyph::nn::linear::Weight;
use glyph::nn::network::{Network, NetworkBuilder};
use glyph::nn::tensor::{EncTensor, PackOrder};

const SEED: u64 = 20260728;
const BATCH: usize = 2;

/// The paper MLP's shape (FC-ReLU-FC-ReLU-FC-softmax) at test widths.
fn paper_shaped_mlp(
    client: &mut ClientKeys,
    rng: &mut GlyphRng,
    engine: &GlyphEngine,
) -> Network {
    NetworkBuilder::input_vec(3)
        .fc(3)
        .relu(8, 7)
        .fc(3)
        .relu(7, 7)
        .fc(2)
        .softmax(3, 7)
        .grad_shift(8)
        .build(client, rng, engine)
        .expect("paper-shaped MLP builds")
}

struct RunResult {
    logits: Vec<Vec<i64>>,
    weights: Vec<i64>,
}

fn weight_snapshot(net: &Network, client: &ClientKeys) -> Vec<i64> {
    net.fc_layers()
        .iter()
        .flat_map(|l| {
            l.w.iter().flat_map(|row| {
                row.iter().map(|w| match w {
                    Weight::Enc(ct) => client.decrypt_batch(ct, 1, 0)[0],
                    Weight::Plain(p) => p.value(),
                })
            })
        })
        .collect()
}

/// One fixed-seed forward + train_step; returns decrypted logits and the
/// post-step weight snapshot. `serial` selects the switch path.
fn run(serial: bool) -> RunResult {
    let (mut engine, mut client) = GlyphEngine::setup(EngineProfile::Test, BATCH, SEED);
    engine.serial_switch = serial;
    let mut rng = GlyphRng::new(SEED ^ 0x90);
    let mut net = paper_shaped_mlp(&mut client, &mut rng, &engine);

    let x_cols = [vec![40i64, -20], vec![10, 30], vec![-5, 25]];
    let x_cts = x_cols.iter().map(|v| client.encrypt_batch(v, 0)).collect();
    let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
    let labels = EncTensor::new(
        vec![client.encrypt_batch(&[0, 127], 0), client.encrypt_batch(&[127, 0], 0)],
        vec![2],
        PackOrder::Reversed,
        0,
    );

    // capture the forward logits (softmax head output, reverse-packed)
    let pass = net.forward(&x, &engine);
    let logits: Vec<Vec<i64>> =
        pass.output().cts.iter().map(|ct| client.decrypt_batch(ct, BATCH, 0)).collect();

    // the full mini-batch step (re-runs forward internally — both paths
    // replay the identical op sequence, so the authority RNG stays aligned)
    net.train_step(&x, &labels, &engine);
    let weights = weight_snapshot(&net, &client);
    RunResult { logits, weights }
}

#[test]
fn batched_switch_train_step_is_byte_identical_to_serial_reference() {
    let reference = run(true); // golden values: the retained serial path
    let batched = run(false); // the scratch-backed switch engine

    assert_eq!(
        reference.logits, batched.logits,
        "forward logits must decrypt byte-identically across switch paths"
    );
    assert_eq!(
        reference.weights, batched.weights,
        "post-step weights (hence weight deltas) must decrypt byte-identically"
    );
    // sanity: the step actually trained — golden equality of two no-op runs
    // would be vacuous
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, BATCH, SEED);
    let mut rng = GlyphRng::new(SEED ^ 0x90);
    let fresh = paper_shaped_mlp(&mut client, &mut rng, &engine);
    let initial = weight_snapshot(&fresh, &client);
    assert_eq!(initial.len(), reference.weights.len());
    assert_ne!(initial, reference.weights, "the golden step must move at least one weight");
}
