//! Switch conformance harness, part 1: seeded randomized round-trip
//! property tests for the batch-parallel scheme-switch engine.
//!
//! The property: `to_bits_positions` ∘ (weighted-gate recomposition) ∘
//! `pack_at_and_raise` is the IDENTITY on quantized plaintexts — for every
//! supported value bit width (1..=8), across BGV levels, lane counts,
//! sparse coefficient-position sets and plaintext moduli. The recomposition
//! runs the real `and_weighted_raw` gate bootstraps against an encrypted
//! TRUE, so every lattice stage of the switch is exercised: Δ map, sample
//! extraction, LWE key switch, PBS digit extraction, weighted gates,
//! packing key switch, modulus raise.
//!
//! Every assertion carries the failing case's seed so a red run is
//! reproducible: set `GLYPH_PROP_SEED` to replay a base seed (the
//! `ntt_properties.rs` convention).

use glyph::bgv::{BgvContext, BgvParams, BgvSecretKey, KeyAuthority, Plaintext};
use glyph::math::modarith::gen_ntt_primes;
use glyph::math::GlyphRng;
use glyph::switch::extract::bit_position;
use glyph::switch::{LweExtractor, Repacker, SwitchError, SWITCH_BITS};
use glyph::tfhe::{encode_bit, LweCiphertext, LweKey, TfheCloudKey, TfheParams, TrlweKey};
use std::sync::Arc;

fn base_seed() -> u64 {
    std::env::var("GLYPH_PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x5317_c45e_ed00_4242)
}

struct Fixture {
    ctx: Arc<BgvContext>,
    sk: Arc<BgvSecretKey>,
    gate_lwe_key: LweKey,
    gate_ck: TfheCloudKey,
    extract_ck: TfheCloudKey,
    fwd: LweExtractor,
    bwd: Repacker,
    auth: Arc<KeyAuthority>,
    rng: GlyphRng,
}

/// Full switch fixture over a *custom* plaintext modulus `t` (the test
/// primes are ≡ 1 mod 2^26, so any power-of-two `t` up to 2^26 keeps the
/// Δ maps exact — the modulus sweep below relies on this).
fn fixture_with_t(t: u64, seed: u64) -> Fixture {
    let align = 1u64 << 26;
    let params = BgvParams {
        n: 256,
        primes: gen_ntt_primes(3, align, 1u64 << 32),
        t,
        sigma: 3.2,
        prime_align: align,
    };
    let ctx = BgvContext::new(params);
    let mut rng = GlyphRng::new(seed);
    let sk = Arc::new(BgvSecretKey::generate(&ctx, &mut rng));
    let tfhe = TfheParams::test_params();
    let lwe_key = LweKey::generate_binary(tfhe.n, &mut rng);
    let gate_ring = TrlweKey::generate(tfhe.big_n, &mut rng);
    let gate_ck = TfheCloudKey::generate(&lwe_key, &gate_ring, &tfhe, &mut rng);
    let ext = TfheParams::test_extract_params();
    let ext_ring = TrlweKey::generate(ext.big_n, &mut rng);
    let extract_ck = TfheCloudKey::generate(&lwe_key, &ext_ring, &ext, &mut rng);
    let fwd = LweExtractor::generate(&sk, &lwe_key, &ext, &mut rng);
    let bwd = Repacker::generate(&gate_ring, &sk, &mut rng);
    let auth = KeyAuthority::new(sk.clone(), GlyphRng::new(seed ^ 0xa77));
    Fixture { ctx, sk, gate_lwe_key: lwe_key, gate_ck, extract_ck, fwd, bwd, auth, rng }
}

impl Fixture {
    /// Homomorphic identity recomposition: AND every delivered bit with an
    /// encrypted TRUE at its weighted torus position (`2^(24+i)` grid) and
    /// sum — the exact contract the activation gates satisfy.
    fn recompose(&mut self, lane_bits: &[LweCiphertext]) -> LweCiphertext {
        let truth = LweCiphertext::encrypt(
            encode_bit(true),
            &self.gate_lwe_key,
            self.gate_ck.params.alpha_lwe,
            &mut self.rng,
        );
        let mut acc: Option<LweCiphertext> = None;
        for (i, b) in lane_bits.iter().enumerate() {
            let w = self.gate_ck.and_weighted_raw(b, &truth, bit_position(i));
            match &mut acc {
                None => acc = Some(w),
                Some(a) => a.add_assign(&w),
            }
        }
        acc.expect("SWITCH_BITS ≥ 1")
    }
}

/// One round trip at `level`: encrypt `values` (pre-quantized to the top 8
/// bits of `t`) at sparse `positions`, switch down to two's-complement
/// bits, recompose through the weighted gates, pack back at the SAME
/// positions and raise; the decryption must equal `values` identically.
fn assert_round_trip(
    f: &mut Fixture,
    values: &[i64],
    positions: &[usize],
    level: usize,
    seed: u64,
) {
    let t = f.ctx.params.t;
    let frac = t.trailing_zeros() - SWITCH_BITS;
    let n = f.ctx.params.n;
    let mut coeffs = vec![0i64; n];
    for (v, &p) in values.iter().zip(positions) {
        coeffs[p] = v << frac;
    }
    let pt = Plaintext::encode_batch(&coeffs, &f.ctx.params);
    let mut ct = f.sk.encrypt(&pt, &mut f.rng);
    ct.mod_switch_to(level, &f.ctx);
    let bits = f
        .fwd
        .to_bits_positions(&ct, positions, &f.extract_ck)
        .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    assert_eq!(bits.len(), positions.len(), "seed {seed}");
    assert!(bits.iter().all(|b| b.len() == SWITCH_BITS as usize), "seed {seed}");
    let recomposed: Vec<LweCiphertext> = bits.iter().map(|b| f.recompose(b)).collect();
    let out = f.bwd.pack_at_and_raise(&recomposed, positions, &f.auth);
    let got = f.sk.decrypt(&out);
    for (v, &p) in values.iter().zip(positions) {
        assert_eq!(
            got.coeffs[p], *v,
            "seed {seed}: position {p}, level {level}, t=2^{}",
            t.trailing_zeros()
        );
    }
    // positions that were never packed come back exactly zero
    if let Some(free) = (0..n).find(|p| !positions.contains(p)) {
        assert_eq!(got.coeffs[free], 0, "seed {seed}: untouched position {free}");
    }
}

/// Random signed value fitting in `width` bits (two's complement).
fn rand_value(rng: &mut GlyphRng, width: u32) -> i64 {
    let span = 1u64 << width; // [−2^(w−1), 2^(w−1))
    (rng.uniform_mod(span) as i64) - (span as i64 / 2)
}

#[test]
fn round_trip_is_identity_for_every_bit_width() {
    let seed = base_seed();
    let mut f = fixture_with_t(1 << 16, seed);
    for width in 1..=SWITCH_BITS {
        let case_seed = seed ^ (u64::from(width) << 32);
        let mut vr = GlyphRng::new(case_seed);
        let values: Vec<i64> = (0..3).map(|_| rand_value(&mut vr, width)).collect();
        let positions: Vec<usize> = vec![0, 1, 2];
        assert_round_trip(&mut f, &values, &positions, f.ctx.top_level(), case_seed);
    }
}

#[test]
fn round_trip_survives_sparse_positions_levels_and_lane_counts() {
    let seed = base_seed() ^ 0x10c4;
    let mut f = fixture_with_t(1 << 16, seed);
    let top = f.ctx.top_level();
    // (level, lane count) sweep with randomized sparse position sets
    for (case, &(level, lanes)) in [(top, 1usize), (top, 5), (top - 1, 3)].iter().enumerate() {
        let case_seed = seed ^ ((case as u64 + 1) << 40);
        let mut vr = GlyphRng::new(case_seed);
        let mut positions: Vec<usize> = Vec::new();
        while positions.len() < lanes {
            let p = vr.uniform_mod(f.ctx.params.n as u64) as usize;
            if !positions.contains(&p) {
                positions.push(p);
            }
        }
        let values: Vec<i64> = (0..lanes).map(|_| rand_value(&mut vr, SWITCH_BITS)).collect();
        assert_round_trip(&mut f, &values, &positions, level, case_seed);
    }
}

#[test]
fn round_trip_is_identity_across_plaintext_moduli() {
    // the switch quantizes at the top 8 bits of t — sweep t itself
    let seed = base_seed() ^ 0x7a11;
    for (case, log_t) in [12u32, 20].into_iter().enumerate() {
        let case_seed = seed ^ ((case as u64 + 1) << 48);
        let mut f = fixture_with_t(1u64 << log_t, case_seed);
        let mut vr = GlyphRng::new(case_seed ^ 1);
        let values: Vec<i64> = (0..2).map(|_| rand_value(&mut vr, SWITCH_BITS)).collect();
        let positions: Vec<usize> = vec![0, 7];
        assert_round_trip(&mut f, &values, &positions, f.ctx.top_level(), case_seed);
    }
}

#[test]
fn out_of_range_positions_error_instead_of_panicking_end_to_end() {
    let seed = base_seed() ^ 0x0bad;
    let mut f = fixture_with_t(1 << 16, seed);
    let pt = Plaintext::encode_batch(&[1], &f.ctx.params);
    let ct = f.sk.encrypt(&pt, &mut f.rng);
    let slots = f.ctx.params.n;
    let err = f.fwd.to_bits_positions(&ct, &[slots + 3], &f.extract_ck).err().expect("reject");
    assert_eq!(err, SwitchError::PositionOutOfRange { position: slots + 3, slots });
}
