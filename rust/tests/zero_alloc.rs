//! Proof that steady-state blind rotation performs ZERO heap allocations —
//! per CMUX and per call (acceptance criterion of the zero-allocation PBS
//! pipeline; the numbers are recorded in EXPERIMENTS.md §Perf).
//!
//! A counting global allocator wraps `System`; after one warm-up bootstrap
//! sizes the scratch, further blind rotations must not touch the allocator
//! at all. This file holds exactly ONE test so no concurrent test can
//! pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_blind_rotation_is_allocation_free() {
    use glyph::math::GlyphRng;
    use glyph::tfhe::bootstrap::TestPoly;
    use glyph::tfhe::lwe::{LweCiphertext, LweKey};
    use glyph::tfhe::params::TfheParams;
    use glyph::tfhe::scratch::PbsScratch;
    use glyph::tfhe::{BootstrapKey, TrlweKey};

    let params = TfheParams::test_params();
    let mut rng = GlyphRng::new(31337);
    let lwe_key = LweKey::generate_binary(params.n, &mut rng);
    let trlwe_key = TrlweKey::generate(params.big_n, &mut rng);
    let bk = BootstrapKey::generate(&lwe_key, &trlwe_key, &params, &mut rng);
    let tv = TestPoly::constant(params.big_n, 1 << 29);
    let ct = LweCiphertext::encrypt(1 << 29, &lwe_key, params.alpha_lwe, &mut rng);

    let mut scratch = PbsScratch::new();
    // Warm up twice: the first call sizes the ring buffers and the ā buffer.
    let _ = bk.blind_rotate_scratch(&ct, &tv, &mut scratch);
    let _ = bk.blind_rotate_scratch(&ct, &tv, &mut scratch);

    let rotations = 8u64;
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..rotations {
        let acc = bk.blind_rotate_scratch(&ct, &tv, &mut scratch);
        // touch the result so the rotation cannot be optimized away
        std::hint::black_box(acc.b[0]);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);

    // `params.n` LWE coefficients ⇒ up to n CMUXes per rotation: 8 rotations
    // at n = 64 is ~500 CMUXes. The old pipeline allocated ~10 times per
    // CMUX; the scratch pipeline must not allocate at all.
    assert_eq!(
        after - before,
        0,
        "steady-state blind rotation allocated {} times over {rotations} rotations",
        after - before
    );
}
