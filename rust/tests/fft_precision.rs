//! Machine-checks the FFT precision budget stated in `math/fft.rs`: a full
//! TRGSW external-product accumulation of `(k+1)·l = 6` negacyclic products
//! with gadget digits at the documented extreme `|d| = Bg/2 = 2^6` and torus
//! coefficients at the centered boundary `±2^31` has exact integer
//! coefficients below 2^53 (so every one is representable in f64), and the
//! f64 pipeline lands within a few-thousand torus ulps of the exact result —
//! not merely for random inputs but at the adversarial corner the comment
//! reasons about. `GLYPH_PROP_SEED` replays a base seed.

use glyph::math::fft::{Cplx, TorusFft};
use glyph::math::GlyphRng;

const N: usize = 1024;
/// (k+1)·l of the external product the budget is stated for.
const PRODUCTS: usize = 6;
/// Documented digit extreme Bg/2 (bg_bit = 7).
const DMAX: i32 = 64;

fn base_seed() -> u64 {
    std::env::var("GLYPH_PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x9e37_79b9_7f4a_7c15)
}

fn torus_dist(a: u32, b: u32) -> u32 {
    let d = a.wrapping_sub(b);
    d.min(d.wrapping_neg())
}

/// Exact negacyclic `ints × torus` product over Z (no wrapping): the i128
/// oracle the budget is measured against. Torus coefficients are centered.
fn exact_negacyclic_i128(ints: &[i32], torus: &[u32], acc: &mut [i128]) {
    let n = ints.len();
    for i in 0..n {
        if ints[i] == 0 {
            continue;
        }
        for j in 0..n {
            let prod = ints[i] as i128 * (torus[j] as i32) as i128;
            let k = i + j;
            if k < n {
                acc[k] += prod;
            } else {
                acc[k - n] -= prod;
            }
        }
    }
}

/// Adversarial extreme polynomials: digits pinned to ±Bg/2, torus
/// coefficients pinned to the two centered boundary values (−2^31 as
/// 0x8000_0000 and +2^31−1 as 0x7fff_ffff), signs drawn from the seed.
fn extreme_pair(rng: &mut GlyphRng) -> (Vec<i32>, Vec<u32>) {
    let ints: Vec<i32> =
        (0..N).map(|_| if rng.next_u64() & 1 == 0 { DMAX } else { -DMAX }).collect();
    let torus: Vec<u32> =
        (0..N).map(|_| if rng.next_u64() & 1 == 0 { 0x8000_0000 } else { 0x7fff_ffff }).collect();
    (ints, torus)
}

#[test]
fn budget_holds_at_documented_extremes() {
    // One worst-case external-product accumulation: 6 products, all digits
    // at ±Bg/2, all torus coefficients at ±2^31.
    let fft = TorusFft::new(N);
    let mut rng = GlyphRng::new(base_seed() ^ 0xfacade);
    let mut acc = vec![Cplx::default(); N / 2];
    let mut exact = vec![0i128; N];
    for _ in 0..PRODUCTS {
        let (ints, torus) = extreme_pair(&mut rng);
        let fa = fft.forward_int(&ints);
        let fb = fft.forward_torus(&torus);
        fft.mul_acc(&fa, &fb, &mut acc);
        exact_negacyclic_i128(&ints, &torus, &mut exact);
    }

    // The module-doc claim, machine-checked: every exact coefficient of the
    // accumulated product is f64-representable (< 2^53)…
    let max_mag = exact.iter().map(|c| c.unsigned_abs()).max().unwrap();
    assert!(max_mag < 1u128 << 53, "budget exceeded: max |coeff| = 2^{:.1}", (max_mag as f64).log2());
    // …and the test genuinely stresses the budget (analytically the bound is
    // 6·N·2^6·2^31 ≈ 2^49.6; random signs concentrate around 2^44+):
    assert!(max_mag > 1u128 << 42, "extremes too weak: max |coeff| = 2^{:.1}", (max_mag as f64).log2());

    // The f64 pipeline must land within a few-thousand torus ulps of the
    // exact wrapped result — invisible at the value position 2^24.
    let mut fast = vec![0u32; N];
    fft.inverse_add_to_torus(&acc, &mut fast);
    for (i, (&f, &e)) in fast.iter().zip(&exact).enumerate() {
        let want = e.rem_euclid(1i128 << 32) as u32;
        let err = torus_dist(f, want);
        assert!(err < 1 << 13, "i={i}: fft={f:#010x} exact={want:#010x} err={err}");
    }
}

#[test]
fn single_product_at_extremes_is_tight() {
    // One negacyclic product at the extremes: exact coefficients ≤
    // N·2^6·2^31 = 2^47, rounding error must stay well under 2^11.
    let fft = TorusFft::new(N);
    for case in 0..5u64 {
        let seed = base_seed() ^ 0x51f7 ^ case;
        let mut rng = GlyphRng::new(seed);
        let (ints, torus) = extreme_pair(&mut rng);
        let fast = fft.negacyclic_mul_int_torus(&ints, &torus);
        let mut exact = vec![0i128; N];
        exact_negacyclic_i128(&ints, &torus, &mut exact);
        for (i, (&f, &e)) in fast.iter().zip(&exact).enumerate() {
            let want = e.rem_euclid(1i128 << 32) as u32;
            let err = torus_dist(f, want);
            assert!(err < 1 << 11, "case {case} seed {seed} i={i}: err={err}");
        }
    }
}

#[test]
fn randomized_accumulations_stay_within_budget() {
    // Random digit/torus draws (the realistic regime) across seeds: the
    // exact accumulation must stay f64-representable and the pipeline's
    // error far below the extreme-case tolerance.
    let fft = TorusFft::new(N);
    for case in 0..10u64 {
        let seed = base_seed() ^ 0xacc ^ case;
        let mut rng = GlyphRng::new(seed);
        let mut acc = vec![Cplx::default(); N / 2];
        let mut exact = vec![0i128; N];
        for _ in 0..PRODUCTS {
            let ints: Vec<i32> =
                (0..N).map(|_| (rng.uniform_mod(2 * DMAX as u64 + 1) as i32) - DMAX).collect();
            let torus: Vec<u32> = (0..N).map(|_| rng.torus32()).collect();
            let fa = fft.forward_int(&ints);
            let fb = fft.forward_torus(&torus);
            fft.mul_acc(&fa, &fb, &mut acc);
            exact_negacyclic_i128(&ints, &torus, &mut exact);
        }
        let max_mag = exact.iter().map(|c| c.unsigned_abs()).max().unwrap();
        assert!(max_mag < 1u128 << 53, "case {case} seed {seed}: max 2^{:.1}", (max_mag as f64).log2());
        let mut fast = vec![0u32; N];
        fft.inverse_add_to_torus(&acc, &mut fast);
        for (i, (&f, &e)) in fast.iter().zip(&exact).enumerate() {
            let want = e.rem_euclid(1i128 << 32) as u32;
            let err = torus_dist(f, want);
            assert!(err < 1 << 11, "case {case} seed {seed} i={i}: err={err}");
        }
    }
}
