//! Seeded property tests for the modular-multiply family: `mul_mod`
//! (u128 `%` reference), `barrett_mul`/`barrett_reduce`, `mul_shoup` and
//! `mul_shoup_lazy` must all agree at edge moduli (p near 2^32, tiny p) and
//! edge operands (0, 1, p/2, p−1), and `pow_mod` must match an
//! iterated-multiply oracle on both its Barrett (`m < 2^32`) and `mul_mod`
//! (`m ≥ 2^32`) ladders. `GLYPH_PROP_SEED` replays a base seed.

use glyph::math::modarith::{
    barrett_mul, barrett_precompute, barrett_reduce, gen_ntt_primes, mul_mod, mul_shoup,
    mul_shoup_lazy, pow_mod, shoup_precompute,
};
use glyph::math::GlyphRng;

const CASES: u64 = 200;

fn base_seed() -> u64 {
    std::env::var("GLYPH_PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x9e37_79b9_7f4a_7c15)
}

/// Edge moduli: the largest 32-bit prime (2^32 − 5), the top prime of the
/// NTT chain (≡ 1 mod 2^26, just below 2^32), a mid NTT prime, and tiny
/// primes where p−1 wraps in a single digit.
fn edge_moduli() -> Vec<u64> {
    let top_chain = gen_ntt_primes(1, 1 << 26, 1 << 32)[0];
    vec![4294967291, top_chain, 469762049, 257, 3]
}

fn edge_values(m: u64) -> Vec<u64> {
    [0u64, 1, 2, m / 2, m.saturating_sub(2), m - 1]
        .into_iter()
        .filter(|&v| v < m)
        .collect()
}

#[test]
fn multiply_family_agrees_at_edges() {
    for &p in &edge_moduli() {
        let br = barrett_precompute(p);
        for &a in &edge_values(p) {
            for &w in &edge_values(p) {
                let want = mul_mod(a, w, p);
                assert_eq!(barrett_mul(a, w, p, br), want, "barrett: p={p} a={a} w={w}");
                let ws = shoup_precompute(w, p);
                assert_eq!(mul_shoup(a, w, ws, p), want, "shoup: p={p} a={a} w={w}");
                let lazy = mul_shoup_lazy(a, w, ws, p);
                assert!(lazy < 2 * p, "lazy out of [0,2p): p={p} a={a} w={w} got {lazy}");
                assert_eq!(lazy % p, want, "lazy residue: p={p} a={a} w={w}");
            }
        }
    }
}

#[test]
fn multiply_family_agrees_randomized() {
    for &p in &edge_moduli() {
        let br = barrett_precompute(p);
        for case in 0..CASES {
            let seed = base_seed() ^ p.rotate_left(17) ^ case;
            let mut rng = GlyphRng::new(seed);
            let a = rng.next_u64() % p;
            let w = rng.next_u64() % p;
            let want = mul_mod(a, w, p);
            assert_eq!(barrett_mul(a, w, p, br), want, "barrett: p={p} case={case} seed={seed}");
            let ws = shoup_precompute(w, p);
            assert_eq!(mul_shoup(a, w, ws, p), want, "shoup: p={p} case={case} seed={seed}");
            let lazy = mul_shoup_lazy(a, w, ws, p);
            assert!(lazy < 2 * p, "lazy range: p={p} case={case} seed={seed}");
            assert_eq!(lazy % p, want, "lazy residue: p={p} case={case} seed={seed}");
            // barrett_reduce must be canonical for arbitrary u64 input, not
            // just 32×32 products — feed it a raw 64-bit value
            let x = rng.next_u64();
            assert_eq!(barrett_reduce(x, p, br), x % p, "reduce: p={p} case={case} seed={seed}");
        }
    }
}

#[test]
fn shoup_stays_correct_for_unreduced_operands() {
    // The lazy NTT keeps the variable operand redundant in [0, 4p); the
    // Shoup product must stay exact for ANY u64 `a`, only `w` is reduced.
    for &p in &edge_moduli() {
        for case in 0..CASES {
            let seed = base_seed() ^ p.rotate_left(41) ^ case;
            let mut rng = GlyphRng::new(seed);
            let w = rng.next_u64() % p;
            let ws = shoup_precompute(w, p);
            for a in [rng.next_u64(), 4 * p - 1, u64::MAX, p, 2 * p + 1] {
                let want = mul_mod(a % p, w, p);
                assert_eq!(
                    mul_shoup(a, w, ws, p) % p,
                    want,
                    "unreduced shoup: p={p} a={a} case={case} seed={seed}"
                );
                let lazy = mul_shoup_lazy(a, w, ws, p);
                assert!(lazy < 2 * p, "unreduced lazy range: p={p} a={a} case={case} seed={seed}");
                assert_eq!(lazy % p, want, "unreduced lazy: p={p} a={a} case={case} seed={seed}");
            }
        }
    }
}

#[test]
fn pow_mod_matches_iterated_multiply_oracle() {
    // small exponents: literal repeated multiplication
    for &m in &edge_moduli() {
        for case in 0..CASES / 4 {
            let seed = base_seed() ^ m.rotate_left(29) ^ case;
            let mut rng = GlyphRng::new(seed);
            let a = rng.next_u64() % m;
            let e = rng.next_u64() % 64;
            let mut want = 1u64 % m;
            for _ in 0..e {
                want = mul_mod(want, a, m);
            }
            assert_eq!(pow_mod(a, e, m), want, "pow: m={m} a={a} e={e} seed={seed}");
        }
    }
}

#[test]
fn pow_mod_edge_cases_and_fermat() {
    // m = 1: everything is 0 (the fixed `1 % m` bootstrap)
    assert_eq!(pow_mod(0, 0, 1), 0);
    assert_eq!(pow_mod(12345, 678, 1), 0);
    // e = 0 is the empty product
    for &m in &edge_moduli() {
        if m > 1 {
            assert_eq!(pow_mod(98765, 0, m), 1, "m={m}");
        }
    }
    // Fermat on the Barrett ladder (every edge modulus here is prime < 2^32)
    for &p in &edge_moduli() {
        for a in [2u64, 5, p - 1] {
            if a % p != 0 {
                assert_eq!(pow_mod(a, p - 1, p), 1, "fermat p={p} a={a}");
            }
        }
    }
    // m ≥ 2^32 exercises the mul_mod ladder: 2^64 − 59 is prime
    let m = 0xffff_ffff_ffff_ffc5u64;
    assert_eq!(pow_mod(2, m - 1, m), 1);
    assert_eq!(pow_mod(m - 1, 2, m), 1);
    // unreduced base must be folded before the ladder
    assert_eq!(pow_mod(u64::MAX, 3, 469762049), pow_mod(u64::MAX % 469762049, 3, 469762049));
}
