//! Cross-module integration tests: the full stack composed end to end at
//! test-scale parameters, plus consistency between the cost model, the
//! scheduler and the live op counters.

use glyph::coordinator::cost::{mlp_table, total_row, OpLatencies, Scheme};
use glyph::coordinator::scheduler;
use glyph::math::GlyphRng;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::tensor::{EncTensor, PackOrder};
use glyph::train::{GlyphMlp, MlpConfig};

/// The live counters of a real encrypted train step must match the cost
/// model's op-count columns for the same architecture (MultCC exactly; the
/// switch/act counts up to the per-value vs per-neuron accounting).
#[test]
fn cost_model_matches_live_counters() {
    let dims = vec![5usize, 4, 3];
    let batch = 2;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 42);
    let mut rng = GlyphRng::new(9);
    let config = MlpConfig {
        dims: dims.clone(),
        act_shifts: vec![8, 7],
        err_shifts: vec![7, 7],
        grad_shift: 8,
        softmax_bits: 3,
    };
    let mut mlp = GlyphMlp::new_random(config, &mut client, &mut rng, &engine).unwrap();
    let x_cts = (0..5).map(|i| client.encrypt_batch(&vec![(i as i64) * 7 - 10; batch], 0)).collect();
    let x = EncTensor::new(x_cts, vec![5], PackOrder::Forward, 0);
    let lab_cts = (0..3).map(|k| client.encrypt_batch(&vec![if k == 0 { 127 } else { 0 }; batch], 0)).collect();
    let labels = EncTensor::new(lab_cts, vec![3], PackOrder::Reversed, 0);
    mlp.train_step(&x, &labels, &engine);

    let live = engine.counter.snapshot();
    let rows = mlp_table(&dims, Scheme::GlyphMlp, &OpLatencies::paper());
    let modeled = total_row(&rows);
    // forward MACs + backward errors + gradients: the model counts each FC
    // row once; live counters see forward + error (hidden only) + gradient.
    assert_eq!(live.mult_cc, modeled.mult_cc, "MultCC count mismatch: live {live:?} vs model {modeled:?}");
    assert!(live.act_gates > 0 && live.switch_b2t > 0 && live.switch_t2b > 0);
}

/// The scheduler's switch count must equal the number of switch-annotated
/// rows in the generated Table 3.
#[test]
fn scheduler_and_table_agree_on_switches() {
    let plan = scheduler::mlp_plan();
    assert!(plan.validate());
    let rows = mlp_table(&[784, 128, 32, 10], Scheme::GlyphMlp, &OpLatencies::paper());
    let table_switches = rows.iter().filter(|r| r.switch != "-").count();
    // the plan covers forward + backward with gradients; every Act row and
    // every switch-annotated FC row corresponds to a plan boundary.
    assert!(plan.switch_count() >= 6);
    assert!(table_switches >= 6);
}

/// Dataset → encrypt → one FC forward → decrypt must equal the plaintext
/// reference MAC over real (synthetic) image features.
#[test]
fn data_pipeline_to_encrypted_mac() {
    let batch = 3;
    let ds = glyph::data::synthetic_digits(batch, 77, "it");
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 4242);
    // 4 center pixels as features
    let feats: Vec<Vec<i64>> = (0..4)
        .map(|f| {
            (0..batch)
                .map(|b| ds.image_i8(b)[(13 + f / 2) * 28 + 13 + f % 2])
                .collect()
        })
        .collect();
    let weights = vec![vec![3i64, -2, 1, -1]];
    let layer = glyph::nn::linear::FcLayer::new_encrypted(&weights, &mut client, 0);
    let x_cts = feats.iter().map(|v| client.encrypt_batch(v, 0)).collect();
    let x = EncTensor::new(x_cts, vec![4], PackOrder::Forward, 0);
    let u = layer.forward(&x, &engine);
    let got = client.decrypt_batch(&u.cts[0], batch, 0);
    let want: Vec<i64> = (0..batch)
        .map(|b| (0..4).map(|f| weights[0][f] * feats[f][b]).sum())
        .collect();
    assert_eq!(got, want);
}

/// The noise-refresh substitution keeps training functional across many
/// switch round trips (regression guard for noise-budget accounting).
#[test]
fn repeated_switch_round_trips_stay_correct() {
    let batch = 2;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 777);
    let mut ct = client.encrypt_batch(&[55, -66], 0);
    let positions: Vec<usize> = (0..batch).collect();
    let frac = engine.frac_bits();
    for round in 0..4 {
        let bits = engine.switch_to_bits(&ct, &positions, frac);
        // identity recomposition
        let truth = engine.trivial_bit(true);
        let lanes: Vec<glyph::nn::backend::Bit> = bits
            .iter()
            .map(|lane_bits| {
                let mut acc: Option<glyph::nn::backend::Bit> = None;
                for (i, b) in lane_bits.iter().enumerate() {
                    let w = engine.gate_and_weighted(b, &truth, glyph::switch::extract::bit_position(i));
                    match &mut acc {
                        None => acc = Some(w),
                        Some(a) => a.add_assign(&w),
                    }
                }
                acc.unwrap()
            })
            .collect();
        ct = engine.switch_to_bgv(&lanes, &positions);
        assert_eq!(client.decrypt_batch(&ct, batch, 0), vec![55, -66], "round {round}");
    }
    assert_eq!(engine.counter.snapshot().switch_b2t, 4);
}
