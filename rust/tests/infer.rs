//! Forward-only inference conformance (ROADMAP item 5):
//!
//! * Differential: an [`InferenceSession`] restored from a trained
//!   [`Checkpoint`] must produce logits **byte-identical** to the training
//!   path's `Trainer::eval_scores` on the same weights — loading a model
//!   through the wire format and freezing it changes nothing about what it
//!   computes.
//! * Backend equivalence: the same explicit weight matrices scored on the
//!   clear mirror and on real FHE decode to identical logit rows.
//! * The checkpoint/seed guard: a model trained under one seed refuses to
//!   load into a session keyed for another.
//! * Output modes: argmax/top-k are consistent views of the logits.

use glyph::coordinator::scheduler::StepPhase;
use glyph::math::GlyphRng;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::train::{GlyphMlp, InferenceSession, MlpConfig, OutputMode, Predictions, Trainer};
use glyph::wire::{Checkpoint, WireCodec};

const BATCH: usize = 2;

/// Train a tiny clear-backend MLP for a few steps and return the trainer
/// plus its engine/codec (the training path the session is compared to).
fn trained_clear() -> (Trainer, GlyphEngine, glyph::nn::backend::ClearCodec, glyph::data::Dataset) {
    let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, BATCH);
    let config = MlpConfig::tiny(6, 5, 3);
    let mut rng = GlyphRng::new(0x5eed ^ 0xb11d);
    let mlp = GlyphMlp::new_random(config, &mut codec, &mut rng, &engine).unwrap();
    let mut trainer = Trainer::new(mlp.net, 3);
    let train = glyph::data::synthetic_digits(BATCH * 6, 11, "infer-train");
    trainer.train_steps(&train, 6, &engine, &mut codec).unwrap();
    let test = glyph::data::synthetic_digits(BATCH * 4, 12, "infer-test");
    (trainer, engine, codec, test)
}

#[test]
fn checkpoint_session_logits_match_training_path_byte_identically() {
    let (trainer, engine, mut codec, test) = trained_clear();
    let reference = trainer.eval_scores(&test, test.len(), &engine, &mut codec).unwrap();

    // Round-trip the trained model through the wire format into a frozen
    // session on a *fresh* engine/codec, as a separate process would.
    let ckpt =
        Checkpoint::capture(&trainer.net, &engine, 4242, 1, 6, 0.0, None).unwrap();
    let bytes = ckpt.to_wire();
    let (engine2, mut codec2) = GlyphEngine::setup_clear(EngineProfile::Test, BATCH);
    let ckpt2 = Checkpoint::from_wire(&bytes, &engine2).unwrap();
    let session = InferenceSession::from_checkpoint(
        MlpConfig::tiny(6, 5, 3),
        &ckpt2,
        4242,
        &mut codec2,
        &engine2,
    )
    .unwrap();

    assert!(session.plan().steps.iter().all(|s| s.phase == StepPhase::Forward));
    let rows = session.scores(&test, test.len(), &engine2, &mut codec2).unwrap();
    assert_eq!(rows, reference, "frozen session logits must be byte-identical to eval_scores");

    // and the forward-only plan prices the scoring exactly
    let batches = (test.len() / BATCH) as u64;
    let predicted = session.plan().totals().to_snapshot().scale(batches);
    let before = engine2.counter.snapshot();
    session.scores(&test, test.len(), &engine2, &mut codec2).unwrap();
    let live = engine2.counter.snapshot().since(&before);
    let diff = live.diff_ignoring(&predicted, &glyph::serve::metrics::UNPREDICTED_OPS);
    assert!(
        diff.is_empty(),
        "forward-only scoring drifted from the plan: {}",
        glyph::coordinator::OpSnapshot::render_diff(&diff)
    );
}

#[test]
fn checkpoint_refuses_mismatched_seed() {
    let (trainer, engine, _codec, _test) = trained_clear();
    let ckpt = Checkpoint::capture(&trainer.net, &engine, 4242, 1, 6, 0.0, None).unwrap();
    let (engine2, mut codec2) = GlyphEngine::setup_clear(EngineProfile::Test, BATCH);
    let err = InferenceSession::from_checkpoint(
        MlpConfig::tiny(6, 5, 3),
        &ckpt,
        999,
        &mut codec2,
        &engine2,
    )
    .err()
    .expect("wrong-seed model load must be refused");
    let msg = err.to_string();
    assert!(msg.contains("4242") && msg.contains("999"), "{msg}");
}

#[test]
fn fhe_checkpoint_roundtrips_into_inference_session() {
    // Train one FHE step, persist, reload under a fresh engine keyed with
    // the SAME seed (keygen is deterministic), and score: the restored
    // weight ciphertexts must decrypt correctly under the regenerated key.
    let seed = 20260803;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, BATCH, seed);
    let config = MlpConfig::tiny(4, 3, 2);
    let mut rng = GlyphRng::new(seed ^ 0xb11d);
    let mlp = GlyphMlp::new_random(config, &mut client, &mut rng, &engine).unwrap();
    let mut trainer = Trainer::new(mlp.net, 2);
    let train = glyph::data::synthetic_cancer(BATCH * 2, 21);
    trainer.train_steps(&train, 1, &engine, &mut client).unwrap();
    let test = glyph::data::synthetic_cancer(BATCH * 2, 22);
    let reference = trainer.eval_scores(&test, test.len(), &engine, &mut client).unwrap();

    let ckpt =
        Checkpoint::capture(&trainer.net, &engine, seed, 1, 1, 0.0, Some(client.rng.state()))
            .unwrap();
    let bytes = ckpt.to_wire();

    let (engine2, mut client2) = GlyphEngine::setup(EngineProfile::Test, BATCH, seed);
    let ckpt2 = Checkpoint::from_wire(&bytes, &engine2).unwrap();
    let session = InferenceSession::from_checkpoint(
        MlpConfig::tiny(4, 3, 2),
        &ckpt2,
        seed,
        &mut client2,
        &engine2,
    )
    .unwrap();
    let rows = session.scores(&test, test.len(), &engine2, &mut client2).unwrap();
    assert_eq!(rows, reference, "FHE model round-trip changed the logits");
}

#[test]
fn clear_and_fhe_sessions_decode_identical_logits() {
    // Same explicit 8-bit weights, same inputs, both backends: the clear
    // mirror is byte-exact, so the decoded logit rows must be equal.
    let config = MlpConfig::tiny(6, 5, 3);
    let weights: Vec<Vec<Vec<i64>>> = vec![
        (0..5).map(|j| (0..6).map(|i| ((3 * i + j) % 9) as i64 - 4).collect()).collect(),
        (0..3).map(|j| (0..5).map(|i| ((i * j + 2) % 7) as i64 - 3).collect()).collect(),
    ];
    let test = glyph::data::synthetic_digits(BATCH * 2, 33, "infer-eq");

    let (clear, mut clear_codec) = GlyphEngine::setup_clear(EngineProfile::Test, BATCH);
    let clear_session =
        InferenceSession::from_weights(config.clone(), weights.clone(), &mut clear_codec, &clear)
            .unwrap();
    let clear_rows = clear_session.scores(&test, test.len(), &clear, &mut clear_codec).unwrap();

    let (fhe, mut fhe_client) = GlyphEngine::setup(EngineProfile::Test, BATCH, 20260804);
    let fhe_session =
        InferenceSession::from_weights(config, weights, &mut fhe_client, &fhe).unwrap();
    let fhe_rows = fhe_session.scores(&test, test.len(), &fhe, &mut fhe_client).unwrap();

    assert_eq!(clear_rows, fhe_rows, "clear and FHE inference disagree");
}

#[test]
fn output_modes_are_consistent_views_of_the_logits() {
    let (trainer, engine, mut codec, test) = trained_clear();
    let session = InferenceSession::from_network(trainer.net, 3);
    let Predictions::Logits(rows) = session
        .predict(&test, test.len(), OutputMode::Logits, &engine, &mut codec)
        .unwrap()
    else {
        panic!("Logits mode must return logit rows")
    };
    let Predictions::Argmax(labels) = session
        .predict(&test, test.len(), OutputMode::Argmax, &engine, &mut codec)
        .unwrap()
    else {
        panic!("Argmax mode must return labels")
    };
    let Predictions::TopK(top) = session
        .predict(&test, test.len(), OutputMode::TopK(2), &engine, &mut codec)
        .unwrap()
    else {
        panic!("TopK mode must return ranked pairs")
    };
    assert_eq!(rows.len(), labels.len());
    assert_eq!(rows.len(), top.len());
    for (i, row) in rows.iter().enumerate() {
        // argmax label scores the row maximum…
        assert_eq!(row[labels[i]], *row.iter().max().unwrap());
        // …and is exactly top-1
        assert_eq!(top[i][0].0, labels[i]);
        assert_eq!(top[i].len(), 2);
        // top-k is sorted by score
        assert!(top[i][0].1 >= top[i][1].1);
    }
}
