//! Packing conformance harness, part 1: seeded randomized property tests
//! for the cross-sample SIMD minibatch layout.
//!
//! The core property: `unpack_columns ∘ pack_columns` is the IDENTITY on
//! per-feature sample columns — across batch sizes, feature counts that
//! leave the final block partial, sparse occupancy masks (vacant lanes
//! stay zero in both directions), and every supported power-of-two
//! plaintext modulus. The same geometry is checked one layer down through
//! `Plaintext::try_encode_strided` / `try_decode_strided`, through a real
//! BGV encrypt/decrypt, and at the capacity boundary where one extra
//! feature lane or sample must produce `EncodingError::StrideOverrun`
//! instead of silently folding lanes together.
//!
//! Every assertion carries the failing trial's seed so a red run is
//! reproducible: set `GLYPH_PROP_SEED` to replay a base seed (the
//! `ntt_properties.rs` convention).

use glyph::bgv::{BgvContext, BgvParams, BgvSecretKey, EncodingError, Plaintext};
use glyph::math::modarith::gen_ntt_primes;
use glyph::math::GlyphRng;
use glyph::nn::PackedLayout;

fn base_seed() -> u64 {
    std::env::var("GLYPH_PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x5317_c45e_ed00_4242)
}

/// BGV parameters over a *custom* plaintext modulus `t` (the test primes
/// are ≡ 1 mod 2^26, so any power-of-two `t` up to 2^26 keeps the Δ maps
/// exact — the modulus sweep below relies on this).
fn params_with_t(n: usize, t: u64) -> BgvParams {
    let align = 1u64 << 26;
    BgvParams { n, primes: gen_ntt_primes(3, align, 1u64 << 32), t, sigma: 3.2, prime_align: align }
}

/// Draw a legal layout for ring degree `n`: batch small enough that the
/// derived stride fits, then a feature count that usually spans several
/// blocks and usually leaves the last one partial.
fn draw_layout(rng: &mut GlyphRng, n: usize) -> (PackedLayout, usize) {
    let max_batch = n / 2; // stride = next_pow2(2·batch−1) ≤ n ⇔ batch ≤ n/2
    let batch = 1 + rng.uniform_mod(max_batch as u64) as usize;
    let layout = PackedLayout::for_ring(batch, n)
        .unwrap_or_else(|e| panic!("for_ring({batch}, {n}) must fit: {e}"));
    let features = 1 + rng.uniform_mod(3 * layout.feats_per_ct as u64) as usize;
    (layout, features)
}

/// Random per-feature sample columns with values in `[−bound, bound]`.
fn draw_columns(rng: &mut GlyphRng, features: usize, batch: usize, bound: i64) -> Vec<Vec<i64>> {
    (0..features)
        .map(|_| {
            (0..batch).map(|_| rng.uniform_mod(2 * bound as u64 + 1) as i64 - bound).collect()
        })
        .collect()
}

/// The columns a decoder must see: the originals with vacant lanes zeroed.
fn masked(cols: &[Vec<i64>], layout: &PackedLayout) -> Vec<Vec<i64>> {
    cols.iter()
        .map(|col| {
            col.iter()
                .enumerate()
                .map(|(b, &v)| if layout.occupied(b) { v } else { 0 })
                .collect()
        })
        .collect()
}

#[test]
fn pack_unpack_roundtrip_across_batch_sizes_and_partial_blocks() {
    for trial in 0..64u64 {
        let seed = base_seed().wrapping_add(trial);
        let mut rng = GlyphRng::new(seed);
        let n = [64usize, 256, 1024][rng.uniform_mod(3) as usize];
        let (layout, features) = draw_layout(&mut rng, n);
        let cols = draw_columns(&mut rng, features, layout.batch, 1 << 15);

        let blocks = layout.pack_columns(&cols, n);
        assert_eq!(
            blocks.len(),
            layout.blocks(features),
            "seed {seed}: block count must match the layout ({features} features, F = {})",
            layout.feats_per_ct
        );
        // Dense layout: every written coefficient is a payload coefficient,
        // everything else stays zero (a partial final block must not carry
        // lanes beyond its feature count).
        for (bi, coeffs) in blocks.iter().enumerate() {
            let feats = layout.feats_in_block(features, bi);
            for (c, &v) in coeffs.iter().enumerate() {
                let lane = c / layout.stride;
                let sample = c % layout.stride;
                let is_payload = lane < feats && sample < layout.batch;
                if !is_payload {
                    assert_eq!(
                        v, 0,
                        "seed {seed}: block {bi} coeff {c} is outside the payload and must be zero"
                    );
                }
            }
        }
        assert_eq!(
            layout.unpack_columns(&blocks, features),
            cols,
            "seed {seed}: unpack ∘ pack must be the identity (batch {}, stride {}, {features} \
             features over n = {n})",
            layout.batch,
            layout.stride
        );
    }
}

#[test]
fn sparse_occupancy_masks_zero_vacant_lanes_both_ways() {
    for trial in 0..64u64 {
        let seed = base_seed().wrapping_add(0x1000).wrapping_add(trial);
        let mut rng = GlyphRng::new(seed);
        let n = [64usize, 256][rng.uniform_mod(2) as usize];
        let (dense, features) = draw_layout(&mut rng, n);
        // Random sparse mask; a trailing-false prefix mask models the
        // partial final minibatch of an epoch.
        let mask: Vec<bool> = if rng.uniform_mod(2) == 0 {
            let filled = 1 + rng.uniform_mod(dense.batch as u64) as usize;
            (0..dense.batch).map(|b| b < filled).collect()
        } else {
            (0..dense.batch).map(|_| rng.uniform_mod(2) == 0).collect()
        };
        let layout = dense.with_occupancy(mask.clone());
        let cols = draw_columns(&mut rng, features, layout.batch, 1 << 15);

        let blocks = layout.pack_columns(&cols, n);
        // Vacant lanes must encode as zero in every feature lane...
        for (bi, coeffs) in blocks.iter().enumerate() {
            for k in 0..layout.feats_in_block(features, bi) {
                for (b, &occ) in mask.iter().enumerate() {
                    if !occ {
                        assert_eq!(
                            coeffs[k * layout.stride + b],
                            0,
                            "seed {seed}: vacant lane {b} of block {bi} lane {k} must pack to zero"
                        );
                    }
                }
            }
        }
        // ...and decode as zero even if a vacant slot somehow carried data.
        let mut dirty = blocks.clone();
        if let Some(b) = mask.iter().position(|&occ| !occ) {
            dirty[0][b] = 7;
        }
        assert_eq!(
            layout.unpack_columns(&dirty, features),
            masked(&cols, &layout),
            "seed {seed}: unpack must return the occupancy-masked columns (mask {mask:?})"
        );
    }
}

#[test]
fn strided_plaintext_roundtrip_across_moduli() {
    // All supported plaintext moduli are powers of two up to the prime
    // alignment; sweep the full range including the MAC profile's 2^26.
    for (ti, &t) in [1u64 << 8, 1 << 12, 1 << 16, 1 << 20, 1 << 26].iter().enumerate() {
        for trial in 0..8u64 {
            let seed = base_seed().wrapping_add(0x2000 + (ti as u64) * 0x100).wrapping_add(trial);
            let mut rng = GlyphRng::new(seed);
            let n = 256;
            let p = params_with_t(n, t);
            let (layout, features) = draw_layout(&mut rng, n);
            let bound = (t / 2) as i64 - 1;
            let cols = draw_columns(&mut rng, features, layout.batch, bound);

            // Per block: the strided plaintext encoding must agree with the
            // layout's own coefficient placement and invert exactly.
            let packed = layout.pack_columns(&cols, n);
            for bi in 0..layout.blocks(features) {
                let feats = layout.feats_in_block(features, bi);
                let sub = &cols[bi * layout.feats_per_ct..bi * layout.feats_per_ct + feats];
                let pt = Plaintext::try_encode_strided(sub, layout.stride, &p).unwrap_or_else(|e| {
                    panic!("seed {seed}: t = 2^{}: encode must fit: {e}", t.trailing_zeros())
                });
                assert_eq!(
                    pt.coeffs, packed[bi],
                    "seed {seed}: t = 2^{}: encode_strided and pack_columns must place \
                     coefficients identically (block {bi})",
                    t.trailing_zeros()
                );
                assert_eq!(
                    pt.try_decode_strided(layout.stride, feats, layout.batch).unwrap(),
                    sub.to_vec(),
                    "seed {seed}: t = 2^{}: decode ∘ encode must be the identity",
                    t.trailing_zeros()
                );
            }
        }
    }
}

#[test]
fn strided_encoding_survives_bgv_encrypt_decrypt() {
    for (ti, &t) in [1u64 << 8, 1 << 16, 1 << 26].iter().enumerate() {
        for trial in 0..2u64 {
            let seed = base_seed().wrapping_add(0x3000 + (ti as u64) * 0x100).wrapping_add(trial);
            let mut rng = GlyphRng::new(seed);
            let n = 256;
            let ctx = BgvContext::new(params_with_t(n, t));
            let sk = BgvSecretKey::generate(&ctx, &mut rng);
            let (layout, features) = draw_layout(&mut rng, n);
            let feats = features.min(layout.feats_per_ct); // one block end-to-end
            let bound = ((t / 2) as i64 - 1).min(1 << 20);
            let cols = draw_columns(&mut rng, feats, layout.batch, bound);

            let pt = Plaintext::encode_strided(&cols, layout.stride, &ctx.params);
            let ct = sk.encrypt(&pt, &mut rng);
            let back = sk.decrypt(&ct).try_decode_strided(layout.stride, feats, layout.batch);
            assert_eq!(
                back.unwrap(),
                cols,
                "seed {seed}: t = 2^{}: a strided packing must survive BGV encrypt/decrypt \
                 (batch {}, stride {})",
                t.trailing_zeros(),
                layout.batch,
                layout.stride
            );
        }
    }
}

#[test]
fn capacity_boundaries_are_exact() {
    for trial in 0..32u64 {
        let seed = base_seed().wrapping_add(0x4000).wrapping_add(trial);
        let mut rng = GlyphRng::new(seed);
        let n = 256;
        let p = params_with_t(n, 1 << 16);
        // Random power-of-two stride; `full` lanes fill the ring exactly.
        let stride = 1usize << (1 + rng.uniform_mod(8)); // 2..=256
        let full = n / stride;
        let batch = 1 + rng.uniform_mod(stride as u64) as usize; // ≤ stride
        let col = |v: i64| vec![v; batch];

        // Exactly full is accepted and inverts.
        let cols: Vec<Vec<i64>> = (0..full as i64).map(col).collect();
        let pt = Plaintext::try_encode_strided(&cols, stride, &p).unwrap_or_else(|e| {
            panic!("seed {seed}: exactly-full ({full} lanes × stride {stride}) must fit: {e}")
        });
        assert_eq!(
            pt.try_decode_strided(stride, full, batch).unwrap(),
            cols,
            "seed {seed}: exactly-full roundtrip"
        );

        // One feature lane over must overrun, not wrap.
        let over: Vec<Vec<i64>> = (0..=full as i64).map(col).collect();
        assert!(
            matches!(
                Plaintext::try_encode_strided(&over, stride, &p),
                Err(EncodingError::StrideOverrun { features, .. }) if features == full + 1
            ),
            "seed {seed}: {} lanes × stride {stride} must be a StrideOverrun",
            full + 1
        );
        assert!(
            pt.try_decode_strided(stride, full + 1, batch).is_err(),
            "seed {seed}: decode validates the same lane-count geometry"
        );

        // One sample over the stride window must overrun too.
        let wide = vec![vec![1i64; stride + 1]];
        assert!(
            matches!(
                Plaintext::try_encode_strided(&wide, stride, &p),
                Err(EncodingError::StrideOverrun { batch: b, .. }) if b == stride + 1
            ),
            "seed {seed}: batch {} in a stride-{stride} window must be a StrideOverrun",
            stride + 1
        );
        assert!(
            pt.try_decode_strided(stride, full.max(1), stride + 1).is_err(),
            "seed {seed}: decode validates the same batch geometry"
        );
    }

    // The layout constructor enforces the same bound symbolically: a batch
    // whose derived stride exceeds the ring degree is rejected up front.
    let err = PackedLayout::for_ring(200, 256).unwrap_err();
    assert!(err.contains("exceeds the ring degree"), "got: {err}");
    assert!(PackedLayout::for_ring(0, 256).is_err(), "zero samples is not a layout");
    // And the densest legal layout saturates the no-wrap bound exactly.
    let l = PackedLayout::for_ring(128, 256).expect("batch = n/2 is the boundary");
    assert_eq!((l.stride, l.feats_per_ct), (256, 1));
}
