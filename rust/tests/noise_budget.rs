//! Noise-budget regression guard for the lazy-relinearization MAC engine:
//! after one full encrypted `train_step` (MLP and transfer-CNN plans), the
//! decryption noise margin of every live ciphertext — layer outputs of a
//! post-update forward pass and the updated encrypted weights — must stay
//! above a recorded floor.
//!
//! Why: deferring relinearization lets the degree-2 tensor component grow
//! across a whole row before the single relin. That is *less* total relin
//! noise than the per-term reference (one key-switch error per row instead
//! of one per term), but any future change that silently eats the budget —
//! more pre-relin depth, a wrong digit decomposition, a dropped mod-switch
//! — lands here before it corrupts decryption in production profiles.
//!
//! Floors (test profile, q ≈ 2^96, t = 2^16): fresh encryptions sit at a
//! ≈70-bit margin; one lazy-relin MAC row costs ≈2^56 of relin noise,
//! leaving ≈35 bits. The floors below leave slack for RNG tails while
//! still catching any structural regression (a second uncompensated relin
//! or a skipped rescale burns >10 bits at once).

use glyph::math::GlyphRng;
use glyph::nn::batchnorm::BnLayer;
use glyph::nn::engine::{ClientKeys, EngineProfile, GlyphEngine};
use glyph::nn::linear::Weight;
use glyph::nn::network::{Network, NetworkBuilder};
use glyph::nn::tensor::{EncTensor, PackOrder};
use glyph::train::{CnnConfig, GlyphCnn};

/// Minimum post-train-step margin (bits) for any forward-pass ciphertext.
const OUTPUT_FLOOR_BITS: f64 = 18.0;
/// Minimum margin for the updated encrypted weights (fresh − fresh).
const WEIGHT_FLOOR_BITS: f64 = 40.0;

fn min_forward_margin(net: &Network, x: &EncTensor, client: &ClientKeys, engine: &GlyphEngine) -> f64 {
    let pass = net.forward(x, engine);
    pass.outputs
        .iter()
        .flat_map(|t| t.cts.iter())
        .map(|ct| client.bgv_sk.noise_margin_bits(ct.fhe()))
        .fold(f64::INFINITY, f64::min)
}

fn min_weight_margin(net: &Network, client: &ClientKeys) -> f64 {
    net.fc_layers()
        .iter()
        .flat_map(|l| l.w.iter().flatten())
        .filter_map(|w| match w {
            Weight::Enc(ct) => Some(client.bgv_sk.noise_margin_bits(ct.fhe())),
            Weight::Plain(_) => None,
        })
        .fold(f64::INFINITY, f64::min)
}

#[test]
fn mlp_train_step_keeps_noise_margin_above_floor() {
    let batch = 2;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 20260801);
    let mut rng = GlyphRng::new(51);
    let mut net = NetworkBuilder::input_vec(3)
        .fc(4)
        .relu(8, 7)
        .fc(2)
        .softmax(3, 7)
        .grad_shift(8)
        .build(&mut client, &mut rng, &engine)
        .unwrap();
    let x_cts = (0..3).map(|i| client.encrypt_batch(&[5 - 3 * i as i64, 2 * i as i64], 0)).collect();
    let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
    let lab_cts = (0..2)
        .map(|k| {
            let mut v = vec![if k == 0 { 127i64 } else { 0 }, if k == 1 { 127 } else { 0 }];
            v.reverse();
            client.encrypt_batch(&v, 0)
        })
        .collect();
    let labels = EncTensor::new(lab_cts, vec![2], PackOrder::Reversed, 0);

    net.train_step(&x, &labels, &engine);

    let out_margin = min_forward_margin(&net, &x, &client, &engine);
    assert!(
        out_margin > OUTPUT_FLOOR_BITS,
        "MLP forward margin {out_margin:.1} bits under floor {OUTPUT_FLOOR_BITS}"
    );
    let w_margin = min_weight_margin(&net, &client);
    assert!(
        w_margin > WEIGHT_FLOOR_BITS,
        "MLP weight margin {w_margin:.1} bits under floor {WEIGHT_FLOOR_BITS}"
    );
}

#[test]
fn transfer_cnn_train_step_keeps_noise_margin_above_floor() {
    let batch = 2;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 20260802);
    let mut rng = GlyphRng::new(53);
    let config = CnnConfig::tiny();
    let rand_kernels = |oc: usize, ic: usize, k: usize, rng: &mut GlyphRng| -> Vec<Vec<Vec<Vec<i64>>>> {
        (0..oc)
            .map(|_| {
                (0..ic)
                    .map(|_| {
                        (0..k).map(|_| (0..k).map(|_| (rng.uniform_mod(7) as i64) - 3).collect()).collect()
                    })
                    .collect()
            })
            .collect()
    };
    let c1w = rand_kernels(2, 1, 3, &mut rng);
    let c2w = rand_kernels(3, 2, 3, &mut rng);
    let bn1 = BnLayer { gain: vec![1, 1], bias: vec![0, 0], gain_shift: 0 };
    let bn2 = BnLayer { gain: vec![1, 1, 1], bias: vec![0, 0, 0], gain_shift: 0 };
    let mut cnn =
        GlyphCnn::new(config, &c1w, bn1, &c2w, bn2, &mut client, &mut rng, &engine).unwrap();

    let cts: Vec<_> = (0..14 * 14)
        .map(|i| client.encrypt_batch(&[(i % 9) as i64 - 4, (i % 5) as i64 - 2], 0))
        .collect();
    let x = EncTensor::new(cts, vec![1, 14, 14], PackOrder::Forward, 0);
    let labels = EncTensor::new(
        vec![client.encrypt_batch(&[0, 127], 0), client.encrypt_batch(&[127, 0], 0)],
        vec![2],
        PackOrder::Reversed,
        0,
    );

    cnn.train_step(&x, &labels, &engine);

    let out_margin = min_forward_margin(&cnn.net, &x, &client, &engine);
    assert!(
        out_margin > OUTPUT_FLOOR_BITS,
        "CNN forward margin {out_margin:.1} bits under floor {OUTPUT_FLOOR_BITS}"
    );
    let w_margin = min_weight_margin(&cnn.net, &client);
    assert!(
        w_margin > WEIGHT_FLOOR_BITS,
        "CNN weight margin {w_margin:.1} bits under floor {WEIGHT_FLOOR_BITS}"
    );
}
