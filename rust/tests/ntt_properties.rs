//! Seeded randomized property tests for the NTT/RNS layer under the BGV
//! MAC engine: NTT∘iNTT identity, fast vs schoolbook negacyclic products,
//! `pointwise_acc`/`pointwise_acc2` linearity, and `mod_switch_down`
//! plaintext preservation — ≥100 random cases per prime of the test chain.
//! Every assertion carries the failing case's seed so a red run is
//! reproducible: set `GLYPH_PROP_SEED` to replay a base seed.

use glyph::math::modarith::{add_mod, gen_ntt_primes, mul_mod};
use glyph::math::ntt::negacyclic_mul_naive;
use glyph::math::{GlyphRng, NttTable, RnsContext, RnsPoly};

const CASES: u64 = 100;
const N: usize = 256;

fn base_seed() -> u64 {
    std::env::var("GLYPH_PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x9e37_79b9_7f4a_7c15)
}

fn chain() -> Vec<u64> {
    // the same generator the BGV test profile uses (3 limbs, ≡1 mod 2^26)
    gen_ntt_primes(3, 1 << 26, 1 << 32)
}

fn rand_poly(n: usize, p: u64, rng: &mut GlyphRng) -> Vec<u64> {
    (0..n).map(|_| rng.next_u64() % p).collect()
}

#[test]
fn ntt_roundtrip_identity_randomized() {
    for &p in &chain() {
        let table = NttTable::new(N, p);
        for case in 0..CASES {
            let seed = base_seed() ^ (p.wrapping_mul(31)) ^ case;
            let mut rng = GlyphRng::new(seed);
            let a = rand_poly(N, p, &mut rng);
            let mut b = a.clone();
            table.forward(&mut b);
            table.inverse(&mut b);
            assert_eq!(a, b, "NTT∘iNTT identity failed: prime {p}, case {case}, seed {seed}");
        }
    }
}

#[test]
fn negacyclic_mul_matches_schoolbook_randomized() {
    // n = 64 keeps the O(n²) oracle affordable at 100 cases × 3 primes.
    let n = 64;
    for &p in &chain() {
        let table = NttTable::new(n, p);
        for case in 0..CASES {
            let seed = base_seed() ^ (p.wrapping_mul(131)) ^ case;
            let mut rng = GlyphRng::new(seed);
            let a = rand_poly(n, p, &mut rng);
            let b = rand_poly(n, p, &mut rng);
            assert_eq!(
                table.negacyclic_mul(&a, &b),
                negacyclic_mul_naive(&a, &b, p),
                "negacyclic product mismatch: prime {p}, case {case}, seed {seed}"
            );
        }
    }
}

#[test]
fn pointwise_acc_is_linear_and_acc2_fuses_exactly() {
    let n = 128;
    for &p in &chain() {
        let table = NttTable::new(n, p);
        for case in 0..CASES {
            let seed = base_seed() ^ (p.wrapping_mul(257)) ^ case;
            let mut rng = GlyphRng::new(seed);
            let a = rand_poly(n, p, &mut rng);
            let b = rand_poly(n, p, &mut rng);
            let c = rand_poly(n, p, &mut rng);
            let d = rand_poly(n, p, &mut rng);
            let acc0 = rand_poly(n, p, &mut rng);

            // linearity: acc + a·b + c·b == acc + (a+c)·b
            let mut lhs = acc0.clone();
            table.pointwise_acc(&mut lhs, &a, &b);
            table.pointwise_acc(&mut lhs, &c, &b);
            let apc: Vec<u64> = a.iter().zip(&c).map(|(&x, &y)| add_mod(x, y, p)).collect();
            let mut rhs = acc0.clone();
            table.pointwise_acc(&mut rhs, &apc, &b);
            assert_eq!(lhs, rhs, "pointwise_acc linearity: prime {p}, case {case}, seed {seed}");

            // the fused cross-term pass == two single passes
            let mut fused = acc0.clone();
            table.pointwise_acc2(&mut fused, &a, &b, &c, &d);
            let mut split = acc0.clone();
            table.pointwise_acc(&mut split, &a, &b);
            table.pointwise_acc(&mut split, &c, &d);
            assert_eq!(fused, split, "pointwise_acc2 fusion: prime {p}, case {case}, seed {seed}");

            // reference semantics at a spot coefficient
            let j = (rng.next_u64() % n as u64) as usize;
            let want = add_mod(
                acc0[j],
                add_mod(mul_mod(a[j], b[j], p), mul_mod(c[j], d[j], p), p),
                p,
            );
            assert_eq!(fused[j], want, "pointwise_acc2 value: prime {p}, case {case}, seed {seed}");
        }
    }
}

#[test]
fn mod_switch_down_preserves_plaintext_randomized() {
    // phase = m + t·e with random m and sizeable e; after dropping the top
    // limb the phase must still be ≡ m (mod t) at every coefficient.
    let primes = chain();
    let ctx = RnsContext::new(N, &primes);
    let t = 1u64 << 16;
    for case in 0..CASES {
        let seed = base_seed() ^ 0xfeed ^ case;
        let mut rng = GlyphRng::new(seed);
        let coeffs: Vec<i64> = (0..N)
            .map(|_| {
                let m = (rng.uniform_mod(t) as i64) - (t as i64 / 2);
                let e = rng.gaussian_i64(1e6);
                m + t as i64 * e
            })
            .collect();
        let levels = 2 + (case % 2) as usize; // start from 2 or 3 limbs
        let mut poly = RnsPoly::from_signed(&ctx, &coeffs, levels);
        poly.mod_switch_down(t);
        assert_eq!(poly.level, levels - 1);
        let sub_ctx = RnsContext::new(N, &primes[..levels - 1]);
        for j in 0..N {
            let res: Vec<u64> = (0..levels - 1).map(|i| poly.res[i][j]).collect();
            let got = sub_ctx.crt_coeff_mod_t(&res, t);
            let want = coeffs[j].rem_euclid(t as i64) as u64;
            assert_eq!(got, want, "mod-switch drift: case {case}, seed {seed}, coeff {j}");
        }
    }
}
