//! Plan/execution consistency: the regression guard for the Network/Plan
//! redesign. A compiled `scheduler::Plan` carries exact per-step op counts;
//! running one real encrypted `train_step` must bump the live `OpCounter`
//! by *precisely* those totals — switches included. Any drift between what
//! the scheduler promises and what execution does fails here.

use glyph::coordinator::scheduler::StepPhase;
use glyph::math::GlyphRng;
use glyph::nn::batchnorm::BnLayer;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::network::NetworkBuilder;
use glyph::nn::tensor::{EncTensor, PackOrder};
use glyph::train::{CnnConfig, GlyphCnn, InferenceSession, MlpConfig};

fn assert_counts_match(live: glyph::coordinator::OpSnapshot, predicted: glyph::coordinator::StepOps) {
    // Plans carry no relin/mod-switch prediction (both depend on the MAC
    // engine's laziness), so those two counters are excluded — the same
    // contract the serve layer's drift gauge uses. Everything else must
    // match exactly, lane-level switch counters included.
    let diff = live.diff_ignoring(&predicted.to_snapshot(), &glyph::serve::metrics::UNPREDICTED_OPS);
    assert!(
        diff.is_empty(),
        "live execution drifted from the compiled plan: {}",
        glyph::coordinator::OpSnapshot::render_diff(&diff)
    );
}

#[test]
fn mlp_train_step_matches_compiled_plan_exactly() {
    let batch = 2;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 20260728);
    let mut rng = GlyphRng::new(17);
    let mut net = NetworkBuilder::input_vec(3)
        .fc(4)
        .relu(8, 7)
        .fc(2)
        .softmax(3, 7)
        .grad_shift(8)
        .build(&mut client, &mut rng, &engine)
        .unwrap();
    assert!(net.plan.validate());
    let predicted = net.plan.totals();
    // the plan predicts a real switch mix, not zeros — including the
    // lane-level extract/repack accounting of the batched switch engine
    assert!(predicted.switch_b2t > 0 && predicted.switch_t2b > 0 && predicted.act_gates > 0);
    assert!(predicted.extract_lanes > 0 && predicted.repack_lanes > 0);

    let x_cts = (0..3).map(|i| client.encrypt_batch(&[7 * i as i64 - 4, 9 - i as i64], 0)).collect();
    let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
    let lab_cts = (0..2)
        .map(|k| {
            let mut v = vec![if k == 0 { 127i64 } else { 0 }, if k == 1 { 127 } else { 0 }];
            v.reverse();
            client.encrypt_batch(&v, 0)
        })
        .collect();
    let labels = EncTensor::new(lab_cts, vec![2], PackOrder::Reversed, 0);

    let before = engine.counter.snapshot();
    net.train_step(&x, &labels, &engine);
    let live = engine.counter.snapshot().since(&before);
    assert_counts_match(live, predicted);
}

#[test]
fn transfer_cnn_train_step_matches_compiled_plan_exactly() {
    let batch = 2;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 20260729);
    let mut rng = GlyphRng::new(23);
    let config = CnnConfig::tiny();
    let rand_kernels = |oc: usize, ic: usize, k: usize, rng: &mut GlyphRng| -> Vec<Vec<Vec<Vec<i64>>>> {
        (0..oc)
            .map(|_| {
                (0..ic)
                    .map(|_| {
                        (0..k).map(|_| (0..k).map(|_| (rng.uniform_mod(7) as i64) - 3).collect()).collect()
                    })
                    .collect()
            })
            .collect()
    };
    let c1w = rand_kernels(2, 1, 3, &mut rng);
    let c2w = rand_kernels(3, 2, 3, &mut rng);
    let bn1 = BnLayer { gain: vec![1, 1], bias: vec![0, 0], gain_shift: 0 };
    let bn2 = BnLayer { gain: vec![1, 1, 1], bias: vec![0, 0, 0], gain_shift: 0 };
    let mut cnn =
        GlyphCnn::new(config, &c1w, bn1, &c2w, bn2, &mut client, &mut rng, &engine).unwrap();
    let predicted = cnn.net.plan.totals();
    // frozen features are MultCP-dominated, head is MultCC — the plan
    // carries the paper's transfer-learning split
    assert!(predicted.mult_cp > predicted.mult_cc);

    let cts: Vec<_> = (0..14 * 14)
        .map(|i| client.encrypt_batch(&[(i % 9) as i64 - 4, (i % 5) as i64 - 2], 0))
        .collect();
    let x = EncTensor::new(cts, vec![1, 14, 14], PackOrder::Forward, 0);
    let labels = EncTensor::new(
        vec![client.encrypt_batch(&[0, 127], 0), client.encrypt_batch(&[127, 0], 0)],
        vec![2],
        PackOrder::Reversed,
        0,
    );
    let before = engine.counter.snapshot();
    cnn.train_step(&x, &labels, &engine);
    let live = engine.counter.snapshot().since(&before);
    assert_counts_match(live, predicted);
}

#[test]
fn forward_only_mlp_inference_matches_forward_plan_exactly() {
    let batch = 2;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 20260801);
    let mut rng = GlyphRng::new(31);
    let mut net = NetworkBuilder::input_vec(3)
        .fc(4)
        .relu(8, 7)
        .fc(2)
        .softmax(3, 7)
        .grad_shift(8)
        .build(&mut client, &mut rng, &engine)
        .unwrap();
    net.plan = net.plan.forward_only();
    assert!(net.plan.validate());
    assert!(net.plan.steps.iter().all(|s| s.phase == StepPhase::Forward));
    let predicted = net.plan.totals();
    // a forward pass is strictly cheaper than a train step but still
    // crosses the cryptosystem switch both ways (MAC → TFHE act → MAC)
    assert!(predicted.switch_b2t > 0 && predicted.switch_t2b > 0 && predicted.act_gates > 0);

    let x_cts = (0..3).map(|i| client.encrypt_batch(&[5 * i as i64 - 3, 2 - i as i64], 0)).collect();
    let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
    let before = engine.counter.snapshot();
    let _ = net.forward(&x, &engine);
    let live = engine.counter.snapshot().since(&before);
    assert_counts_match(live, predicted);
}

#[test]
fn forward_only_packed_inference_matches_forward_plan_exactly() {
    // The packed (cross-sample SIMD) layout compiles different per-block
    // counts; the forward-only contract must hold there too. Clear backend:
    // the mirror counts ops identically and runs epoch-fast in CI.
    let batch = 4;
    let (engine, mut codec) = GlyphEngine::setup_clear_packed(EngineProfile::Test, batch);
    let config = MlpConfig::tiny(6, 5, 3);
    let weights = vec![
        (0..5).map(|j| (0..6).map(|i| ((i * j) % 7) as i64 - 3).collect()).collect(),
        (0..3).map(|j| (0..5).map(|i| ((i + j) % 5) as i64 - 2).collect()).collect(),
    ];
    let session = InferenceSession::from_weights(config, weights, &mut codec, &engine).unwrap();
    assert!(session.plan().steps.iter().all(|s| s.phase == StepPhase::Forward));
    let batches = 3usize;
    let predicted = session.plan().totals().to_snapshot().scale(batches as u64);

    let ds = glyph::data::synthetic_digits(batch * batches, 77, "fwd-packed");
    let before = engine.counter.snapshot();
    let rows = session.scores(&ds, batch * batches, &engine, &mut codec).unwrap();
    assert_eq!(rows.len(), batch * batches);
    let live = engine.counter.snapshot().since(&before);
    let diff = live.diff_ignoring(&predicted, &glyph::serve::metrics::UNPREDICTED_OPS);
    assert!(
        diff.is_empty(),
        "packed forward-only scoring drifted from the plan: {}",
        glyph::coordinator::OpSnapshot::render_diff(&diff)
    );
}

#[test]
fn forward_only_frozen_conv_cnn_matches_forward_plan_exactly() {
    let batch = 2;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 20260802);
    let mut rng = GlyphRng::new(41);
    let config = CnnConfig::tiny();
    let rand_kernels = |oc: usize, ic: usize, k: usize, rng: &mut GlyphRng| -> Vec<Vec<Vec<Vec<i64>>>> {
        (0..oc)
            .map(|_| {
                (0..ic)
                    .map(|_| {
                        (0..k).map(|_| (0..k).map(|_| (rng.uniform_mod(7) as i64) - 3).collect()).collect()
                    })
                    .collect()
            })
            .collect()
    };
    let c1w = rand_kernels(2, 1, 3, &mut rng);
    let c2w = rand_kernels(3, 2, 3, &mut rng);
    let bn1 = BnLayer { gain: vec![1, 1], bias: vec![0, 0], gain_shift: 0 };
    let bn2 = BnLayer { gain: vec![1, 1, 1], bias: vec![0, 0, 0], gain_shift: 0 };
    let mut cnn =
        GlyphCnn::new(config, &c1w, bn1, &c2w, bn2, &mut client, &mut rng, &engine).unwrap();
    cnn.net.plan = cnn.net.plan.forward_only();
    assert!(cnn.net.plan.steps.iter().all(|s| s.phase == StepPhase::Forward));
    let predicted = cnn.net.plan.totals();
    // inference through frozen plaintext features stays MultCP-dominated
    assert!(predicted.mult_cp > predicted.mult_cc);

    let cts: Vec<_> = (0..14 * 14)
        .map(|i| client.encrypt_batch(&[(i % 7) as i64 - 3, (i % 4) as i64 - 2], 0))
        .collect();
    let x = EncTensor::new(cts, vec![1, 14, 14], PackOrder::Forward, 0);
    let before = engine.counter.snapshot();
    let _ = cnn.net.forward(&x, &engine);
    let live = engine.counter.snapshot().since(&before);
    assert_counts_match(live, predicted);
}
