//! Proof that steady-state BGV MACs perform ZERO heap allocations — per
//! `Cc`/`Cp` accumulate *and* per `relin_finalize_into` (the acceptance
//! criterion of the lazy-relin MAC engine, extending the counting-allocator
//! harness of `zero_alloc.rs` to the BGV side; numbers in EXPERIMENTS.md
//! §BGV MAC perf log).
//!
//! A counting global allocator wraps `System`; after one warm-up row sizes
//! the scratch (and the cached weights are built), further full MAC rows —
//! at the paper MLP's fan-ins 784/128/32 — must not touch the allocator at
//! all. This file holds exactly ONE test so no concurrent test can pollute
//! the counter (each integration-test file is its own process).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_bgv_mac_rows_are_allocation_free() {
    use glyph::bgv::{BgvContext, BgvParams, BgvScratch, BgvSecretKey, CachedPlaintext, Plaintext, RelinKey};
    use glyph::math::GlyphRng;

    let ctx = BgvContext::new(BgvParams::test_params());
    let mut rng = GlyphRng::new(31338);
    let sk = BgvSecretKey::generate(&ctx, &mut rng);
    let rlk = RelinKey::generate(&sk, &mut rng);
    let level = ctx.top_level();
    let rctx = ctx.ctx_at(level).clone();

    // The paper MLP's layer fan-ins (784-128-32-10): one MAC row per layer
    // at the widest width, reusing the same operand pool.
    let fan_ins = [784usize, 128, 32];
    let widest = fan_ins[0];
    let enc = |sk: &BgvSecretKey, vals: &[i64], rng: &mut GlyphRng| {
        sk.encrypt(&Plaintext::encode_batch(vals, &ctx.params), rng)
    };
    let ws: Vec<_> = (0..widest)
        .map(|i| enc(&sk, &[(i % 15) as i64 - 7], &mut rng))
        .collect();
    let xs: Vec<_> = (0..widest)
        .map(|i| enc(&sk, &[(i % 9) as i64 - 4, ((i * 3) % 11) as i64 - 5], &mut rng))
        .collect();
    let wp: Vec<_> = (0..widest)
        .map(|i| CachedPlaintext::scalar((i % 13) as i64 - 6, &ctx))
        .collect();

    let mut scratch = BgvScratch::new();
    // Warm up: size the scratch buffers and the reusable output ciphertext.
    scratch.begin(&rctx, level);
    for i in 0..widest {
        scratch.mac_cc_tensor_into(&ws[i], &xs[i]);
    }
    let mut out = scratch.relin_finalize(&rlk, &ctx);

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for &fan_in in &fan_ins {
        // encrypted-weight row (MultCC tensor accumulate + one lazy relin)
        scratch.begin(&rctx, level);
        for i in 0..fan_in {
            scratch.mac_cc_tensor_into(&ws[i], &xs[i]);
        }
        scratch.relin_finalize_into(&mut out, &rlk, &ctx);
        std::hint::black_box(out.c0.res[0][0]);

        // frozen-weight row (cached MultCP accumulate, relin-free)
        scratch.begin(&rctx, level);
        for i in 0..fan_in {
            scratch.mac_cp_into(&xs[i], &wp[i]);
        }
        scratch.relin_finalize_into(&mut out, &rlk, &ctx);
        std::hint::black_box(out.c0.res[0][0]);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);

    let macs: usize = fan_ins.iter().map(|f| 2 * f).sum();
    assert_eq!(
        after - before,
        0,
        "steady-state BGV MAC allocated {} times over {macs} MACs + {} finalizes",
        after - before,
        2 * fan_ins.len()
    );
}
