//! End-to-end `glyph serve` smoke tests against the real binary over
//! loopback TCP: the full protocol surface, the CLI's strict flag parsing,
//! and the PR's acceptance bar — `kill -9` the server mid-epoch, restart it
//! on the same data directory, and the recovered job must finish with
//! weights/logits/op counters byte-identical to an uninterrupted run.

use glyph::serve::client::ClientError;
use glyph::serve::{
    run_infer_job, run_job, Fetched, InferOutcome, InferResult, InferSpec, JobHandle, JobResult,
    JobSpec, JobState, RunOptions, RunOutcome,
};
use glyph::serve::ServeClient;
use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_glyph");

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("glyph-smoke-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Spawn `glyph serve`, parse the bound address off its stdout, keep the
/// pipe drained so the child can never block on a full buffer.
fn spawn_server(data_dir: &std::path::Path, step_delay_ms: u64) -> (Child, SocketAddr) {
    spawn_server_env(data_dir, step_delay_ms, &[])
}

/// [`spawn_server`] with extra environment variables (fault injection).
fn spawn_server_env(
    data_dir: &std::path::Path,
    step_delay_ms: u64,
    envs: &[(&str, &str)],
) -> (Child, SocketAddr) {
    let mut cmd = Command::new(BIN);
    cmd.args(["serve", "--addr", "127.0.0.1:0", "--workers", "1"])
        .arg("--data-dir")
        .arg(data_dir)
        .stdout(Stdio::piped())
        .stderr(Stdio::null());
    if step_delay_ms > 0 {
        cmd.env("GLYPH_SERVE_STEP_DELAY_MS", step_delay_ms.to_string());
    }
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let mut child = cmd.spawn().expect("glyph binary spawns");
    let stdout = child.stdout.take().expect("stdout piped");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("server stdout readable");
        assert!(n > 0, "server exited before announcing its address");
        if let Some(rest) = line.trim().strip_prefix("glyph-serve listening on ") {
            break rest.parse::<SocketAddr>().expect("printed address parses");
        }
    };
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    (child, addr)
}

fn client(addr: SocketAddr) -> ServeClient {
    ServeClient::connect(addr).expect("connects to server")
}

fn wait_completed(c: &mut ServeClient, id: u64, secs: u64) -> JobResult {
    let status = c.wait(id, Duration::from_secs(secs)).expect("job finishes in time");
    assert_eq!(status.state, JobState::Completed, "job failed: {}", status.message);
    c.fetch_result(id).expect("completed job has a result")
}

/// Uninterrupted in-process reference run for `spec` (no persistence).
fn reference_run(spec: &JobSpec) -> JobResult {
    match run_job(&JobHandle::new(0, spec.clone()), None, &RunOptions::default()).unwrap() {
        RunOutcome::Completed(result) => result,
        other => panic!("reference run did not complete: {other:?}"),
    }
}

/// Uninterrupted in-process solo reference for an inference spec.
fn reference_infer(spec: &InferSpec) -> InferResult {
    match run_infer_job(&JobHandle::new_infer(0, spec.clone()), None).unwrap() {
        InferOutcome::Completed(result) => result,
        InferOutcome::Cancelled => panic!("reference infer run reported cancelled"),
    }
}

fn assert_identical(served: &JobResult, reference: &JobResult) {
    assert_eq!(served.steps, reference.steps);
    assert_eq!(served.weights_digest, reference.weights_digest, "weights differ");
    assert_eq!(served.logits_digest, reference.logits_digest, "logits differ");
    assert_eq!(served.ops, reference.ops, "op counters differ");
}

#[test]
fn end_to_end_protocol_over_loopback() {
    let dir = temp_dir("e2e");
    let (mut child, addr) = spawn_server(&dir, 0);
    let mut c = client(addr);
    c.ping().expect("ping");

    let mut spec = JobSpec::small_clear("smoke", 7);
    spec.samples = 16;
    spec.checkpoint_every = 2;
    let id = c.submit(&spec).expect("submit accepted");
    let result = wait_completed(&mut c, id, 120);
    assert_eq!(result.id, id);
    assert_eq!(result.steps, 4); // 16 samples / batch 4 × 1 epoch
    assert_identical(&result, &reference_run(&spec));

    // metrics: uptime, state gauges, per-job live vs predicted counters
    let text = c.metrics().expect("metrics");
    assert!(text.contains("glyph_uptime_seconds"), "{text}");
    assert!(text.contains("glyph_jobs{state=\"completed\"} 1"), "{text}");
    assert!(
        text.contains(&format!("glyph_job_steps{{job=\"{id}\",tenant=\"smoke\"}} 4")),
        "{text}"
    );
    assert!(text.contains("kind=\"predicted\""), "{text}");
    assert!(text.contains("glyph_job_op_drift"), "{text}");

    // request-level failures come back as protocol errors, not hangups
    assert!(matches!(c.status(9999), Err(ClientError::Server(_))));
    let mut bad = spec.clone();
    bad.dims = vec![16];
    assert!(matches!(c.submit(&bad), Err(ClientError::Server(_))));

    c.shutdown().expect("graceful shutdown");
    let status = child.wait().expect("server exits");
    assert!(status.success(), "server exit status: {status:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_dash_nine_mid_epoch_resumes_byte_identically() {
    let mut spec = JobSpec::small_clear("crash", 0xc0de);
    spec.samples = 40;
    spec.epochs = 2; // 20 total steps
    spec.checkpoint_every = 3;

    let dir = temp_dir("kill9");
    // Server A paces steps so the kill reliably lands mid-run.
    let (mut a, addr_a) = spawn_server(&dir, 40);
    let mut c = client(addr_a);
    let id = c.submit(&spec).expect("submit accepted");

    // Wait until at least one checkpoint is on disk, then SIGKILL — no
    // drain, no flush, exactly the crash the checkpoint format is for.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let st = c.status(id).expect("status while running");
        if st.checkpoints >= 1 && st.step < st.total_steps {
            break;
        }
        assert!(
            st.state == JobState::Queued || st.state == JobState::Running,
            "job ended before the kill: {:?}",
            st.state
        );
        assert!(Instant::now() < deadline, "no checkpoint within 60s");
        std::thread::sleep(Duration::from_millis(20));
    }
    a.kill().expect("kill -9 server A");
    let _ = a.wait();

    // Server B on the same directory: startup recovery must find the spec,
    // re-enqueue the job under the same id, and resume from the checkpoint.
    let (mut b, addr_b) = spawn_server(&dir, 0);
    let mut c = client(addr_b);
    let result = wait_completed(&mut c, id, 120);
    assert_eq!(result.id, id);
    assert!(result.resumes >= 1, "recovered run must report its resume");
    assert_identical(&result, &reference_run(&spec));

    // the metrics surface records the resume
    let text = c.metrics().expect("metrics");
    assert!(
        text.contains(&format!("glyph_job_resumes{{job=\"{id}\",tenant=\"crash\"}}")),
        "{text}"
    );

    c.shutdown().expect("graceful shutdown");
    let _ = b.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_cli_flags_error_descriptively() {
    // `--epochs banana` used to silently fall back to the default; it must
    // now fail fast with the offending flag and value named.
    let out = Command::new(BIN)
        .args(["train-mlp", "--backend", "clear", "--epochs", "banana"])
        .output()
        .expect("binary runs");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bad --epochs value \"banana\""), "stderr: {err}");

    // flag present, value missing
    let out = Command::new(BIN)
        .args(["train-mlp", "--backend", "clear", "--samples"])
        .output()
        .expect("binary runs");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--samples requires a value"), "stderr: {err}");

    // structurally bad dims are rejected before any network/keys are built
    let out = Command::new(BIN)
        .args(["submit", "--dims", "16,0,4"])
        .output()
        .expect("binary runs");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--dims"), "stderr: {err}");
}

#[test]
fn empty_dims_jobspec_is_a_typed_error_not_a_panic() {
    // The CLI validates dims before submit, but the library path must never
    // rely on that: a raw spec with no layers has no output width, and the
    // old code `.expect("validated")`-panicked on it.
    let mut spec = JobSpec::small_clear("bad", 1);
    spec.dims = vec![];
    let err = run_job(&JobHandle::new(7, spec), None, &RunOptions::default())
        .err()
        .expect("empty dims must be an error, not a panic");
    let msg = err.to_string();
    assert!(msg.contains("dims"), "error must name the bad field: {msg}");
}

#[test]
fn terminal_fetch_states_for_unknown_and_cancelled_jobs() {
    let dir = temp_dir("terminal");
    // Pace steps so job A reliably occupies the single worker while we
    // exercise B's queued-cancel path.
    let (mut child, addr) = spawn_server(&dir, 40);
    let mut c = client(addr);

    // unknown id: a protocol error naming the job, not a hangup
    match c.fetch(12345) {
        Err(ClientError::Server(msg)) => assert!(msg.contains("unknown job"), "{msg}"),
        other => panic!("unknown-id fetch must be a server error, got {other:?}"),
    }

    let mut long = JobSpec::small_clear("terminal", 0xabad);
    long.samples = 40;
    long.epochs = 2; // 20 paced steps: plenty of runway
    long.checkpoint_every = 3;
    let a = c.submit(&long).expect("submit A");
    let b = c.submit(&JobSpec::small_clear("terminal", 0xcafe)).expect("submit B");

    // B is queued behind A on the only worker; cancel it before it starts.
    c.cancel(b).expect("cancel queued job");
    let st = c.status(b).expect("status of cancelled job");
    assert_eq!(st.state, JobState::Cancelled);
    assert!(
        matches!(c.fetch(b), Ok(Fetched::Cancelled)),
        "cancelled-before-start job must fetch as the terminal Cancelled frame"
    );

    // Cancel A mid-run: same terminal answer once the worker notices.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let st = c.status(a).expect("status of running job");
        if st.state == JobState::Running && st.step > 0 {
            break;
        }
        assert!(Instant::now() < deadline, "job A never started running");
        std::thread::sleep(Duration::from_millis(20));
    }
    c.cancel(a).expect("cancel running job");
    let st = c.wait(a, Duration::from_secs(60)).expect("job A reaches a terminal state");
    assert_eq!(st.state, JobState::Cancelled, "message: {}", st.message);
    assert!(
        matches!(c.fetch(a), Ok(Fetched::Cancelled)),
        "cancelled-mid-run job must fetch as the terminal Cancelled frame"
    );

    c.shutdown().expect("graceful shutdown");
    let _ = child.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn worker_panic_fails_one_job_and_leaves_the_server_serving() {
    let dir = temp_dir("panic");
    // Fault injection: the first job panics mid-step inside the worker.
    let (mut child, addr) = spawn_server_env(&dir, 0, &[("GLYPH_SERVE_PANIC_ONCE", "2")]);
    let mut c = client(addr);

    let mut spec = JobSpec::small_clear("panic", 0xdead);
    spec.samples = 16;
    spec.checkpoint_every = 2;
    let doomed = c.submit(&spec).expect("submit accepted");
    let st = c.wait(doomed, Duration::from_secs(120)).expect("job reaches a terminal state");
    assert_eq!(st.state, JobState::Failed, "message: {}", st.message);
    assert!(st.message.contains("panicked"), "failure must say why: {}", st.message);

    // The panic was contained to that job: the same worker thread keeps
    // serving, and a second identical job completes correctly.
    c.ping().expect("server answers ping after a worker panic");
    let text = c.metrics().expect("metrics after a worker panic");
    assert!(text.contains("glyph_jobs{state=\"failed\"} 1"), "{text}");
    let spec2 = JobSpec { tenant: "panic2".into(), ..spec.clone() };
    let healthy = c.submit(&spec2).expect("submit after a worker panic");
    let result = wait_completed(&mut c, healthy, 120);
    assert_identical(&result, &reference_run(&spec2));

    c.shutdown().expect("graceful shutdown");
    let _ = child.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ragged_infer_reports_real_image_counts_over_loopback() {
    let dir = temp_dir("ragged");
    let (mut child, addr) = spawn_server(&dir, 0);
    let mut c = client(addr);

    // 5 samples at batch 2: three chunks, the last half-filled. The old
    // accounting billed batches × batch = 6 images; the real count is 5.
    let mut ispec = InferSpec::small_clear("ragged", 41);
    ispec.batch = 2;
    ispec.samples = 5;
    let id = c.submit_infer(&ispec).expect("submit ragged infer job");
    let st = c.wait(id, Duration::from_secs(120)).expect("infer finishes in time");
    assert_eq!(st.state, JobState::Completed, "infer failed: {}", st.message);
    assert_eq!(st.images, 5, "status must report real images, not padded slots");
    assert_eq!(st.step, 3);
    assert_eq!(st.total_steps, 3, "the ragged tail is a planned step");

    let Fetched::Infer(result) = c.fetch(id).expect("completed infer job has a result") else {
        panic!("infer job must fetch as an InferResult");
    };
    assert_eq!(result.images, 5, "padding slots must not be billed as scored images");
    assert_eq!(result.batches, 3);
    let reference = reference_infer(&ispec);
    assert_eq!(result.logits_digest, reference.logits_digest, "served logits diverged");
    assert_eq!(result.predictions_digest, reference.predictions_digest);

    // the scrape surface divides latency by the same real image count
    let text = c.metrics().expect("metrics");
    let labels = format!("job=\"{id}\",tenant=\"ragged\"");
    assert!(text.contains(&format!("glyph_infer_images_total{{{labels}}} 5")), "{text}");
    assert!(text.contains(&format!("glyph_infer_latency_seconds{{{labels}}}")), "{text}");

    c.shutdown().expect("graceful shutdown");
    let _ = child.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn coalesced_tenants_share_one_group_and_match_solo_digests() {
    let dir = temp_dir("coalesce");
    // Paced steps keep the single worker busy on a blocker job long enough
    // for both coalesce submissions to land in the lane before it drains.
    let (mut child, addr) = spawn_server(&dir, 30);
    let mut c = client(addr);

    let mut blocker = JobSpec::small_clear("blocker", 1);
    blocker.samples = 40; // 10 paced steps of runway
    c.submit(&blocker).expect("submit blocker");

    let mut aspec = InferSpec::small_clear("alice", 43);
    aspec.batch = 2;
    aspec.samples = 6;
    aspec.coalesce = true;
    let mut bspec = aspec.clone();
    bspec.tenant = "bob".into();
    bspec.samples = 4;
    let a = c.submit_infer(&aspec).expect("submit alice");
    let b = c.submit_infer(&bspec).expect("submit bob");

    let st_a = c.wait(a, Duration::from_secs(120)).expect("alice finishes");
    assert_eq!(st_a.state, JobState::Completed, "alice failed: {}", st_a.message);
    let st_b = c.wait(b, Duration::from_secs(120)).expect("bob finishes");
    assert_eq!(st_b.state, JobState::Completed, "bob failed: {}", st_b.message);
    assert_ne!(st_a.group, 0, "coalesced jobs must record a batch group");
    assert_eq!(st_a.group, st_b.group, "both tenants must share one batch group");

    // Coalescing is invisible in the scores: each tenant's digests are
    // byte-identical to a solo in-process run of its own spec.
    for (id, spec) in [(a, &aspec), (b, &bspec)] {
        let Fetched::Infer(result) = c.fetch(id).expect("coalesced member has a result") else {
            panic!("infer job must fetch as an InferResult");
        };
        let reference = reference_infer(spec);
        assert_eq!(result.logits_digest, reference.logits_digest, "job {id}: logits diverged");
        assert_eq!(result.predictions_digest, reference.predictions_digest, "job {id}");
        assert_eq!(result.images, reference.images, "job {id}: image counts diverged");
    }

    // Lane gauges: one group, 6+4 images over 3 passes of width 4 → 10 of
    // 12 slots filled.
    let text = c.metrics().expect("metrics");
    let lane = format!("lane=\"{}\"", aspec.lane_label());
    assert!(text.contains(&format!("glyph_lane_groups_total{{{lane}}} 1")), "{text}");
    assert!(text.contains(&format!("glyph_lane_images_total{{{lane}}} 10")), "{text}");
    assert!(text.contains(&format!("glyph_lane_fill_ratio{{{lane}}} 0.833333")), "{text}");

    c.shutdown().expect("graceful shutdown");
    let _ = child.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cancelling_one_coalesced_member_leaves_the_other_intact() {
    let dir = temp_dir("coalesce-cancel");
    let (mut child, addr) = spawn_server(&dir, 40);
    let mut c = client(addr);

    let mut blocker = JobSpec::small_clear("blocker", 2);
    blocker.samples = 20; // 5 paced steps: enough to enlane both members
    c.submit(&blocker).expect("submit blocker");

    let mut aspec = InferSpec::small_clear("alice", 47);
    aspec.batch = 2;
    aspec.samples = 40; // 20 paced passes: the cancel lands mid-group
    aspec.coalesce = true;
    let mut bspec = aspec.clone();
    bspec.tenant = "bob".into();
    let a = c.submit_infer(&aspec).expect("submit alice");
    let b = c.submit_infer(&bspec).expect("submit bob");

    // Wait for the group to start scoring bob, then cancel him mid-group.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let st = c.status(b).expect("status of coalesced member");
        if st.state == JobState::Running && st.step >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "coalesced group never started");
        std::thread::sleep(Duration::from_millis(20));
    }
    c.cancel(b).expect("cancel coalesced member");
    let st_b = c.wait(b, Duration::from_secs(120)).expect("bob reaches a terminal state");
    assert_eq!(st_b.state, JobState::Cancelled, "message: {}", st_b.message);
    assert!(
        matches!(c.fetch(b), Ok(Fetched::Cancelled)),
        "cancelled member must fetch as the terminal Cancelled frame"
    );

    // The survivor keeps scoring in the same group and stays byte-exact.
    let st_a = c.wait(a, Duration::from_secs(120)).expect("alice finishes");
    assert_eq!(st_a.state, JobState::Completed, "alice failed: {}", st_a.message);
    assert_ne!(st_a.group, 0);
    assert_eq!(st_a.group, st_b.group, "both members were coalesced into one group");
    let Fetched::Infer(result) = c.fetch(a).expect("survivor has a result") else {
        panic!("infer job must fetch as an InferResult");
    };
    let reference = reference_infer(&aspec);
    assert_eq!(result.images, 40);
    assert_eq!(result.logits_digest, reference.logits_digest, "survivor logits diverged");
    assert_eq!(result.predictions_digest, reference.predictions_digest);

    c.shutdown().expect("graceful shutdown");
    let _ = child.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn infer_job_end_to_end_over_loopback() {
    let dir = temp_dir("infer");
    let (mut child, addr) = spawn_server(&dir, 0);
    let mut c = client(addr);

    // Train first: the infer job scores that job's persisted final model.
    let train = JobSpec::small_clear("infer-e2e", 31);
    let model_id = c.submit(&train).expect("submit train job");
    wait_completed(&mut c, model_id, 120);

    let mut ispec = InferSpec::small_clear("infer-e2e", 31);
    ispec.model_job = model_id;

    // Guard rails first: a seed mismatch means the weights would not
    // decrypt under the inference key, and a dangling model_job has no
    // weights at all. Both must be submit-time errors.
    let mut bad = ispec.clone();
    bad.seed = 32;
    assert!(matches!(c.submit_infer(&bad), Err(ClientError::Server(_))));
    bad = ispec.clone();
    bad.model_job = 9999;
    assert!(matches!(c.submit_infer(&bad), Err(ClientError::Server(_))));

    let id = c.submit_infer(&ispec).expect("submit infer job");
    let st = c.wait(id, Duration::from_secs(120)).expect("infer finishes in time");
    assert_eq!(st.state, JobState::Completed, "infer failed: {}", st.message);
    assert_eq!(st.images, ispec.samples, "status must report images scored");
    let Fetched::Infer(result) = c.fetch(id).expect("completed infer job has a result") else {
        panic!("infer job must fetch as an InferResult");
    };
    assert_eq!(result.id, id);
    assert_eq!(result.images, ispec.samples);
    assert_eq!(result.batches, ispec.samples / ispec.batch);

    // Scoring is deterministic: resubmitting the same spec reproduces the
    // exact logits and predictions, digest for digest.
    let id2 = c.submit_infer(&ispec).expect("resubmit infer job");
    c.wait(id2, Duration::from_secs(120)).expect("second infer finishes");
    let Fetched::Infer(again) = c.fetch(id2).expect("second infer has a result") else {
        panic!("infer job must fetch as an InferResult");
    };
    assert_eq!(again.logits_digest, result.logits_digest, "logits digest not reproducible");
    assert_eq!(again.predictions_digest, result.predictions_digest);
    assert_eq!(again.ops, result.ops, "op counters not reproducible");

    // Per-job inference metrics are on the scrape surface.
    let text = c.metrics().expect("metrics");
    assert!(
        text.contains(&format!(
            "glyph_infer_images_total{{job=\"{id}\",tenant=\"infer-e2e\"}} {}",
            ispec.samples
        )),
        "{text}"
    );
    assert!(text.contains("glyph_infer_latency_seconds"), "{text}");

    c.shutdown().expect("graceful shutdown");
    let _ = child.wait();
    let _ = std::fs::remove_dir_all(&dir);
}
