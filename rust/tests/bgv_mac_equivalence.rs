//! Equivalence of the scratch/lazy-relinearization BGV MAC path against the
//! retained per-term reference path (`mul_assign`/`mul_plain_assign` +
//! `add_assign`), mirroring `pbs_equivalence.rs` on the BGV side: for fixed
//! RNG seeds, both paths must *decrypt bit-identically* — same plaintext
//! coefficients over the whole ring, not merely close values — for MultCP
//! and MultCC weights, across forward/backward/gradient MAC shapes and
//! across the levels of the modulus chain.
//!
//! (The ciphertext *phases* legitimately differ: the reference path adds
//! one relinearization error per `Cc` term, the lazy path exactly one per
//! row — that is the point of the optimization. Equality of every decoded
//! plaintext coefficient is the correctness contract.)

use glyph::bgv::{
    mac_row, BgvCiphertext, BgvContext, BgvScratch, BgvSecretKey, CachedPlaintext, MacTerm,
    Plaintext, RelinKey,
};
use glyph::math::GlyphRng;
use glyph::nn::engine::{ClientKeys, EngineProfile, GlyphEngine};
use glyph::nn::linear::FcLayer;
use glyph::nn::tensor::{EncTensor, PackOrder};
use std::sync::Arc;

struct Fx {
    ctx: Arc<BgvContext>,
    sk: BgvSecretKey,
    rlk: RelinKey,
    rng: GlyphRng,
}

fn fixture(seed: u64) -> Fx {
    let ctx = BgvContext::new(glyph::bgv::BgvParams::test_params());
    let mut rng = GlyphRng::new(seed);
    let sk = BgvSecretKey::generate(&ctx, &mut rng);
    let rlk = RelinKey::generate(&sk, &mut rng);
    Fx { ctx, sk, rlk, rng }
}

fn enc_at(f: &mut Fx, vals: &[i64], level: usize) -> BgvCiphertext {
    let pt = Plaintext::encode_batch(vals, &f.ctx.params);
    f.sk.encrypt_at(&pt, level, &mut f.rng)
}

/// Whole-ring decryption (every coefficient, not just the batch lanes).
fn dec_full(f: &Fx, ct: &BgvCiphertext) -> Vec<i64> {
    f.sk.decrypt(ct).coeffs
}

/// Reference accumulation: per-term relinearization + AddCC.
fn reference_row(f: &Fx, terms: &[MacTerm]) -> BgvCiphertext {
    let mut acc: Option<BgvCiphertext> = None;
    for t in terms {
        let product = match *t {
            MacTerm::Cc(a, b) => {
                let mut p = a.clone();
                p.mul_assign(b, &f.rlk, &f.ctx);
                p
            }
            MacTerm::Cp(x, w) => {
                let mut p = x.clone();
                p.mul_plain_cached_assign(w);
                p
            }
        };
        match &mut acc {
            None => acc = Some(product),
            Some(a) => a.add_assign(&product),
        }
    }
    acc.expect("row has terms")
}

#[test]
fn mult_cc_rows_decrypt_identically_across_levels() {
    // MultCC + relinearization needs at least two limbs of headroom (the
    // digit × key-error convolution is ~2^58 at test scale, vs q_1/2 ≈
    // 2^31), matching real engine usage: relin never runs at the bottom
    // level. Levels 2..=top are the chain the MAC engine actually serves.
    let mut f = fixture(20260728);
    let mut scratch = BgvScratch::new();
    for level in 2..=f.ctx.top_level() {
        for in_dim in [1usize, 2, 7, 16] {
            let mut ws = Vec::new();
            let mut xs = Vec::new();
            let mut rng = GlyphRng::new(level as u64 * 1000 + in_dim as u64);
            for _ in 0..in_dim {
                let wv = (rng.uniform_mod(31) as i64) - 15;
                let xv: Vec<i64> =
                    (0..4).map(|_| (rng.uniform_mod(255) as i64) - 127).collect();
                ws.push(enc_at(&mut f, &[wv], level));
                xs.push(enc_at(&mut f, &xv, level));
            }
            let row: Vec<MacTerm> =
                ws.iter().zip(&xs).map(|(w, x)| MacTerm::Cc(w, x)).collect();
            let fast = mac_row(&mut scratch, &row, &f.rlk, &f.ctx);
            let reference = reference_row(&f, &row);
            assert_eq!(fast.level, level);
            assert_eq!(
                dec_full(&f, &fast),
                dec_full(&f, &reference),
                "level {level}, in_dim {in_dim}"
            );
        }
    }
}

#[test]
fn mult_cp_rows_decrypt_identically_across_every_level() {
    // MultCP is relin-free, so it runs clean at *every* level including the
    // bottom limb (small weights keep the noise inside q_1/2).
    let mut f = fixture(20260729);
    let mut scratch = BgvScratch::new();
    for level in 1..=f.ctx.top_level() {
        for in_dim in [1usize, 3, 9] {
            let mut rng = GlyphRng::new(level as u64 * 77 + in_dim as u64);
            let mut xs = Vec::new();
            let mut wps = Vec::new();
            for _ in 0..in_dim {
                let wv = (rng.uniform_mod(15) as i64) - 7;
                let xv: Vec<i64> = (0..4).map(|_| (rng.uniform_mod(31) as i64) - 15).collect();
                xs.push(enc_at(&mut f, &xv, level));
                wps.push(CachedPlaintext::scalar(wv, &f.ctx));
            }
            let row: Vec<MacTerm> =
                xs.iter().zip(&wps).map(|(x, w)| MacTerm::Cp(x, w)).collect();
            let fast = mac_row(&mut scratch, &row, &f.rlk, &f.ctx);
            let reference = reference_row(&f, &row);
            assert_eq!(
                dec_full(&f, &fast),
                dec_full(&f, &reference),
                "level {level}, in_dim {in_dim}"
            );
        }
    }
}

#[test]
fn mixed_cc_cp_rows_decrypt_identically() {
    let mut f = fixture(20260730);
    let mut scratch = BgvScratch::new();
    let level = f.ctx.top_level();
    let mut rng = GlyphRng::new(99);
    let mut ws = Vec::new();
    let mut xs = Vec::new();
    let mut wps = Vec::new();
    for _ in 0..6 {
        let wv = (rng.uniform_mod(31) as i64) - 15;
        let xv: Vec<i64> = (0..4).map(|_| (rng.uniform_mod(255) as i64) - 127).collect();
        ws.push(enc_at(&mut f, &[wv], level));
        xs.push(enc_at(&mut f, &xv, level));
        wps.push(CachedPlaintext::scalar(wv - 1, &f.ctx));
    }
    let row: Vec<MacTerm> = (0..6)
        .map(|i| {
            if i % 2 == 0 {
                MacTerm::Cc(&ws[i], &xs[i])
            } else {
                MacTerm::Cp(&xs[i], &wps[i])
            }
        })
        .collect();
    let fast = mac_row(&mut scratch, &row, &f.rlk, &f.ctx);
    let reference = reference_row(&f, &row);
    assert_eq!(dec_full(&f, &fast), dec_full(&f, &reference));
}

#[test]
fn gradient_shape_reverse_packed_convolution_matches() {
    // The backward gradient MAC: forward-packed x ⊗ reverse-packed δ, batch
    // sum at coefficient batch−1 — the lazy path must leave the identical
    // coefficient everywhere (the switch later reads position batch−1).
    let mut f = fixture(20260731);
    let mut scratch = BgvScratch::new();
    let level = f.ctx.top_level();
    let batch = 4usize;
    let x_vals = vec![3i64, -2, 5, 1];
    let d_vals = vec![2i64, 4, -1, 3];
    let mut d_rev = d_vals.clone();
    d_rev.reverse();
    let x = enc_at(&mut f, &x_vals, level);
    let d = enc_at(&mut f, &d_rev, level);
    let row = [MacTerm::Cc(&x, &d)];
    let fast = mac_row(&mut scratch, &row, &f.rlk, &f.ctx);
    let reference = reference_row(&f, &row);
    let fast_pt = dec_full(&f, &fast);
    assert_eq!(fast_pt, dec_full(&f, &reference));
    let want: i64 = x_vals.iter().zip(&d_vals).map(|(a, b)| a * b).sum();
    assert_eq!(fast_pt[batch - 1], want);
}

#[test]
fn fc_layer_paths_match_naive_engine_oracle() {
    // Forward / backward_error / gradients through the pooled FcLayer (the
    // mac_rows_many path) against a hand-rolled naive loop over the counted
    // reference ops — the layer-level mirror of the row tests above.
    let batch = 3usize;
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 4096);
    let w_init = vec![vec![2i64, -3, 4], vec![1, 0, -5]];
    let layer = FcLayer::new_encrypted(&w_init, &mut client, 0);
    let enc_cols = |client: &mut ClientKeys, cols: &[Vec<i64>], order: PackOrder| {
        let cts = cols.iter().map(|v| client.encrypt_batch(v, 0)).collect();
        EncTensor::new(cts, vec![cols.len()], order, 0)
    };
    let x_cols = vec![vec![5i64, -1, 0], vec![7, 2, -3], vec![-2, 6, 1]];
    let x = enc_cols(&mut client, &x_cols, PackOrder::Forward);

    // forward
    let u = layer.forward(&x, &engine);
    let naive_forward: Vec<BgvCiphertext> = (0..2)
        .map(|j| {
            let mut acc: Option<BgvCiphertext> = None;
            for i in 0..3 {
                let wct = match &layer.w[j][i] {
                    glyph::nn::linear::Weight::Enc(ct) => ct,
                    _ => unreachable!("encrypted layer"),
                };
                let mut t = wct.fhe().clone();
                t.mul_assign(x.cts[i].fhe(), &engine.fhe().rlk, &engine.fhe().ctx);
                match &mut acc {
                    None => acc = Some(t),
                    Some(a) => a.add_assign(&t),
                }
            }
            acc.unwrap()
        })
        .collect();
    for j in 0..2 {
        assert_eq!(
            client.bgv_sk.decrypt(u.cts[j].fhe()).coeffs,
            client.bgv_sk.decrypt(&naive_forward[j]).coeffs,
            "forward row {j}"
        );
    }

    // backward error (reverse-packed delta)
    let d_cols = vec![vec![4i64, -2, 1], vec![-3, 5, 2]];
    let delta = enc_cols(&mut client, &d_cols, PackOrder::Reversed);
    let back = layer.backward_error(&delta, &engine);
    for i in 0..3 {
        let mut acc: Option<BgvCiphertext> = None;
        for j in 0..2 {
            let wct = match &layer.w[j][i] {
                glyph::nn::linear::Weight::Enc(ct) => ct,
                _ => unreachable!(),
            };
            let mut t = wct.fhe().clone();
            t.mul_assign(delta.cts[j].fhe(), &engine.fhe().rlk, &engine.fhe().ctx);
            match &mut acc {
                None => acc = Some(t),
                Some(a) => a.add_assign(&t),
            }
        }
        assert_eq!(
            client.bgv_sk.decrypt(back.cts[i].fhe()).coeffs,
            client.bgv_sk.decrypt(&acc.unwrap()).coeffs,
            "backward col {i}"
        );
    }

    // gradients
    let grads = layer.gradients(&x, &delta, &engine);
    for j in 0..2 {
        for i in 0..3 {
            let mut g = x.cts[i].fhe().clone();
            g.mul_assign(delta.cts[j].fhe(), &engine.fhe().rlk, &engine.fhe().ctx);
            assert_eq!(
                client.bgv_sk.decrypt(grads[j][i].fhe()).coeffs,
                client.bgv_sk.decrypt(&g).coeffs,
                "gradient ({j},{i})"
            );
        }
    }
}
