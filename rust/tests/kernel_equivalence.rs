//! Scalar vs SIMD kernel bit-identity, enforced directly at every dispatch
//! point of the ring-arithmetic core (`math/kernels.rs`): NTT forward /
//! inverse / pointwise passes, the complex FFT pipeline (to the last f64
//! bit), the gadget decomposition and the hoisted LWE key switch, plus a
//! whole TRGSW external product run under both kernel sets. Seeded with the
//! `GLYPH_PROP_SEED` replay convention of `tests/ntt_properties.rs`.
//!
//! The five conformance suites check the same property end-to-end through
//! the CI kernel matrix (`GLYPH_KERNELS=scalar` vs `=simd`); this suite
//! pins both kernel sets in ONE process so a divergence fails fast with the
//! exact operation named.

use glyph::math::fft::TorusFft;
use glyph::math::kernels::{scalar_kernels, simd_kernels};
use glyph::math::modarith::{gen_ntt_primes, shoup_precompute};
use glyph::math::{GlyphRng, NttTable};
use glyph::tfhe::{
    KsScratch, LweCiphertext, LweKey, LweKeySwitchKey, TfheParams, TrgswCiphertext,
    TrlweCiphertext, TrlweKey,
};

const CASES: u64 = 25;

fn base_seed() -> u64 {
    std::env::var("GLYPH_PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x9e37_79b9_7f4a_7c15)
}

fn chain() -> Vec<u64> {
    gen_ntt_primes(3, 1 << 26, 1 << 32)
}

fn rand_poly(n: usize, p: u64, rng: &mut GlyphRng) -> Vec<u64> {
    (0..n).map(|_| rng.next_u64() % p).collect()
}

#[test]
fn ntt_transforms_are_bit_identical() {
    for &p in &chain() {
        for n in [64usize, 256, 1024] {
            let ts = NttTable::with_kernels(n, p, scalar_kernels());
            let tv = NttTable::with_kernels(n, p, simd_kernels());
            for case in 0..CASES {
                let seed = base_seed() ^ (p.wrapping_mul(n as u64)) ^ case;
                let mut rng = GlyphRng::new(seed);
                let a = rand_poly(n, p, &mut rng);
                let mut fs = a.clone();
                let mut fv = a.clone();
                ts.forward(&mut fs);
                tv.forward(&mut fv);
                assert_eq!(fs, fv, "forward: prime {p}, n {n}, case {case}, seed {seed}");
                ts.inverse(&mut fs);
                tv.inverse(&mut fv);
                assert_eq!(fs, fv, "inverse: prime {p}, n {n}, case {case}, seed {seed}");
                assert_eq!(fs, a, "roundtrip: prime {p}, n {n}, case {case}, seed {seed}");
            }
        }
    }
}

#[test]
fn pointwise_passes_are_bit_identical() {
    let n = 256;
    for &p in &chain() {
        let ts = NttTable::with_kernels(n, p, scalar_kernels());
        let tv = NttTable::with_kernels(n, p, simd_kernels());
        for case in 0..CASES {
            let seed = base_seed() ^ (p.wrapping_mul(977)) ^ case;
            let mut rng = GlyphRng::new(seed);
            let a = rand_poly(n, p, &mut rng);
            let b = rand_poly(n, p, &mut rng);
            let c = rand_poly(n, p, &mut rng);
            let d = rand_poly(n, p, &mut rng);
            let acc0 = rand_poly(n, p, &mut rng);

            let mut x1 = a.clone();
            let mut x2 = a.clone();
            ts.pointwise(&mut x1, &b);
            tv.pointwise(&mut x2, &b);
            assert_eq!(x1, x2, "pointwise: prime {p}, case {case}, seed {seed}");

            let mut s1 = acc0.clone();
            let mut s2 = acc0.clone();
            ts.pointwise_acc(&mut s1, &a, &b);
            tv.pointwise_acc(&mut s2, &a, &b);
            assert_eq!(s1, s2, "pointwise_acc: prime {p}, case {case}, seed {seed}");

            let mut f1 = acc0.clone();
            let mut f2 = acc0.clone();
            ts.pointwise_acc2(&mut f1, &a, &b, &c, &d);
            tv.pointwise_acc2(&mut f2, &a, &b, &c, &d);
            assert_eq!(f1, f2, "pointwise_acc2: prime {p}, case {case}, seed {seed}");

            let s = rng.next_u64() % p;
            let ss = shoup_precompute(s, p);
            let mut m1 = a.clone();
            let mut m2 = a.clone();
            ts.scalar_mul(&mut m1, s, ss);
            tv.scalar_mul(&mut m2, s, ss);
            assert_eq!(m1, m2, "scalar_mul: prime {p}, case {case}, seed {seed}");
        }
    }
}

#[test]
fn fft_pipeline_is_bit_identical_to_the_last_f64_bit() {
    for n in [64usize, 256, 1024] {
        let fs = TorusFft::with_kernels(n, scalar_kernels());
        let fv = TorusFft::with_kernels(n, simd_kernels());
        for case in 0..CASES {
            let seed = base_seed() ^ (n as u64).wrapping_mul(0x5bd1) ^ case;
            let mut rng = GlyphRng::new(seed);
            let ints: Vec<i32> = (0..n).map(|_| (rng.uniform_mod(129) as i32) - 64).collect();
            let torus: Vec<u32> = (0..n).map(|_| rng.torus32()).collect();

            let zs = fs.forward_torus(&torus);
            let zv = fv.forward_torus(&torus);
            let is = fs.forward_int(&ints);
            let iv = fv.forward_int(&ints);
            for (k, ((ts, tv), (gs, gv))) in
                zs.iter().zip(&zv).zip(is.iter().zip(&iv)).enumerate()
            {
                assert_eq!(ts.re.to_bits(), tv.re.to_bits(), "fwd_torus re: n {n}, case {case}, seed {seed}, lane {k}");
                assert_eq!(ts.im.to_bits(), tv.im.to_bits(), "fwd_torus im: n {n}, case {case}, seed {seed}, lane {k}");
                assert_eq!(gs.re.to_bits(), gv.re.to_bits(), "fwd_int re: n {n}, case {case}, seed {seed}, lane {k}");
                assert_eq!(gs.im.to_bits(), gv.im.to_bits(), "fwd_int im: n {n}, case {case}, seed {seed}, lane {k}");
            }

            // frequency MAC + inverse: the rounded torus output must agree
            // exactly (it does if the f64s do)
            assert_eq!(
                fs.negacyclic_mul_int_torus(&ints, &torus),
                fv.negacyclic_mul_int_torus(&ints, &torus),
                "negacyclic int×torus: n {n}, case {case}, seed {seed}"
            );
        }
    }
}

#[test]
fn gadget_decomposition_is_identical() {
    let n = 512;
    for (levels, bb) in [(2usize, 8u32), (3, 7), (7, 4), (8, 2)] {
        for case in 0..CASES {
            let seed = base_seed() ^ ((levels as u64) << 8) ^ (bb as u64) ^ case;
            let mut rng = GlyphRng::new(seed);
            let a: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            let mut ds = vec![0i32; levels * n];
            let mut dv = vec![0i32; levels * n];
            scalar_kernels().decompose_poly(&a, levels, bb, &mut ds);
            simd_kernels().decompose_poly(&a, levels, bb, &mut dv);
            assert_eq!(ds, dv, "decompose: levels {levels}, bb {bb}, case {case}, seed {seed}");
        }
    }
}

#[test]
fn lwe_keyswitch_is_bit_identical_under_both_kernels() {
    let mut rng = GlyphRng::new(base_seed() ^ 0x4b53);
    let src = LweKey::generate_binary(256, &mut rng);
    let dst = LweKey::generate_binary(64, &mut rng);
    let mut ksk = LweKeySwitchKey::generate(&src, &dst, 2, 8, 1e-8, &mut rng);
    for case in 0..CASES {
        let msg = (rng.next_u64() as u32) & 0xfff0_0000;
        let ct = LweCiphertext::encrypt(msg, &src, 1e-8, &mut rng);
        ksk.kernels = scalar_kernels();
        let out_s = ksk.switch(&ct);
        ksk.kernels = simd_kernels();
        let out_v = ksk.switch(&ct);
        assert_eq!(out_s.a, out_v.a, "ks mask: case {case}");
        assert_eq!(out_s.b, out_v.b, "ks body: case {case}");

        // caller-owned scratch path == thread-local path
        let mut scratch = KsScratch::new();
        let mut out_w = LweCiphertext::trivial(0, 64);
        ksk.switch_into_with(&ct, &mut scratch, &mut out_w);
        assert_eq!(out_v.a, out_w.a, "ks scratch mask: case {case}");
        assert_eq!(out_v.b, out_w.b, "ks scratch body: case {case}");
    }
}

#[test]
fn trgsw_external_product_is_bit_identical() {
    // The TRGSW rows come from ONE key (forward FFTs are themselves
    // bit-identical across kernels, asserted above), then the external
    // product runs once per kernel set through an explicitly-pinned plan.
    let params = TfheParams::test_params();
    let mut rng = GlyphRng::new(base_seed() ^ 0x7274);
    let key = TrlweKey::generate(params.big_n, &mut rng);
    let fft_s = TorusFft::with_kernels(params.big_n, scalar_kernels());
    let fft_v = TorusFft::with_kernels(params.big_n, simd_kernels());
    let msg: Vec<u32> = (0..params.big_n).map(|i| ((i % 8) as u32) << 28).collect();
    let c = TrlweCiphertext::encrypt(&msg, &key, params.alpha_rlwe, &mut rng);
    for bit in [0i32, 1] {
        let g = TrgswCiphertext::encrypt_scalar(bit, &key, &params, &mut rng);
        let prod_s = g.external_product(&c, &fft_s);
        let prod_v = g.external_product(&c, &fft_v);
        assert_eq!(prod_s.a, prod_v.a, "external product mask, bit {bit}");
        assert_eq!(prod_s.b, prod_v.b, "external product body, bit {bit}");
    }
}
