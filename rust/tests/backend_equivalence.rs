//! Differential backend conformance: the clear execution backend must be
//! **byte-identical** to the FHE path — decrypt(FHE(train_step)) ==
//! clear(train_step) for logits, per-unit forward outputs, propagated
//! errors, gradients and post-update weights — across random shapes,
//! shifts, softmax bit widths, and both MLP and frozen-conv transfer
//! topologies.
//!
//! Alignment contract: the suite drives every switch crossing on the 8-bit
//! quantization grid (zero activation shifts, or shift-`s` layers fed
//! values ≡ 0 mod 2^s), which is the regime the extraction design itself
//! guarantees deterministic — mid-window phases sit ≈2^23 from any PBS
//! decision boundary, far beyond the modulus-switch noise. Off-grid
//! residues land inside that noise band where even the lattice path is
//! only accurate to ±1 ulp (module docs of `switch::extract`), so no
//! deterministic mirror can — or should — track individual noise draws.
//!
//! Seeds print on failure; set `GLYPH_PROP_SEED` to replay a base seed
//! (the `ntt_properties.rs` / `switch_roundtrip.rs` convention).

use glyph::coordinator::{OpSnapshot, StepOps};
use glyph::math::GlyphRng;
use glyph::nn::backend::Codec;
use glyph::nn::engine::{ClientKeys, EngineProfile, GlyphEngine};
use glyph::nn::linear::Weight;
use glyph::nn::network::{Network, NetworkBuilder};
use glyph::nn::tensor::{EncTensor, PackOrder, PackedLayout};

fn base_seed() -> u64 {
    std::env::var("GLYPH_PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0xbac_4e9d_0042_7e57)
}

const BATCH: usize = 2;

struct Backends {
    fhe: GlyphEngine,
    fhe_client: ClientKeys,
    clear: GlyphEngine,
    clear_codec: glyph::nn::backend::ClearCodec,
}

impl Backends {
    fn new(seed: u64) -> Self {
        let (fhe, fhe_client) = GlyphEngine::setup(EngineProfile::Test, BATCH, seed);
        let (clear, clear_codec) = GlyphEngine::setup_clear(EngineProfile::Test, BATCH);
        Backends { fhe, fhe_client, clear, clear_codec }
    }
}

fn encode_cols(
    codec: &mut dyn Codec,
    cols: &[Vec<i64>],
    shape: Vec<usize>,
    order: PackOrder,
) -> EncTensor {
    let cts = cols.iter().map(|v| codec.encrypt_batch(v, 0)).collect();
    EncTensor::new(cts, shape, order, 0)
}

fn one_hot_labels(codec: &mut dyn Codec, classes: usize, sample_classes: &[usize]) -> EncTensor {
    let cts = (0..classes)
        .map(|k| {
            let mut v: Vec<i64> =
                sample_classes.iter().map(|&l| if l == k { 127 } else { 0 }).collect();
            v.reverse();
            codec.encrypt_batch(&v, 0)
        })
        .collect();
    EncTensor::new(cts, vec![classes], PackOrder::Reversed, 0)
}

fn weight_snapshot(net: &Network, codec: &dyn Codec) -> Vec<i64> {
    net.fc_layers()
        .iter()
        .flat_map(|l| {
            l.w.iter().flat_map(|row| {
                row.iter().map(|w| match w {
                    Weight::Enc(ct) => codec.decrypt_batch(ct, 1, 0)[0],
                    Weight::Plain(p) => p.value(),
                })
            })
        })
        .collect()
}

fn decode_tensor(codec: &dyn Codec, t: &EncTensor) -> Vec<Vec<i64>> {
    t.cts.iter().map(|ct| codec.decrypt_batch(ct, BATCH, 0)).collect()
}

/// Build the same network on both backends (same weight-draw seed), run one
/// forward + train_step on identical inputs, and assert every decoded
/// intermediate, the logits, the op-counter deltas and the updated weights
/// agree byte-for-byte. Also asserts the clear path's live counters equal
/// the compiled plan's totals exactly.
#[allow(clippy::too_many_arguments)]
fn assert_train_step_equivalent(
    case: &str,
    seed: u64,
    be: &mut Backends,
    build: impl Fn() -> NetworkBuilder,
    x_cols: &[Vec<i64>],
    in_shape: Vec<usize>,
    classes: usize,
    sample_classes: &[usize],
) {
    let mut rng_f = GlyphRng::new(seed ^ 0x11);
    let mut rng_c = GlyphRng::new(seed ^ 0x11);
    let mut net_f = build()
        .build(&mut be.fhe_client, &mut rng_f, &be.fhe)
        .unwrap_or_else(|e| panic!("case {case} seed {seed}: fhe build failed: {e}"));
    let mut net_c = build()
        .build(&mut be.clear_codec, &mut rng_c, &be.clear)
        .unwrap_or_else(|e| panic!("case {case} seed {seed}: clear build failed: {e}"));
    assert_eq!(
        weight_snapshot(&net_f, &be.fhe_client),
        weight_snapshot(&net_c, &be.clear_codec),
        "case {case} seed {seed}: initial weights must encode identically"
    );

    let x_f = encode_cols(&mut be.fhe_client, x_cols, in_shape.clone(), PackOrder::Forward);
    let x_c = encode_cols(&mut be.clear_codec, x_cols, in_shape.clone(), PackOrder::Forward);
    let lab_f = one_hot_labels(&mut be.fhe_client, classes, sample_classes);
    let lab_c = one_hot_labels(&mut be.clear_codec, classes, sample_classes);

    // forward: every unit's output (and thus the logits/distribution) must
    // decode identically
    let pass_f = net_f.forward(&x_f, &be.fhe);
    let pass_c = net_c.forward(&x_c, &be.clear);
    assert_eq!(pass_f.outputs.len(), pass_c.outputs.len(), "case {case} seed {seed}");
    for (u, (tf, tc)) in pass_f.outputs.iter().zip(&pass_c.outputs).enumerate() {
        assert_eq!(
            decode_tensor(&be.fhe_client, tf),
            decode_tensor(&be.clear_codec, tc),
            "case {case} seed {seed}: unit {u} forward output diverged"
        );
    }

    // one full SGD step: identical op accounting and identical weights
    let before_f = be.fhe.counter.snapshot();
    let before_c = be.clear.counter.snapshot();
    net_f.train_step(&x_f, &lab_f, &be.fhe);
    net_c.train_step(&x_c, &lab_c, &be.clear);
    let delta_f = be.fhe.counter.snapshot().since(&before_f);
    let delta_c = be.clear.counter.snapshot().since(&before_c);
    assert_eq!(
        delta_f, delta_c,
        "case {case} seed {seed}: backends must count ops identically"
    );
    assert_counts_match(case, seed, delta_c, net_c.plan.totals());
    assert_eq!(
        weight_snapshot(&net_f, &be.fhe_client),
        weight_snapshot(&net_c, &be.clear_codec),
        "case {case} seed {seed}: post-update weights diverged"
    );
}

fn assert_counts_match(case: &str, seed: u64, live: OpSnapshot, predicted: StepOps) {
    let pairs = [
        ("mult_cc", live.mult_cc, predicted.mult_cc),
        ("mult_cp", live.mult_cp, predicted.mult_cp),
        ("add_cc", live.add_cc, predicted.add_cc),
        ("tlu", live.tlu, predicted.tlu),
        ("act_gates", live.act_gates, predicted.act_gates),
        ("extract_pbs", live.extract_pbs, predicted.extract_pbs),
        ("switch_b2t", live.switch_b2t, predicted.switch_b2t),
        ("switch_t2b", live.switch_t2b, predicted.switch_t2b),
        ("refresh", live.refresh, predicted.refresh),
        ("extract_lanes", live.extract_lanes, predicted.extract_lanes),
        ("repack_lanes", live.repack_lanes, predicted.repack_lanes),
    ];
    for (name, l, p) in pairs {
        assert_eq!(l, p, "case {case} seed {seed}: clear-path {name} != plan");
    }
}

#[test]
fn paper_shaped_mlp_train_step_is_bit_identical() {
    let seed = base_seed();
    let mut be = Backends::new(seed);
    // the paper MLP's unit mix (FC-ReLU-FC-ReLU-FC-softmax) at test widths,
    // grid-aligned shifts
    let build = || {
        NetworkBuilder::input_vec(4)
            .fc(4)
            .relu(0, 0)
            .fc(3)
            .relu(0, 0)
            .fc(2)
            .softmax(3, 0)
            .grad_shift(0)
    };
    let x_cols = vec![vec![40i64, -20], vec![10, 30], vec![-5, 25], vec![7, -13]];
    assert_train_step_equivalent(
        "paper-mlp",
        seed,
        &mut be,
        build,
        &x_cols,
        vec![4],
        2,
        &[0, 1],
    );
}

#[test]
fn random_shapes_and_shifts_are_bit_identical() {
    let seed = base_seed() ^ 0x5afe;
    let mut be = Backends::new(seed);
    let mut vr = GlyphRng::new(seed);
    for case in 0..2 {
        let case_seed = seed ^ ((case as u64 + 1) << 40);
        let in_dim = 2 + vr.uniform_mod(3) as usize;
        let hidden = 2 + vr.uniform_mod(3) as usize;
        let classes = 2 + vr.uniform_mod(2) as usize;
        let bits = 2 + vr.uniform_mod(3) as usize; // softmax width 2..=4
        // a nonzero first-layer activation shift, exercised on the grid:
        // inputs are multiples of 2^s, so the quantization stays aligned
        let s = vr.uniform_mod(4) as u32;
        let x_cols: Vec<Vec<i64>> = (0..in_dim)
            .map(|_| {
                (0..BATCH)
                    .map(|_| ((vr.uniform_mod(31) as i64) - 15) << s)
                    .collect()
            })
            .collect();
        let sample_classes: Vec<usize> =
            (0..BATCH).map(|_| vr.uniform_mod(classes as u64) as usize).collect();
        let build = || {
            NetworkBuilder::input_vec(in_dim)
                .fc(hidden)
                .relu(s, 0)
                .fc(classes)
                .softmax(bits, 0)
                .grad_shift(0)
        };
        assert_train_step_equivalent(
            &format!("random-{case} (in {in_dim}, hidden {hidden}, classes {classes}, bits {bits}, shift {s})"),
            case_seed,
            &mut be,
            build,
            &x_cols,
            vec![in_dim],
            classes,
            &sample_classes,
        );
    }
}

#[test]
fn logit_shift_and_gradient_truncation_round_identically() {
    // single trainable FC + softmax with a nonzero logit shift and a
    // nonzero grad_shift: the `∇ >> grad_shift` rounding through the
    // switch round trip must agree bit for bit
    let seed = base_seed() ^ 0x9afd;
    let mut be = Backends::new(seed);
    let s = 3u32;
    let build = || NetworkBuilder::input_vec(3).fc(2).softmax(3, s).grad_shift(2);
    let x_cols = vec![
        vec![5i64 << s, -(3i64 << s)],
        vec![-(7i64 << s), 1 << s],
        vec![2 << s, 4 << s],
    ];
    assert_train_step_equivalent("logit-grad-shift", seed, &mut be, build, &x_cols, vec![3], 2, &[1, 0]);
}

#[test]
fn frozen_conv_transfer_topology_is_bit_identical() {
    let seed = base_seed() ^ 0xc22;
    let mut be = Backends::new(seed);
    let mut kr = GlyphRng::new(seed ^ 0x77);
    let rand_kernels = |oc: usize, ic: usize, k: usize, rng: &mut GlyphRng| -> Vec<Vec<Vec<Vec<i64>>>> {
        (0..oc)
            .map(|_| {
                (0..ic)
                    .map(|_| {
                        (0..k)
                            .map(|_| (0..k).map(|_| (rng.uniform_mod(7) as i64) - 3).collect())
                            .collect()
                    })
                    .collect()
            })
            .collect()
    };
    let c1 = rand_kernels(2, 1, 3, &mut kr);
    let c2 = rand_kernels(3, 2, 3, &mut kr);
    // conv→BN→ReLU→pool ×2 → flatten → trainable FC head, all shifts on
    // the grid (the paper's Table-4 transfer pipeline at tiny scale)
    let build = || {
        NetworkBuilder::input_image(1, 14, 14)
            .conv_frozen(c1.clone())
            .batchnorm_identity(2)
            .relu(0, 0)
            .avg_pool()
            .conv_frozen(c2.clone())
            .batchnorm_identity(3)
            .relu(0, 0)
            .avg_pool()
            .flatten()
            .fc(4)
            .relu(0, 0)
            .fc(2)
            .softmax(3, 0)
            .grad_shift(0)
    };
    let mut xr = GlyphRng::new(seed ^ 0x88);
    let x_cols: Vec<Vec<i64>> = (0..14 * 14)
        .map(|_| (0..BATCH).map(|_| (xr.uniform_mod(17) as i64) - 8).collect())
        .collect();
    assert_train_step_equivalent(
        "transfer-cnn",
        seed,
        &mut be,
        build,
        &x_cols,
        vec![1, 14, 14],
        2,
        &[1, 0],
    );
}

/// Encrypt a minibatch in the cross-sample SIMD layout: feature columns
/// interleaved into `PackedLayout` blocks, one ciphertext per block.
fn pack_input(
    codec: &mut dyn Codec,
    layout: &PackedLayout,
    cols: &[Vec<i64>],
    shape: Vec<usize>,
    n: usize,
) -> EncTensor {
    let cts =
        layout.pack_columns(cols, n).iter().map(|coeffs| codec.encrypt_coeffs(coeffs, 0)).collect();
    EncTensor::packed(cts, shape, PackOrder::Forward, 0, layout.clone())
}

/// Flattened row-major weight readback of every trainable packed FC layer
/// (comparable to [`weight_snapshot`] of the per-sample reference net).
fn packed_weight_snapshot(net: &Network, codec: &dyn Codec) -> Vec<i64> {
    net.packed_fc_units()
        .iter()
        .flat_map(|(_, l)| l.decrypt_weights(codec).into_iter().flatten())
        .collect()
}

/// Per-class batch readout of an output-unit tensor, honouring its
/// `lane_base` (packed-MAC softmax inputs sit at `payload_base() + b`;
/// per-sample tensors at base 0 — the helper covers both).
fn decode_output(codec: &dyn Codec, t: &EncTensor) -> Vec<Vec<i64>> {
    let pos: Vec<usize> = (0..BATCH).map(|c| c + t.lane_base).collect();
    t.cts.iter().map(|ct| codec.decrypt_positions(ct, &pos, 0)).collect()
}

/// The packed differential contract: build the same network (same
/// weight-draw seed) on three engines — the per-sample FHE reference, the
/// packed FHE path, and the packed clear mirror — run one forward +
/// train_step on the same minibatch, and assert the packed path decrypts
/// byte-identical logits, batch-summed gradient updates and post-step
/// weights to the per-sample reference, with the packed live op counters
/// equal to the packed plan's totals exactly.
fn assert_packed_matches_per_sample(
    case: &str,
    seed: u64,
    build: impl Fn() -> NetworkBuilder,
    x_cols: &[Vec<i64>],
    in_shape: Vec<usize>,
    classes: usize,
    sample_classes: &[usize],
) {
    let (ref_e, mut ref_c) = GlyphEngine::setup(EngineProfile::Test, BATCH, seed);
    let (pk_e, mut pk_c) = GlyphEngine::setup_packed(EngineProfile::Test, BATCH, seed ^ 0x9e37);
    let (pc_e, mut pc_c) = GlyphEngine::setup_clear_packed(EngineProfile::Test, BATCH);
    let layout = pk_e.packed_layout().expect("packed engine carries a layout").clone();

    let mut net_ref = build()
        .build(&mut ref_c, &mut GlyphRng::new(seed ^ 0x11), &ref_e)
        .unwrap_or_else(|e| panic!("case {case} seed {seed}: reference build failed: {e}"));
    let mut net_pk = build()
        .build(&mut pk_c, &mut GlyphRng::new(seed ^ 0x11), &pk_e)
        .unwrap_or_else(|e| panic!("case {case} seed {seed}: packed fhe build failed: {e}"));
    let mut net_pc = build()
        .build(&mut pc_c, &mut GlyphRng::new(seed ^ 0x11), &pc_e)
        .unwrap_or_else(|e| panic!("case {case} seed {seed}: packed clear build failed: {e}"));

    let w0 = weight_snapshot(&net_ref, &ref_c);
    assert_eq!(
        packed_weight_snapshot(&net_pk, &pk_c),
        w0,
        "case {case} seed {seed}: packed weight blocks must decode to the per-sample matrix"
    );
    assert_eq!(
        packed_weight_snapshot(&net_pc, &pc_c),
        w0,
        "case {case} seed {seed}: packed clear weights must encode identically"
    );

    let x_ref = encode_cols(&mut ref_c, x_cols, in_shape.clone(), PackOrder::Forward);
    let x_pk = pack_input(&mut pk_c, &layout, x_cols, in_shape.clone(), pk_e.params().n);
    let x_pc = pack_input(&mut pc_c, &layout, x_cols, in_shape.clone(), pc_e.params().n);
    let lab_ref = one_hot_labels(&mut ref_c, classes, sample_classes);
    let lab_pk = one_hot_labels(&mut pk_c, classes, sample_classes);
    let lab_pc = one_hot_labels(&mut pc_c, classes, sample_classes);

    // logits: one packed forward must decrypt exactly what BATCH per-sample
    // lanes of the reference forward produce
    let logits_ref = decode_output(&ref_c, net_ref.forward(&x_ref, &ref_e).output());
    let logits_pk = decode_output(&pk_c, net_pk.forward(&x_pk, &pk_e).output());
    let logits_pc = decode_output(&pc_c, net_pc.forward(&x_pc, &pc_e).output());
    assert_eq!(logits_pk, logits_ref, "case {case} seed {seed}: packed logits diverged");
    assert_eq!(logits_pc, logits_ref, "case {case} seed {seed}: packed clear logits diverged");

    // one SGD step: packed FHE and packed clear count identically, and the
    // live counters equal the packed plan's totals exactly
    let before_pk = pk_e.counter.snapshot();
    let before_pc = pc_e.counter.snapshot();
    net_ref.train_step(&x_ref, &lab_ref, &ref_e);
    net_pk.train_step(&x_pk, &lab_pk, &pk_e);
    net_pc.train_step(&x_pc, &lab_pc, &pc_e);
    let delta_pk = pk_e.counter.snapshot().since(&before_pk);
    let delta_pc = pc_e.counter.snapshot().since(&before_pc);
    assert_eq!(
        delta_pk, delta_pc,
        "case {case} seed {seed}: packed backends must count ops identically"
    );
    assert_counts_match(case, seed, delta_pc, net_pc.plan.totals());

    // post-update weights — and therefore the batch-summed gradients that
    // produced them — must be byte-identical to the per-sample path
    let w_ref = weight_snapshot(&net_ref, &ref_c);
    let w_pk = packed_weight_snapshot(&net_pk, &pk_c);
    let w_pc = packed_weight_snapshot(&net_pc, &pc_c);
    let grads = |after: &[i64]| -> Vec<i64> {
        w0.iter().zip(after).map(|(b, a)| b - a).collect::<Vec<_>>()
    };
    assert_eq!(
        grads(&w_pk),
        grads(&w_ref),
        "case {case} seed {seed}: packed gradient updates diverged from the per-sample path"
    );
    assert_eq!(w_pk, w_ref, "case {case} seed {seed}: packed post-update weights diverged");
    assert_eq!(w_pc, w_ref, "case {case} seed {seed}: packed clear post-update weights diverged");
}

#[test]
fn packed_mlp_train_step_matches_per_sample_path() {
    let seed = base_seed() ^ 0x9ac_ed;
    let build = || {
        NetworkBuilder::input_vec(4)
            .fc(4)
            .relu(0, 0)
            .fc(3)
            .relu(0, 0)
            .fc(2)
            .softmax(3, 0)
            .grad_shift(0)
    };
    let x_cols = vec![vec![40i64, -20], vec![10, 30], vec![-5, 25], vec![7, -13]];
    assert_packed_matches_per_sample("packed-mlp", seed, build, &x_cols, vec![4], 2, &[0, 1]);
}

#[test]
fn packed_frozen_conv_transfer_head_matches_per_sample_path() {
    let seed = base_seed() ^ 0xcc8;
    let mut kr = GlyphRng::new(seed ^ 0x77);
    let c1: Vec<Vec<Vec<Vec<i64>>>> = (0..2)
        .map(|_| {
            (0..1)
                .map(|_| {
                    (0..3)
                        .map(|_| (0..3).map(|_| (kr.uniform_mod(7) as i64) - 3).collect())
                        .collect()
                })
                .collect()
        })
        .collect();
    // frozen conv backbone consumes the packed image; the trainable head
    // crosses both packing seams (flatten re-pack, then packed FC→ReLU→FC)
    let build = || {
        NetworkBuilder::input_image(1, 10, 10)
            .conv_frozen(c1.clone())
            .batchnorm_identity(2)
            .relu(0, 0)
            .avg_pool()
            .flatten()
            .fc(4)
            .relu(0, 0)
            .fc(2)
            .softmax(3, 0)
            .grad_shift(0)
    };
    let mut xr = GlyphRng::new(seed ^ 0x88);
    let x_cols: Vec<Vec<i64>> = (0..10 * 10)
        .map(|_| (0..BATCH).map(|_| (xr.uniform_mod(17) as i64) - 8).collect())
        .collect();
    assert_packed_matches_per_sample(
        "packed-transfer-cnn",
        seed,
        build,
        &x_cols,
        vec![1, 10, 10],
        2,
        &[1, 0],
    );
}

#[test]
fn layer_level_errors_and_gradients_match() {
    // the Layer-API pieces in isolation: ReLU forward/iReLU error masks and
    // the FC convolution-trick gradients decode identically across backends
    use glyph::nn::activation::{irelu_layer, relu_layer};
    use glyph::nn::linear::FcLayer;
    let seed = base_seed() ^ 0x1a9e;
    let mut be = Backends::new(seed);
    let mut vr = GlyphRng::new(seed);
    let u_vals: Vec<Vec<i64>> = (0..3)
        .map(|_| (0..BATCH).map(|_| (vr.uniform_mod(255) as i64) - 127).collect())
        .collect();
    let d_vals: Vec<Vec<i64>> = (0..3)
        .map(|_| {
            let mut v: Vec<i64> = (0..BATCH).map(|_| (vr.uniform_mod(255) as i64) - 127).collect();
            v.reverse();
            v
        })
        .collect();
    let u_f = encode_cols(&mut be.fhe_client, &u_vals, vec![3], PackOrder::Forward);
    let u_c = encode_cols(&mut be.clear_codec, &u_vals, vec![3], PackOrder::Forward);
    let d_f = encode_cols(&mut be.fhe_client, &d_vals, vec![3], PackOrder::Reversed);
    let d_c = encode_cols(&mut be.clear_codec, &d_vals, vec![3], PackOrder::Reversed);

    let (a_f, st_f) = relu_layer(&be.fhe, &u_f, 0, PackOrder::Forward);
    let (a_c, st_c) = relu_layer(&be.clear, &u_c, 0, PackOrder::Forward);
    assert_eq!(
        decode_tensor(&be.fhe_client, &a_f),
        decode_tensor(&be.clear_codec, &a_c),
        "seed {seed}: ReLU activations diverged"
    );
    let e_f = irelu_layer(&be.fhe, &d_f, &st_f, 0);
    let e_c = irelu_layer(&be.clear, &d_c, &st_c, 0);
    assert_eq!(
        decode_tensor(&be.fhe_client, &e_f),
        decode_tensor(&be.clear_codec, &e_c),
        "seed {seed}: iReLU errors diverged"
    );

    let w_init = vec![vec![2i64, -3, 4], vec![1, 0, -5]];
    let fc_f = FcLayer::new_encrypted(&w_init, &mut be.fhe_client, 0);
    let fc_c = FcLayer::new_encrypted(&w_init, &mut be.clear_codec, 0);
    let g_f = fc_f.gradients(&u_f, &d_f, &be.fhe);
    let g_c = fc_c.gradients(&u_c, &d_c, &be.clear);
    for j in 0..2 {
        for i in 0..3 {
            // the convolution-trick batch sum lives at coefficient batch−1
            let got_f = be.fhe_client.decrypt_batch(&g_f[j][i], BATCH, 0)[BATCH - 1];
            let got_c = be.clear_codec.decrypt_batch(&g_c[j][i], BATCH, 0)[BATCH - 1];
            assert_eq!(got_f, got_c, "seed {seed}: gradient ({j},{i}) diverged");
        }
    }
}

#[test]
fn clear_epoch_on_mnist_subset_matches_plan_totals() {
    // the acceptance scenario: a full clear-backend epoch over an MNIST
    // subset completes in CI with live op counters exactly matching the
    // compiled plan's totals × steps — every homomorphic op the plan
    // promises is the op the clear engine counts.
    use glyph::train::Trainer;
    let batch = 8;
    let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Default, batch);
    let mut rng = GlyphRng::new(7);
    let net = NetworkBuilder::input_vec(196)
        .fc(32)
        .relu(8, 8)
        .fc(10)
        .softmax(8, 8)
        .grad_shift(12)
        .build(&mut codec, &mut rng, &engine)
        .unwrap();
    let totals = net.plan.totals();
    let mut trainer = Trainer::new(net, 10);
    let ds = glyph::data::mnist(true, 128, 5);
    let stats = trainer.train_epoch(&ds, &engine, &mut codec).expect("epoch runs");
    assert_eq!(stats.steps, 16);
    assert_eq!(stats.samples, 128);
    let n = stats.steps as u64;
    assert_counts_match("clear-epoch", 7, stats.ops, scale_ops(totals, n));
}

fn scale_ops(t: StepOps, n: u64) -> StepOps {
    StepOps {
        mult_cc: t.mult_cc * n,
        mult_cp: t.mult_cp * n,
        add_cc: t.add_cc * n,
        tlu: t.tlu * n,
        relu_values: t.relu_values * n,
        softmax_values: t.softmax_values * n,
        act_gates: t.act_gates * n,
        extract_pbs: t.extract_pbs * n,
        switch_b2t: t.switch_b2t * n,
        switch_t2b: t.switch_t2b * n,
        refresh: t.refresh * n,
        extract_lanes: t.extract_lanes * n,
        repack_lanes: t.repack_lanes * n,
    }
}
