//! Checkpoint/resume conformance (PR 7's acceptance bar): a training run
//! interrupted at a checkpoint boundary and resumed by a *fresh* process
//! must finish with weights, logits and op counters byte-identical to an
//! uninterrupted run. Exercised at epoch scale on the clear backend and
//! differentially spot-checked on FHE for one resumed train step.

use glyph::serve::job::checkpoint_path;
use glyph::serve::{run_job, JobBackend, JobHandle, JobSpec, RunOptions, RunOutcome};
use glyph::serve::{JobResult, JobState};
use std::path::PathBuf;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("glyph-resume-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_to_completion(handle: &JobHandle, dir: Option<&std::path::Path>) -> JobResult {
    match run_job(handle, dir, &RunOptions::default()).unwrap() {
        RunOutcome::Completed(result) => result,
        other => panic!("expected completion, got {other:?}"),
    }
}

fn halt_after(handle: &JobHandle, dir: &std::path::Path, checkpoints: u64) {
    let opts = RunOptions { halt_after_checkpoints: Some(checkpoints) };
    match run_job(handle, Some(dir), &opts).unwrap() {
        RunOutcome::Halted => {}
        other => panic!("expected a halt, got {other:?}"),
    }
}

/// Everything two runs must agree on, byte for byte. (`seconds` is
/// wall-clock and `resumes`/`id` are bookkeeping — excluded by design.)
fn assert_identical(resumed: &JobResult, reference: &JobResult) {
    assert_eq!(resumed.steps, reference.steps, "step counts differ");
    assert_eq!(
        resumed.weights_digest, reference.weights_digest,
        "final weights are not byte-identical"
    );
    assert_eq!(
        resumed.logits_digest, reference.logits_digest,
        "evaluation logits are not byte-identical"
    );
    assert_eq!(resumed.ops, reference.ops, "op counters drifted across the resume");
    assert_eq!(resumed.accuracy, reference.accuracy, "accuracy differs");
}

#[test]
fn clear_run_resumes_byte_identically_across_two_interruptions() {
    let mut spec = JobSpec::small_clear("resume", 0x5eed);
    spec.samples = 48;
    spec.epochs = 2;
    spec.checkpoint_every = 5; // 24 total steps → checkpoints at 5/10/15/20

    // Uninterrupted reference, no persistence at all.
    let reference = run_to_completion(&JobHandle::new(1, spec.clone()), None);
    assert_eq!(reference.steps, 24);
    assert_eq!(reference.resumes, 0);

    // Interrupted run: each leg uses a brand-new JobHandle, modelling a
    // killed and restarted server process that recovered the job from disk.
    let dir = temp_dir("clear");
    halt_after(&JobHandle::new(2, spec.clone()), &dir, 1); // dies at step 5
    assert!(checkpoint_path(&dir).exists(), "halt must leave a checkpoint behind");
    halt_after(&JobHandle::new(2, spec.clone()), &dir, 1); // resumes, dies at 10
    let handle = JobHandle::new(2, spec.clone());
    let resumed = run_to_completion(&handle, Some(&dir)); // resumes at 10, finishes

    assert_identical(&resumed, &reference);
    assert_eq!(resumed.resumes, 1, "the final process resumed exactly once");
    assert_eq!(handle.status().state, JobState::Completed);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fhe_run_resumes_byte_identically_after_one_step() {
    // Reduced-scale FHE: 2 steps total, checkpoint after step 1, halt,
    // resume in a fresh handle. Keygen, encryption noise and the authority
    // RNG all replay from the spec seed + checkpointed cursors.
    let spec = JobSpec {
        tenant: "fhe".into(),
        backend: JobBackend::Fhe,
        profile: glyph::nn::engine::EngineProfile::Test,
        dims: vec![16, 4, 3],
        batch: 2,
        epochs: 1,
        steps_per_epoch: 2,
        samples: 4,
        eval_samples: 2,
        dataset: "digits".into(),
        seed: 0xfe11,
        checkpoint_every: 1,
        softmax_bits: 3,
    };

    let reference = run_to_completion(&JobHandle::new(1, spec.clone()), None);
    assert_eq!(reference.steps, 2);

    let dir = temp_dir("fhe");
    halt_after(&JobHandle::new(2, spec.clone()), &dir, 1);
    let resumed = run_to_completion(&JobHandle::new(2, spec.clone()), Some(&dir));

    assert_identical(&resumed, &reference);
    assert_eq!(resumed.resumes, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_with_foreign_seed_is_refused() {
    let mut spec = JobSpec::small_clear("seed-a", 100);
    spec.checkpoint_every = 2;
    let dir = temp_dir("foreign");
    halt_after(&JobHandle::new(1, spec.clone()), &dir, 1);

    let mut other = spec;
    other.seed = 101; // same shape, different job identity
    let err = run_job(&JobHandle::new(1, other), Some(&dir), &RunOptions::default()).unwrap_err();
    assert!(err.to_string().contains("seed"), "unexpected error: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cancelled_job_reports_cancelled() {
    let handle = JobHandle::new(9, JobSpec::small_clear("cancel", 5));
    handle.cancel.store(true, std::sync::atomic::Ordering::Relaxed);
    match run_job(&handle, None, &RunOptions::default()).unwrap() {
        RunOutcome::Cancelled => {}
        other => panic!("expected cancellation, got {other:?}"),
    }
    assert_eq!(handle.status().state, JobState::Cancelled);
}
