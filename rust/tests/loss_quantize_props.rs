//! Seeded randomized property tests for `nn/loss.rs` and `nn/quantize.rs`
//! (the `ntt_properties.rs` pattern: many cases per property, failing
//! seeds printed, `GLYPH_PROP_SEED` replays a base seed).
//!
//! Loss: the quadratic derivative δ = d − t is linear, sign-correct and
//! batch-exact on both execution backends. Quantize: the SWALP helpers
//! round-trip within one ulp, saturate at ±127, and `requantize_shift`
//! agrees with the switch's own `quantize_plain` reference on random
//! values and shifts.

use glyph::math::GlyphRng;
use glyph::nn::backend::Codec;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::loss::quadratic_loss_delta;
use glyph::nn::quantize::{dequantize, quantize_i8, requantize_shift, shift_for};
use glyph::nn::tensor::{EncTensor, PackOrder};
use glyph::switch::extract::quantize_plain;

fn base_seed() -> u64 {
    std::env::var("GLYPH_PROP_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0x10_55_0b_5e_55_10_75)
}

#[test]
fn loss_delta_is_d_minus_t_signed_and_linear() {
    let seed = base_seed();
    let batch = 4;
    let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, batch);
    let mut rng = GlyphRng::new(seed);
    for case in 0..100 {
        let case_seed = seed ^ ((case as u64) << 32);
        let classes = 2 + rng.uniform_mod(4) as usize;
        let d_vals: Vec<Vec<i64>> = (0..classes)
            .map(|_| (0..batch).map(|_| rng.uniform_mod(128) as i64).collect())
            .collect();
        let t_vals: Vec<Vec<i64>> = (0..classes)
            .map(|_| (0..batch).map(|_| if rng.uniform_mod(2) == 1 { 127 } else { 0 }).collect())
            .collect();
        let enc = |codec: &mut dyn Codec, cols: &[Vec<i64>]| {
            let cts = cols.iter().map(|v| codec.encrypt_batch(v, 0)).collect();
            EncTensor::new(cts, vec![cols.len()], PackOrder::Reversed, 0)
        };
        let d = enc(&mut codec, &d_vals);
        let t = enc(&mut codec, &t_vals);
        let delta = quadratic_loss_delta(&d, &t, &engine);
        for k in 0..classes {
            let got = codec.decrypt_batch(&delta.cts[k], batch, 0);
            for b in 0..batch {
                let want = d_vals[k][b] - t_vals[k][b];
                assert_eq!(got[b], want, "seed {case_seed}: class {k} lane {b}");
                // sign property: the gradient pushes the distribution
                // toward the one-hot target
                if t_vals[k][b] == 127 {
                    assert!(got[b] <= 0, "seed {case_seed}: hot-class delta must be ≤ 0");
                } else {
                    assert!(got[b] >= 0, "seed {case_seed}: cold-class delta must be ≥ 0");
                }
            }
        }
        // scale property: doubling d − t doubles δ (linearity over the ring)
        let d2_vals: Vec<Vec<i64>> = d_vals
            .iter()
            .zip(&t_vals)
            .map(|(dr, tr)| dr.iter().zip(tr).map(|(&a, &b)| 2 * a - b).collect())
            .collect();
        let d2 = enc(&mut codec, &d2_vals);
        let delta2 = quadratic_loss_delta(&d2, &t, &engine);
        for k in 0..classes {
            let got = codec.decrypt_batch(&delta.cts[k], batch, 0);
            let got2 = codec.decrypt_batch(&delta2.cts[k], batch, 0);
            for b in 0..batch {
                assert_eq!(got2[b], 2 * got[b], "seed {case_seed}: δ must scale linearly");
            }
        }
    }
}

#[test]
fn loss_delta_identical_on_both_backends() {
    let seed = base_seed() ^ 0xd1ff;
    let batch = 3;
    let (fhe, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, seed);
    let (clear, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, batch);
    let mut rng = GlyphRng::new(seed);
    let d_vals: Vec<Vec<i64>> =
        (0..3).map(|_| (0..batch).map(|_| rng.uniform_mod(128) as i64).collect()).collect();
    let t_vals: Vec<Vec<i64>> =
        (0..3).map(|_| (0..batch).map(|_| (rng.uniform_mod(2) as i64) * 127).collect()).collect();
    let enc = |codec: &mut dyn Codec, cols: &[Vec<i64>]| {
        let cts = cols.iter().map(|v| codec.encrypt_batch(v, 0)).collect();
        EncTensor::new(cts, vec![cols.len()], PackOrder::Reversed, 0)
    };
    let delta_f = quadratic_loss_delta(&enc(&mut client, &d_vals), &enc(&mut client, &t_vals), &fhe);
    let delta_c = quadratic_loss_delta(&enc(&mut codec, &d_vals), &enc(&mut codec, &t_vals), &clear);
    for k in 0..3 {
        assert_eq!(
            client.decrypt_batch(&delta_f.cts[k], batch, 0),
            codec.decrypt_batch(&delta_c.cts[k], batch, 0),
            "seed {seed}: class {k}"
        );
    }
}

#[test]
fn quantize_roundtrip_and_saturation_properties() {
    let seed = base_seed() ^ 0x9a;
    let mut rng = GlyphRng::new(seed);
    for case in 0..100 {
        let case_seed = seed ^ ((case as u64) << 32);
        let n = 1 + rng.uniform_mod(64) as usize;
        let scale = 2f64.powi(rng.uniform_mod(24) as i32 - 12);
        let xs: Vec<f64> = (0..n)
            .map(|_| (rng.uniform_mod(20001) as f64 / 10000.0 - 1.0) * scale)
            .collect();
        let (vs, e) = quantize_i8(&xs);
        assert!(vs.iter().all(|&v| v.abs() <= 127), "seed {case_seed}: 8-bit range");
        let back = dequantize(&vs, e);
        let ulp = 2f64.powi(e);
        for (x, y) in xs.iter().zip(&back) {
            assert!(
                (x - y).abs() <= ulp,
                "seed {case_seed}: round-trip error {} > ulp {ulp}",
                (x - y).abs()
            );
        }
        // the exponent is minimal: max |x| must need more than half the range
        let max = xs.iter().fold(0f64, |m, &x| m.max(x.abs()));
        if max > 0.0 {
            let used = vs.iter().map(|v| v.abs()).max().unwrap();
            assert!(used > 63 || max <= 63.5 * ulp, "seed {case_seed}: wasted range ({used})");
        }
    }
    // saturation: values past the representable range clamp to ±127
    let (vs, _e) = quantize_i8(&[1e30, -1e30, 0.0]);
    assert_eq!(vs[0], 127);
    assert_eq!(vs[1], -127);
    assert_eq!(vs[2], 0);
}

#[test]
fn requantize_shift_matches_switch_quantization_reference() {
    let seed = base_seed() ^ 0x5e1f;
    let mut rng = GlyphRng::new(seed);
    let t = 1u64 << 16; // test-profile plaintext modulus, frac = 8
    for case in 0..100 {
        let case_seed = seed ^ ((case as u64) << 32);
        let shift = 1 + rng.uniform_mod(8) as u32;
        let xs: Vec<i64> =
            (0..8).map(|_| rng.uniform_mod(1 << (shift + 8)) as i64 - (1 << (shift + 7))).collect();
        let got = requantize_shift(&xs, shift);
        for (&x, &g) in xs.iter().zip(&got) {
            // the switch's reference: pre-shift to the top of t, then take
            // the top 8 bits round-to-nearest
            let frac = t.trailing_zeros() - 8;
            let want = quantize_plain((x << (frac - shift)) % (t as i64), t);
            assert_eq!(g, want, "seed {case_seed}: x={x} shift={shift}");
            assert!(g.abs() <= 128, "seed {case_seed}: 8-bit output");
        }
    }
    // shift_for brings any magnitude into range
    for case in 0..50 {
        let m = rng.uniform_mod(1 << 40) as i64;
        let s = shift_for(m);
        assert!(m >> s <= 127, "case {case}: shift_for({m}) = {s}");
        assert!(s == 0 || (m >> (s - 1)) > 127, "case {case}: minimal shift");
    }
}
