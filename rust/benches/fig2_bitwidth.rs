//! Figure 2: FHESGD accuracy & activation-latency share vs the lookup-table
//! bit width. The TLU's indicator tree doubles per bit, so latency grows
//! 2^b while sigmoid fidelity saturates — the paper's motivation plot.

use glyph::bench_util::{report, time_once};
use glyph::bgv::lut::LookupTable;
use glyph::coordinator::cost::{mlp_table, total_row, OpLatencies, Scheme};
use glyph::train::fhesgd::TluDomain;

fn main() {
    let domain = TluDomain::new(true, 1);
    let mut md = String::from(
        "### Figure 2 — FHESGD vs lookup bit width\n\n| bits | TLU latency (s) | sigmoid RMSE | act share of mini-batch |\n|---|---|---|---|\n",
    );
    let mut last_latency = 0.0;
    for bits in 2..=8usize {
        let table = LookupTable::sigmoid(bits, (bits / 2) as u32, (bits - 1) as u32);
        // quantization fidelity vs float sigmoid over the input range
        let mut err = 0f64;
        let n = 1usize << bits;
        for v in 0..n {
            let half = 1i64 << (bits - 1);
            let sv = if (v as i64) >= half { v as i64 - (1i64 << bits) } else { v as i64 };
            let x = sv as f64 / 2f64.powi((bits / 2) as i32);
            let s = 1.0 / (1.0 + (-x).exp());
            let q = table.entries[v] as f64 / 2f64.powi((bits - 1) as i32);
            err += (s - q) * (s - q);
        }
        let rmse = (err / n as f64).sqrt();
        let enc = domain.encrypt_bits(1, bits);
        let latency = time_once(|| {
            let _ = table.evaluate(&enc, &domain.rlk, &domain.ctx);
        });
        // act share: plug the measured TLU cost at this width into the
        // table generator alongside representative measured MAC costs.
        let mut lat = OpLatencies::paper();
        lat.tlu = latency;
        lat.mult_cc = 0.000_5; // representative measured MAC (test profile)
        lat.add_cc = 0.000_05;
        let rows = mlp_table(&[784, 128, 32, 10], Scheme::Fhesgd, &lat);
        let t = total_row(&rows).time_s;
        let act: f64 = rows.iter().filter(|r| r.layer.starts_with("Act")).map(|r| r.time_s).sum();
        md.push_str(&format!("| {bits} | {latency:.4} | {rmse:.4} | {:.1}% |\n", 100.0 * act / t));
        last_latency = latency;
    }
    md.push_str("\nshape: latency ≈ doubles per bit (2·(2^b−1) MultCC tree), accuracy saturates — matches Figure 2.\n");
    report("fig2", &md);
    assert!(last_latency > 0.0);
}
