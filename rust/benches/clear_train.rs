//! Clear-backend epoch throughput: samples/sec of full `Trainer` epochs per
//! dataset (the four paper datasets' synthetic stand-ins), plus the
//! backend-parity counters — one identical `train_step` executed on both
//! backends must bump every homomorphic-op counter by exactly the same
//! amount (the pricing/accounting contract `tests/backend_equivalence.rs`
//! locks; recorded here so the artifact trail shows it per PR). Emits
//! `bench_out/BENCH_clear_train.json`.

use glyph::bench_util::{report_json_with_counters, BenchRecord};
use glyph::data::Dataset;
use glyph::math::GlyphRng;
use glyph::nn::backend::Codec;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::network::NetworkBuilder;
use glyph::nn::tensor::{EncTensor, PackOrder};
use glyph::train::{GlyphMlp, MlpConfig, Trainer};

fn epoch_rate(ds: &Dataset, classes: usize) -> (f64, usize) {
    let batch = 8;
    let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Default, batch);
    let mut rng = GlyphRng::new(7);
    let config = MlpConfig {
        dims: vec![196, 64, classes],
        act_shifts: vec![8, 8],
        err_shifts: vec![8, 8],
        grad_shift: 12,
        softmax_bits: 8,
    };
    let mlp = GlyphMlp::new_random(config, &mut codec, &mut rng, &engine).expect("builds");
    let mut trainer = Trainer::new(mlp.net, classes);
    let stats = trainer.train_epoch(ds, &engine, &mut codec).expect("epoch runs");
    (stats.seconds / stats.samples.max(1) as f64, stats.samples)
}

/// One tiny train_step on each backend; returns (fhe HOP, clear HOP) —
/// equal by the engine's shared accounting.
fn parity_step(engine: &GlyphEngine, codec: &mut dyn Codec) -> u64 {
    let mut rng = GlyphRng::new(3);
    let mut net = NetworkBuilder::input_vec(3)
        .fc(4)
        .relu(0, 0)
        .fc(2)
        .softmax(3, 0)
        .grad_shift(0)
        .build(codec, &mut rng, engine)
        .expect("builds");
    let x_cts = (0..3).map(|i| codec.encrypt_batch(&[7 * i as i64 - 4, 9 - i as i64], 0)).collect();
    let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
    let lab_cts = (0..2)
        .map(|k| codec.encrypt_batch(&if k == 0 { vec![0, 127] } else { vec![127, 0] }, 0))
        .collect();
    let labels = EncTensor::new(lab_cts, vec![2], PackOrder::Reversed, 0);
    let before = engine.counter.snapshot();
    net.train_step(&x, &labels, engine);
    engine.counter.snapshot().since(&before).hop()
}

fn parity_hops() -> (u64, u64) {
    let batch = 2;
    let (fhe, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 20260729);
    let (clear, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, batch);
    (parity_step(&fhe, &mut client), parity_step(&clear, &mut codec))
}

fn main() {
    let samples = 256usize;
    eprintln!("clear_train bench: {samples}-sample epochs, 196-64-c MLP, batch 8");
    let datasets: Vec<(&str, Dataset, usize)> = vec![
        ("mnist_synth", glyph::data::mnist(true, samples, 5), 10),
        ("cancer_synth", glyph::data::synthetic_cancer(samples, 5), 7),
        ("svhn_synth", glyph::data::synthetic_svhn(samples, 5), 10),
        ("cifar_synth", glyph::data::synthetic_cifar(samples, 5), 10),
    ];
    let mut records = Vec::new();
    let mut total_samples = 0usize;
    for (name, ds, classes) in &datasets {
        let (secs_per_sample, n) = epoch_rate(ds, *classes);
        total_samples += n;
        println!(
            "{name}: {n} samples, {:.1} samples/s ({:.3} ms/sample)",
            1.0 / secs_per_sample,
            secs_per_sample * 1e3
        );
        records.push(BenchRecord::new(&format!("epoch_sample_{name}"), secs_per_sample, 1));
    }
    let (fhe_hop, clear_hop) = parity_hops();
    assert_eq!(fhe_hop, clear_hop, "backends must count HOPs identically");
    println!("parity: fhe HOP {fhe_hop} == clear HOP {clear_hop}");
    report_json_with_counters(
        "clear_train",
        &records,
        &[
            ("epoch_samples_total", total_samples as u64),
            ("parity_hop_fhe", fhe_hop),
            ("parity_hop_clear", clear_hop),
        ],
    );
}
