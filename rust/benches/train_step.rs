//! Mini-batch `train_step` throughput through the plan-driven `Network`
//! API: one full encrypted SGD step (FC MACs, switch round trips, TFHE
//! ReLU/softmax gates, gradient requantization) on a reduced-scale MLP.
//! Emits `bench_out/BENCH_train_step.json` so the per-PR perf trajectory
//! accumulates data points (`GLYPH_BENCH_FULL=1` switches to the
//! production-shaped crypto profile).

use glyph::bench_util::{full_profile, report_json, time_op, BenchRecord};
use glyph::coordinator::max_threads;
use glyph::math::GlyphRng;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::network::NetworkBuilder;
use glyph::nn::tensor::{EncTensor, PackOrder};

fn main() {
    let profile = if full_profile() { EngineProfile::Default } else { EngineProfile::Test };
    let batch = 4usize;
    let (in_dim, hidden, classes) = (8usize, 6usize, 3usize);
    eprintln!(
        "train_step bench: {in_dim}-{hidden}-{classes} MLP, batch {batch}, {} profile",
        if full_profile() { "full" } else { "test" }
    );
    let (engine, mut client) = GlyphEngine::setup(profile, batch, 20260728);
    let mut rng = GlyphRng::new(3);
    let shift = engine.frac_bits().min(8);
    let err_shift = shift.saturating_sub(1).max(1);
    let mut net = NetworkBuilder::input_vec(in_dim)
        .fc(hidden)
        .relu(shift, err_shift)
        .fc(classes)
        .softmax(3, err_shift)
        .grad_shift(shift)
        .build(&mut client, &mut rng, &engine)
        .expect("valid bench network");

    let x_cts = (0..in_dim)
        .map(|i| {
            let col: Vec<i64> = (0..batch).map(|b| ((i * 7 + b * 3) % 19) as i64 - 9).collect();
            client.encrypt_batch(&col, 0)
        })
        .collect();
    let x = EncTensor::new(x_cts, vec![in_dim], PackOrder::Forward, 0);
    let lab_cts = (0..classes)
        .map(|k| {
            let mut v: Vec<i64> =
                (0..batch).map(|b| if b % classes == k { 127 } else { 0 }).collect();
            v.reverse();
            client.encrypt_batch(&v, 0)
        })
        .collect();
    let labels = EncTensor::new(lab_cts, vec![classes], PackOrder::Reversed, 0);

    // warm-up (key-dependent caches, thread pool spin-up)
    net.train_step(&x, &labels, &engine);
    let iters = if full_profile() { 1 } else { 3 };
    let secs = time_op(iters, || net.train_step(&x, &labels, &engine));

    // values/sec: every activation value of every sample in the mini-batch
    let act_values = (hidden + classes) * batch;
    let threads = max_threads();
    let records = vec![
        BenchRecord::new("train_step", secs, threads),
        BenchRecord::new("train_step_sample", secs / batch as f64, threads),
        BenchRecord::new("train_step_value", secs / act_values as f64, threads),
    ];
    println!(
        "train_step: {:.3}s/step  {:.2} samples/sec  {:.2} activation values/sec",
        secs,
        batch as f64 / secs,
        act_values as f64 / secs
    );
    report_json("train_step", &records);
}
