//! Figure 3: an all-TFHE MLP — activations get cheap but the MACs explode,
//! because an 8-bit multiply in TFHE gates costs hundreds of bootstraps vs
//! one BGV MultCC. We measure a real TFHE ripple-carry adder and derive the
//! gate-multiplier cost, then print the FC/Act split both ways.

use glyph::bench_util::{report, report_json, time_once, BenchRecord};
use glyph::coordinator::GlyphPool;
use glyph::math::GlyphRng;
use glyph::tfhe::{encode_bit, LweCiphertext, LweKey, TfheCloudKey, TfheParams, TrlweKey};

/// 8-bit ripple-carry add: 5 gates/bit (the standard full-adder net).
fn ripple_add(ck: &TfheCloudKey, a: &[LweCiphertext], b: &[LweCiphertext]) -> Vec<LweCiphertext> {
    let mut carry = ck.not(&a[0]); // dummy-false via NOT(x)+AND trick below
    carry = ck.and(&carry, &a[0]); // = false
    let mut out = Vec::with_capacity(8);
    for i in (0..8).rev() {
        let axb = ck.xor(&a[i], &b[i]);
        let sum = ck.xor(&axb, &carry);
        let t1 = ck.and(&a[i], &b[i]);
        let t2 = ck.and(&axb, &carry);
        carry = ck.or(&t1, &t2);
        out.push(sum);
    }
    out.reverse();
    out
}

fn main() {
    let params = TfheParams::test_params();
    let mut rng = GlyphRng::new(33);
    let key = LweKey::generate_binary(params.n, &mut rng);
    let ring = TrlweKey::generate(params.big_n, &mut rng);
    let ck = TfheCloudKey::generate(&key, &ring, &params, &mut rng);
    let bits =
        |v: u8, rng: &mut GlyphRng| -> Vec<LweCiphertext> {
            (0..8)
                .rev()
                .map(|i| LweCiphertext::encrypt(encode_bit((v >> i) & 1 == 1), &key, params.alpha_lwe, rng))
                .collect()
        };
    let a = bits(57, &mut rng);
    let b = bits(43, &mut rng);
    let t_add = time_once(|| {
        let _ = ripple_add(&ck, &a, &b);
    });
    // 8×8-bit multiply ≈ 64 ANDs + 7 ripple adds
    let t_and = time_once(|| {
        let _ = ck.and(&a[0], &b[0]);
    });
    let t_mult_tfhe = 64.0 * t_and + 7.0 * t_add;
    // measured BGV MultCC at comparable scale (test profile constant; the
    // table1 bench measures it precisely — use a conservative stand-in)
    let t_mult_bgv = 0.0005;
    let macs = (784 * 128 + 128 * 32 + 32 * 10) as f64;
    let act_values = (128 + 32 + 10) as f64;
    let t_act_tfhe = act_values * 15.0 * t_and; // ReLU ≈ 15 bootstraps/value

    // ---- gate-bootstraps/sec: the PBS pipeline's headline metric ----------
    // sequential: one worker reusing one scratch; pooled: the full GlyphPool.
    let k = 64usize;
    let pairs: Vec<(&LweCiphertext, &LweCiphertext)> = (0..k).map(|_| (&a[0], &b[0])).collect();
    // warm up scratch + pool workers before timing
    let _ = ck.and(&a[0], &b[0]);
    let _ = ck.and_many(&pairs);
    let t_seq = time_once(|| {
        for (c1, c2) in &pairs {
            let _ = ck.and(c1, c2);
        }
    }) / k as f64;
    let t_pool = time_once(|| {
        let _ = ck.and_many(&pairs);
    }) / k as f64;
    let threads = GlyphPool::global().threads();
    report_json(
        "fig3",
        &[
            BenchRecord::new("gate_bootstrap", t_seq, 1),
            BenchRecord::new("gate_bootstrap_pool", t_pool, threads),
            BenchRecord::new("tfhe_8bit_multiply", 64.0 * t_and + 7.0 * t_add, 1),
        ],
    );

    let fc_tfhe = macs * t_mult_tfhe;
    let fc_bgv = macs * t_mult_bgv;
    let md = format!(
        "### Figure 3 — all-TFHE MLP vs Glyph split (forward pass, derived from measured gates)\n\n\
        measured: TFHE AND = {t_and:.4} s, 8-bit ripple add = {t_add:.3} s → 8-bit TFHE multiply ≈ {t_mult_tfhe:.3} s\n\n\
        | configuration | FC time (s) | Act time (s) | FC share |\n|---|---|---|---|\n\
        | all-TFHE | {fc_tfhe:.0} | {t_act_tfhe:.1} | {:.1}% |\n\
        | Glyph (BGV MAC + TFHE act) | {fc_bgv:.1} | {t_act_tfhe:.1} | {:.1}% |\n\n\
        shape: in the all-TFHE MLP the MACs dominate overwhelmingly (paper Fig. 3); switching MACs to BGV removes that wall.\n",
        100.0 * fc_tfhe / (fc_tfhe + t_act_tfhe),
        100.0 * fc_bgv / (fc_bgv + t_act_tfhe),
    );
    let md = format!(
        "{md}\ngate bootstraps/sec: {:.1} sequential → {:.1} across {} pool threads ({:.2}× scaling)\n",
        1.0 / t_seq,
        1.0 / t_pool,
        threads,
        t_seq / t_pool,
    );
    report("fig3", &md);
    assert!(t_mult_tfhe / t_mult_bgv > 17.0, "paper claims 17–30× BGV advantage; got {}", t_mult_tfhe / t_mult_bgv);
}
