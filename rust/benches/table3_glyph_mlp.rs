//! Table 3: Glyph MLP mini-batch breakdown (TFHE activations + switching)
//! and the headline latency reduction vs Table 2.

use glyph::bench_util::{full_profile, report};
use glyph::coordinator::cost::{mlp_table, to_markdown, total_row, OpLatencies, Scheme};

fn main() {
    let dims = [784, 128, 32, 10];
    let paper_lat = OpLatencies::paper();
    let glyph = mlp_table(&dims, Scheme::GlyphMlp, &paper_lat);
    let fhesgd_total = total_row(&mlp_table(&dims, Scheme::Fhesgd, &paper_lat)).time_s;
    let mut md = to_markdown("Table 3 — Glyph MLP mini-batch (paper-calibrated)", &glyph);
    let g = total_row(&glyph).time_s;
    md.push_str(&format!("\nreduction vs FHESGD: {:.1}% (paper: 97.4%); paper Table-3 total: 2991 s, ours: {:.0} s\n", 100.0*(1.0-g/fhesgd_total), g));

    eprintln!("measuring our per-op latencies…");
    let ours = OpLatencies::measure(!full_profile());
    let measured = mlp_table(&dims, Scheme::GlyphMlp, &ours);
    md.push_str(&to_markdown("Table 3 — Glyph MLP mini-batch (measured ops)", &measured));
    let gm = total_row(&measured).time_s;
    let fm = total_row(&mlp_table(&dims, Scheme::Fhesgd, &ours)).time_s;
    md.push_str(&format!("\nmeasured-calibration reduction vs FHESGD: {:.1}%\n", 100.0*(1.0-gm/fm)));
    report("table3", &md);
    assert!(1.0 - g / fhesgd_total > 0.95);
}
