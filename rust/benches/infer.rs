//! Forward-only encrypted inference throughput: scoring a frozen MLP
//! through an [`InferenceSession`] with zero backward steps. Measures the
//! amortized per-image latency at batch 1 (the interactive floor) against
//! coefficient-batched and cross-sample packed batch-8 scoring (the
//! amortization lever), and asserts the forward-only plan still prices the
//! timed work exactly. Emits `bench_out/BENCH_infer.json`.
//! `GLYPH_BENCH_FULL=1` switches to the production-shaped crypto profile.

use glyph::bench_util::{full_profile, report_json_with_counters, time_op, BenchRecord};
use glyph::coordinator::max_threads;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::train::{InferenceSession, MlpConfig};

const IN_DIM: usize = 8;
const HIDDEN: usize = 6;
const CLASSES: usize = 3;
const BATCH: usize = 8;
const BATCHES: usize = 2;

/// Deterministic 8-bit weight matrices (same model on every path).
fn weights() -> Vec<Vec<Vec<i64>>> {
    vec![
        (0..HIDDEN)
            .map(|j| (0..IN_DIM).map(|i| ((3 * i + 5 * j) % 15) as i64 - 7).collect())
            .collect(),
        (0..CLASSES)
            .map(|j| (0..HIDDEN).map(|i| ((i * j + 4) % 11) as i64 - 5).collect())
            .collect(),
    ]
}

/// Seconds per scored image at `batch` width. Also proves the timed work
/// is exactly what the forward-only plan predicted — a bench that drifted
/// from the plan would be measuring the wrong thing.
fn time_infer(profile: EngineProfile, batch: usize, packed: bool, iters: usize) -> f64 {
    let (engine, mut client) = if packed {
        GlyphEngine::setup_packed(profile, batch, 20260808)
    } else {
        GlyphEngine::setup(profile, batch, 20260808)
    };
    let config = MlpConfig::tiny(IN_DIM, HIDDEN, CLASSES);
    let session = InferenceSession::from_weights(config, weights(), &mut client, &engine)
        .expect("bench session builds");
    let images = batch * BATCHES;
    let ds = glyph::data::synthetic_digits(images, 9, "infer-bench");
    session.scores(&ds, images, &engine, &mut client).expect("warm-up scoring"); // warm-up

    let before = engine.counter.snapshot();
    let secs = time_op(iters, || {
        session.scores(&ds, images, &engine, &mut client).expect("scoring runs");
    });
    let live = engine.counter.snapshot().since(&before);
    let predicted =
        session.plan().totals().to_snapshot().scale((BATCHES * iters) as u64);
    let diff = live.diff_ignoring(&predicted, &glyph::serve::metrics::UNPREDICTED_OPS);
    assert!(
        diff.is_empty(),
        "timed scoring drifted from the forward-only plan: {}",
        glyph::coordinator::OpSnapshot::render_diff(&diff)
    );
    secs / images as f64
}

fn main() {
    let profile = if full_profile() { EngineProfile::Default } else { EngineProfile::Test };
    let iters = if full_profile() { 1 } else { 2 };
    eprintln!(
        "infer bench: {IN_DIM}-{HIDDEN}-{CLASSES} MLP, batch {BATCH}, {} profile",
        if full_profile() { "full" } else { "test" }
    );

    // interactive floor: one image per forward pass (batch-1 keys)
    let secs_single = time_infer(profile, 1, false, iters);
    // per-scalar coefficient batching at width 8 (for context)
    let secs_coeff = time_infer(profile, BATCH, false, iters);
    // the cross-sample packed path
    let secs_packed = time_infer(profile, BATCH, true, iters);
    let speedup = secs_single / secs_packed;

    let threads = max_threads();
    let records = vec![
        // secs_per_op = amortized seconds per IMAGE, so ops_per_sec = images/sec
        BenchRecord::new("per_image_batch1", secs_single, threads),
        BenchRecord::new("per_image_coeff_batch8", secs_coeff, threads),
        BenchRecord::new("per_image_packed_batch8", secs_packed, threads),
    ];
    println!(
        "infer: batch-1 {:.2} images/sec  coeff-batch8 {:.2}  packed-batch8 {:.2}  \
         amortization {speedup:.2}x",
        1.0 / secs_single,
        1.0 / secs_coeff,
        1.0 / secs_packed,
    );
    if speedup < 2.0 {
        eprintln!(
            "warning: packed batch-{BATCH} amortization {speedup:.2}x below the 2x target"
        );
    }
    report_json_with_counters(
        "infer",
        &records,
        &[("batch", BATCH as u64), ("speedup_pct", (speedup * 100.0).round() as u64)],
    );
}
