//! Table 4: Glyph CNN + transfer learning mini-batch breakdown (MNIST):
//! frozen plaintext convs (MultCP) + encrypted FC head (MultCC).

use glyph::bench_util::{full_profile, report};
use glyph::coordinator::cost::{cnn_table, mlp_table, to_markdown, total_row, CnnShape, OpLatencies, Scheme};

fn main() {
    let lat = OpLatencies::paper();
    let rows = cnn_table(&CnnShape::paper_mnist(), &lat);
    let mut md = to_markdown("Table 4 — Glyph CNN + TL mini-batch (paper-calibrated)", &rows);
    let cnn = total_row(&rows).time_s;
    let mlp = total_row(&mlp_table(&[784, 128, 32, 10], Scheme::GlyphMlp, &lat)).time_s;
    md.push_str(&format!("\nCNN+TL vs Glyph-MLP: {:.1}% faster (paper: 56.7% on MNIST); paper total 3.5K s, ours {:.0} s\n",
        100.0 * (1.0 - cnn / mlp), cnn));

    eprintln!("measuring our per-op latencies…");
    let ours = OpLatencies::measure(!full_profile());
    let measured = cnn_table(&CnnShape::paper_mnist(), &ours);
    md.push_str(&to_markdown("Table 4 — Glyph CNN + TL mini-batch (measured ops)", &measured));
    report("table4", &md);
    assert!(cnn < mlp, "transfer CNN must beat the MLP");
}
