//! Table 1: per-op latency comparison (BGV MultCC/MultCP/AddCC/TLU vs the
//! TFHE-side activation costs), measured on this implementation and printed
//! next to the paper's numbers.

use glyph::bench_util::{full_profile, report};
use glyph::coordinator::cost::OpLatencies;

fn main() {
    let test_scale = !full_profile();
    eprintln!("table1_ops: measuring ({} profile)…", if test_scale { "test" } else { "FULL" });
    let ours = OpLatencies::measure(test_scale);
    let paper = OpLatencies::paper();
    let md = format!(
        "### Table 1 — FHE operation latencies (s)\n\n\
         profile: {}\n\n\
         | Operation | ours | paper (BGV/TFHE) | ratio ours (op/MultCC) | ratio paper |\n|---|---|---|---|---|\n\
         | MultCC | {:.6} | 0.012 | 1.0 | 1.0 |\n\
         | MultCP | {:.6} | 0.001 | {:.2} | 0.083 |\n\
         | AddCC | {:.6} | 0.002 | {:.4} | 0.17 |\n\
         | TLU (BGV bit-sliced) | {:.4} | 307.9 | {:.0} | 25658 |\n\
         | ReLU/value (TFHE) | {:.4} | 0.1 | {:.1} | 8.3 |\n\
         | softmax/value (TFHE) | {:.4} | 3.3 | {:.1} | 275 |\n",
        if test_scale { "test-scale" } else { "full" },
        ours.mult_cc,
        ours.mult_cp, ours.mult_cp / ours.mult_cc,
        ours.add_cc, ours.add_cc / ours.mult_cc,
        ours.tlu, ours.tlu / ours.mult_cc,
        ours.relu_value, ours.relu_value / ours.mult_cc,
        ours.softmax_value, ours.softmax_value / ours.mult_cc,
    );
    let _ = paper;
    report("table1", &md);
    // headline shape: TLU must be orders of magnitude above a MAC
    assert!(ours.tlu / ours.mult_cc > 100.0, "TLU/MultCC ratio too small");
}
