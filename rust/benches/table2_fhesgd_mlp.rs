//! Table 2: FHESGD MLP mini-batch breakdown on MNIST — generated in both
//! calibrations (paper per-op latencies and measured ones).

use glyph::bench_util::{full_profile, report};
use glyph::coordinator::cost::{mlp_table, to_markdown, total_row, OpLatencies, Scheme};

fn main() {
    let dims = [784, 128, 32, 10];
    let paper = mlp_table(&dims, Scheme::Fhesgd, &OpLatencies::paper());
    let mut md = to_markdown("Table 2 — FHESGD MLP mini-batch (paper-calibrated)", &paper);
    let t = total_row(&paper);
    let act: f64 = paper.iter().filter(|r| r.layer.starts_with("Act")).map(|r| r.time_s).sum();
    md.push_str(&format!("\npaper: total 118K s; ours (paper-calibrated): {:.0} s, activation share {:.1}%\n", t.time_s, 100.0*act/t.time_s));

    eprintln!("measuring our per-op latencies…");
    let ours = OpLatencies::measure(!full_profile());
    let measured = mlp_table(&dims, Scheme::Fhesgd, &ours);
    md.push_str(&to_markdown("Table 2 — FHESGD MLP mini-batch (measured ops)", &measured));
    let tm = total_row(&measured);
    let actm: f64 = measured.iter().filter(|r| r.layer.starts_with("Act")).map(|r| r.time_s).sum();
    md.push_str(&format!("\nmeasured-calibration total: {:.0} s, activation share {:.1}%\n", tm.time_s, 100.0*actm/tm.time_s));
    report("table2", &md);
    assert!(act / t.time_s > 0.97);
}
