//! Ablations over DESIGN.md's choices:
//!  (a) native Rust NTT MAC vs the XLA-offloaded Pallas kernel (PJRT);
//!  (b) batch width amortization of the switch (values/ciphertext);
//!  (c) softmax: Figure-4 MUX tree vs single programmable bootstrap.

use glyph::bench_util::{report, time_once, time_op};
use glyph::math::{GlyphRng, NttTable};
use glyph::nn::activation::SoftmaxUnit;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::tensor::{EncTensor, PackOrder};

fn main() {
    let mut md = String::from("### Ablations\n\n");

    // (a) native NTT pointwise MAC vs XLA offload
    let p = 469762049u64;
    let n = 256usize;
    let batchk = 8usize;
    let table = NttTable::new(n, p);
    let mut rng = GlyphRng::new(1);
    let a: Vec<u64> = (0..batchk * n).map(|_| rng.uniform_mod(p)).collect();
    let b: Vec<u64> = (0..batchk * n).map(|_| rng.uniform_mod(p)).collect();
    let mut acc: Vec<u64> = vec![0; batchk * n];
    let t_native = time_op(200, || {
        for k in 0..batchk {
            table.pointwise_acc(&mut acc[k * n..(k + 1) * n], &a[k * n..(k + 1) * n], &b[k * n..(k + 1) * n]);
        }
    });
    let xla = glyph::runtime::Runtime::new("artifacts")
        .and_then(|rt| rt.load("ntt_mac"))
        .ok();
    match &xla {
        Some(art) => {
            let t_xla = time_op(20, || {
                let _ = art
                    .run_u64(&[(&a, &[batchk, n]), (&b, &[batchk, n]), (&acc, &[batchk, n])])
                    .unwrap();
            });
            md.push_str(&format!(
                "(a) batched pointwise MAC {batchk}×{n}: native {:.2} µs vs XLA-offload {:.2} µs — native wins below ~10^5 elements (PJRT call overhead); offload is for fused whole-layer batches\n\n",
                t_native * 1e6, t_xla * 1e6));
        }
        None => md.push_str("(a) skipped: artifacts not built\n\n"),
    }

    // (b) switch amortization over batch width
    for batch in [1usize, 4, 16] {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 5);
        let u = EncTensor::new(vec![client.encrypt_batch(&vec![42; batch], 0)], vec![1], PackOrder::Forward, 0);
        let t = time_once(|| {
            let _ = glyph::nn::activation::relu_layer(&engine, &u, 0, PackOrder::Forward);
        });
        md.push_str(&format!("(b) ReLU layer, batch {batch}: {:.3} s total, {:.3} s/value\n", t, t / batch as f64));
    }
    md.push_str("\n");

    // (c) softmax MUX tree vs single PBS
    let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 1, 6);
    let unit = SoftmaxUnit::logistic(3, 2);
    let ct = client.encrypt_batch(&[3], 0);
    let bits = engine.switch_to_bits(&ct, &[0], 0);
    let t_tree = time_once(|| {
        let _ = unit.evaluate_mux(&engine, &bits[0][..3]);
    });
    let lwes = engine.fhe().fwd_switch.to_torus_lanes(ct.fhe(), 1).expect("lane 0 fits the ring");
    let value_bit = glyph::nn::backend::Bit::Fhe(lwes[0].clone());
    let t_pbs = time_once(|| {
        let _ = unit.evaluate_pbs(&engine, &value_bit);
    });
    md.push_str(&format!(
        "(c) 3-bit softmax unit: MUX tree {:.4} s vs single-PBS {:.4} s ({}× faster; the tree is the paper-faithful 2^n-gate unit)\n",
        t_tree, t_pbs, (t_tree / t_pbs) as u64
    ));
    report("ablations", &md);
}
