//! Tables 6/7/8: the Skin-Cancer-MNIST counterparts (2352-input MLP,
//! 64/96-channel CNN), both calibrations.

use glyph::bench_util::{full_profile, report};
use glyph::coordinator::cost::{cnn_table, mlp_table, to_markdown, total_row, CnnShape, OpLatencies, Scheme};

fn main() {
    let dims = [2352, 128, 32, 7]; // 28×28×3 input
    let lat = OpLatencies::paper();
    let mut md = String::new();
    let t6 = mlp_table(&dims, Scheme::Fhesgd, &lat);
    md.push_str(&to_markdown("Table 6 — FHESGD MLP (Cancer, paper-calibrated)", &t6));
    let t7 = mlp_table(&dims, Scheme::GlyphMlp, &lat);
    md.push_str(&to_markdown("Table 7 — Glyph MLP (Cancer, paper-calibrated)", &t7));
    let t8 = cnn_table(&CnnShape::paper_cancer(), &lat);
    md.push_str(&to_markdown("Table 8 — Glyph CNN + TL (Cancer, paper-calibrated)", &t8));
    let (f, g, c) = (total_row(&t6).time_s, total_row(&t7).time_s, total_row(&t8).time_s);
    md.push_str(&format!(
        "\nGlyph-MLP vs FHESGD: {:.1}% reduction (paper: 91.4%); CNN+TL vs Glyph-MLP: {:.1}% (paper: 67.2%)\n",
        100.0 * (1.0 - g / f),
        100.0 * (1.0 - c / g)
    ));
    eprintln!("measuring our per-op latencies…");
    let ours = OpLatencies::measure(!full_profile());
    md.push_str(&to_markdown("Table 7 — Glyph MLP (Cancer, measured ops)", &mlp_table(&dims, Scheme::GlyphMlp, &ours)));
    report("tables_cancer", &md);
    assert!(1.0 - g / f > 0.85);
    assert!(c < g);
}
