//! Table 5: overall training latency and multi-thread scaling. SGD's
//! independent weight-update MACs parallelize across the executor; the
//! overall latency uses the paper's own estimator (mini-batch latency ×
//! mini-batch count).

use glyph::bench_util::report;
use glyph::coordinator::cost::{measure_scaling, mlp_table, overall_latency, total_row, OpLatencies, Scheme, cnn_table, CnnShape};
use glyph::coordinator::max_threads;

fn main() {
    let mut md = String::from("### Table 5 — thread scaling (independent MAC work items)\n\n| threads | speedup |\n|---|---|\n");
    let work = 256;
    let maxt = max_threads();
    let mut sweep: Vec<usize> = vec![1, 2, 4, 8, 16, 32, 48];
    sweep.retain(|&t| t <= maxt);
    let mut best = 1.0f64;
    for &t in &sweep {
        let s = measure_scaling(t, work);
        best = best.max(s);
        md.push_str(&format!("| {t} | {s:.2}× |\n"));
    }
    md.push_str(&format!("\nmax threads here: {maxt}; paper observed 9.3× at 48 threads (memory-bound)\n"));

    // overall latency estimates, paper methodology
    let lat = OpLatencies::paper();
    let mlp_mb = total_row(&mlp_table(&[784, 128, 32, 10], Scheme::GlyphMlp, &lat)).time_s;
    let fhesgd_mb = total_row(&mlp_table(&[784, 128, 32, 10], Scheme::Fhesgd, &lat)).time_s;
    let cnn_mb = total_row(&cnn_table(&CnnShape::paper_mnist(), &lat)).time_s;
    let years = |s: f64| s / (365.25 * 86400.0);
    let days = |s: f64| s / 86400.0;
    md.push_str("\n### Table 5 — overall training latency (paper-calibrated, paper estimator)\n\n");
    md.push_str("| network | threads | epochs | time | paper |\n|---|---|---|---|---|\n");
    md.push_str(&format!("| FHESGD MLP (MNIST) | 1 | 50 | {:.0} years | 187 years |\n", years(overall_latency(fhesgd_mb, 1000, 50, 1.0))));
    md.push_str(&format!("| Glyph MLP (MNIST) | 1 | 50 | {:.1} years | (13.4 years @48t) |\n", years(overall_latency(mlp_mb, 1000, 50, 1.0))));
    md.push_str(&format!("| Glyph CNN+TL (MNIST) | 1 | 5 | {:.2} months | 2.46 months |\n", overall_latency(cnn_mb, 1000, 5, 1.0) / (30.44 * 86400.0)));
    md.push_str(&format!("| Glyph CNN+TL (MNIST) | 48 | 5 | {:.1} days | 8 days |\n", days(overall_latency(cnn_mb, 1000, 5, 9.3))));
    report("table5", &md);
    if maxt > 1 {
        assert!(best > 1.05, "no parallel speedup measured on a {maxt}-core host");
    } else {
        eprintln!("single-core host: scaling assertion skipped (sweep still recorded)");
    }
}
