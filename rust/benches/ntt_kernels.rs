//! Ring-kernel microbench: scalar vs SIMD (lazy-reduction) kernels head to
//! head on the four hot loops they cover — negacyclic NTT forward/inverse,
//! the fused pointwise MAC, the complex FFT pipeline and the hoisted LWE
//! key switch. Emits `bench_out/BENCH_ntt.json` with per-degree NTTs/sec
//! and butterflies/sec plus `*_speedup_x100` counters (simd over scalar).
//! Build with `RUSTFLAGS="-C target-cpu=native"` to give LLVM the wide
//! lanes the simd kernels are shaped for; `GLYPH_BENCH_FULL=1` adds the
//! larger ring degrees.

use glyph::bench_util::{full_profile, report_json_with_counters, time_op, BenchRecord};
use glyph::math::fft::{Cplx, TorusFft};
use glyph::math::kernels::{scalar_kernels, simd_kernels, RingKernels};
use glyph::math::modarith::gen_ntt_primes;
use glyph::math::{GlyphRng, NttTable};
use glyph::tfhe::{LweCiphertext, LweKey, LweKeySwitchKey};

const KERNELS: [(&str, fn() -> &'static dyn RingKernels); 2] =
    [("scalar", scalar_kernels), ("simd", simd_kernels)];

fn main() {
    let p = gen_ntt_primes(1, 1 << 26, 1 << 32)[0];
    let degrees: &[usize] = if full_profile() { &[256, 1024, 4096, 8192] } else { &[256, 1024, 4096] };
    eprintln!("ntt_kernels bench: p = {p}, degrees {degrees:?}");
    let mut records = Vec::new();
    let mut counters: Vec<(String, u64)> = Vec::new();

    // --- NTT forward/inverse + fused pointwise MAC, per degree --------------
    for &n in degrees {
        let iters = (1 << 22) / n; // ~4M butterffly-carrying lanes per leg
        let log2n = n.trailing_zeros() as u64;
        let butterflies = (n as u64 / 2) * log2n;
        let mut secs = [[0f64; 3]; 2]; // [kernel][fwd, inv, acc2]
        for (ki, (kname, kfn)) in KERNELS.iter().enumerate() {
            let table = NttTable::with_kernels(n, p, kfn());
            let mut rng = GlyphRng::new(0x6e74 ^ n as u64);
            let mut a: Vec<u64> = (0..n).map(|_| rng.next_u64() % p).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64() % p).collect();
            let c: Vec<u64> = (0..n).map(|_| rng.next_u64() % p).collect();
            let d: Vec<u64> = (0..n).map(|_| rng.next_u64() % p).collect();
            let mut acc: Vec<u64> = (0..n).map(|_| rng.next_u64() % p).collect();

            let t_fwd = time_op(iters, || {
                table.forward(&mut a);
                std::hint::black_box(a[0]);
            });
            let t_inv = time_op(iters, || {
                table.inverse(&mut a);
                std::hint::black_box(a[0]);
            });
            let t_acc2 = time_op(iters, || {
                table.pointwise_acc2(&mut acc, &a, &b, &c, &d);
                std::hint::black_box(acc[0]);
            });
            secs[ki] = [t_fwd, t_inv, t_acc2];
            records.push(BenchRecord::new(&format!("ntt_fwd_n{n}_{kname}"), t_fwd, 1));
            records.push(BenchRecord::new(&format!("ntt_inv_n{n}_{kname}"), t_inv, 1));
            records.push(BenchRecord::new(&format!("pointwise_acc2_n{n}_{kname}"), t_acc2, 1));
            counters.push((
                format!("ntt_fwd_n{n}_{kname}_butterflies_per_sec"),
                (butterflies as f64 / t_fwd) as u64,
            ));
            counters.push((format!("ntt_fwd_n{n}_{kname}_per_sec"), (1.0 / t_fwd) as u64));
            println!(
                "n={n:5} {kname:6}: fwd {:9.1} NTT/s ({:.3e} bf/s)  inv {:9.1} NTT/s  acc2 {:9.1}/s",
                1.0 / t_fwd,
                butterflies as f64 / t_fwd,
                1.0 / t_inv,
                1.0 / t_acc2
            );
        }
        for (op, i) in [("ntt_fwd", 0usize), ("ntt_inv", 1), ("pointwise_acc2", 2)] {
            counters
                .push((format!("{op}_n{n}_speedup_x100"), (100.0 * secs[0][i] / secs[1][i]) as u64));
        }
    }

    // --- complex FFT pipeline (blind-rotation shape, N = 1024) --------------
    let n_fft = 1024usize;
    let iters = 2048;
    let mut fft_secs = [0f64; 2];
    for (ki, (kname, kfn)) in KERNELS.iter().enumerate() {
        let fft = TorusFft::with_kernels(n_fft, kfn());
        let mut rng = GlyphRng::new(0xfff7);
        let ints: Vec<i32> = (0..n_fft).map(|_| (rng.uniform_mod(129) as i32) - 64).collect();
        let torus: Vec<u32> = (0..n_fft).map(|_| rng.torus32()).collect();
        let fb = fft.forward_torus(&torus);
        let mut lane = vec![Cplx::default(); n_fft / 2];
        let mut acc = vec![Cplx::default(); n_fft / 2];
        let mut out = vec![0u32; n_fft];
        let t = time_op(iters, || {
            fft.forward_int_into(&ints, &mut lane);
            fft.mul_acc(&lane, &fb, &mut acc);
            fft.inverse_add_to_torus_inplace(&mut acc, &mut out);
            std::hint::black_box(out[0]);
        });
        fft_secs[ki] = t;
        records.push(BenchRecord::new(&format!("fft_int_mac_inv_n{n_fft}_{kname}"), t, 1));
        println!("fft n={n_fft} {kname:6}: {:9.1} fwd+mac+inv/s", 1.0 / t);
    }
    counters.push((
        format!("fft_n{n_fft}_speedup_x100"),
        (100.0 * fft_secs[0] / fft_secs[1]) as u64,
    ));

    // --- hoisted LWE key switch (extractor shape: 256 → 64) -----------------
    let mut rng = GlyphRng::new(0x4b53);
    let src = LweKey::generate_binary(256, &mut rng);
    let dst = LweKey::generate_binary(64, &mut rng);
    let mut ksk = LweKeySwitchKey::generate(&src, &dst, 2, 8, 1e-8, &mut rng);
    let ct = LweCiphertext::encrypt(1 << 29, &src, 1e-8, &mut rng);
    let mut out = LweCiphertext::trivial(0, 64);
    let ks_iters = 4096;
    let mut ks_secs = [0f64; 2];
    for (ki, (kname, kfn)) in KERNELS.iter().enumerate() {
        ksk.kernels = kfn();
        ksk.switch_into(&ct, &mut out); // warm the thread-local scratch
        let t = time_op(ks_iters, || {
            ksk.switch_into(&ct, &mut out);
            std::hint::black_box(out.b);
        });
        ks_secs[ki] = t;
        records.push(BenchRecord::new(&format!("lwe_keyswitch_256to64_{kname}"), t, 1));
        println!("keyswitch 256→64 {kname:6}: {:9.1} switches/s", 1.0 / t);
    }
    counters.push(("keyswitch_speedup_x100".to_string(), (100.0 * ks_secs[0] / ks_secs[1]) as u64));

    let counter_refs: Vec<(&str, u64)> = counters.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    report_json_with_counters("ntt", &records, &counter_refs);
}
