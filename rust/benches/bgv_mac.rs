//! BGV MAC engine microbench: the retained per-term reference path (clone +
//! `mul_assign` relin + `add_assign` per term) against the scratch-backed
//! lazy-relinearization row engine (`mac_rows_many`), plus the cached vs
//! uncached MultCP weight lift. Emits `bench_out/BENCH_bgv_mac.json` with a
//! `counters` section recording the relinearizations-per-row accounting —
//! the lazy path must save ≥ in_dim/2 relins per FC row (it saves
//! `in_dim − 1`). `GLYPH_BENCH_FULL=1` runs the production-shaped profile.

use glyph::bench_util::{full_profile, report_json_with_counters, time_op, BenchRecord};
use glyph::bgv::{CachedPlaintext, Plaintext};
use glyph::nn::backend::Term;
use glyph::coordinator::max_threads;
use glyph::nn::engine::{EngineProfile, GlyphEngine};

fn main() {
    let profile = if full_profile() { EngineProfile::Default } else { EngineProfile::Test };
    let batch = 4usize;
    let (in_dim, out_dim) = (32usize, 8usize);
    eprintln!(
        "bgv_mac bench: {in_dim}-wide rows × {out_dim}, batch {batch}, {} profile",
        if full_profile() { "full" } else { "test" }
    );
    let (engine, mut client) = GlyphEngine::setup(profile, batch, 20260728);

    let ws: Vec<_> = (0..in_dim).map(|i| client.encrypt_scalar((i % 15) as i64 - 7)).collect();
    let xs: Vec<_> = (0..in_dim)
        .map(|i| {
            let col: Vec<i64> = (0..batch).map(|b| ((i * 5 + b * 3) % 17) as i64 - 8).collect();
            client.encrypt_batch(&col, 0)
        })
        .collect();
    let iters = if full_profile() { 3 } else { 10 };

    // --- reference: one relin per term --------------------------------------
    let fhe = engine.fhe();
    let t_ref = time_op(iters, || {
        let mut acc: Option<glyph::bgv::BgvCiphertext> = None;
        for i in 0..in_dim {
            let mut t = ws[i].fhe().clone();
            t.mul_assign(xs[i].fhe(), &fhe.rlk, &fhe.ctx);
            match &mut acc {
                None => acc = Some(t),
                Some(a) => a.add_assign(&t),
            }
        }
        std::hint::black_box(acc.unwrap().c0.res[0][0]);
    });

    // --- lazy: one relin per row, counted -----------------------------------
    let row: Vec<Term> = ws.iter().zip(&xs).map(|(w, x)| Term::Cc(w, x)).collect();
    let single = [row];
    // warm-up sizes the worker scratches
    let _ = engine.mac_rows_many(&single);
    let before = engine.counter.snapshot();
    let t_lazy = time_op(iters, || {
        let out = engine.mac_rows_many(&single);
        std::hint::black_box(out[0].fhe().c0.res[0][0]);
    });
    let lazy_counts = engine.counter.snapshot().since(&before);
    let relins_per_row_lazy = lazy_counts.relin / iters as u64;

    // --- batched fan-out: out_dim rows across the pool ----------------------
    let rows: Vec<Vec<Term>> = (0..out_dim)
        .map(|_| ws.iter().zip(&xs).map(|(w, x)| Term::Cc(w, x)).collect())
        .collect();
    let t_rows = time_op(iters, || {
        let out = engine.mac_rows_many(&rows);
        std::hint::black_box(out[out_dim - 1].fhe().c0.res[0][0]);
    });

    // --- MultCP: per-call lift vs cached evaluation form --------------------
    let wp_plain = Plaintext::encode_scalar(9, &fhe.ctx.params);
    let wp_cached = CachedPlaintext::new(wp_plain.clone(), &fhe.ctx);
    let cp_iters = iters * 10;
    let t_cp_uncached = time_op(cp_iters, || {
        let mut t = xs[0].fhe().clone();
        t.mul_plain_assign(&wp_plain, &fhe.ctx);
        std::hint::black_box(t.c0.res[0][0]);
    });
    let t_cp_cached = time_op(cp_iters, || {
        let mut t = xs[0].fhe().clone();
        t.mul_plain_cached_assign(&wp_cached);
        std::hint::black_box(t.c0.res[0][0]);
    });

    let relins_per_row_reference = in_dim as u64; // one relin per MultCC term
    let threads = max_threads();
    println!(
        "fc_row({in_dim} terms): reference {t_ref:.4}s  lazy {t_lazy:.4}s  ({:.2}x)  \
         {out_dim}-row fan-out {t_rows:.4}s",
        t_ref / t_lazy
    );
    println!(
        "mult_cp: uncached {:.6}s  cached {:.6}s  ({:.2}x)   relins/row: {} -> {}",
        t_cp_uncached,
        t_cp_cached,
        t_cp_uncached / t_cp_cached,
        relins_per_row_reference,
        relins_per_row_lazy
    );
    assert!(
        relins_per_row_reference - relins_per_row_lazy >= relins_per_row_reference / 2,
        "lazy relin must save at least in_dim/2 relins per row"
    );

    let records = vec![
        BenchRecord::new("fc_row_reference", t_ref, 1),
        BenchRecord::new("fc_row_lazy", t_lazy, 1),
        BenchRecord::new("fc_rows_fanout", t_rows / out_dim as f64, threads),
        BenchRecord::new("mac_term_lazy", t_lazy / in_dim as f64, 1),
        BenchRecord::new("mult_cp_uncached", t_cp_uncached, 1),
        BenchRecord::new("mult_cp_cached", t_cp_cached, 1),
    ];
    report_json_with_counters(
        "bgv_mac",
        &records,
        &[
            ("in_dim", in_dim as u64),
            ("relins_per_row_reference", relins_per_row_reference),
            ("relins_per_row_lazy", relins_per_row_lazy),
            ("relins_saved_per_row", relins_per_row_reference - relins_per_row_lazy),
        ],
    );
}
