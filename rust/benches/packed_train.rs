//! Cross-sample SIMD minibatch throughput: one encrypted `train_step` over
//! a `PackedLayout` minibatch (batch × feature slot blocks, one MAC per
//! weight block, one extract fan-out per value column) versus the
//! per-sample baseline that steps the same network one sample at a time.
//! Emits `bench_out/BENCH_packed_train.json` with samples/sec for both
//! paths and the packed speedup, plus clear-backend epoch accuracies
//! demonstrating the equal-accuracy floor (the packed path is
//! byte-identical to the per-sample path — `tests/backend_equivalence.rs`
//! — so the floors cannot differ; the bench records them anyway).
//! `GLYPH_BENCH_FULL=1` switches to the production-shaped crypto profile.

use glyph::bench_util::{full_profile, report_json_with_counters, time_op, BenchRecord};
use glyph::coordinator::max_threads;
use glyph::math::GlyphRng;
use glyph::nn::backend::Codec;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::network::{Network, NetworkBuilder};
use glyph::nn::tensor::{EncTensor, PackOrder};
use glyph::train::Trainer;

const IN_DIM: usize = 8;
const HIDDEN: usize = 6;
const CLASSES: usize = 3;
const BATCH: usize = 8;

fn build_net(engine: &GlyphEngine, codec: &mut dyn Codec, seed: u64) -> Network {
    let shift = engine.frac_bits().min(8);
    let err_shift = shift.saturating_sub(1).max(1);
    NetworkBuilder::input_vec(IN_DIM)
        .fc(HIDDEN)
        .relu(shift, err_shift)
        .fc(CLASSES)
        .softmax(3, err_shift)
        .grad_shift(shift)
        .build(codec, &mut GlyphRng::new(seed), engine)
        .expect("valid bench network")
}

/// Deterministic minibatch columns: feature `i`, sample `b`.
fn x_cols(batch: usize) -> Vec<Vec<i64>> {
    (0..IN_DIM)
        .map(|i| (0..batch).map(|b| ((i * 7 + b * 3) % 19) as i64 - 9).collect())
        .collect()
}

fn labels(codec: &mut dyn Codec, batch: usize) -> EncTensor {
    let cts = (0..CLASSES)
        .map(|k| {
            let mut v: Vec<i64> =
                (0..batch).map(|b| if b % CLASSES == k { 127 } else { 0 }).collect();
            v.reverse();
            codec.encrypt_batch(&v, 0)
        })
        .collect();
    EncTensor::new(cts, vec![CLASSES], PackOrder::Reversed, 0)
}

/// Seconds per train_step on a per-scalar (coefficient-batched) engine.
fn time_per_scalar(profile: EngineProfile, batch: usize, iters: usize) -> f64 {
    let (engine, mut client) = GlyphEngine::setup(profile, batch, 20260808);
    let mut net = build_net(&engine, &mut client, 3);
    let cts = x_cols(batch).iter().map(|v| client.encrypt_batch(v, 0)).collect();
    let x = EncTensor::new(cts, vec![IN_DIM], PackOrder::Forward, 0);
    let lab = labels(&mut client, batch);
    net.train_step(&x, &lab, &engine); // warm-up
    time_op(iters, || net.train_step(&x, &lab, &engine))
}

/// Seconds per train_step on the packed cross-sample engine.
fn time_packed(profile: EngineProfile, batch: usize, iters: usize) -> f64 {
    let (engine, mut client) = GlyphEngine::setup_packed(profile, batch, 20260808);
    let layout = engine.packed_layout().expect("packed engine").clone();
    let mut net = build_net(&engine, &mut client, 3);
    let cts = layout
        .pack_columns(&x_cols(batch), engine.params().n)
        .iter()
        .map(|coeffs| client.encrypt_coeffs(coeffs, 0))
        .collect();
    let x = EncTensor::packed(cts, vec![IN_DIM], PackOrder::Forward, 0, layout);
    let lab = labels(&mut client, batch);
    net.train_step(&x, &lab, &engine); // warm-up
    time_op(iters, || net.train_step(&x, &lab, &engine))
}

/// Clear-backend epoch accuracy (permille) at MNIST-like scale — packed and
/// per-scalar engines must land on the exact same floor.
fn clear_accuracy(packed: bool) -> u64 {
    let batch = BATCH;
    let (engine, mut codec) = if packed {
        GlyphEngine::setup_clear_packed(EngineProfile::Default, batch)
    } else {
        GlyphEngine::setup_clear(EngineProfile::Default, batch)
    };
    let net = NetworkBuilder::input_vec(196)
        .fc(32)
        .relu(8, 8)
        .fc(10)
        .softmax(8, 8)
        .grad_shift(12)
        .build(&mut codec, &mut GlyphRng::new(7), &engine)
        .expect("accuracy net");
    let mut trainer = Trainer::new(net, 10);
    let train = glyph::data::synthetic_digits(240, 5, "packed-bench-train");
    let test = glyph::data::synthetic_digits(80, 6, "packed-bench-test");
    trainer.train_epoch(&train, &engine, &mut codec).expect("epoch runs");
    let acc = trainer.evaluate(&test, 80, &engine, &mut codec).expect("eval runs");
    (acc * 1000.0).round() as u64
}

fn main() {
    let profile = if full_profile() { EngineProfile::Default } else { EngineProfile::Test };
    let iters = if full_profile() { 1 } else { 2 };
    eprintln!(
        "packed_train bench: {IN_DIM}-{HIDDEN}-{CLASSES} MLP, batch {BATCH}, {} profile",
        if full_profile() { "full" } else { "test" }
    );

    // per-sample baseline: one sample per step (batch-1 keys)
    let secs_single = time_per_scalar(profile, 1, iters);
    // per-scalar coefficient batching at the same width (for context)
    let secs_coeff = time_per_scalar(profile, BATCH, iters);
    // the packed cross-sample path
    let secs_packed = time_packed(profile, BATCH, iters);

    let sps_single = 1.0 / secs_single;
    let sps_coeff = BATCH as f64 / secs_coeff;
    let sps_packed = BATCH as f64 / secs_packed;
    let speedup = sps_packed / sps_single;

    let acc_base = clear_accuracy(false);
    let acc_packed = clear_accuracy(true);
    assert_eq!(
        acc_packed, acc_base,
        "packed and per-sample accuracy floors must be identical (byte-identical training)"
    );

    let threads = max_threads();
    let records = vec![
        // secs_per_op = seconds per SAMPLE, so ops_per_sec = samples/sec
        BenchRecord::new("per_sample_baseline", secs_single, threads),
        BenchRecord::new("per_scalar_coeff_batch8", secs_coeff / BATCH as f64, threads),
        BenchRecord::new("packed_batch8", secs_packed / BATCH as f64, threads),
        BenchRecord::new("packed_step", secs_packed, threads),
    ];
    println!(
        "packed_train: baseline {:.2} samples/sec  coeff-batch {:.2}  packed {:.2}  \
         speedup {speedup:.2}x  accuracy floor {:.1}% (both paths)",
        sps_single,
        sps_coeff,
        sps_packed,
        acc_base as f64 / 10.0
    );
    if speedup < 4.0 {
        eprintln!("warning: packed speedup {speedup:.2}x below the 4x target at batch {BATCH}");
    }
    report_json_with_counters(
        "packed_train",
        &records,
        &[
            ("batch", BATCH as u64),
            ("speedup_pct", (speedup * 100.0).round() as u64),
            ("accuracy_baseline_permille", acc_base),
            ("accuracy_packed_permille", acc_packed),
        ],
    );
}
