//! Scheme-switch engine microbench: the retained serial per-lane reference
//! path against the batch-parallel scratch engine, both directions.
//!
//! * down-switch (BGV→TFHE): `switch_down_many` over a layer boundary's
//!   worth of ciphertexts — serial = per-ciphertext / per-lane / per-bit
//!   loop, pooled = one extract fan-out + one `pbs_many` digit extraction;
//! * up-switch (TFHE→BGV): `switch_up_many` over the same boundary —
//!   serial = per-group pack + raise loop, pooled = packing key switches
//!   fanned across the pool with warm `RepackScratch` buffers.
//!
//! Emits `bench_out/BENCH_switch.json` with lanes/sec per direction and a
//! `counters` section carrying the pooled-vs-serial speedups (×100) plus
//! the lane counts — the EXPERIMENTS.md §Scheme switch numbers.
//! `GLYPH_BENCH_FULL=1` runs the production-shaped profile.

use glyph::bench_util::{full_profile, report_json_with_counters, time_op, BenchRecord};
use glyph::coordinator::max_threads;
use glyph::nn::backend::{Bit, Ct};
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::switch::VALUE_POS;
use glyph::tfhe::LweCiphertext;

fn main() {
    let profile = if full_profile() { EngineProfile::Default } else { EngineProfile::Test };
    let (lanes, n_cts, iters) = if full_profile() { (16usize, 3usize, 1) } else { (8, 3, 2) };
    eprintln!(
        "switch bench: {n_cts} cts × {lanes} lanes, {} profile, {} threads",
        if full_profile() { "full" } else { "test" },
        max_threads()
    );
    let (mut engine, mut client) = GlyphEngine::setup(profile, lanes, 20260728);

    let cts: Vec<Ct> = (0..n_cts)
        .map(|c| {
            let vals: Vec<i64> = (0..lanes).map(|b| ((c * 37 + b * 11) % 200) as i64 - 100).collect();
            client.encrypt_batch(&vals, 0)
        })
        .collect();
    let ct_refs: Vec<&Ct> = cts.iter().collect();
    let positions: Vec<usize> = (0..lanes).collect();
    let total_lanes = (n_cts * lanes) as f64;
    let pre = engine.frac_bits();

    // ---- down-switch: serial reference vs pooled engine --------------------
    engine.serial_switch = true;
    let t_down_serial = time_op(iters, || {
        let bits = engine.switch_down_many(&ct_refs, &positions, pre);
        std::hint::black_box(bits[0][0][0].fhe().b);
    });
    engine.serial_switch = false;
    // warm the worker scratches before timing
    let _ = engine.switch_down_many(&ct_refs, &positions, pre);
    let t_down_pooled = time_op(iters, || {
        let bits = engine.switch_down_many(&ct_refs, &positions, pre);
        std::hint::black_box(bits[0][0][0].fhe().b);
    });

    // ---- up-switch: serial reference vs pooled engine ----------------------
    let gate_dim = engine.gate_ext_dim();
    let groups_owned: Vec<Vec<Bit>> = (0..n_cts)
        .map(|c| {
            (0..lanes)
                .map(|b| {
                    let v = ((c * 13 + b * 7) % 200) as i64 - 100;
                    Bit::Fhe(LweCiphertext::trivial((v << VALUE_POS) as u32, gate_dim))
                })
                .collect()
        })
        .collect();
    let groups: Vec<(&[Bit], &[usize])> =
        groups_owned.iter().map(|g| (g.as_slice(), positions.as_slice())).collect();
    engine.serial_switch = true;
    let t_up_serial = time_op(iters, || {
        let out = engine.switch_up_many(&groups);
        std::hint::black_box(out[0].fhe().level);
    });
    engine.serial_switch = false;
    let _ = engine.switch_up_many(&groups);
    let t_up_pooled = time_op(iters, || {
        let out = engine.switch_up_many(&groups);
        std::hint::black_box(out[0].fhe().level);
    });

    let down_speedup = t_down_serial / t_down_pooled;
    let up_speedup = t_up_serial / t_up_pooled;
    println!(
        "down-switch: serial {:.4}s ({:.1} lanes/s)  pooled {:.4}s ({:.1} lanes/s)  {:.2}x",
        t_down_serial,
        total_lanes / t_down_serial,
        t_down_pooled,
        total_lanes / t_down_pooled,
        down_speedup
    );
    println!(
        "up-switch:   serial {:.4}s ({:.1} lanes/s)  pooled {:.4}s ({:.1} lanes/s)  {:.2}x",
        t_up_serial,
        total_lanes / t_up_serial,
        t_up_pooled,
        total_lanes / t_up_pooled,
        up_speedup
    );

    let per_lane = total_lanes;
    let threads = max_threads();
    let records = vec![
        BenchRecord::new("down_switch_lane_serial", t_down_serial / per_lane, 1),
        BenchRecord::new("down_switch_lane_pooled", t_down_pooled / per_lane, threads),
        BenchRecord::new("up_switch_lane_serial", t_up_serial / per_lane, 1),
        BenchRecord::new("up_switch_lane_pooled", t_up_pooled / per_lane, threads),
    ];
    report_json_with_counters(
        "switch",
        &records,
        &[
            ("cts", n_cts as u64),
            ("lanes_per_ct", lanes as u64),
            ("down_speedup_x100", (down_speedup * 100.0) as u64),
            ("up_speedup_x100", (up_speedup * 100.0) as u64),
        ],
    );
}
