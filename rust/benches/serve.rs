//! Serve-layer throughput: end-to-end jobs/sec through a real in-process
//! server over loopback TCP (submit → worker → result), and checkpoint
//! persistence bandwidth (capture+save / load+restore MB/s) on a
//! paper-shaped clear MLP. Emits `bench_out/BENCH_serve.json`.

use glyph::bench_util::{report_json_with_counters, time_once, BenchRecord};
use glyph::math::GlyphRng;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::serve::job::weights_digest;
use glyph::serve::{JobSpec, JobState, RunningServer, ServeClient, ServeConfig};
use glyph::train::{GlyphMlp, MlpConfig};
use glyph::wire::{write_atomic, Checkpoint, WireCodec};
use std::time::Duration;

/// Round-trip N tiny clear jobs through the server; returns secs/job.
fn jobs_per_sec(workers: usize, jobs: usize) -> f64 {
    let server = RunningServer::start(ServeConfig {
        addr: "127.0.0.1:0".into(),
        data_dir: None,
        workers,
    })
    .expect("server starts");
    let mut client = ServeClient::connect(server.addr()).expect("connects");

    let secs = time_once(|| {
        let ids: Vec<u64> = (0..jobs)
            .map(|i| {
                let mut spec = JobSpec::small_clear("bench", 1000 + i as u64);
                spec.samples = 8; // 2 steps per job
                spec.checkpoint_every = 0;
                client.submit(&spec).expect("submit")
            })
            .collect();
        for id in ids {
            let st = client.wait(id, Duration::from_secs(600)).expect("job finishes");
            assert_eq!(st.state, JobState::Completed, "{}", st.message);
        }
    });
    server.shutdown();
    server.wait();
    secs / jobs as f64
}

/// Checkpoint save/load bandwidth on a paper-shaped (196-64-10) clear MLP.
fn checkpoint_bandwidth() -> (f64, f64, u64) {
    let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 8);
    let config = || MlpConfig::for_dims(vec![196, 64, 10], EngineProfile::Test.frac_bits(), 8);
    let mut rng = GlyphRng::new(7);
    let mlp = GlyphMlp::new_random(config(), &mut codec, &mut rng, &engine).expect("builds");

    let dir = std::env::temp_dir().join(format!("glyph-bench-serve-{}", std::process::id()));
    let path = dir.join("checkpoint.bin");
    let save_secs = time_once(|| {
        let ckpt = Checkpoint::capture(&mlp.net, &engine, 7, 0, 1, 0.0, None).expect("captures");
        write_atomic(&path, &ckpt.to_wire()).expect("writes");
    });
    let bytes = std::fs::metadata(&path).expect("checkpoint written").len();

    let mut rng2 = GlyphRng::new(8);
    let mut mlp2 = GlyphMlp::new_random(config(), &mut codec, &mut rng2, &engine).expect("builds");
    let load_secs = time_once(|| {
        let raw = std::fs::read(&path).expect("reads");
        let ckpt = Checkpoint::from_wire(&raw, &engine).expect("decodes");
        ckpt.restore(&mut mlp2.net, &engine).expect("restores");
    });
    assert_eq!(weights_digest(&mlp2.net), weights_digest(&mlp.net), "restore must be exact");
    let _ = std::fs::remove_dir_all(&dir);
    (save_secs, load_secs, bytes)
}

fn main() {
    let jobs = 8;
    eprintln!("serve bench: {jobs} clear jobs through a loopback server, then checkpoint i/o");

    let secs_1w = jobs_per_sec(1, jobs);
    let secs_2w = jobs_per_sec(2, jobs);
    println!("jobs/sec: {:.1} (1 worker), {:.1} (2 workers)", 1.0 / secs_1w, 1.0 / secs_2w);

    let (save_secs, load_secs, bytes) = checkpoint_bandwidth();
    let mb = bytes as f64 / (1024.0 * 1024.0);
    let save_mbps = mb / save_secs;
    let load_mbps = mb / load_secs;
    println!(
        "checkpoint: {bytes} bytes, save {save_mbps:.0} MB/s, load {load_mbps:.0} MB/s \
         (capture/restore + frame codec included)"
    );

    report_json_with_counters(
        "serve",
        &[
            BenchRecord::new("job_clear_2step_1worker", secs_1w, 1),
            BenchRecord::new("job_clear_2step_2workers", secs_2w, 2),
            BenchRecord::new("checkpoint_save", save_secs, 1),
            BenchRecord::new("checkpoint_load", load_secs, 1),
        ],
        &[
            ("jobs_completed", (2 * jobs) as u64),
            ("checkpoint_bytes", bytes),
            ("checkpoint_save_mb_per_s", save_mbps as u64),
            ("checkpoint_load_mb_per_s", load_mbps as u64),
        ],
    );
}
