//! Multi-tenant inference scheduling throughput: four lane-compatible
//! batch-2 jobs scored serially (one engine per job, the pre-coalescing
//! worker behavior) against the same four jobs coalesced into one shared
//! batch group at width 8 — plus the solo batch-1 interactive floor and
//! the solo packed path for context. One tenant's sample count is ragged,
//! so the group's final passes run partially filled and the reported fill
//! ratio is the honest occupancy, not 100%. Each path's amortized
//! seconds-per-image includes engine setup and model build, because that
//! is what a served request actually costs. Emits
//! `bench_out/BENCH_serve_infer.json`. `GLYPH_BENCH_FULL=1` switches the
//! lane to real FHE at the test-profile parameters.

use glyph::bench_util::{full_profile, report_json_with_counters, time_once, BenchRecord};
use glyph::coordinator::max_threads;
use glyph::nn::engine::EngineProfile;
use glyph::serve::{run_infer_group, run_infer_job, InferOutcome, InferSpec, JobBackend, JobHandle};

const TENANTS: usize = 4;

fn spec(tenant: &str, batch: u64, samples: u64, packed: bool) -> InferSpec {
    let mut s = InferSpec::small_clear(tenant, 20260808);
    if full_profile() {
        s.backend = JobBackend::Fhe;
        s.profile = EngineProfile::Test;
        s.dims = vec![8, 6, 3];
    }
    s.batch = batch;
    s.samples = samples;
    s.packed = packed;
    s.coalesce = true;
    s
}

/// Score one spec solo; returns (seconds, images).
fn solo(spec: &InferSpec) -> (f64, u64) {
    let handle = JobHandle::new_infer(1, spec.clone());
    let mut images = 0;
    let secs = time_once(|| {
        match run_infer_job(&handle, None).expect("solo bench run") {
            InferOutcome::Completed(result) => images = result.images,
            InferOutcome::Cancelled => panic!("bench job reported cancelled"),
        }
    });
    (secs, images)
}

fn main() {
    let full = full_profile();
    // Per-tenant sample counts; the last is ragged so the coalesced group's
    // tail passes run with vacant slots.
    let samples: Vec<u64> = if full { vec![4, 4, 4, 3] } else { vec![16, 16, 16, 15] };
    let batch = 2;
    eprintln!(
        "serve_infer bench: {TENANTS} batch-{batch} tenants, {} backend",
        if full { "FHE (test profile)" } else { "clear" }
    );

    // Interactive floor and solo packed amortization, for context.
    let (secs_b1, images_b1) = solo(&spec("floor", 1, samples[0], false));
    let packed_batch = batch * TENANTS as u64;
    let (secs_packed, images_packed) =
        solo(&spec("packed", packed_batch, samples[0].max(packed_batch), true));

    // Serial: one engine + model build per tenant, the old worker behavior.
    let specs: Vec<InferSpec> = (0..TENANTS)
        .map(|i| spec(&format!("tenant{i}"), batch, samples[i], false))
        .collect();
    let mut serial_images = 0;
    let mut serial_secs = 0.0;
    for s in &specs {
        let (secs, images) = solo(s);
        serial_secs += secs;
        serial_images += images;
    }

    // Coalesced: the same four jobs in one shared batch group at width 8.
    let handles: Vec<JobHandle> =
        specs.iter().enumerate().map(|(i, s)| JobHandle::new_infer(i as u64 + 1, s.clone())).collect();
    let refs: Vec<&JobHandle> = handles.iter().collect();
    let mut group_images = 0;
    let mut fill = 0.0;
    let group_secs = time_once(|| {
        let (outcomes, stats) = run_infer_group(&refs, None, 1).expect("coalesced bench run");
        for (id, outcome) in &outcomes {
            assert!(
                matches!(outcome, InferOutcome::Completed(_)),
                "coalesced member {id} did not complete"
            );
        }
        group_images = stats.images;
        fill = stats.filled_slots as f64 / stats.total_slots.max(1) as f64;
    });
    assert_eq!(group_images, serial_images, "coalescing must score the same images");
    let speedup = (serial_secs / serial_images as f64) / (group_secs / group_images as f64);

    let threads = max_threads();
    println!(
        "serve_infer: batch-1 {:.2} images/sec  packed {:.2}  serial-4x {:.2}  \
         coalesced-4x {:.2}  fill {:.0}%  coalescing speedup {speedup:.2}x",
        images_b1 as f64 / secs_b1,
        images_packed as f64 / secs_packed,
        serial_images as f64 / serial_secs,
        group_images as f64 / group_secs,
        fill * 100.0,
    );
    if speedup < 2.0 {
        eprintln!("warning: coalescing speedup {speedup:.2}x below the 2x target");
    }

    report_json_with_counters(
        "serve_infer",
        &[
            // secs_per_op = amortized seconds per IMAGE, so ops_per_sec = images/sec
            BenchRecord::new("per_image_solo_batch1", secs_b1 / images_b1 as f64, threads),
            BenchRecord::new(
                "per_image_solo_packed",
                secs_packed / images_packed as f64,
                threads,
            ),
            BenchRecord::new(
                "per_image_serial_4tenant",
                serial_secs / serial_images as f64,
                threads,
            ),
            BenchRecord::new(
                "per_image_coalesced_4tenant",
                group_secs / group_images as f64,
                threads,
            ),
        ],
        &[
            ("tenants", TENANTS as u64),
            ("images_total", serial_images),
            ("coalesced_fill_ratio_pct", (fill * 100.0).round() as u64),
            ("coalesced_speedup_pct", (speedup * 100.0).round() as u64),
        ],
    );
}
