//! The job runner: one [`JobSpec`] → a deterministic, checkpoint-resumable
//! training run.
//!
//! Everything the run touches derives from the spec's seed: dataset
//! synthesis, weight initialization, key generation, encryption noise.
//! [`run_job`] therefore *rebuilds* the engine and network from the spec on
//! every invocation; if a checkpoint exists in the job directory it then
//! overwrites the trained weights, reloads the op counters and repositions
//! the RNG cursors, and re-enters the epoch loop at the recorded step. The
//! invariant (locked by `tests/serve_resume.rs`): a run interrupted at any
//! checkpoint boundary and resumed in a fresh process produces final
//! weights, logits and op counters byte-identical to an uninterrupted run.

use super::lock_clean;
use super::protocol::{
    InferResult, InferSpec, JobBackend, JobKind, JobResult, JobSpec, JobState, JobStatus,
};
use crate::coordinator::metrics::OpSnapshot;
use crate::coordinator::scheduler::Plan;
use crate::data::{DataError, Dataset};
use crate::math::GlyphRng;
use crate::nn::backend::{ClearCodec, Codec};
use crate::nn::engine::{ClientKeys, GlyphEngine};
use crate::nn::linear::Weight;
use crate::nn::network::{Network, NetworkError};
use crate::nn::tensor::PackedLayout;
use crate::train::infer::argmax_rows;
use crate::train::{GlyphMlp, InferError, InferenceSession, MlpConfig, Trainer};
use crate::wire::{fnv1a64, write_atomic, Checkpoint, WireCodec, WireError, WireWriter};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Why a job could not run (worker-side; the server relays the message in
/// the job's `Failed` status).
#[derive(Debug)]
pub enum JobError {
    Spec(String),
    Network(NetworkError),
    Data(DataError),
    Wire(WireError),
    Io(std::io::Error),
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Spec(msg) => write!(f, "invalid job spec: {msg}"),
            JobError::Network(e) => write!(f, "network build failed: {e}"),
            JobError::Data(e) => write!(f, "dataset error: {e}"),
            JobError::Wire(e) => write!(f, "checkpoint error: {e}"),
            JobError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for JobError {}

impl From<NetworkError> for JobError {
    fn from(e: NetworkError) -> Self {
        JobError::Network(e)
    }
}

impl From<DataError> for JobError {
    fn from(e: DataError) -> Self {
        JobError::Data(e)
    }
}

impl From<WireError> for JobError {
    fn from(e: WireError) -> Self {
        JobError::Wire(e)
    }
}

impl From<std::io::Error> for JobError {
    fn from(e: std::io::Error) -> Self {
        JobError::Io(e)
    }
}

impl From<InferError> for JobError {
    fn from(e: InferError) -> Self {
        match e {
            InferError::Network(e) => JobError::Network(e),
            InferError::Wire(e) => JobError::Wire(e),
            InferError::Data(e) => JobError::Data(e),
            InferError::Import(msg) => JobError::Spec(msg),
        }
    }
}

/// What a queued job will run: a training spec or an inference spec. The
/// queue, worker pool, persistence layout and status surface are shared;
/// only the runner entry point differs.
#[derive(Clone, Debug)]
pub enum JobPayload {
    Train(JobSpec),
    Infer(InferSpec),
}

impl JobPayload {
    pub fn kind(&self) -> JobKind {
        match self {
            JobPayload::Train(_) => JobKind::Train,
            JobPayload::Infer(_) => JobKind::Infer,
        }
    }

    pub fn tenant(&self) -> &str {
        match self {
            JobPayload::Train(s) => &s.tenant,
            JobPayload::Infer(s) => &s.tenant,
        }
    }
}

/// Shared server↔worker view of one job.
pub struct JobHandle {
    pub id: u64,
    pub payload: JobPayload,
    /// Set by `cancel` requests; the runner checks it between chunks.
    pub cancel: AtomicBool,
    status: Mutex<JobStatus>,
}

impl JobHandle {
    pub fn new(id: u64, spec: JobSpec) -> JobHandle {
        let total_steps = spec.epochs * planned_steps_per_epoch(&spec);
        JobHandle::with_payload(id, JobPayload::Train(spec), total_steps)
    }

    pub fn new_infer(id: u64, spec: InferSpec) -> JobHandle {
        // ceiling, not floor: the ragged final minibatch is scored through
        // occupancy masks, so it counts as a (partially filled) step
        let total_steps = spec.samples.div_ceil(spec.batch.max(1));
        JobHandle::with_payload(id, JobPayload::Infer(spec), total_steps)
    }

    fn with_payload(id: u64, payload: JobPayload, total_steps: u64) -> JobHandle {
        let status = JobStatus {
            id,
            tenant: payload.tenant().to_string(),
            kind: payload.kind(),
            state: JobState::Queued,
            epoch: 0,
            step: 0,
            total_steps,
            checkpoints: 0,
            resumes: 0,
            live_ops: OpSnapshot::default(),
            predicted_ops: OpSnapshot::default(),
            images: 0,
            seconds: 0.0,
            group: 0,
            message: String::new(),
        };
        JobHandle { id, payload, cancel: AtomicBool::new(false), status: Mutex::new(status) }
    }

    /// The training spec, if this is a training job.
    pub fn train_spec(&self) -> Option<&JobSpec> {
        match &self.payload {
            JobPayload::Train(s) => Some(s),
            JobPayload::Infer(_) => None,
        }
    }

    /// The inference spec, if this is an inference job.
    pub fn infer_spec(&self) -> Option<&InferSpec> {
        match &self.payload {
            JobPayload::Train(_) => None,
            JobPayload::Infer(s) => Some(s),
        }
    }

    pub fn status(&self) -> JobStatus {
        lock_clean(&self.status).clone()
    }

    pub fn update<F: FnOnce(&mut JobStatus)>(&self, f: F) {
        f(&mut lock_clean(&self.status));
    }
}

/// Steps per epoch the spec implies before the dataset is loaded (the
/// loaded dataset can only shrink this, and loaders honour `samples`).
fn planned_steps_per_epoch(spec: &JobSpec) -> u64 {
    let from_data = spec.samples / spec.batch.max(1);
    if spec.steps_per_epoch > 0 {
        spec.steps_per_epoch.min(from_data)
    } else {
        from_data
    }
}

/// Worker-side run options. The default runs to completion; tests inject a
/// halt to simulate a crash at an exact checkpoint boundary.
#[derive(Default)]
pub struct RunOptions {
    /// Stop (returning [`RunOutcome::Halted`]) after this many checkpoints
    /// have been written *by this invocation*.
    pub halt_after_checkpoints: Option<u64>,
}

/// How a [`run_job`] invocation ended.
#[derive(Debug)]
pub enum RunOutcome {
    Completed(JobResult),
    Cancelled,
    /// `RunOptions::halt_after_checkpoints` fired (tests only).
    Halted,
}

enum JobCodec {
    Clear(ClearCodec),
    Fhe(ClientKeys),
}

impl JobCodec {
    fn as_dyn(&mut self) -> &mut dyn Codec {
        match self {
            JobCodec::Clear(c) => c,
            JobCodec::Fhe(c) => c,
        }
    }
}

fn load_dataset(dataset: &str, train_split: bool, count: usize, seed: u64) -> Result<Dataset, JobError> {
    Ok(match dataset {
        "digits" => crate::data::synthetic_digits(count, seed, "serve"),
        // real IDX files ignore the seed; evaluation must read the held-out
        // split, not a train-set prefix
        "mnist" => crate::data::mnist(train_split, count, seed),
        "cancer" => crate::data::synthetic_cancer(count, seed),
        "svhn" => crate::data::synthetic_svhn(count, seed),
        "cifar" => crate::data::synthetic_cifar(count, seed),
        other => return Err(JobError::Spec(format!("unknown dataset {other:?}"))),
    })
}

/// The spec's derived MLP config (shared with plan compilation so the
/// server prices exactly what the worker executes).
pub fn job_config(spec: &JobSpec) -> Result<MlpConfig, JobError> {
    spec.validate().map_err(JobError::Spec)?;
    let dims: Vec<usize> = spec.dims.iter().map(|&d| d as usize).collect();
    Ok(MlpConfig::for_dims(dims, spec.profile.frac_bits(), spec.softmax_bits as usize))
}

/// The inference spec's derived MLP config (same shape contract).
pub fn infer_config(spec: &InferSpec) -> Result<MlpConfig, JobError> {
    spec.validate().map_err(JobError::Spec)?;
    let dims: Vec<usize> = spec.dims.iter().map(|&d| d as usize).collect();
    Ok(MlpConfig::for_dims(dims, spec.profile.frac_bits(), spec.softmax_bits as usize))
}

/// Shape-only plan compilation for a spec (submit-time validation + the
/// metrics endpoint's per-step prediction; no keys are generated).
pub fn compiled_plan(spec: &JobSpec) -> Result<Plan, JobError> {
    job_config(spec)?.builder()?.compile(spec.batch as usize).map_err(JobError::Network)
}

/// Forward-only plan compilation for an inference spec: the full training
/// plan's forward prefix, which is exactly what one scored minibatch costs.
pub fn compiled_infer_plan(spec: &InferSpec) -> Result<Plan, JobError> {
    Ok(infer_config(spec)?
        .builder()?
        .compile(spec.batch as usize)
        .map_err(JobError::Network)?
        .forward_only())
}

/// FNV-1a over the canonical wire encoding of every trainable weight
/// ciphertext, in layer/row/column order — the byte-identity witness two
/// runs are compared by.
pub fn weights_digest(net: &Network) -> u64 {
    let mut buf = Vec::new();
    for (_, fc) in net.fc_units() {
        if !fc.is_trainable() {
            continue;
        }
        for row in &fc.w {
            for wt in row {
                if let Weight::Enc(ct) = wt {
                    buf.extend_from_slice(&ct.to_wire());
                }
            }
        }
    }
    fnv1a64(&buf)
}

fn logits_digest(rows: &[Vec<i64>]) -> u64 {
    let mut w = WireWriter::new();
    w.put_len(rows.len());
    for row in rows {
        w.put_i64s(row);
    }
    fnv1a64(&w.into_bytes())
}

/// Test-support pacing knob: sleep this many milliseconds per trained step
/// so crash-recovery tests can reliably land a `kill -9` mid-run. Unset or
/// 0 in production.
fn step_delay_ms() -> u64 {
    std::env::var("GLYPH_SERVE_STEP_DELAY_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// Test-support fault injection: `GLYPH_SERVE_PANIC_ONCE=<step>` makes the
/// first job to reach that global step panic mid-run, exactly once per
/// process. The hardening tests use it to prove a worker panic degrades one
/// job to `Failed` while the server keeps answering. Unset in production.
static PANIC_FIRED: AtomicBool = AtomicBool::new(false);

fn maybe_panic_once(global: u64) {
    let Some(at) = std::env::var("GLYPH_SERVE_PANIC_ONCE").ok().and_then(|v| v.parse::<u64>().ok())
    else {
        return;
    };
    if global >= at && !PANIC_FIRED.swap(true, Ordering::SeqCst) {
        panic!("injected fault: GLYPH_SERVE_PANIC_ONCE fired at step {global}");
    }
}

/// The checkpoint file inside a job directory.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join("checkpoint.bin")
}

/// The persisted final model inside a completed training job's directory
/// (a [`Checkpoint`] frame captured after the last step; what inference
/// jobs load via `model_job`).
pub fn model_path(dir: &Path) -> PathBuf {
    dir.join("model.bin")
}

/// Run (or resume) a job. `dir` is the job's persistence directory — with
/// `None` the run is purely in-memory (no checkpoints are read or
/// written). Returns the outcome; job state transitions are published
/// through `handle`.
pub fn run_job(
    handle: &JobHandle,
    dir: Option<&Path>,
    opts: &RunOptions,
) -> Result<RunOutcome, JobError> {
    let spec = handle
        .train_spec()
        .ok_or_else(|| JobError::Spec("run_job invoked on a non-training job".into()))?;
    let config = job_config(spec)?;
    let batch = spec.batch as usize;
    // `job_config` validated dims above, but never panic on a malformed
    // spec — a worker thread's panic must not be reachable from user input
    let classes = *spec
        .dims
        .last()
        .ok_or_else(|| JobError::Spec("dims is empty: no output layer width".into()))?
        as usize;

    // Engine + codec. Keygen (FHE) is deterministic from the spec seed, so
    // a resumed run regenerates the identical key material.
    let (engine, mut codec) = match spec.backend {
        JobBackend::Clear => {
            let (e, c) = GlyphEngine::setup_clear(spec.profile, batch);
            (e, JobCodec::Clear(c))
        }
        JobBackend::Fhe => {
            let (e, c) = GlyphEngine::setup(spec.profile, batch, spec.seed);
            (e, JobCodec::Fhe(c))
        }
    };

    // Datasets: split seeds derive from the job seed.
    let train = load_dataset(&spec.dataset, true, spec.samples as usize, spec.seed ^ 0x7261)?;
    let eval_n = if spec.eval_samples > 0 {
        spec.eval_samples as usize
    } else {
        ((spec.samples / 4) as usize).max(batch)
    };
    let test = load_dataset(&spec.dataset, false, eval_n, spec.seed ^ 0x7465)?;
    // Real IDX loaders can return fewer rows than requested; never ask
    // evaluation to score past the loaded set's end, and refuse (typed, not
    // a downstream panic) when what loaded cannot fill one minibatch.
    let eval_n = eval_n.min(test.len());
    if eval_n < batch {
        return Err(JobError::Spec(format!(
            "evaluation set {} holds {} samples, fewer than one minibatch of {batch}",
            test.name,
            test.len()
        )));
    }

    // Network: initial weight draws and their encryptions replay the
    // original build exactly (same seeds), then a checkpoint — if any —
    // overwrites the trained state.
    let mut rng = GlyphRng::new(spec.seed ^ 0xb11d);
    let mlp = GlyphMlp::new_random(config, codec.as_dyn(), &mut rng, &engine)?;
    let mut trainer = Trainer::new(mlp.net, classes);

    let spe = planned_steps_per_epoch(spec).min((train.len() / batch) as u64);
    if spe == 0 {
        return Err(JobError::Spec(format!(
            "dataset {} yields no full minibatch of {batch}",
            train.name
        )));
    }
    let total = spec.epochs * spe;
    let ce = spec.checkpoint_every;

    // Resume from the latest checkpoint, if the job directory holds one.
    let ckpt_path = dir.map(checkpoint_path);
    let mut global: u64 = 0;
    let mut seconds: f64 = 0.0;
    if let Some(path) = ckpt_path.as_ref().filter(|p| p.exists()) {
        let bytes = std::fs::read(path)?;
        let ckpt = Checkpoint::from_wire(&bytes, &engine)?;
        if ckpt.job_seed != spec.seed {
            return Err(JobError::Spec(format!(
                "checkpoint in {} belongs to a job with seed {}, this job's seed is {}",
                path.display(),
                ckpt.job_seed,
                spec.seed
            )));
        }
        ckpt.restore(&mut trainer.net, &engine)?;
        if let JobCodec::Fhe(ck) = &mut codec {
            let state = ckpt.client_rng.ok_or_else(|| {
                JobError::Spec("FHE checkpoint is missing the client RNG cursor".into())
            })?;
            ck.rng = GlyphRng::from_state(state);
        }
        global = ckpt.step.min(total);
        seconds = ckpt.seconds;
        handle.update(|st| st.resumes += 1);
    }

    let per_step = trainer.net.plan.totals().to_snapshot();
    let publish = |st_global: u64, live: OpSnapshot| {
        handle.update(|st| {
            st.state = JobState::Running;
            st.step = st_global;
            st.epoch = st_global / spe;
            st.total_steps = total;
            st.checkpoints = if ce > 0 { st_global / ce } else { 0 };
            st.live_ops = live;
            st.predicted_ops = per_step.scale(st_global);
        });
    };
    publish(global, engine.counter.snapshot());

    let delay = step_delay_ms();
    let mut written_this_run = 0u64;
    while global < total {
        if handle.cancel.load(Ordering::Relaxed) {
            handle.update(|st| st.state = JobState::Cancelled);
            return Ok(RunOutcome::Cancelled);
        }
        let idx = global % spe;
        let mut chunk = (spe - idx).min(total - global);
        if ce > 0 {
            chunk = chunk.min(ce - global % ce);
        }
        let stats =
            trainer.train_range(&train, idx as usize, chunk as usize, &engine, codec.as_dyn())?;
        if stats.steps == 0 {
            return Err(JobError::Spec("training made no progress (dataset too small?)".into()));
        }
        global += stats.steps as u64;
        seconds += stats.seconds;
        if delay > 0 {
            std::thread::sleep(std::time::Duration::from_millis(delay * stats.steps as u64));
        }
        maybe_panic_once(global);
        publish(global, engine.counter.snapshot());

        if ce > 0 && global % ce == 0 && global < total {
            if let Some(path) = &ckpt_path {
                let client_rng = match &codec {
                    JobCodec::Fhe(ck) => Some(ck.rng.state()),
                    JobCodec::Clear(_) => None,
                };
                let ckpt = Checkpoint::capture(
                    &trainer.net,
                    &engine,
                    spec.seed,
                    global / spe,
                    global,
                    seconds,
                    client_rng,
                )?;
                write_atomic(path, &ckpt.to_wire())?;
                written_this_run += 1;
                if opts.halt_after_checkpoints == Some(written_this_run) {
                    return Ok(RunOutcome::Halted);
                }
            }
        }
    }

    // Training-only op totals are the SLA signal (plan totals × steps);
    // snapshot them before evaluation adds its forward-pass ops.
    let train_ops = engine.counter.snapshot();

    // Persist the final model so inference jobs (`model_job = this id`)
    // and `glyph infer --model` can serve it after the checkpoint below is
    // deleted. Captured before evaluation so its op counters are the
    // training-only totals.
    if let Some(d) = dir {
        let client_rng = match &codec {
            JobCodec::Fhe(ck) => Some(ck.rng.state()),
            JobCodec::Clear(_) => None,
        };
        let model = Checkpoint::capture(
            &trainer.net,
            &engine,
            spec.seed,
            spec.epochs,
            total,
            seconds,
            client_rng,
        )?;
        write_atomic(&model_path(d), &model.to_wire())?;
    }

    let scores = trainer.eval_scores(&test, eval_n, &engine, codec.as_dyn())?;
    let mut correct = 0usize;
    for (i, row) in scores.iter().enumerate() {
        let best = row.iter().enumerate().max_by_key(|&(k, &v)| (v, std::cmp::Reverse(k)));
        if best.map(|(k, _)| k) == Some(test.labels[i] % classes) {
            correct += 1;
        }
    }
    let result = JobResult {
        id: handle.id,
        steps: total,
        seconds,
        accuracy: correct as f64 / scores.len() as f64,
        ops: train_ops,
        weights_digest: weights_digest(&trainer.net),
        logits_digest: logits_digest(&scores),
        resumes: handle.status().resumes,
    };
    handle.update(|st| {
        st.state = JobState::Completed;
        st.step = total;
        st.epoch = spec.epochs;
        st.live_ops = train_ops;
        st.predicted_ops = per_step.scale(total);
    });
    Ok(RunOutcome::Completed(result))
}

/// How a [`run_infer_job`] invocation ended. Inference has no checkpoints
/// to halt at — a cancelled or crashed job simply re-scores from scratch.
#[derive(Debug)]
pub enum InferOutcome {
    Completed(InferResult),
    Cancelled,
}

fn predictions_digest(labels: &[usize]) -> u64 {
    let mut w = WireWriter::new();
    let as_u64: Vec<u64> = labels.iter().map(|&l| l as u64).collect();
    w.put_u64s(&as_u64);
    fnv1a64(&w.into_bytes())
}

/// Run an inference job solo: a coalesced group of one. `dir` is the
/// *job's* persistence directory; the model referenced by `spec.model_job`
/// is read from the sibling directory `../<model_job>/model.bin` (written
/// by [`run_job`] at training completion). With `model_job == 0` the model
/// is fresh deterministic random init — a latency/conformance probe where
/// only op counts and timing matter.
pub fn run_infer_job(handle: &JobHandle, dir: Option<&Path>) -> Result<InferOutcome, JobError> {
    handle
        .infer_spec()
        .ok_or_else(|| JobError::Spec("run_infer_job invoked on a non-inference job".into()))?;
    let jobs_root = dir.and_then(Path::parent);
    let (mut outcomes, _) = run_infer_group(&[handle], jobs_root, 0)?;
    Ok(outcomes.remove(0).1)
}

/// Occupancy accounting for one coalesced batch group, feeding the
/// per-lane fill-ratio and amortized-latency gauges.
#[derive(Clone, Copy, Debug, Default)]
pub struct GroupStats {
    /// Shared forward passes executed.
    pub passes: u64,
    /// Slots that carried a real image, summed over passes.
    pub filled_slots: u64,
    /// Slots available (`passes × group width`).
    pub total_slots: u64,
    /// Wall-clock spent inside shared passes.
    pub seconds: f64,
    /// Real images scored across all members.
    pub images: u64,
}

/// Per-member scoring state inside a coalesced group.
struct GroupMember<'a> {
    handle: &'a JobHandle,
    ds: Dataset,
    /// Real images this member will score (loader may return fewer than
    /// `spec.samples`; padding slots are never counted).
    total: usize,
    chunks: u64,
    cursor: usize,
    step: u64,
    rows: Vec<Vec<i64>>,
    seconds: f64,
    live_share: OpSnapshot,
    predicted_share: OpSnapshot,
    cancelled: bool,
}

/// Score a *batch group*: `handles` are lane-compatible inference jobs
/// (identical [`InferSpec::lane_label`]; tenant and sample count may
/// differ) coalesced into one engine of width `members × batch`. Member
/// `j` owns the contiguous slot window `[j·batch, (j+1)·batch)`; every
/// shared forward pass fills each active member's window from its own
/// dataset cursor (occupancy masks for ragged tails and finished/cancelled
/// members) and de-interleaves the per-slot logit rows back to their
/// owners. Because the per-lane forward pipeline never mixes batch lanes,
/// each occupied slot's row is byte-identical to a solo run of the same
/// sample.
///
/// Op accounting stays exact: each pass is checked against the compiled
/// plan's forward totals (at group width), then the live delta *and* the
/// plan prediction are split among that pass's active members with the
/// same telescoping proportional shares — per-member live−predicted drift
/// is zero by construction, and member shares reconstruct the group total
/// counter for counter.
///
/// Returns one `(job id, outcome)` per member in input order, plus the
/// group's occupancy stats. A member cancelled mid-group vacates its slots
/// while the others continue.
pub fn run_infer_group(
    handles: &[&JobHandle],
    jobs_root: Option<&Path>,
    group: u64,
) -> Result<(Vec<(u64, InferOutcome)>, GroupStats), JobError> {
    let first = handles
        .first()
        .ok_or_else(|| JobError::Spec("empty batch group".into()))?
        .infer_spec()
        .ok_or_else(|| JobError::Spec("batch group contains a non-inference job".into()))?;
    for h in handles {
        let spec = h
            .infer_spec()
            .ok_or_else(|| JobError::Spec("batch group contains a non-inference job".into()))?;
        if spec.lane_label() != first.lane_label() {
            return Err(JobError::Spec(format!(
                "job {} (lane {}) cannot share a batch group with lane {}",
                h.id,
                spec.lane_label(),
                first.lane_label()
            )));
        }
    }
    let config = infer_config(first)?;
    let batch = first.batch as usize;
    let width = handles.len() * batch;
    let classes = *first
        .dims
        .last()
        .ok_or_else(|| JobError::Spec("dims is empty: no output layer width".into()))?
        as usize;

    // Engine + codec at group width. On FHE the spec seed must be the
    // *training* seed — the model's weight ciphertexts only decrypt under
    // that key material. Weights are constant polynomials, so one model
    // build serves every batch width.
    let (mut engine, mut codec) = match first.backend {
        JobBackend::Clear => {
            let (e, c) = GlyphEngine::setup_clear(first.profile, width);
            (e, JobCodec::Clear(c))
        }
        JobBackend::Fhe => {
            let (e, c) = GlyphEngine::setup(first.profile, width, first.seed);
            (e, JobCodec::Fhe(c))
        }
    };
    if first.packed {
        // pre-check the layout fit so an oversized group is a typed error,
        // not an `enable_packing` panic escaping the worker
        PackedLayout::for_ring(width, engine.params().n).map_err(|e| {
            JobError::Spec(format!("batch group of {width} slots cannot pack: {e}"))
        })?;
        engine.enable_packing();
    }

    let session = if first.model_job == 0 {
        let mut rng = GlyphRng::new(first.seed ^ 0xb11d);
        let mlp = GlyphMlp::new_random(config, codec.as_dyn(), &mut rng, &engine)?;
        InferenceSession::from_network(mlp.net, classes)
    } else {
        let root = jobs_root
            .ok_or_else(|| JobError::Spec("model_job requires a persistent data dir".into()))?;
        let path = model_path(&root.join(first.model_job.to_string()));
        let bytes = std::fs::read(&path).map_err(|e| {
            JobError::Spec(format!(
                "model of job {} not found ({}): {e}",
                first.model_job,
                path.display()
            ))
        })?;
        let ckpt = Checkpoint::from_wire(&bytes, &engine)?;
        InferenceSession::from_checkpoint(config, &ckpt, first.seed, codec.as_dyn(), &engine)?
    };
    let features = session.features();

    // Scoring is priced by the forward-only plan; model build/restore ops
    // (weight encryption) are not part of that contract, so the counter
    // starts clean here.
    engine.counter.store(&OpSnapshot::default());
    let per_pass = session.plan().totals().to_snapshot();

    // Held-out splits, same derivation as training evaluation. The lane
    // key pins dataset and seed, so members with different sample counts
    // read prefixes of the same synthetic stream.
    let mut members: Vec<GroupMember<'_>> = Vec::with_capacity(handles.len());
    for &h in handles {
        let spec = h.infer_spec().expect("validated above");
        let ds = load_dataset(&spec.dataset, false, spec.samples as usize, spec.seed ^ 0x7465)?;
        let total = ds.len().min(spec.samples as usize);
        if total == 0 {
            return Err(JobError::Spec(format!("dataset {} loaded no samples", ds.name)));
        }
        let chunks = (total as u64).div_ceil(spec.batch.max(1));
        members.push(GroupMember {
            handle: h,
            ds,
            total,
            chunks,
            cursor: 0,
            step: 0,
            rows: Vec::with_capacity(total),
            seconds: 0.0,
            live_share: OpSnapshot::default(),
            predicted_share: OpSnapshot::default(),
            cancelled: false,
        });
    }
    for m in &members {
        let (chunks, step, images, secs, live, pred) =
            (m.chunks, m.step, m.cursor as u64, m.seconds, m.live_share, m.predicted_share);
        m.handle.update(|st| {
            st.state = JobState::Running;
            st.step = step;
            st.total_steps = chunks;
            st.images = images;
            st.seconds = secs;
            st.live_ops = live;
            st.predicted_ops = pred;
            st.group = group;
        });
    }

    let delay = step_delay_ms();
    let mut stats = GroupStats::default();
    loop {
        for m in &mut members {
            if !m.cancelled && m.handle.cancel.load(Ordering::Relaxed) {
                m.cancelled = true;
                m.handle.update(|st| st.state = JobState::Cancelled);
            }
        }
        let active: Vec<usize> = (0..members.len())
            .filter(|&j| !members[j].cancelled && members[j].cursor < members[j].total)
            .collect();
        if active.is_empty() {
            break;
        }

        // Assemble the shared batch: each active member's window is filled
        // from its cursor, ragged tails padded with vacant (zeroed) slots.
        let mut cols = vec![vec![0i64; width]; features];
        let mut occupied = vec![false; width];
        let mut occ_counts: Vec<(usize, u64)> = Vec::with_capacity(active.len());
        for &j in &active {
            let m = &members[j];
            let (mcols, _labels, mocc) = m.ds.minibatch_padded(m.cursor, batch, features)?;
            for (f, col) in mcols.iter().enumerate() {
                cols[f][j * batch..(j + 1) * batch].copy_from_slice(col);
            }
            occupied[j * batch..(j + 1) * batch].copy_from_slice(&mocc);
            occ_counts.push((j, mocc.iter().filter(|&&o| o).count() as u64));
        }

        let before = engine.counter.snapshot();
        let t0 = std::time::Instant::now();
        let slot_rows = session.scores_slots(&cols, &occupied, &engine, codec.as_dyn())?;
        let pass_secs = t0.elapsed().as_secs_f64();
        let delta = engine.counter.snapshot().since(&before);

        // Plan conformance per pass: a shared pass must cost exactly the
        // compiled forward totals at group width, or attribution would
        // split a number nobody can price.
        let drift = delta.diff_ignoring(&per_pass, &super::metrics::UNPREDICTED_OPS);
        if !drift.is_empty() {
            return Err(JobError::Spec(format!(
                "coalesced pass diverged from the compiled plan: {}",
                OpSnapshot::render_diff(&drift)
            )));
        }

        // Attribution: split the live delta AND the plan prediction with
        // the same telescoping occupied-slot shares, so the member shares
        // reconstruct the group totals exactly and per-member drift is 0.
        let pass_slots: u64 = occ_counts.iter().map(|&(_, c)| c).sum();
        let mut sold = 0u64;
        for &(j, count) in &occ_counts {
            let live = delta.split_share(sold, sold + count, pass_slots);
            let pred = per_pass.split_share(sold, sold + count, pass_slots);
            sold += count;
            let m = &mut members[j];
            for b in 0..count as usize {
                m.rows.push(slot_rows[j * batch + b].clone());
            }
            m.cursor += count as usize;
            m.step += 1;
            m.seconds += pass_secs * count as f64 / pass_slots as f64;
            m.live_share = m.live_share.plus(&live);
            m.predicted_share = m.predicted_share.plus(&pred);
            let (step, images, secs, live, pred) =
                (m.step, m.cursor as u64, m.seconds, m.live_share, m.predicted_share);
            m.handle.update(|st| {
                st.step = step;
                st.images = images;
                st.seconds = secs;
                st.live_ops = live;
                st.predicted_ops = pred;
            });
        }
        stats.passes += 1;
        stats.filled_slots += pass_slots;
        stats.total_slots += width as u64;
        stats.seconds += pass_secs;
        stats.images += pass_slots;
        if delay > 0 {
            std::thread::sleep(std::time::Duration::from_millis(delay));
        }
        maybe_panic_once(stats.passes);
    }

    let mut outcomes = Vec::with_capacity(members.len());
    for m in &members {
        if m.cancelled {
            outcomes.push((m.handle.id, InferOutcome::Cancelled));
            continue;
        }
        let predicted = argmax_rows(&m.rows);
        let correct = predicted
            .iter()
            .zip(&m.ds.labels)
            .filter(|&(&p, &label)| p == label % classes)
            .count();
        let result = InferResult {
            id: m.handle.id,
            // real images only — padding slots in the ragged final batch
            // are vacant lanes, not scored work
            images: m.cursor as u64,
            batches: m.chunks,
            seconds: m.seconds,
            accuracy: correct as f64 / predicted.len().max(1) as f64,
            ops: m.live_share,
            logits_digest: logits_digest(&m.rows),
            predictions_digest: predictions_digest(&predicted),
        };
        let (step, chunks, images, secs, live, pred) =
            (m.step, m.chunks, m.cursor as u64, m.seconds, m.live_share, m.predicted_share);
        m.handle.update(|st| {
            st.state = JobState::Completed;
            st.step = step;
            st.total_steps = chunks;
            st.images = images;
            st.seconds = secs;
            st.live_ops = live;
            st.predicted_ops = pred;
        });
        outcomes.push((m.handle.id, InferOutcome::Completed(result)));
    }
    Ok((outcomes, stats))
}
