//! The job runner: one [`JobSpec`] → a deterministic, checkpoint-resumable
//! training run.
//!
//! Everything the run touches derives from the spec's seed: dataset
//! synthesis, weight initialization, key generation, encryption noise.
//! [`run_job`] therefore *rebuilds* the engine and network from the spec on
//! every invocation; if a checkpoint exists in the job directory it then
//! overwrites the trained weights, reloads the op counters and repositions
//! the RNG cursors, and re-enters the epoch loop at the recorded step. The
//! invariant (locked by `tests/serve_resume.rs`): a run interrupted at any
//! checkpoint boundary and resumed in a fresh process produces final
//! weights, logits and op counters byte-identical to an uninterrupted run.

use super::protocol::{JobBackend, JobResult, JobSpec, JobState, JobStatus};
use crate::coordinator::metrics::OpSnapshot;
use crate::coordinator::scheduler::Plan;
use crate::data::{DataError, Dataset};
use crate::math::GlyphRng;
use crate::nn::backend::{ClearCodec, Codec};
use crate::nn::engine::{ClientKeys, GlyphEngine};
use crate::nn::linear::Weight;
use crate::nn::network::{Network, NetworkError};
use crate::train::{GlyphMlp, MlpConfig, Trainer};
use crate::wire::{fnv1a64, write_atomic, Checkpoint, WireCodec, WireError, WireWriter};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Why a job could not run (worker-side; the server relays the message in
/// the job's `Failed` status).
#[derive(Debug)]
pub enum JobError {
    Spec(String),
    Network(NetworkError),
    Data(DataError),
    Wire(WireError),
    Io(std::io::Error),
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Spec(msg) => write!(f, "invalid job spec: {msg}"),
            JobError::Network(e) => write!(f, "network build failed: {e}"),
            JobError::Data(e) => write!(f, "dataset error: {e}"),
            JobError::Wire(e) => write!(f, "checkpoint error: {e}"),
            JobError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for JobError {}

impl From<NetworkError> for JobError {
    fn from(e: NetworkError) -> Self {
        JobError::Network(e)
    }
}

impl From<DataError> for JobError {
    fn from(e: DataError) -> Self {
        JobError::Data(e)
    }
}

impl From<WireError> for JobError {
    fn from(e: WireError) -> Self {
        JobError::Wire(e)
    }
}

impl From<std::io::Error> for JobError {
    fn from(e: std::io::Error) -> Self {
        JobError::Io(e)
    }
}

/// Shared server↔worker view of one job.
pub struct JobHandle {
    pub id: u64,
    pub spec: JobSpec,
    /// Set by `cancel` requests; the runner checks it between chunks.
    pub cancel: AtomicBool,
    status: Mutex<JobStatus>,
}

impl JobHandle {
    pub fn new(id: u64, spec: JobSpec) -> JobHandle {
        let total_steps = spec.epochs * planned_steps_per_epoch(&spec);
        let status = JobStatus {
            id,
            tenant: spec.tenant.clone(),
            state: JobState::Queued,
            epoch: 0,
            step: 0,
            total_steps,
            checkpoints: 0,
            resumes: 0,
            live_ops: OpSnapshot::default(),
            predicted_ops: OpSnapshot::default(),
            message: String::new(),
        };
        JobHandle { id, spec, cancel: AtomicBool::new(false), status: Mutex::new(status) }
    }

    pub fn status(&self) -> JobStatus {
        self.status.lock().unwrap().clone()
    }

    pub fn update<F: FnOnce(&mut JobStatus)>(&self, f: F) {
        f(&mut self.status.lock().unwrap());
    }
}

/// Steps per epoch the spec implies before the dataset is loaded (the
/// loaded dataset can only shrink this, and loaders honour `samples`).
fn planned_steps_per_epoch(spec: &JobSpec) -> u64 {
    let from_data = spec.samples / spec.batch.max(1);
    if spec.steps_per_epoch > 0 {
        spec.steps_per_epoch.min(from_data)
    } else {
        from_data
    }
}

/// Worker-side run options. The default runs to completion; tests inject a
/// halt to simulate a crash at an exact checkpoint boundary.
#[derive(Default)]
pub struct RunOptions {
    /// Stop (returning [`RunOutcome::Halted`]) after this many checkpoints
    /// have been written *by this invocation*.
    pub halt_after_checkpoints: Option<u64>,
}

/// How a [`run_job`] invocation ended.
#[derive(Debug)]
pub enum RunOutcome {
    Completed(JobResult),
    Cancelled,
    /// `RunOptions::halt_after_checkpoints` fired (tests only).
    Halted,
}

enum JobCodec {
    Clear(ClearCodec),
    Fhe(ClientKeys),
}

impl JobCodec {
    fn as_dyn(&mut self) -> &mut dyn Codec {
        match self {
            JobCodec::Clear(c) => c,
            JobCodec::Fhe(c) => c,
        }
    }
}

fn load_dataset(spec: &JobSpec, train_split: bool, count: usize, seed: u64) -> Result<Dataset, JobError> {
    Ok(match spec.dataset.as_str() {
        "digits" => crate::data::synthetic_digits(count, seed, "serve"),
        // real IDX files ignore the seed; evaluation must read the held-out
        // split, not a train-set prefix
        "mnist" => crate::data::mnist(train_split, count, seed),
        "cancer" => crate::data::synthetic_cancer(count, seed),
        "svhn" => crate::data::synthetic_svhn(count, seed),
        "cifar" => crate::data::synthetic_cifar(count, seed),
        other => return Err(JobError::Spec(format!("unknown dataset {other:?}"))),
    })
}

/// The spec's derived MLP config (shared with plan compilation so the
/// server prices exactly what the worker executes).
pub fn job_config(spec: &JobSpec) -> Result<MlpConfig, JobError> {
    spec.validate().map_err(JobError::Spec)?;
    let dims: Vec<usize> = spec.dims.iter().map(|&d| d as usize).collect();
    Ok(MlpConfig::for_dims(dims, spec.profile.frac_bits(), spec.softmax_bits as usize))
}

/// Shape-only plan compilation for a spec (submit-time validation + the
/// metrics endpoint's per-step prediction; no keys are generated).
pub fn compiled_plan(spec: &JobSpec) -> Result<Plan, JobError> {
    job_config(spec)?.builder()?.compile(spec.batch as usize).map_err(JobError::Network)
}

/// FNV-1a over the canonical wire encoding of every trainable weight
/// ciphertext, in layer/row/column order — the byte-identity witness two
/// runs are compared by.
pub fn weights_digest(net: &Network) -> u64 {
    let mut buf = Vec::new();
    for (_, fc) in net.fc_units() {
        if !fc.is_trainable() {
            continue;
        }
        for row in &fc.w {
            for wt in row {
                if let Weight::Enc(ct) = wt {
                    buf.extend_from_slice(&ct.to_wire());
                }
            }
        }
    }
    fnv1a64(&buf)
}

fn logits_digest(rows: &[Vec<i64>]) -> u64 {
    let mut w = WireWriter::new();
    w.put_len(rows.len());
    for row in rows {
        w.put_i64s(row);
    }
    fnv1a64(&w.into_bytes())
}

/// Test-support pacing knob: sleep this many milliseconds per trained step
/// so crash-recovery tests can reliably land a `kill -9` mid-run. Unset or
/// 0 in production.
fn step_delay_ms() -> u64 {
    std::env::var("GLYPH_SERVE_STEP_DELAY_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// The checkpoint file inside a job directory.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join("checkpoint.bin")
}

/// Run (or resume) a job. `dir` is the job's persistence directory — with
/// `None` the run is purely in-memory (no checkpoints are read or
/// written). Returns the outcome; job state transitions are published
/// through `handle`.
pub fn run_job(
    handle: &JobHandle,
    dir: Option<&Path>,
    opts: &RunOptions,
) -> Result<RunOutcome, JobError> {
    let spec = &handle.spec;
    let config = job_config(spec)?;
    let batch = spec.batch as usize;
    let classes = *spec.dims.last().expect("validated") as usize;

    // Engine + codec. Keygen (FHE) is deterministic from the spec seed, so
    // a resumed run regenerates the identical key material.
    let (engine, mut codec) = match spec.backend {
        JobBackend::Clear => {
            let (e, c) = GlyphEngine::setup_clear(spec.profile, batch);
            (e, JobCodec::Clear(c))
        }
        JobBackend::Fhe => {
            let (e, c) = GlyphEngine::setup(spec.profile, batch, spec.seed);
            (e, JobCodec::Fhe(c))
        }
    };

    // Datasets: split seeds derive from the job seed.
    let train = load_dataset(spec, true, spec.samples as usize, spec.seed ^ 0x7261)?;
    let eval_n = if spec.eval_samples > 0 {
        spec.eval_samples as usize
    } else {
        ((spec.samples / 4) as usize).max(batch)
    };
    let test = load_dataset(spec, false, eval_n, spec.seed ^ 0x7465)?;

    // Network: initial weight draws and their encryptions replay the
    // original build exactly (same seeds), then a checkpoint — if any —
    // overwrites the trained state.
    let mut rng = GlyphRng::new(spec.seed ^ 0xb11d);
    let mlp = GlyphMlp::new_random(config, codec.as_dyn(), &mut rng, &engine)?;
    let mut trainer = Trainer::new(mlp.net, classes);

    let spe = planned_steps_per_epoch(spec).min((train.len() / batch) as u64);
    if spe == 0 {
        return Err(JobError::Spec(format!(
            "dataset {} yields no full minibatch of {batch}",
            train.name
        )));
    }
    let total = spec.epochs * spe;
    let ce = spec.checkpoint_every;

    // Resume from the latest checkpoint, if the job directory holds one.
    let ckpt_path = dir.map(checkpoint_path);
    let mut global: u64 = 0;
    let mut seconds: f64 = 0.0;
    if let Some(path) = ckpt_path.as_ref().filter(|p| p.exists()) {
        let bytes = std::fs::read(path)?;
        let ckpt = Checkpoint::from_wire(&bytes, &engine)?;
        if ckpt.job_seed != spec.seed {
            return Err(JobError::Spec(format!(
                "checkpoint in {} belongs to a job with seed {}, this job's seed is {}",
                path.display(),
                ckpt.job_seed,
                spec.seed
            )));
        }
        ckpt.restore(&mut trainer.net, &engine)?;
        if let JobCodec::Fhe(ck) = &mut codec {
            let state = ckpt.client_rng.ok_or_else(|| {
                JobError::Spec("FHE checkpoint is missing the client RNG cursor".into())
            })?;
            ck.rng = GlyphRng::from_state(state);
        }
        global = ckpt.step.min(total);
        seconds = ckpt.seconds;
        handle.update(|st| st.resumes += 1);
    }

    let per_step = trainer.net.plan.totals().to_snapshot();
    let publish = |st_global: u64, live: OpSnapshot| {
        handle.update(|st| {
            st.state = JobState::Running;
            st.step = st_global;
            st.epoch = st_global / spe;
            st.total_steps = total;
            st.checkpoints = if ce > 0 { st_global / ce } else { 0 };
            st.live_ops = live;
            st.predicted_ops = per_step.scale(st_global);
        });
    };
    publish(global, engine.counter.snapshot());

    let delay = step_delay_ms();
    let mut written_this_run = 0u64;
    while global < total {
        if handle.cancel.load(Ordering::Relaxed) {
            handle.update(|st| st.state = JobState::Cancelled);
            return Ok(RunOutcome::Cancelled);
        }
        let idx = global % spe;
        let mut chunk = (spe - idx).min(total - global);
        if ce > 0 {
            chunk = chunk.min(ce - global % ce);
        }
        let stats =
            trainer.train_range(&train, idx as usize, chunk as usize, &engine, codec.as_dyn())?;
        if stats.steps == 0 {
            return Err(JobError::Spec("training made no progress (dataset too small?)".into()));
        }
        global += stats.steps as u64;
        seconds += stats.seconds;
        if delay > 0 {
            std::thread::sleep(std::time::Duration::from_millis(delay * stats.steps as u64));
        }
        publish(global, engine.counter.snapshot());

        if ce > 0 && global % ce == 0 && global < total {
            if let Some(path) = &ckpt_path {
                let client_rng = match &codec {
                    JobCodec::Fhe(ck) => Some(ck.rng.state()),
                    JobCodec::Clear(_) => None,
                };
                let ckpt = Checkpoint::capture(
                    &trainer.net,
                    &engine,
                    spec.seed,
                    global / spe,
                    global,
                    seconds,
                    client_rng,
                )?;
                write_atomic(path, &ckpt.to_wire())?;
                written_this_run += 1;
                if opts.halt_after_checkpoints == Some(written_this_run) {
                    return Ok(RunOutcome::Halted);
                }
            }
        }
    }

    // Training-only op totals are the SLA signal (plan totals × steps);
    // snapshot them before evaluation adds its forward-pass ops.
    let train_ops = engine.counter.snapshot();
    let scores = trainer.eval_scores(&test, eval_n, &engine, codec.as_dyn())?;
    let mut correct = 0usize;
    for (i, row) in scores.iter().enumerate() {
        let best = row.iter().enumerate().max_by_key(|&(k, &v)| (v, std::cmp::Reverse(k)));
        if best.map(|(k, _)| k) == Some(test.labels[i] % classes) {
            correct += 1;
        }
    }
    let result = JobResult {
        id: handle.id,
        steps: total,
        seconds,
        accuracy: correct as f64 / scores.len() as f64,
        ops: train_ops,
        weights_digest: weights_digest(&trainer.net),
        logits_digest: logits_digest(&scores),
        resumes: handle.status().resumes,
    };
    handle.update(|st| {
        st.state = JobState::Completed;
        st.step = total;
        st.epoch = spec.epochs;
        st.live_ops = train_ops;
        st.predicted_ops = per_step.scale(total);
    });
    Ok(RunOutcome::Completed(result))
}
