//! The serve protocol: wire-framed request/response messages over a
//! u32-length-prefixed TCP stream.
//!
//! Framing: every message on the socket is `len: u32 LE` followed by `len`
//! bytes of a [`WireCodec`] frame ([`Request`] client→server, [`Response`]
//! server→client). The wire frame carries its own magic/version/checksum,
//! so a torn or corrupted message is rejected with a descriptive error
//! rather than desynchronizing the stream.

use crate::coordinator::metrics::OpSnapshot;
use crate::nn::engine::EngineProfile;
use crate::wire::{get_nested, put_nested, WireCodec, WireError, WireReader, WireWriter};
use std::io::{Read, Write};

/// Upper bound on one framed message (keys/ciphertexts never travel over
/// this protocol — job state lives server-side — so frames stay small).
pub const MAX_FRAME: u32 = 16 << 20;

/// Write one length-prefixed message.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    let len = u32::try_from(payload.len())
        .ok()
        .filter(|&l| l <= MAX_FRAME)
        .ok_or_else(|| std::io::Error::other(format!("frame of {} bytes exceeds MAX_FRAME", payload.len())))?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one length-prefixed message. `Ok(None)` on clean EOF before the
/// length word (peer hung up between messages).
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(std::io::Error::other(format!("peer announced a {len}-byte frame (max {MAX_FRAME})")));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(Some(buf))
}

/// Which execution backend a job runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobBackend {
    /// The bit-exact plaintext mirror (epoch-scale, CI, conformance).
    Clear,
    /// Reduced-scale encrypted training (test-profile keys).
    Fhe,
}

/// Everything needed to run — and deterministically *re-run* — a training
/// job. All randomness (dataset synthesis, weight init, key generation,
/// encryption noise) derives from `seed`, which is what makes checkpoint
/// resume byte-identical: the runner rebuilds the exact network and
/// repositions the RNG cursors recorded in the checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Tenant label (metrics dimension; one `FheState` session per job).
    pub tenant: String,
    pub backend: JobBackend,
    /// Parameter profile: `Default` (production-shaped) or `Test`.
    pub profile: EngineProfile,
    /// MLP layer widths, input first.
    pub dims: Vec<u64>,
    /// Mini-batch width.
    pub batch: u64,
    pub epochs: u64,
    /// Steps per epoch; 0 = as many full minibatches as the dataset holds.
    pub steps_per_epoch: u64,
    /// Training-set size to load.
    pub samples: u64,
    /// Held-out evaluation samples (0 = `samples/4`, min one batch).
    pub eval_samples: u64,
    /// Dataset name: digits|mnist|cancer|svhn|cifar.
    pub dataset: String,
    /// Master determinism seed (see above).
    pub seed: u64,
    /// Persist a checkpoint every K global steps (0 = never; the job still
    /// recovers by restarting from step 0).
    pub checkpoint_every: u64,
    /// Softmax unit output bits.
    pub softmax_bits: u64,
}

impl JobSpec {
    /// A small clear-backend job with sane defaults (tests, bench, CLI).
    pub fn small_clear(tenant: &str, seed: u64) -> JobSpec {
        JobSpec {
            tenant: tenant.into(),
            backend: JobBackend::Clear,
            profile: EngineProfile::Default,
            dims: vec![16, 8, 4],
            batch: 4,
            epochs: 1,
            steps_per_epoch: 0,
            samples: 32,
            eval_samples: 0,
            dataset: "digits".into(),
            seed,
            checkpoint_every: 4,
            softmax_bits: 3,
        }
    }

    /// Structural validation (the server rejects bad specs at submit, the
    /// runner re-validates before building keys).
    pub fn validate(&self) -> Result<(), String> {
        if self.dims.len() < 2 || self.dims.iter().any(|&d| d == 0) {
            return Err(format!("dims needs at least two nonzero widths, got {:?}", self.dims));
        }
        if self.batch == 0 {
            return Err("batch must be nonzero".into());
        }
        if self.epochs == 0 {
            return Err("epochs must be nonzero".into());
        }
        if self.samples < self.batch {
            return Err(format!(
                "samples ({}) must cover at least one minibatch ({})",
                self.samples, self.batch
            ));
        }
        if !matches!(self.dataset.as_str(), "digits" | "mnist" | "cancer" | "svhn" | "cifar") {
            return Err(format!(
                "dataset must be digits|mnist|cancer|svhn|cifar, got {:?}",
                self.dataset
            ));
        }
        if self.softmax_bits == 0 || self.softmax_bits > 16 {
            return Err(format!("softmax_bits {} is outside 1..=16", self.softmax_bits));
        }
        Ok(())
    }
}

impl WireCodec for JobSpec {
    const TAG: [u8; 4] = *b"JSPC";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_str(&self.tenant);
        w.put_u8(match self.backend {
            JobBackend::Clear => 0,
            JobBackend::Fhe => 1,
        });
        w.put_u8(match self.profile {
            EngineProfile::Default => 0,
            EngineProfile::Test => 1,
        });
        w.put_u64s(&self.dims);
        w.put_u64(self.batch);
        w.put_u64(self.epochs);
        w.put_u64(self.steps_per_epoch);
        w.put_u64(self.samples);
        w.put_u64(self.eval_samples);
        w.put_str(&self.dataset);
        w.put_u64(self.seed);
        w.put_u64(self.checkpoint_every);
        w.put_u64(self.softmax_bits);
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        Ok(JobSpec {
            tenant: r.str()?,
            backend: match r.u8()? {
                0 => JobBackend::Clear,
                1 => JobBackend::Fhe,
                other => return Err(WireError::Malformed(format!("bad backend {other}"))),
            },
            profile: match r.u8()? {
                0 => EngineProfile::Default,
                1 => EngineProfile::Test,
                other => return Err(WireError::Malformed(format!("bad profile {other}"))),
            },
            dims: r.u64s()?,
            batch: r.u64()?,
            epochs: r.u64()?,
            steps_per_epoch: r.u64()?,
            samples: r.u64()?,
            eval_samples: r.u64()?,
            dataset: r.str()?,
            seed: r.u64()?,
            checkpoint_every: r.u64()?,
            softmax_bits: r.u64()?,
        })
    }
}

/// Everything needed to run a forward-only inference job: score `samples`
/// encrypted inputs through a frozen model, batched. The model comes from
/// a completed training job's persisted final checkpoint (`model_job`), or
/// — with `model_job == 0` — from deterministic random init (conformance
/// and latency probes, where only op counts and timing matter).
#[derive(Clone, Debug, PartialEq)]
pub struct InferSpec {
    /// Tenant label (metrics dimension).
    pub tenant: String,
    pub backend: JobBackend,
    pub profile: EngineProfile,
    /// MLP layer widths, input first (must match the model job's dims).
    pub dims: Vec<u64>,
    /// Mini-batch width for the forward passes (amortization lever; need
    /// not match the training batch).
    pub batch: u64,
    /// Samples to score (full minibatches only).
    pub samples: u64,
    /// Dataset name: digits|mnist|cancer|svhn|cifar (held-out split).
    pub dataset: String,
    /// Determinism seed. On FHE this must equal the model job's seed —
    /// keygen derives from it, and the model's weight ciphertexts only
    /// decrypt under the training key.
    pub seed: u64,
    /// Softmax unit output bits (must match the model job's).
    pub softmax_bits: u64,
    /// Completed training job whose persisted model to serve (0 = fresh
    /// deterministic random weights).
    pub model_job: u64,
    /// Score through the cross-sample SIMD packed layout (v2). Packed
    /// engines rebuild weight geometry at encode time, so this requires
    /// `model_job == 0` — checkpointed models restore the per-scalar
    /// layer path.
    pub packed: bool,
    /// Opt into the shared scoring lane (v2): batch-compatible coalesce
    /// jobs are drained together and scored in one widened engine batch,
    /// with occupancy masks for partial fills and exact per-job op
    /// attribution split from the shared counter delta.
    pub coalesce: bool,
}

impl InferSpec {
    /// A small clear-backend inference job with sane defaults.
    pub fn small_clear(tenant: &str, seed: u64) -> InferSpec {
        InferSpec {
            tenant: tenant.into(),
            backend: JobBackend::Clear,
            profile: EngineProfile::Default,
            dims: vec![16, 8, 4],
            batch: 4,
            samples: 16,
            dataset: "digits".into(),
            seed,
            softmax_bits: 3,
            model_job: 0,
            packed: false,
            coalesce: false,
        }
    }

    /// The lane-compatibility key: two coalesce jobs may share one scoring
    /// lane (and therefore one engine, one key stream, one model build)
    /// iff every field here matches. Rendered into the per-lane metric
    /// labels, so it doubles as the lane's human-readable identity.
    pub fn lane_label(&self) -> String {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        format!(
            "{}-{}-d{}-b{}-sm{}-{}-seed{}-model{}{}",
            match self.backend {
                JobBackend::Clear => "clear",
                JobBackend::Fhe => "fhe",
            },
            match self.profile {
                EngineProfile::Default => "default",
                EngineProfile::Test => "test",
            },
            dims.join("x"),
            self.batch,
            self.softmax_bits,
            self.dataset,
            self.seed,
            self.model_job,
            if self.packed { "-packed" } else { "" },
        )
    }

    /// Structural validation (submit-time; the runner re-validates).
    pub fn validate(&self) -> Result<(), String> {
        if self.dims.len() < 2 || self.dims.iter().any(|&d| d == 0) {
            return Err(format!("dims needs at least two nonzero widths, got {:?}", self.dims));
        }
        if self.batch == 0 {
            return Err("batch must be nonzero".into());
        }
        if self.samples < self.batch {
            return Err(format!(
                "samples ({}) must cover at least one minibatch ({})",
                self.samples, self.batch
            ));
        }
        if !matches!(self.dataset.as_str(), "digits" | "mnist" | "cancer" | "svhn" | "cifar") {
            return Err(format!(
                "dataset must be digits|mnist|cancer|svhn|cifar, got {:?}",
                self.dataset
            ));
        }
        if self.softmax_bits == 0 || self.softmax_bits > 16 {
            return Err(format!("softmax_bits {} is outside 1..=16", self.softmax_bits));
        }
        if self.packed && self.model_job != 0 {
            return Err(format!(
                "packed inference requires a fresh model (model_job 0), got model_job {}",
                self.model_job
            ));
        }
        Ok(())
    }
}

impl WireCodec for InferSpec {
    const TAG: [u8; 4] = *b"ISPC";
    // v2: adds packed/coalesce (the batched-scheduling opt-ins)
    const VERSION: u16 = 2;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_str(&self.tenant);
        w.put_u8(match self.backend {
            JobBackend::Clear => 0,
            JobBackend::Fhe => 1,
        });
        w.put_u8(match self.profile {
            EngineProfile::Default => 0,
            EngineProfile::Test => 1,
        });
        w.put_u64s(&self.dims);
        w.put_u64(self.batch);
        w.put_u64(self.samples);
        w.put_str(&self.dataset);
        w.put_u64(self.seed);
        w.put_u64(self.softmax_bits);
        w.put_u64(self.model_job);
        w.put_u8(self.packed as u8);
        w.put_u8(self.coalesce as u8);
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        Ok(InferSpec {
            tenant: r.str()?,
            backend: match r.u8()? {
                0 => JobBackend::Clear,
                1 => JobBackend::Fhe,
                other => return Err(WireError::Malformed(format!("bad backend {other}"))),
            },
            profile: match r.u8()? {
                0 => EngineProfile::Default,
                1 => EngineProfile::Test,
                other => return Err(WireError::Malformed(format!("bad profile {other}"))),
            },
            dims: r.u64s()?,
            batch: r.u64()?,
            samples: r.u64()?,
            dataset: r.str()?,
            seed: r.u64()?,
            softmax_bits: r.u64()?,
            model_job: r.u64()?,
            packed: r.u8()? != 0,
            coalesce: r.u8()? != 0,
        })
    }
}

/// Job lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Completed,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }
}

/// Which workload a job runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    Train,
    Infer,
}

impl JobKind {
    pub fn name(self) -> &'static str {
        match self {
            JobKind::Train => "train",
            JobKind::Infer => "infer",
        }
    }
}

/// Point-in-time view of a job, as returned by `status` and rendered by
/// `metrics`.
#[derive(Clone, Debug)]
pub struct JobStatus {
    pub id: u64,
    pub tenant: String,
    /// Train or infer workload (v2).
    pub kind: JobKind,
    pub state: JobState,
    /// Epoch the cursor is inside.
    pub epoch: u64,
    /// Global minibatch steps completed.
    pub step: u64,
    /// Total steps the job will run (`epochs × steps_per_epoch`).
    pub total_steps: u64,
    /// Checkpoints persisted so far (across restarts).
    pub checkpoints: u64,
    /// Times this job resumed from a checkpoint after a restart.
    pub resumes: u64,
    /// Live op counters at the cursor.
    pub live_ops: OpSnapshot,
    /// Compiled-plan prediction for the cursor (per-step totals × steps).
    pub predicted_ops: OpSnapshot,
    /// Images scored so far (infer jobs; `step × batch`).
    pub images: u64,
    /// Scoring wall-clock so far (infer jobs; drives the latency gauge).
    pub seconds: f64,
    /// Batch group this job was coalesced into (v3; 0 = scored solo).
    pub group: u64,
    /// Failure detail when `state == Failed`.
    pub message: String,
}

impl WireCodec for JobStatus {
    const TAG: [u8; 4] = *b"JSTA";
    // v3: adds group (the coalesced batch-group id, 0 = solo)
    const VERSION: u16 = 3;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_u64(self.id);
        w.put_str(&self.tenant);
        w.put_u8(match self.kind {
            JobKind::Train => 0,
            JobKind::Infer => 1,
        });
        w.put_u8(match self.state {
            JobState::Queued => 0,
            JobState::Running => 1,
            JobState::Completed => 2,
            JobState::Failed => 3,
            JobState::Cancelled => 4,
        });
        w.put_u64(self.epoch);
        w.put_u64(self.step);
        w.put_u64(self.total_steps);
        w.put_u64(self.checkpoints);
        w.put_u64(self.resumes);
        put_nested(w, &self.live_ops);
        put_nested(w, &self.predicted_ops);
        w.put_u64(self.images);
        w.put_f64(self.seconds);
        w.put_u64(self.group);
        w.put_str(&self.message);
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        Ok(JobStatus {
            id: r.u64()?,
            tenant: r.str()?,
            kind: match r.u8()? {
                0 => JobKind::Train,
                1 => JobKind::Infer,
                other => return Err(WireError::Malformed(format!("bad job kind {other}"))),
            },
            state: match r.u8()? {
                0 => JobState::Queued,
                1 => JobState::Running,
                2 => JobState::Completed,
                3 => JobState::Failed,
                4 => JobState::Cancelled,
                other => return Err(WireError::Malformed(format!("bad job state {other}"))),
            },
            epoch: r.u64()?,
            step: r.u64()?,
            total_steps: r.u64()?,
            checkpoints: r.u64()?,
            resumes: r.u64()?,
            live_ops: get_nested(r, &())?,
            predicted_ops: get_nested(r, &())?,
            images: r.u64()?,
            seconds: r.f64()?,
            group: r.u64()?,
            message: r.str()?,
        })
    }
}

/// Final outcome of a completed job. Model weights stay server-side (they
/// are ciphertexts under the tenant's key); the result carries integrity
/// digests so conformance tests can prove two runs produced byte-identical
/// models without moving them.
#[derive(Clone, Debug, PartialEq)]
pub struct JobResult {
    pub id: u64,
    /// Steps actually trained.
    pub steps: u64,
    /// Training wall-clock (checkpointed across restarts).
    pub seconds: f64,
    /// Held-out accuracy at completion.
    pub accuracy: f64,
    /// Training-only op totals (evaluation excluded; equals plan totals ×
    /// steps up to relin/mod-switch).
    pub ops: OpSnapshot,
    /// FNV-1a over the wire encoding of every trainable weight ciphertext.
    pub weights_digest: u64,
    /// FNV-1a over the decoded evaluation logits.
    pub logits_digest: u64,
    /// Times the job resumed from a checkpoint.
    pub resumes: u64,
}

impl WireCodec for JobResult {
    const TAG: [u8; 4] = *b"JRES";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_u64(self.id);
        w.put_u64(self.steps);
        w.put_f64(self.seconds);
        w.put_f64(self.accuracy);
        put_nested(w, &self.ops);
        w.put_u64(self.weights_digest);
        w.put_u64(self.logits_digest);
        w.put_u64(self.resumes);
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        Ok(JobResult {
            id: r.u64()?,
            steps: r.u64()?,
            seconds: r.f64()?,
            accuracy: r.f64()?,
            ops: get_nested(r, &())?,
            weights_digest: r.u64()?,
            logits_digest: r.u64()?,
            resumes: r.u64()?,
        })
    }
}

/// Final outcome of a completed inference job.
#[derive(Clone, Debug, PartialEq)]
pub struct InferResult {
    pub id: u64,
    /// Images scored.
    pub images: u64,
    /// Full forward-pass minibatches run.
    pub batches: u64,
    /// Scoring wall-clock.
    pub seconds: f64,
    /// Argmax accuracy against the held-out labels.
    pub accuracy: f64,
    /// Scoring op totals (equals forward-only plan totals × batches up to
    /// relin/mod-switch).
    pub ops: OpSnapshot,
    /// FNV-1a over the decoded logit rows (byte-identity witness).
    pub logits_digest: u64,
    /// FNV-1a over the argmax label sequence.
    pub predictions_digest: u64,
}

impl WireCodec for InferResult {
    const TAG: [u8; 4] = *b"IRES";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        w.put_u64(self.id);
        w.put_u64(self.images);
        w.put_u64(self.batches);
        w.put_f64(self.seconds);
        w.put_f64(self.accuracy);
        put_nested(w, &self.ops);
        w.put_u64(self.logits_digest);
        w.put_u64(self.predictions_digest);
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        Ok(InferResult {
            id: r.u64()?,
            images: r.u64()?,
            batches: r.u64()?,
            seconds: r.f64()?,
            accuracy: r.f64()?,
            ops: get_nested(r, &())?,
            logits_digest: r.u64()?,
            predictions_digest: r.u64()?,
        })
    }
}

/// Client→server message.
#[derive(Clone, Debug)]
pub enum Request {
    Submit(JobSpec),
    Status { id: u64 },
    Cancel { id: u64 },
    FetchResult { id: u64 },
    Metrics,
    /// Liveness probe.
    Ping,
    /// Graceful stop: drain workers, exit the accept loop.
    Shutdown,
    /// Submit a forward-only inference job.
    SubmitInfer(InferSpec),
}

impl WireCodec for Request {
    const TAG: [u8; 4] = *b"RREQ";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        match self {
            Request::Submit(spec) => {
                w.put_u8(0);
                put_nested(w, spec);
            }
            Request::Status { id } => {
                w.put_u8(1);
                w.put_u64(*id);
            }
            Request::Cancel { id } => {
                w.put_u8(2);
                w.put_u64(*id);
            }
            Request::FetchResult { id } => {
                w.put_u8(3);
                w.put_u64(*id);
            }
            Request::Metrics => w.put_u8(4),
            Request::Ping => w.put_u8(5),
            Request::Shutdown => w.put_u8(6),
            Request::SubmitInfer(spec) => {
                w.put_u8(7);
                put_nested(w, spec);
            }
        }
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        Ok(match r.u8()? {
            0 => Request::Submit(get_nested(r, &())?),
            1 => Request::Status { id: r.u64()? },
            2 => Request::Cancel { id: r.u64()? },
            3 => Request::FetchResult { id: r.u64()? },
            4 => Request::Metrics,
            5 => Request::Ping,
            6 => Request::Shutdown,
            7 => Request::SubmitInfer(get_nested(r, &())?),
            other => return Err(WireError::Malformed(format!("bad request variant {other}"))),
        })
    }
}

/// Server→client message.
#[derive(Clone, Debug)]
pub enum Response {
    Submitted { id: u64 },
    Status(JobStatus),
    Cancelled { id: u64 },
    Result(JobResult),
    /// Prometheus text exposition.
    Metrics(String),
    Pong,
    ShuttingDown,
    /// Request-level failure (unknown job, invalid spec, …).
    Error(String),
    /// Completed inference job's outcome (`fetch-result` on infer jobs).
    InferResult(InferResult),
}

impl WireCodec for Response {
    const TAG: [u8; 4] = *b"RRSP";
    const VERSION: u16 = 1;
    type Ctx = ();

    fn encode_body(&self, w: &mut WireWriter) {
        match self {
            Response::Submitted { id } => {
                w.put_u8(0);
                w.put_u64(*id);
            }
            Response::Status(st) => {
                w.put_u8(1);
                put_nested(w, st);
            }
            Response::Cancelled { id } => {
                w.put_u8(2);
                w.put_u64(*id);
            }
            Response::Result(res) => {
                w.put_u8(3);
                put_nested(w, res);
            }
            Response::Metrics(text) => {
                w.put_u8(4);
                w.put_str(text);
            }
            Response::Pong => w.put_u8(5),
            Response::ShuttingDown => w.put_u8(6),
            Response::Error(msg) => {
                w.put_u8(7);
                w.put_str(msg);
            }
            Response::InferResult(res) => {
                w.put_u8(8);
                put_nested(w, res);
            }
        }
    }

    fn decode_body(r: &mut WireReader<'_>, _: &()) -> Result<Self, WireError> {
        Ok(match r.u8()? {
            0 => Response::Submitted { id: r.u64()? },
            1 => Response::Status(get_nested(r, &())?),
            2 => Response::Cancelled { id: r.u64()? },
            3 => Response::Result(get_nested(r, &())?),
            4 => Response::Metrics(r.str()?),
            5 => Response::Pong,
            6 => Response::ShuttingDown,
            7 => Response::Error(r.str()?),
            8 => Response::InferResult(get_nested(r, &())?),
            other => return Err(WireError::Malformed(format!("bad response variant {other}"))),
        })
    }
}
