//! Blocking client for the serve protocol. Used by the CLI subcommands
//! (`glyph submit`/`status`/...), the smoke tests and the bench.

use super::protocol::{
    read_frame, write_frame, InferResult, InferSpec, JobResult, JobSpec, JobStatus, Request,
    Response,
};
use crate::wire::WireCodec;
use std::fmt;
use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

#[derive(Debug)]
pub enum ClientError {
    Io(io::Error),
    /// Frame arrived but did not decode as a `Response`.
    Wire(crate::wire::WireError),
    /// Server replied `Response::Error(..)`.
    Server(String),
    /// Server replied, but with a variant the call does not expect.
    Unexpected(String),
    /// Server closed the connection without replying.
    Disconnected,
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io error: {e}"),
            ClientError::Wire(e) => write!(f, "bad response frame: {e}"),
            ClientError::Server(msg) => write!(f, "server error: {msg}"),
            ClientError::Unexpected(msg) => write!(f, "unexpected response: {msg}"),
            ClientError::Disconnected => write!(f, "server closed the connection"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<crate::wire::WireError> for ClientError {
    fn from(e: crate::wire::WireError) -> Self {
        ClientError::Wire(e)
    }
}

/// What [`ServeClient::fetch`] found for a job in a terminal state.
#[derive(Clone, Debug)]
pub enum Fetched {
    Train(JobResult),
    Infer(InferResult),
    /// The job was cancelled and will never produce a result.
    Cancelled,
}

/// One TCP connection to a glyph server; requests are serialized on it.
pub struct ServeClient {
    stream: TcpStream,
}

impl ServeClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<ServeClient> {
        Ok(ServeClient { stream: TcpStream::connect(addr)? })
    }

    /// Send one request and read one response.
    pub fn request(&mut self, req: &Request) -> Result<Response, ClientError> {
        write_frame(&mut self.stream, &req.to_wire())?;
        let frame = read_frame(&mut self.stream)?.ok_or(ClientError::Disconnected)?;
        let resp = Response::from_wire(&frame, &())?;
        if let Response::Error(msg) = resp {
            return Err(ClientError::Server(msg));
        }
        Ok(resp)
    }

    pub fn submit(&mut self, spec: &JobSpec) -> Result<u64, ClientError> {
        match self.request(&Request::Submit(spec.clone()))? {
            Response::Submitted { id } => Ok(id),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    pub fn status(&mut self, id: u64) -> Result<JobStatus, ClientError> {
        match self.request(&Request::Status { id })? {
            Response::Status(status) => Ok(status),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    pub fn cancel(&mut self, id: u64) -> Result<(), ClientError> {
        match self.request(&Request::Cancel { id })? {
            Response::Cancelled { .. } => Ok(()),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    pub fn submit_infer(&mut self, spec: &InferSpec) -> Result<u64, ClientError> {
        match self.request(&Request::SubmitInfer(spec.clone()))? {
            Response::Submitted { id } => Ok(id),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    pub fn fetch_result(&mut self, id: u64) -> Result<JobResult, ClientError> {
        match self.request(&Request::FetchResult { id })? {
            Response::Result(result) => Ok(result),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Kind-agnostic result fetch: training and inference results both
    /// land here, as does the terminal `Cancelled` answer a cancelled job
    /// gives pollers (so they stop instead of retrying an `Error`).
    pub fn fetch(&mut self, id: u64) -> Result<Fetched, ClientError> {
        match self.request(&Request::FetchResult { id })? {
            Response::Result(result) => Ok(Fetched::Train(result)),
            Response::InferResult(result) => Ok(Fetched::Infer(result)),
            Response::Cancelled { .. } => Ok(Fetched::Cancelled),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    pub fn metrics(&mut self) -> Result<String, ClientError> {
        match self.request(&Request::Metrics)? {
            Response::Metrics(text) => Ok(text),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    pub fn ping(&mut self) -> Result<(), ClientError> {
        match self.request(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    pub fn shutdown(&mut self) -> Result<(), ClientError> {
        match self.request(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => Err(ClientError::Unexpected(format!("{other:?}"))),
        }
    }

    /// Poll `status` until the job leaves the queued/running states or
    /// `timeout` elapses.
    pub fn wait(&mut self, id: u64, timeout: Duration) -> Result<JobStatus, ClientError> {
        let deadline = Instant::now() + timeout;
        loop {
            let status = self.status(id)?;
            match status.state {
                super::protocol::JobState::Queued | super::protocol::JobState::Running => {
                    if Instant::now() >= deadline {
                        return Err(ClientError::Unexpected(format!(
                            "timed out waiting for job {id} (state: {})",
                            status.state.name()
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
                _ => return Ok(status),
            }
        }
    }
}
