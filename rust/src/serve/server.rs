//! The `glyph serve` server: accept loop, job queue, worker pool,
//! startup recovery.
//!
//! Threading model: one non-blocking accept thread (polls the shutdown
//! flag between accepts), one short-lived thread per connection, and N
//! worker threads popping job ids off a `Condvar`-guarded queue. Workers
//! own the engine/session for the job they run — nothing homomorphic is
//! shared across threads.
//!
//! Durability: with a data directory, every submitted spec is persisted
//! to `jobs/<id>/spec.bin` (training) or `jobs/<id>/infer.bin`
//! (inference) before the submit reply, checkpoints land in the same
//! directory every K steps, results in `result.bin`, and a completed
//! training job's final model in `model.bin` (what inference jobs load
//! via `model_job`). On startup the server scans `jobs/*`: finished jobs
//! are loaded into the result cache, unfinished ones are re-enqueued and
//! resume from their latest checkpoint inside [`run_job`]. `kill -9`
//! mid-epoch therefore loses at most K steps of work and zero bytes of
//! determinism.
//!
//! Hardening: every shared mutex is taken through a poison-recovering
//! lock and each job run is wrapped in `catch_unwind`, so a panic
//! anywhere inside one job degrades that job to `Failed` while the
//! server keeps answering submit/status/metrics.

use super::job::{
    checkpoint_path, compiled_infer_plan, compiled_plan, run_infer_group, run_infer_job, run_job,
    InferOutcome, JobHandle, JobPayload, RunOptions, RunOutcome,
};
use super::lock_clean;
use super::metrics;
use super::protocol::{
    read_frame, write_frame, InferResult, InferSpec, JobResult, JobSpec, JobState, Request,
    Response,
};
use crate::wire::WireCodec;
use std::collections::{HashMap, VecDeque};
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration. `addr` may use port 0 to let the OS pick;
/// the bound address is reported by [`RunningServer::addr`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Durable state root (`jobs/<id>/{spec,checkpoint,result}.bin`).
    /// `None` disables persistence (jobs are memory-only, no resume).
    pub data_dir: Option<PathBuf>,
    /// Worker threads; clamped to at least 1.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { addr: "127.0.0.1:0".into(), data_dir: None, workers: 1 }
    }
}

/// A completed job's cached outcome (training and inference results share
/// the `result.bin` slot; the payload kind disambiguates on recovery).
enum StoredResult {
    Train(JobResult),
    Infer(InferResult),
}

/// Hard cap on a coalesced batch group's total slot width: bounds engine
/// memory and keeps the packed layout well inside every profile's ring.
const MAX_GROUP_SLOTS: u64 = 64;

struct Shared {
    jobs: Mutex<HashMap<u64, Arc<JobHandle>>>,
    queue: Mutex<VecDeque<u64>>,
    queue_cv: Condvar,
    next_id: AtomicU64,
    shutdown: AtomicBool,
    data_dir: Option<PathBuf>,
    results: Mutex<HashMap<u64, StoredResult>>,
    started: Instant,
    /// Shared scoring lanes: lane label → queued coalesce job ids, FIFO.
    /// Membership in a lane's deque IS the claim token — a worker drains
    /// compatible jobs under this lock, and a main-queue token whose id is
    /// no longer in its lane has already been scored by another group.
    lanes: Mutex<HashMap<String, VecDeque<u64>>>,
    /// Accumulated per-lane coalescing stats behind the `/metrics` gauges.
    lane_stats: Mutex<HashMap<String, metrics::LaneView>>,
    /// Batch-group id allocator (0 is reserved for "scored solo").
    next_group: AtomicU64,
}

impl Shared {
    fn job_dir(&self, id: u64) -> Option<PathBuf> {
        self.data_dir.as_ref().map(|d| d.join("jobs").join(id.to_string()))
    }

    fn enqueue(&self, id: u64) {
        lock_clean(&self.queue).push_back(id);
        self.queue_cv.notify_one();
    }

    fn enlane(&self, lane: String, id: u64) {
        lock_clean(&self.lanes).entry(lane).or_default().push_back(id);
    }
}

/// A started server. Dropping it does NOT stop the threads; call
/// [`RunningServer::shutdown`] then [`RunningServer::wait`].
pub struct RunningServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl RunningServer {
    /// Bind, recover durable state, and spawn the accept + worker threads.
    pub fn start(cfg: ServeConfig) -> io::Result<RunningServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;

        let shared = Arc::new(Shared {
            jobs: Mutex::new(HashMap::new()),
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            data_dir: cfg.data_dir.clone(),
            results: Mutex::new(HashMap::new()),
            started: Instant::now(),
            lanes: Mutex::new(HashMap::new()),
            lane_stats: Mutex::new(HashMap::new()),
            next_group: AtomicU64::new(1),
        });

        if let Some(dir) = &cfg.data_dir {
            recover(&shared, dir)?;
        }

        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();

        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(&listener, &shared))
        };

        Ok(RunningServer { addr, shared, accept: Some(accept), workers })
    }

    /// The bound listen address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask every thread to stop. Workers finish the job they are running
    /// and skip the rest of the queue.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue_cv.notify_all();
    }

    /// Join the accept thread and all workers.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Scan `dir/jobs/*` and rebuild in-memory state: completed jobs feed the
/// result cache, everything else goes back on the queue (and will resume
/// from its checkpoint, if one exists).
fn recover(shared: &Arc<Shared>, dir: &Path) -> io::Result<()> {
    let jobs_root = dir.join("jobs");
    if !jobs_root.is_dir() {
        return Ok(());
    }
    let mut max_id = 0u64;
    let mut pending = Vec::new();
    for entry in std::fs::read_dir(&jobs_root)? {
        let entry = entry?;
        let Ok(id) = entry.file_name().to_string_lossy().parse::<u64>() else {
            continue;
        };
        // Training jobs persist `spec.bin`, inference jobs `infer.bin`.
        let handle = if let Ok(bytes) = std::fs::read(entry.path().join("spec.bin")) {
            let Ok(spec) = JobSpec::from_wire(&bytes, &()) else {
                continue;
            };
            Arc::new(JobHandle::new(id, spec))
        } else if let Ok(bytes) = std::fs::read(entry.path().join("infer.bin")) {
            let Ok(spec) = InferSpec::from_wire(&bytes, &()) else {
                continue;
            };
            Arc::new(JobHandle::new_infer(id, spec))
        } else {
            continue;
        };
        max_id = max_id.max(id);
        let result_bytes = std::fs::read(entry.path().join("result.bin")).ok();
        let stored = result_bytes.and_then(|b| match &handle.payload {
            JobPayload::Train(_) => JobResult::from_wire(&b, &()).ok().map(StoredResult::Train),
            JobPayload::Infer(_) => InferResult::from_wire(&b, &()).ok().map(StoredResult::Infer),
        });
        if let Some(stored) = stored {
            handle.update(|st| {
                st.state = JobState::Completed;
                match &stored {
                    StoredResult::Train(r) => {
                        st.step = r.steps;
                        st.resumes = r.resumes;
                        st.live_ops = r.ops;
                    }
                    StoredResult::Infer(r) => {
                        st.step = r.batches;
                        st.images = r.images;
                        st.seconds = r.seconds;
                        st.live_ops = r.ops;
                    }
                }
            });
            lock_clean(&shared.results).insert(id, stored);
            lock_clean(&shared.jobs).insert(id, handle);
        } else {
            let lane = handle
                .infer_spec()
                .filter(|s| s.coalesce)
                .map(super::protocol::InferSpec::lane_label);
            lock_clean(&shared.jobs).insert(id, Arc::clone(&handle));
            pending.push((id, lane));
        }
    }
    shared.next_id.store(max_id + 1, Ordering::SeqCst);
    pending.sort_unstable();
    for (id, lane) in pending {
        // coalesce jobs rejoin their scoring lane before the main queue, so
        // recovered siblings coalesce again instead of running solo
        if let Some(lane) = lane {
            shared.enlane(lane, id);
        }
        shared.enqueue(id);
    }
    Ok(())
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nonblocking(false);
                let shared = Arc::clone(shared);
                std::thread::spawn(move || handle_conn(&shared, stream));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

fn handle_conn(shared: &Arc<Shared>, mut stream: TcpStream) {
    while let Ok(Some(frame)) = read_frame(&mut stream) {
        let resp = match Request::from_wire(&frame, &()) {
            Ok(req) => dispatch(shared, req),
            Err(e) => Response::Error(format!("bad request frame: {e}")),
        };
        let closing = matches!(resp, Response::ShuttingDown);
        if write_frame(&mut stream, &resp.to_wire()).is_err() || closing {
            return;
        }
    }
}

fn dispatch(shared: &Arc<Shared>, req: Request) -> Response {
    match req {
        Request::Submit(spec) => match submit(shared, spec) {
            Ok(id) => Response::Submitted { id },
            Err(msg) => Response::Error(msg),
        },
        Request::SubmitInfer(spec) => match submit_infer(shared, spec) {
            Ok(id) => Response::Submitted { id },
            Err(msg) => Response::Error(msg),
        },
        Request::Status { id } => match lock_clean(&shared.jobs).get(&id) {
            Some(h) => Response::Status(h.status()),
            None => Response::Error(format!("unknown job {id}")),
        },
        Request::Cancel { id } => {
            let handle = lock_clean(&shared.jobs).get(&id).cloned();
            match handle {
                Some(h) => {
                    h.cancel.store(true, Ordering::SeqCst);
                    // A queued job never reaches its worker-side cancel
                    // check promptly, so flip the state here.
                    h.update(|st| {
                        if st.state == JobState::Queued {
                            st.state = JobState::Cancelled;
                        }
                    });
                    Response::Cancelled { id }
                }
                None => Response::Error(format!("unknown job {id}")),
            }
        }
        Request::FetchResult { id } => {
            match lock_clean(&shared.results).get(&id) {
                Some(StoredResult::Train(r)) => return Response::Result(r.clone()),
                Some(StoredResult::Infer(r)) => return Response::InferResult(r.clone()),
                None => {}
            }
            match lock_clean(&shared.jobs).get(&id) {
                // A cancelled job will never produce a result: answer with
                // the terminal `Cancelled` frame so pollers stop, instead
                // of an Error they would retry forever.
                Some(h) if h.status().state == JobState::Cancelled => Response::Cancelled { id },
                Some(h) => Response::Error(format!(
                    "job {id} not completed (state: {})",
                    h.status().state.name()
                )),
                None => Response::Error(format!("unknown job {id}")),
            }
        }
        Request::Metrics => {
            let mut statuses: Vec<_> =
                lock_clean(&shared.jobs).values().map(|h| h.status()).collect();
            statuses.sort_by_key(|s| s.id);
            let mut lanes: Vec<_> = lock_clean(&shared.lane_stats).values().cloned().collect();
            lanes.sort_by(|a, b| a.lane.cmp(&b.lane));
            Response::Metrics(metrics::render(
                shared.started.elapsed().as_secs_f64(),
                &statuses,
                &lanes,
            ))
        }
        Request::Ping => Response::Pong,
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.queue_cv.notify_all();
            Response::ShuttingDown
        }
    }
}

fn submit(shared: &Arc<Shared>, spec: JobSpec) -> Result<u64, String> {
    // Compile the plan up front: a spec the planner rejects should fail
    // the submit, not the job hours later.
    compiled_plan(&spec).map_err(|e| format!("rejected spec: {e}"))?;
    let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
    if let Some(dir) = shared.job_dir(id) {
        crate::wire::write_atomic(&dir.join("spec.bin"), &spec.to_wire())
            .map_err(|e| format!("persisting spec: {e}"))?;
    }
    let handle = Arc::new(JobHandle::new(id, spec));
    lock_clean(&shared.jobs).insert(id, Arc::clone(&handle));
    shared.enqueue(id);
    Ok(id)
}

fn submit_infer(shared: &Arc<Shared>, spec: InferSpec) -> Result<u64, String> {
    compiled_infer_plan(&spec).map_err(|e| format!("rejected spec: {e}"))?;
    if spec.model_job != 0 {
        // Cross-check against the referenced training job now, not hours
        // later in the worker: the model must exist, be finished, and have
        // been trained under a compatible spec (same topology and — the
        // FHE-critical part — the same seed, or the weight ciphertexts
        // would not decrypt under this session's keys).
        if shared.data_dir.is_none() {
            return Err(format!(
                "model_job {} requires a server data dir (models are not persisted)",
                spec.model_job
            ));
        }
        let model = lock_clean(&shared.jobs)
            .get(&spec.model_job)
            .cloned()
            .ok_or_else(|| format!("model job {} is unknown", spec.model_job))?;
        let tspec = model
            .train_spec()
            .ok_or_else(|| format!("model job {} is not a training job", spec.model_job))?
            .clone();
        let state = model.status().state;
        if state != JobState::Completed {
            return Err(format!(
                "model job {} has no model yet (state: {})",
                spec.model_job,
                state.name()
            ));
        }
        if tspec.dims != spec.dims {
            return Err(format!(
                "dims {:?} do not match model job {}'s dims {:?}",
                spec.dims, spec.model_job, tspec.dims
            ));
        }
        if tspec.backend != spec.backend {
            return Err(format!("backend does not match model job {}'s", spec.model_job));
        }
        if tspec.profile != spec.profile {
            return Err(format!("profile does not match model job {}'s", spec.model_job));
        }
        if tspec.seed != spec.seed {
            return Err(format!(
                "seed {} does not match model job {}'s seed {} (the model only decrypts under the training key)",
                spec.seed, spec.model_job, tspec.seed
            ));
        }
        if tspec.softmax_bits != spec.softmax_bits {
            return Err(format!("softmax_bits does not match model job {}'s", spec.model_job));
        }
    }
    let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
    if let Some(dir) = shared.job_dir(id) {
        crate::wire::write_atomic(&dir.join("infer.bin"), &spec.to_wire())
            .map_err(|e| format!("persisting spec: {e}"))?;
    }
    let lane = spec.coalesce.then(|| spec.lane_label());
    let handle = Arc::new(JobHandle::new_infer(id, spec));
    lock_clean(&shared.jobs).insert(id, Arc::clone(&handle));
    // lane membership must exist before the queue token is visible, or a
    // fast worker would run the job solo
    if let Some(lane) = lane {
        shared.enlane(lane, id);
    }
    shared.enqueue(id);
    Ok(id)
}

/// What one dispatched job run produced (training and inference unified so
/// the worker's persistence/panic handling is one code path).
enum RanOutcome {
    Train(RunOutcome),
    Infer(InferOutcome),
}

fn panic_text(p: Box<dyn std::any::Any + Send>) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".into())
}

fn worker_loop(shared: &Arc<Shared>) {
    loop {
        let id = {
            let mut queue = lock_clean(&shared.queue);
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(id) = queue.pop_front() {
                    break id;
                }
                queue = shared
                    .queue_cv
                    .wait(queue)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        let handle = match lock_clean(&shared.jobs).get(&id) {
            Some(h) => Arc::clone(h),
            None => continue,
        };
        // Coalesce inference jobs are claimed through their scoring lane,
        // not the bare queue token: this token may pull a whole batch group
        // along, or find its job already scored by an earlier group.
        if let Some(spec) = handle.infer_spec().filter(|s| s.coalesce) {
            let (lane, batch) = (spec.lane_label(), spec.batch);
            run_coalesced(shared, id, &lane, batch);
            continue;
        }
        if handle.cancel.load(Ordering::SeqCst) {
            handle.update(|st| st.state = JobState::Cancelled);
            continue;
        }
        let dir = shared.job_dir(id);
        // A panic anywhere inside a job run (engine, trainer, injected
        // fault) must fail *that job* and leave the worker serving the
        // queue — one tenant's crash is not a denial of service for the
        // rest.
        let ran = catch_unwind(AssertUnwindSafe(|| match &handle.payload {
            JobPayload::Train(_) => {
                run_job(&handle, dir.as_deref(), &RunOptions::default()).map(RanOutcome::Train)
            }
            JobPayload::Infer(_) => run_infer_job(&handle, dir.as_deref()).map(RanOutcome::Infer),
        }));
        match ran {
            Ok(Ok(RanOutcome::Train(RunOutcome::Completed(result)))) => {
                if let Some(dir) = &dir {
                    let _ = crate::wire::write_atomic(
                        &dir.join("result.bin"),
                        &result.to_wire(),
                    );
                    // The checkpoint is dead weight once the result exists
                    // (the final model persists separately in model.bin).
                    let _ = std::fs::remove_file(checkpoint_path(dir));
                }
                lock_clean(&shared.results).insert(id, StoredResult::Train(result));
            }
            Ok(Ok(RanOutcome::Infer(InferOutcome::Completed(result)))) => {
                if let Some(dir) = &dir {
                    let _ = crate::wire::write_atomic(
                        &dir.join("result.bin"),
                        &result.to_wire(),
                    );
                }
                lock_clean(&shared.results).insert(id, StoredResult::Infer(result));
            }
            Ok(Ok(RanOutcome::Train(RunOutcome::Cancelled | RunOutcome::Halted)))
            | Ok(Ok(RanOutcome::Infer(InferOutcome::Cancelled))) => {}
            Ok(Err(e)) => handle.update(|st| {
                st.state = JobState::Failed;
                st.message = e.to_string();
            }),
            Err(panic) => {
                let msg = panic_text(panic);
                handle.update(|st| {
                    st.state = JobState::Failed;
                    st.message = format!("worker panicked: {msg}");
                });
            }
        }
    }
}

/// Claim and run one coalesced batch group from a scoring lane. `id` is
/// the queue token that woke this worker; if it is no longer in the lane,
/// an earlier group already scored it and there is nothing to do.
/// Otherwise the worker drains up to `MAX_GROUP_SLOTS / batch` compatible
/// jobs (FIFO, always including `id`) under the lanes lock — the drain is
/// the claim, so two workers can never run the same job — and scores them
/// in one shared engine batch.
fn run_coalesced(shared: &Arc<Shared>, id: u64, lane: &str, batch: u64) {
    let claimed: Vec<u64> = {
        let mut lanes = lock_clean(&shared.lanes);
        let Some(deque) = lanes.get_mut(lane) else { return };
        if !deque.contains(&id) {
            return;
        }
        let cap = (MAX_GROUP_SLOTS / batch.max(1)).max(1) as usize;
        let take = deque.len().min(cap);
        deque.drain(..take).collect()
    };

    let mut members: Vec<Arc<JobHandle>> = Vec::with_capacity(claimed.len());
    for cid in claimed {
        let Some(h) = lock_clean(&shared.jobs).get(&cid).cloned() else { continue };
        if h.cancel.load(Ordering::SeqCst) {
            h.update(|st| st.state = JobState::Cancelled);
            continue;
        }
        members.push(h);
    }
    if members.is_empty() {
        return;
    }

    let group = shared.next_group.fetch_add(1, Ordering::SeqCst);
    let jobs_root = shared.data_dir.as_ref().map(|d| d.join("jobs"));
    let refs: Vec<&JobHandle> = members.iter().map(Arc::as_ref).collect();
    let ran =
        catch_unwind(AssertUnwindSafe(|| run_infer_group(&refs, jobs_root.as_deref(), group)));
    match ran {
        Ok(Ok((outcomes, stats))) => {
            for (cid, outcome) in outcomes {
                if let InferOutcome::Completed(result) = outcome {
                    if let Some(dir) = shared.job_dir(cid) {
                        let _ =
                            crate::wire::write_atomic(&dir.join("result.bin"), &result.to_wire());
                    }
                    lock_clean(&shared.results).insert(cid, StoredResult::Infer(result));
                }
            }
            let mut all = lock_clean(&shared.lane_stats);
            let entry = all
                .entry(lane.to_string())
                .or_insert_with(|| metrics::LaneView { lane: lane.to_string(), ..Default::default() });
            entry.groups += 1;
            entry.passes += stats.passes;
            entry.filled_slots += stats.filled_slots;
            entry.total_slots += stats.total_slots;
            entry.seconds += stats.seconds;
            entry.images += stats.images;
        }
        Ok(Err(e)) => {
            let msg = e.to_string();
            fail_members(&members, &msg);
        }
        Err(panic) => {
            let msg = format!("worker panicked: {}", panic_text(panic));
            fail_members(&members, &msg);
        }
    }
}

/// Degrade every non-terminal member of a failed batch group to `Failed`.
/// Members already `Cancelled` mid-group keep that terminal state.
fn fail_members(members: &[Arc<JobHandle>], msg: &str) {
    for h in members {
        h.update(|st| {
            if st.state != JobState::Cancelled {
                st.state = JobState::Failed;
                st.message = msg.to_string();
            }
        });
    }
}
