//! The `glyph serve` server: accept loop, job queue, worker pool,
//! startup recovery.
//!
//! Threading model: one non-blocking accept thread (polls the shutdown
//! flag between accepts), one short-lived thread per connection, and N
//! worker threads popping job ids off a `Condvar`-guarded queue. Workers
//! own the engine/session for the job they run — nothing homomorphic is
//! shared across threads.
//!
//! Durability: with a data directory, every submitted spec is persisted
//! to `jobs/<id>/spec.bin` before the submit reply, checkpoints land in
//! the same directory every K steps, and results in `result.bin`. On
//! startup the server scans `jobs/*`: finished jobs are loaded into the
//! result cache, unfinished ones are re-enqueued and resume from their
//! latest checkpoint inside [`run_job`]. `kill -9` mid-epoch therefore
//! loses at most K steps of work and zero bytes of determinism.

use super::job::{checkpoint_path, compiled_plan, run_job, JobHandle, RunOptions, RunOutcome};
use super::metrics;
use super::protocol::{read_frame, write_frame, JobResult, JobSpec, JobState, Request, Response};
use crate::wire::WireCodec;
use std::collections::{HashMap, VecDeque};
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration. `addr` may use port 0 to let the OS pick;
/// the bound address is reported by [`RunningServer::addr`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Durable state root (`jobs/<id>/{spec,checkpoint,result}.bin`).
    /// `None` disables persistence (jobs are memory-only, no resume).
    pub data_dir: Option<PathBuf>,
    /// Worker threads; clamped to at least 1.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { addr: "127.0.0.1:0".into(), data_dir: None, workers: 1 }
    }
}

struct Shared {
    jobs: Mutex<HashMap<u64, Arc<JobHandle>>>,
    queue: Mutex<VecDeque<u64>>,
    queue_cv: Condvar,
    next_id: AtomicU64,
    shutdown: AtomicBool,
    data_dir: Option<PathBuf>,
    results: Mutex<HashMap<u64, JobResult>>,
    started: Instant,
}

impl Shared {
    fn job_dir(&self, id: u64) -> Option<PathBuf> {
        self.data_dir.as_ref().map(|d| d.join("jobs").join(id.to_string()))
    }

    fn enqueue(&self, id: u64) {
        self.queue.lock().unwrap().push_back(id);
        self.queue_cv.notify_one();
    }
}

/// A started server. Dropping it does NOT stop the threads; call
/// [`RunningServer::shutdown`] then [`RunningServer::wait`].
pub struct RunningServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl RunningServer {
    /// Bind, recover durable state, and spawn the accept + worker threads.
    pub fn start(cfg: ServeConfig) -> io::Result<RunningServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;

        let shared = Arc::new(Shared {
            jobs: Mutex::new(HashMap::new()),
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            data_dir: cfg.data_dir.clone(),
            results: Mutex::new(HashMap::new()),
            started: Instant::now(),
        });

        if let Some(dir) = &cfg.data_dir {
            recover(&shared, dir)?;
        }

        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();

        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(&listener, &shared))
        };

        Ok(RunningServer { addr, shared, accept: Some(accept), workers: workers })
    }

    /// The bound listen address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask every thread to stop. Workers finish the job they are running
    /// and skip the rest of the queue.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue_cv.notify_all();
    }

    /// Join the accept thread and all workers.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Scan `dir/jobs/*` and rebuild in-memory state: completed jobs feed the
/// result cache, everything else goes back on the queue (and will resume
/// from its checkpoint, if one exists).
fn recover(shared: &Arc<Shared>, dir: &Path) -> io::Result<()> {
    let jobs_root = dir.join("jobs");
    if !jobs_root.is_dir() {
        return Ok(());
    }
    let mut max_id = 0u64;
    let mut pending = Vec::new();
    for entry in std::fs::read_dir(&jobs_root)? {
        let entry = entry?;
        let Ok(id) = entry.file_name().to_string_lossy().parse::<u64>() else {
            continue;
        };
        let spec_bytes = match std::fs::read(entry.path().join("spec.bin")) {
            Ok(b) => b,
            Err(_) => continue,
        };
        let Ok(spec) = JobSpec::from_wire(&spec_bytes, &()) else {
            continue;
        };
        max_id = max_id.max(id);
        let handle = Arc::new(JobHandle::new(id, spec));
        let result_bytes = std::fs::read(entry.path().join("result.bin")).ok();
        if let Some(result) =
            result_bytes.and_then(|b| JobResult::from_wire(&b, &()).ok())
        {
            handle.update(|st| {
                st.state = JobState::Completed;
                st.step = result.steps;
                st.resumes = result.resumes;
                st.live_ops = result.ops;
            });
            shared.results.lock().unwrap().insert(id, result);
            shared.jobs.lock().unwrap().insert(id, handle);
        } else {
            shared.jobs.lock().unwrap().insert(id, Arc::clone(&handle));
            pending.push(id);
        }
    }
    shared.next_id.store(max_id + 1, Ordering::SeqCst);
    pending.sort_unstable();
    for id in pending {
        shared.enqueue(id);
    }
    Ok(())
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nonblocking(false);
                let shared = Arc::clone(shared);
                std::thread::spawn(move || handle_conn(&shared, stream));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

fn handle_conn(shared: &Arc<Shared>, mut stream: TcpStream) {
    while let Ok(Some(frame)) = read_frame(&mut stream) {
        let resp = match Request::from_wire(&frame, &()) {
            Ok(req) => dispatch(shared, req),
            Err(e) => Response::Error(format!("bad request frame: {e}")),
        };
        let closing = matches!(resp, Response::ShuttingDown);
        if write_frame(&mut stream, &resp.to_wire()).is_err() || closing {
            return;
        }
    }
}

fn dispatch(shared: &Arc<Shared>, req: Request) -> Response {
    match req {
        Request::Submit(spec) => match submit(shared, spec) {
            Ok(id) => Response::Submitted { id },
            Err(msg) => Response::Error(msg),
        },
        Request::Status { id } => match shared.jobs.lock().unwrap().get(&id) {
            Some(h) => Response::Status(h.status()),
            None => Response::Error(format!("unknown job {id}")),
        },
        Request::Cancel { id } => {
            let handle = shared.jobs.lock().unwrap().get(&id).cloned();
            match handle {
                Some(h) => {
                    h.cancel.store(true, Ordering::SeqCst);
                    // A queued job never reaches its worker-side cancel
                    // check promptly, so flip the state here.
                    h.update(|st| {
                        if st.state == JobState::Queued {
                            st.state = JobState::Cancelled;
                        }
                    });
                    Response::Cancelled { id }
                }
                None => Response::Error(format!("unknown job {id}")),
            }
        }
        Request::FetchResult { id } => {
            if let Some(r) = shared.results.lock().unwrap().get(&id) {
                return Response::Result(r.clone());
            }
            match shared.jobs.lock().unwrap().get(&id) {
                Some(h) => Response::Error(format!(
                    "job {id} not completed (state: {})",
                    h.status().state.name()
                )),
                None => Response::Error(format!("unknown job {id}")),
            }
        }
        Request::Metrics => {
            let mut statuses: Vec<_> =
                shared.jobs.lock().unwrap().values().map(|h| h.status()).collect();
            statuses.sort_by_key(|s| s.id);
            Response::Metrics(metrics::render(
                shared.started.elapsed().as_secs_f64(),
                &statuses,
            ))
        }
        Request::Ping => Response::Pong,
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.queue_cv.notify_all();
            Response::ShuttingDown
        }
    }
}

fn submit(shared: &Arc<Shared>, spec: JobSpec) -> Result<u64, String> {
    // Compile the plan up front: a spec the planner rejects should fail
    // the submit, not the job hours later.
    compiled_plan(&spec).map_err(|e| format!("rejected spec: {e}"))?;
    let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
    let handle = Arc::new(JobHandle::new(id, spec));
    if let Some(dir) = shared.job_dir(id) {
        crate::wire::write_atomic(&dir.join("spec.bin"), &handle.spec.to_wire())
            .map_err(|e| format!("persisting spec: {e}"))?;
    }
    shared.jobs.lock().unwrap().insert(id, Arc::clone(&handle));
    shared.enqueue(id);
    Ok(id)
}

fn worker_loop(shared: &Arc<Shared>) {
    loop {
        let id = {
            let mut queue = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(id) = queue.pop_front() {
                    break id;
                }
                queue = shared.queue_cv.wait(queue).unwrap();
            }
        };
        let handle = match shared.jobs.lock().unwrap().get(&id) {
            Some(h) => Arc::clone(h),
            None => continue,
        };
        if handle.cancel.load(Ordering::SeqCst) {
            handle.update(|st| st.state = JobState::Cancelled);
            continue;
        }
        let dir = shared.job_dir(id);
        match run_job(&handle, dir.as_deref(), &RunOptions::default()) {
            Ok(RunOutcome::Completed(result)) => {
                if let Some(dir) = &dir {
                    let _ = crate::wire::write_atomic(
                        &dir.join("result.bin"),
                        &result.to_wire(),
                    );
                    // The checkpoint is dead weight once the result exists.
                    let _ = std::fs::remove_file(checkpoint_path(dir));
                }
                shared.results.lock().unwrap().insert(id, result);
            }
            Ok(RunOutcome::Cancelled) => {}
            Ok(RunOutcome::Halted) => {} // test-only option, unused here
            Err(e) => handle.update(|st| {
                st.state = JobState::Failed;
                st.message = e.to_string();
            }),
        }
    }
}
