//! Prometheus text exposition for the serve layer.
//!
//! The interesting series is the pair `glyph_job_ops{kind="live"}` /
//! `glyph_job_ops{kind="predicted"}`: compiled plans price executions
//! exactly (plan totals × steps), so live−predicted drift is an SLA and
//! billing signal that costs nothing to produce.
//! `relin`/`mod_switch` have no plan-level prediction (they depend on the
//! MAC engine's laziness), so the drift gauge ignores them while both
//! series still expose them.

use super::protocol::{JobKind, JobStatus};
use crate::coordinator::metrics::OpSnapshot;
use std::fmt::Write as _;

/// Counters excluded from the drift gauge (no plan-level prediction).
pub const UNPREDICTED_OPS: [&str; 2] = ["relin", "mod_switch"];

/// Sum of |live − predicted| over the predicted counters.
pub fn op_drift(live: &OpSnapshot, predicted: &OpSnapshot) -> u64 {
    live.diff_ignoring(predicted, &UNPREDICTED_OPS)
        .iter()
        .map(|&(_, a, b)| a.abs_diff(b))
        .sum()
}

/// One scoring lane's accumulated coalescing stats, as rendered into the
/// per-lane gauges. A lane is a batch-compatibility class of coalesce
/// inference jobs ([`crate::serve::protocol::InferSpec::lane_label`]); its
/// counters aggregate every batch group the lane has run.
#[derive(Clone, Debug, Default)]
pub struct LaneView {
    /// The lane-compatibility label (metric label `lane`).
    pub lane: String,
    /// Batch groups the lane has completed.
    pub groups: u64,
    /// Shared forward passes across all groups.
    pub passes: u64,
    /// Slots that carried a real image, summed over passes.
    pub filled_slots: u64,
    /// Slots available (`Σ passes × group width`).
    pub total_slots: u64,
    /// Wall-clock spent inside shared passes.
    pub seconds: f64,
    /// Real images scored through the lane.
    pub images: u64,
}

/// Render the full exposition. `statuses` should be sorted by job id and
/// `lanes` by label for stable scrapes.
pub fn render(uptime_seconds: f64, statuses: &[JobStatus], lanes: &[LaneView]) -> String {
    let mut out = String::new();
    let w = &mut out;
    let _ = writeln!(w, "# HELP glyph_uptime_seconds Seconds since the server started.");
    let _ = writeln!(w, "# TYPE glyph_uptime_seconds gauge");
    let _ = writeln!(w, "glyph_uptime_seconds {uptime_seconds:.3}");

    let _ = writeln!(w, "# HELP glyph_jobs Jobs by lifecycle state.");
    let _ = writeln!(w, "# TYPE glyph_jobs gauge");
    for state in ["queued", "running", "completed", "failed", "cancelled"] {
        let n = statuses.iter().filter(|s| s.state.name() == state).count();
        let _ = writeln!(w, "glyph_jobs{{state=\"{state}\"}} {n}");
    }

    let _ = writeln!(w, "# HELP glyph_job_steps Minibatch steps completed by a job.");
    let _ = writeln!(w, "# TYPE glyph_job_steps counter");
    let _ = writeln!(w, "# HELP glyph_job_steps_planned Total steps the job will run.");
    let _ = writeln!(w, "# TYPE glyph_job_steps_planned gauge");
    let _ = writeln!(w, "# HELP glyph_job_checkpoints Checkpoints persisted for a job.");
    let _ = writeln!(w, "# TYPE glyph_job_checkpoints counter");
    let _ = writeln!(w, "# HELP glyph_job_resumes Times a job resumed from a checkpoint.");
    let _ = writeln!(w, "# TYPE glyph_job_resumes counter");
    for s in statuses {
        let labels = format!("job=\"{}\",tenant=\"{}\"", s.id, s.tenant);
        let _ = writeln!(w, "glyph_job_steps{{{labels}}} {}", s.step);
        let _ = writeln!(w, "glyph_job_steps_planned{{{labels}}} {}", s.total_steps);
        let _ = writeln!(w, "glyph_job_checkpoints{{{labels}}} {}", s.checkpoints);
        let _ = writeln!(w, "glyph_job_resumes{{{labels}}} {}", s.resumes);
    }

    let infer: Vec<&JobStatus> = statuses.iter().filter(|s| s.kind == JobKind::Infer).collect();
    if !infer.is_empty() {
        let _ = writeln!(w, "# HELP glyph_infer_images_total Images scored by an inference job.");
        let _ = writeln!(w, "# TYPE glyph_infer_images_total counter");
        let _ = writeln!(w, "# HELP glyph_infer_seconds Scoring wall-clock of an inference job.");
        let _ = writeln!(w, "# TYPE glyph_infer_seconds counter");
        let _ = writeln!(
            w,
            "# HELP glyph_infer_latency_seconds Amortized per-image scoring latency."
        );
        let _ = writeln!(w, "# TYPE glyph_infer_latency_seconds gauge");
        for s in &infer {
            let labels = format!("job=\"{}\",tenant=\"{}\"", s.id, s.tenant);
            let _ = writeln!(w, "glyph_infer_images_total{{{labels}}} {}", s.images);
            let _ = writeln!(w, "glyph_infer_seconds{{{labels}}} {:.6}", s.seconds);
            let latency = if s.images > 0 { s.seconds / s.images as f64 } else { 0.0 };
            let _ = writeln!(w, "glyph_infer_latency_seconds{{{labels}}} {latency:.6}");
        }
    }

    if !lanes.is_empty() {
        let _ = writeln!(
            w,
            "# HELP glyph_lane_groups_total Coalesced batch groups a scoring lane has run."
        );
        let _ = writeln!(w, "# TYPE glyph_lane_groups_total counter");
        let _ = writeln!(
            w,
            "# HELP glyph_lane_images_total Real images scored through a lane's shared batches."
        );
        let _ = writeln!(w, "# TYPE glyph_lane_images_total counter");
        let _ = writeln!(
            w,
            "# HELP glyph_lane_fill_ratio Occupied fraction of the lane's shared batch slots \
             (1 = every coalesced pass ran full)."
        );
        let _ = writeln!(w, "# TYPE glyph_lane_fill_ratio gauge");
        let _ = writeln!(
            w,
            "# HELP glyph_lane_coalesced_latency_seconds Amortized per-image latency of the \
             lane's shared passes."
        );
        let _ = writeln!(w, "# TYPE glyph_lane_coalesced_latency_seconds gauge");
        for l in lanes {
            let labels = format!("lane=\"{}\"", l.lane);
            let _ = writeln!(w, "glyph_lane_groups_total{{{labels}}} {}", l.groups);
            let _ = writeln!(w, "glyph_lane_images_total{{{labels}}} {}", l.images);
            let fill = if l.total_slots > 0 {
                l.filled_slots as f64 / l.total_slots as f64
            } else {
                0.0
            };
            let _ = writeln!(w, "glyph_lane_fill_ratio{{{labels}}} {fill:.6}");
            let latency = if l.images > 0 { l.seconds / l.images as f64 } else { 0.0 };
            let _ = writeln!(w, "glyph_lane_coalesced_latency_seconds{{{labels}}} {latency:.6}");
        }
    }

    let _ = writeln!(
        w,
        "# HELP glyph_job_ops Homomorphic op counters per job: live execution vs. the \
         compiled plan's prediction."
    );
    let _ = writeln!(w, "# TYPE glyph_job_ops counter");
    for s in statuses {
        for (kind, snap) in [("live", &s.live_ops), ("predicted", &s.predicted_ops)] {
            for (op, v) in snap.fields() {
                let _ = writeln!(
                    w,
                    "glyph_job_ops{{job=\"{}\",tenant=\"{}\",op=\"{op}\",kind=\"{kind}\"}} {v}",
                    s.id, s.tenant
                );
            }
        }
    }

    let _ = writeln!(
        w,
        "# HELP glyph_job_op_drift Sum of |live-predicted| over plan-predicted op counters \
         (0 = execution matches the plan exactly)."
    );
    let _ = writeln!(w, "# TYPE glyph_job_op_drift gauge");
    for s in statuses {
        let _ = writeln!(
            w,
            "glyph_job_op_drift{{job=\"{}\",tenant=\"{}\"}} {}",
            s.id,
            s.tenant,
            op_drift(&s.live_ops, &s.predicted_ops)
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::JobState;

    #[test]
    fn renders_drift_and_states() {
        // relin is unpredicted: it must not count as drift
        let live = OpSnapshot { mult_cc: 10, relin: 3, ..Default::default() };
        let predicted = OpSnapshot { mult_cc: 10, ..Default::default() };
        let status = JobStatus {
            id: 1,
            tenant: "acme".into(),
            kind: JobKind::Train,
            state: JobState::Running,
            epoch: 0,
            step: 5,
            total_steps: 24,
            checkpoints: 1,
            resumes: 0,
            live_ops: live,
            predicted_ops: predicted,
            images: 0,
            seconds: 0.0,
            group: 0,
            message: String::new(),
        };
        assert_eq!(op_drift(&live, &predicted), 0);
        let text = render(1.5, &[status.clone()], &[]);
        assert!(text.contains("glyph_jobs{state=\"running\"} 1"), "{text}");
        assert!(text.contains(
            "glyph_job_ops{job=\"1\",tenant=\"acme\",op=\"mult_cc\",kind=\"live\"} 10"
        ));
        assert!(text.contains("glyph_job_op_drift{job=\"1\",tenant=\"acme\"} 0"));
        // train-only scrapes carry no inference series at all
        assert!(!text.contains("glyph_infer_images_total"), "{text}");
        let mut drifted = live;
        drifted.mult_cc = 12;
        assert_eq!(op_drift(&drifted, &predicted), 2);
    }

    #[test]
    fn renders_infer_gauges() {
        let status = JobStatus {
            id: 7,
            tenant: "acme".into(),
            kind: JobKind::Infer,
            state: JobState::Completed,
            epoch: 0,
            step: 4,
            total_steps: 4,
            checkpoints: 0,
            resumes: 0,
            live_ops: OpSnapshot::default(),
            predicted_ops: OpSnapshot::default(),
            images: 32,
            seconds: 1.6,
            group: 0,
            message: String::new(),
        };
        let text = render(2.0, &[status], &[]);
        assert!(text.contains("glyph_infer_images_total{job=\"7\",tenant=\"acme\"} 32"), "{text}");
        assert!(text.contains("glyph_infer_seconds{job=\"7\",tenant=\"acme\"} 1.600000"), "{text}");
        assert!(
            text.contains("glyph_infer_latency_seconds{job=\"7\",tenant=\"acme\"} 0.050000"),
            "{text}"
        );
        // no coalescing lanes → no lane series at all
        assert!(!text.contains("glyph_lane_fill_ratio"), "{text}");
    }

    #[test]
    fn renders_lane_gauges() {
        let lane = LaneView {
            lane: "clear-default-d16x8x4-b2-sm3-digits-seed9-model0".into(),
            groups: 3,
            passes: 8,
            filled_slots: 48,
            total_slots: 64,
            seconds: 1.2,
            images: 48,
        };
        let text = render(2.0, &[], &[lane]);
        let labels = "lane=\"clear-default-d16x8x4-b2-sm3-digits-seed9-model0\"";
        assert!(text.contains(&format!("glyph_lane_groups_total{{{labels}}} 3")), "{text}");
        assert!(text.contains(&format!("glyph_lane_images_total{{{labels}}} 48")), "{text}");
        assert!(text.contains(&format!("glyph_lane_fill_ratio{{{labels}}} 0.750000")), "{text}");
        assert!(
            text.contains(&format!("glyph_lane_coalesced_latency_seconds{{{labels}}} 0.025000")),
            "{text}"
        );
    }
}
