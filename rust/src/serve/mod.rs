//! `glyph serve` — the multi-tenant training job service (ROADMAP item 2).
//!
//! The paper's deployment model is non-interactive outsourced training:
//! clients upload encrypted data once, a server trains for days, the
//! clients come back for the model. This module is that server:
//!
//! * [`protocol`] — the length-prefixed TCP request/response protocol
//!   (`submit`, `status`, `cancel`, `fetch-result`, `metrics`,
//!   `shutdown`), every message a [`crate::wire::WireCodec`] frame.
//! * [`job`] — the job runner: builds the engine/network/dataset from a
//!   [`protocol::JobSpec`] deterministically, drives
//!   [`crate::train::Trainer`] epoch loops in checkpoint-bounded chunks,
//!   persists a [`crate::wire::Checkpoint`] every K steps (atomic
//!   write+rename), and resumes byte-identically after a crash.
//! * [`server`] — `TcpListener` accept loop + job queue + N worker
//!   threads, with startup recovery that re-enqueues every incomplete job
//!   found in the data directory.
//! * [`metrics`] — Prometheus text exposition built from the
//!   `OpCounter`/`Plan` machinery: per-job live counters next to the
//!   compiled plan's predictions (drift is a free SLA/billing signal —
//!   plans price executions exactly).
//! * [`client`] — a small blocking client used by the CLI subcommands,
//!   the smoke tests and the bench.

pub mod client;
pub mod job;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use client::{Fetched, ServeClient};
pub use job::{
    run_infer_group, run_infer_job, run_job, GroupStats, InferOutcome, JobError, JobHandle,
    JobPayload, RunOptions, RunOutcome,
};
pub use protocol::{
    read_frame, write_frame, InferResult, InferSpec, JobBackend, JobKind, JobResult, JobSpec,
    JobState, JobStatus, Request, Response, MAX_FRAME,
};
pub use server::{RunningServer, ServeConfig};

/// Poison-recovering lock: one worker thread panicking while holding a
/// shared mutex must degrade *that job*, never wedge every subsequent
/// request into a panic cascade. The guarded data (job maps, queues,
/// status structs) is always left in a consistent state by the writers —
/// each update is a single field-assignment batch — so recovering the
/// inner value is safe; the poison flag itself is the only casualty.
pub(crate) fn lock_clean<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}
