//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts (HLO text,
//! produced once by `make artifacts`) and execute them from Rust. Python is
//! never on this path — the binary is self-contained once `artifacts/`
//! exists.
//!
//! Used by the accuracy experiments (Figures 7/8: plaintext-domain quantized
//! training, exactly as the paper evaluates accuracy), by transfer-learning
//! pre-training, and by the optional XLA offload of batched NTT MACs.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT CPU client plus the artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifact_dir: PathBuf,
}

/// One compiled executable.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Default artifact directory: `$GLYPH_ARTIFACTS` or `./artifacts`.
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("GLYPH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(dir)
    }

    /// Load and compile `<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        Ok(Artifact { exe, name: name.to_string() })
    }
}

impl Artifact {
    /// Execute on f32 inputs; returns the flattened f32 outputs of the
    /// result tuple (artifacts are lowered with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).context("reshape input")
            })
            .collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("executing {}", self.name))?;
        let parts = result.decompose_tuple()?;
        parts
            .into_iter()
            .map(|lit| {
                // outputs may be f32 or i32/u8 predictions; convert via f32
                lit.convert(xla::PrimitiveType::F32)?
                    .to_vec::<f32>()
                    .context("output to_vec")
            })
            .collect()
    }
}

impl Artifact {
    /// Execute on u64 inputs (the ntt_mac kernel path); returns u64 outputs.
    pub fn run_u64(&self, inputs: &[(&[u64], &[usize])]) -> Result<Vec<Vec<u64>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).context("reshape input")
            })
            .collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("executing {}", self.name))?;
        let parts = result.decompose_tuple()?;
        parts.into_iter().map(|lit| lit.to_vec::<u64>().context("output to_vec")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke-tests require the artifacts; they are built by `make artifacts`
    /// before `cargo test` (the Makefile ordering).
    fn have_artifacts() -> bool {
        Path::new("artifacts/ntt_mac.hlo.txt").exists()
    }

    #[test]
    fn pjrt_client_comes_up() {
        let rt = Runtime::new("artifacts").expect("client");
        assert!(rt.client.device_count() >= 1);
    }

    #[test]
    fn ntt_mac_artifact_matches_native_ntt() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new("artifacts").unwrap();
        let art = rt.load("ntt_mac").unwrap();
        // The kernel computes acc' = (acc + a*b) mod p element-wise over
        // (BATCH, N) u64 arrays, exported with fixed shapes (8, 256) and
        // p = 469762049 (see python/compile/kernels/ntt_mac.py).
        let p = 469762049u64;
        let (bsz, n) = (8usize, 256usize);
        let a: Vec<u64> = (0..bsz * n).map(|i| (i as u64 * 7919 + 1) % p).collect();
        let b: Vec<u64> = (0..bsz * n).map(|i| (i as u64 * 104729 + 5) % p).collect();
        let acc: Vec<u64> = vec![3; bsz * n];
        let out = art
            .run_u64(&[(&a, &[bsz, n]), (&b, &[bsz, n]), (&acc, &[bsz, n])])
            .unwrap();
        for i in 0..(bsz * n) {
            let want = (3 + crate::math::mul_mod(a[i], b[i], p)) % p;
            assert_eq!(out[0][i], want, "i={i}");
        }
    }
}
