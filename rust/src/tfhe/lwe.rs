//! TLWE: scalar LWE ciphertexts over the discretized torus (torus32).
//!
//! The key type is a generic small-integer vector so the same ciphertext
//! machinery serves both TFHE binary keys and the LWE samples extracted from
//! BGV ciphertexts (whose key is the ternary RLWE secret's coefficient
//! vector) during cryptosystem switching.

use crate::math::rng::GlyphRng;

/// LWE secret key: small integer coefficients (binary for TFHE proper,
/// ternary for BGV-extracted keys).
#[derive(Clone)]
pub struct LweKey {
    pub s: Vec<i32>,
}

impl LweKey {
    /// Fresh binary key of dimension `n`.
    pub fn generate_binary(n: usize, rng: &mut GlyphRng) -> Self {
        LweKey { s: (0..n).map(|_| (rng.next_u64() & 1) as i32).collect() }
    }

    /// Key from explicit coefficients (e.g. a BGV secret's coefficients).
    pub fn from_coeffs(s: Vec<i32>) -> Self {
        LweKey { s }
    }

    pub fn dim(&self) -> usize {
        self.s.len()
    }
}

/// An LWE ciphertext `(a, b)` with phase `b − ⟨a, s⟩` (wrapping torus32).
#[derive(Clone, Debug)]
pub struct LweCiphertext {
    pub a: Vec<u32>,
    pub b: u32,
}

impl LweCiphertext {
    /// Noiseless embedding of a constant (the "trivial" ciphertext).
    pub fn trivial(mu: u32, n: usize) -> Self {
        LweCiphertext { a: vec![0; n], b: mu }
    }

    /// Encrypt torus element `mu` with Gaussian noise `alpha`.
    pub fn encrypt(mu: u32, key: &LweKey, alpha: f64, rng: &mut GlyphRng) -> Self {
        let n = key.dim();
        let a: Vec<u32> = (0..n).map(|_| rng.torus32()).collect();
        let mut b = mu.wrapping_add(rng.torus32_gaussian(alpha));
        for i in 0..n {
            b = b.wrapping_add((key.s[i] as i64 as u32).wrapping_mul(a[i]));
        }
        LweCiphertext { a, b }
    }

    /// Phase `b − ⟨a, s⟩`; decryption rounds this to the plaintext grid.
    pub fn phase(&self, key: &LweKey) -> u32 {
        let mut p = self.b;
        for i in 0..self.a.len() {
            p = p.wrapping_sub((key.s[i] as i64 as u32).wrapping_mul(self.a[i]));
        }
        p
    }

    pub fn dim(&self) -> usize {
        self.a.len()
    }

    pub fn add_assign(&mut self, o: &Self) {
        debug_assert_eq!(self.dim(), o.dim());
        for (x, &y) in self.a.iter_mut().zip(&o.a) {
            *x = x.wrapping_add(y);
        }
        self.b = self.b.wrapping_add(o.b);
    }

    pub fn sub_assign(&mut self, o: &Self) {
        debug_assert_eq!(self.dim(), o.dim());
        for (x, &y) in self.a.iter_mut().zip(&o.a) {
            *x = x.wrapping_sub(y);
        }
        self.b = self.b.wrapping_sub(o.b);
    }

    pub fn neg_assign(&mut self) {
        for x in self.a.iter_mut() {
            *x = x.wrapping_neg();
        }
        self.b = self.b.wrapping_neg();
    }

    /// Add a plaintext constant to the phase.
    pub fn add_constant(&mut self, mu: u32) {
        self.b = self.b.wrapping_add(mu);
    }

    /// Multiply by a small signed integer (noise grows by |k|).
    pub fn scalar_mul_assign(&mut self, k: i32) {
        let ku = k as i64 as u32;
        for x in self.a.iter_mut() {
            *x = x.wrapping_mul(ku);
        }
        self.b = self.b.wrapping_mul(ku);
    }

    /// Switch to a smaller power-of-two modulus `2^log2q` (used before blind
    /// rotation, where the exponent ring is Z_{2N}). Returns rescaled
    /// coefficients `round(x · 2^log2q / 2^32)` as integers in `[0, 2^log2q)`.
    pub fn rescale_to(&self, log2q: u32) -> (Vec<u32>, u32) {
        let mut a = vec![0u32; self.a.len()];
        let b = self.rescale_to_into(log2q, &mut a);
        (a, b)
    }

    /// Allocation-free [`Self::rescale_to`]: writes the rescaled mask into
    /// `out` (length = dim) and returns the rescaled body.
    pub fn rescale_to_into(&self, log2q: u32, out: &mut [u32]) -> u32 {
        debug_assert_eq!(out.len(), self.a.len());
        let shift = 32 - log2q;
        let half = 1u32 << (shift - 1);
        let mask = (1u64 << log2q) as u32 - 1; // log2q < 32 in all uses
        let f = |x: u32| -> u32 { ((x.wrapping_add(half)) >> shift) & mask };
        for (o, &x) in out.iter_mut().zip(&self.a) {
            *o = f(x);
        }
        f(self.b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn torus_dist(a: u32, b: u32) -> u32 {
        let d = a.wrapping_sub(b);
        d.min(d.wrapping_neg())
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let mut rng = GlyphRng::new(1);
        let key = LweKey::generate_binary(128, &mut rng);
        for msg in [0u32, 1 << 29, 1u32 << 31, (1u32 << 29).wrapping_neg()] {
            let ct = LweCiphertext::encrypt(msg, &key, 1e-7, &mut rng);
            assert!(torus_dist(ct.phase(&key), msg) < 1 << 20);
        }
    }

    #[test]
    fn homomorphic_addition() {
        let mut rng = GlyphRng::new(2);
        let key = LweKey::generate_binary(128, &mut rng);
        let m1 = 1u32 << 28;
        let m2 = 1u32 << 27;
        let mut c1 = LweCiphertext::encrypt(m1, &key, 1e-8, &mut rng);
        let c2 = LweCiphertext::encrypt(m2, &key, 1e-8, &mut rng);
        c1.add_assign(&c2);
        assert!(torus_dist(c1.phase(&key), m1.wrapping_add(m2)) < 1 << 20);
        c1.sub_assign(&c2);
        assert!(torus_dist(c1.phase(&key), m1) < 1 << 20);
    }

    #[test]
    fn trivial_has_exact_phase() {
        let key = LweKey::generate_binary(32, &mut GlyphRng::new(3));
        let ct = LweCiphertext::trivial(12345, 32);
        assert_eq!(ct.phase(&key), 12345);
    }

    #[test]
    fn scalar_mul_scales_phase() {
        let mut rng = GlyphRng::new(4);
        let key = LweKey::generate_binary(64, &mut rng);
        let m = 1u32 << 26;
        let mut ct = LweCiphertext::encrypt(m, &key, 1e-9, &mut rng);
        ct.scalar_mul_assign(5);
        assert!(torus_dist(ct.phase(&key), 5 * m) < 1 << 20);
        ct.scalar_mul_assign(-1);
        assert!(torus_dist(ct.phase(&key), (5 * m).wrapping_neg()) < 1 << 20);
    }

    #[test]
    fn ternary_key_roundtrip() {
        // Key = ternary coefficients, as in BGV-extracted samples.
        let mut rng = GlyphRng::new(5);
        let key = LweKey::from_coeffs((0..256).map(|_| rng.ternary() as i32).collect());
        let msg = 0xdead_0000u32;
        let ct = LweCiphertext::encrypt(msg, &key, 1e-8, &mut rng);
        assert!(torus_dist(ct.phase(&key), msg) < 1 << 20);
    }

    #[test]
    fn rescale_preserves_phase_approximately() {
        let mut rng = GlyphRng::new(6);
        let key = LweKey::generate_binary(64, &mut rng);
        let msg = 3u32 << 29;
        let ct = LweCiphertext::encrypt(msg, &key, 1e-9, &mut rng);
        let (a, b) = ct.rescale_to(11); // 2N = 2048
        // recompute phase in Z_2048
        let mut p = b as i64;
        for i in 0..64 {
            p -= key.s[i] as i64 * a[i] as i64;
        }
        let p = p.rem_euclid(2048) as u32;
        let want = (msg as u64 * 2048 / (1u64 << 32)) as u32;
        let d = (p as i32 - want as i32).rem_euclid(2048);
        assert!(d.min(2048 - d) < 40, "p={p} want={want}");
    }
}
