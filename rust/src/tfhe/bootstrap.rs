//! Blind rotation and programmable bootstrapping (PBS).
//!
//! The PBS evaluates an arbitrary (negacyclic) function of the phase while
//! resetting noise: the paper's gate bootstraps, its softmax lookup unit and
//! our 8-bit digit extraction in the cryptosystem switch are all PBS calls
//! with different test polynomials.

use super::lwe::{LweCiphertext, LweKey};
use super::params::TfheParams;
use super::scratch::{with_local_scratch, PbsScratch, RingScratch};
use super::tgsw::TrgswCiphertext;
use super::tlwe::{rotate_poly_into, rotate_sub_into, TrlweCiphertext, TrlweKey};
use crate::math::rng::GlyphRng;

/// A test polynomial for the PBS: `N` torus values, one per phase window of
/// width `1/2N` covering the positive half-torus `[0, 1/2)`; the negative
/// half is the negacyclic mirror `f(x + 1/2) = −f(x)`.
#[derive(Clone)]
pub struct TestPoly {
    pub coeffs: Vec<u32>,
}

impl TestPoly {
    /// Build from a window function: `f(w)` is the output for phases in
    /// `[w/2N, (w+1)/2N)`, `w ∈ 0..N`.
    pub fn from_fn(n: usize, f: impl Fn(usize) -> u32) -> Self {
        TestPoly { coeffs: (0..n).map(f).collect() }
    }

    /// Constant test polynomial: sign bootstrap with output ±mu.
    pub fn constant(n: usize, mu: u32) -> Self {
        TestPoly { coeffs: vec![mu; n] }
    }
}

/// Bootstrapping key: a TRGSW encryption of every LWE key bit, plus the
/// TRLWE key it rides on (kept private to the key owner; the server only
/// sees the TRGSW material).
pub struct BootstrapKey {
    pub params: TfheParams,
    pub bsk: Vec<TrgswCiphertext>,
    /// FFT plan shared with the TRLWE key (same ring degree).
    pub fft: std::sync::Arc<crate::math::fft::TorusFft>,
}

impl BootstrapKey {
    /// Generate for LWE key `lwe_key` under TRLWE key `trlwe_key`.
    pub fn generate(
        lwe_key: &LweKey,
        trlwe_key: &TrlweKey,
        params: &TfheParams,
        rng: &mut GlyphRng,
    ) -> Self {
        assert_eq!(trlwe_key.n, params.big_n);
        let bsk = lwe_key
            .s
            .iter()
            .map(|&si| {
                debug_assert!(si == 0 || si == 1, "blind rotation needs a binary LWE key");
                TrgswCiphertext::encrypt_scalar(si, trlwe_key, params, rng)
            })
            .collect();
        BootstrapKey { params: params.clone(), bsk, fft: trlwe_key.fft.clone() }
    }

    /// Blind rotation: `acc ← X^{−b̄ + Σ ā_i s_i} · testv` as a TRLWE.
    ///
    /// Runs on this thread's scratch; the result is cloned out. Hot callers
    /// should hold a [`PbsScratch`] and use [`Self::blind_rotate_scratch`].
    pub fn blind_rotate(&self, lwe: &LweCiphertext, testv: &TestPoly) -> TrlweCiphertext {
        with_local_scratch(|s| self.blind_rotate_scratch(lwe, testv, s).clone())
    }

    /// Reference blind rotation: the original allocating rotate/CMUX chain,
    /// kept verbatim so `tests/pbs_equivalence.rs` can assert the scratch
    /// pipeline is bit-exact against it.
    pub fn blind_rotate_reference(&self, lwe: &LweCiphertext, testv: &TestPoly) -> TrlweCiphertext {
        let n2 = 2 * self.params.big_n as u32;
        let log2n2 = n2.trailing_zeros();
        let (bara, barb) = lwe.rescale_to(log2n2);
        // acc = X^{-barb} * testv
        let neg_rot = (n2 - barb) % n2;
        let mut acc = TrlweCiphertext::trivial(&testv.coeffs).rotate(neg_rot as usize);
        for (i, bsk_i) in self.bsk.iter().enumerate() {
            if bara[i] == 0 {
                continue;
            }
            let rotated = acc.rotate(bara[i] as usize);
            acc = bsk_i.cmux(&rotated, &acc, &self.fft);
        }
        acc
    }

    /// Zero-allocation blind rotation: every CMUX reuses the scratch's digit
    /// buffer, FFT lane, FFT accumulators and ping-pong TRLWE accumulators;
    /// the rotated CMUX operand is formed by index arithmetic straight into
    /// the spare buffer. Steady state (scratch already sized for this ring)
    /// performs **zero** heap allocations — see `tests/zero_alloc.rs`.
    ///
    /// Returns a borrow of the final accumulator, valid until the scratch is
    /// next used. Bit-exact against [`Self::blind_rotate_reference`].
    pub fn blind_rotate_scratch<'s>(
        &self,
        lwe: &LweCiphertext,
        testv: &TestPoly,
        scratch: &'s mut PbsScratch,
    ) -> &'s TrlweCiphertext {
        let big_n = self.params.big_n;
        let n2 = 2 * big_n as u32;
        let log2n2 = n2.trailing_zeros();
        let (ring, bara) = scratch.ring_and_bara(big_n, lwe.dim());
        let RingScratch { dig, fft_lane, acc_a, acc_b, acc0, acc1, diff, .. } = ring;
        let barb = lwe.rescale_to_into(log2n2, bara);
        // acc0 = X^{−barb}·testv as a trivial ciphertext.
        let neg_rot = (n2 - barb) % n2;
        rotate_poly_into(&testv.coeffs, neg_rot as usize, &mut acc0.b);
        for x in acc0.a.iter_mut() {
            *x = 0;
        }
        for (i, bsk_i) in self.bsk.iter().enumerate() {
            let k = bara[i] as usize;
            if k == 0 {
                continue;
            }
            // diff = X^k·acc − acc; acc1 = acc + bsk_i ⊡ diff; swap.
            rotate_sub_into(&acc0.a, k, &mut diff.a);
            rotate_sub_into(&acc0.b, k, &mut diff.b);
            bsk_i.external_product_into(diff, &self.fft, dig, fft_lane, acc_a, acc_b, acc1);
            acc1.add_assign(acc0);
            std::mem::swap(&mut *acc0, &mut *acc1);
        }
        acc0
    }

    /// Programmable bootstrap: returns an LWE ciphertext (under the TRLWE
    /// extracted key, dimension N) of `f(phase)` with fresh noise.
    pub fn bootstrap(&self, lwe: &LweCiphertext, testv: &TestPoly) -> LweCiphertext {
        with_local_scratch(|s| self.bootstrap_with(lwe, testv, s))
    }

    /// [`Self::bootstrap`] against a caller-owned scratch (the pool workers'
    /// entry point).
    pub fn bootstrap_with(&self, lwe: &LweCiphertext, testv: &TestPoly, scratch: &mut PbsScratch) -> LweCiphertext {
        self.blind_rotate_scratch(lwe, testv, scratch).sample_extract(0)
    }

    /// Sign bootstrap: output `+mu` for phase ∈ [0, 1/2), `−mu` otherwise.
    pub fn bootstrap_sign(&self, lwe: &LweCiphertext, mu: u32) -> LweCiphertext {
        self.bootstrap(lwe, &TestPoly::constant(self.params.big_n, mu))
    }

    /// [`Self::bootstrap_sign`] against a caller-owned scratch and a
    /// pre-built constant test polynomial (batch paths hoist the test-poly
    /// allocation out of the per-item loop).
    pub fn bootstrap_sign_with(&self, lwe: &LweCiphertext, tv_mu: &TestPoly, scratch: &mut PbsScratch) -> LweCiphertext {
        self.bootstrap_with(lwe, tv_mu, scratch)
    }

    /// Batched programmable bootstrap: one blind rotation per input, all
    /// sharing `testv`, fanned across the global [`GlyphPool`] with one
    /// scratch per worker. Order-preserving and bit-exact against a
    /// sequential [`Self::bootstrap`] loop.
    ///
    /// [`GlyphPool`]: crate::coordinator::executor::GlyphPool
    pub fn bootstrap_many(&self, lwes: Vec<LweCiphertext>, testv: &TestPoly) -> Vec<LweCiphertext> {
        crate::coordinator::executor::GlyphPool::global()
            .map_with(lwes, |lwe, s| self.bootstrap_with(&lwe, testv, &mut s.pbs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn torus_dist(a: u32, b: u32) -> u32 {
        let d = a.wrapping_sub(b);
        d.min(d.wrapping_neg())
    }

    struct Fixture {
        params: TfheParams,
        lwe_key: LweKey,
        trlwe_key: TrlweKey,
        ext_key: LweKey,
        bk: BootstrapKey,
        rng: GlyphRng,
    }

    fn fixture(seed: u64) -> Fixture {
        let params = TfheParams::test_params();
        let mut rng = GlyphRng::new(seed);
        let lwe_key = LweKey::generate_binary(params.n, &mut rng);
        let trlwe_key = TrlweKey::generate(params.big_n, &mut rng);
        let ext_key = trlwe_key.extracted_lwe_key();
        let bk = BootstrapKey::generate(&lwe_key, &trlwe_key, &params, &mut rng);
        Fixture { params, lwe_key, trlwe_key, ext_key, bk, rng }
    }

    #[test]
    fn sign_bootstrap_positive_and_negative() {
        let mut f = fixture(20);
        let mu_out = 1u32 << 29;
        for (msg, want_positive) in [
            (1u32 << 29, true),
            (1u32 << 30, true),
            ((1u32 << 29).wrapping_neg(), false),
            ((1u32 << 30).wrapping_neg(), false),
        ] {
            let ct = LweCiphertext::encrypt(msg, &f.lwe_key, f.params.alpha_lwe, &mut f.rng);
            let out = f.bk.bootstrap_sign(&ct, mu_out);
            let ph = out.phase(&f.ext_key);
            let want = if want_positive { mu_out } else { mu_out.wrapping_neg() };
            assert!(torus_dist(ph, want) < 1 << 26, "msg={msg:#x} ph={ph:#x} want={want:#x}");
        }
        let _ = &f.trlwe_key;
    }

    #[test]
    fn bootstrap_output_noise_is_fresh() {
        // Bootstrapping a ciphertext with large-ish input noise still yields
        // an output close to ±mu (noise reset).
        let mut f = fixture(21);
        let msg = 1u32 << 29;
        let mut ct = LweCiphertext::encrypt(msg, &f.lwe_key, f.params.alpha_lwe, &mut f.rng);
        // add deliberate extra noise, well within the 1/8 margin
        ct.add_constant(1 << 24);
        let out = f.bk.bootstrap_sign(&ct, 1 << 29);
        assert!(torus_dist(out.phase(&f.ext_key), 1 << 29) < 1 << 26);
    }

    #[test]
    fn programmable_windows_select_values() {
        // Program a 4-level staircase over the positive half-torus and check
        // phases land on the right step.
        let mut f = fixture(22);
        let n = f.params.big_n;
        let tv = TestPoly::from_fn(n, |w| ((w * 4 / n) as u32) << 28);
        // message windows: phase = (i + 0.5)/8 for i in 0..4 (positive half)
        for i in 0..4u32 {
            let msg = (i * 2 + 1) << 28; // (2i+1)/16 of the torus
            let ct = LweCiphertext::encrypt(msg, &f.lwe_key, f.params.alpha_lwe, &mut f.rng);
            let out = f.bk.bootstrap(&ct, &tv);
            let ph = out.phase(&f.ext_key);
            let want = i << 28;
            assert!(torus_dist(ph, want) < 1 << 26, "i={i} ph={ph:#x} want={want:#x}");
        }
    }

    #[test]
    fn negacyclic_mirror_on_negative_half() {
        let mut f = fixture(23);
        let n = f.params.big_n;
        let tv = TestPoly::constant(n, 1 << 29);
        // phase in the negative half → −mu
        let msg = (3u32 << 29).wrapping_neg();
        let ct = LweCiphertext::encrypt(msg, &f.lwe_key, f.params.alpha_lwe, &mut f.rng);
        let out = f.bk.bootstrap(&ct, &tv);
        assert!(torus_dist(out.phase(&f.ext_key), (1u32 << 29).wrapping_neg()) < 1 << 26);
    }
}
