//! Reusable scratch state for the programmable-bootstrapping hot path.
//!
//! Every CMUX of a blind rotation used to heap-allocate its gadget digit
//! vectors, two forward-FFT buffers, FFT-domain accumulators and a
//! cloned/rotated TRLWE — ~10 allocations per CMUX, ~n·10 per bootstrap.
//! [`PbsScratch`] owns all of those buffers once, so a steady-state blind
//! rotation performs **zero** heap allocations per CMUX (asserted by
//! `tests/zero_alloc.rs` with a counting global allocator; see
//! EXPERIMENTS.md §Perf).
//!
//! A scratch is *not* thread-safe: each `GlyphPool` worker owns one, and the
//! single-threaded entry points borrow a thread-local instance via
//! [`with_local_scratch`]. Because the engine runs two TFHE instantiations
//! (gate ring and extraction ring), the scratch keeps one sized buffer set
//! per ring degree it has seen ([`RingScratch`]).

use super::tlwe::TrlweCiphertext;
use crate::math::fft::Cplx;
use std::cell::RefCell;

/// Exact-size buffers for one blind-rotation ring degree `n`.
pub struct RingScratch {
    /// Ring degree these buffers are sized for.
    pub n: usize,
    /// One gadget-digit polynomial, reused for every (component, level).
    pub dig: Vec<i32>,
    /// Forward-FFT lane of the current digit polynomial (N/2).
    pub fft_lane: Vec<Cplx>,
    /// FFT-domain accumulators for the TRLWE a/b components (N/2 each).
    pub acc_a: Vec<Cplx>,
    pub acc_b: Vec<Cplx>,
    /// Ping-pong blind-rotation accumulators.
    pub acc0: TrlweCiphertext,
    pub acc1: TrlweCiphertext,
    /// Rotated-difference CMUX operand (`X^k·acc − acc`).
    pub diff: TrlweCiphertext,
}

impl RingScratch {
    pub fn new(n: usize) -> Self {
        RingScratch {
            n,
            dig: vec![0i32; n],
            fft_lane: vec![Cplx::default(); n / 2],
            acc_a: vec![Cplx::default(); n / 2],
            acc_b: vec![Cplx::default(); n / 2],
            acc0: TrlweCiphertext::zero(n),
            acc1: TrlweCiphertext::zero(n),
            diff: TrlweCiphertext::zero(n),
        }
    }
}

/// All scratch state one executor (thread) needs to run bootstraps against
/// any number of ring degrees. Grows on first use, never shrinks; steady
/// state is allocation-free.
pub struct PbsScratch {
    rings: Vec<RingScratch>,
    /// Rescaled LWE mask ā ∈ Z_2N (blind-rotation exponents).
    bara: Vec<u32>,
}

impl PbsScratch {
    pub fn new() -> Self {
        PbsScratch { rings: Vec::new(), bara: Vec::new() }
    }

    /// Number of distinct ring degrees this scratch has been sized for.
    pub fn ring_count(&self) -> usize {
        self.rings.len()
    }

    /// The buffer set for ring degree `n`, created on first use.
    pub fn ring(&mut self, n: usize) -> &mut RingScratch {
        if let Some(i) = self.rings.iter().position(|r| r.n == n) {
            return &mut self.rings[i];
        }
        self.rings.push(RingScratch::new(n));
        self.rings.last_mut().expect("just pushed")
    }

    /// Split borrow: the ring-degree buffers *and* the ā buffer (resized to
    /// `bara_len`) in one call, so blind rotation can use both at once.
    pub fn ring_and_bara(&mut self, n: usize, bara_len: usize) -> (&mut RingScratch, &mut [u32]) {
        if !self.rings.iter().any(|r| r.n == n) {
            self.rings.push(RingScratch::new(n));
        }
        if self.bara.len() < bara_len {
            self.bara.resize(bara_len, 0);
        }
        let idx = self.rings.iter().position(|r| r.n == n).expect("ensured above");
        (&mut self.rings[idx], &mut self.bara[..bara_len])
    }
}

impl Default for PbsScratch {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    static LOCAL_SCRATCH: RefCell<PbsScratch> = RefCell::new(PbsScratch::new());
}

/// Run `f` with this thread's scratch. Only the *entry points* of the PBS
/// pipeline may call this (never code that can run inside it), so the
/// `RefCell` borrow is never reentrant.
pub fn with_local_scratch<R>(f: impl FnOnce(&mut PbsScratch) -> R) -> R {
    LOCAL_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_buffers_are_sized_and_cached() {
        let mut s = PbsScratch::new();
        {
            let r = s.ring(64);
            assert_eq!(r.dig.len(), 64);
            assert_eq!(r.fft_lane.len(), 32);
            assert_eq!(r.acc0.a.len(), 64);
        }
        let _ = s.ring(256);
        let _ = s.ring(64);
        assert_eq!(s.ring_count(), 2, "same degree must not re-allocate");
    }

    #[test]
    fn ring_and_bara_split_borrow() {
        let mut s = PbsScratch::new();
        let (r, bara) = s.ring_and_bara(128, 65);
        assert_eq!(r.n, 128);
        assert_eq!(bara.len(), 65);
        bara[0] = 7;
        r.dig[0] = -3;
        let (r2, bara2) = s.ring_and_bara(128, 65);
        assert_eq!(bara2[0], 7);
        assert_eq!(r2.dig[0], -3);
        assert_eq!(s.ring_count(), 1);
    }

    #[test]
    fn thread_local_scratch_is_reused() {
        let first = with_local_scratch(|s| {
            let _ = s.ring(32);
            s.ring_count()
        });
        let second = with_local_scratch(|s| {
            let _ = s.ring(32);
            s.ring_count()
        });
        assert_eq!(first, second);
    }
}
