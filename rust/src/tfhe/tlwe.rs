//! TRLWE: ring-LWE ciphertexts over the torus polynomial ring
//! `T_N[X]/(X^N+1)` with k = 1.
//!
//! TRLWE carries the blind-rotation accumulator and the packed outputs of
//! the TFHE→BGV functional key switch. `SampleExtract` (paper §4.2 step ➌)
//! pulls a single coefficient out as a scalar LWE ciphertext under the key's
//! coefficient vector.

use super::lwe::{LweCiphertext, LweKey};
use crate::math::fft::TorusFft;
use crate::math::rng::GlyphRng;
use std::sync::Arc;

/// TRLWE secret key: a binary polynomial, with its FFT cached.
pub struct TrlweKey {
    pub n: usize,
    pub s: Vec<i32>,
    pub fft: Arc<TorusFft>,
    s_fft: Vec<crate::math::fft::Cplx>,
}

impl TrlweKey {
    pub fn generate(n: usize, rng: &mut GlyphRng) -> Self {
        let s: Vec<i32> = (0..n).map(|_| (rng.next_u64() & 1) as i32).collect();
        let fft = Arc::new(TorusFft::new(n));
        let s_fft = fft.forward_int(&s);
        TrlweKey { n, s, fft, s_fft }
    }

    /// Key with explicit coefficients (e.g. the BGV ternary secret, for the
    /// torus32 packing step of the switch).
    pub fn from_coeffs(s: Vec<i32>) -> Self {
        let n = s.len();
        let fft = Arc::new(TorusFft::new(n));
        let s_fft = fft.forward_int(&s);
        TrlweKey { n, s, fft, s_fft }
    }

    /// The scalar-LWE key whose coefficients are this key's coefficients —
    /// the key under which `SampleExtract` outputs decrypt.
    pub fn extracted_lwe_key(&self) -> LweKey {
        LweKey::from_coeffs(self.s.clone())
    }
}

/// A TRLWE ciphertext `(a, b)`, phase `b − s·a` (negacyclic).
#[derive(Clone)]
pub struct TrlweCiphertext {
    pub a: Vec<u32>,
    pub b: Vec<u32>,
}

impl TrlweCiphertext {
    pub fn zero(n: usize) -> Self {
        TrlweCiphertext { a: vec![0; n], b: vec![0; n] }
    }

    /// Noiseless ciphertext of a plaintext polynomial.
    pub fn trivial(mu: &[u32]) -> Self {
        TrlweCiphertext { a: vec![0; mu.len()], b: mu.to_vec() }
    }

    /// Encrypt a torus polynomial.
    pub fn encrypt(mu: &[u32], key: &TrlweKey, alpha: f64, rng: &mut GlyphRng) -> Self {
        let n = key.n;
        debug_assert_eq!(mu.len(), n);
        let a: Vec<u32> = (0..n).map(|_| rng.torus32()).collect();
        // b = s·a + mu + e
        let sa = key.fft.negacyclic_mul_int_torus(&key.s, &a);
        let b: Vec<u32> = (0..n)
            .map(|i| sa[i].wrapping_add(mu[i]).wrapping_add(rng.torus32_gaussian(alpha)))
            .collect();
        TrlweCiphertext { a, b }
    }

    /// Phase polynomial `b − s·a`.
    pub fn phase(&self, key: &TrlweKey) -> Vec<u32> {
        let sa = key.fft.negacyclic_mul_int_torus(&key.s, &self.a);
        (0..key.n).map(|i| self.b[i].wrapping_sub(sa[i])).collect()
    }

    /// Phase using the cached key FFT (hot path for tests/diagnostics).
    pub fn phase_cached(&self, key: &TrlweKey) -> Vec<u32> {
        let fa = key.fft.forward_torus(&self.a);
        let mut acc = vec![crate::math::fft::Cplx::default(); key.n / 2];
        key.fft.mul_acc(&key.s_fft, &fa, &mut acc);
        let mut sa = vec![0u32; key.n];
        key.fft.inverse_add_to_torus(&acc, &mut sa);
        (0..key.n).map(|i| self.b[i].wrapping_sub(sa[i])).collect()
    }

    /// Overwrite `self` with `o`'s coefficients (no allocation; lengths must
    /// match — scratch buffers are sized per ring degree).
    pub fn copy_from(&mut self, o: &Self) {
        self.a.copy_from_slice(&o.a);
        self.b.copy_from_slice(&o.b);
    }

    pub fn add_assign(&mut self, o: &Self) {
        for (x, &y) in self.a.iter_mut().zip(&o.a) {
            *x = x.wrapping_add(y);
        }
        for (x, &y) in self.b.iter_mut().zip(&o.b) {
            *x = x.wrapping_add(y);
        }
    }

    pub fn sub_assign(&mut self, o: &Self) {
        for (x, &y) in self.a.iter_mut().zip(&o.a) {
            *x = x.wrapping_sub(y);
        }
        for (x, &y) in self.b.iter_mut().zip(&o.b) {
            *x = x.wrapping_sub(y);
        }
    }

    /// Multiply by `X^k` (negacyclic), `k ∈ [0, 2N)`.
    pub fn rotate(&self, k: usize) -> Self {
        TrlweCiphertext { a: rotate_poly(&self.a, k), b: rotate_poly(&self.b, k) }
    }

    /// `SampleExtract`: the LWE ciphertext of coefficient `pos` of the
    /// phase, under [`TrlweKey::extracted_lwe_key`].
    pub fn sample_extract(&self, pos: usize) -> LweCiphertext {
        let n = self.a.len();
        debug_assert!(pos < n);
        let mut a = vec![0u32; n];
        for j in 0..n {
            if j <= pos {
                a[j] = self.a[pos - j];
            } else {
                a[j] = self.a[n + pos - j].wrapping_neg();
            }
        }
        LweCiphertext { a, b: self.b[pos] }
    }
}

/// Multiply a torus polynomial by `X^k` in the negacyclic ring, `k ∈ [0,2N)`.
pub fn rotate_poly(p: &[u32], k: usize) -> Vec<u32> {
    let mut out = vec![0u32; p.len()];
    rotate_poly_into(p, k, &mut out);
    out
}

/// Allocation-free [`rotate_poly`]: writes `X^k·p` into `out` (`out` must
/// not alias `p`). Index arithmetic only — no clone, no temporary.
pub fn rotate_poly_into(p: &[u32], k: usize, out: &mut [u32]) {
    let n = p.len();
    debug_assert_eq!(out.len(), n);
    let k = k % (2 * n);
    for i in 0..n {
        let j = i + k;
        if j < n {
            out[j] = p[i];
        } else if j < 2 * n {
            out[j - n] = p[i].wrapping_neg();
        } else {
            out[j - 2 * n] = p[i];
        }
    }
}

/// Fused CMUX operand: `out = X^k·p − p` (negacyclic, wrapping), the
/// `rotated − acc` difference blind rotation feeds the external product,
/// computed without materialising the rotation.
pub fn rotate_sub_into(p: &[u32], k: usize, out: &mut [u32]) {
    rotate_poly_into(p, k, out);
    for (o, &x) in out.iter_mut().zip(p) {
        *o = o.wrapping_sub(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn torus_dist(a: u32, b: u32) -> u32 {
        let d = a.wrapping_sub(b);
        d.min(d.wrapping_neg())
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let mut rng = GlyphRng::new(1);
        let key = TrlweKey::generate(256, &mut rng);
        let mu: Vec<u32> = (0..256).map(|i| (i as u32) << 24).collect();
        let ct = TrlweCiphertext::encrypt(&mu, &key, 1e-9, &mut rng);
        let ph = ct.phase(&key);
        for i in 0..256 {
            assert!(torus_dist(ph[i], mu[i]) < 1 << 18, "i={i}");
        }
    }

    #[test]
    fn phase_cached_matches_phase() {
        let mut rng = GlyphRng::new(2);
        let key = TrlweKey::generate(256, &mut rng);
        let mu: Vec<u32> = (0..256).map(|_| rng.torus32()).collect();
        let ct = TrlweCiphertext::encrypt(&mu, &key, 1e-9, &mut rng);
        let p1 = ct.phase(&key);
        let p2 = ct.phase_cached(&key);
        for i in 0..256 {
            assert!(torus_dist(p1[i], p2[i]) < 1 << 8, "i={i}");
        }
    }

    #[test]
    fn rotate_poly_negacyclic_sign() {
        let p = vec![1u32, 2, 3, 4];
        // X^1: [−4, 1, 2, 3]
        assert_eq!(rotate_poly(&p, 1), vec![4u32.wrapping_neg(), 1, 2, 3]);
        // X^4 = −1
        assert_eq!(rotate_poly(&p, 4), vec![1u32.wrapping_neg(), 2u32.wrapping_neg(), 3u32.wrapping_neg(), 4u32.wrapping_neg()]);
        // X^8 = identity
        assert_eq!(rotate_poly(&p, 8), p);
    }

    #[test]
    fn rotate_sub_into_matches_rotate_then_sub() {
        let p: Vec<u32> = (0..32).map(|i| (i as u32).wrapping_mul(0x9e37_79b9)).collect();
        for k in [0usize, 1, 31, 32, 33, 63] {
            let mut fused = vec![0u32; 32];
            rotate_sub_into(&p, k, &mut fused);
            let rot = rotate_poly(&p, k);
            let want: Vec<u32> = rot.iter().zip(&p).map(|(&r, &x)| r.wrapping_sub(x)).collect();
            assert_eq!(fused, want, "k={k}");
        }
    }

    #[test]
    fn rotation_commutes_with_phase() {
        let mut rng = GlyphRng::new(3);
        let key = TrlweKey::generate(128, &mut rng);
        let mu: Vec<u32> = (0..128).map(|_| rng.torus32()).collect();
        let ct = TrlweCiphertext::encrypt(&mu, &key, 1e-9, &mut rng);
        let k = 37;
        let rot_phase = ct.rotate(k).phase(&key);
        let want = rotate_poly(&ct.phase(&key), k);
        for i in 0..128 {
            assert!(torus_dist(rot_phase[i], want[i]) < 1 << 10, "i={i}");
        }
    }

    #[test]
    fn sample_extract_matches_phase_coefficient() {
        let mut rng = GlyphRng::new(4);
        let key = TrlweKey::generate(128, &mut rng);
        let lwe_key = key.extracted_lwe_key();
        let mu: Vec<u32> = (0..128).map(|_| rng.torus32()).collect();
        let ct = TrlweCiphertext::encrypt(&mu, &key, 1e-9, &mut rng);
        let ph = ct.phase(&key);
        for pos in [0usize, 1, 63, 127] {
            let lwe = ct.sample_extract(pos);
            assert!(torus_dist(lwe.phase(&lwe_key), ph[pos]) < 1 << 10, "pos={pos}");
        }
    }

    #[test]
    fn homomorphic_add_sub() {
        let mut rng = GlyphRng::new(5);
        let key = TrlweKey::generate(64, &mut rng);
        let mu1: Vec<u32> = (0..64).map(|_| rng.torus32()).collect();
        let mu2: Vec<u32> = (0..64).map(|_| rng.torus32()).collect();
        let mut c1 = TrlweCiphertext::encrypt(&mu1, &key, 1e-9, &mut rng);
        let c2 = TrlweCiphertext::encrypt(&mu2, &key, 1e-9, &mut rng);
        c1.add_assign(&c2);
        c1.sub_assign(&c2);
        let ph = c1.phase(&key);
        for i in 0..64 {
            assert!(torus_dist(ph[i], mu1[i]) < 1 << 12);
        }
    }
}
