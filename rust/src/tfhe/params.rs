//! TFHE parameter sets.
//!
//! The `default()` profile follows the paper's §5.1 noise figures (TLWE
//! α = 6.10e-5, TRLWE α = 3.29e-10) with the LWE dimension raised from the
//! paper's 280 to 560 to be comfortably ≥80-bit by current estimators. The
//! `extract()` profile is used only for the 8-bit digit-extraction
//! bootstraps of the cryptosystem switch: those decide top-bits at a 2^24
//! grid, so the blind-rotation ring is enlarged to N = 4096 to push the
//! modulus-switch rounding noise (σ ≈ √n·2^32/(4N)/√12) well under the
//! 2^23 decision margin. `test()` is a fast low-security profile for unit
//! tests and the reduced-scale end-to-end examples.

/// Parameters for one TFHE instantiation.
#[derive(Clone, Debug)]
pub struct TfheParams {
    /// TLWE dimension n.
    pub n: usize,
    /// TLWE noise standard deviation (fraction of the torus).
    pub alpha_lwe: f64,
    /// TRLWE / blind-rotation ring degree N (k = 1).
    pub big_n: usize,
    /// TRLWE noise standard deviation.
    pub alpha_rlwe: f64,
    /// TRGSW decomposition levels ℓ.
    pub l: usize,
    /// log2 of the TRGSW decomposition base Bg.
    pub bg_bit: u32,
    /// log2 of the LWE key-switch base.
    pub ks_base_bit: u32,
    /// LWE key-switch levels.
    pub ks_len: usize,
}

impl TfheParams {
    /// Production-shaped profile (gates): ≥80-bit, paper §5.1 noise.
    pub fn default_params() -> Self {
        TfheParams {
            n: 560,
            alpha_lwe: 6.10e-5,
            big_n: 1024,
            alpha_rlwe: 3.29e-10,
            l: 3,
            bg_bit: 7,
            ks_base_bit: 2,
            ks_len: 8,
        }
    }

    /// Digit-extraction profile for the 8-bit switch bootstraps.
    pub fn extract_params() -> Self {
        TfheParams {
            n: 560,
            alpha_lwe: 6.10e-5,
            big_n: 4096,
            alpha_rlwe: 1.0e-11,
            l: 3,
            bg_bit: 8,
            ks_base_bit: 4,
            ks_len: 7,
        }
    }

    /// Test-scale digit-extraction profile: the blind-rotation ring must be
    /// large enough that the modulus-switch rounding noise
    /// (≈ √(n/2)·0.29·2^32/N) stays several σ below the 2^23 decision
    /// margin of 8-bit extraction.
    pub fn test_extract_params() -> Self {
        TfheParams {
            n: 64,
            alpha_lwe: 1.0e-7,
            big_n: 2048,
            alpha_rlwe: 1.0e-11,
            l: 3,
            bg_bit: 8,
            ks_base_bit: 4,
            ks_len: 7,
        }
    }

    /// Fast, low-security profile for unit tests and reduced-scale demos.
    pub fn test_params() -> Self {
        TfheParams {
            n: 64,
            alpha_lwe: 1.0e-7,
            big_n: 512,
            alpha_rlwe: 1.0e-9,
            l: 3,
            bg_bit: 7,
            ks_base_bit: 2,
            ks_len: 8,
        }
    }

    /// The TRGSW decomposition base Bg.
    #[inline]
    pub fn bg(&self) -> u32 {
        1 << self.bg_bit
    }
}
