//! TFHE (Fast Fully Homomorphic Encryption over the Torus) — torus32.
//!
//! Implements the three-level scheme of Chillotti et al. the paper uses for
//! its activations: TLWE ([`lwe`]), TRLWE ([`tlwe`]) and TRGSW ([`tgsw`]),
//! plus blind rotation / programmable bootstrapping ([`bootstrap`]), the
//! homomorphic gate library ([`gates`], paper Algorithms 1–2 consume these)
//! and LWE key switching ([`keyswitch`]).
//!
//! Conventions:
//! * the discretized torus is `u32` ("torus32"): the real torus element is
//!   `x / 2^32 mod 1`;
//! * LWE phase is `b − Σ a_i·s_i` (wrapping), TRLWE phase is `b − s·a` in
//!   `T_N[X]/(X^N+1)`;
//! * boolean messages are encoded at `±1/8` (`MU_BIT = 2^29`), the standard
//!   TFHE gate encoding.

pub mod bootstrap;
pub mod gates;
pub mod keyswitch;
pub mod lwe;
pub mod params;
pub mod scratch;
pub mod tgsw;
pub mod tlwe;

pub use bootstrap::{BootstrapKey, TestPoly};
pub use gates::TfheCloudKey;
pub use keyswitch::{KsScratch, LweKeySwitchKey, RepackScratch};
pub use lwe::{LweCiphertext, LweKey};
pub use params::TfheParams;
pub use scratch::PbsScratch;
pub use tgsw::TrgswCiphertext;
pub use tlwe::{TrlweCiphertext, TrlweKey};

/// Torus encoding of a boolean: `true ↦ +1/8`, `false ↦ −1/8`.
pub const MU_BIT: u32 = 1 << 29;

/// Encode a boolean at the gate positions.
#[inline]
pub fn encode_bit(b: bool) -> u32 {
    if b {
        MU_BIT
    } else {
        MU_BIT.wrapping_neg()
    }
}

/// Decode a torus phase back to a boolean (sign test).
#[inline]
pub fn decode_bit(phase: u32) -> bool {
    // positive half of the torus = [0, 1/2)
    (phase as i32) >= 0
}
