//! TRGSW ciphertexts, the gadget decomposition, the external product and
//! CMUX — the multiplexer at the heart of blind rotation (and of the paper's
//! softmax lookup unit, Figure 4).

use super::params::TfheParams;
use super::tlwe::{TrlweCiphertext, TrlweKey};
use crate::math::fft::{Cplx, TorusFft};
use crate::math::rng::GlyphRng;

/// TRGSW ciphertext of a small integer polynomial μ: 2ℓ TRLWE rows
/// `Z + μ·G`, stored directly in the FFT domain for the external product.
pub struct TrgswCiphertext {
    pub l: usize,
    pub bg_bit: u32,
    /// rows[u][j] for u ∈ {0 = a-component, 1 = b-component}, j ∈ 0..ℓ;
    /// each row is a TRLWE (a, b) with both polys in FFT form.
    pub rows: Vec<Vec<(Vec<Cplx>, Vec<Cplx>)>>,
}

impl TrgswCiphertext {
    /// Encrypt the constant integer polynomial `mu` (usually a key bit).
    pub fn encrypt_scalar(
        mu: i32,
        key: &TrlweKey,
        params: &TfheParams,
        rng: &mut GlyphRng,
    ) -> Self {
        let n = key.n;
        let fft = &key.fft;
        let mut rows = vec![Vec::with_capacity(params.l), Vec::with_capacity(params.l)];
        for u in 0..2 {
            for j in 0..params.l {
                // Fresh TRLWE encryption of zero…
                let mut z = TrlweCiphertext::encrypt(&vec![0u32; n], key, params.alpha_rlwe, rng);
                // …plus μ·H_j on component u, H_j = 2^(32−(j+1)·bg_bit).
                let h = 1u64 << (32 - (j as u32 + 1) * params.bg_bit);
                let add = (mu as i64).wrapping_mul(h as i64) as u32;
                if u == 0 {
                    z.a[0] = z.a[0].wrapping_add(add);
                } else {
                    z.b[0] = z.b[0].wrapping_add(add);
                }
                rows[u].push((fft.forward_torus(&z.a), fft.forward_torus(&z.b)));
            }
        }
        TrgswCiphertext { l: params.l, bg_bit: params.bg_bit, rows }
    }

    /// External product `self ⊡ c`: a TRLWE whose phase is ≈ μ · phase(c).
    ///
    /// Reference (allocating) path, kept verbatim for the bit-exactness
    /// tests against the scratch pipeline (`tests/pbs_equivalence.rs`).
    pub fn external_product(&self, c: &TrlweCiphertext, fft: &TorusFft) -> TrlweCiphertext {
        let n = c.a.len();
        let m = n / 2;
        let dec_a = decompose(&c.a, self.l, self.bg_bit);
        let dec_b = decompose(&c.b, self.l, self.bg_bit);
        let mut acc_a = vec![Cplx::default(); m];
        let mut acc_b = vec![Cplx::default(); m];
        for j in 0..self.l {
            let fa = fft.forward_int(&dec_a[j]);
            let fb = fft.forward_int(&dec_b[j]);
            fft.mul_acc(&fa, &self.rows[0][j].0, &mut acc_a);
            fft.mul_acc(&fa, &self.rows[0][j].1, &mut acc_b);
            fft.mul_acc(&fb, &self.rows[1][j].0, &mut acc_a);
            fft.mul_acc(&fb, &self.rows[1][j].1, &mut acc_b);
        }
        let mut out = TrlweCiphertext::zero(n);
        fft.inverse_add_to_torus(&acc_a, &mut out.a);
        fft.inverse_add_to_torus(&acc_b, &mut out.b);
        out
    }

    /// Allocation-free external product into `out` using caller-owned
    /// buffers (one digit polynomial `dig`, one FFT lane, two FFT
    /// accumulators — the fields of a `RingScratch`, passed split so the
    /// borrows stay disjoint). Bit-identical to
    /// [`Self::external_product`]: digits, FFT passes and the floating-point
    /// accumulation order are exactly the reference path's.
    #[allow(clippy::too_many_arguments)]
    pub fn external_product_into(
        &self,
        c: &TrlweCiphertext,
        fft: &TorusFft,
        dig: &mut [i32],
        fft_lane: &mut [Cplx],
        acc_a: &mut [Cplx],
        acc_b: &mut [Cplx],
        out: &mut TrlweCiphertext,
    ) {
        let n = c.a.len();
        debug_assert_eq!(fft.n, n);
        debug_assert_eq!(dig.len(), n);
        debug_assert_eq!(fft_lane.len(), n / 2);
        for x in acc_a.iter_mut() {
            *x = Cplx::default();
        }
        for x in acc_b.iter_mut() {
            *x = Cplx::default();
        }
        let half_bg = 1i32 << (self.bg_bit - 1);
        let mask = (1u32 << self.bg_bit) - 1;
        let offset = decompose_offset(self.l, self.bg_bit);
        for j in 0..self.l {
            let shift = 32 - (j as u32 + 1) * self.bg_bit;
            for (d, &x) in dig.iter_mut().zip(&c.a) {
                *d = (((x.wrapping_add(offset) >> shift) & mask) as i32) - half_bg;
            }
            fft.forward_int_into(dig, fft_lane);
            fft.mul_acc(fft_lane, &self.rows[0][j].0, acc_a);
            fft.mul_acc(fft_lane, &self.rows[0][j].1, acc_b);
            for (d, &x) in dig.iter_mut().zip(&c.b) {
                *d = (((x.wrapping_add(offset) >> shift) & mask) as i32) - half_bg;
            }
            fft.forward_int_into(dig, fft_lane);
            fft.mul_acc(fft_lane, &self.rows[1][j].0, acc_a);
            fft.mul_acc(fft_lane, &self.rows[1][j].1, acc_b);
        }
        for x in out.a.iter_mut() {
            *x = 0;
        }
        for x in out.b.iter_mut() {
            *x = 0;
        }
        fft.inverse_add_to_torus_inplace(acc_a, &mut out.a);
        fft.inverse_add_to_torus_inplace(acc_b, &mut out.b);
    }

    /// [`Self::external_product_into`] driven by a [`PbsScratch`]; returns an
    /// owned ciphertext (one allocation for the result — the internals stay
    /// allocation-free). Convenience for tests and one-off callers.
    pub fn external_product_scratch(
        &self,
        c: &TrlweCiphertext,
        fft: &TorusFft,
        scratch: &mut crate::tfhe::scratch::PbsScratch,
    ) -> TrlweCiphertext {
        let n = c.a.len();
        let ring = scratch.ring(n);
        let crate::tfhe::scratch::RingScratch { dig, fft_lane, acc_a, acc_b, acc0, .. } = ring;
        self.external_product_into(c, fft, dig, fft_lane, acc_a, acc_b, acc0);
        acc0.clone()
    }

    /// CMUX: returns an encryption of `d1` if μ = 1, `d0` if μ = 0:
    /// `d0 + self ⊡ (d1 − d0)`.
    ///
    /// Reference (allocating) path, kept for the bit-exactness tests.
    pub fn cmux(&self, d1: &TrlweCiphertext, d0: &TrlweCiphertext, fft: &TorusFft) -> TrlweCiphertext {
        let mut diff = d1.clone();
        diff.sub_assign(d0);
        let mut out = self.external_product(&diff, fft);
        out.add_assign(d0);
        out
    }

    /// Allocation-free CMUX into `out` (`diff` is clobbered as scratch).
    /// Bit-identical to [`Self::cmux`].
    #[allow(clippy::too_many_arguments)]
    pub fn cmux_into(
        &self,
        d1: &TrlweCiphertext,
        d0: &TrlweCiphertext,
        fft: &TorusFft,
        dig: &mut [i32],
        fft_lane: &mut [Cplx],
        acc_a: &mut [Cplx],
        acc_b: &mut [Cplx],
        diff: &mut TrlweCiphertext,
        out: &mut TrlweCiphertext,
    ) {
        diff.copy_from(d1);
        diff.sub_assign(d0);
        self.external_product_into(diff, fft, dig, fft_lane, acc_a, acc_b, out);
        out.add_assign(d0);
    }
}

/// The rounding/centering offset of the balanced gadget decomposition:
/// `Σ_j (Bg/2)·2^(32−(j+1)·bg_bit)`.
#[inline]
pub fn decompose_offset(l: usize, bg_bit: u32) -> u32 {
    crate::math::kernels::gadget_offset(l, bg_bit)
}

/// Balanced base-2^bg_bit digit decomposition of a torus polynomial:
/// digits in `[−Bg/2, Bg/2)` with `Σ_j d_j·H_j ≈ x` (error < H_{ℓ-1}/2).
pub fn decompose(poly: &[u32], l: usize, bg_bit: u32) -> Vec<Vec<i32>> {
    let n = poly.len();
    let mut flat = vec![0i32; l * n];
    decompose_into(poly, l, bg_bit, &mut flat);
    (0..l).map(|j| flat[j * n..(j + 1) * n].to_vec()).collect()
}

/// Allocation-free balanced decomposition into a flat `l·n` digit buffer
/// (digit `j` occupies `out[j*n..(j+1)*n]`). The offset trick rounds
/// instead of truncating and centers every digit. Routed through the
/// selected ring kernels (both implementations emit identical digits —
/// the decomposition is pure integer arithmetic).
pub fn decompose_into(poly: &[u32], l: usize, bg_bit: u32, out: &mut [i32]) {
    debug_assert_eq!(out.len(), l * poly.len());
    crate::math::kernels::default_kernels().decompose_poly(poly, l, bg_bit, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn torus_dist(a: u32, b: u32) -> u32 {
        let d = a.wrapping_sub(b);
        d.min(d.wrapping_neg())
    }

    #[test]
    fn decomposition_reconstructs() {
        let l = 3;
        let bg_bit = 7;
        let poly: Vec<u32> = vec![0, 1 << 31, 0x12345678, 0xdeadbeef, 0xffffffff, 42, 1 << 11, 1 << 10];
        let dec = decompose(&poly, l, bg_bit);
        for i in 0..poly.len() {
            let mut acc = 0i64;
            for j in 0..l {
                let h = 1i64 << (32 - (j as u32 + 1) * bg_bit);
                acc += dec[j][i] as i64 * h;
            }
            let err = torus_dist(acc as u32, poly[i]);
            // max reconstruction error < 2^(32 − l·bg_bit) = 2^11
            assert!(err < 1 << 11, "i={i} err={err}");
            for j in 0..l {
                assert!(dec[j][i] >= -(1 << (bg_bit - 1)) && dec[j][i] < (1 << (bg_bit - 1)));
            }
        }
    }

    #[test]
    fn external_product_scales_phase() {
        let params = TfheParams::test_params();
        let mut rng = GlyphRng::new(10);
        let key = TrlweKey::generate(params.big_n, &mut rng);
        let mu_msg: Vec<u32> = (0..params.big_n).map(|i| ((i % 8) as u32) << 28).collect();
        let c = TrlweCiphertext::encrypt(&mu_msg, &key, params.alpha_rlwe, &mut rng);
        for bit in [0i32, 1] {
            let g = TrgswCiphertext::encrypt_scalar(bit, &key, &params, &mut rng);
            let prod = g.external_product(&c, &key.fft);
            let ph = prod.phase(&key);
            for i in 0..params.big_n {
                let want = if bit == 1 { mu_msg[i] } else { 0 };
                assert!(torus_dist(ph[i], want) < 1 << 22, "bit={bit} i={i} got={} want={want}", ph[i]);
            }
        }
    }

    #[test]
    fn cmux_selects() {
        let params = TfheParams::test_params();
        let mut rng = GlyphRng::new(11);
        let key = TrlweKey::generate(params.big_n, &mut rng);
        let n = params.big_n;
        let m1: Vec<u32> = vec![1u32 << 30; n];
        let m0: Vec<u32> = vec![3u32 << 29; n];
        let d1 = TrlweCiphertext::encrypt(&m1, &key, params.alpha_rlwe, &mut rng);
        let d0 = TrlweCiphertext::encrypt(&m0, &key, params.alpha_rlwe, &mut rng);
        for bit in [0i32, 1] {
            let g = TrgswCiphertext::encrypt_scalar(bit, &key, &params, &mut rng);
            let sel = g.cmux(&d1, &d0, &key.fft);
            let ph = sel.phase(&key);
            let want = if bit == 1 { &m1 } else { &m0 };
            for i in 0..n {
                assert!(torus_dist(ph[i], want[i]) < 1 << 22, "bit={bit} i={i}");
            }
        }
    }

    #[test]
    fn cmux_chain_noise_stays_bounded() {
        // 16 chained CMUXes (a mini blind rotation) must keep the message
        // decodable at the 1/8 grid.
        let params = TfheParams::test_params();
        let mut rng = GlyphRng::new(12);
        let key = TrlweKey::generate(params.big_n, &mut rng);
        let n = params.big_n;
        let msg: Vec<u32> = vec![1u32 << 29; n];
        let mut acc = TrlweCiphertext::trivial(&msg);
        for step in 0..16 {
            let bit = (step % 2) as i32;
            let g = TrgswCiphertext::encrypt_scalar(bit, &key, &params, &mut rng);
            let rotated = acc.rotate(step + 1);
            acc = g.cmux(&rotated, &acc, &key.fft);
        }
        // We don't track the exact rotation here; just verify noise: decrypt
        // then re-encode each coefficient to the nearest multiple of 1/8 and
        // check the distance.
        let ph = acc.phase(&key);
        for i in 0..n {
            let nearest = ((ph[i] as u64 + (1 << 28)) >> 29) << 29;
            assert!(torus_dist(ph[i], nearest as u32) < 1 << 26, "i={i}");
        }
    }
}
