//! LWE key switching (scalar → scalar) and the packing functional key
//! switch (many LWEs → one TRLWE), both at torus32.
//!
//! The scalar switch moves bootstrap outputs (dimension N, extracted key)
//! back to the gate key (dimension n), and moves BGV-extracted samples
//! (dimension N_bgv, ternary key) onto the TFHE key during BGV→TFHE
//! switching. The packing switch is the TFHE §4.2 public functional key
//! switch the paper's TFHE→BGV direction uses to place sample `i`'s value
//! at coefficient `X^i` of one ring ciphertext.

use super::lwe::{LweCiphertext, LweKey};
use super::tlwe::{TrlweCiphertext, TrlweKey};
use crate::math::fft::Cplx;
use crate::math::kernels::{default_kernels, gadget_offset, RingKernels};
use crate::math::rng::GlyphRng;
use std::cell::RefCell;

/// Upper bound on key-switch decomposition levels (every parameter set uses
/// ≤ 8); lets the hot loops keep digits in a stack array instead of a
/// heap `Vec` per coefficient (EXPERIMENTS.md §Perf).
pub const MAX_KS_LEVELS: usize = 16;

/// Balanced digit decomposition of a torus32 scalar: `len` digits in
/// `[−B/2, B/2)`, MSB-first with base `B = 2^base_bit`.
fn decompose_scalar(x: u32, len: usize, base_bit: u32) -> Vec<i32> {
    let mut digits = [0i32; MAX_KS_LEVELS];
    decompose_scalar_into(x, len, base_bit, &mut digits);
    digits[..len].to_vec()
}

/// Allocation-free [`decompose_scalar`] into a stack buffer (the repack
/// path's per-sample form; the scalar switch decomposes the whole mask at
/// once through the kernel layer instead — see [`KsScratch`]).
#[inline]
fn decompose_scalar_into(x: u32, len: usize, base_bit: u32, out: &mut [i32; MAX_KS_LEVELS]) {
    debug_assert!(len <= MAX_KS_LEVELS);
    let base = 1u32 << base_bit;
    let half = base >> 1;
    let mask = base - 1;
    let xx = x.wrapping_add(gadget_offset(len, base_bit));
    for j in 0..len {
        let shift = 32 - (j as u32 + 1) * base_bit;
        out[j] = (((xx >> shift) & mask) as i32) - half as i32;
    }
}

/// Scratch for the hoisted LWE key switch: the whole input mask is
/// decomposed ONCE per switch into this digit-major matrix
/// (`digits[j·n + i]` = digit `j` of `a_i`) by a branchless kernel pass,
/// then reused across every output coefficient by the row-apply loop —
/// instead of re-deriving digits coefficient by coefficient inside the
/// accumulation. Sized on first use per `(n, len)`, reused across switches
/// (steady state is allocation-free — `tests/zero_alloc_switch.rs`).
pub struct KsScratch {
    digits: Vec<i32>,
    n: usize,
    len: usize,
}

impl KsScratch {
    pub fn new() -> Self {
        KsScratch { digits: Vec::new(), n: 0, len: 0 }
    }

    fn ensure(&mut self, n: usize, len: usize) {
        if self.n != n || self.len != len {
            self.digits = vec![0i32; len * n];
            self.n = n;
            self.len = len;
        }
    }
}

impl Default for KsScratch {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    /// Per-thread switch scratch (the `tfhe/scratch.rs` pattern): gate-level
    /// callers (`TfheCloudKey::pbs`) and pool workers hit their own copy
    /// with no locking and no signature changes.
    static KS_SCRATCH: RefCell<KsScratch> = RefCell::new(KsScratch::new());
}

/// Key-switching key from `src` to `dst` (scalar LWE).
pub struct LweKeySwitchKey {
    pub base_bit: u32,
    pub len: usize,
    /// ks[i][j]: LWE_dst encryption of `src_i · 2^(32−(j+1)·base_bit)`.
    pub ks: Vec<Vec<LweCiphertext>>,
    pub dst_dim: usize,
    /// Kernel set for the decompose + AXPY hot loops (public so conformance
    /// tests and benches can pin scalar vs simd on one key).
    pub kernels: &'static dyn RingKernels,
}

impl LweKeySwitchKey {
    pub fn generate(
        src: &LweKey,
        dst: &LweKey,
        base_bit: u32,
        len: usize,
        alpha: f64,
        rng: &mut GlyphRng,
    ) -> Self {
        assert!(len <= MAX_KS_LEVELS, "ks_len {len} exceeds MAX_KS_LEVELS");
        let ks = src
            .s
            .iter()
            .map(|&si| {
                (0..len)
                    .map(|j| {
                        let h = 1u64 << (32 - (j as u64 + 1) * base_bit as u64);
                        let mu = (si as i64).wrapping_mul(h as i64) as u32;
                        LweCiphertext::encrypt(mu, dst, alpha, rng)
                    })
                    .collect()
            })
            .collect();
        LweKeySwitchKey { base_bit, len, ks, dst_dim: dst.dim(), kernels: default_kernels() }
    }

    /// Switch `ct` (under `src`) to an LWE under `dst`. One output
    /// allocation; the per-coefficient digits stay on the stack.
    pub fn switch(&self, ct: &LweCiphertext) -> LweCiphertext {
        let mut out = LweCiphertext::trivial(ct.b, self.dst_dim);
        self.switch_into(ct, &mut out);
        out
    }

    /// Allocation-free [`Self::switch`] into a warm output ciphertext
    /// (`out.a.len()` must already be `dst_dim`): same integer arithmetic,
    /// bit-identical result, zero steady-state heap traffic — the
    /// scratch-backed half of the BGV→TFHE switch asserted by
    /// `tests/zero_alloc_switch.rs`. Scratch comes from a per-thread
    /// `KS_SCRATCH`; use [`Self::switch_into_with`] to pass your own.
    pub fn switch_into(&self, ct: &LweCiphertext, out: &mut LweCiphertext) {
        KS_SCRATCH.with(|s| self.switch_into_with(ct, &mut s.borrow_mut(), out));
    }

    /// Two-phase hoisted key switch. Phase 1 decomposes the whole `n`-lane
    /// mask into `scratch.digits` in one branchless level-major kernel pass.
    /// Phase 2 walks the digit matrix in the reference `(i, j)` order and
    /// applies non-zero digits as wrapping AXPYs over the `dst_dim` output
    /// lanes. Wrapping u32 arithmetic is exact and order-preserving here, so
    /// the result is bit-identical to the per-coefficient reference (a zero
    /// `a_i` decomposes to all-zero digits, which phase 2 skips just like
    /// the old `ai == 0` fast path did).
    pub fn switch_into_with(
        &self,
        ct: &LweCiphertext,
        scratch: &mut KsScratch,
        out: &mut LweCiphertext,
    ) {
        debug_assert_eq!(out.a.len(), self.dst_dim, "warm output at dst_dim required");
        out.a.fill(0);
        out.b = ct.b;
        let n = ct.a.len();
        scratch.ensure(n, self.len);
        self.kernels.decompose_poly(&ct.a, self.len, self.base_bit, &mut scratch.digits);
        for i in 0..n {
            for j in 0..self.len {
                let d = scratch.digits[j * n + i];
                if d == 0 {
                    continue;
                }
                // out −= d · ks[i][j]
                let row = &self.ks[i][j];
                let du = d as u32;
                self.kernels.ks_submul(&mut out.a, &row.a, du);
                out.b = out.b.wrapping_sub(du.wrapping_mul(row.b));
            }
        }
    }
}

/// Reusable buffers for one worker's packing key switches: everything
/// [`PackingKeySwitchKey::pack`] used to allocate per call (digit
/// polynomials, FFT lanes, FFT-domain accumulators, inverse-FFT outputs).
/// Sized on first use per ring degree / level count, reused across packs —
/// steady state is allocation-free (`tests/zero_alloc_switch.rs`).
pub struct RepackScratch {
    digit_polys: Vec<i32>,
    any: Vec<bool>,
    fft_lane: Vec<Cplx>,
    acc_a: Vec<Cplx>,
    acc_b: Vec<Cplx>,
    sub_a: Vec<u32>,
    sub_b: Vec<u32>,
    n: usize,
    len: usize,
}

impl RepackScratch {
    pub fn new() -> Self {
        RepackScratch {
            digit_polys: Vec::new(),
            any: Vec::new(),
            fft_lane: Vec::new(),
            acc_a: Vec::new(),
            acc_b: Vec::new(),
            sub_a: Vec::new(),
            sub_b: Vec::new(),
            n: 0,
            len: 0,
        }
    }

    /// Size every buffer for ring degree `n` and `len` decomposition levels
    /// (no-op when already warm for these dimensions).
    fn ensure(&mut self, n: usize, len: usize) {
        if self.n == n && self.len == len {
            return;
        }
        self.digit_polys = vec![0i32; len * n];
        self.any = vec![false; len];
        self.fft_lane = vec![Cplx::default(); n / 2];
        self.acc_a = vec![Cplx::default(); n / 2];
        self.acc_b = vec![Cplx::default(); n / 2];
        self.sub_a = vec![0u32; n];
        self.sub_b = vec![0u32; n];
        self.n = n;
        self.len = len;
    }
}

impl Default for RepackScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Packing (public functional) key-switching key: moves K scalar LWEs under
/// `src` into one TRLWE under `dst_ring`, placing sample m at coefficient
/// `X^{pos_m}`.
pub struct PackingKeySwitchKey {
    pub base_bit: u32,
    pub len: usize,
    /// pk[i][j]: TRLWE_dst encryption of the constant poly
    /// `src_i · 2^(32−(j+1)·base_bit)`, FFT form for both components.
    pub pk: Vec<Vec<(Vec<Cplx>, Vec<Cplx>)>>,
    pub ring_n: usize,
    fft: std::sync::Arc<crate::math::fft::TorusFft>,
}

impl PackingKeySwitchKey {
    pub fn generate(
        src: &LweKey,
        dst_ring: &TrlweKey,
        base_bit: u32,
        len: usize,
        alpha: f64,
        rng: &mut GlyphRng,
    ) -> Self {
        assert!(len <= MAX_KS_LEVELS, "ks_len {len} exceeds MAX_KS_LEVELS");
        let n = dst_ring.n;
        let pk = src
            .s
            .iter()
            .map(|&si| {
                (0..len)
                    .map(|j| {
                        let h = 1u64 << (32 - (j as u64 + 1) * base_bit as u64);
                        let mut mu = vec![0u32; n];
                        mu[0] = (si as i64).wrapping_mul(h as i64) as u32;
                        let ct = TrlweCiphertext::encrypt(&mu, dst_ring, alpha, rng);
                        (dst_ring.fft.forward_torus(&ct.a), dst_ring.fft.forward_torus(&ct.b))
                    })
                    .collect()
            })
            .collect();
        PackingKeySwitchKey { base_bit, len, pk, ring_n: n, fft: dst_ring.fft.clone() }
    }

    /// Pack `samples[m]` at coefficient `positions[m]` of one TRLWE.
    ///
    /// Allocating convenience wrapper over [`Self::pack_into`] (fresh
    /// scratch and output per call — the retained reference shape).
    pub fn pack<S: std::borrow::Borrow<LweCiphertext>>(
        &self,
        samples: &[S],
        positions: &[usize],
    ) -> TrlweCiphertext {
        let mut out = TrlweCiphertext::zero(self.ring_n);
        let mut scratch = RepackScratch::new();
        self.pack_into(samples, positions, &mut scratch, &mut out);
        out
    }

    /// Scratch-backed [`Self::pack`] into a warm output ciphertext:
    /// bit-identical to the reference (same floating-point accumulation
    /// sequence), zero heap allocations once `scratch` and `out` are sized
    /// (`tests/zero_alloc_switch.rs`). Generic over owned (`&[LweCiphertext]`)
    /// and borrowed (`&[&LweCiphertext]`) sample slices so batch callers
    /// need no per-group reference `Vec`.
    ///
    /// Implements the public functional key switch with f = the packing
    /// linear map: the decomposition digits of every `a^{(m)}_i` are gathered
    /// into integer polynomials (digit × X^{pos_m}) so each key row is
    /// multiplied only once per level, then `b^{(m)}` lands on coefficient
    /// `pos_m` of the b-component.
    pub fn pack_into<S: std::borrow::Borrow<LweCiphertext>>(
        &self,
        samples: &[S],
        positions: &[usize],
        scratch: &mut RepackScratch,
        out: &mut TrlweCiphertext,
    ) {
        assert_eq!(samples.len(), positions.len());
        let n = self.ring_n;
        debug_assert!(out.a.len() == n && out.b.len() == n, "warm output at ring_n required");
        for &p in positions {
            assert!(p < n, "pack position {p} outside the {n}-coefficient ring");
        }
        let src_dim = self.pk.len();
        scratch.ensure(n, self.len);
        scratch.acc_a.fill(Cplx::default());
        scratch.acc_b.fill(Cplx::default());
        // For each source index i: all `len` digit polynomials
        // Σ_m digit_j(a^{(m)}_i)·X^{pos_m}, built with ONE stack
        // decomposition per sample, then one FFT + mul-acc per non-zero
        // level in (i, j) order — the floating-point accumulation sequence
        // matches the reference exactly.
        let mut digits = [0i32; MAX_KS_LEVELS];
        for i in 0..src_dim {
            scratch.digit_polys.fill(0);
            scratch.any.fill(false);
            for (m, ct) in samples.iter().enumerate() {
                let ct = ct.borrow();
                if ct.a[i] == 0 {
                    continue; // zero decomposes to all-zero digits
                }
                decompose_scalar_into(ct.a[i], self.len, self.base_bit, &mut digits);
                for j in 0..self.len {
                    let d = digits[j];
                    if d != 0 {
                        scratch.digit_polys[j * n + positions[m]] += d;
                        scratch.any[j] = true;
                    }
                }
            }
            for j in 0..self.len {
                if !scratch.any[j] {
                    continue;
                }
                self.fft
                    .forward_int_into(&scratch.digit_polys[j * n..(j + 1) * n], &mut scratch.fft_lane);
                // acc −= digit_poly · pk[i][j]  (both components)
                let row = &self.pk[i][j];
                // negate via multiplying digits by −1: cheaper to subtract at
                // the end; here accumulate then subtract once.
                self.fft.mul_acc(&scratch.fft_lane, &row.0, &mut scratch.acc_a);
                self.fft.mul_acc(&scratch.fft_lane, &row.1, &mut scratch.acc_b);
            }
        }
        // out = (0, Σ_m b^{(m)} X^{pos_m}) − Σ acc
        out.a.fill(0);
        out.b.fill(0);
        scratch.sub_a.fill(0);
        scratch.sub_b.fill(0);
        self.fft.inverse_add_to_torus_inplace(&mut scratch.acc_a, &mut scratch.sub_a);
        self.fft.inverse_add_to_torus_inplace(&mut scratch.acc_b, &mut scratch.sub_b);
        for i in 0..n {
            out.a[i] = out.a[i].wrapping_sub(scratch.sub_a[i]);
            out.b[i] = out.b[i].wrapping_sub(scratch.sub_b[i]);
        }
        for (m, ct) in samples.iter().enumerate() {
            out.b[positions[m]] = out.b[positions[m]].wrapping_add(ct.borrow().b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::params::TfheParams;

    fn torus_dist(a: u32, b: u32) -> u32 {
        let d = a.wrapping_sub(b);
        d.min(d.wrapping_neg())
    }

    #[test]
    fn decompose_scalar_reconstructs() {
        for x in [0u32, 1 << 31, 0xdeadbeef, 0x12345678, u32::MAX] {
            for (len, bb) in [(8usize, 2u32), (7, 4), (3, 7)] {
                let d = decompose_scalar(x, len, bb);
                let mut acc = 0i64;
                for (j, &dj) in d.iter().enumerate() {
                    acc += dj as i64 * (1i64 << (32 - (j as u32 + 1) * bb));
                }
                let err = torus_dist(acc as u32, x);
                assert!(err < 1 << (32 - len as u32 * bb), "x={x:#x} len={len} bb={bb} err={err}");
            }
        }
    }

    #[test]
    fn lwe_keyswitch_preserves_message() {
        let mut rng = GlyphRng::new(30);
        let src = LweKey::generate_binary(256, &mut rng);
        let dst = LweKey::generate_binary(64, &mut rng);
        let ksk = LweKeySwitchKey::generate(&src, &dst, 2, 8, 1e-8, &mut rng);
        for msg in [1u32 << 29, (1u32 << 29).wrapping_neg(), 1 << 30] {
            let ct = LweCiphertext::encrypt(msg, &src, 1e-8, &mut rng);
            let out = ksk.switch(&ct);
            assert_eq!(out.dim(), 64);
            assert!(torus_dist(out.phase(&dst), msg) < 1 << 24, "msg={msg:#x}");
        }
    }

    #[test]
    fn lwe_keyswitch_from_ternary_key() {
        // BGV→TFHE: source key is ternary (RLWE coefficients).
        let mut rng = GlyphRng::new(31);
        let src = LweKey::from_coeffs((0..256).map(|_| rng.ternary() as i32).collect());
        let dst = LweKey::generate_binary(64, &mut rng);
        let ksk = LweKeySwitchKey::generate(&src, &dst, 4, 7, 1e-9, &mut rng);
        let msg = 5u32 << 27;
        let ct = LweCiphertext::encrypt(msg, &src, 1e-9, &mut rng);
        let out = ksk.switch(&ct);
        assert!(torus_dist(out.phase(&dst), msg) < 1 << 23);
    }

    #[test]
    fn packing_keyswitch_places_values_at_positions() {
        let params = TfheParams::test_params();
        let mut rng = GlyphRng::new(32);
        let src = LweKey::generate_binary(64, &mut rng);
        let ring = TrlweKey::generate(params.big_n, &mut rng);
        let pksk = PackingKeySwitchKey::generate(&src, &ring, 4, 7, 1e-9, &mut rng);
        let msgs = [1u32 << 29, 1 << 30, (1u32 << 29).wrapping_neg(), 3 << 28];
        let positions = [0usize, 5, 17, 100];
        let cts: Vec<LweCiphertext> =
            msgs.iter().map(|&m| LweCiphertext::encrypt(m, &src, 1e-9, &mut rng)).collect();
        let refs: Vec<&LweCiphertext> = cts.iter().collect();
        let packed = pksk.pack(&refs, &positions);
        let ph = packed.phase(&ring);
        for (m, &pos) in positions.iter().enumerate() {
            assert!(torus_dist(ph[pos], msgs[m]) < 1 << 24, "m={m} ph={:#x}", ph[pos]);
        }
        // untouched positions stay (near) zero
        assert!(torus_dist(ph[200], 0) < 1 << 24);
    }

    #[test]
    fn packing_then_extract_roundtrip() {
        // pack K LWEs, extract them back — the switch's inner loop.
        let params = TfheParams::test_params();
        let mut rng = GlyphRng::new(33);
        let src = LweKey::generate_binary(64, &mut rng);
        let ring = TrlweKey::generate(params.big_n, &mut rng);
        let ext = ring.extracted_lwe_key();
        let pksk = PackingKeySwitchKey::generate(&src, &ring, 4, 7, 1e-9, &mut rng);
        let k = 8;
        let msgs: Vec<u32> = (0..k).map(|i| ((i + 1) as u32) << 27).collect();
        let cts: Vec<LweCiphertext> =
            msgs.iter().map(|&m| LweCiphertext::encrypt(m, &src, 1e-9, &mut rng)).collect();
        let refs: Vec<&LweCiphertext> = cts.iter().collect();
        let positions: Vec<usize> = (0..k).collect();
        let packed = pksk.pack(&refs, &positions);
        for i in 0..k {
            let lwe = packed.sample_extract(i);
            assert!(torus_dist(lwe.phase(&ext), msgs[i]) < 1 << 24, "i={i}");
        }
    }
}
