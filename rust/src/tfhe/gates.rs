//! The homomorphic gate library (gate bootstrapping).
//!
//! These are the operations the paper's Table 1 bills as "TFHE" ops and that
//! Algorithms 1–2 (ReLU/iReLU) and the Figure-4 softmax unit consume:
//! `HomoNot` (bootstrap-free), `HomoAND`/`OR`/`XOR` (one bootstrap each) and
//! the homomorphic multiplexer (two bootstraps on the critical path).
//!
//! Every boolean travels at the `±1/8` encoding; each bootstrapped gate ends
//! with a key switch from the extracted key (dim N) back to the gate key
//! (dim n) so gates compose indefinitely.

use super::bootstrap::{BootstrapKey, TestPoly};
use super::keyswitch::LweKeySwitchKey;
use super::lwe::{LweCiphertext, LweKey};
use super::params::TfheParams;
use super::tlwe::TrlweKey;
use super::MU_BIT;
use crate::coordinator::executor::GlyphPool;
use crate::math::rng::GlyphRng;

/// Everything the (untrusted) evaluator needs to run gates: bootstrapping
/// key + N→n key-switching key.
pub struct TfheCloudKey {
    pub params: TfheParams,
    pub bk: BootstrapKey,
    pub ksk: LweKeySwitchKey,
}

impl TfheCloudKey {
    pub fn generate(lwe_key: &LweKey, trlwe_key: &TrlweKey, params: &TfheParams, rng: &mut GlyphRng) -> Self {
        let bk = BootstrapKey::generate(lwe_key, trlwe_key, params, rng);
        let ext = trlwe_key.extracted_lwe_key();
        let ksk = LweKeySwitchKey::generate(&ext, lwe_key, params.ks_base_bit, params.ks_len, params.alpha_lwe, rng);
        TfheCloudKey { params: params.clone(), bk, ksk }
    }

    /// Bootstrap to ±`mu` then key-switch back to the gate key.
    fn gate_bootstrap(&self, lin: &LweCiphertext, mu: u32) -> LweCiphertext {
        let boot = self.bk.bootstrap_sign(lin, mu);
        self.ksk.switch(&boot)
    }

    /// Bootstrap with an arbitrary test polynomial, then key-switch.
    pub fn pbs(&self, lin: &LweCiphertext, tv: &TestPoly) -> LweCiphertext {
        let boot = self.bk.bootstrap(lin, tv);
        self.ksk.switch(&boot)
    }

    /// Bootstrap with an arbitrary test polynomial, NO key switch (output is
    /// under the extracted dim-N key) — used by the switch pipeline where
    /// the next step is itself a key/packing switch.
    pub fn pbs_raw(&self, lin: &LweCiphertext, tv: &TestPoly) -> LweCiphertext {
        self.bk.bootstrap(lin, tv)
    }

    // ---- batched fan-out (the GlyphPool pipeline) ---------------------------

    /// Batched [`Self::pbs`]: one PBS + key switch per input, all sharing
    /// `tv`, fanned across the global [`GlyphPool`]. Order-preserving and
    /// bit-exact against the sequential loop.
    pub fn pbs_many(&self, lins: Vec<LweCiphertext>, tv: &TestPoly) -> Vec<LweCiphertext> {
        GlyphPool::global().map_with(lins, |lin, scratch| {
            let boot = self.bk.bootstrap_with(&lin, tv, &mut scratch.pbs);
            self.ksk.switch(&boot)
        })
    }

    /// Batched [`Self::pbs_raw`] (no key switch).
    pub fn pbs_raw_many(&self, lins: Vec<LweCiphertext>, tv: &TestPoly) -> Vec<LweCiphertext> {
        GlyphPool::global().map_with(lins, |lin, scratch| self.bk.bootstrap_with(&lin, tv, &mut scratch.pbs))
    }

    /// Batched HomoAND: one gate bootstrap per `(c1, c2)` pair across the
    /// pool (the gate-bootstraps/sec metric of `benches/fig3_tfhe_only.rs`
    /// measures exactly this entry point).
    pub fn and_many(&self, pairs: &[(&LweCiphertext, &LweCiphertext)]) -> Vec<LweCiphertext> {
        let tv = TestPoly::constant(self.params.big_n, MU_BIT);
        GlyphPool::global().map_with(pairs.to_vec(), |(c1, c2), scratch| {
            let mut lin = c1.clone();
            lin.add_assign(c2);
            lin.add_constant(MU_BIT.wrapping_neg());
            let boot = self.bk.bootstrap_sign_with(&lin, &tv, &mut scratch.pbs);
            self.ksk.switch(&boot)
        })
    }

    /// Batched [`Self::and_weighted_raw`]: one `(c1, c2, pos)` job per
    /// output bit, fanned across the pool. The activation layers fan every
    /// lane × bit of a tensor through this in a single call; the constant
    /// test polynomials are hoisted — one per distinct bit position, not
    /// one ring-sized vector per job.
    pub fn and_weighted_raw_many(
        &self,
        jobs: &[(&LweCiphertext, &LweCiphertext, u32)],
    ) -> Vec<LweCiphertext> {
        let mut tvs: Vec<(u32, TestPoly)> = Vec::new();
        for &(_, _, pos) in jobs {
            debug_assert!(pos >= 1 && pos <= 31);
            if !tvs.iter().any(|(p, _)| *p == pos) {
                tvs.push((pos, TestPoly::constant(self.params.big_n, 1u32 << (pos - 1))));
            }
        }
        GlyphPool::global().map_with(jobs.to_vec(), |(c1, c2, pos), scratch| {
            let tv = &tvs.iter().find(|(p, _)| *p == pos).expect("hoisted above").1;
            let mut lin = c1.clone();
            lin.add_assign(c2);
            lin.add_constant(MU_BIT.wrapping_neg());
            let mu = 1u32 << (pos - 1);
            let mut out = self.bk.bootstrap_sign_with(&lin, tv, &mut scratch.pbs);
            out.add_constant(mu); // {0, 2^pos}
            out
        })
    }

    /// HomoNOT — negation, no bootstrapping (paper Alg. 1 line 2).
    pub fn not(&self, c: &LweCiphertext) -> LweCiphertext {
        let mut out = c.clone();
        out.neg_assign();
        out
    }

    /// HomoAND — one gate bootstrap.
    pub fn and(&self, c1: &LweCiphertext, c2: &LweCiphertext) -> LweCiphertext {
        let mut lin = c1.clone();
        lin.add_assign(c2);
        lin.add_constant(MU_BIT.wrapping_neg()); // −1/8
        self.gate_bootstrap(&lin, MU_BIT)
    }

    /// HomoOR.
    pub fn or(&self, c1: &LweCiphertext, c2: &LweCiphertext) -> LweCiphertext {
        let mut lin = c1.clone();
        lin.add_assign(c2);
        lin.add_constant(MU_BIT); // +1/8
        self.gate_bootstrap(&lin, MU_BIT)
    }

    /// HomoNAND.
    pub fn nand(&self, c1: &LweCiphertext, c2: &LweCiphertext) -> LweCiphertext {
        let mut lin = c1.clone();
        lin.add_assign(c2);
        lin.neg_assign();
        lin.add_constant(MU_BIT); // 1/8 − c1 − c2
        self.gate_bootstrap(&lin, MU_BIT)
    }

    /// HomoXOR — one bootstrap (2·(c1+c2) + 1/4).
    pub fn xor(&self, c1: &LweCiphertext, c2: &LweCiphertext) -> LweCiphertext {
        let mut lin = c1.clone();
        lin.add_assign(c2);
        lin.scalar_mul_assign(2);
        lin.add_constant(1 << 30); // +1/4
        self.gate_bootstrap(&lin, MU_BIT)
    }

    /// Homomorphic multiplexer `sel ? d1 : d0` — two bootstraps on the
    /// critical path (paper Fig. 4's building block).
    pub fn mux(&self, sel: &LweCiphertext, d1: &LweCiphertext, d0: &LweCiphertext) -> LweCiphertext {
        // t1 = AND(sel, d1), t0 = AND(NOT sel, d0), out = t1 + t0 + 1/8
        // computed without the final keyswitch until after the sum.
        let mut lin1 = sel.clone();
        lin1.add_assign(d1);
        lin1.add_constant(MU_BIT.wrapping_neg());
        let t1 = self.bk.bootstrap_sign(&lin1, MU_BIT >> 1); // ±1/16

        let mut lin0 = self.not(sel);
        lin0.add_assign(d0);
        lin0.add_constant(MU_BIT.wrapping_neg());
        let t0 = self.bk.bootstrap_sign(&lin0, MU_BIT >> 1); // ±1/16

        let mut sum = t1;
        sum.add_assign(&t0);
        sum.add_constant(MU_BIT >> 1); // recenter: {−1/16,+3/16} → ±1/8
        self.ksk.switch(&sum)
    }

    /// AND whose *true* output lands exactly at torus position `2^pos`
    /// (and *false* at 0). Used to recompose activation bits at their binary
    /// weight during TFHE→BGV switching — the paper's "functional gate
    /// bootstrapping restricted to multiples of p^{−r}" (§4.2, Thm 3 step ➊).
    ///
    /// The output stays under the extracted dim-N key (no key switch): the
    /// next pipeline stage is the packing key switch, which consumes dim-N
    /// samples directly.
    pub fn and_weighted_raw(&self, c1: &LweCiphertext, c2: &LweCiphertext, pos: u32) -> LweCiphertext {
        debug_assert!(pos >= 1 && pos <= 31);
        let mut lin = c1.clone();
        lin.add_assign(c2);
        lin.add_constant(MU_BIT.wrapping_neg());
        let mu = 1u32 << (pos - 1);
        let mut out = self.bk.bootstrap_sign(&lin, mu);
        out.add_constant(mu); // {0, 2^pos}
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tfhe::{decode_bit, encode_bit};

    struct Fx {
        params: TfheParams,
        key: LweKey,
        ext_key: LweKey,
        ck: TfheCloudKey,
        rng: GlyphRng,
    }

    fn fixture(seed: u64) -> Fx {
        let params = TfheParams::test_params();
        let mut rng = GlyphRng::new(seed);
        let key = LweKey::generate_binary(params.n, &mut rng);
        let trlwe_key = TrlweKey::generate(params.big_n, &mut rng);
        let ext_key = trlwe_key.extracted_lwe_key();
        let ck = TfheCloudKey::generate(&key, &trlwe_key, &params, &mut rng);
        Fx { params, key, ext_key, ck, rng }
    }

    fn enc(f: &mut Fx, b: bool) -> LweCiphertext {
        LweCiphertext::encrypt(encode_bit(b), &f.key, f.params.alpha_lwe, &mut f.rng)
    }

    fn dec(f: &Fx, c: &LweCiphertext) -> bool {
        decode_bit(c.phase(&f.key))
    }

    #[test]
    fn truth_tables() {
        let mut f = fixture(40);
        for a in [false, true] {
            for b in [false, true] {
                let ca = enc(&mut f, a);
                let cb = enc(&mut f, b);
                assert_eq!(dec(&f, &f.ck.and(&ca, &cb)), a && b, "AND {a} {b}");
                assert_eq!(dec(&f, &f.ck.or(&ca, &cb)), a || b, "OR {a} {b}");
                assert_eq!(dec(&f, &f.ck.nand(&ca, &cb)), !(a && b), "NAND {a} {b}");
                assert_eq!(dec(&f, &f.ck.xor(&ca, &cb)), a ^ b, "XOR {a} {b}");
            }
        }
    }

    #[test]
    fn not_is_free_and_correct() {
        let mut f = fixture(41);
        for a in [false, true] {
            let ca = enc(&mut f, a);
            assert_eq!(dec(&f, &f.ck.not(&ca)), !a);
        }
    }

    #[test]
    fn mux_selects_correctly() {
        let mut f = fixture(42);
        for s in [false, true] {
            for d1 in [false, true] {
                for d0 in [false, true] {
                    let cs = enc(&mut f, s);
                    let c1 = enc(&mut f, d1);
                    let c0 = enc(&mut f, d0);
                    let out = f.ck.mux(&cs, &c1, &c0);
                    assert_eq!(dec(&f, &out), if s { d1 } else { d0 }, "s={s} d1={d1} d0={d0}");
                }
            }
        }
    }

    #[test]
    fn gates_compose_deep_circuit() {
        // A small ripple of 12 chained gates must stay correct: bootstrap
        // noise reset is what makes this work.
        let mut f = fixture(43);
        let mut acc = enc(&mut f, true);
        let mut expect = true;
        for i in 0..12 {
            let b = i % 3 == 0;
            let cb = enc(&mut f, b);
            if i % 2 == 0 {
                acc = f.ck.xor(&acc, &cb);
                expect ^= b;
            } else {
                acc = f.ck.and(&acc, &cb);
                expect &= b;
            }
            assert_eq!(dec(&f, &acc), expect, "step {i}");
        }
    }

    #[test]
    fn and_weighted_lands_on_position() {
        let mut f = fixture(44);
        let pos = 27u32;
        for (a, b) in [(true, true), (true, false), (false, true), (false, false)] {
            let ca = enc(&mut f, a);
            let cb = enc(&mut f, b);
            let out = f.ck.and_weighted_raw(&ca, &cb, pos);
            let ph = out.phase(&f.ext_key);
            let want: u32 = if a && b { 1 << pos } else { 0 };
            let d = ph.wrapping_sub(want);
            let dist = d.min(d.wrapping_neg());
            assert!(dist < 1 << (pos - 2), "a={a} b={b} ph={ph:#x} want={want:#x}");
        }
    }
}
