//! Plan-driven model construction: [`NetworkBuilder`] → [`Network`].
//!
//! A network is declared as a fluent chain of [`LayerSpec`]s
//! (`.fc(128).relu(8, 7).fc(10).softmax(3, 7)` / `.conv_frozen(..)`), with
//! every quantization shift carried by the layer spec it belongs to —
//! replacing the parallel `act_shifts`/`err_shifts` vectors of the old
//! `MlpConfig`, whose silent index clamping is now a descriptive
//! [`NetworkError`] at construction time.
//!
//! `build` materializes the units (encrypting trainable weights under the
//! client key) and compiles the executable `scheduler::Plan` through each
//! unit's `Layer::plan_entry`. Execution *walks that plan*: forward runs
//! the plan's forward steps in order, `train_step` runs the backward steps
//! the plan emitted (error propagation exactly where the plan says a
//! trainable layer needs the signal, gradient steps only for trainable
//! units), so the plan's per-step op counts are the single source of truth
//! shared with the cost model and the CLI.
//!
//! [`NetworkBuilder::compile`] produces the same plan *without* key
//! material or weights (shape-only), which is what `glyph plan` uses to
//! print paper-scale schedules instantly.

use super::activation::{ReluLayer, SoftmaxLayer, SoftmaxUnit};
use super::backend::Codec;
use super::batchnorm::BnLayer;
use super::conv::ConvLayer;
use super::engine::GlyphEngine;
use super::layer::{
    bn_forward_ops, conv_forward_ops, fc_error_ops, fc_forward_ops, fc_gradient_ops,
    pool_forward_ops, relu_error_ops, relu_forward_ops, softmax_error_ops, softmax_forward_ops,
    FlattenLayer, Layer, LayerGrads, LayerPlanEntry, LayerState,
};
use super::linear::{FcLayer, PackedFcLayer};
use super::pool::AvgPoolLayer;
use super::tensor::{EncTensor, PackedLayout};
use crate::coordinator::scheduler::{LayerKind, Plan, PlanLayer, StepPhase};
use crate::math::rng::GlyphRng;
use crate::switch::SWITCH_BITS;
use std::fmt;

/// Construction-time validation errors (no silent clamping anywhere).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NetworkError {
    /// The builder holds no layers.
    EmptyNetwork,
    /// A layer's input geometry does not fit.
    Shape { unit: String, detail: String },
    /// A quantization-shift schedule does not match the architecture or
    /// exceeds the engine's fixed-point budget.
    ShiftSchedule { detail: String },
    /// Provided weights do not match the declared geometry, or are missing.
    Weights { unit: String, detail: String },
    /// Structurally invalid layer ordering.
    Topology { detail: String },
}

impl fmt::Display for NetworkError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetworkError::EmptyNetwork => write!(f, "network has no layers"),
            NetworkError::Shape { unit, detail } => write!(f, "{unit}: {detail}"),
            NetworkError::ShiftSchedule { detail } => write!(f, "shift schedule: {detail}"),
            NetworkError::Weights { unit, detail } => write!(f, "{unit} weights: {detail}"),
            NetworkError::Topology { detail } => write!(f, "topology: {detail}"),
        }
    }
}

impl std::error::Error for NetworkError {}

/// One declared layer. Quantization shifts live on the spec that applies
/// them (the unified schedule the builder validates as a whole).
pub enum LayerSpec {
    /// Fully-connected layer. `init: None` → random 8-bit weights drawn at
    /// build time; `enc` selects encrypted-trainable vs frozen-plaintext.
    Fc { out: usize, init: Option<Vec<Vec<i64>>>, enc: bool },
    /// Convolution (`kernels[oc][ic][kh][kw]`). `init: None` is a
    /// shape-only placeholder, valid for `compile` but not `build`.
    Conv { out_ch: usize, k: usize, init: Option<Vec<Vec<Vec<Vec<i64>>>>>, enc: bool },
    /// Frozen affine batch-norm.
    BatchNorm { bn: BnLayer },
    /// 2×2 stride-2 average pooling.
    AvgPool,
    /// CHW → vector adapter (zero homomorphic ops).
    Flatten,
    /// TFHE ReLU with its forward/backward quantization shifts.
    Relu { act_shift: u32, err_shift: u32 },
    /// Figure-4 softmax output unit (must be the last layer).
    Softmax { bits: usize, logit_shift: u32 },
    /// An arbitrary pre-built unit (e.g. the FHESGD sigmoid TLU).
    Custom { unit: Box<dyn Layer> },
}

impl LayerSpec {
    /// Weight-free plan entry: the same kinds/shapes/op counts the
    /// materialized unit's `Layer::plan_entry` reports (shared helper
    /// formulas guarantee it).
    fn plan_entry(
        &self,
        shape: &[usize],
        batch: usize,
        is_last: bool,
    ) -> Result<LayerPlanEntry, NetworkError> {
        match self {
            LayerSpec::Fc { out, init, enc } => {
                if shape.len() != 1 {
                    return Err(NetworkError::Shape {
                        unit: "fc".into(),
                        detail: format!(
                            "FC needs a flat input vector, got shape {shape:?} — insert .flatten() first"
                        ),
                    });
                }
                let in_dim = shape[0];
                if in_dim == 0 || *out == 0 {
                    return Err(NetworkError::Shape {
                        unit: "fc".into(),
                        detail: format!("zero-width FC ({in_dim}→{out})"),
                    });
                }
                if let Some(w) = init {
                    if w.len() != *out || w.iter().any(|row| row.len() != in_dim) {
                        return Err(NetworkError::Weights {
                            unit: "fc".into(),
                            detail: format!(
                                "expected {out}×{in_dim} weight matrix, got {}×{}",
                                w.len(),
                                w.first().map_or(0, Vec::len)
                            ),
                        });
                    }
                }
                Ok(LayerPlanEntry {
                    kind: LayerKind::Fc { trainable: *enc },
                    out_shape: vec![*out],
                    // builder-made FC layers carry no bias (0 bias terms)
                    forward: fc_forward_ops(in_dim, *out, *enc, 0),
                    error: Some(fc_error_ops(in_dim, *out, *enc)),
                    gradient: if *enc { Some(fc_gradient_ops(in_dim, *out)) } else { None },
                    out_packed: false,
                })
            }
            LayerSpec::Conv { out_ch, k, init, enc } => {
                if shape.len() != 3 {
                    return Err(NetworkError::Shape {
                        unit: "conv".into(),
                        detail: format!("conv needs a CHW input, got shape {shape:?}"),
                    });
                }
                let (c, h, w) = (shape[0], shape[1], shape[2]);
                if *out_ch == 0 || *k == 0 || c == 0 {
                    return Err(NetworkError::Shape {
                        unit: "conv".into(),
                        detail: format!(
                            "zero-size convolution ({c}→{out_ch} channels, {k}×{k} kernel)"
                        ),
                    });
                }
                if h < *k || w < *k {
                    return Err(NetworkError::Shape {
                        unit: "conv".into(),
                        detail: format!("{k}×{k} kernel does not fit a {h}×{w} input"),
                    });
                }
                if let Some(ker) = init {
                    let ok = ker.len() == *out_ch
                        && ker.iter().all(|oc| {
                            oc.len() == c
                                && oc.iter().all(|ic| {
                                    ic.len() == *k && ic.iter().all(|row| row.len() == *k)
                                })
                        });
                    if !ok {
                        return Err(NetworkError::Weights {
                            unit: "conv".into(),
                            detail: format!("expected {out_ch}×{c}×{k}×{k} kernels"),
                        });
                    }
                }
                let (oh, ow) = (h - k + 1, w - k + 1);
                Ok(LayerPlanEntry {
                    kind: LayerKind::Conv { trainable: false },
                    out_shape: vec![*out_ch, oh, ow],
                    forward: conv_forward_ops(c, *out_ch, *k, oh, ow, *enc),
                    error: None,
                    gradient: None,
                    out_packed: false,
                })
            }
            LayerSpec::BatchNorm { bn } => {
                if shape.len() != 3 {
                    return Err(NetworkError::Shape {
                        unit: "batchnorm".into(),
                        detail: format!("BN needs a CHW input, got shape {shape:?}"),
                    });
                }
                if bn.gain.len() != shape[0] {
                    return Err(NetworkError::Shape {
                        unit: "batchnorm".into(),
                        detail: format!("{} BN channels on a {}-channel tensor", bn.gain.len(), shape[0]),
                    });
                }
                Ok(LayerPlanEntry {
                    kind: LayerKind::BatchNorm,
                    out_shape: shape.to_vec(),
                    forward: bn_forward_ops(shape.iter().product()),
                    error: None,
                    gradient: None,
                    out_packed: false,
                })
            }
            LayerSpec::AvgPool => {
                if shape.len() != 3 || shape[1] < 2 || shape[2] < 2 {
                    return Err(NetworkError::Shape {
                        unit: "avg_pool".into(),
                        detail: format!("2×2 pooling needs a CHW input with H,W ≥ 2, got {shape:?}"),
                    });
                }
                let out_shape = vec![shape[0], shape[1] / 2, shape[2] / 2];
                Ok(LayerPlanEntry {
                    kind: LayerKind::AvgPool,
                    forward: pool_forward_ops(out_shape.iter().product()),
                    out_shape,
                    error: None,
                    gradient: None,
                    out_packed: false,
                })
            }
            LayerSpec::Flatten => Ok(LayerPlanEntry {
                kind: LayerKind::Flatten,
                out_shape: vec![shape.iter().product()],
                forward: Default::default(),
                error: None,
                gradient: None,
                out_packed: false,
            }),
            LayerSpec::Relu { .. } => {
                let cts: usize = shape.iter().product();
                Ok(LayerPlanEntry {
                    kind: LayerKind::Relu,
                    out_shape: shape.to_vec(),
                    forward: relu_forward_ops(cts, batch),
                    error: Some(relu_error_ops(cts, batch)),
                    gradient: None,
                    out_packed: false,
                })
            }
            LayerSpec::Softmax { bits, .. } => {
                if !is_last {
                    return Err(NetworkError::Topology {
                        detail: "softmax must be the last layer".into(),
                    });
                }
                if *bits == 0 || *bits > SWITCH_BITS as usize {
                    return Err(NetworkError::Topology {
                        detail: format!("softmax width {bits} outside 1..={SWITCH_BITS} bits"),
                    });
                }
                if shape.len() != 1 {
                    return Err(NetworkError::Shape {
                        unit: "softmax".into(),
                        detail: format!("softmax needs a flat logit vector, got shape {shape:?}"),
                    });
                }
                let unit = SoftmaxUnit::logistic(*bits, 4);
                Ok(LayerPlanEntry {
                    kind: LayerKind::Softmax,
                    out_shape: shape.to_vec(),
                    forward: softmax_forward_ops(shape[0], batch, unit.plan_gates_per_lane()),
                    error: Some(softmax_error_ops(shape[0])),
                    gradient: None,
                    out_packed: false,
                })
            }
            LayerSpec::Custom { unit } => Ok(unit.plan_entry(shape, batch)),
        }
    }
}

/// The fluent network declaration.
pub struct NetworkBuilder {
    in_shape: Vec<usize>,
    specs: Vec<LayerSpec>,
    grad_shift: u32,
}

impl NetworkBuilder {
    /// Start from an arbitrary input shape.
    pub fn input(shape: &[usize]) -> Self {
        NetworkBuilder { in_shape: shape.to_vec(), specs: Vec::new(), grad_shift: 8 }
    }

    /// Start from a flat feature vector (MLPs).
    pub fn input_vec(dim: usize) -> Self {
        Self::input(&[dim])
    }

    /// Start from a CHW image (CNNs).
    pub fn input_image(c: usize, h: usize, w: usize) -> Self {
        Self::input(&[c, h, w])
    }

    /// Trainable FC layer with random 8-bit initial weights, encrypted at
    /// build time.
    pub fn fc(mut self, out: usize) -> Self {
        self.specs.push(LayerSpec::Fc { out, init: None, enc: true });
        self
    }

    /// Trainable FC layer from explicit initial weights, encrypted at
    /// build time.
    pub fn fc_encrypted(mut self, init: Vec<Vec<i64>>) -> Self {
        let out = init.len();
        self.specs.push(LayerSpec::Fc { out, init: Some(init), enc: true });
        self
    }

    /// Frozen plaintext FC layer (transfer learning).
    pub fn fc_frozen(mut self, init: Vec<Vec<i64>>) -> Self {
        let out = init.len();
        self.specs.push(LayerSpec::Fc { out, init: Some(init), enc: false });
        self
    }

    /// Frozen plaintext convolution from pre-trained kernels.
    pub fn conv_frozen(mut self, init: Vec<Vec<Vec<Vec<i64>>>>) -> Self {
        let out_ch = init.len();
        let k = init.first().and_then(|oc| oc.first()).map_or(0, Vec::len);
        self.specs.push(LayerSpec::Conv { out_ch, k, init: Some(init), enc: false });
        self
    }

    /// Shape-only frozen convolution: compiles to a plan but cannot be
    /// built (used by `glyph plan --cnn` to print paper-scale schedules
    /// without materializing weights).
    pub fn conv_frozen_shape(mut self, out_ch: usize, k: usize) -> Self {
        self.specs.push(LayerSpec::Conv { out_ch, k, init: None, enc: false });
        self
    }

    /// Encrypted-kernel convolution (forward-only ablation).
    pub fn conv_encrypted(mut self, init: Vec<Vec<Vec<Vec<i64>>>>) -> Self {
        let out_ch = init.len();
        let k = init.first().and_then(|oc| oc.first()).map_or(0, Vec::len);
        self.specs.push(LayerSpec::Conv { out_ch, k, init: Some(init), enc: true });
        self
    }

    /// Frozen affine batch-norm.
    pub fn batchnorm(mut self, bn: BnLayer) -> Self {
        self.specs.push(LayerSpec::BatchNorm { bn });
        self
    }

    /// Identity batch-norm placeholder (plan printing / tests).
    pub fn batchnorm_identity(self, channels: usize) -> Self {
        self.batchnorm(BnLayer { gain: vec![1; channels], bias: vec![0; channels], gain_shift: 0 })
    }

    /// 2×2 stride-2 average pooling.
    pub fn avg_pool(mut self) -> Self {
        self.specs.push(LayerSpec::AvgPool);
        self
    }

    /// CHW → vector adapter in front of the FC head.
    pub fn flatten(mut self) -> Self {
        self.specs.push(LayerSpec::Flatten);
        self
    }

    /// TFHE ReLU; `act_shift`/`err_shift` are this layer's forward and
    /// backward quantization shifts.
    pub fn relu(mut self, act_shift: u32, err_shift: u32) -> Self {
        self.specs.push(LayerSpec::Relu { act_shift, err_shift });
        self
    }

    /// Figure-4 softmax output unit over `bits`-bit logits quantized by
    /// `logit_shift` (the producing FC layer's activation shift).
    pub fn softmax(mut self, bits: usize, logit_shift: u32) -> Self {
        self.specs.push(LayerSpec::Softmax { bits, logit_shift });
        self
    }

    /// An arbitrary pre-built unit.
    pub fn custom(mut self, unit: Box<dyn Layer>) -> Self {
        self.specs.push(LayerSpec::Custom { unit });
        self
    }

    /// Gradient/learning-rate shift for every trainable layer.
    pub fn grad_shift(mut self, shift: u32) -> Self {
        self.grad_shift = shift;
        self
    }

    /// Walk the specs: validate, name and compute every unit's plan entry
    /// plus its output shape.
    fn plan_layers(&self, batch: usize) -> Result<Vec<(PlanLayer, Vec<usize>)>, NetworkError> {
        if self.specs.is_empty() {
            return Err(NetworkError::EmptyNetwork);
        }
        let mut shape = self.in_shape.clone();
        let mut out = Vec::with_capacity(self.specs.len());
        let (mut n_fc, mut n_conv, mut n_bn, mut n_pool, mut n_act) = (0, 0, 0, 0, 0);
        let last = self.specs.len() - 1;
        for (i, spec) in self.specs.iter().enumerate() {
            let entry = spec.plan_entry(&shape, batch, i == last)?;
            let name = match entry.kind {
                LayerKind::Fc { .. } => {
                    n_fc += 1;
                    format!("FC{n_fc}")
                }
                LayerKind::Conv { .. } => {
                    n_conv += 1;
                    format!("Conv{n_conv}")
                }
                LayerKind::BatchNorm => {
                    n_bn += 1;
                    format!("BN{n_bn}")
                }
                LayerKind::AvgPool => {
                    n_pool += 1;
                    format!("Pool{n_pool}")
                }
                LayerKind::Flatten => "Flatten".into(),
                LayerKind::Relu | LayerKind::Softmax | LayerKind::SigmoidTlu => {
                    n_act += 1;
                    format!("Act{n_act}")
                }
                LayerKind::QuadraticLoss => "Loss".into(),
            };
            shape = entry.out_shape.clone();
            out.push((
                PlanLayer {
                    name,
                    kind: entry.kind,
                    unit: Some(i),
                    forward: entry.forward,
                    error: entry.error,
                    gradient: entry.gradient,
                },
                entry.out_shape,
            ));
        }
        Ok(out)
    }

    /// Compile the executable plan *without* keys or weights — shape-only,
    /// instant even at paper scale.
    pub fn compile(&self, batch: usize) -> Result<Plan, NetworkError> {
        let layers: Vec<PlanLayer> =
            self.plan_layers(batch)?.into_iter().map(|(l, _)| l).collect();
        Ok(Plan::from_layers(&layers))
    }

    /// Validate every shift against the engine's fixed-point budget.
    fn validate_shifts(&self, frac: u32) -> Result<(), NetworkError> {
        if self.grad_shift > frac {
            return Err(NetworkError::ShiftSchedule {
                detail: format!(
                    "grad_shift {} exceeds the engine's {frac} fraction bits",
                    self.grad_shift
                ),
            });
        }
        for (i, spec) in self.specs.iter().enumerate() {
            match spec {
                LayerSpec::Relu { act_shift, err_shift } => {
                    if *act_shift > frac || *err_shift > frac {
                        return Err(NetworkError::ShiftSchedule {
                            detail: format!(
                                "layer {i}: ReLU shifts (act {act_shift}, err {err_shift}) exceed the engine's {frac} fraction bits"
                            ),
                        });
                    }
                }
                LayerSpec::Softmax { logit_shift, .. } => {
                    if *logit_shift > frac {
                        return Err(NetworkError::ShiftSchedule {
                            detail: format!(
                                "layer {i}: softmax logit shift {logit_shift} exceeds the engine's {frac} fraction bits"
                            ),
                        });
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Materialize the network: encode trainable weights through the
    /// backend's codec (encrypting them under the client key on FHE),
    /// build every unit, and compile the executable plan.
    pub fn build(
        self,
        client: &mut dyn Codec,
        rng: &mut GlyphRng,
        engine: &GlyphEngine,
    ) -> Result<Network, NetworkError> {
        let plan_layers = self.plan_layers(engine.batch)?;
        self.validate_shifts(engine.frac_bits())?;
        // the shift a following activation will apply (stored on the
        // producing FC/conv layer for inspection)
        let next_shift: Vec<u32> = (0..self.specs.len())
            .map(|i| match self.specs.get(i + 1) {
                Some(LayerSpec::Relu { act_shift, .. }) => *act_shift,
                Some(LayerSpec::Softmax { logit_shift, .. }) => *logit_shift,
                _ => 0,
            })
            .collect();
        let in_shapes: Vec<Vec<usize>> = std::iter::once(self.in_shape.clone())
            .chain(plan_layers.iter().map(|(_, s)| s.clone()))
            .collect();
        let grad_shift = self.grad_shift;
        let in_shape = self.in_shape.clone();
        let mut units: Vec<NamedUnit> = Vec::with_capacity(self.specs.len());
        // under the packed engine, whether the *next* unit's forward input
        // arrives as packed blocks: the trainer packs the network input, and
        // the flat ReLU re-packs its per-neuron outputs; everything else
        // hands per-scalar ciphertexts downstream
        let mut in_packed = engine.packed_layout().is_some();
        for (i, spec) in self.specs.into_iter().enumerate() {
            let name = plan_layers[i].0.name.clone();
            let spec_is_relu = matches!(spec, LayerSpec::Relu { .. });
            let layer: Box<dyn Layer> = match spec {
                LayerSpec::Fc { out, init, enc } => {
                    let in_dim = in_shapes[i][0];
                    let w = init.unwrap_or_else(|| {
                        (0..out)
                            .map(|_| {
                                (0..in_dim).map(|_| (rng.uniform_mod(31) as i64) - 15).collect()
                            })
                            .collect()
                    });
                    match (enc, engine.packed_layout()) {
                        (true, Some(layout)) => Box::new(PackedFcLayer::new_encrypted(
                            &w,
                            client,
                            next_shift[i],
                            layout,
                            in_packed,
                            engine.params().n,
                        )),
                        (true, None) => Box::new(FcLayer::new_encrypted(&w, client, next_shift[i])),
                        (false, _) => Box::new(FcLayer::new_plain(&w, engine, next_shift[i])),
                    }
                }
                LayerSpec::Conv { init, enc, .. } => {
                    let ker = init.ok_or_else(|| NetworkError::Weights {
                        unit: name.clone(),
                        detail: "shape-only conv spec cannot be built — provide kernels".into(),
                    })?;
                    if enc {
                        Box::new(ConvLayer::new_encrypted(&ker, client, next_shift[i]))
                    } else {
                        Box::new(ConvLayer::new_plain(&ker, engine, next_shift[i]))
                    }
                }
                LayerSpec::BatchNorm { bn } => Box::new(bn),
                LayerSpec::AvgPool => Box::new(AvgPoolLayer),
                LayerSpec::Flatten => Box::new(FlattenLayer),
                LayerSpec::Relu { act_shift, err_shift } => {
                    Box::new(ReluLayer { act_shift, err_shift })
                }
                LayerSpec::Softmax { bits, logit_shift } => Box::new(SoftmaxLayer {
                    unit: SoftmaxUnit::logistic(bits, 4),
                    logit_shift,
                }),
                LayerSpec::Custom { unit } => unit,
            };
            // only the flat (1-D input) ReLU emits packed blocks; every
            // other unit — packed FC, conv, BN, pool, flatten, CHW ReLU —
            // hands per-scalar ciphertexts to the unit above
            in_packed = spec_is_relu && in_shapes[i].len() == 1;
            units.push(NamedUnit { name, layer });
        }
        let plan =
            Network::compile_units(&units, &in_shape, engine.batch, engine.packed_layout());
        Ok(Network { units, in_shape, grad_shift, plan })
    }
}

/// A materialized unit with its table-row name (FC1, Act2, …).
pub struct NamedUnit {
    pub name: String,
    pub layer: Box<dyn Layer>,
}

/// Everything one network forward pass produces: per-unit outputs and
/// backward state. `outputs[i]` is unit `i`'s output; the input of unit
/// `i > 0` is `outputs[i − 1]`.
pub struct ForwardPass {
    pub outputs: Vec<EncTensor>,
    pub states: Vec<LayerState>,
}

impl ForwardPass {
    /// The network output (the last unit's tensor).
    pub fn output(&self) -> &EncTensor {
        self.outputs.last().expect("network has at least one unit")
    }
}

/// A compiled, executable network. Built by [`NetworkBuilder::build`];
/// `forward`/`train_step` walk [`Network::plan`].
pub struct Network {
    pub units: Vec<NamedUnit>,
    pub in_shape: Vec<usize>,
    pub grad_shift: u32,
    /// The compiled schedule (recompile with [`Network::compile`] after
    /// changing the engine's batch width).
    pub plan: Plan,
}

impl Network {
    fn compile_units(
        units: &[NamedUnit],
        in_shape: &[usize],
        batch: usize,
        layout: Option<&PackedLayout>,
    ) -> Plan {
        let mut shape = in_shape.to_vec();
        // packed engines hand the network its input as packed blocks; each
        // entry's `out_packed` feeds the next unit's `in_packed`
        let mut in_packed = layout.is_some();
        let mut layers = Vec::with_capacity(units.len());
        for (i, u) in units.iter().enumerate() {
            let e = match layout {
                Some(l) => u.layer.plan_entry_packed(&shape, l, in_packed),
                None => u.layer.plan_entry(&shape, batch),
            };
            in_packed = e.out_packed;
            layers.push(PlanLayer {
                name: u.name.clone(),
                kind: e.kind,
                unit: Some(i),
                forward: e.forward,
                error: e.error,
                gradient: e.gradient,
            });
            shape = e.out_shape;
        }
        Plan::from_layers(&layers)
    }

    /// Compile the schedule for this network under `engine`'s batch width —
    /// the one plan consumed by execution, the cost model and the CLI.
    /// Packed engines compile the packed schedule (exact per-block counts).
    pub fn compile(&self, engine: &GlyphEngine) -> Plan {
        Self::compile_units(&self.units, &self.in_shape, engine.batch, engine.packed_layout())
    }

    /// Forward pass: walk the plan's forward steps in order.
    pub fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> ForwardPass {
        let mut outputs: Vec<EncTensor> = Vec::with_capacity(self.units.len());
        let mut states: Vec<LayerState> = Vec::with_capacity(self.units.len());
        for step in self.plan.steps.iter().filter(|s| s.phase == StepPhase::Forward) {
            let i = step.unit.expect("compiled plans carry unit indices");
            debug_assert_eq!(i, outputs.len(), "forward steps must cover units in order");
            let (out, st) = {
                let input = if i == 0 { x } else { &outputs[i - 1] };
                self.units[i].layer.forward(input, engine)
            };
            outputs.push(out);
            states.push(st);
        }
        ForwardPass { outputs, states }
    }

    /// One encrypted SGD mini-batch step, *driven by the compiled plan*:
    /// the backward walk executes exactly the error/gradient steps the plan
    /// emitted (error propagation stops below the lowest trainable layer,
    /// the paper's transfer-learning truncation), then applies all updates.
    /// `x` is forward-packed, `labels_rev` the reverse-packed one-hot
    /// targets; the output unit turns them into the loss derivative.
    pub fn train_step(&mut self, x: &EncTensor, labels_rev: &EncTensor, engine: &GlyphEngine) {
        assert!(
            self.units.last().is_some_and(|u| u.layer.is_output_unit()),
            "train_step needs the network to end in an output unit (softmax or an output \
             sigmoid) that turns the labels into a loss derivative; this network is \
             forward-only — append .softmax(..) to train it"
        );
        let pass = self.forward(x, engine);
        let backward: Vec<(usize, StepPhase)> = self
            .plan
            .steps
            .iter()
            .filter(|s| s.phase != StepPhase::Forward)
            .map(|s| (s.unit.expect("compiled plans carry unit indices"), s.phase))
            .collect();
        // `delta` is the error arriving *at the current unit's output*;
        // a unit's error step computes the propagated error (`pending`),
        // which is committed when the walk moves on to a lower unit — so a
        // layer's gradient step still sees the incoming delta even though
        // the plan lists error before gradient (the Tables-3/4 row order).
        let mut delta: Option<EncTensor> = None;
        let mut pending: Option<EncTensor> = None;
        let mut cur_unit: Option<usize> = None;
        let mut grads: Vec<Option<LayerGrads>> = (0..self.units.len()).map(|_| None).collect();
        for (i, phase) in backward {
            if cur_unit != Some(i) {
                if let Some(p) = pending.take() {
                    delta = Some(p);
                }
                cur_unit = Some(i);
            }
            match phase {
                StepPhase::Error => {
                    let next = {
                        // the first error step is the output unit's loss
                        // derivative, fed by the labels
                        let incoming = delta.as_ref().unwrap_or(labels_rev);
                        self.units[i].layer.backward_error(incoming, &pass.states[i], engine)
                    };
                    pending = Some(next);
                }
                StepPhase::Gradient => {
                    let below = if i == 0 { x } else { &pass.outputs[i - 1] };
                    let d = delta.as_ref().expect(
                        "plan emitted a gradient before any error signal — the network lacks an output unit",
                    );
                    grads[i] = self.units[i].layer.gradients(below, d, engine);
                }
                StepPhase::Forward => unreachable!(),
            }
        }
        for (i, g) in grads.iter().enumerate() {
            if let Some(g) = g {
                self.units[i].layer.apply_gradients(g, self.grad_shift, engine);
            }
        }
    }

    /// The trainable/inspectable FC layers, bottom-up.
    pub fn fc_layers(&self) -> Vec<&FcLayer> {
        self.units.iter().filter_map(|u| u.layer.as_fc()).collect()
    }

    /// FC layers with their unit indices, bottom-up (checkpoint capture
    /// keys weights by unit index).
    pub fn fc_units(&self) -> Vec<(usize, &FcLayer)> {
        self.units
            .iter()
            .enumerate()
            .filter_map(|(i, u)| u.layer.as_fc().map(|fc| (i, fc)))
            .collect()
    }

    /// Mutable FC access by unit index (checkpoint restore).
    pub fn fc_unit_mut(&mut self, unit: usize) -> Option<&mut FcLayer> {
        self.units.get_mut(unit).and_then(|u| u.layer.as_fc_mut())
    }

    /// Packed FC layers with their unit indices, bottom-up (weight readback
    /// for packed networks goes through [`PackedFcLayer::decrypt_weights`]).
    pub fn packed_fc_units(&self) -> Vec<(usize, &PackedFcLayer)> {
        self.units
            .iter()
            .enumerate()
            .filter_map(|(i, u)| u.layer.as_packed_fc().map(|fc| (i, fc)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::EngineProfile;
    use crate::nn::tensor::PackOrder;

    fn tiny_mlp_builder() -> NetworkBuilder {
        NetworkBuilder::input_vec(3).fc(4).relu(8, 7).fc(2).softmax(3, 7).grad_shift(8)
    }

    #[test]
    fn builder_compile_matches_built_network_plan() {
        let batch = 2;
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 111);
        let mut rng = GlyphRng::new(5);
        let spec_plan = tiny_mlp_builder().compile(batch).unwrap();
        let net = tiny_mlp_builder().build(&mut client, &mut rng, &engine).unwrap();
        assert_eq!(spec_plan.steps.len(), net.plan.steps.len());
        for (a, b) in spec_plan.steps.iter().zip(&net.plan.steps) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.system, b.system);
            assert_eq!(a.switch, b.switch);
            assert_eq!(a.ops, b.ops, "{}", a.name);
        }
        assert!(net.plan.validate());
        let names: Vec<&str> = net.plan.steps.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "FC1-forward",
                "Act1-forward",
                "FC2-forward",
                "Act2-forward",
                "Act2-error",
                "FC2-error",
                "FC2-gradient",
                "Act1-error",
                "FC1-gradient"
            ]
        );
    }

    #[test]
    fn builder_rejects_bad_shift_schedule() {
        let batch = 2;
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 112);
        let mut rng = GlyphRng::new(6);
        // test profile has 8 fraction bits; 20 must be rejected, not clamped
        let err = NetworkBuilder::input_vec(3)
            .fc(2)
            .softmax(3, 20)
            .build(&mut client, &mut rng, &engine)
            .err()
            .expect("over-budget logit shift must fail");
        assert!(matches!(err, NetworkError::ShiftSchedule { .. }), "{err}");
        assert!(err.to_string().contains("20"), "{err}");
    }

    #[test]
    fn builder_rejects_fc_on_image_without_flatten() {
        let err = NetworkBuilder::input_image(1, 4, 4).fc(2).compile(2).err().unwrap();
        assert!(matches!(err, NetworkError::Shape { .. }), "{err}");
        assert!(err.to_string().contains("flatten"), "{err}");
    }

    #[test]
    fn builder_rejects_zero_size_conv() {
        let err = NetworkBuilder::input_image(1, 14, 14).conv_frozen(vec![]).compile(2).err().unwrap();
        assert!(matches!(err, NetworkError::Shape { .. }), "{err}");
        assert!(err.to_string().contains("zero-size"), "{err}");
    }

    #[test]
    fn builder_rejects_midstream_softmax() {
        let err =
            NetworkBuilder::input_vec(4).fc(3).softmax(3, 7).fc(2).compile(2).err().unwrap();
        assert!(matches!(err, NetworkError::Topology { .. }), "{err}");
    }

    #[test]
    #[should_panic(expected = "output unit")]
    fn train_step_refuses_networks_without_an_output_unit() {
        let batch = 2;
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 114);
        let mut rng = GlyphRng::new(8);
        // forward-only chain: labels must never flow backward as a fake
        // loss derivative
        let mut net = NetworkBuilder::input_vec(3)
            .fc(4)
            .relu(8, 7)
            .build(&mut client, &mut rng, &engine)
            .unwrap();
        let x_cts = (0..3).map(|i| client.encrypt_batch(&[i as i64, 1], 0)).collect();
        let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
        let lab_cts = (0..4).map(|_| client.encrypt_batch(&[0, 0], 0)).collect();
        let labels = EncTensor::new(lab_cts, vec![4], PackOrder::Reversed, 0);
        net.train_step(&x, &labels, &engine);
    }

    #[test]
    fn network_train_step_moves_weights() {
        let batch = 2;
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 113);
        let mut rng = GlyphRng::new(7);
        let mut net = tiny_mlp_builder().build(&mut client, &mut rng, &engine).unwrap();
        let x_cts = (0..3).map(|i| client.encrypt_batch(&[10 * i as i64, -5], 0)).collect();
        let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
        let lab_cts = (0..2)
            .map(|k| client.encrypt_batch(&[if k == 0 { 127 } else { 0 }, 0], 0))
            .collect();
        let labels = EncTensor::new(lab_cts, vec![2], PackOrder::Reversed, 0);
        let before: Vec<i64> = net
            .fc_layers()
            .iter()
            .flat_map(|l| {
                l.w.iter().flat_map(|row| {
                    row.iter().map(|w| match w {
                        crate::nn::linear::Weight::Enc(ct) => client.decrypt_batch(ct, 1, 0)[0],
                        crate::nn::linear::Weight::Plain(p) => p.value(),
                    })
                })
            })
            .collect();
        net.train_step(&x, &labels, &engine);
        let after: Vec<i64> = net
            .fc_layers()
            .iter()
            .flat_map(|l| {
                l.w.iter().flat_map(|row| {
                    row.iter().map(|w| match w {
                        crate::nn::linear::Weight::Enc(ct) => client.decrypt_batch(ct, 1, 0)[0],
                        crate::nn::linear::Weight::Plain(p) => p.value(),
                    })
                })
            })
            .collect();
        assert_eq!(before.len(), 3 * 4 + 4 * 2);
        assert_ne!(before, after, "training must move at least one weight");
    }
}
