//! Convolutional layers (valid padding, stride 1).
//!
//! Under transfer learning the kernels are plaintext (frozen, pre-trained on
//! a public dataset), so every MAC is a cheap MultCP — the mechanism behind
//! the paper's Table-4 "MultCP" columns. An encrypted-kernel variant (full
//! Glyph-from-scratch CNN training) is supported for completeness and used
//! by the ablation benches.

use super::backend::{Codec, PlainWeight, Term};
use super::engine::GlyphEngine;
use super::layer::{
    conv_forward_ops, conv_forward_packed_ops, Layer, LayerPlanEntry, LayerState,
};
use super::linear::{shared_plain, Weight};
use super::tensor::{EncTensor, PackOrder, PackedLayout};
use crate::coordinator::scheduler::LayerKind;
use std::collections::{BTreeMap, HashMap};

/// A 2-D convolution `out[oc] = Σ_ic k[oc][ic] * x[ic]`, valid, stride 1.
pub struct ConvLayer {
    /// kernels[oc][ic][kh][kw]
    pub kernels: Vec<Vec<Vec<Vec<Weight>>>>,
    pub in_ch: usize,
    pub out_ch: usize,
    pub k: usize,
    pub out_shift: u32,
}

impl ConvLayer {
    /// Frozen plaintext kernels (transfer learning); one evaluation-form
    /// lift per distinct tap value, cached at construction and shared
    /// across the kernel bank.
    pub fn new_plain(init: &[Vec<Vec<Vec<i64>>>], engine: &GlyphEngine, out_shift: u32) -> Self {
        let out_ch = init.len();
        let in_ch = init[0].len();
        let k = init[0][0].len();
        let mut cache = HashMap::new();
        let kernels = init
            .iter()
            .map(|oc| {
                oc.iter()
                    .map(|ic| {
                        ic.iter()
                            .map(|row| {
                                row.iter()
                                    .map(|&v| Weight::Plain(shared_plain(&mut cache, v, engine)))
                                    .collect()
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        ConvLayer { kernels, in_ch, out_ch, k, out_shift }
    }

    /// Encrypted kernels (from-scratch CNN training; ablation).
    pub fn new_encrypted(
        init: &[Vec<Vec<Vec<i64>>>],
        client: &mut dyn Codec,
        out_shift: u32,
    ) -> Self {
        let out_ch = init.len();
        let in_ch = init[0].len();
        let k = init[0][0].len();
        let kernels = init
            .iter()
            .map(|oc| {
                oc.iter()
                    .map(|ic| {
                        ic.iter()
                            .map(|row| row.iter().map(|&v| Weight::Enc(client.encrypt_scalar(v))).collect())
                            .collect()
                    })
                    .collect()
            })
            .collect();
        ConvLayer { kernels, in_ch, out_ch, k, out_shift }
    }

    pub fn out_hw(&self, in_h: usize, in_w: usize) -> (usize, usize) {
        (in_h - self.k + 1, in_w - self.k + 1)
    }

    /// Forward convolution on a CHW tensor: one MAC row per output
    /// position (`in_ch·k²` taps each), fanned across the pool through the
    /// lazy-relin engine. The layer's *exit* conversion — all
    /// `out_ch·oh·ow` output ciphertexts crossing to TFHE for the following
    /// activation — rides the batched switch engine: the downstream
    /// `relu_layer` hands the whole tensor to `switch_down_many` in one
    /// fan-out instead of per-ciphertext calls.
    pub fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        assert_eq!(x.shape.len(), 3, "conv expects CHW");
        assert_eq!(x.shape[0], self.in_ch);
        let (in_h, in_w) = (x.shape[1], x.shape[2]);
        let (oh, ow) = self.out_hw(in_h, in_w);
        let mut rows: Vec<Vec<Term>> = Vec::with_capacity(self.out_ch * oh * ow);
        for oc in 0..self.out_ch {
            for y in 0..oh {
                for xx in 0..ow {
                    let mut row = Vec::with_capacity(self.in_ch * self.k * self.k);
                    for ic in 0..self.in_ch {
                        for ky in 0..self.k {
                            for kx in 0..self.k {
                                let xin = x.chw(ic, y + ky, xx + kx);
                                row.push(self.kernels[oc][ic][ky][kx].term(xin));
                            }
                        }
                    }
                    rows.push(row);
                }
            }
        }
        let cts = engine.mac_rows_many(&rows);
        EncTensor::new(cts, vec![self.out_ch, oh, ow], x.order, x.shift)
    }

    /// Forward convolution over a cross-sample SIMD packed image: the CHW
    /// input arrives as [`PackedLayout`] blocks over the flattened feature
    /// index `j = (ic·H + y)·W + x`, and each output position MACs one
    /// anchored kernel *polynomial* per input block its taps touch — tap
    /// `j` anchored at `(F−1 − j mod F)·stride` so every product lands on
    /// the common payload base. One MultCP carries the whole minibatch,
    /// which is the packed layout's amortization of the Table-4 MultCP
    /// columns. Output: per-pixel ciphertexts with the batch at
    /// `payload_base() + b` (frozen plaintext kernels only — the
    /// encrypted-kernel ablation keeps the per-scalar layout).
    pub fn forward_packed(&self, x: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        let layout = x.layout.as_ref().expect("packed conv consumes packed blocks");
        assert!(
            !self.is_encrypted(),
            "the packed conv path supports frozen plaintext kernels only"
        );
        assert_eq!(x.shape.len(), 3, "conv expects CHW");
        assert_eq!(x.shape[0], self.in_ch);
        assert_eq!(x.order, PackOrder::Forward, "packed conv inputs pack forward");
        let (in_h, in_w) = (x.shape[1], x.shape[2]);
        let (oh, ow) = self.out_hw(in_h, in_w);
        let n = engine.params().n;
        let f = layout.feats_per_ct;
        // group each output position's taps by input block and bake one
        // anchored kernel polynomial per (position, channel, block)
        let mut weights: Vec<PlainWeight> = Vec::new();
        // per MAC row: the (input block, index into `weights`) of each term
        let mut row_specs: Vec<Vec<(usize, usize)>> = Vec::with_capacity(self.out_ch * oh * ow);
        for oc in 0..self.out_ch {
            for y in 0..oh {
                for xx in 0..ow {
                    let mut per_block: BTreeMap<usize, Vec<i64>> = BTreeMap::new();
                    for ic in 0..self.in_ch {
                        for ky in 0..self.k {
                            for kx in 0..self.k {
                                let j = (ic * in_h + y + ky) * in_w + xx + kx;
                                let anchor = (f - 1 - j % f) * layout.stride;
                                let tap = match &self.kernels[oc][ic][ky][kx] {
                                    Weight::Plain(p) => p.value(),
                                    Weight::Enc(_) => unreachable!("checked above"),
                                };
                                per_block.entry(j / f).or_insert_with(|| vec![0i64; n])
                                    [anchor] += tap;
                            }
                        }
                    }
                    let mut spec = Vec::with_capacity(per_block.len());
                    for (block, coeffs) in &per_block {
                        spec.push((*block, weights.len()));
                        weights.push(engine.poly_weight(coeffs));
                    }
                    row_specs.push(spec);
                }
            }
        }
        let rows: Vec<Vec<Term>> = row_specs
            .iter()
            .map(|spec| {
                spec.iter().map(|&(b, w)| Term::Cp(&x.cts[b], &weights[w])).collect()
            })
            .collect();
        let cts = engine.mac_rows_many(&rows);
        EncTensor::new(cts, vec![self.out_ch, oh, ow], x.order, x.shift)
            .with_lane_base(layout.payload_base())
    }
}

impl ConvLayer {
    /// Whether the kernels are encrypted (the from-scratch ablation) or
    /// frozen plaintext (transfer learning).
    pub fn is_encrypted(&self) -> bool {
        matches!(self.kernels.first().map(|oc| &oc[0][0][0]), Some(Weight::Enc(_)))
    }
}

impl Layer for ConvLayer {
    fn plan_entry(&self, in_shape: &[usize], _batch: usize) -> LayerPlanEntry {
        assert_eq!(in_shape.len(), 3, "conv expects CHW");
        assert_eq!(in_shape[0], self.in_ch, "conv channel mismatch");
        let (oh, ow) = self.out_hw(in_shape[1], in_shape[2]);
        LayerPlanEntry {
            // encrypted kernels run forward-only (ablation); conv
            // backprop is out of scope, so the plan never trains a conv
            kind: LayerKind::Conv { trainable: false },
            out_shape: vec![self.out_ch, oh, ow],
            forward: conv_forward_ops(self.in_ch, self.out_ch, self.k, oh, ow, self.is_encrypted()),
            error: None,
            gradient: None,
            out_packed: false,
        }
    }

    fn plan_entry_packed(
        &self,
        in_shape: &[usize],
        layout: &PackedLayout,
        in_packed: bool,
    ) -> LayerPlanEntry {
        assert!(in_packed, "the packed conv front consumes the packed input image");
        assert!(
            !self.is_encrypted(),
            "the packed conv path supports frozen plaintext kernels only"
        );
        assert_eq!(in_shape.len(), 3, "conv expects CHW");
        assert_eq!(in_shape[0], self.in_ch, "conv channel mismatch");
        let (oh, ow) = self.out_hw(in_shape[1], in_shape[2]);
        LayerPlanEntry {
            kind: LayerKind::Conv { trainable: false },
            out_shape: vec![self.out_ch, oh, ow],
            forward: conv_forward_packed_ops(
                self.in_ch,
                self.out_ch,
                self.k,
                in_shape[1],
                in_shape[2],
                layout,
            ),
            error: None,
            gradient: None,
            // per-pixel ciphertexts with the batch at the payload lanes
            out_packed: false,
        }
    }

    fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState) {
        let out = if x.is_packed() {
            self.forward_packed(x, engine)
        } else {
            ConvLayer::forward(self, x, engine)
        };
        (out, LayerState::None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::{EngineProfile, GlyphEngine};
    use crate::nn::tensor::PackOrder;

    #[test]
    fn plain_conv_matches_reference() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 800);
        // 1 channel, 3×3 input, 2×2 kernel.
        let img_b0 = [[1i64, 2, 3], [4, 5, 6], [7, 8, 9]];
        let img_b1 = [[-1i64, 0, 1], [2, -2, 3], [0, 1, -1]];
        let cts: Vec<_> = (0..9)
            .map(|i| {
                let (y, x) = (i / 3, i % 3);
                client.encrypt_batch(&[img_b0[y][x], img_b1[y][x]], 0)
            })
            .collect();
        let x = EncTensor::new(cts, vec![1, 3, 3], PackOrder::Forward, 0);
        let kern = vec![vec![vec![vec![1i64, -1], vec![2, 0]]]];
        let layer = ConvLayer::new_plain(&kern, &eng, 0);
        let out = layer.forward(&x, &eng);
        assert_eq!(out.shape, vec![1, 2, 2]);
        let reference = |img: &[[i64; 3]; 3], y: usize, x: usize| {
            img[y][x] - img[y][x + 1] + 2 * img[y + 1][x]
        };
        for y in 0..2 {
            for xx in 0..2 {
                let got = client.decrypt_batch(out.chw(0, y, xx), 2, 0);
                assert_eq!(got, vec![reference(&img_b0, y, xx), reference(&img_b1, y, xx)], "({y},{xx})");
            }
        }
        let s = eng.counter.snapshot();
        assert_eq!(s.mult_cp, 16); // 4 positions × 4 kernel taps
        assert_eq!(s.mult_cc, 0);
    }

    #[test]
    fn packed_conv_amortizes_mult_cp_over_blocks() {
        let (eng, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
        let layout = PackedLayout { batch: 2, stride: 4, feats_per_ct: 2, occupancy: None };
        let img_b0 = [[1i64, 2, 3], [4, 5, 6], [7, 8, 9]];
        let img_b1 = [[-1i64, 0, 1], [2, -2, 3], [0, 1, -1]];
        // flattened feature j = y·3 + x, one [sample] column each
        let cols: Vec<Vec<i64>> =
            (0..9).map(|j| vec![img_b0[j / 3][j % 3], img_b1[j / 3][j % 3]]).collect();
        let cts: Vec<_> =
            layout.pack_columns(&cols, 256).iter().map(|c| codec.encrypt_coeffs(c, 0)).collect();
        let x = EncTensor::packed(cts, vec![1, 3, 3], PackOrder::Forward, 0, layout.clone());
        let kern = vec![vec![vec![vec![1i64, -1], vec![2, 0]]]];
        let layer = ConvLayer::new_plain(&kern, &eng, 0);
        let (out, _) = Layer::forward(&layer, &x, &eng);
        assert_eq!(out.shape, vec![1, 2, 2]);
        assert!(!out.is_packed());
        assert_eq!(out.lane_base, layout.payload_base());
        let reference = |img: &[[i64; 3]; 3], y: usize, x: usize| {
            img[y][x] - img[y][x + 1] + 2 * img[y + 1][x]
        };
        let lanes = layout.lane_positions(PackOrder::Forward, out.lane_base);
        for y in 0..2 {
            for xx in 0..2 {
                let got = codec.decrypt_positions(&out.cts[y * 2 + xx], &lanes, 0);
                assert_eq!(
                    got,
                    vec![reference(&img_b0, y, xx), reference(&img_b1, y, xx)],
                    "({y},{xx})"
                );
            }
        }
        // live counters match the packed plan formula exactly: each of the
        // 4 output positions touches 3 of the 5 input blocks
        let s = eng.counter.snapshot();
        let plan = crate::nn::layer::conv_forward_packed_ops(1, 1, 2, 3, 3, &layout);
        assert_eq!((s.mult_cp, s.add_cc), (plan.mult_cp, plan.add_cc));
        assert_eq!((s.mult_cp, s.add_cc), (12, 8));
        assert_eq!(s.mult_cc, 0);
    }

    #[test]
    fn fhe_packed_conv_matches_the_clear_mirror() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 802);
        let layout = PackedLayout { batch: 2, stride: 4, feats_per_ct: 2, occupancy: None };
        let img_b0 = [[1i64, 2, 3], [4, 5, 6], [7, 8, 9]];
        let img_b1 = [[-1i64, 0, 1], [2, -2, 3], [0, 1, -1]];
        let cols: Vec<Vec<i64>> =
            (0..9).map(|j| vec![img_b0[j / 3][j % 3], img_b1[j / 3][j % 3]]).collect();
        let cts: Vec<_> =
            layout.pack_columns(&cols, 256).iter().map(|c| client.encrypt_coeffs(c, 0)).collect();
        let x = EncTensor::packed(cts, vec![1, 3, 3], PackOrder::Forward, 0, layout.clone());
        let kern = vec![vec![vec![vec![1i64, -1], vec![2, 0]]]];
        let layer = ConvLayer::new_plain(&kern, &eng, 0);
        let out = layer.forward_packed(&x, &eng);
        let reference = |img: &[[i64; 3]; 3], y: usize, x: usize| {
            img[y][x] - img[y][x + 1] + 2 * img[y + 1][x]
        };
        let lanes = layout.lane_positions(PackOrder::Forward, out.lane_base);
        for y in 0..2 {
            for xx in 0..2 {
                let got = client.decrypt_positions(&out.cts[y * 2 + xx], &lanes, 0);
                assert_eq!(
                    got,
                    vec![reference(&img_b0, y, xx), reference(&img_b1, y, xx)],
                    "({y},{xx})"
                );
            }
        }
    }

    #[test]
    fn encrypted_conv_counts_mult_cc() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 1, 801);
        let cts: Vec<_> = (0..4).map(|i| client.encrypt_batch(&[i as i64 + 1], 0)).collect();
        let x = EncTensor::new(cts, vec![1, 2, 2], PackOrder::Forward, 0);
        let kern = vec![vec![vec![vec![3i64, 0], vec![0, -2]]]];
        let layer = ConvLayer::new_encrypted(&kern, &mut client, 0);
        let out = layer.forward(&x, &eng);
        // 3·1 − 2·4 = −5
        assert_eq!(client.decrypt_batch(out.chw(0, 0, 0), 1, 0), vec![-5]);
        assert_eq!(eng.counter.snapshot().mult_cc, 4);
    }
}
