//! `EncTensor`: an activation/error tensor under either execution backend.
//!
//! One [`Ct`] per network scalar; the mini-batch lives in the polynomial
//! coefficients. Forward tensors pack sample b at coefficient b; backward
//! tensors pack sample b at coefficient `batch−1−b` (*reversed*), so that a
//! forward × backward MultCC leaves the batch-summed product — the SGD
//! gradient reduction — at coefficient `batch−1` (the negacyclic
//! convolution trick; DESIGN.md §2.1). The packing convention is
//! backend-independent: the clear mirror keeps the same coefficient layout.

use super::backend::Ct;

/// Packing order of the batch dimension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackOrder {
    /// sample b ↦ coefficient b.
    Forward,
    /// sample b ↦ coefficient batch−1−b.
    Reversed,
}

impl PackOrder {
    /// Coefficient positions of the batch lanes in this order.
    pub fn positions(&self, batch: usize) -> Vec<usize> {
        match self {
            PackOrder::Forward => (0..batch).collect(),
            PackOrder::Reversed => (0..batch).rev().collect(),
        }
    }
}

/// A backend-polymorphic tensor: `cts[i]` holds scalar `i` (row-major over
/// `shape`) for every sample of the mini-batch.
#[derive(Clone)]
pub struct EncTensor {
    pub cts: Vec<Ct>,
    pub shape: Vec<usize>,
    pub order: PackOrder,
    /// Fixed-point scale: stored value = real value · 2^shift.
    pub shift: u32,
}

impl EncTensor {
    pub fn new(cts: Vec<Ct>, shape: Vec<usize>, order: PackOrder, shift: u32) -> Self {
        debug_assert_eq!(cts.len(), shape.iter().product::<usize>());
        EncTensor { cts, shape, order, shift }
    }

    pub fn len(&self) -> usize {
        self.cts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cts.is_empty()
    }

    /// Index into a CHW-shaped tensor.
    pub fn chw(&self, c: usize, h: usize, w: usize) -> &Ct {
        let (_ch, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        &self.cts[(c * hh + h) * ww + w]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_positions() {
        assert_eq!(PackOrder::Forward.positions(4), vec![0, 1, 2, 3]);
        assert_eq!(PackOrder::Reversed.positions(4), vec![3, 2, 1, 0]);
    }
}
