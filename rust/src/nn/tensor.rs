//! `EncTensor`: an activation/error tensor under either execution backend.
//!
//! Two coefficient layouts share the ring:
//!
//! * **Per-scalar** (the original layout): one [`Ct`] per network scalar;
//!   the mini-batch lives in the polynomial coefficients. Forward tensors
//!   pack sample b at coefficient b; backward tensors pack sample b at
//!   coefficient `batch−1−b` (*reversed*), so that a forward × backward
//!   MultCC leaves the batch-summed product — the SGD gradient reduction —
//!   at coefficient `batch−1` (the negacyclic convolution trick;
//!   DESIGN.md §2.1).
//! * **Packed blocks** ([`PackedLayout`]): one [`Ct`] carries a
//!   `batch × feature` slot block — feature `j` of sample `b` at
//!   coefficient `(j mod F)·stride + b` — so MAC, switch, and bootstrap
//!   work is amortized across the whole mini-batch. `stride` is sized so
//!   that a packed × packed negacyclic product keeps every cross term off
//!   the payload lanes (see the field docs below).
//!
//! The packing conventions are backend-independent: the clear mirror keeps
//! the same coefficient layout bit-exactly.

use super::backend::Ct;

/// Packing order of the batch dimension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackOrder {
    /// sample b ↦ coefficient b.
    Forward,
    /// sample b ↦ coefficient batch−1−b.
    Reversed,
}

impl PackOrder {
    /// Coefficient positions of the batch lanes in this order.
    pub fn positions(&self, batch: usize) -> Vec<usize> {
        match self {
            PackOrder::Forward => (0..batch).collect(),
            PackOrder::Reversed => (0..batch).rev().collect(),
        }
    }
}

/// Cross-sample SIMD packing descriptor: how a `batch × feature` slot block
/// maps onto one ciphertext's coefficient slots.
///
/// Layout invariants (all enforced by [`PackedLayout::for_ring`]):
///
/// * `stride ≥ 2·batch − 1`, so a forward lane `b` times a reversed lane
///   `batch−1−b'` spreads at most `±(batch−1)` coefficients around its
///   feature's payload slot without touching a neighbouring feature.
/// * `stride · (2·feats_per_ct − 1) ≤ n`, so the negacyclic wrap of a
///   packed × packed product never folds garbage back onto payload lanes.
///
/// With `F = feats_per_ct`, feature `j` of sample `b` lives at coefficient
/// `(j mod F)·stride + b` of block `⌊j/F⌋` (forward order), or at
/// `(F−1−(j mod F))·stride + (batch−1−b)` (reversed order). Packed weight
/// blocks anchor weight `k` at `(F−1−k)·stride`, so every block's MAC
/// payload lands at the common base `(F−1)·stride + b`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedLayout {
    /// Samples interleaved per feature lane (samples-per-ciphertext).
    pub batch: usize,
    /// Slot stride between consecutive feature lanes.
    pub stride: usize,
    /// Feature lanes per ciphertext (`F`).
    pub feats_per_ct: usize,
    /// Occupancy of the batch lanes: `None` = fully occupied; otherwise
    /// `occupancy[b]` says whether sample lane `b` carries payload (partial
    /// final mini-batches leave trailing lanes vacant, sparse masks leave
    /// holes). Vacant lanes encode as zero and decode as zero.
    pub occupancy: Option<Vec<bool>>,
}

impl PackedLayout {
    /// Derive the densest legal layout for `batch` samples in a ring of
    /// degree `n`: the smallest power-of-two stride that isolates the
    /// cross-sample spread, then as many feature lanes as fit under the
    /// no-wrap bound.
    pub fn for_ring(batch: usize, n: usize) -> Result<Self, String> {
        if batch == 0 {
            return Err("packed layout needs at least one sample lane".into());
        }
        let stride = (2 * batch - 1).next_power_of_two();
        if stride > n {
            return Err(format!(
                "batch {batch} needs slot stride {stride} which exceeds the ring degree {n}"
            ));
        }
        let feats_per_ct = (n / stride + 1) / 2;
        debug_assert!(feats_per_ct >= 1 && stride * (2 * feats_per_ct - 1) <= n);
        Ok(PackedLayout { batch, stride, feats_per_ct, occupancy: None })
    }

    /// Restrict the layout to a subset of occupied sample lanes.
    pub fn with_occupancy(mut self, mask: Vec<bool>) -> Self {
        assert_eq!(mask.len(), self.batch, "occupancy mask must cover every sample lane");
        self.occupancy = Some(mask);
        self
    }

    /// Whether sample lane `b` carries payload.
    pub fn occupied(&self, b: usize) -> bool {
        match &self.occupancy {
            None => true,
            Some(m) => m[b],
        }
    }

    /// Number of ciphertext blocks covering `features` feature lanes.
    pub fn blocks(&self, features: usize) -> usize {
        features.div_ceil(self.feats_per_ct)
    }

    /// Feature lanes carried by block `block` of a `features`-wide tensor
    /// (the final block may be partial).
    pub fn feats_in_block(&self, features: usize, block: usize) -> usize {
        let start = block * self.feats_per_ct;
        self.feats_per_ct.min(features - start)
    }

    /// The common payload base of a packed MAC product:
    /// `(F−1)·stride`. Every block's output lands at `payload_base() + b`.
    pub fn payload_base(&self) -> usize {
        (self.feats_per_ct - 1) * self.stride
    }

    /// Batch-lane positions of a per-scalar ciphertext whose payload sits
    /// at coefficient `base + b` (forward) — e.g. a packed MAC output at
    /// [`Self::payload_base`], or a clean post-bootstrap value at base 0.
    pub fn lane_positions(&self, order: PackOrder, base: usize) -> Vec<usize> {
        order.positions(self.batch).into_iter().map(|p| base + p).collect()
    }

    /// Every payload position of a packed block carrying `feats` feature
    /// lanes, feature-major then sample: lane `k·batch + b` of the result
    /// is feature `k`, sample `b`. Forward blocks anchor feature `k` at
    /// `k·stride` with the batch ascending; reversed blocks (FC
    /// backward-error outputs) anchor it at `(F−1−k)·stride` with the
    /// batch reversed. Built from the switch layer's position-set
    /// primitives, so one extract/repack fan-out serves every sample.
    pub fn block_positions(&self, order: PackOrder, feats: usize) -> Vec<usize> {
        let anchors = match order {
            PackOrder::Forward => crate::switch::strided_positions(0, self.stride, feats),
            PackOrder::Reversed => self.weight_positions(feats),
        };
        crate::switch::interleaved_positions(&anchors, self.batch, order == PackOrder::Reversed)
    }

    /// Positions of the batch-summed gradients inside a packed
    /// `x_block × reversed δ` product: weight lane `k` at
    /// `k·stride + batch−1`.
    pub fn gradient_positions(&self, feats: usize) -> Vec<usize> {
        crate::switch::strided_positions(self.batch - 1, self.stride, feats)
    }

    /// Positions of the weight lanes of a packed weight block: weight `k`
    /// at `(F−1−k)·stride` (top-anchored so every block MACs to the common
    /// [`Self::payload_base`]).
    pub fn weight_positions(&self, feats: usize) -> Vec<usize> {
        (0..feats).map(|k| (self.feats_per_ct - 1 - k) * self.stride).collect()
    }

    /// Interleave per-feature sample columns (`cols[j][b]` = feature `j`,
    /// sample `b`) into per-block coefficient vectors, honouring the
    /// occupancy mask (vacant lanes stay zero). The inverse of
    /// [`Self::unpack_columns`].
    pub fn pack_columns(&self, cols: &[Vec<i64>], n: usize) -> Vec<Vec<i64>> {
        (0..self.blocks(cols.len()))
            .map(|block| {
                let mut coeffs = vec![0i64; n];
                for k in 0..self.feats_in_block(cols.len(), block) {
                    let col = &cols[block * self.feats_per_ct + k];
                    assert_eq!(col.len(), self.batch, "every feature column spans the batch");
                    for (b, &v) in col.iter().enumerate() {
                        if self.occupied(b) {
                            coeffs[k * self.stride + b] = v;
                        }
                    }
                }
                coeffs
            })
            .collect()
    }

    /// One-shot sparse-occupancy packing: [`Self::pack_columns`] under a
    /// caller-supplied mask, returning the masked layout alongside the
    /// block coefficient vectors. This is the coalesced-serving entry
    /// point — a partially filled cross-job batch packs its occupied slots
    /// without mutating the engine's shared layout, and the returned
    /// layout travels with the tensor so decode masks the same slots.
    pub fn pack_columns_masked(
        &self,
        cols: &[Vec<i64>],
        occupied: &[bool],
        n: usize,
    ) -> (PackedLayout, Vec<Vec<i64>>) {
        let layout = self.clone().with_occupancy(occupied.to_vec());
        let blocks = layout.pack_columns(cols, n);
        (layout, blocks)
    }

    /// Read `features` per-feature sample columns back out of per-block
    /// coefficient vectors (vacant lanes decode as zero).
    pub fn unpack_columns(&self, blocks: &[Vec<i64>], features: usize) -> Vec<Vec<i64>> {
        assert_eq!(blocks.len(), self.blocks(features), "block count must match the layout");
        (0..features)
            .map(|j| {
                let coeffs = &blocks[j / self.feats_per_ct];
                (0..self.batch)
                    .map(|b| {
                        if self.occupied(b) {
                            coeffs[(j % self.feats_per_ct) * self.stride + b]
                        } else {
                            0
                        }
                    })
                    .collect()
            })
            .collect()
    }
}

/// A backend-polymorphic tensor. Per-scalar tensors (`layout == None`) hold
/// one `Ct` per network scalar (row-major over `shape`) with the batch at
/// coefficients `lane_base + b`; packed tensors (`layout == Some`) hold one
/// `Ct` per [`PackedLayout`] block.
#[derive(Clone)]
pub struct EncTensor {
    pub cts: Vec<Ct>,
    pub shape: Vec<usize>,
    pub order: PackOrder,
    /// Fixed-point scale: stored value = real value · 2^shift.
    pub shift: u32,
    /// `Some` when the cts are packed `batch × feature` blocks.
    pub layout: Option<PackedLayout>,
    /// Coefficient offset of sample lane 0 in a per-scalar tensor (packed
    /// MAC outputs carry their payload at [`PackedLayout::payload_base`]
    /// instead of coefficient 0). Always 0 for packed-block tensors.
    pub lane_base: usize,
}

impl EncTensor {
    pub fn new(cts: Vec<Ct>, shape: Vec<usize>, order: PackOrder, shift: u32) -> Self {
        debug_assert_eq!(cts.len(), shape.iter().product::<usize>());
        EncTensor { cts, shape, order, shift, layout: None, lane_base: 0 }
    }

    /// A packed-block tensor: `cts[B]` carries feature lanes
    /// `B·F .. B·F+feats_in_block` of the flattened shape.
    pub fn packed(
        cts: Vec<Ct>,
        shape: Vec<usize>,
        order: PackOrder,
        shift: u32,
        layout: PackedLayout,
    ) -> Self {
        debug_assert_eq!(cts.len(), layout.blocks(shape.iter().product::<usize>()));
        EncTensor { cts, shape, order, shift, layout: Some(layout), lane_base: 0 }
    }

    /// Same tensor with its per-scalar payload anchored at `base + b`.
    pub fn with_lane_base(mut self, base: usize) -> Self {
        debug_assert!(self.layout.is_none(), "lane_base applies to per-scalar tensors");
        self.lane_base = base;
        self
    }

    /// Whether the cts are packed `batch × feature` blocks.
    pub fn is_packed(&self) -> bool {
        self.layout.is_some()
    }

    /// Number of *network scalars* (shape product) — equal to `cts.len()`
    /// on per-scalar tensors, but larger than the block count on packed
    /// tensors.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.cts.is_empty()
    }

    /// Index into a CHW-shaped tensor (per-scalar layout only).
    pub fn chw(&self, c: usize, h: usize, w: usize) -> &Ct {
        debug_assert!(self.layout.is_none(), "chw indexes per-scalar tensors");
        let (_ch, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        &self.cts[(c * hh + h) * ww + w]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_positions() {
        assert_eq!(PackOrder::Forward.positions(4), vec![0, 1, 2, 3]);
        assert_eq!(PackOrder::Reversed.positions(4), vec![3, 2, 1, 0]);
    }

    #[test]
    fn layout_geometry() {
        // n = 256, batch = 8: stride 16 (≥ 2·8−1), F = (16+1)/2 = 8.
        let l = PackedLayout::for_ring(8, 256).unwrap();
        assert_eq!((l.stride, l.feats_per_ct), (16, 8));
        assert!(l.stride * (2 * l.feats_per_ct - 1) <= 256);
        assert_eq!(l.payload_base(), 7 * 16);
        assert_eq!(l.blocks(20), 3);
        assert_eq!(l.feats_in_block(20, 2), 4);

        // batch = 2 on the test ring: stride 4, F = 32.
        let l = PackedLayout::for_ring(2, 256).unwrap();
        assert_eq!((l.stride, l.feats_per_ct), (4, 32));

        // a batch too wide for the ring is rejected up front
        assert!(PackedLayout::for_ring(200, 256).is_err());
        assert!(PackedLayout::for_ring(0, 256).is_err());
    }

    #[test]
    fn layout_positions() {
        let l = PackedLayout::for_ring(2, 16).unwrap(); // stride 4, F = 2
        assert_eq!(l.block_positions(PackOrder::Forward, 2), vec![0, 1, 4, 5]);
        // reversed: feature k anchored at (F−1−k)·stride, batch reversed
        assert_eq!(l.block_positions(PackOrder::Reversed, 2), vec![5, 4, 1, 0]);
        assert_eq!(l.gradient_positions(2), vec![1, 5]);
        assert_eq!(l.weight_positions(2), vec![4, 0]);
        assert_eq!(l.lane_positions(PackOrder::Forward, l.payload_base()), vec![4, 5]);
        assert_eq!(l.lane_positions(PackOrder::Reversed, 0), vec![1, 0]);
    }

    #[test]
    fn pack_unpack_columns_roundtrip() {
        let l = PackedLayout::for_ring(2, 16).unwrap(); // stride 4, F = 2
        let cols = vec![vec![1, 2], vec![3, 4], vec![5, 6]];
        let blocks = l.pack_columns(&cols, 16);
        assert_eq!(blocks.len(), 2);
        assert_eq!(&blocks[0][..6], &[1, 2, 0, 0, 3, 4]);
        assert_eq!(&blocks[1][..2], &[5, 6]);
        assert_eq!(l.unpack_columns(&blocks, 3), cols);

        // a sparse occupancy mask zeroes the vacant lane both ways
        let sparse = l.clone().with_occupancy(vec![true, false]);
        let blocks = sparse.pack_columns(&cols, 16);
        assert_eq!(&blocks[0][..6], &[1, 0, 0, 0, 3, 0]);
        assert_eq!(sparse.unpack_columns(&blocks, 3), vec![vec![1, 0], vec![3, 0], vec![5, 0]]);

        // the one-shot masked entry point matches with_occupancy + pack and
        // leaves the base layout untouched
        let (masked, blocks2) = l.pack_columns_masked(&cols, &[true, false], 16);
        assert_eq!(blocks2, blocks);
        assert_eq!(masked.occupancy, Some(vec![true, false]));
        assert_eq!(l.occupancy, None, "masked packing must not mutate the shared layout");
    }
}
