//! TFHE activations: forward ReLU (paper Algorithm 1), backward iReLU
//! (Algorithm 2) and the Figure-4 softmax lookup unit, plus the
//! FHESGD-baseline sigmoid TLU hookup.
//!
//! Inputs arrive as the 8 two's-complement bit values (MSB/sign first) the
//! BGV→TFHE switch delivers; outputs are recomposed values with every bit
//! emitted directly at its weighted torus position (`2^(24+i)`) by the
//! parameterized gate bootstraps, ready for the packing key switch back to
//! BGV. Everything here is backend-polymorphic over [`Bit`]: on the FHE
//! backend the gates are real bootstraps, on the clear backend they are the
//! exact noiseless phase mirrors, so the recomposed values agree bit for
//! bit.

use super::backend::Bit;
use super::engine::GlyphEngine;
use super::layer::{
    relu_error_ops, relu_error_packed_ops, relu_forward_ops, relu_forward_packed_ops,
    softmax_error_ops, softmax_forward_ops, Layer, LayerPlanEntry, LayerState,
};
use super::loss::quadratic_loss_delta;
use super::tensor::{EncTensor, PackOrder, PackedLayout};
use crate::coordinator::executor::GlyphPool;
use crate::coordinator::scheduler::LayerKind;
use crate::switch::extract::bit_position;
use crate::switch::SWITCH_BITS;
use crate::tfhe::TestPoly;

/// Sign bits retained by the forward pass for iReLU.
pub struct ReluState {
    /// sign bit (u[n−1]) per ciphertext per lane, gate encoding. Under the
    /// per-scalar layout that is [neuron][sample]; the packed flat pass
    /// keeps the same [neuron][sample] indexing so the backward block walk
    /// can look a lane's sign up by its global feature index.
    pub signs: Vec<Vec<Bit>>,
}

/// Forward ReLU on one value's bits (Algorithm 1): output bit i =
/// `AND(u[i], NOT u[n−1])`, MSB forced to 0; bits are emitted at their
/// weighted positions and summed into one recomposed value.
pub fn relu_bits(engine: &GlyphEngine, bits: &[Bit]) -> (Bit, Bit) {
    let sign = bits[0].clone();
    let not_sign = engine.gate_not(&sign);
    let mut acc: Option<Bit> = None;
    for i in 1..SWITCH_BITS as usize {
        let w = engine.gate_and_weighted(&bits[i], &not_sign, bit_position(i));
        match &mut acc {
            None => acc = Some(w),
            Some(a) => a.add_assign(&w),
        }
    }
    (acc.expect("SWITCH_BITS ≥ 2"), sign)
}

/// Backward iReLU on one error value's bits (Algorithm 2):
/// `δ_{l−1}[i] = AND(δ_l[i], NOT u[n−1])` for every bit including the sign.
pub fn irelu_bits(engine: &GlyphEngine, delta_bits: &[Bit], u_sign: &Bit) -> Bit {
    let not_sign = engine.gate_not(u_sign);
    let mut acc: Option<Bit> = None;
    for i in 0..SWITCH_BITS as usize {
        let w = engine.gate_and_weighted(&delta_bits[i], &not_sign, bit_position(i));
        match &mut acc {
            None => acc = Some(w),
            Some(a) => a.add_assign(&w),
        }
    }
    acc.unwrap()
}

/// Shared recomposition core of the batched ReLU/iReLU layers: for every
/// lane, AND bits `start_bit..8` against the lane's NOT(sign) at their
/// weighted positions — all lanes in one `gate_and_weighted_many` fan-out —
/// then sum each lane's weighted bits back into one value (same gates and
/// same per-lane sum order as the sequential [`relu_bits`]/[`irelu_bits`]).
fn weighted_and_lanes(
    engine: &GlyphEngine,
    lanes_bits: &[Vec<Bit>],
    not_signs: &[Bit],
    start_bit: usize,
) -> Vec<Bit> {
    let per_lane = SWITCH_BITS as usize - start_bit;
    let mut jobs = Vec::with_capacity(lanes_bits.len() * per_lane);
    for (lane, bits) in lanes_bits.iter().enumerate() {
        for i in start_bit..SWITCH_BITS as usize {
            jobs.push((&bits[i], &not_signs[lane], bit_position(i)));
        }
    }
    let weighted = engine.gate_and_weighted_many(&jobs);
    weighted
        .chunks(per_lane)
        .map(|lane_bits| {
            let mut acc = lane_bits[0].clone();
            for w in &lane_bits[1..] {
                acc.add_assign(w);
            }
            acc
        })
        .collect()
}

/// Batched Algorithm 1 over every lane of a ciphertext (lanes × 7 weighted
/// ANDs in one fan-out; bit 0 is the sign, forced out of the output).
fn relu_lanes(engine: &GlyphEngine, lanes_bits: &[Vec<Bit>]) -> (Vec<Bit>, Vec<Bit>) {
    let signs: Vec<Bit> = lanes_bits.iter().map(|bits| bits[0].clone()).collect();
    let not_signs: Vec<Bit> = signs.iter().map(|s| engine.gate_not(s)).collect();
    let recomposed = weighted_and_lanes(engine, lanes_bits, &not_signs, 1);
    (recomposed, signs)
}

/// Batched Algorithm 2 over every lane (lanes × 8 weighted ANDs, the sign
/// bit included); bit-exact against a per-lane [`irelu_bits`] loop. Takes
/// sign *references* so the caller can flatten its per-ciphertext state
/// without cloning.
fn irelu_lanes(engine: &GlyphEngine, lanes_bits: &[Vec<Bit>], lane_signs: &[&Bit]) -> Vec<Bit> {
    let not_signs: Vec<Bit> = lane_signs.iter().map(|s| engine.gate_not(s)).collect();
    weighted_and_lanes(engine, lanes_bits, &not_signs, 0)
}

/// Shared boundary plumbing of every TFHE unit: ONE batched down-switch of
/// all ciphertexts × lanes, the unit's gate stage over the flattened
/// lane-bit matrix, ONE batched up-switch packing each ciphertext's lanes
/// back at `out_positions`. The gate stage receives `[ct-major lane][bit]`
/// and must return one recomposed value per lane in the same order.
fn cross_boundary<F>(
    engine: &GlyphEngine,
    cts: &[super::backend::Ct],
    in_positions: &[usize],
    out_positions: &[usize],
    pre_shift: u32,
    gates: F,
) -> Vec<super::backend::Ct>
where
    F: FnOnce(Vec<Vec<Bit>>) -> Vec<Bit>,
{
    let ct_refs: Vec<&super::backend::Ct> = cts.iter().collect();
    let all_bits = engine.switch_down_many(&ct_refs, in_positions, pre_shift);
    let flat_bits: Vec<Vec<Bit>> = all_bits.into_iter().flatten().collect();
    let recomposed = gates(flat_bits);
    let lanes_per_ct = in_positions.len();
    debug_assert_eq!(recomposed.len(), cts.len() * lanes_per_ct);
    let groups: Vec<(&[Bit], &[usize])> =
        recomposed.chunks(lanes_per_ct).map(|chunk| (chunk, out_positions)).collect();
    engine.switch_up_many(&groups)
}

/// Full ReLU layer: BGV pre-activations → TFHE bits → Alg-1 gates → packed
/// fresh BGV activations (8-bit, shift 0) in `out_order` packing.
///
/// `out_shift` is the per-layer quantization shift (how many low bits of
/// the MAC result the activation drops; must be ≤ the engine's frac bits).
///
/// The whole tensor crosses each boundary at once: ONE `switch_down_many`
/// extracts every ciphertext × lane × bit (this is where a conv layer's
/// forward exit — hundreds of CHW ciphertexts — fans out in a single call),
/// one pooled gate fan-out runs Algorithm 1 over all lanes, and ONE
/// `switch_up_many` packs every ciphertext back. Bit-identical to the
/// per-ciphertext serial walk (`engine.serial_switch` replays it) and to
/// the clear backend's integer mirror.
pub fn relu_layer(
    engine: &GlyphEngine,
    u: &EncTensor,
    out_shift: u32,
    out_order: PackOrder,
) -> (EncTensor, ReluState) {
    let frac = engine.frac_bits();
    assert!(out_shift <= frac, "out_shift {out_shift} exceeds frac {frac}");
    let pre_shift = frac - out_shift;
    // packed MAC producers anchor their payload at `lane_base + b`
    // (per-scalar producers keep lane_base 0, so this is the old path)
    let in_positions: Vec<usize> =
        u.order.positions(engine.batch).into_iter().map(|p| p + u.lane_base).collect();
    let out_positions = out_order.positions(engine.batch);
    // Algorithm 1 on every lane of the tensor in one pooled gate fan-out
    // (same per-lane jobs and sums as the per-ciphertext loop); the sign
    // bits ride out through the closure for the backward pass
    let mut flat_signs: Vec<Bit> = Vec::new();
    let outs = cross_boundary(engine, &u.cts, &in_positions, &out_positions, pre_shift, |flat| {
        let (recomposed, signs) = relu_lanes(engine, &flat);
        flat_signs = signs;
        recomposed
    });
    // regroup the flat signs per ciphertext by moving, not cloning
    let lanes_per_ct = in_positions.len();
    let mut it = flat_signs.into_iter();
    let signs: Vec<Vec<Bit>> =
        (0..u.cts.len()).map(|_| (&mut it).take(lanes_per_ct).collect()).collect();
    (EncTensor::new(outs, u.shape.clone(), out_order, 0), ReluState { signs })
}

/// Full iReLU layer: BGV errors → bits → Alg-2 gates → packed fresh BGV
/// errors (8-bit, reversed packing for the gradient trick). Batched like
/// [`relu_layer`]: one down-switch, one gate fan-out and one up-switch for
/// the whole tensor.
pub fn irelu_layer(
    engine: &GlyphEngine,
    delta: &EncTensor,
    state: &ReluState,
    out_shift: u32,
) -> EncTensor {
    let frac = engine.frac_bits();
    let pre_shift = frac - out_shift;
    let in_positions: Vec<usize> =
        delta.order.positions(engine.batch).into_iter().map(|p| p + delta.lane_base).collect();
    let out_positions = PackOrder::Reversed.positions(engine.batch);
    let flat_signs: Vec<&Bit> = state.signs.iter().flatten().collect();
    let outs =
        cross_boundary(engine, &delta.cts, &in_positions, &out_positions, pre_shift, |flat| {
            irelu_lanes(engine, &flat, &flat_signs)
        });
    EncTensor::new(outs, delta.shape.clone(), PackOrder::Reversed, 0)
}

/// Packed flat ReLU: consumes the packed FC layer's per-neuron MAC outputs
/// (batch at `lane_base + b`), runs the same Algorithm-1 gate pool as
/// [`relu_layer`], then repacks the bootstrapped lanes into cross-sample
/// SIMD blocks — ONE T2B group per [`PackedLayout`] block instead of one
/// per neuron, which is where the batch amortization of the up-switch
/// comes from. Counters mirror `relu_forward_packed_ops` exactly.
pub fn relu_layer_packed(
    engine: &GlyphEngine,
    u: &EncTensor,
    out_shift: u32,
    layout: &PackedLayout,
) -> (EncTensor, ReluState) {
    assert!(!u.is_packed(), "packed ReLU consumes per-neuron MAC outputs, not blocks");
    assert_eq!(u.order, PackOrder::Forward, "packed ReLU inputs pack forward");
    let features = u.len();
    let frac = engine.frac_bits();
    assert!(out_shift <= frac, "out_shift {out_shift} exceeds frac {frac}");
    let pre_shift = frac - out_shift;
    // one down-switch fans out every neuron × sample lane
    let in_positions = layout.lane_positions(PackOrder::Forward, u.lane_base);
    let ct_refs: Vec<&super::backend::Ct> = u.cts.iter().collect();
    let all_bits = engine.switch_down_many(&ct_refs, &in_positions, pre_shift);
    let flat_bits: Vec<Vec<Bit>> = all_bits.into_iter().flatten().collect();
    // Algorithm 1 over all lanes in one pooled fan-out; lane j·batch + b is
    // neuron j, sample b
    let (recomposed, flat_signs) = relu_lanes(engine, &flat_bits);
    debug_assert_eq!(recomposed.len(), features * layout.batch);
    // regroup the neuron-major lanes into per-block T2B groups: block B
    // carries neurons B·F .. B·F+feats, whose lanes are contiguous in
    // `recomposed`, at the block's forward payload grid
    let batch = layout.batch;
    let block_pos: Vec<Vec<usize>> = (0..layout.blocks(features))
        .map(|block| {
            layout.block_positions(PackOrder::Forward, layout.feats_in_block(features, block))
        })
        .collect();
    let mut groups: Vec<(&[Bit], &[usize])> = Vec::with_capacity(block_pos.len());
    let mut cursor = 0usize;
    for pos in &block_pos {
        groups.push((&recomposed[cursor..cursor + pos.len()], pos.as_slice()));
        cursor += pos.len();
    }
    debug_assert_eq!(cursor, recomposed.len());
    let outs = engine.switch_up_many(&groups);
    // signs regroup per neuron ([neuron][sample]) by moving, not cloning
    let mut it = flat_signs.into_iter();
    let signs: Vec<Vec<Bit>> =
        (0..features).map(|_| (&mut it).take(batch).collect()).collect();
    (
        EncTensor::packed(outs, u.shape.clone(), PackOrder::Forward, 0, layout.clone()),
        ReluState { signs },
    )
}

/// Packed flat iReLU: the FC error step delivers packed-*reversed* blocks,
/// so one B2T per block extracts every feature × sample lane at once (two
/// `switch_down_many` calls when the final block is partial — its payload
/// grid differs); the Algorithm-2 masked lanes then regroup per neuron in
/// reverse packing for the gradient convolution below. Counters mirror
/// `relu_error_packed_ops` exactly.
pub fn irelu_layer_packed(
    engine: &GlyphEngine,
    delta: &EncTensor,
    state: &ReluState,
    out_shift: u32,
    layout: &PackedLayout,
) -> EncTensor {
    assert_eq!(delta.order, PackOrder::Reversed, "packed iReLU inputs pack reversed");
    let features = delta.len();
    let blocks = layout.blocks(features);
    assert_eq!(delta.cts.len(), blocks, "block count must match the layout");
    let batch = layout.batch;
    let frac = engine.frac_bits();
    let pre_shift = frac - out_shift;
    // full blocks share one payload grid; a partial final block has its own
    let last_feats = layout.feats_in_block(features, blocks - 1);
    let full = if last_feats == layout.feats_per_ct { blocks } else { blocks - 1 };
    let mut all_bits: Vec<Vec<Vec<Bit>>> = Vec::with_capacity(blocks);
    if full > 0 {
        let pos = layout.block_positions(PackOrder::Reversed, layout.feats_per_ct);
        let refs: Vec<&super::backend::Ct> = delta.cts[..full].iter().collect();
        all_bits.extend(engine.switch_down_many(&refs, &pos, pre_shift));
    }
    if full < blocks {
        let pos = layout.block_positions(PackOrder::Reversed, last_feats);
        all_bits.extend(engine.switch_down_many(&[&delta.cts[blocks - 1]], &pos, pre_shift));
    }
    // block B's lane k·batch + b is feature B·F + k, sample b — the same
    // [neuron][sample] indexing the forward pass stored its signs under
    let flat_bits: Vec<Vec<Bit>> = all_bits.into_iter().flatten().collect();
    debug_assert_eq!(flat_bits.len(), features * batch);
    debug_assert_eq!(state.signs.len(), features);
    let sign_refs: Vec<&Bit> = state.signs.iter().flatten().collect();
    let recomposed = irelu_lanes(engine, &flat_bits, &sign_refs);
    // per-neuron reversed T2B groups: lane b of neuron j repacks at
    // coefficient batch−1−b for the gradient trick below
    let out_positions = PackOrder::Reversed.positions(batch);
    let groups: Vec<(&[Bit], &[usize])> =
        recomposed.chunks(batch).map(|chunk| (chunk, out_positions.as_slice())).collect();
    let outs = engine.switch_up_many(&groups);
    EncTensor::new(outs, delta.shape.clone(), PackOrder::Reversed, 0)
}

// ---------------------------------------------------------------------------
// Network units (the `Layer` trait face of the activations)
// ---------------------------------------------------------------------------

/// TFHE ReLU as a network unit: Algorithm 1 forward, Algorithm 2 backward,
/// with the per-layer quantization shifts carried in the unit itself.
pub struct ReluLayer {
    /// Bits the forward activation drops from the MAC scale.
    pub act_shift: u32,
    /// Bits the backward iReLU drops from the error scale.
    pub err_shift: u32,
}

impl Layer for ReluLayer {
    fn plan_entry(&self, in_shape: &[usize], batch: usize) -> LayerPlanEntry {
        let cts: usize = in_shape.iter().product();
        LayerPlanEntry {
            kind: LayerKind::Relu,
            out_shape: in_shape.to_vec(),
            forward: relu_forward_ops(cts, batch),
            error: Some(relu_error_ops(cts, batch)),
            gradient: None,
            out_packed: false,
        }
    }

    fn plan_entry_packed(
        &self,
        in_shape: &[usize],
        layout: &PackedLayout,
        in_packed: bool,
    ) -> LayerPlanEntry {
        assert!(!in_packed, "ReLU consumes per-neuron (or per-pixel) MAC outputs");
        if in_shape.len() == 1 {
            // flat head ReLU: per-neuron inputs, cross-sample SIMD blocks out
            let f = in_shape[0];
            LayerPlanEntry {
                kind: LayerKind::Relu,
                out_shape: in_shape.to_vec(),
                forward: relu_forward_packed_ops(f, layout),
                error: Some(relu_error_packed_ops(f, layout)),
                gradient: None,
                out_packed: true,
            }
        } else {
            // CHW feature-extractor ReLU: per-pixel tensors on both sides;
            // the op counts are position-independent, so the per-scalar
            // formulas hold verbatim
            self.plan_entry(in_shape, layout.batch)
        }
    }

    fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState) {
        if let Some(layout) = engine.packed_layout() {
            if x.shape.len() == 1 {
                let (a, st) = relu_layer_packed(engine, x, self.act_shift, layout);
                return (a, LayerState::Relu(st));
            }
        }
        let (a, st) = relu_layer(engine, x, self.act_shift, PackOrder::Forward);
        (a, LayerState::Relu(st))
    }

    fn backward_error(
        &self,
        delta: &EncTensor,
        state: &LayerState,
        engine: &GlyphEngine,
    ) -> EncTensor {
        let st = match state {
            LayerState::Relu(s) => s,
            _ => unreachable!("ReLU backward needs its forward sign state"),
        };
        if let Some(layout) = delta.layout.as_ref() {
            return irelu_layer_packed(engine, delta, st, self.err_shift, layout);
        }
        irelu_layer(engine, delta, st, self.err_shift)
    }
}

/// The Figure-4 softmax output unit: forward runs the MUX-tree lookup per
/// lane and repacks reverse-order for the loss; backward computes the
/// quadratic-loss derivative δ = d − t from the stored forward output
/// (paper Eq. 6 — one SubCC per class, kept on BGV).
pub struct SoftmaxLayer {
    pub unit: SoftmaxUnit,
    /// Quantization shift of the incoming logits (the producing FC layer's
    /// activation shift).
    pub logit_shift: u32,
}

impl Layer for SoftmaxLayer {
    fn plan_entry(&self, in_shape: &[usize], batch: usize) -> LayerPlanEntry {
        let cts: usize = in_shape.iter().product();
        LayerPlanEntry {
            kind: LayerKind::Softmax,
            out_shape: in_shape.to_vec(),
            forward: softmax_forward_ops(cts, batch, self.unit.plan_gates_per_lane()),
            error: Some(softmax_error_ops(cts)),
            gradient: None,
            out_packed: false,
        }
    }

    fn plan_entry_packed(
        &self,
        in_shape: &[usize],
        layout: &PackedLayout,
        in_packed: bool,
    ) -> LayerPlanEntry {
        // the packed FC head hands the softmax per-neuron logits (batch at
        // strided payload lanes), so the per-scalar counts hold verbatim
        assert!(!in_packed, "softmax consumes per-neuron logits");
        self.plan_entry(in_shape, layout.batch)
    }

    fn forward(&self, u: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState) {
        let frac = engine.frac_bits();
        let pre_shift = frac - self.logit_shift;
        // packed-layout FC logits anchor their payload at `lane_base + b`
        let in_positions: Vec<usize> =
            u.order.positions(engine.batch).into_iter().map(|p| p + u.lane_base).collect();
        let out_positions = PackOrder::Reversed.positions(engine.batch);
        // the whole logit tensor down-switches in one fan-out, every
        // class × lane MUX tree fans in one call, and one batched
        // up-switch packs all classes back
        let cts = cross_boundary(engine, &u.cts, &in_positions, &out_positions, pre_shift, |flat| {
            let lane_slices: Vec<&[Bit]> =
                flat.iter().map(|bits| &bits[..self.unit.in_bits]).collect();
            self.unit.evaluate_mux_many(engine, &lane_slices)
        });
        let d = EncTensor::new(cts, u.shape.to_vec(), PackOrder::Reversed, 0);
        (d.clone(), LayerState::Output(d))
    }

    fn backward_error(
        &self,
        labels_rev: &EncTensor,
        state: &LayerState,
        engine: &GlyphEngine,
    ) -> EncTensor {
        let d = match state {
            LayerState::Output(d) => d,
            _ => unreachable!("softmax backward needs its forward output"),
        };
        quadratic_loss_delta(d, labels_rev, engine)
    }

    fn is_output_unit(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// Softmax (Figure 4)
// ---------------------------------------------------------------------------

/// The Figure-4 softmax unit: a per-neuron b-bit lookup table evaluated
/// with homomorphic multiplexers over the input bits.
pub struct SoftmaxUnit {
    pub in_bits: usize,
    /// entries[v] = quantized output (8-bit, at the 2^24 grid) for input v
    /// (v is the two's-complement byte read MSB-first).
    pub entries: Vec<u8>,
}

impl SoftmaxUnit {
    /// Normalized-exponential (logistic) table: a monotone squashing of the
    /// logit into [0, 127], the per-neuron approximation the paper's
    /// Figure-4 unit tabulates. `in_frac` is the logit's fraction bits.
    pub fn logistic(in_bits: usize, in_frac: u32) -> Self {
        let n = 1usize << in_bits;
        let entries = (0..n)
            .map(|v| {
                let sv = if v >= n / 2 { v as i64 - n as i64 } else { v as i64 };
                let x = sv as f64 / 2f64.powi(in_frac as i32);
                let s = 1.0 / (1.0 + (-x).exp());
                (s * 127.0).round() as u8
            })
            .collect();
        SoftmaxUnit { in_bits, entries }
    }

    /// Paper-mode evaluation: bit-sliced MUX trees (two bootstraps per MUX
    /// on the critical path, Figure 4). Leaf-level muxes over constants are
    /// folded away, so each output bit costs a depth-(b−1) tree.
    /// Returns the recomposed value (output already at the 2^24 grid).
    ///
    /// The 8 output-bit trees are independent — on the FHE backend they fan
    /// across the global `GlyphPool`, and the surviving bits are weighted in
    /// one batched gate fan-out. Same values as the sequential loop.
    pub fn evaluate_mux(&self, engine: &GlyphEngine, bits: &[Bit]) -> Bit {
        self.evaluate_mux_many(engine, &[bits]).pop().expect("one lane, one output")
    }

    /// Batched Figure-4 unit: every lane's 8 output-bit MUX trees fan across
    /// the pool in ONE call (lanes × 8 independent trees), then a single
    /// batched weighting pass recomposes each lane. Order-preserving and
    /// bit-exact against a per-lane [`Self::evaluate_mux`] loop.
    pub fn evaluate_mux_many(&self, engine: &GlyphEngine, lanes_bits: &[&[Bit]]) -> Vec<Bit> {
        let lanes = lanes_bits.len();
        let mut tree_jobs = Vec::with_capacity(lanes * 8);
        for (lane, bits) in lanes_bits.iter().enumerate() {
            assert_eq!(bits.len(), self.in_bits);
            for j in 0..8u32 {
                tree_jobs.push((lane, j));
            }
        }
        // clear-mode trees are nanoseconds each — the pool fan-out would
        // cost more than the work, so they evaluate inline
        let nodes: Vec<Option<Bit>> = if engine.is_clear() {
            tree_jobs
                .into_iter()
                .map(|(lane, j)| self.mux_tree_bit(engine, lanes_bits[lane], j))
                .collect()
        } else {
            GlyphPool::global()
                .map(tree_jobs, |(lane, j)| self.mux_tree_bit(engine, lanes_bits[lane], j))
        };
        let truth = engine.trivial_bit(true);
        let mut weight_jobs: Vec<(&Bit, &Bit, u32)> = Vec::new();
        let mut lane_of: Vec<usize> = Vec::new();
        for (idx, node) in nodes.iter().enumerate() {
            if let Some(n) = node {
                weight_jobs.push((n, &truth, 24 + (idx % 8) as u32));
                lane_of.push(idx / 8);
            }
        }
        let weighted = engine.gate_and_weighted_many(&weight_jobs);
        let mut accs: Vec<Option<Bit>> = vec![None; lanes];
        for (w, &lane) in weighted.iter().zip(&lane_of) {
            match &mut accs[lane] {
                None => accs[lane] = Some(w.clone()),
                Some(a) => a.add_assign(w),
            }
        }
        accs.into_iter().map(|a| a.unwrap_or_else(|| engine.trivial_weighted_zero())).collect()
    }

    /// One output bit's MUX tree. Returns None if the bit is constant 0
    /// across all entries, Some(gate-encoded boolean) otherwise.
    fn mux_tree_bit(&self, engine: &GlyphEngine, bits: &[Bit], j: u32) -> Option<Bit> {
        #[derive(Clone)]
        enum Node {
            Const(bool),
            Ct(Bit),
        }
        // leaves, indexed by the value read MSB-first
        let mut level: Vec<Node> =
            self.entries.iter().map(|&e| Node::Const((e >> j) & 1 == 1)).collect();
        // fold from the LSB side: selection bit for the last level is the
        // last (LSB) input bit.
        for bit in bits.iter().rev() {
            let mut next = Vec::with_capacity(level.len() / 2);
            for pair in level.chunks(2) {
                let (d0, d1) = (&pair[0], &pair[1]);
                let node = match (d0, d1) {
                    (Node::Const(a), Node::Const(b)) if a == b => Node::Const(*a),
                    (Node::Const(false), Node::Const(true)) => Node::Ct(bit.clone()),
                    (Node::Const(true), Node::Const(false)) => Node::Ct(engine.gate_not(bit)),
                    (d0, d1) => {
                        let c0 = match d0 {
                            Node::Const(b) => engine.trivial_bit(*b),
                            Node::Ct(c) => c.clone(),
                        };
                        let c1 = match d1 {
                            Node::Const(b) => engine.trivial_bit(*b),
                            Node::Ct(c) => c.clone(),
                        };
                        Node::Ct(engine.gate_mux(bit, &c1, &c0))
                    }
                };
                next.push(node);
            }
            level = next;
        }
        debug_assert_eq!(level.len(), 1);
        match level.into_iter().next().unwrap() {
            Node::Const(false) => None,
            Node::Const(true) => Some(engine.trivial_bit(true)),
            Node::Ct(c) => Some(c),
        }
    }

    /// Exact bootstrapped-gate count of [`Self::evaluate_mux_many`] per
    /// lane, derived at compile time by folding the (plaintext) table
    /// constants symbolically: every surviving MUX costs 2 bootstraps, every
    /// surviving output bit one weighted-AND recomposition, NOTs are free.
    /// This is what `plan_entry` feeds the compiled `Plan`, so the
    /// plan/execution consistency test can assert live counters exactly —
    /// on both backends, which count gates identically.
    pub fn plan_gates_per_lane(&self) -> u64 {
        #[derive(Clone, Copy, PartialEq)]
        enum Node {
            Const(bool),
            Sym,
        }
        let mut gates = 0u64;
        for j in 0..8u32 {
            let mut level: Vec<Node> =
                self.entries.iter().map(|&e| Node::Const((e >> j) & 1 == 1)).collect();
            for _ in 0..self.in_bits {
                let mut next = Vec::with_capacity(level.len() / 2);
                for pair in level.chunks(2) {
                    let node = match (pair[0], pair[1]) {
                        (Node::Const(a), Node::Const(b)) if a == b => Node::Const(a),
                        // (0,1) is the selection bit itself, (1,0) its
                        // bootstrap-free NOT — no gates either way
                        (Node::Const(false), Node::Const(true))
                        | (Node::Const(true), Node::Const(false)) => Node::Sym,
                        _ => {
                            gates += 2; // gate_mux: 2 bootstraps
                            Node::Sym
                        }
                    };
                    next.push(node);
                }
                level = next;
            }
            if level[0] != Node::Const(false) {
                gates += 1; // weighted-AND recomposition of the live bit
            }
        }
        gates
    }

    /// Fast mode: one programmable bootstrap per neuron (an ablation over
    /// the paper's MUX tree). The logit must fit in `in_bits−1` bits; an
    /// offset moves the full signed range into the positive half-torus.
    pub fn evaluate_pbs(&self, engine: &GlyphEngine, value_lwe: &Bit) -> Bit {
        self.evaluate_pbs_many(engine, std::slice::from_ref(value_lwe))
            .pop()
            .expect("one input, one output")
    }

    /// Batched fast mode: the lookup test polynomial is programmed once and
    /// every lane's PBS fans across the pool (FHE) or evaluates through the
    /// noiseless blind-rotate model (clear).
    pub fn evaluate_pbs_many(&self, engine: &GlyphEngine, value_lwes: &[Bit]) -> Vec<Bit> {
        let nb = self.in_bits as u32;
        debug_assert!(nb >= 1);
        let big_n = engine.ext_big_n();
        // phase = v·2^(32−nb); add 2^31 so v ∈ [−2^(nb−1), 2^(nb−1)) maps to
        // [0, 2^32) positive-half windows of the doubled table.
        // window w of N covers v = w·2^nb/N − 2^(nb−1)… program entries.
        let entries = &self.entries;
        let n_entries = entries.len();
        let tv = TestPoly::from_fn(big_n, |w| {
            let v = (w * n_entries) / big_n; // 0..2^nb over positive half = full signed range shifted
            let signed_index = (v + n_entries / 2) % n_entries; // undo the +2^31 offset
            (entries[signed_index] as u32) << crate::switch::VALUE_POS
        });
        engine.counter.bump(&engine.counter.act_gates, value_lwes.len() as u64);
        if engine.is_clear() {
            let cb = engine.clear();
            value_lwes
                .iter()
                .map(|lwe| Bit::Clear(cb.pbs_model(lwe.phase().wrapping_add(1u32 << 31), &tv)))
                .collect()
        } else {
            let shifted: Vec<crate::tfhe::LweCiphertext> = value_lwes
                .iter()
                .map(|lwe| {
                    let mut s = lwe.fhe().clone();
                    s.add_constant(1u32 << 31);
                    s
                })
                .collect();
            engine.fhe().extract_ck.pbs_raw_many(shifted, &tv).into_iter().map(Bit::Fhe).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::{EngineProfile, GlyphEngine};
    use crate::nn::tensor::{EncTensor, PackOrder};

    fn engine() -> (GlyphEngine, crate::nn::engine::ClientKeys) {
        GlyphEngine::setup(EngineProfile::Test, 4, 321)
    }

    #[test]
    fn relu_layer_matches_plain() {
        let (eng, mut client) = engine();
        let vals: Vec<i64> = vec![37, -25, 0, 101];
        // store at shift 3 (simulating a small MAC scale), drop 3 bits
        let ct = client.encrypt_batch(&vals, 3);
        let u = EncTensor::new(vec![ct], vec![1], PackOrder::Forward, 3);
        let (a, _state) = relu_layer(&eng, &u, 3, PackOrder::Forward);
        let got = client.decrypt_batch(&a.cts[0], 4, 0);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn clear_relu_layer_matches_plain() {
        use crate::nn::backend::Codec;
        let (eng, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 4);
        let vals: Vec<i64> = vec![37, -25, 0, 101];
        let ct = codec.encrypt_batch(&vals, 3);
        let u = EncTensor::new(vec![ct], vec![1], PackOrder::Forward, 3);
        let (a, state) = relu_layer(&eng, &u, 3, PackOrder::Forward);
        let got = codec.decrypt_batch(&a.cts[0], 4, 0);
        let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
        assert_eq!(got, want);
        // and the backward mask mirrors Algorithm 2
        let mut d_rev = vec![9i64, -9, 9, -9];
        d_rev.reverse();
        let delta =
            EncTensor::new(vec![codec.encrypt_batch(&d_rev, 0)], vec![1], PackOrder::Reversed, 0);
        let out = irelu_layer(&eng, &delta, &state, 0);
        let got: Vec<i64> = codec.decrypt_batch(&out.cts[0], 4, 0).into_iter().rev().collect();
        assert_eq!(got, vec![9, 0, 9, -9]);
    }

    #[test]
    fn relu_then_irelu_propagates_error_only_where_positive() {
        let (eng, mut client) = engine();
        let u_vals: Vec<i64> = vec![50, -50, 7, -7];
        let d_vals: Vec<i64> = vec![13, 13, -9, -9];
        let u_ct = client.encrypt_batch(&u_vals, 0);
        let u = EncTensor::new(vec![u_ct], vec![1], PackOrder::Forward, 0);
        let (_a, state) = relu_layer(&eng, &u, 0, PackOrder::Forward);
        // backward errors arrive reverse-packed
        let mut d_rev = d_vals.clone();
        d_rev.reverse();
        let d_ct = client.encrypt_batch(&d_rev, 0);
        let delta = EncTensor::new(vec![d_ct], vec![1], PackOrder::Reversed, 0);
        let out = irelu_layer(&eng, &delta, &state, 0);
        // decrypt reverse-packed output
        let got_rev = client.decrypt_batch(&out.cts[0], 4, 0);
        let got: Vec<i64> = got_rev.into_iter().rev().collect();
        let want: Vec<i64> = u_vals.iter().zip(&d_vals).map(|(&u, &d)| if u >= 0 { d } else { 0 }).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn softmax_mux_tree_small_table() {
        let (eng, mut client) = engine();
        // 3-bit unit, exactly the paper's Figure-4 size.
        let unit = SoftmaxUnit { in_bits: 3, entries: vec![10, 20, 30, 40, 50, 60, 70, 80] };
        // Drive it directly with encrypted bit inputs for v = 5 (101b): the
        // byte with top bits 101 is 0xA0 = −96 as two's complement.
        let v = 5usize;
        let byte = (v as i64) << 5;
        let signed = if byte >= 128 { byte - 256 } else { byte };
        let ct = client.encrypt_batch(&[signed << eng.frac_bits()], 0);
        let bits_all = eng.switch_to_bits(&ct, &[0], 0);
        let bits3 = bits_all[0][..3].to_vec();
        let out = unit.evaluate_mux(&eng, &bits3);
        // decrypt the weighted value through the packing switch
        let packed = eng.switch_to_bgv(&[out], &[0]);
        let got = client.decrypt_batch(&packed, 1, 0);
        assert_eq!(got, vec![unit.entries[v] as i64]);
    }

    #[test]
    fn clear_softmax_mux_tree_matches_table() {
        use crate::nn::backend::Codec;
        let (eng, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 1);
        let unit = SoftmaxUnit { in_bits: 3, entries: vec![10, 20, 30, 40, 50, 60, 70, 80] };
        for v in 0..8usize {
            let byte = (v as i64) << 5;
            let signed = if byte >= 128 { byte - 256 } else { byte };
            let ct = codec.encrypt_batch(&[signed << eng.frac_bits()], 0);
            let bits_all = eng.switch_to_bits(&ct, &[0], 0);
            let out = unit.evaluate_mux(&eng, &bits_all[0][..3]);
            let packed = eng.switch_to_bgv(&[out], &[0]);
            assert_eq!(codec.decrypt_batch(&packed, 1, 0), vec![unit.entries[v] as i64], "v={v}");
        }
    }

    #[test]
    fn softmax_plan_gate_count_matches_live_counter() {
        let (eng, mut client) = engine();
        let unit = SoftmaxUnit { in_bits: 3, entries: vec![10, 20, 30, 40, 50, 60, 70, 80] };
        let v = 3usize;
        let byte = (v as i64) << 5;
        let signed = if byte >= 128 { byte - 256 } else { byte };
        let ct = client.encrypt_batch(&[signed << eng.frac_bits()], 0);
        let bits_all = eng.switch_to_bits(&ct, &[0], 0);
        let before = eng.counter.snapshot().act_gates;
        let _ = unit.evaluate_mux(&eng, &bits_all[0][..3]);
        let live = eng.counter.snapshot().act_gates - before;
        assert_eq!(live, unit.plan_gates_per_lane());
        // and the full logistic table used by real networks
        let logistic = SoftmaxUnit::logistic(3, 2);
        let before = eng.counter.snapshot().act_gates;
        let _ = logistic.evaluate_mux(&eng, &bits_all[0][..3]);
        let live = eng.counter.snapshot().act_gates - before;
        assert_eq!(live, logistic.plan_gates_per_lane());
    }

    #[test]
    fn clear_softmax_gate_count_matches_plan_too() {
        use crate::nn::backend::Codec;
        let (eng, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 1);
        let logistic = SoftmaxUnit::logistic(3, 2);
        let ct = codec.encrypt_batch(&[3 << eng.frac_bits()], 0);
        let bits_all = eng.switch_to_bits(&ct, &[0], 0);
        let before = eng.counter.snapshot().act_gates;
        let _ = logistic.evaluate_mux(&eng, &bits_all[0][..3]);
        let live = eng.counter.snapshot().act_gates - before;
        assert_eq!(live, logistic.plan_gates_per_lane());
    }

    #[test]
    fn relu_unit_layer_roundtrip() {
        use crate::nn::layer::Layer;
        let (eng, mut client) = engine();
        let vals: Vec<i64> = vec![21, -4, 0, 7];
        let ct = client.encrypt_batch(&vals, 0);
        let u = EncTensor::new(vec![ct], vec![1], PackOrder::Forward, 0);
        let unit = ReluLayer { act_shift: 0, err_shift: 0 };
        let entry = unit.plan_entry(&[1], 4);
        assert_eq!(entry.forward.switch_b2t, 1);
        assert_eq!(entry.forward.act_gates, 4 * 7);
        let (a, state) = Layer::forward(&unit, &u, &eng);
        assert_eq!(
            client.decrypt_batch(&a.cts[0], 4, 0),
            vals.iter().map(|&v| v.max(0)).collect::<Vec<_>>()
        );
        let mut d_rev = vec![5i64, 5, 5, 5];
        d_rev.reverse();
        let delta = EncTensor::new(vec![client.encrypt_batch(&d_rev, 0)], vec![1], PackOrder::Reversed, 0);
        let out = unit.backward_error(&delta, &state, &eng);
        let got: Vec<i64> = client.decrypt_batch(&out.cts[0], 4, 0).into_iter().rev().collect();
        assert_eq!(got, vec![5, 0, 5, 5]);
    }

    /// Compact packed layout for the activation tests: 2 samples, stride 4,
    /// 2 feature lanes per block (partial final block at 3 features).
    fn tiny_layout() -> super::PackedLayout {
        super::PackedLayout { batch: 2, stride: 4, feats_per_ct: 2, occupancy: None }
    }

    #[test]
    fn clear_packed_relu_roundtrip_with_partial_block() {
        use crate::nn::backend::Codec;
        use crate::nn::layer::{relu_error_packed_ops, relu_forward_packed_ops};
        let (eng, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
        let layout = tiny_layout();
        let u_vals: [[i64; 2]; 3] = [[37, -25], [-3, 7], [100, -1]];
        let cts = u_vals.iter().map(|v| codec.encrypt_batch(v, 0)).collect();
        let u = EncTensor::new(cts, vec![3], PackOrder::Forward, 0);

        let before = eng.counter.snapshot();
        let (a, state) = relu_layer_packed(&eng, &u, 0, &layout);
        let after = eng.counter.snapshot();
        let plan = relu_forward_packed_ops(3, &layout);
        assert_eq!(after.switch_b2t - before.switch_b2t, plan.switch_b2t);
        assert_eq!(after.switch_t2b - before.switch_t2b, plan.switch_t2b);
        assert_eq!(after.refresh - before.refresh, plan.refresh);
        assert_eq!(after.act_gates - before.act_gates, plan.act_gates);
        assert_eq!(after.extract_pbs - before.extract_pbs, plan.extract_pbs);
        assert_eq!(after.extract_lanes - before.extract_lanes, plan.extract_lanes);
        assert_eq!(after.repack_lanes - before.repack_lanes, plan.repack_lanes);

        // blocks carry relu(u) on the forward SIMD grid
        assert!(a.is_packed());
        assert_eq!(a.cts.len(), 2);
        assert_eq!(
            eng_decrypt(&codec, &a.cts[0], &layout.block_positions(PackOrder::Forward, 2)),
            vec![37, 0, 0, 7]
        );
        assert_eq!(
            eng_decrypt(&codec, &a.cts[1], &layout.block_positions(PackOrder::Forward, 1)),
            vec![100, 0]
        );

        // backward: packed-reversed blocks in, per-neuron reversed out
        let d_vals: [[i64; 2]; 3] = [[5, -6], [7, 8], [-9, 10]];
        let mut b0 = vec![0i64; 256];
        let mut b1 = vec![0i64; 256];
        for (j, d) in d_vals.iter().enumerate() {
            let (block, k) = (j / 2, j % 2);
            let anchor = (layout.feats_per_ct - 1 - k) * layout.stride;
            let coeffs = if block == 0 { &mut b0 } else { &mut b1 };
            for (b, &v) in d.iter().enumerate() {
                coeffs[anchor + (layout.batch - 1 - b)] = v;
            }
        }
        let delta = EncTensor::packed(
            vec![codec.encrypt_coeffs(&b0, 0), codec.encrypt_coeffs(&b1, 0)],
            vec![3],
            PackOrder::Reversed,
            0,
            layout.clone(),
        );
        let before = eng.counter.snapshot();
        let out = irelu_layer_packed(&eng, &delta, &state, 0, &layout);
        let after = eng.counter.snapshot();
        let plan = relu_error_packed_ops(3, &layout);
        assert_eq!(after.switch_b2t - before.switch_b2t, plan.switch_b2t);
        assert_eq!(after.switch_t2b - before.switch_t2b, plan.switch_t2b);
        assert_eq!(after.refresh - before.refresh, plan.refresh);
        assert_eq!(after.act_gates - before.act_gates, plan.act_gates);
        assert_eq!(after.extract_pbs - before.extract_pbs, plan.extract_pbs);
        assert_eq!(after.extract_lanes - before.extract_lanes, plan.extract_lanes);
        assert_eq!(after.repack_lanes - before.repack_lanes, plan.repack_lanes);

        assert!(!out.is_packed());
        let want: [[i64; 2]; 3] = [[5, 0], [0, 8], [-9, 0]];
        for j in 0..3 {
            let got: Vec<i64> =
                codec.decrypt_batch(&out.cts[j], 2, 0).into_iter().rev().collect();
            assert_eq!(got, want[j], "neuron {j}");
        }
    }

    #[test]
    fn fhe_packed_relu_matches_the_clear_mirror() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 777);
        let layout = tiny_layout();
        let u_vals: [[i64; 2]; 3] = [[37, -25], [-3, 7], [100, -1]];
        let cts = u_vals.iter().map(|v| client.encrypt_batch(v, 0)).collect();
        let u = EncTensor::new(cts, vec![3], PackOrder::Forward, 0);
        let (a, state) = relu_layer_packed(&eng, &u, 0, &layout);
        assert_eq!(
            client.decrypt_positions(&a.cts[0], &layout.block_positions(PackOrder::Forward, 2), 0),
            vec![37, 0, 0, 7]
        );
        assert_eq!(
            client.decrypt_positions(&a.cts[1], &layout.block_positions(PackOrder::Forward, 1), 0),
            vec![100, 0]
        );
        // one reversed block through the backward mask
        let mut b0 = vec![0i64; 256];
        b0[4] = -6; // neuron 0, sample 1
        b0[5] = 5; // neuron 0, sample 0
        b0[0] = 8; // neuron 1, sample 1
        b0[1] = 7; // neuron 1, sample 0
        let mut b1 = vec![0i64; 256];
        b1[4] = 10;
        b1[5] = -9;
        let delta = EncTensor::packed(
            vec![client.encrypt_coeffs(&b0, 0), client.encrypt_coeffs(&b1, 0)],
            vec![3],
            PackOrder::Reversed,
            0,
            layout.clone(),
        );
        let out = irelu_layer_packed(&eng, &delta, &state, 0, &layout);
        let want: [[i64; 2]; 3] = [[5, 0], [0, 8], [-9, 0]];
        for j in 0..3 {
            let got: Vec<i64> =
                client.decrypt_batch(&out.cts[j], 2, 0).into_iter().rev().collect();
            assert_eq!(got, want[j], "neuron {j}");
        }
    }

    #[test]
    fn relu_plan_entry_packed_splits_flat_and_chw() {
        let unit = ReluLayer { act_shift: 0, err_shift: 0 };
        let layout = tiny_layout();
        // flat head: SIMD blocks out, amortized up-switch
        let flat = unit.plan_entry_packed(&[3], &layout, false);
        assert!(flat.out_packed);
        assert_eq!(flat.forward.switch_b2t, 3);
        assert_eq!(flat.forward.switch_t2b, 2);
        assert_eq!(flat.error.as_ref().unwrap().switch_b2t, 2);
        assert_eq!(flat.error.as_ref().unwrap().switch_t2b, 3);
        // CHW extractor: per-pixel both sides, per-scalar counts verbatim
        let chw = unit.plan_entry_packed(&[2, 2, 2], &layout, false);
        assert!(!chw.out_packed);
        let per_scalar = unit.plan_entry(&[2, 2, 2], layout.batch);
        assert_eq!(chw.forward.switch_b2t, per_scalar.forward.switch_b2t);
        assert_eq!(chw.forward.act_gates, per_scalar.forward.act_gates);
    }

    fn eng_decrypt(
        codec: &dyn crate::nn::backend::Codec,
        ct: &crate::nn::backend::Ct,
        positions: &[usize],
    ) -> Vec<i64> {
        codec.decrypt_positions(ct, positions, 0)
    }

    #[test]
    fn logistic_table_monotone_and_bounded() {
        let u = SoftmaxUnit::logistic(8, 4);
        assert_eq!(u.entries.len(), 256);
        assert_eq!(u.entries[0], 64); // sigmoid(0) ≈ 0.5 → 64
        // monotone over the signed range −128..127
        let signed: Vec<u8> = (0..256).map(|v| u.entries[(v + 128) % 256]).collect();
        for w in signed.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
