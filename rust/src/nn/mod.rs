//! Encrypted neural-network layers (paper §4).
//!
//! * [`engine`] — `GlyphEngine`: the counted-op execution engine; every
//!   layer op goes through it so Tables 2–8 accounting is exact. Since
//!   PR 5 it fronts a pluggable backend: the FHE key material, or —
//! * [`backend`] — the bit-exact clear mirror (`ClearBackend`): plain
//!   integer lanes with `decrypt(FHE(op))` semantics, key-less setup,
//!   epoch-scale training in seconds, identical op accounting.
//! * [`tensor`] — `EncTensor`: one BGV ciphertext per network scalar, the
//!   mini-batch packed in coefficients (forward order) or reverse order
//!   (backward tensors, enabling the convolution-trick batch reduction).
//! * [`linear`] — FC layers with encrypted (MultCC) or plaintext-frozen
//!   (MultCP, transfer learning) weights; backward + gradients.
//! * [`conv`] — convolution (transfer learning: plaintext kernels).
//! * [`pool`] — average pooling (AddCC + shift folding).
//! * [`batchnorm`] — frozen affine BN (MultCP/AddCP).
//! * [`activation`] — TFHE ReLU (Alg 1), iReLU (Alg 2), the Figure-4
//!   softmax MUX-tree unit, and the FHESGD sigmoid-TLU baseline.
//! * [`loss`] — the quadratic loss derivative (Eq. 6).
//! * [`quantize`] — plain-side SWALP-style 8-bit quantization helpers used
//!   by data preparation and the reference pipelines.
//! * [`layer`] — the [`layer::Layer`] trait every unit implements
//!   (`plan_entry`/`forward`/`backward_error`/`gradients`).
//! * [`network`] — [`network::NetworkBuilder`] → [`network::Network`]: the
//!   fluent, validated model-construction API whose compiled
//!   `scheduler::Plan` drives execution, the cost model and the CLI.

pub mod activation;
pub mod backend;
pub mod batchnorm;
pub mod conv;
pub mod engine;
pub mod layer;
pub mod linear;
pub mod loss;
pub mod network;
pub mod pool;
pub mod quantize;
pub mod tensor;

pub use backend::{Bit, ClearBackend, ClearCodec, ClearCt, Codec, Ct, PlainVector, PlainWeight, Term};
pub use engine::{Backend, ClientKeys, EngineProfile, FheState, GlyphEngine};
pub use layer::{Layer, LayerGrads, LayerPlanEntry, LayerState};
pub use network::{ForwardPass, LayerSpec, Network, NetworkBuilder, NetworkError};
pub use tensor::{EncTensor, PackOrder, PackedLayout};
