//! Encrypted neural-network layers (paper §4).
//!
//! * [`engine`] — `GlyphEngine`: all evaluator key material + HOP counters;
//!   every layer op goes through it so Tables 2–8 accounting is exact.
//! * [`tensor`] — `EncTensor`: one BGV ciphertext per network scalar, the
//!   mini-batch packed in coefficients (forward order) or reverse order
//!   (backward tensors, enabling the convolution-trick batch reduction).
//! * [`linear`] — FC layers with encrypted (MultCC) or plaintext-frozen
//!   (MultCP, transfer learning) weights; backward + gradients.
//! * [`conv`] — convolution (transfer learning: plaintext kernels).
//! * [`pool`] — average pooling (AddCC + shift folding).
//! * [`batchnorm`] — frozen affine BN (MultCP/AddCP).
//! * [`activation`] — TFHE ReLU (Alg 1), iReLU (Alg 2), the Figure-4
//!   softmax MUX-tree unit, and the FHESGD sigmoid-TLU baseline.
//! * [`loss`] — the quadratic loss derivative (Eq. 6).
//! * [`quantize`] — plain-side SWALP-style 8-bit quantization helpers used
//!   by data preparation and the reference pipelines.

pub mod activation;
pub mod batchnorm;
pub mod conv;
pub mod engine;
pub mod linear;
pub mod loss;
pub mod pool;
pub mod quantize;
pub mod tensor;

pub use engine::{ClientKeys, GlyphEngine};
pub use tensor::{EncTensor, PackOrder};
