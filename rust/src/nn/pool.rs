//! Average pooling (paper §4.1 "Pooling"): BGV additions only; the ÷4 is
//! folded into the fixed-point shift (power-of-two scales make it free),
//! exactly why Glyph prefers average over max pooling — no switch needed.

use super::backend::Ct;
use super::engine::GlyphEngine;
use super::layer::{pool_forward_ops, Layer, LayerPlanEntry, LayerState};
use super::tensor::EncTensor;
use crate::coordinator::scheduler::LayerKind;

/// 2×2 average pooling with stride 2 on a CHW tensor. The output carries
/// `shift + 2` (the sum of four values at scale 2^shift is the average at
/// scale 2^(shift+2)).
pub fn avg_pool2(x: &EncTensor, engine: &GlyphEngine) -> EncTensor {
    assert_eq!(x.shape.len(), 3);
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let (oh, ow) = (h / 2, w / 2);
    let mut cts: Vec<Ct> = Vec::with_capacity(c * oh * ow);
    for ch in 0..c {
        for y in 0..oh {
            for xx in 0..ow {
                let mut acc = x.chw(ch, 2 * y, 2 * xx).clone();
                engine.add_cc(&mut acc, x.chw(ch, 2 * y, 2 * xx + 1));
                engine.add_cc(&mut acc, x.chw(ch, 2 * y + 1, 2 * xx));
                engine.add_cc(&mut acc, x.chw(ch, 2 * y + 1, 2 * xx + 1));
                cts.push(acc);
            }
        }
    }
    EncTensor::new(cts, vec![c, oh, ow], x.order, x.shift + 2).with_lane_base(x.lane_base)
}

/// 2×2 stride-2 average pooling as a network unit (AddCC only — the ÷4
/// folds into the fixed-point shift, which is why Glyph prefers average
/// pooling: no switch needed).
pub struct AvgPoolLayer;

impl Layer for AvgPoolLayer {
    fn plan_entry(&self, in_shape: &[usize], _batch: usize) -> LayerPlanEntry {
        assert_eq!(in_shape.len(), 3, "pool expects CHW");
        let (c, h, w) = (in_shape[0], in_shape[1], in_shape[2]);
        let out_shape = vec![c, h / 2, w / 2];
        LayerPlanEntry {
            kind: LayerKind::AvgPool,
            forward: pool_forward_ops(out_shape.iter().product()),
            out_shape,
            error: None, // pooling backward folds into neighbours under TL
            gradient: None,
            out_packed: false,
        }
    }

    fn plan_entry_packed(
        &self,
        in_shape: &[usize],
        layout: &super::tensor::PackedLayout,
        in_packed: bool,
    ) -> LayerPlanEntry {
        // pooling consumes the clean per-pixel ReLU outputs under the
        // packed layout too — AddCC counts are position-independent
        assert!(!in_packed, "pooling consumes per-pixel activation outputs");
        self.plan_entry(in_shape, layout.batch)
    }

    fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState) {
        (avg_pool2(x, engine), LayerState::None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::{EngineProfile, GlyphEngine};
    use crate::nn::tensor::PackOrder;

    #[test]
    fn pools_sums_and_bumps_shift() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 900);
        // 1×4×4 tensor with values = linear index, two batch lanes
        let cts: Vec<_> = (0..16)
            .map(|i| client.encrypt_batch(&[i as i64, 2 * i as i64], 0))
            .collect();
        let x = EncTensor::new(cts, vec![1, 4, 4], PackOrder::Forward, 3);
        let out = avg_pool2(&x, &eng);
        assert_eq!(out.shape, vec![1, 2, 2]);
        assert_eq!(out.shift, 5);
        // window (0,0): 0+1+4+5 = 10
        assert_eq!(client.decrypt_batch(out.chw(0, 0, 0), 2, 0), vec![10, 20]);
        // window (1,1): 10+11+14+15 = 50
        assert_eq!(client.decrypt_batch(out.chw(0, 1, 1), 2, 0), vec![50, 100]);
        assert_eq!(eng.counter.snapshot().add_cc, 12);
    }
}
