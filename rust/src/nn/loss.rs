//! The quadratic loss derivative (paper Eq. 6):
//! `isoftmax(d, t) = δ = d − t`, computed in BGV (one SubCC per output
//! neuron) — the paper keeps this on the BGV side to avoid a switch.

use super::engine::GlyphEngine;
use super::tensor::{EncTensor, PackOrder};

/// δ = d − labels. Both operands must be reverse-packed (the backward pass
/// starts here); labels are the client-encrypted one-hot rows.
pub fn quadratic_loss_delta(d: &EncTensor, labels: &EncTensor, engine: &GlyphEngine) -> EncTensor {
    assert_eq!(d.len(), labels.len());
    assert_eq!(d.order, PackOrder::Reversed);
    assert_eq!(labels.order, PackOrder::Reversed);
    assert_eq!(d.shift, labels.shift, "operand scales must match");
    let cts = d
        .cts
        .iter()
        .zip(&labels.cts)
        .map(|(dc, lc)| {
            let mut delta = dc.clone();
            engine.sub_cc(&mut delta, lc);
            delta
        })
        .collect();
    EncTensor::new(cts, d.shape.clone(), PackOrder::Reversed, d.shift)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::{EngineProfile, GlyphEngine};

    #[test]
    fn delta_is_d_minus_t() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 920);
        let d_cts = vec![client.encrypt_batch(&[90, 10], 0), client.encrypt_batch(&[10, 80], 0)];
        let t_cts = vec![client.encrypt_batch(&[127, 0], 0), client.encrypt_batch(&[0, 127], 0)];
        let d = EncTensor::new(d_cts, vec![2], PackOrder::Reversed, 0);
        let t = EncTensor::new(t_cts, vec![2], PackOrder::Reversed, 0);
        let delta = quadratic_loss_delta(&d, &t, &eng);
        assert_eq!(client.decrypt_batch(&delta.cts[0], 2, 0), vec![-37, 10]);
        assert_eq!(client.decrypt_batch(&delta.cts[1], 2, 0), vec![10, -47]);
        assert_eq!(eng.counter.snapshot().add_cc, 2);
    }
}
