//! Fully-connected layers over encrypted (or clear-mirrored) tensors.
//!
//! Weights are either constant-polynomial ciphertexts (MultCC MACs — the
//! FHESGD/Glyph trainable layers) or plaintext scalars (MultCP — the
//! transfer-learning frozen layers), on whichever backend the engine runs.
//! The backward pass consumes reverse-packed error tensors; gradients fall
//! out of the negacyclic convolution trick at coefficient `batch−1`
//! (DESIGN.md §2.1) and are re-quantized through the cryptosystem switch
//! before the SGD update — exactly the `FC-gradient … BGV-TFHE` rows of the
//! paper's Table 3. The clear backend mirrors every one of those steps
//! (including the gradient's `∇ >> grad_shift` rounding) bit for bit.

use super::backend::{Bit, Codec, Ct, PlainWeight, Term};
use super::engine::GlyphEngine;
use super::layer::{
    fc_error_ops, fc_error_packed_ops, fc_forward_ops, fc_forward_packed_ops, fc_gradient_ops,
    fc_gradient_packed_ops, Layer, LayerGrads, LayerPlanEntry, LayerState,
};
use super::tensor::{EncTensor, PackOrder, PackedLayout};
use crate::coordinator::scheduler::LayerKind;
use crate::switch::extract::bit_position;
use std::collections::HashMap;

/// A layer weight: trainable ciphertext or frozen plaintext. Frozen FHE
/// weights carry their per-level NTT-domain lifts
/// ([`crate::bgv::CachedPlaintext`], built once at construction and shared
/// across equal weight values), so every MultCP against them is a pure
/// pointwise pass; frozen clear weights are bare scalars.
pub enum Weight {
    Enc(Ct),
    Plain(PlainWeight),
}

impl Weight {
    /// The MAC-row term multiplying this weight with `x`.
    pub fn term<'a>(&'a self, x: &'a Ct) -> Term<'a> {
        match self {
            Weight::Enc(wct) => Term::Cc(wct, x),
            Weight::Plain(wpt) => Term::Cp(x, wpt),
        }
    }
}

/// One frozen weight per *distinct* value, shared within a layer: frozen
/// weights are 8-bit integers, so the cache is bounded at ≤256 entries per
/// layer instead of one per weight (on the FHE backend a paper-scale frozen
/// layer would otherwise pay ~100KB + a full NTT set per weight; the clear
/// backend shares the scalars for symmetry).
pub(crate) fn shared_plain(
    cache: &mut HashMap<i64, PlainWeight>,
    v: i64,
    engine: &GlyphEngine,
) -> PlainWeight {
    cache.entry(v).or_insert_with(|| engine.scalar_weight(v)).clone()
}

/// A fully-connected layer `u = W·x (+ b)`.
pub struct FcLayer {
    /// w[out][in]
    pub w: Vec<Vec<Weight>>,
    pub bias: Option<Vec<Weight>>,
    pub in_dim: usize,
    pub out_dim: usize,
    /// Quantization shift applied by the following activation.
    pub out_shift: u32,
}

impl FcLayer {
    /// Trainable layer from plain 8-bit initial weights, encoded under the
    /// backend's codec (encrypted on FHE, mirrored on clear).
    pub fn new_encrypted(init: &[Vec<i64>], client: &mut dyn Codec, out_shift: u32) -> Self {
        let out_dim = init.len();
        let in_dim = init[0].len();
        let w = init
            .iter()
            .map(|row| row.iter().map(|&v| Weight::Enc(client.encrypt_scalar(v))).collect())
            .collect();
        FcLayer { w, bias: None, in_dim, out_dim, out_shift }
    }

    /// Frozen plaintext layer (transfer learning); caches one weight per
    /// distinct value, shared across the matrix.
    pub fn new_plain(init: &[Vec<i64>], engine: &GlyphEngine, out_shift: u32) -> Self {
        let out_dim = init.len();
        let in_dim = init[0].len();
        let mut cache = HashMap::new();
        let w = init
            .iter()
            .map(|row| {
                row.iter().map(|&v| Weight::Plain(shared_plain(&mut cache, v, engine))).collect()
            })
            .collect();
        FcLayer { w, bias: None, in_dim, out_dim, out_shift }
    }

    /// Forward MACs: `u[j] = Σ_i w[j][i] ⊗ x[i]`, one lazy-relin MAC row
    /// per output neuron fanned across the pool (`mac_rows_many`). Output
    /// keeps `x`'s packing order and accumulates scale `x.shift` (weights
    /// are 8-bit integers at scale 0).
    pub fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        assert_eq!(x.len(), self.in_dim);
        let rows: Vec<Vec<Term>> = (0..self.out_dim)
            .map(|j| (0..self.in_dim).map(|i| self.w[j][i].term(&x.cts[i])).collect())
            .collect();
        let mut cts = engine.mac_rows_many(&rows);
        if let Some(bias) = &self.bias {
            for (j, u) in cts.iter_mut().enumerate() {
                match &bias[j] {
                    Weight::Enc(bct) => engine.add_cc(u, bct),
                    Weight::Plain(bpt) => engine.add_plain_w(u, bpt),
                }
            }
        }
        EncTensor::new(cts, vec![self.out_dim], x.order, x.shift)
    }

    /// Backward error propagation: `δ_{l−1}[i] = Σ_j w[j][i] ⊗ δ_l[j]`
    /// (before the iReLU mask), one MAC row per input neuron. Keeps the
    /// reversed packing.
    pub fn backward_error(&self, delta: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        assert_eq!(delta.len(), self.out_dim);
        assert_eq!(delta.order, PackOrder::Reversed);
        let rows: Vec<Vec<Term>> = (0..self.in_dim)
            .map(|i| (0..self.out_dim).map(|j| self.w[j][i].term(&delta.cts[j])).collect())
            .collect();
        let cts = engine.mac_rows_many(&rows);
        EncTensor::new(cts, vec![self.in_dim], PackOrder::Reversed, delta.shift)
    }

    /// Gradient MACs: `∇w[j][i] = Σ_b x[b][i]·δ[b][j]`, one MultCC each —
    /// forward-packed x × reverse-packed δ leaves the batch sum at
    /// coefficient `batch−1`. All `out·in` products fan across the pool as
    /// single-term rows.
    pub fn gradients(&self, x: &EncTensor, delta: &EncTensor, engine: &GlyphEngine) -> LayerGrads {
        assert_eq!(x.order, PackOrder::Forward);
        assert_eq!(delta.order, PackOrder::Reversed);
        let rows: Vec<Vec<Term>> = (0..self.out_dim)
            .flat_map(|j| (0..self.in_dim).map(move |i| vec![Term::Cc(&x.cts[i], &delta.cts[j])]))
            .collect();
        let mut flat = engine.mac_rows_many(&rows).into_iter();
        (0..self.out_dim)
            .map(|_| (0..self.in_dim).map(|_| flat.next().expect("out·in rows")).collect())
            .collect()
    }

    /// SGD update: re-quantize each gradient through the switch (extracting
    /// the batch-sum coefficient with an effective learning-rate shift) and
    /// subtract from the encrypted weights. `grad_shift` plays the role of
    /// `−log2(lr · scale⁻¹)`: the extracted 8-bit step is `∇ >> grad_shift`.
    ///
    /// The whole update crosses the switch in three batched fan-outs: ONE
    /// `switch_down_many` extracts every trainable weight's batch-sum bits,
    /// one `gate_and_weighted_many` recomposes all weights × 8 bits, and ONE
    /// `switch_up_many` packs/raises every weight's gradient step — same
    /// values and op counts as the per-weight serial loop, on both backends.
    pub fn apply_gradients(&mut self, grads: &[Vec<Ct>], grad_shift: u32, engine: &GlyphEngine) {
        let frac = engine.frac_bits();
        assert!(grad_shift <= frac);
        let pre_shift = frac - grad_shift;
        let sum_pos = [engine.batch - 1];
        // 1. bits of every batch-summed gradient (position batch−1), one
        //    pooled down-switch over all trainable weights
        let mut targets: Vec<(usize, usize)> = Vec::new();
        let mut g_refs: Vec<&Ct> = Vec::new();
        for (j, row) in grads.iter().enumerate() {
            for (i, g) in row.iter().enumerate() {
                if matches!(self.w[j][i], Weight::Enc(_)) {
                    g_refs.push(g);
                    targets.push((j, i));
                }
            }
        }
        if targets.is_empty() {
            return;
        }
        let all_bits: Vec<Vec<Bit>> = engine
            .switch_down_many(&g_refs, &sum_pos, pre_shift)
            .into_iter()
            .map(|mut lanes| lanes.swap_remove(0))
            .collect();
        // 2. identity recomposition at the weighted positions — one pooled
        //    fan-out over all weights × bits
        let truth = engine.trivial_bit(true);
        let jobs: Vec<(&Bit, &Bit, u32)> = all_bits
            .iter()
            .flat_map(|bits| bits.iter().enumerate().map(|(bi, b)| (b, &truth, bit_position(bi))))
            .collect();
        let weighted = engine.gate_and_weighted_many(&jobs);
        // 3. per weight: sum its bit contributions into one recomposed LWE,
        //    then raise every step in one batched up-switch and subtract
        let bits_per = all_bits[0].len();
        let accs: Vec<Bit> = weighted
            .chunks(bits_per)
            .map(|chunk| {
                let mut acc = chunk[0].clone();
                for w in &chunk[1..] {
                    acc.add_assign(w);
                }
                acc
            })
            .collect();
        // fresh constant-poly gradient steps at coefficient 0
        let zero_pos = [0usize];
        let groups: Vec<(&[Bit], &[usize])> =
            accs.iter().map(|a| (std::slice::from_ref(a), &zero_pos[..])).collect();
        let steps = engine.switch_up_many(&groups);
        for (t, step) in steps.iter().enumerate() {
            let (j, i) = targets[t];
            if let Weight::Enc(wct) = &mut self.w[j][i] {
                engine.sub_cc(wct, step);
            }
        }
    }
}

impl FcLayer {
    /// Whether the layer trains (ciphertext weights) or is frozen plaintext.
    pub fn is_trainable(&self) -> bool {
        matches!(self.w.first().and_then(|row| row.first()), Some(Weight::Enc(_)))
    }
}

impl Layer for FcLayer {
    fn plan_entry(&self, in_shape: &[usize], _batch: usize) -> LayerPlanEntry {
        let in_dim: usize = in_shape.iter().product();
        assert_eq!(in_dim, self.in_dim, "FC input width mismatch");
        let enc = self.is_trainable();
        let enc_bias_terms = self
            .bias
            .as_ref()
            .map_or(0, |b| b.iter().filter(|w| matches!(w, Weight::Enc(_))).count());
        let forward = fc_forward_ops(self.in_dim, self.out_dim, enc, enc_bias_terms);
        LayerPlanEntry {
            kind: LayerKind::Fc { trainable: enc },
            out_shape: vec![self.out_dim],
            forward,
            error: Some(fc_error_ops(self.in_dim, self.out_dim, enc)),
            gradient: if enc { Some(fc_gradient_ops(self.in_dim, self.out_dim)) } else { None },
            out_packed: false,
        }
    }

    fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState) {
        (FcLayer::forward(self, x, engine), LayerState::None)
    }

    fn backward_error(
        &self,
        delta: &EncTensor,
        _state: &LayerState,
        engine: &GlyphEngine,
    ) -> EncTensor {
        FcLayer::backward_error(self, delta, engine)
    }

    fn gradients(
        &self,
        below: &EncTensor,
        delta: &EncTensor,
        engine: &GlyphEngine,
    ) -> Option<LayerGrads> {
        Some(FcLayer::gradients(self, below, delta, engine))
    }

    fn apply_gradients(&mut self, grads: &LayerGrads, grad_shift: u32, engine: &GlyphEngine) {
        FcLayer::apply_gradients(self, grads, grad_shift, engine);
    }

    fn as_fc(&self) -> Option<&FcLayer> {
        Some(self)
    }

    fn as_fc_mut(&mut self) -> Option<&mut FcLayer> {
        Some(self)
    }
}

/// A fully-connected layer under the cross-sample SIMD minibatch layout:
/// the weight matrix is stored as one ciphertext per (output neuron, input
/// block), weight `k` of block `B` anchored at coefficient `(F−1−k)·stride`
/// ([`PackedLayout::weight_positions`] — top-anchored even in a partial
/// final block, so every block's MAC payload lands at the common
/// [`PackedLayout::payload_base`]). One MAC row per output neuron then
/// serves the whole minibatch: `out·B(in)` MultCC instead of `out·in`.
///
/// Always trainable (the packed weight blocks are ciphertexts); frozen
/// layers keep the per-scalar `FcLayer` MultCP path.
pub struct PackedFcLayer {
    /// `w_blocks[out][block]`: packed weight-block ciphertexts.
    pub w_blocks: Vec<Vec<Ct>>,
    pub layout: PackedLayout,
    pub in_dim: usize,
    pub out_dim: usize,
    /// Quantization shift applied by the following activation.
    pub out_shift: u32,
    /// Whether the forward input arrives as packed blocks (`false` at the
    /// CNN flatten seam, where the layer re-packs per-scalar inputs with
    /// monomial shifts first).
    pub in_packed: bool,
}

impl PackedFcLayer {
    /// Trainable packed layer from plain 8-bit initial weights: row `o` of
    /// `init` is interleaved into `B(in)` weight-block ciphertexts under
    /// the backend's codec. `n` is the ring degree the blocks encode into.
    pub fn new_encrypted(
        init: &[Vec<i64>],
        client: &mut dyn Codec,
        out_shift: u32,
        layout: &PackedLayout,
        in_packed: bool,
        n: usize,
    ) -> Self {
        let out_dim = init.len();
        let in_dim = init[0].len();
        let f = layout.feats_per_ct;
        let w_blocks = init
            .iter()
            .map(|row| {
                (0..layout.blocks(in_dim))
                    .map(|block| {
                        let mut coeffs = vec![0i64; n];
                        for k in 0..layout.feats_in_block(in_dim, block) {
                            coeffs[(f - 1 - k) * layout.stride] = row[block * f + k];
                        }
                        client.encrypt_coeffs(&coeffs, 0)
                    })
                    .collect()
            })
            .collect();
        PackedFcLayer {
            w_blocks,
            layout: layout.clone(),
            in_dim,
            out_dim,
            out_shift,
            in_packed,
        }
    }

    /// The forward input as packed blocks: pass-through for packed tensors,
    /// monomial-shift pack-on-entry (counted) for per-scalar inputs.
    fn input_blocks(&self, x: &EncTensor, engine: &GlyphEngine) -> Vec<Ct> {
        if x.is_packed() {
            assert_eq!(x.layout.as_ref(), Some(&self.layout), "input layout mismatch");
            x.cts.clone()
        } else {
            assert_eq!(x.lane_base, 0, "pack-on-entry needs clean base-0 inputs");
            let refs: Vec<&Ct> = x.cts.iter().collect();
            engine.pack_clean_blocks(&refs, &self.layout)
        }
    }

    /// Forward MACs: `u[j] = Σ_B W[j][B] ⊗ x[B]`, one MAC row per output
    /// neuron over the input *blocks*. The output is per-neuron with the
    /// whole batch at the payload lanes `payload_base() + b`.
    pub fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        assert_eq!(x.len(), self.in_dim);
        assert_eq!(x.order, PackOrder::Forward);
        let x_blocks = self.input_blocks(x, engine);
        let rows: Vec<Vec<Term>> = (0..self.out_dim)
            .map(|j| x_blocks.iter().enumerate().map(|(b, xb)| Term::Cc(&self.w_blocks[j][b], xb)).collect())
            .collect();
        let cts = engine.mac_rows_many(&rows);
        EncTensor::new(cts, vec![self.out_dim], x.order, x.shift)
            .with_lane_base(self.layout.payload_base())
    }

    /// Backward error: `δ_{l−1} = Wᵀ·δ_l` as one MAC row per *input block*
    /// over the per-neuron reversed deltas — the products land garbage-free
    /// on the packed-reversed grid (feature `k` at `(F−1−k)·stride`, sample
    /// `b` at `batch−1−b`), so the output is a packed-reversed block tensor.
    pub fn backward_error(&self, delta: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        assert_eq!(delta.len(), self.out_dim);
        assert_eq!(delta.order, PackOrder::Reversed);
        assert!(!delta.is_packed(), "deltas stay per-neuron between packed layers");
        let rows: Vec<Vec<Term>> = (0..self.layout.blocks(self.in_dim))
            .map(|b| {
                (0..self.out_dim).map(|j| Term::Cc(&self.w_blocks[j][b], &delta.cts[j])).collect()
            })
            .collect();
        let cts = engine.mac_rows_many(&rows);
        EncTensor::packed(
            cts,
            vec![self.in_dim],
            PackOrder::Reversed,
            delta.shift,
            self.layout.clone(),
        )
    }

    /// Gradient MACs: one convolution-trick MultCC per (neuron, input
    /// block) — packed forward `x[B]` × reversed `δ_j` leaves the `F`
    /// batch-summed gradients of block `B` at coefficients
    /// `k·stride + batch−1` (the stride isolates the cross-sample spread).
    /// `grads[j]` holds `B(in)` block products.
    pub fn gradients(&self, x: &EncTensor, delta: &EncTensor, engine: &GlyphEngine) -> LayerGrads {
        assert_eq!(x.order, PackOrder::Forward);
        assert_eq!(delta.order, PackOrder::Reversed);
        let x_blocks = self.input_blocks(x, engine);
        let rows: Vec<Vec<Term>> = (0..self.out_dim)
            .flat_map(|j| x_blocks.iter().map(move |xb| vec![Term::Cc(xb, &delta.cts[j])]))
            .collect();
        let mut flat = engine.mac_rows_many(&rows).into_iter();
        (0..self.out_dim)
            .map(|_| x_blocks.iter().map(|_| flat.next().expect("out·blocks rows")).collect())
            .collect()
    }

    /// SGD update: extract every weight lane's batch-sum bits from the
    /// block products (full blocks in one pooled down-switch, the partial
    /// final block in a second — the counters sum identically), recompose
    /// through weighted gates, repack one T2B group per weight block at the
    /// weight anchors, and subtract — one SubCC per block ciphertext
    /// instead of one per weight.
    pub fn apply_gradients(&mut self, grads: &[Vec<Ct>], grad_shift: u32, engine: &GlyphEngine) {
        let frac = engine.frac_bits();
        assert!(grad_shift <= frac);
        let pre_shift = frac - grad_shift;
        let f = self.layout.feats_per_ct;
        let nblocks = self.layout.blocks(self.in_dim);
        // 1. per-lane bits of every block product, grouped by lane count so
        //    each pooled down-switch shares one position set (full blocks
        //    in one pass, a partial final block in a second)
        let last_feats = self.layout.feats_in_block(self.in_dim, nblocks - 1);
        let feat_passes: &[usize] = if last_feats == f { &[f] } else { &[f, last_feats] };
        let mut order: Vec<(usize, usize)> = Vec::new();
        let mut lanes_per: Vec<usize> = Vec::new();
        let mut bit_sets: Vec<Vec<Vec<Bit>>> = Vec::new();
        for &feats in feat_passes {
            let mut refs: Vec<&Ct> = Vec::new();
            for j in 0..self.out_dim {
                for b in 0..nblocks {
                    if self.layout.feats_in_block(self.in_dim, b) == feats {
                        order.push((j, b));
                        lanes_per.push(feats);
                        refs.push(&grads[j][b]);
                    }
                }
            }
            if refs.is_empty() {
                continue;
            }
            let positions = self.layout.gradient_positions(feats);
            bit_sets.extend(engine.switch_down_many(&refs, &positions, pre_shift));
        }
        // 2. identity recomposition at the weighted positions — one pooled
        //    fan-out over every weight lane × bit
        let truth = engine.trivial_bit(true);
        let jobs: Vec<(&Bit, &Bit, u32)> = bit_sets
            .iter()
            .flat_map(|lanes| lanes.iter())
            .flat_map(|bits| bits.iter().enumerate().map(|(bi, b)| (b, &truth, bit_position(bi))))
            .collect();
        let weighted = engine.gate_and_weighted_many(&jobs);
        // 3. per weight lane: fold its bit contributions, then raise one
        //    packed group per block at the weight anchors and subtract
        let bits_per = crate::switch::SWITCH_BITS as usize;
        let accs: Vec<Bit> = weighted
            .chunks(bits_per)
            .map(|chunk| {
                let mut acc = chunk[0].clone();
                for w in &chunk[1..] {
                    acc.add_assign(w);
                }
                acc
            })
            .collect();
        let full_pos = self.layout.weight_positions(f);
        let last_feats = self.layout.feats_in_block(self.in_dim, nblocks - 1);
        let last_pos = self.layout.weight_positions(last_feats);
        let mut groups: Vec<(&[Bit], &[usize])> = Vec::new();
        let mut cursor = 0usize;
        for (idx, _) in order.iter().enumerate() {
            let feats = lanes_per[idx];
            let pos: &[usize] = if feats == f { &full_pos } else { &last_pos };
            groups.push((&accs[cursor..cursor + feats], pos));
            cursor += feats;
        }
        let steps = engine.switch_up_many(&groups);
        for (idx, step) in steps.iter().enumerate() {
            let (j, b) = order[idx];
            engine.sub_cc(&mut self.w_blocks[j][b], step);
        }
    }

    /// Decrypted weight matrix (test/bench introspection): reads every
    /// weight lane back off its block anchor through the codec.
    pub fn decrypt_weights(&self, codec: &dyn Codec) -> Vec<Vec<i64>> {
        (0..self.out_dim)
            .map(|j| {
                (0..self.layout.blocks(self.in_dim))
                    .flat_map(|b| {
                        let feats = self.layout.feats_in_block(self.in_dim, b);
                        codec.decrypt_positions(
                            &self.w_blocks[j][b],
                            &self.layout.weight_positions(feats),
                            0,
                        )
                    })
                    .collect()
            })
            .collect()
    }
}

impl Layer for PackedFcLayer {
    fn plan_entry(&self, _in_shape: &[usize], _batch: usize) -> LayerPlanEntry {
        panic!("PackedFcLayer only compiles under the packed layout (plan_entry_packed)")
    }

    fn plan_entry_packed(
        &self,
        in_shape: &[usize],
        layout: &PackedLayout,
        in_packed: bool,
    ) -> LayerPlanEntry {
        let in_dim: usize = in_shape.iter().product();
        assert_eq!(in_dim, self.in_dim, "FC input width mismatch");
        assert_eq!(layout, &self.layout, "engine/layer layout mismatch");
        assert_eq!(in_packed, self.in_packed, "input packedness mismatch");
        LayerPlanEntry {
            kind: LayerKind::Fc { trainable: true },
            out_shape: vec![self.out_dim],
            forward: fc_forward_packed_ops(self.in_dim, self.out_dim, layout, in_packed, 0),
            error: Some(fc_error_packed_ops(self.in_dim, self.out_dim, layout)),
            gradient: Some(fc_gradient_packed_ops(self.in_dim, self.out_dim, layout, in_packed)),
            out_packed: false,
        }
    }

    fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState) {
        (PackedFcLayer::forward(self, x, engine), LayerState::None)
    }

    fn backward_error(
        &self,
        delta: &EncTensor,
        _state: &LayerState,
        engine: &GlyphEngine,
    ) -> EncTensor {
        PackedFcLayer::backward_error(self, delta, engine)
    }

    fn gradients(
        &self,
        below: &EncTensor,
        delta: &EncTensor,
        engine: &GlyphEngine,
    ) -> Option<LayerGrads> {
        Some(PackedFcLayer::gradients(self, below, delta, engine))
    }

    fn apply_gradients(&mut self, grads: &LayerGrads, grad_shift: u32, engine: &GlyphEngine) {
        PackedFcLayer::apply_gradients(self, grads, grad_shift, engine);
    }

    fn as_packed_fc(&self) -> Option<&PackedFcLayer> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::{ClientKeys, EngineProfile, GlyphEngine};

    fn enc_x(client: &mut ClientKeys, cols: &[Vec<i64>]) -> EncTensor {
        // cols[i] = values of input scalar i across the batch
        let cts = cols.iter().map(|v| client.encrypt_batch(v, 0)).collect();
        EncTensor::new(cts, vec![cols.len()], PackOrder::Forward, 0)
    }

    #[test]
    fn forward_matches_plain_mac() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 3, 700);
        let w = vec![vec![2i64, -3], vec![1, 4]];
        let layer = FcLayer::new_encrypted(&w, &mut client, 0);
        let x_cols = vec![vec![5i64, -1, 0], vec![7, 2, -3]];
        let x = enc_x(&mut client, &x_cols);
        let u = layer.forward(&x, &eng);
        for j in 0..2 {
            let got = client.decrypt_batch(&u.cts[j], 3, 0);
            let want: Vec<i64> = (0..3)
                .map(|b| (0..2).map(|i| w[j][i] * x_cols[i][b]).sum())
                .collect();
            assert_eq!(got, want, "row {j}");
        }
        let s = eng.counter.snapshot();
        assert_eq!(s.mult_cc, 4);
        assert_eq!(s.add_cc, 2);
    }

    #[test]
    fn plain_weights_use_mult_cp() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 701);
        let w = vec![vec![3i64, 3]];
        let layer = FcLayer::new_plain(&w, &eng, 0);
        let x = enc_x(&mut client, &vec![vec![4i64, -4], vec![1, 1]]);
        let u = layer.forward(&x, &eng);
        assert_eq!(client.decrypt_batch(&u.cts[0], 2, 0), vec![15, -9]);
        let s = eng.counter.snapshot();
        assert_eq!((s.mult_cc, s.mult_cp), (0, 2));
    }

    #[test]
    fn gradient_convolution_trick_sums_batch() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 4, 702);
        let layer = FcLayer::new_encrypted(&vec![vec![0i64]], &mut client, 0);
        let x_vals = vec![3i64, -2, 5, 1];
        let d_vals = vec![2i64, 4, -1, 3]; // per-sample errors
        let x = enc_x(&mut client, &vec![x_vals.clone()]);
        let mut d_rev = d_vals.clone();
        d_rev.reverse();
        let d_ct = client.encrypt_batch(&d_rev, 0);
        let delta = EncTensor::new(vec![d_ct], vec![1], PackOrder::Reversed, 0);
        let grads = layer.gradients(&x, &delta, &eng);
        // coefficient batch−1 = Σ_b x_b·δ_b
        let got = client.decrypt_batch(&grads[0][0], 4, 0)[3];
        let want: i64 = x_vals.iter().zip(&d_vals).map(|(a, b)| a * b).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn apply_gradients_updates_encrypted_weight() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 703);
        let mut layer = FcLayer::new_encrypted(&vec![vec![10i64]], &mut client, 0);
        // craft a gradient ciphertext with batch-sum 24 at coefficient 1
        let g = client.encrypt_batch(&[0, 24], 0);
        // grad_shift 1 → step = 24 >> 1 = 12 → w: 10 − 12 = −2
        layer.apply_gradients(&[vec![g]], 1, &eng);
        if let Weight::Enc(wct) = &layer.w[0][0] {
            assert_eq!(client.decrypt_batch(wct, 1, 0), vec![-2]);
        } else {
            panic!("weight should be encrypted");
        }
        let s = eng.counter.snapshot();
        assert_eq!(s.switch_b2t, 1);
        assert_eq!(s.switch_t2b, 1);
    }

    #[test]
    fn clear_backend_mirrors_forward_gradient_and_update() {
        use crate::nn::backend::Codec;
        let (eng, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
        let mut layer = FcLayer::new_encrypted(&vec![vec![10i64]], &mut codec, 0);
        let g = codec.encrypt_batch(&[0, 24], 0);
        layer.apply_gradients(&[vec![g]], 1, &eng);
        if let Weight::Enc(wct) = &layer.w[0][0] {
            assert_eq!(codec.decrypt_batch(wct, 1, 0), vec![-2]);
        } else {
            panic!("weight should be a clear ciphertext mirror");
        }
        let s = eng.counter.snapshot();
        assert_eq!((s.switch_b2t, s.switch_t2b, s.act_gates), (1, 1, 8));
    }

    // ---- cross-sample SIMD packed FC ------------------------------------

    use crate::nn::backend::Codec;
    use crate::nn::tensor::PackedLayout;

    /// Packed input tensor from per-feature sample columns.
    fn packed_x(
        codec: &mut dyn Codec,
        layout: &PackedLayout,
        cols: &[Vec<i64>],
        n: usize,
    ) -> EncTensor {
        let cts = layout
            .pack_columns(cols, n)
            .iter()
            .map(|coeffs| codec.encrypt_coeffs(coeffs, 0))
            .collect();
        EncTensor::packed(cts, vec![cols.len()], PackOrder::Forward, 0, layout.clone())
    }

    #[test]
    fn packed_forward_serves_every_sample_per_mac_row() {
        // batch 4 → stride 8, F = 16 on the test ring; 3 inputs fit one
        // block, so 2 neurons cost 2 MultCC total (vs 6 per-scalar).
        let (eng, mut client) = GlyphEngine::setup_packed(EngineProfile::Test, 4, 710);
        let layout = eng.packed_layout().unwrap().clone();
        let n = eng.params().n;
        let w = vec![vec![2i64, -3, 1], vec![1, 4, -2]];
        let layer = PackedFcLayer::new_encrypted(&w, &mut client, 0, &layout, true, n);
        let x_cols =
            vec![vec![5i64, -1, 0, 2], vec![7, 2, -3, 1], vec![-4, 0, 6, -2]];
        let x = packed_x(&mut client, &layout, &x_cols, n);
        let u = layer.forward(&x, &eng);
        assert!(!u.is_packed());
        assert_eq!(u.lane_base, layout.payload_base());
        let lanes = layout.lane_positions(PackOrder::Forward, layout.payload_base());
        for j in 0..2 {
            let got = client.decrypt_positions(&u.cts[j], &lanes, 0);
            let want: Vec<i64> = (0..4)
                .map(|b| (0..3).map(|i| w[j][i] * x_cols[i][b]).sum())
                .collect();
            assert_eq!(got, want, "row {j}");
        }
        let s = eng.counter.snapshot();
        assert_eq!((s.mult_cc, s.add_cc), (2, 0));
    }

    #[test]
    fn packed_backward_error_lands_on_the_reversed_grid() {
        let (eng, mut client) = GlyphEngine::setup_packed(EngineProfile::Test, 3, 711);
        let layout = eng.packed_layout().unwrap().clone();
        let n = eng.params().n;
        let w = vec![vec![2i64, -1], vec![3, 5]];
        let layer = PackedFcLayer::new_encrypted(&w, &mut client, 0, &layout, true, n);
        // per-neuron reversed deltas (what softmax error / iReLU emit)
        let d_cols = vec![vec![1i64, -2, 4], vec![3, 0, -1]];
        let d_cts = d_cols
            .iter()
            .map(|col| {
                let mut rev = col.clone();
                rev.reverse();
                client.encrypt_batch(&rev, 0)
            })
            .collect();
        let delta = EncTensor::new(d_cts, vec![2], PackOrder::Reversed, 0);
        let below = layer.backward_error(&delta, &eng);
        assert!(below.is_packed());
        assert_eq!(below.order, PackOrder::Reversed);
        let pos = layout.block_positions(PackOrder::Reversed, 2);
        let got = client.decrypt_positions(&below.cts[0], &pos, 0);
        // lane k·batch + b = Σ_j w[j][k]·δ_j[b]
        for k in 0..2 {
            for b in 0..3 {
                let want: i64 = (0..2).map(|j| w[j][k] * d_cols[j][b]).sum();
                assert_eq!(got[k * 3 + b], want, "feature {k} sample {b}");
            }
        }
        let s = eng.counter.snapshot();
        assert_eq!((s.mult_cc, s.add_cc), (2, 1));
    }

    #[test]
    fn packed_gradients_and_update_mirror_the_per_weight_path() {
        // batch 2: full packed SGD step — gradient block products carry the
        // batch sums at k·stride+1, the update lands on the weight anchors.
        let (eng, mut client) = GlyphEngine::setup_packed(EngineProfile::Test, 2, 712);
        let layout = eng.packed_layout().unwrap().clone();
        let n = eng.params().n;
        let w = vec![vec![10i64, -6]];
        let mut layer = PackedFcLayer::new_encrypted(&w, &mut client, 0, &layout, true, n);
        let x_cols = vec![vec![3i64, -2], vec![5, 1]];
        let x = packed_x(&mut client, &layout, &x_cols, n);
        let d_col = vec![2i64, 4];
        let mut d_rev = d_col.clone();
        d_rev.reverse();
        let delta =
            EncTensor::new(vec![client.encrypt_batch(&d_rev, 0)], vec![1], PackOrder::Reversed, 0);
        let grads = layer.gradients(&x, &delta, &eng);
        assert_eq!(grads[0].len(), 1);
        let sums = client.decrypt_positions(&grads[0][0], &layout.gradient_positions(2), 0);
        // Σ_b x_i[b]·δ[b]: [3·2 + (−2)·4, 5·2 + 1·4] = [−2, 14]
        assert_eq!(sums, vec![-2, 14]);
        // grad_shift 1 → steps [−1, 7] → w = [10 − (−1), −6 − 7]
        layer.apply_gradients(&grads, 1, &eng);
        assert_eq!(layer.decrypt_weights(&client), vec![vec![11, -13]]);
        let s = eng.counter.snapshot();
        // 1 gradient MultCC, 1 B2T of 2 lanes, 16 PBS + 16 gates, 1 T2B
        // group of 2 lanes, 1 SubCC
        assert_eq!((s.mult_cc, s.switch_b2t, s.switch_t2b, s.refresh), (1, 1, 1, 1));
        assert_eq!((s.extract_lanes, s.repack_lanes, s.act_gates, s.add_cc), (2, 2, 16, 1));
    }

    #[test]
    fn packed_partial_final_block_splits_the_switch_calls() {
        // Force F < in_dim with a partial final block: batch 32 on n=256
        // → stride 64, F = 2; in_dim 3 → blocks [2, 1].
        let (eng, mut codec) = GlyphEngine::setup_clear_packed(EngineProfile::Test, 32);
        let layout = eng.packed_layout().unwrap().clone();
        assert_eq!(layout.feats_per_ct, 2);
        let n = eng.params().n;
        let w = vec![vec![4i64, -2, 7]];
        let mut layer = PackedFcLayer::new_encrypted(&w, &mut codec, 0, &layout, true, n);
        let x_cols: Vec<Vec<i64>> =
            (0..3).map(|i| (0..32).map(|b| ((i + b) % 5) as i64 - 2).collect()).collect();
        let x = packed_x(&mut codec, &layout, &x_cols, n);
        let d_col: Vec<i64> = (0..32).map(|b| (b % 3) as i64 - 1).collect();
        let mut d_rev = d_col.clone();
        d_rev.reverse();
        let delta =
            EncTensor::new(vec![codec.encrypt_batch(&d_rev, 0)], vec![1], PackOrder::Reversed, 0);
        let grads = layer.gradients(&x, &delta, &eng);
        layer.apply_gradients(&grads, 0, &eng);
        let want: Vec<i64> = (0..3)
            .map(|i| {
                let g: i64 = (0..32).map(|b| x_cols[i][b] * d_col[b]).sum();
                w[0][i] - g
            })
            .collect();
        assert_eq!(layer.decrypt_weights(&codec), vec![want]);
        let s = eng.counter.snapshot();
        // 2 gradient blocks → 2 B2T / 2 T2B / 2 SubCC, but still 3 weight
        // lanes extracted/repacked (2 + 1 across the split calls)
        assert_eq!((s.mult_cc, s.switch_b2t, s.switch_t2b), (2, 2, 2));
        assert_eq!((s.extract_lanes, s.repack_lanes, s.act_gates), (3, 3, 24));
    }
}
