//! Fully-connected layers over encrypted (or clear-mirrored) tensors.
//!
//! Weights are either constant-polynomial ciphertexts (MultCC MACs — the
//! FHESGD/Glyph trainable layers) or plaintext scalars (MultCP — the
//! transfer-learning frozen layers), on whichever backend the engine runs.
//! The backward pass consumes reverse-packed error tensors; gradients fall
//! out of the negacyclic convolution trick at coefficient `batch−1`
//! (DESIGN.md §2.1) and are re-quantized through the cryptosystem switch
//! before the SGD update — exactly the `FC-gradient … BGV-TFHE` rows of the
//! paper's Table 3. The clear backend mirrors every one of those steps
//! (including the gradient's `∇ >> grad_shift` rounding) bit for bit.

use super::backend::{Bit, Codec, Ct, PlainWeight, Term};
use super::engine::GlyphEngine;
use super::layer::{
    fc_error_ops, fc_forward_ops, fc_gradient_ops, Layer, LayerGrads, LayerPlanEntry, LayerState,
};
use super::tensor::{EncTensor, PackOrder};
use crate::coordinator::scheduler::LayerKind;
use crate::switch::extract::bit_position;
use std::collections::HashMap;

/// A layer weight: trainable ciphertext or frozen plaintext. Frozen FHE
/// weights carry their per-level NTT-domain lifts
/// ([`crate::bgv::CachedPlaintext`], built once at construction and shared
/// across equal weight values), so every MultCP against them is a pure
/// pointwise pass; frozen clear weights are bare scalars.
pub enum Weight {
    Enc(Ct),
    Plain(PlainWeight),
}

impl Weight {
    /// The MAC-row term multiplying this weight with `x`.
    pub fn term<'a>(&'a self, x: &'a Ct) -> Term<'a> {
        match self {
            Weight::Enc(wct) => Term::Cc(wct, x),
            Weight::Plain(wpt) => Term::Cp(x, wpt),
        }
    }
}

/// One frozen weight per *distinct* value, shared within a layer: frozen
/// weights are 8-bit integers, so the cache is bounded at ≤256 entries per
/// layer instead of one per weight (on the FHE backend a paper-scale frozen
/// layer would otherwise pay ~100KB + a full NTT set per weight; the clear
/// backend shares the scalars for symmetry).
pub(crate) fn shared_plain(
    cache: &mut HashMap<i64, PlainWeight>,
    v: i64,
    engine: &GlyphEngine,
) -> PlainWeight {
    cache.entry(v).or_insert_with(|| engine.scalar_weight(v)).clone()
}

/// A fully-connected layer `u = W·x (+ b)`.
pub struct FcLayer {
    /// w[out][in]
    pub w: Vec<Vec<Weight>>,
    pub bias: Option<Vec<Weight>>,
    pub in_dim: usize,
    pub out_dim: usize,
    /// Quantization shift applied by the following activation.
    pub out_shift: u32,
}

impl FcLayer {
    /// Trainable layer from plain 8-bit initial weights, encoded under the
    /// backend's codec (encrypted on FHE, mirrored on clear).
    pub fn new_encrypted(init: &[Vec<i64>], client: &mut dyn Codec, out_shift: u32) -> Self {
        let out_dim = init.len();
        let in_dim = init[0].len();
        let w = init
            .iter()
            .map(|row| row.iter().map(|&v| Weight::Enc(client.encrypt_scalar(v))).collect())
            .collect();
        FcLayer { w, bias: None, in_dim, out_dim, out_shift }
    }

    /// Frozen plaintext layer (transfer learning); caches one weight per
    /// distinct value, shared across the matrix.
    pub fn new_plain(init: &[Vec<i64>], engine: &GlyphEngine, out_shift: u32) -> Self {
        let out_dim = init.len();
        let in_dim = init[0].len();
        let mut cache = HashMap::new();
        let w = init
            .iter()
            .map(|row| {
                row.iter().map(|&v| Weight::Plain(shared_plain(&mut cache, v, engine))).collect()
            })
            .collect();
        FcLayer { w, bias: None, in_dim, out_dim, out_shift }
    }

    /// Forward MACs: `u[j] = Σ_i w[j][i] ⊗ x[i]`, one lazy-relin MAC row
    /// per output neuron fanned across the pool (`mac_rows_many`). Output
    /// keeps `x`'s packing order and accumulates scale `x.shift` (weights
    /// are 8-bit integers at scale 0).
    pub fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        assert_eq!(x.len(), self.in_dim);
        let rows: Vec<Vec<Term>> = (0..self.out_dim)
            .map(|j| (0..self.in_dim).map(|i| self.w[j][i].term(&x.cts[i])).collect())
            .collect();
        let mut cts = engine.mac_rows_many(&rows);
        if let Some(bias) = &self.bias {
            for (j, u) in cts.iter_mut().enumerate() {
                match &bias[j] {
                    Weight::Enc(bct) => engine.add_cc(u, bct),
                    Weight::Plain(bpt) => engine.add_plain_w(u, bpt),
                }
            }
        }
        EncTensor::new(cts, vec![self.out_dim], x.order, x.shift)
    }

    /// Backward error propagation: `δ_{l−1}[i] = Σ_j w[j][i] ⊗ δ_l[j]`
    /// (before the iReLU mask), one MAC row per input neuron. Keeps the
    /// reversed packing.
    pub fn backward_error(&self, delta: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        assert_eq!(delta.len(), self.out_dim);
        assert_eq!(delta.order, PackOrder::Reversed);
        let rows: Vec<Vec<Term>> = (0..self.in_dim)
            .map(|i| (0..self.out_dim).map(|j| self.w[j][i].term(&delta.cts[j])).collect())
            .collect();
        let cts = engine.mac_rows_many(&rows);
        EncTensor::new(cts, vec![self.in_dim], PackOrder::Reversed, delta.shift)
    }

    /// Gradient MACs: `∇w[j][i] = Σ_b x[b][i]·δ[b][j]`, one MultCC each —
    /// forward-packed x × reverse-packed δ leaves the batch sum at
    /// coefficient `batch−1`. All `out·in` products fan across the pool as
    /// single-term rows.
    pub fn gradients(&self, x: &EncTensor, delta: &EncTensor, engine: &GlyphEngine) -> LayerGrads {
        assert_eq!(x.order, PackOrder::Forward);
        assert_eq!(delta.order, PackOrder::Reversed);
        let rows: Vec<Vec<Term>> = (0..self.out_dim)
            .flat_map(|j| (0..self.in_dim).map(move |i| vec![Term::Cc(&x.cts[i], &delta.cts[j])]))
            .collect();
        let mut flat = engine.mac_rows_many(&rows).into_iter();
        (0..self.out_dim)
            .map(|_| (0..self.in_dim).map(|_| flat.next().expect("out·in rows")).collect())
            .collect()
    }

    /// SGD update: re-quantize each gradient through the switch (extracting
    /// the batch-sum coefficient with an effective learning-rate shift) and
    /// subtract from the encrypted weights. `grad_shift` plays the role of
    /// `−log2(lr · scale⁻¹)`: the extracted 8-bit step is `∇ >> grad_shift`.
    ///
    /// The whole update crosses the switch in three batched fan-outs: ONE
    /// `switch_down_many` extracts every trainable weight's batch-sum bits,
    /// one `gate_and_weighted_many` recomposes all weights × 8 bits, and ONE
    /// `switch_up_many` packs/raises every weight's gradient step — same
    /// values and op counts as the per-weight serial loop, on both backends.
    pub fn apply_gradients(&mut self, grads: &[Vec<Ct>], grad_shift: u32, engine: &GlyphEngine) {
        let frac = engine.frac_bits();
        assert!(grad_shift <= frac);
        let pre_shift = frac - grad_shift;
        let sum_pos = [engine.batch - 1];
        // 1. bits of every batch-summed gradient (position batch−1), one
        //    pooled down-switch over all trainable weights
        let mut targets: Vec<(usize, usize)> = Vec::new();
        let mut g_refs: Vec<&Ct> = Vec::new();
        for (j, row) in grads.iter().enumerate() {
            for (i, g) in row.iter().enumerate() {
                if matches!(self.w[j][i], Weight::Enc(_)) {
                    g_refs.push(g);
                    targets.push((j, i));
                }
            }
        }
        if targets.is_empty() {
            return;
        }
        let all_bits: Vec<Vec<Bit>> = engine
            .switch_down_many(&g_refs, &sum_pos, pre_shift)
            .into_iter()
            .map(|mut lanes| lanes.swap_remove(0))
            .collect();
        // 2. identity recomposition at the weighted positions — one pooled
        //    fan-out over all weights × bits
        let truth = engine.trivial_bit(true);
        let jobs: Vec<(&Bit, &Bit, u32)> = all_bits
            .iter()
            .flat_map(|bits| bits.iter().enumerate().map(|(bi, b)| (b, &truth, bit_position(bi))))
            .collect();
        let weighted = engine.gate_and_weighted_many(&jobs);
        // 3. per weight: sum its bit contributions into one recomposed LWE,
        //    then raise every step in one batched up-switch and subtract
        let bits_per = all_bits[0].len();
        let accs: Vec<Bit> = weighted
            .chunks(bits_per)
            .map(|chunk| {
                let mut acc = chunk[0].clone();
                for w in &chunk[1..] {
                    acc.add_assign(w);
                }
                acc
            })
            .collect();
        // fresh constant-poly gradient steps at coefficient 0
        let zero_pos = [0usize];
        let groups: Vec<(&[Bit], &[usize])> =
            accs.iter().map(|a| (std::slice::from_ref(a), &zero_pos[..])).collect();
        let steps = engine.switch_up_many(&groups);
        for (t, step) in steps.iter().enumerate() {
            let (j, i) = targets[t];
            if let Weight::Enc(wct) = &mut self.w[j][i] {
                engine.sub_cc(wct, step);
            }
        }
    }
}

impl FcLayer {
    /// Whether the layer trains (ciphertext weights) or is frozen plaintext.
    pub fn is_trainable(&self) -> bool {
        matches!(self.w.first().and_then(|row| row.first()), Some(Weight::Enc(_)))
    }
}

impl Layer for FcLayer {
    fn plan_entry(&self, in_shape: &[usize], _batch: usize) -> LayerPlanEntry {
        let in_dim: usize = in_shape.iter().product();
        assert_eq!(in_dim, self.in_dim, "FC input width mismatch");
        let enc = self.is_trainable();
        let enc_bias_terms = self
            .bias
            .as_ref()
            .map_or(0, |b| b.iter().filter(|w| matches!(w, Weight::Enc(_))).count());
        let forward = fc_forward_ops(self.in_dim, self.out_dim, enc, enc_bias_terms);
        LayerPlanEntry {
            kind: LayerKind::Fc { trainable: enc },
            out_shape: vec![self.out_dim],
            forward,
            error: Some(fc_error_ops(self.in_dim, self.out_dim, enc)),
            gradient: if enc { Some(fc_gradient_ops(self.in_dim, self.out_dim)) } else { None },
        }
    }

    fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState) {
        (FcLayer::forward(self, x, engine), LayerState::None)
    }

    fn backward_error(
        &self,
        delta: &EncTensor,
        _state: &LayerState,
        engine: &GlyphEngine,
    ) -> EncTensor {
        FcLayer::backward_error(self, delta, engine)
    }

    fn gradients(
        &self,
        below: &EncTensor,
        delta: &EncTensor,
        engine: &GlyphEngine,
    ) -> Option<LayerGrads> {
        Some(FcLayer::gradients(self, below, delta, engine))
    }

    fn apply_gradients(&mut self, grads: &LayerGrads, grad_shift: u32, engine: &GlyphEngine) {
        FcLayer::apply_gradients(self, grads, grad_shift, engine);
    }

    fn as_fc(&self) -> Option<&FcLayer> {
        Some(self)
    }

    fn as_fc_mut(&mut self) -> Option<&mut FcLayer> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::{ClientKeys, EngineProfile, GlyphEngine};

    fn enc_x(client: &mut ClientKeys, cols: &[Vec<i64>]) -> EncTensor {
        // cols[i] = values of input scalar i across the batch
        let cts = cols.iter().map(|v| client.encrypt_batch(v, 0)).collect();
        EncTensor::new(cts, vec![cols.len()], PackOrder::Forward, 0)
    }

    #[test]
    fn forward_matches_plain_mac() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 3, 700);
        let w = vec![vec![2i64, -3], vec![1, 4]];
        let layer = FcLayer::new_encrypted(&w, &mut client, 0);
        let x_cols = vec![vec![5i64, -1, 0], vec![7, 2, -3]];
        let x = enc_x(&mut client, &x_cols);
        let u = layer.forward(&x, &eng);
        for j in 0..2 {
            let got = client.decrypt_batch(&u.cts[j], 3, 0);
            let want: Vec<i64> = (0..3)
                .map(|b| (0..2).map(|i| w[j][i] * x_cols[i][b]).sum())
                .collect();
            assert_eq!(got, want, "row {j}");
        }
        let s = eng.counter.snapshot();
        assert_eq!(s.mult_cc, 4);
        assert_eq!(s.add_cc, 2);
    }

    #[test]
    fn plain_weights_use_mult_cp() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 701);
        let w = vec![vec![3i64, 3]];
        let layer = FcLayer::new_plain(&w, &eng, 0);
        let x = enc_x(&mut client, &vec![vec![4i64, -4], vec![1, 1]]);
        let u = layer.forward(&x, &eng);
        assert_eq!(client.decrypt_batch(&u.cts[0], 2, 0), vec![15, -9]);
        let s = eng.counter.snapshot();
        assert_eq!((s.mult_cc, s.mult_cp), (0, 2));
    }

    #[test]
    fn gradient_convolution_trick_sums_batch() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 4, 702);
        let layer = FcLayer::new_encrypted(&vec![vec![0i64]], &mut client, 0);
        let x_vals = vec![3i64, -2, 5, 1];
        let d_vals = vec![2i64, 4, -1, 3]; // per-sample errors
        let x = enc_x(&mut client, &vec![x_vals.clone()]);
        let mut d_rev = d_vals.clone();
        d_rev.reverse();
        let d_ct = client.encrypt_batch(&d_rev, 0);
        let delta = EncTensor::new(vec![d_ct], vec![1], PackOrder::Reversed, 0);
        let grads = layer.gradients(&x, &delta, &eng);
        // coefficient batch−1 = Σ_b x_b·δ_b
        let got = client.decrypt_batch(&grads[0][0], 4, 0)[3];
        let want: i64 = x_vals.iter().zip(&d_vals).map(|(a, b)| a * b).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn apply_gradients_updates_encrypted_weight() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 703);
        let mut layer = FcLayer::new_encrypted(&vec![vec![10i64]], &mut client, 0);
        // craft a gradient ciphertext with batch-sum 24 at coefficient 1
        let g = client.encrypt_batch(&[0, 24], 0);
        // grad_shift 1 → step = 24 >> 1 = 12 → w: 10 − 12 = −2
        layer.apply_gradients(&[vec![g]], 1, &eng);
        if let Weight::Enc(wct) = &layer.w[0][0] {
            assert_eq!(client.decrypt_batch(wct, 1, 0), vec![-2]);
        } else {
            panic!("weight should be encrypted");
        }
        let s = eng.counter.snapshot();
        assert_eq!(s.switch_b2t, 1);
        assert_eq!(s.switch_t2b, 1);
    }

    #[test]
    fn clear_backend_mirrors_forward_gradient_and_update() {
        use crate::nn::backend::Codec;
        let (eng, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
        let mut layer = FcLayer::new_encrypted(&vec![vec![10i64]], &mut codec, 0);
        let g = codec.encrypt_batch(&[0, 24], 0);
        layer.apply_gradients(&[vec![g]], 1, &eng);
        if let Weight::Enc(wct) = &layer.w[0][0] {
            assert_eq!(codec.decrypt_batch(wct, 1, 0), vec![-2]);
        } else {
            panic!("weight should be a clear ciphertext mirror");
        }
        let s = eng.counter.snapshot();
        assert_eq!((s.switch_b2t, s.switch_t2b, s.act_gates), (1, 1, 8));
    }
}
