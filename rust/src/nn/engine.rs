//! `GlyphEngine`: the evaluator-side execution engine every encrypted layer
//! operates through — now a *pluggable backend* front.
//!
//! The engine owns the HOP counters and the counted-op API
//! (`mac_rows_many`, `switch_down_many`, the gate library, …); the actual
//! arithmetic is dispatched to one of two backends:
//!
//! * [`Backend::Fhe`] — the full lattice path: BGV key material
//!   (relinearization key, bootstrapping keys, switching keys) plus the
//!   refresh-authority handle (the documented bootstrapping substitute,
//!   DESIGN.md §5). This is the pre-existing `GlyphEngine` behaviour,
//!   semantics unchanged.
//! * [`Backend::Clear`] — the bit-exact plaintext mirror
//!   ([`crate::nn::backend::ClearBackend`]): no keys, instant setup, every
//!   op on plain integer lanes with semantics equal to
//!   `decrypt(FHE(op))` by construction. Op accounting is **identical** on
//!   both paths — the same counters are bumped by the same formulas, so a
//!   compiled `scheduler::Plan` prices and predicts clear executions
//!   exactly (asserted by `tests/backend_equivalence.rs`).
//!
//! The client keeps [`ClientKeys`] (the BGV secret) on the FHE path and a
//! key-less [`crate::nn::backend::ClearCodec`] on the clear path; both
//! implement [`crate::nn::backend::Codec`].

use super::backend::{
    canon, Bit, ClearBackend, ClearCodec, ClearCt, Codec, Ct, PlainVector, PlainWeight, Term,
};
use super::tensor::PackedLayout;
use crate::bgv::{
    mac_row, BgvCiphertext, BgvContext, BgvParams, BgvSecretKey, CachedPlaintext, KeyAuthority,
    MacTerm, Plaintext, RelinKey,
};
use crate::coordinator::executor::GlyphPool;
use crate::coordinator::metrics::OpCounter;
use crate::math::rng::GlyphRng;
use crate::switch::{LweExtractor, Repacker};
use crate::tfhe::{LweCiphertext, LweKey, TfheCloudKey, TfheParams, TrlweKey};
use std::sync::Arc;

/// Client-side secret material (the FHE backend's codec).
pub struct ClientKeys {
    pub bgv_sk: Arc<BgvSecretKey>,
    pub rng: GlyphRng,
}

impl ClientKeys {
    /// Encrypt a batch of 8-bit values at fixed-point scale `shift`
    /// (value v is stored as v·2^shift in the plaintext ring).
    pub fn encrypt_batch(&mut self, values: &[i64], shift: u32) -> Ct {
        let scaled: Vec<i64> = values.iter().map(|&v| v << shift).collect();
        let pt = Plaintext::encode_batch(&scaled, &self.bgv_sk.ctx.params);
        Ct::Fhe(self.bgv_sk.encrypt(&pt, &mut self.rng))
    }

    /// Encrypt a single weight scalar as a constant polynomial.
    pub fn encrypt_scalar(&mut self, w: i64) -> Ct {
        let pt = Plaintext::encode_scalar(w, &self.bgv_sk.ctx.params);
        Ct::Fhe(self.bgv_sk.encrypt(&pt, &mut self.rng))
    }

    /// Decrypt a batch (optionally un-scaling by `shift`). Also decodes
    /// clear-backend values, so differential tests read both sides through
    /// one call.
    pub fn decrypt_batch(&self, ct: &Ct, lanes: usize, shift: u32) -> Vec<i64> {
        let raw = match ct {
            Ct::Fhe(c) => self.bgv_sk.decrypt(c).decode_batch(lanes),
            Ct::Clear(c) => c.decode_batch(lanes),
        };
        raw.into_iter().map(|v| v >> shift).collect()
    }

    /// Encrypt raw plaintext-ring coefficients at fixed-point scale `shift`
    /// — the packed-layout entry point: `PackedLayout::pack_columns` (and
    /// the `weight_positions` anchors) assemble interleaved slot blocks as
    /// explicit coefficient vectors, which land here verbatim.
    pub fn encrypt_coeffs(&mut self, coeffs: &[i64], shift: u32) -> Ct {
        let scaled: Vec<i64> = coeffs.iter().map(|&v| v << shift).collect();
        let pt = Plaintext::encode_batch(&scaled, &self.bgv_sk.ctx.params);
        Ct::Fhe(self.bgv_sk.encrypt(&pt, &mut self.rng))
    }

    /// Decrypt and read individual coefficient positions (packed layouts
    /// read payload lanes at strided slots rather than a prefix batch).
    pub fn decrypt_positions(&self, ct: &Ct, positions: &[usize], shift: u32) -> Vec<i64> {
        match ct {
            Ct::Fhe(c) => {
                let pt = self.bgv_sk.decrypt(c);
                positions.iter().map(|&p| pt.coeffs[p] >> shift).collect()
            }
            Ct::Clear(c) => {
                positions.iter().map(|&p| Plaintext::center(c.get(p), c.t) >> shift).collect()
            }
        }
    }
}

impl Codec for ClientKeys {
    fn encrypt_batch(&mut self, values: &[i64], shift: u32) -> Ct {
        ClientKeys::encrypt_batch(self, values, shift)
    }

    fn encrypt_scalar(&mut self, w: i64) -> Ct {
        ClientKeys::encrypt_scalar(self, w)
    }

    fn decrypt_batch(&self, ct: &Ct, lanes: usize, shift: u32) -> Vec<i64> {
        ClientKeys::decrypt_batch(self, ct, lanes, shift)
    }

    fn encrypt_coeffs(&mut self, coeffs: &[i64], shift: u32) -> Ct {
        ClientKeys::encrypt_coeffs(self, coeffs, shift)
    }

    fn decrypt_positions(&self, ct: &Ct, positions: &[usize], shift: u32) -> Vec<i64> {
        ClientKeys::decrypt_positions(self, ct, positions, shift)
    }
}

/// The FHE backend's evaluator-side key material.
pub struct FheState {
    pub ctx: Arc<BgvContext>,
    pub rlk: RelinKey,
    pub gate_ck: TfheCloudKey,
    pub extract_ck: TfheCloudKey,
    pub fwd_switch: LweExtractor,
    pub bwd_switch: Repacker,
    pub auth: Arc<KeyAuthority>,
    /// Key-generation seed. Keygen is fully deterministic from it, so the
    /// wire format for an `FheState` is (parameter triple, seed, authority
    /// RNG cursor) and decoding *regenerates* the keys instead of shipping
    /// FFT-domain cloud keys over the wire.
    pub seed: u64,
}

impl FheState {
    /// Deterministic key generation from a seed — the exact sequence
    /// [`GlyphEngine::setup`] runs, factored out so the wire layer can
    /// rebuild identical key material from (params, seed).
    pub fn generate(
        bgv_params: BgvParams,
        gate_params: TfheParams,
        ext_params: TfheParams,
        seed: u64,
    ) -> FheState {
        let ctx = BgvContext::new(bgv_params);
        let mut rng = GlyphRng::new(seed);
        let bgv_sk = Arc::new(BgvSecretKey::generate(&ctx, &mut rng));
        let rlk = RelinKey::generate(&bgv_sk, &mut rng);
        let lwe_key = LweKey::generate_binary(gate_params.n, &mut rng);
        let gate_ring = TrlweKey::generate(gate_params.big_n, &mut rng);
        let gate_ck = TfheCloudKey::generate(&lwe_key, &gate_ring, &gate_params, &mut rng);
        let ext_ring = TrlweKey::generate(ext_params.big_n, &mut rng);
        let extract_ck = TfheCloudKey::generate(&lwe_key, &ext_ring, &ext_params, &mut rng);
        let fwd_switch = LweExtractor::generate(&bgv_sk, &lwe_key, &ext_params, &mut rng);
        let bwd_switch = Repacker::generate(&gate_ring, &bgv_sk, &mut rng);
        let auth = KeyAuthority::new(bgv_sk, GlyphRng::new(seed ^ 0x5eed));
        FheState { ctx, rlk, gate_ck, extract_ck, fwd_switch, bwd_switch, auth, seed }
    }

    /// The client keys matching this evaluator state's keygen seed, at their
    /// initial RNG cursor (what [`GlyphEngine::setup`] hands out).
    pub fn client_keys(&self) -> ClientKeys {
        ClientKeys { bgv_sk: self.auth.sk.clone(), rng: GlyphRng::new(self.seed ^ 0xc11e) }
    }
}

/// Which execution backend an engine runs.
pub enum Backend {
    Fhe(Box<FheState>),
    Clear(ClearBackend),
}

/// Evaluator-side engine: counted-op API + backend dispatch.
pub struct GlyphEngine {
    pub backend: Backend,
    pub counter: OpCounter,
    /// Mini-batch width (≤ N).
    pub batch: usize,
    /// Run the scheme switch on the retained per-lane serial reference path
    /// instead of the batched scratch engine (bit-identical results — the
    /// contract `tests/train_step_golden.rs` locks). FHE backend only;
    /// ignored on the clear path. Default: batched.
    pub serial_switch: bool,
    /// Cross-sample SIMD minibatch packing: when set, tensors carry
    /// `batch × feature` slot blocks ([`PackedLayout`]) instead of one
    /// network scalar per ciphertext, and the layers route through their
    /// packed paths. `None` (the default) is the per-scalar layout of
    /// PR ≤ 7, bit-identical to before.
    pub packed: Option<PackedLayout>,
}

/// Which parameter scale to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineProfile {
    /// Production-shaped parameters (paper §5.1).
    Default,
    /// Reduced test/demo parameters.
    Test,
}

impl EngineProfile {
    /// The profile's fixed-point fraction bits (`GlyphEngine::frac_bits`
    /// without building an engine) — shape-only plan compilation needs the
    /// shift budget before any keys exist.
    pub fn frac_bits(self) -> u32 {
        let (bgv, _, _) = self.params();
        bgv.t.trailing_zeros() - crate::switch::SWITCH_BITS
    }

    fn params(self) -> (BgvParams, TfheParams, TfheParams) {
        match self {
            EngineProfile::Default => (
                BgvParams::mac_params(),
                TfheParams::default_params(),
                TfheParams::extract_params(),
            ),
            EngineProfile::Test => (
                BgvParams::test_params(),
                TfheParams::test_params(),
                TfheParams::test_extract_params(),
            ),
        }
    }
}

impl GlyphEngine {
    /// Generate all FHE key material. Returns the engine (evaluator side)
    /// and the client keys.
    pub fn setup(profile: EngineProfile, batch: usize, seed: u64) -> (GlyphEngine, ClientKeys) {
        let (bgv_params, gate_params, ext_params) = profile.params();
        assert!(batch <= bgv_params.n);
        let state = FheState::generate(bgv_params, gate_params, ext_params, seed);
        let client = state.client_keys();
        let engine = GlyphEngine {
            backend: Backend::Fhe(Box::new(state)),
            counter: OpCounter::default(),
            batch,
            serial_switch: false,
            packed: None,
        };
        (engine, client)
    }

    /// [`Self::setup`] with cross-sample SIMD packing enabled: the layout is
    /// derived from (batch, ring degree) by [`PackedLayout::for_ring`].
    pub fn setup_packed(
        profile: EngineProfile,
        batch: usize,
        seed: u64,
    ) -> (GlyphEngine, ClientKeys) {
        let (mut engine, client) = GlyphEngine::setup(profile, batch, seed);
        engine.enable_packing();
        (engine, client)
    }

    /// Wrap already-generated FHE key material (e.g. decoded off the wire)
    /// in an engine with fresh counters.
    pub fn from_fhe_state(state: FheState, batch: usize) -> GlyphEngine {
        assert!(batch <= state.ctx.params.n);
        GlyphEngine {
            backend: Backend::Fhe(Box::new(state)),
            counter: OpCounter::default(),
            batch,
            serial_switch: false,
            packed: None,
        }
    }

    /// Build a clear-backend engine (no key material, instant) with the
    /// same ring/quantization parameters as the corresponding FHE profile,
    /// plus its key-less codec.
    pub fn setup_clear(profile: EngineProfile, batch: usize) -> (GlyphEngine, ClearCodec) {
        let (bgv_params, _gate, ext_params) = profile.params();
        assert!(batch <= bgv_params.n);
        let codec = ClearCodec { params: bgv_params.clone() };
        let engine = GlyphEngine {
            backend: Backend::Clear(ClearBackend::new(bgv_params, ext_params.big_n)),
            counter: OpCounter::default(),
            batch,
            serial_switch: false,
            packed: None,
        };
        (engine, codec)
    }

    /// [`Self::setup_clear`] with cross-sample SIMD packing enabled —
    /// the bit-exact mirror of [`Self::setup_packed`].
    pub fn setup_clear_packed(profile: EngineProfile, batch: usize) -> (GlyphEngine, ClearCodec) {
        let (mut engine, codec) = GlyphEngine::setup_clear(profile, batch);
        engine.enable_packing();
        (engine, codec)
    }

    /// Switch this engine to the packed minibatch layout (derived from the
    /// engine's batch and ring degree). Panics if the batch does not fit —
    /// the layout needs `(2·batch − 1).next_power_of_two() ≤ n`.
    pub fn enable_packing(&mut self) {
        let n = self.params().n;
        let layout = PackedLayout::for_ring(self.batch, n)
            .unwrap_or_else(|e| panic!("cannot enable minibatch packing: {e}"));
        self.packed = Some(layout);
    }

    /// The active packed layout, if this engine runs the SIMD minibatch
    /// layout (`None` = one scalar per ciphertext, the PR ≤ 7 layout).
    pub fn packed_layout(&self) -> Option<&PackedLayout> {
        self.packed.as_ref()
    }

    /// The FHE backend's key material (panics on the clear backend).
    pub fn fhe(&self) -> &FheState {
        match &self.backend {
            Backend::Fhe(f) => f,
            Backend::Clear(_) => panic!(
                "this engine runs the clear backend; the requested operation needs FHE key material"
            ),
        }
    }

    /// The clear backend (panics on the FHE backend).
    pub fn clear(&self) -> &ClearBackend {
        match &self.backend {
            Backend::Clear(c) => c,
            Backend::Fhe(_) => panic!("this engine runs the FHE backend, not the clear mirror"),
        }
    }

    pub fn is_clear(&self) -> bool {
        matches!(self.backend, Backend::Clear(_))
    }

    /// Backend name for logs/CLI (`"fhe"` / `"clear"`).
    pub fn backend_name(&self) -> &'static str {
        if self.is_clear() {
            "clear"
        } else {
            "fhe"
        }
    }

    /// Ring/quantization parameters (both backends).
    pub fn params(&self) -> &BgvParams {
        match &self.backend {
            Backend::Fhe(f) => &f.ctx.params,
            Backend::Clear(c) => &c.params,
        }
    }

    /// log2(t) − 8: the fixed-point position the switch quantizes at.
    pub fn frac_bits(&self) -> u32 {
        self.params().t.trailing_zeros() - crate::switch::SWITCH_BITS
    }

    /// Digit-extraction blind-rotation ring degree (both backends).
    pub fn ext_big_n(&self) -> usize {
        match &self.backend {
            Backend::Fhe(f) => f.extract_ck.params.big_n,
            Backend::Clear(c) => c.ext_big_n,
        }
    }

    // ---- counted BGV ops ---------------------------------------------------

    pub fn mult_cc(&self, acc: &mut Ct, other: &Ct) {
        self.counter.bump(&self.counter.mult_cc, 1);
        self.counter.bump(&self.counter.relin, 1);
        match (&self.backend, acc, other) {
            (Backend::Fhe(f), Ct::Fhe(a), Ct::Fhe(b)) => a.mul_assign(b, &f.rlk, &f.ctx),
            (Backend::Clear(_), Ct::Clear(a), Ct::Clear(b)) => a.mul_assign(b),
            _ => panic!("MultCC operands do not match the engine backend"),
        }
    }

    /// MultCP against a frozen weight (cached evaluation form on the FHE
    /// path, a scalar on the clear path). Counted identically to MultCC's
    /// plaintext column.
    pub fn mult_cp_w(&self, acc: &mut Ct, w: &PlainWeight) {
        self.counter.bump(&self.counter.mult_cp, 1);
        match (acc, w) {
            (Ct::Fhe(a), PlainWeight::Fhe(c)) => a.mul_plain_cached_assign(c),
            (Ct::Clear(a), PlainWeight::Clear(v)) => a.scalar_mul_assign(*v),
            (Ct::Clear(a), PlainWeight::ClearPoly(p)) => a.mul_assign(p),
            _ => panic!("MultCP operands do not match the engine backend"),
        }
    }

    /// Build a frozen-weight scalar for this backend (the FHE path pays the
    /// per-level NTT lifts once here).
    pub fn scalar_weight(&self, v: i64) -> PlainWeight {
        match &self.backend {
            Backend::Fhe(f) => PlainWeight::Fhe(Arc::new(CachedPlaintext::scalar(v, &f.ctx))),
            Backend::Clear(_) => PlainWeight::Clear(v),
        }
    }

    /// Build a frozen *polynomial* weight — the packed conv layer's
    /// per-(pixel, block) kernel plaintext, with each tap anchored so the
    /// block product lands on the common payload base. `coeffs` spans the
    /// full ring.
    pub fn poly_weight(&self, coeffs: &[i64]) -> PlainWeight {
        match &self.backend {
            Backend::Fhe(f) => {
                assert_eq!(coeffs.len(), f.ctx.params.n);
                let pt = Plaintext { coeffs: coeffs.to_vec(), t: f.ctx.params.t };
                PlainWeight::Fhe(Arc::new(CachedPlaintext::new(pt, &f.ctx)))
            }
            Backend::Clear(cb) => {
                assert_eq!(coeffs.len(), cb.params.n);
                let mut p = ClearCt::zero(cb.params.n, cb.params.t);
                for (i, &v) in coeffs.iter().enumerate() {
                    if v != 0 {
                        p.set(i, canon(v, cb.params.t));
                    }
                }
                PlainWeight::ClearPoly(Arc::new(p))
            }
        }
    }

    /// MultCP by the monomial `X^exp` — the homomorphic lane shift that
    /// re-packs clean per-scalar ciphertexts into SIMD blocks (pack-on-entry
    /// at a packed FC's input seam). Counted as one MultCP, uniformly
    /// including `exp = 0` so live counters match the packed plan formulas.
    pub fn mult_monomial(&self, acc: &mut Ct, exp: usize) {
        self.counter.bump(&self.counter.mult_cp, 1);
        match (&self.backend, acc) {
            (Backend::Fhe(f), Ct::Fhe(a)) => {
                let params = &f.ctx.params;
                let mut coeffs = vec![0i64; params.n];
                coeffs[exp] = 1;
                a.mul_plain_assign(&Plaintext { coeffs, t: params.t }, &f.ctx);
            }
            (Backend::Clear(cb), Ct::Clear(a)) => {
                let mut m = ClearCt::zero(cb.params.n, cb.params.t);
                m.set(exp, 1);
                a.mul_assign(&m);
            }
            _ => panic!("monomial MultCP operand does not match the engine backend"),
        }
    }

    /// Homomorphically interleave *clean* per-scalar ciphertexts (payload at
    /// coefficients `0..batch`, nothing else — what the activation repack
    /// emits) into packed feature blocks: lane `j` shifts to its feature
    /// anchor `(j mod F)·stride` by a monomial MultCP and accumulates into
    /// its block by AddCC. Counts `cts.len()` MultCP and
    /// `cts.len() − blocks` AddCC — the pack-on-entry cost the packed plan
    /// formulas charge.
    pub fn pack_clean_blocks(&self, cts: &[&Ct], layout: &PackedLayout) -> Vec<Ct> {
        let f = layout.feats_per_ct;
        let mut out: Vec<Ct> = Vec::with_capacity(layout.blocks(cts.len()));
        for (j, ct) in cts.iter().enumerate() {
            let mut shifted = (*ct).clone();
            self.mult_monomial(&mut shifted, (j % f) * layout.stride);
            if j % f == 0 {
                out.push(shifted);
            } else {
                let last = out.last_mut().expect("block accumulator exists");
                self.add_cc(last, &shifted);
            }
        }
        out
    }

    pub fn add_cc(&self, acc: &mut Ct, other: &Ct) {
        self.counter.bump(&self.counter.add_cc, 1);
        match (acc, other) {
            (Ct::Fhe(a), Ct::Fhe(b)) => a.add_assign(b),
            (Ct::Clear(a), Ct::Clear(b)) => a.add_assign(b),
            _ => panic!("AddCC operands do not match the engine backend"),
        }
    }

    pub fn sub_cc(&self, acc: &mut Ct, other: &Ct) {
        self.counter.bump(&self.counter.add_cc, 1);
        match (acc, other) {
            (Ct::Fhe(a), Ct::Fhe(b)) => a.sub_assign(b),
            (Ct::Clear(a), Ct::Clear(b)) => a.sub_assign(b),
            _ => panic!("SubCC operands do not match the engine backend"),
        }
    }

    /// Build a reusable plaintext summand (`value` at every position) —
    /// the FHE path pays its ring-sized plaintext once here, amortized
    /// over every ciphertext it is added to ([`Self::add_plain_v`]).
    pub fn plain_at(&self, value: i64, positions: &[usize]) -> PlainVector {
        match &self.backend {
            Backend::Fhe(f) => {
                let params = &f.ctx.params;
                let mut coeffs = vec![0i64; params.n];
                for &p in positions {
                    coeffs[p] = value;
                }
                PlainVector::Fhe(Plaintext { coeffs, t: params.t })
            }
            Backend::Clear(_) => PlainVector::Clear { value, positions: positions.to_vec() },
        }
    }

    /// Uncounted plaintext add of a prebuilt summand (frozen biases — free
    /// AddCP on both backends).
    pub fn add_plain_v(&self, acc: &mut Ct, pv: &PlainVector) {
        match (acc, pv) {
            (Ct::Fhe(a), PlainVector::Fhe(pt)) => a.add_plain(pt, &self.fhe().ctx),
            (Ct::Clear(a), PlainVector::Clear { value, positions }) => {
                let t = a.t;
                for &p in positions {
                    let cur = a.get(p);
                    a.set(p, (cur + canon(*value, t)) % t);
                }
            }
            _ => panic!("AddCP operands do not match the engine backend"),
        }
    }

    /// One-off [`Self::add_plain_v`] (ad-hoc plaintext summands).
    pub fn add_plain_at(&self, acc: &mut Ct, value: i64, positions: &[usize]) {
        self.add_plain_v(acc, &self.plain_at(value, positions));
    }

    /// Uncounted plaintext add of a frozen *weight* (constant polynomial)
    /// — reuses the evaluation-form cache built at construction, so the
    /// FHE path allocates nothing per call (frozen FC biases).
    pub fn add_plain_w(&self, acc: &mut Ct, w: &PlainWeight) {
        match (acc, w) {
            (Ct::Fhe(a), PlainWeight::Fhe(c)) => a.add_plain(&c.pt, &self.fhe().ctx),
            (Ct::Clear(a), PlainWeight::Clear(v)) => {
                let t = a.t;
                let cur = a.get(0);
                a.set(0, (cur + canon(*v, t)) % t);
            }
            _ => panic!("AddCP operands do not match the engine backend"),
        }
    }

    pub fn mod_switch_to(&self, ct: &mut Ct, level: usize) {
        match ct {
            Ct::Fhe(c) => {
                if c.level > level {
                    self.counter.bump(&self.counter.mod_switch, (c.level - level) as u64);
                    c.mod_switch_to(level, &self.fhe().ctx);
                }
            }
            // the clear mirror has no modulus chain; values are exact
            Ct::Clear(_) => {}
        }
    }

    // ---- the batched MAC engine --------------------------------------------

    /// Run a batch of MAC rows (`rows[j]` = output neuron `j`'s
    /// `Σ_i term_i`) through the backend. On FHE this is the
    /// lazy-relinearization scratch engine fanned across `pool` with one
    /// warm [`crate::bgv::BgvScratch`] per worker; on the clear backend the
    /// rows evaluate inline (plain integer MACs need no fan-out).
    /// Order-preserving: `out[j]` is row `j`'s accumulation.
    ///
    /// Op accounting is identical on both backends and to the per-term
    /// reference loop (one MultCC/MultCP per term, `len−1` AddCC per row),
    /// plus one `relin` per row containing a `Cc` term.
    pub fn mac_rows_on(&self, pool: &GlyphPool, rows: &[Vec<Term>]) -> Vec<Ct> {
        self.mac_rows_inner(Some(pool), rows, usize::MAX)
    }

    /// [`Self::mac_rows_on`] across the global pool.
    pub fn mac_rows_many(&self, rows: &[Vec<Term>]) -> Vec<Ct> {
        self.mac_rows_inner(None, rows, usize::MAX)
    }

    /// [`Self::mac_rows_many`] with at most `limit` concurrent executors
    /// (the Table-5 thread-scaling sweep).
    pub fn mac_rows_limit(&self, rows: &[Vec<Term>], limit: usize) -> Vec<Ct> {
        self.mac_rows_inner(None, rows, limit)
    }

    fn mac_rows_inner(&self, pool: Option<&GlyphPool>, rows: &[Vec<Term>], limit: usize) -> Vec<Ct> {
        let (mut cc, mut cp, mut adds, mut relins) = (0u64, 0u64, 0u64, 0u64);
        for row in rows {
            let c = row.iter().filter(|t| matches!(t, Term::Cc(..))).count() as u64;
            cc += c;
            cp += row.len() as u64 - c;
            adds += row.len().saturating_sub(1) as u64;
            relins += u64::from(c > 0);
        }
        self.counter.bump(&self.counter.mult_cc, cc);
        self.counter.bump(&self.counter.mult_cp, cp);
        self.counter.bump(&self.counter.add_cc, adds);
        self.counter.bump(&self.counter.relin, relins);
        match &self.backend {
            Backend::Fhe(f) => {
                let bgv_rows: Vec<Vec<MacTerm>> = rows
                    .iter()
                    .map(|row| {
                        row.iter()
                            .map(|t| match t {
                                Term::Cc(a, b) => MacTerm::Cc(a.fhe(), b.fhe()),
                                Term::Cp(x, w) => MacTerm::Cp(x.fhe(), w.fhe_cached()),
                            })
                            .collect()
                    })
                    .collect();
                // the closure captures only Sync pieces (key material + rows)
                let rlk = &f.rlk;
                let ctx: &BgvContext = &f.ctx;
                let pool = pool.unwrap_or_else(GlyphPool::global);
                pool.map_limit_with((0..rows.len()).collect(), limit, |j, ws| {
                    mac_row(&mut ws.bgv, &bgv_rows[j], rlk, ctx)
                })
                .into_iter()
                .map(Ct::Fhe)
                .collect()
            }
            Backend::Clear(_) => rows
                .iter()
                .map(|row| {
                    let mut acc: Option<ClearCt> = None;
                    for term in row {
                        let prod = match term {
                            Term::Cc(a, b) => {
                                let mut p = a.clear().clone();
                                p.mul_assign(b.clear());
                                p
                            }
                            Term::Cp(x, w) => {
                                let mut p = x.clear().clone();
                                match w {
                                    PlainWeight::ClearPoly(poly) => p.mul_assign(poly),
                                    w => p.scalar_mul_assign(w.value()),
                                }
                                p
                            }
                        };
                        match &mut acc {
                            None => acc = Some(prod),
                            Some(a) => a.add_assign(&prod),
                        }
                    }
                    Ct::Clear(acc.expect("MAC rows are non-empty"))
                })
                .collect(),
        }
    }

    // ---- counted switch ops ------------------------------------------------

    /// BGV→TFHE: quantize the top 8 bits of each requested coefficient and
    /// deliver the two's-complement bits (MSB first) on the TFHE key.
    /// `pre_shift` scales the value up first so that bit 7 of the delivered
    /// byte is bit `log2(t)−1−pre_shift` of the stored fixed-point value.
    pub fn switch_to_bits(&self, ct: &Ct, positions: &[usize], pre_shift: u32) -> Vec<Vec<Bit>> {
        self.switch_down_many(&[ct], positions, pre_shift)
            .pop()
            .expect("one ciphertext in, one out")
    }

    /// Batched BGV→TFHE: every ciphertext's lanes × bits of a whole layer
    /// boundary cross in ONE pool fan-out on the FHE path, and evaluate
    /// inline on the clear path (`quantize_plain` of the pre-shifted
    /// coefficient, then the two's-complement bit split). Result is
    /// `[ct][lane][bit]`. Op accounting is identical on every path: one
    /// `switch_b2t` per ciphertext, one `extract_lanes` per position,
    /// [`crate::switch::SWITCH_BITS`] `extract_pbs` per lane.
    pub fn switch_down_many(
        &self,
        cts: &[&Ct],
        positions: &[usize],
        pre_shift: u32,
    ) -> Vec<Vec<Vec<Bit>>> {
        let lanes = (cts.len() * positions.len()) as u64;
        self.counter.bump(&self.counter.switch_b2t, cts.len() as u64);
        self.counter.bump(&self.counter.extract_lanes, lanes);
        self.counter.bump(&self.counter.extract_pbs, lanes * crate::switch::SWITCH_BITS as u64);
        match &self.backend {
            Backend::Fhe(f) => {
                let fhe_cts: Vec<&BgvCiphertext> = cts.iter().map(|c| c.fhe()).collect();
                // the pre-shift rides inside the extractor's prepare pass
                // (one clone per ciphertext; exact RNS scalar products, so
                // bit-identical to scaling a separate copy first)
                let raw: Vec<Vec<Vec<LweCiphertext>>> = if self.serial_switch {
                    fhe_cts
                        .iter()
                        .map(|ct| {
                            f.fwd_switch
                                .to_bits_serial(ct, positions, &f.extract_ck, pre_shift)
                                .unwrap_or_else(|e| {
                                    panic!("BGV→TFHE switch rejected its positions: {e}")
                                })
                        })
                        .collect()
                } else {
                    f.fwd_switch
                        .to_bits_many(&fhe_cts, positions, &f.extract_ck, pre_shift)
                        .unwrap_or_else(|e| panic!("BGV→TFHE switch rejected its positions: {e}"))
                };
                raw.into_iter()
                    .map(|ct| {
                        ct.into_iter()
                            .map(|lane| lane.into_iter().map(Bit::Fhe).collect())
                            .collect()
                    })
                    .collect()
            }
            Backend::Clear(cb) => cts
                .iter()
                .map(|ct| {
                    positions
                        .iter()
                        .map(|&p| {
                            assert!(
                                p < cb.params.n,
                                "switch position {p} out of range: the ciphertext has {} \
                                 coefficient slots",
                                cb.params.n
                            );
                            cb.value_bits(cb.quantize(ct.clear().get(p), pre_shift))
                        })
                        .collect()
                })
                .collect(),
        }
    }

    /// TFHE→BGV: pack one recomposed LWE per lane at the given positions and
    /// raise to a fresh BGV ciphertext holding the 8-bit values at scale 1.
    pub fn switch_to_bgv(&self, lanes: &[Bit], positions: &[usize]) -> Ct {
        self.switch_up_many(&[(lanes, positions)]).pop().expect("one group in, one out")
    }

    /// Batched TFHE→BGV. FHE path: every lane group's packing key switch
    /// fans across the pool, the modulus raises run serially in submission
    /// order (deterministic authority RNG draws). Clear path: each lane's
    /// exact phase is read on the 2^24 grid, mirroring the raise. Op
    /// accounting is one `switch_t2b` + one `refresh` per group and one
    /// `repack_lanes` per packed lane on every path.
    pub fn switch_up_many(&self, groups: &[(&[Bit], &[usize])]) -> Vec<Ct> {
        let lanes: u64 = groups.iter().map(|(l, _)| l.len() as u64).sum();
        self.counter.bump(&self.counter.switch_t2b, groups.len() as u64);
        self.counter.bump(&self.counter.refresh, groups.len() as u64);
        self.counter.bump(&self.counter.repack_lanes, lanes);
        match &self.backend {
            Backend::Fhe(f) => {
                // borrow the lanes out of the Bit wrappers — no clones
                let fhe_groups: Vec<(Vec<&LweCiphertext>, &[usize])> = groups
                    .iter()
                    .map(|(lanes, positions)| {
                        (lanes.iter().map(|b| b.fhe()).collect(), *positions)
                    })
                    .collect();
                if self.serial_switch {
                    fhe_groups
                        .iter()
                        .map(|(lanes, positions)| {
                            Ct::Fhe(f.bwd_switch.pack_at_and_raise(lanes, positions, &f.auth))
                        })
                        .collect()
                } else {
                    let refs: Vec<(&[&LweCiphertext], &[usize])> =
                        fhe_groups.iter().map(|(l, p)| (l.as_slice(), *p)).collect();
                    f.bwd_switch
                        .pack_and_raise_many(&refs, &f.auth)
                        .into_iter()
                        .map(Ct::Fhe)
                        .collect()
                }
            }
            Backend::Clear(cb) => groups
                .iter()
                .map(|(lanes, positions)| {
                    let t = cb.params.t;
                    let mut out = ClearCt::zero(cb.params.n, t);
                    for (lane, &p) in lanes.iter().zip(positions.iter()) {
                        out.set(p, canon(cb.raise_value(lane.phase()), t));
                    }
                    Ct::Clear(out)
                })
                .collect(),
        }
    }

    // ---- counted TFHE gates -------------------------------------------------

    pub fn gate_not(&self, c: &Bit) -> Bit {
        // NOT is bootstrap-free (paper Alg. 1); not counted as an Act gate.
        match c {
            Bit::Fhe(c) => Bit::Fhe(self.fhe().gate_ck.not(c)),
            Bit::Clear(p) => Bit::Clear(p.wrapping_neg()),
        }
    }

    pub fn gate_and(&self, a: &Bit, b: &Bit) -> Bit {
        self.counter.bump(&self.counter.act_gates, 1);
        match (a, b) {
            (Bit::Fhe(a), Bit::Fhe(b)) => Bit::Fhe(self.fhe().gate_ck.and(a, b)),
            (Bit::Clear(a), Bit::Clear(b)) => {
                Bit::Clear(ClearBackend::and_phase(*a, *b, crate::tfhe::MU_BIT))
            }
            _ => panic!("AND operands do not match the engine backend"),
        }
    }

    pub fn gate_and_weighted(&self, a: &Bit, b: &Bit, pos: u32) -> Bit {
        self.counter.bump(&self.counter.act_gates, 1);
        match (a, b) {
            (Bit::Fhe(a), Bit::Fhe(b)) => Bit::Fhe(self.fhe().gate_ck.and_weighted_raw(a, b, pos)),
            (Bit::Clear(a), Bit::Clear(b)) => {
                Bit::Clear(ClearBackend::and_weighted_phase(*a, *b, pos))
            }
            _ => panic!("weighted-AND operands do not match the engine backend"),
        }
    }

    /// Batched [`Self::gate_and_weighted`]: every `(a, b, pos)` job is one
    /// gate bootstrap. FHE fans across the global `GlyphPool`; the clear
    /// path evaluates inline. The activation layers push all lanes × bits
    /// of a tensor through this at once.
    pub fn gate_and_weighted_many(&self, jobs: &[(&Bit, &Bit, u32)]) -> Vec<Bit> {
        self.counter.bump(&self.counter.act_gates, jobs.len() as u64);
        match &self.backend {
            Backend::Fhe(f) => {
                let fhe_jobs: Vec<(&LweCiphertext, &LweCiphertext, u32)> =
                    jobs.iter().map(|(a, b, p)| (a.fhe(), b.fhe(), *p)).collect();
                f.gate_ck.and_weighted_raw_many(&fhe_jobs).into_iter().map(Bit::Fhe).collect()
            }
            Backend::Clear(_) => jobs
                .iter()
                .map(|(a, b, p)| Bit::Clear(ClearBackend::and_weighted_phase(a.phase(), b.phase(), *p)))
                .collect(),
        }
    }

    pub fn gate_mux(&self, s: &Bit, d1: &Bit, d0: &Bit) -> Bit {
        self.counter.bump(&self.counter.act_gates, 2); // 2 bootstraps on the critical path
        match (s, d1, d0) {
            (Bit::Fhe(s), Bit::Fhe(d1), Bit::Fhe(d0)) => Bit::Fhe(self.fhe().gate_ck.mux(s, d1, d0)),
            (Bit::Clear(s), Bit::Clear(d1), Bit::Clear(d0)) => {
                Bit::Clear(ClearBackend::mux_phase(*s, *d1, *d0))
            }
            _ => panic!("MUX operands do not match the engine backend"),
        }
    }

    /// A trivial (noiseless) gate-encoded boolean on this backend — the
    /// constant-TRUE operand of identity recompositions.
    pub fn trivial_bit(&self, b: bool) -> Bit {
        let mu = crate::tfhe::encode_bit(b);
        match &self.backend {
            Backend::Fhe(f) => Bit::Fhe(LweCiphertext::trivial(mu, f.gate_ck.params.n)),
            Backend::Clear(_) => Bit::Clear(mu),
        }
    }

    /// A trivial zero in the weighted (recomposed, extracted-key) domain.
    pub fn trivial_weighted_zero(&self) -> Bit {
        match &self.backend {
            Backend::Fhe(f) => Bit::Fhe(LweCiphertext::trivial(0, f.gate_ck.params.big_n)),
            Backend::Clear(_) => Bit::Clear(0),
        }
    }

    /// Dimension of LWEs under the gate ring's extracted key (the
    /// recomposition domain consumed by the packing switch). FHE backend
    /// only.
    pub fn gate_ext_dim(&self) -> usize {
        self.fhe().gate_ck.params.big_n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn setup_and_roundtrip() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 4, 42);
        let vals = vec![1i64, -2, 3, -4];
        let ct = client.encrypt_batch(&vals, 0);
        assert_eq!(client.decrypt_batch(&ct, 4, 0), vals);
        assert_eq!(engine.counter.snapshot().hop(), 0);
        assert_eq!(engine.backend_name(), "fhe");
    }

    #[test]
    fn clear_setup_and_roundtrip() {
        let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 4);
        let vals = vec![1i64, -2, 3, -4];
        let ct = codec.encrypt_batch(&vals, 2);
        assert_eq!(codec.decrypt_batch(&ct, 4, 2), vals);
        assert_eq!(engine.backend_name(), "clear");
        assert_eq!(engine.frac_bits(), 8);
    }

    #[test]
    fn counted_mac() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 43);
        let mut w = client.encrypt_scalar(3);
        let x = client.encrypt_batch(&[5, -5], 0);
        engine.mult_cc(&mut w, &x);
        let y = client.encrypt_batch(&[1, 1], 0);
        engine.add_cc(&mut w, &y);
        assert_eq!(client.decrypt_batch(&w, 2, 0), vec![16, -14]);
        let s = engine.counter.snapshot();
        assert_eq!((s.mult_cc, s.add_cc), (1, 1));
    }

    #[test]
    fn clear_counted_mac_mirrors_fhe() {
        let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
        let mut w = codec.encrypt_scalar(3);
        let x = codec.encrypt_batch(&[5, -5], 0);
        engine.mult_cc(&mut w, &x);
        let y = codec.encrypt_batch(&[1, 1], 0);
        engine.add_cc(&mut w, &y);
        assert_eq!(codec.decrypt_batch(&w, 2, 0), vec![16, -14]);
        let s = engine.counter.snapshot();
        assert_eq!((s.mult_cc, s.add_cc, s.relin), (1, 1, 1));
    }

    #[test]
    fn mac_rows_on_a_small_pool_preserves_submission_order() {
        // More rows than pool workers: results must come back in
        // submission order regardless of which worker ran which row.
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 45);
        let n_rows = 9usize;
        let ws: Vec<_> = (0..n_rows).map(|i| client.encrypt_scalar(i as i64 - 4)).collect();
        let xs: Vec<_> =
            (0..n_rows).map(|i| client.encrypt_batch(&[i as i64 + 1, -(i as i64)], 0)).collect();
        let rows: Vec<Vec<Term>> = (0..n_rows).map(|i| vec![Term::Cc(&ws[i], &xs[i])]).collect();
        let pool = GlyphPool::new(2);
        let out = engine.mac_rows_on(&pool, &rows);
        assert_eq!(out.len(), n_rows);
        for i in 0..n_rows {
            let w = i as i64 - 4;
            let want = vec![w * (i as i64 + 1), w * -(i as i64)];
            assert_eq!(client.decrypt_batch(&out[i], 2, 0), want, "row {i}");
        }
    }

    #[test]
    fn mac_rows_propagates_worker_panics_and_pool_survives() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 46);
        let good_w = client.encrypt_scalar(2);
        let good_x = client.encrypt_batch(&[1, 2], 0);
        let mut low = client.encrypt_batch(&[3, 4], 0);
        // level-mismatched operand: the bad row panics (in release mode via
        // the limb index, in debug via the level assert)
        low.fhe_mut().mod_switch_down(&engine.fhe().ctx);
        let pool = GlyphPool::new(2);
        let rows: Vec<Vec<Term>> = (0..6)
            .map(|i| {
                if i == 3 {
                    vec![Term::Cc(&good_w, &low)]
                } else {
                    vec![Term::Cc(&good_w, &good_x)]
                }
            })
            .collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.mac_rows_on(&pool, &rows)
        }));
        assert!(result.is_err(), "a level-mismatched row must panic through the pool");
        // the pool must still serve subsequent batches
        let out = engine.mac_rows_on(&pool, &rows[..1]);
        assert_eq!(client.decrypt_batch(&out[0], 2, 0), vec![2, 4]);
    }

    #[test]
    fn lazy_rows_count_one_relin_per_row() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 47);
        let ws: Vec<_> = (0..5).map(|i| client.encrypt_scalar(i as i64)).collect();
        let x = client.encrypt_batch(&[1, -1], 0);
        let row: Vec<Term> = ws.iter().map(|w| Term::Cc(w, &x)).collect();
        let before = engine.counter.snapshot();
        let _ = engine.mac_rows_many(&[row]);
        let lazy = engine.counter.snapshot().since(&before);
        assert_eq!((lazy.mult_cc, lazy.add_cc, lazy.relin), (5, 4, 1));
        // the per-term reference path pays one relin per MultCC
        let before = engine.counter.snapshot();
        for w in &ws {
            let mut t = w.clone();
            engine.mult_cc(&mut t, &x);
        }
        let reference = engine.counter.snapshot().since(&before);
        assert_eq!((reference.mult_cc, reference.relin), (5, 5));
    }

    #[test]
    fn engine_switch_quantizes_with_pre_shift() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 3, 44);
        // values stored at shift 4; deliver bits of v by pre-shifting the
        // remaining (frac − 4) bits.
        let vals = vec![9i64, -14, 100];
        let ct = client.encrypt_batch(&vals, 4);
        let pre = engine.frac_bits() - 4;
        let bits = engine.switch_to_bits(&ct, &[0, 1, 2], pre);
        // recompose through weighted ANDs with TRUE (identity) and return
        let truth = engine.trivial_bit(true);
        let lanes: Vec<Bit> = bits
            .iter()
            .map(|lane_bits| {
                let mut acc: Option<Bit> = None;
                for (i, b) in lane_bits.iter().enumerate() {
                    let w = engine.gate_and_weighted(b, &truth, crate::switch::extract::bit_position(i));
                    match &mut acc {
                        None => acc = Some(w),
                        Some(a) => a.add_assign(&w),
                    }
                }
                acc.unwrap()
            })
            .collect();
        let out = engine.switch_to_bgv(&lanes, &[0, 1, 2]);
        assert_eq!(client.decrypt_batch(&out, 3, 0), vals);
        let s = engine.counter.snapshot();
        assert_eq!(s.switch_b2t, 1);
        assert_eq!(s.switch_t2b, 1);
        assert_eq!(s.extract_pbs, 24);
        assert_eq!(s.act_gates, 24);
        assert_eq!(s.refresh, 1);
        assert_eq!(s.extract_lanes, 3);
        assert_eq!(s.repack_lanes, 3);
    }

    #[test]
    fn clear_switch_round_trip_and_counters_match_fhe_shape() {
        // the clear mirror of the test above: identical values, identical
        // counter deltas, identical results — no key material involved.
        let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 3);
        let vals = vec![9i64, -14, 100];
        let ct = codec.encrypt_batch(&vals, 4);
        let pre = engine.frac_bits() - 4;
        let bits = engine.switch_to_bits(&ct, &[0, 1, 2], pre);
        let truth = engine.trivial_bit(true);
        let lanes: Vec<Bit> = bits
            .iter()
            .map(|lane_bits| {
                let mut acc: Option<Bit> = None;
                for (i, b) in lane_bits.iter().enumerate() {
                    let w = engine.gate_and_weighted(b, &truth, crate::switch::extract::bit_position(i));
                    match &mut acc {
                        None => acc = Some(w),
                        Some(a) => a.add_assign(&w),
                    }
                }
                acc.unwrap()
            })
            .collect();
        let out = engine.switch_to_bgv(&lanes, &[0, 1, 2]);
        assert_eq!(codec.decrypt_batch(&out, 3, 0), vals);
        let s = engine.counter.snapshot();
        assert_eq!(
            (s.switch_b2t, s.switch_t2b, s.extract_pbs, s.act_gates, s.refresh),
            (1, 1, 24, 24, 1)
        );
        assert_eq!((s.extract_lanes, s.repack_lanes), (3, 3));
    }

    #[test]
    fn clean_pack_interleaves_a_block_on_both_backends() {
        use crate::nn::tensor::PackOrder;
        // batch 2 → stride 4; two features share one block. Clean per-scalar
        // cts (batch at coeffs 0..2) interleave to feature anchors 0 and 4.
        let (engine, mut codec) = GlyphEngine::setup_clear_packed(EngineProfile::Test, 2);
        let layout = engine.packed_layout().unwrap().clone();
        assert_eq!(layout.stride, 4);
        let a = codec.encrypt_batch(&[5, -6], 0);
        let b = codec.encrypt_batch(&[7, 8], 0);
        let blocks = engine.pack_clean_blocks(&[&a, &b], &layout);
        assert_eq!(blocks.len(), 1);
        let pos = layout.block_positions(PackOrder::Forward, 2);
        assert_eq!(codec.decrypt_positions(&blocks[0], &pos, 0), vec![5, -6, 7, 8]);
        let s = engine.counter.snapshot();
        assert_eq!((s.mult_cp, s.add_cc), (2, 1), "in MultCP + (in − blocks) AddCC");

        // FHE mirror: identical payload through real monomial MultCPs.
        let (engine, mut client) = GlyphEngine::setup_packed(EngineProfile::Test, 2, 49);
        let a = client.encrypt_batch(&[5, -6], 0);
        let b = client.encrypt_batch(&[7, 8], 0);
        let blocks = engine.pack_clean_blocks(&[&a, &b], &layout);
        assert_eq!(client.decrypt_positions(&blocks[0], &pos, 0), vec![5, -6, 7, 8]);
        let s = engine.counter.snapshot();
        assert_eq!((s.mult_cp, s.add_cc), (2, 1));
    }

    #[test]
    fn coeff_codec_roundtrips_packed_blocks() {
        use crate::nn::tensor::PackOrder;
        let (engine, mut client) = GlyphEngine::setup_packed(EngineProfile::Test, 3, 50);
        let layout = engine.packed_layout().unwrap().clone();
        let cols = vec![vec![1, -2, 3], vec![-4, 5, -6]];
        let blocks = layout.pack_columns(&cols, engine.params().n);
        let ct = client.encrypt_coeffs(&blocks[0], 2);
        let pos = layout.block_positions(PackOrder::Forward, 2);
        assert_eq!(client.decrypt_positions(&ct, &pos, 2), vec![1, -2, 3, -4, 5, -6]);
    }

    #[test]
    fn batched_switch_counts_like_the_serial_reference() {
        // switch_down_many/switch_up_many must account exactly like the
        // equivalent per-ciphertext serial calls, on both execution paths.
        let (mut engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 48);
        let a = client.encrypt_batch(&[1, -1], 0);
        let b = client.encrypt_batch(&[2, -2], 0);
        for serial in [false, true] {
            engine.serial_switch = serial;
            let before = engine.counter.snapshot();
            let bits = engine.switch_down_many(&[&a, &b], &[0, 1], engine.frac_bits());
            assert_eq!(bits.len(), 2);
            assert_eq!(bits[0].len(), 2);
            assert_eq!(bits[0][0].len(), 8);
            let d = engine.counter.snapshot().since(&before);
            assert_eq!(
                (d.switch_b2t, d.extract_lanes, d.extract_pbs),
                (2, 4, 32),
                "serial={serial}"
            );
            let lanes0 = vec![engine.trivial_weighted_zero(); 2];
            let lanes1 = vec![engine.trivial_weighted_zero(); 3];
            let p0 = [0usize, 1];
            let p1 = [0usize, 1, 2];
            let before = engine.counter.snapshot();
            let out = engine.switch_up_many(&[(&lanes0[..], &p0[..]), (&lanes1[..], &p1[..])]);
            assert_eq!(out.len(), 2);
            let d = engine.counter.snapshot().since(&before);
            assert_eq!((d.switch_t2b, d.refresh, d.repack_lanes), (2, 2, 5), "serial={serial}");
        }
    }
}
