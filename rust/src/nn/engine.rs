//! `GlyphEngine`: the evaluator-side bundle of key material, parameters and
//! HOP counters that every encrypted layer operates through.
//!
//! The client keeps [`ClientKeys`] (the BGV secret); the engine holds only
//! evaluation material (relinearization key, bootstrapping keys, switching
//! keys) plus the refresh authority handle (the documented bootstrapping
//! substitute, DESIGN.md §5).

use crate::bgv::{
    mac_row, BgvCiphertext, BgvContext, BgvParams, BgvSecretKey, CachedPlaintext, KeyAuthority,
    MacTerm, Plaintext, RelinKey,
};
use crate::coordinator::executor::GlyphPool;
use crate::coordinator::metrics::OpCounter;
use crate::math::rng::GlyphRng;
use crate::switch::{LweExtractor, Repacker};
use crate::tfhe::{LweCiphertext, LweKey, TfheCloudKey, TfheParams, TrlweKey};
use std::sync::Arc;

/// Client-side secret material.
pub struct ClientKeys {
    pub bgv_sk: Arc<BgvSecretKey>,
    pub rng: GlyphRng,
}

impl ClientKeys {
    /// Encrypt a batch of 8-bit values at fixed-point scale `shift`
    /// (value v is stored as v·2^shift in the plaintext ring).
    pub fn encrypt_batch(&mut self, values: &[i64], shift: u32) -> BgvCiphertext {
        let scaled: Vec<i64> = values.iter().map(|&v| v << shift).collect();
        let pt = Plaintext::encode_batch(&scaled, &self.bgv_sk.ctx.params);
        self.bgv_sk.encrypt(&pt, &mut self.rng)
    }

    /// Encrypt a single weight scalar as a constant polynomial.
    pub fn encrypt_scalar(&mut self, w: i64) -> BgvCiphertext {
        let pt = Plaintext::encode_scalar(w, &self.bgv_sk.ctx.params);
        self.bgv_sk.encrypt(&pt, &mut self.rng)
    }

    /// Decrypt a batch (optionally un-scaling by `shift`).
    pub fn decrypt_batch(&self, ct: &BgvCiphertext, lanes: usize, shift: u32) -> Vec<i64> {
        self.bgv_sk
            .decrypt(ct)
            .decode_batch(lanes)
            .into_iter()
            .map(|v| v >> shift)
            .collect()
    }
}

/// Evaluator-side engine.
pub struct GlyphEngine {
    pub ctx: Arc<BgvContext>,
    pub rlk: RelinKey,
    pub gate_ck: TfheCloudKey,
    pub extract_ck: TfheCloudKey,
    pub fwd_switch: LweExtractor,
    pub bwd_switch: Repacker,
    pub auth: Arc<KeyAuthority>,
    pub counter: OpCounter,
    /// Mini-batch width (≤ N).
    pub batch: usize,
    /// Run the scheme switch on the retained per-lane serial reference path
    /// instead of the batched scratch engine (bit-identical results — the
    /// contract `tests/train_step_golden.rs` locks). Default: batched.
    pub serial_switch: bool,
}

/// Which parameter scale to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineProfile {
    /// Production-shaped parameters (paper §5.1).
    Default,
    /// Reduced test/demo parameters.
    Test,
}

impl GlyphEngine {
    /// Generate all key material. Returns the engine (evaluator side) and
    /// the client keys.
    pub fn setup(profile: EngineProfile, batch: usize, seed: u64) -> (GlyphEngine, ClientKeys) {
        let (bgv_params, gate_params, ext_params) = match profile {
            EngineProfile::Default => (
                BgvParams::mac_params(),
                TfheParams::default_params(),
                TfheParams::extract_params(),
            ),
            EngineProfile::Test => (
                BgvParams::test_params(),
                TfheParams::test_params(),
                TfheParams::test_extract_params(),
            ),
        };
        assert!(batch <= bgv_params.n);
        let ctx = BgvContext::new(bgv_params);
        let mut rng = GlyphRng::new(seed);
        let bgv_sk = Arc::new(BgvSecretKey::generate(&ctx, &mut rng));
        let rlk = RelinKey::generate(&bgv_sk, &mut rng);
        let lwe_key = LweKey::generate_binary(gate_params.n, &mut rng);
        let gate_ring = TrlweKey::generate(gate_params.big_n, &mut rng);
        let gate_ck = TfheCloudKey::generate(&lwe_key, &gate_ring, &gate_params, &mut rng);
        let ext_ring = TrlweKey::generate(ext_params.big_n, &mut rng);
        let extract_ck = TfheCloudKey::generate(&lwe_key, &ext_ring, &ext_params, &mut rng);
        let fwd_switch = LweExtractor::generate(&bgv_sk, &lwe_key, &ext_params, &mut rng);
        let bwd_switch = Repacker::generate(&gate_ring, &bgv_sk, &mut rng);
        let auth = KeyAuthority::new(bgv_sk.clone(), GlyphRng::new(seed ^ 0x5eed));
        let engine = GlyphEngine {
            ctx,
            rlk,
            gate_ck,
            extract_ck,
            fwd_switch,
            bwd_switch,
            auth,
            counter: OpCounter::default(),
            batch,
            serial_switch: false,
        };
        let client = ClientKeys { bgv_sk, rng: GlyphRng::new(seed ^ 0xc11e) };
        (engine, client)
    }

    /// log2(t) − 8: the fixed-point position the switch quantizes at.
    pub fn frac_bits(&self) -> u32 {
        self.ctx.params.t.trailing_zeros() - crate::switch::SWITCH_BITS
    }

    // ---- counted BGV ops ---------------------------------------------------

    pub fn mult_cc(&self, acc: &mut BgvCiphertext, other: &BgvCiphertext) {
        self.counter.bump(&self.counter.mult_cc, 1);
        self.counter.bump(&self.counter.relin, 1);
        acc.mul_assign(other, &self.rlk, &self.ctx);
    }

    // ---- the batched MAC engine --------------------------------------------

    /// Run a batch of MAC rows (`rows[j]` = output neuron `j`'s
    /// `Σ_i term_i`) through the lazy-relinearization scratch engine,
    /// fanned across `pool` with one warm [`crate::bgv::BgvScratch`] per
    /// worker. Order-preserving: `out[j]` is row `j`'s accumulation, and a
    /// panicking row propagates to the caller.
    ///
    /// Op accounting is identical to the per-term reference loop (one
    /// MultCC/MultCP per term, `len−1` AddCC per row), plus one `relin` per
    /// row containing a `Cc` term — versus one per `Cc` term on the
    /// reference path, the `≥ in_dim/2` saving `benches/bgv_mac.rs` records.
    pub fn mac_rows_on(&self, pool: &GlyphPool, rows: &[Vec<MacTerm>]) -> Vec<BgvCiphertext> {
        self.mac_rows_inner(pool, rows, usize::MAX)
    }

    /// [`Self::mac_rows_on`] across the global pool.
    pub fn mac_rows_many(&self, rows: &[Vec<MacTerm>]) -> Vec<BgvCiphertext> {
        self.mac_rows_inner(GlyphPool::global(), rows, usize::MAX)
    }

    /// [`Self::mac_rows_many`] with at most `limit` concurrent executors
    /// (the Table-5 thread-scaling sweep).
    pub fn mac_rows_limit(&self, rows: &[Vec<MacTerm>], limit: usize) -> Vec<BgvCiphertext> {
        self.mac_rows_inner(GlyphPool::global(), rows, limit)
    }

    fn mac_rows_inner(
        &self,
        pool: &GlyphPool,
        rows: &[Vec<MacTerm>],
        limit: usize,
    ) -> Vec<BgvCiphertext> {
        let (mut cc, mut cp, mut adds, mut relins) = (0u64, 0u64, 0u64, 0u64);
        for row in rows {
            let c = row.iter().filter(|t| matches!(t, MacTerm::Cc(..))).count() as u64;
            cc += c;
            cp += row.len() as u64 - c;
            adds += row.len().saturating_sub(1) as u64;
            relins += u64::from(c > 0);
        }
        self.counter.bump(&self.counter.mult_cc, cc);
        self.counter.bump(&self.counter.mult_cp, cp);
        self.counter.bump(&self.counter.add_cc, adds);
        self.counter.bump(&self.counter.relin, relins);
        // the closure captures only Sync pieces (key material + rows)
        let rlk = &self.rlk;
        let ctx: &BgvContext = &self.ctx;
        pool.map_limit_with((0..rows.len()).collect(), limit, |j, ws| {
            mac_row(&mut ws.bgv, &rows[j], rlk, ctx)
        })
    }

    pub fn mult_cp(&self, acc: &mut BgvCiphertext, pt: &Plaintext) {
        self.counter.bump(&self.counter.mult_cp, 1);
        acc.mul_plain_assign(pt, &self.ctx);
    }

    /// MultCP against a cached evaluation-form weight (counted identically
    /// to [`Self::mult_cp`]; pure pointwise, no per-call NTT).
    pub fn mult_cp_cached(&self, acc: &mut BgvCiphertext, w: &CachedPlaintext) {
        self.counter.bump(&self.counter.mult_cp, 1);
        acc.mul_plain_cached_assign(w);
    }

    pub fn add_cc(&self, acc: &mut BgvCiphertext, other: &BgvCiphertext) {
        self.counter.bump(&self.counter.add_cc, 1);
        acc.add_assign(other);
    }

    pub fn sub_cc(&self, acc: &mut BgvCiphertext, other: &BgvCiphertext) {
        self.counter.bump(&self.counter.add_cc, 1);
        acc.sub_assign(other);
    }

    pub fn mod_switch_to(&self, ct: &mut BgvCiphertext, level: usize) {
        if ct.level > level {
            self.counter.bump(&self.counter.mod_switch, (ct.level - level) as u64);
            ct.mod_switch_to(level, &self.ctx);
        }
    }

    // ---- counted switch ops ------------------------------------------------

    /// BGV→TFHE: quantize the top 8 bits of each requested coefficient and
    /// deliver the two's-complement bits (MSB first) on the TFHE key.
    /// `pre_shift` scales the value up first so that bit 7 of the delivered
    /// byte is bit `log2(t)−1−pre_shift` of the stored fixed-point value.
    pub fn switch_to_bits(
        &self,
        ct: &BgvCiphertext,
        positions: &[usize],
        pre_shift: u32,
    ) -> Vec<Vec<LweCiphertext>> {
        self.switch_down_many(&[ct], positions, pre_shift)
            .pop()
            .expect("one ciphertext in, one out")
    }

    /// Batched BGV→TFHE: every ciphertext's lanes × bits of a whole layer
    /// boundary cross in ONE pool fan-out (the per-worker `SwitchScratch`
    /// extract path + one `pbs_many` digit extraction). Result is
    /// `[ct][lane][bit]`, bit-identical to per-ciphertext
    /// [`Self::switch_to_bits`] calls and to the retained serial reference
    /// (`serial_switch = true`). Op accounting is identical on every path:
    /// one `switch_b2t` per ciphertext, one `extract_lanes` per position,
    /// [`crate::switch::SWITCH_BITS`] `extract_pbs` per lane.
    pub fn switch_down_many(
        &self,
        cts: &[&BgvCiphertext],
        positions: &[usize],
        pre_shift: u32,
    ) -> Vec<Vec<Vec<LweCiphertext>>> {
        let lanes = (cts.len() * positions.len()) as u64;
        self.counter.bump(&self.counter.switch_b2t, cts.len() as u64);
        self.counter.bump(&self.counter.extract_lanes, lanes);
        self.counter.bump(&self.counter.extract_pbs, lanes * crate::switch::SWITCH_BITS as u64);
        // the pre-shift rides inside the extractor's prepare pass (one clone
        // per ciphertext; exact RNS scalar products, so bit-identical to
        // scaling a separate copy first)
        if self.serial_switch {
            cts.iter()
                .map(|ct| {
                    self.fwd_switch
                        .to_bits_serial(ct, positions, &self.extract_ck, pre_shift)
                        .unwrap_or_else(|e| panic!("BGV→TFHE switch rejected its positions: {e}"))
                })
                .collect()
        } else {
            self.fwd_switch
                .to_bits_many(cts, positions, &self.extract_ck, pre_shift)
                .unwrap_or_else(|e| panic!("BGV→TFHE switch rejected its positions: {e}"))
        }
    }

    /// TFHE→BGV: pack one recomposed LWE per lane at the given positions and
    /// raise to a fresh BGV ciphertext holding the 8-bit values at scale 1.
    pub fn switch_to_bgv(&self, lanes: &[LweCiphertext], positions: &[usize]) -> BgvCiphertext {
        self.switch_up_many(&[(lanes, positions)]).pop().expect("one group in, one out")
    }

    /// Batched TFHE→BGV: every lane group's packing key switch fans across
    /// the pool (per-worker `RepackScratch`), the modulus raises run
    /// serially in submission order (deterministic authority RNG draws).
    /// Bit-identical to per-group [`Self::switch_to_bgv`] calls; op
    /// accounting is one `switch_t2b` + one `refresh` per group and one
    /// `repack_lanes` per packed LWE on every path.
    pub fn switch_up_many(
        &self,
        groups: &[(&[LweCiphertext], &[usize])],
    ) -> Vec<BgvCiphertext> {
        let lanes: u64 = groups.iter().map(|(l, _)| l.len() as u64).sum();
        self.counter.bump(&self.counter.switch_t2b, groups.len() as u64);
        self.counter.bump(&self.counter.refresh, groups.len() as u64);
        self.counter.bump(&self.counter.repack_lanes, lanes);
        if self.serial_switch {
            groups
                .iter()
                .map(|(lanes, positions)| {
                    self.bwd_switch.pack_at_and_raise(lanes, positions, &self.auth)
                })
                .collect()
        } else {
            self.bwd_switch.pack_and_raise_many(groups, &self.auth)
        }
    }

    // ---- counted TFHE gates -------------------------------------------------

    pub fn gate_not(&self, c: &LweCiphertext) -> LweCiphertext {
        // NOT is bootstrap-free (paper Alg. 1); not counted as an Act gate.
        self.gate_ck.not(c)
    }

    pub fn gate_and(&self, a: &LweCiphertext, b: &LweCiphertext) -> LweCiphertext {
        self.counter.bump(&self.counter.act_gates, 1);
        self.gate_ck.and(a, b)
    }

    pub fn gate_and_weighted(&self, a: &LweCiphertext, b: &LweCiphertext, pos: u32) -> LweCiphertext {
        self.counter.bump(&self.counter.act_gates, 1);
        self.gate_ck.and_weighted_raw(a, b, pos)
    }

    /// Batched [`Self::gate_and_weighted`]: every `(a, b, pos)` job is one
    /// gate bootstrap, fanned across the global `GlyphPool` (order-
    /// preserving, same ciphertexts as the sequential loop). The activation
    /// layers push all lanes × bits of a tensor through this at once.
    pub fn gate_and_weighted_many(
        &self,
        jobs: &[(&LweCiphertext, &LweCiphertext, u32)],
    ) -> Vec<LweCiphertext> {
        self.counter.bump(&self.counter.act_gates, jobs.len() as u64);
        self.gate_ck.and_weighted_raw_many(jobs)
    }

    pub fn gate_mux(&self, s: &LweCiphertext, d1: &LweCiphertext, d0: &LweCiphertext) -> LweCiphertext {
        self.counter.bump(&self.counter.act_gates, 2); // 2 bootstraps on the critical path
        self.gate_ck.mux(s, d1, d0)
    }

    /// Dimension of LWEs under the gate ring's extracted key (the
    /// recomposition domain consumed by the packing switch).
    pub fn gate_ext_dim(&self) -> usize {
        self.gate_ck.params.big_n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn setup_and_roundtrip() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 4, 42);
        let vals = vec![1i64, -2, 3, -4];
        let ct = client.encrypt_batch(&vals, 0);
        assert_eq!(client.decrypt_batch(&ct, 4, 0), vals);
        assert_eq!(engine.counter.snapshot().hop(), 0);
    }

    #[test]
    fn counted_mac() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 43);
        let mut w = client.encrypt_scalar(3);
        let x = client.encrypt_batch(&[5, -5], 0);
        engine.mult_cc(&mut w, &x);
        let y = client.encrypt_batch(&[1, 1], 0);
        engine.add_cc(&mut w, &y);
        assert_eq!(client.decrypt_batch(&w, 2, 0), vec![16, -14]);
        let s = engine.counter.snapshot();
        assert_eq!((s.mult_cc, s.add_cc), (1, 1));
    }

    #[test]
    fn mac_rows_on_a_small_pool_preserves_submission_order() {
        // More rows than pool workers: results must come back in
        // submission order regardless of which worker ran which row.
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 45);
        let n_rows = 9usize;
        let ws: Vec<_> = (0..n_rows).map(|i| client.encrypt_scalar(i as i64 - 4)).collect();
        let xs: Vec<_> =
            (0..n_rows).map(|i| client.encrypt_batch(&[i as i64 + 1, -(i as i64)], 0)).collect();
        let rows: Vec<Vec<MacTerm>> =
            (0..n_rows).map(|i| vec![MacTerm::Cc(&ws[i], &xs[i])]).collect();
        let pool = GlyphPool::new(2);
        let out = engine.mac_rows_on(&pool, &rows);
        assert_eq!(out.len(), n_rows);
        for i in 0..n_rows {
            let w = i as i64 - 4;
            let want = vec![w * (i as i64 + 1), w * -(i as i64)];
            assert_eq!(client.decrypt_batch(&out[i], 2, 0), want, "row {i}");
        }
    }

    #[test]
    fn mac_rows_propagates_worker_panics_and_pool_survives() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 46);
        let good_w = client.encrypt_scalar(2);
        let good_x = client.encrypt_batch(&[1, 2], 0);
        let mut low = client.encrypt_batch(&[3, 4], 0);
        // level-mismatched operand: the bad row panics (in release mode via
        // the limb index, in debug via the level assert)
        low.mod_switch_down(&engine.ctx);
        let pool = GlyphPool::new(2);
        let rows: Vec<Vec<MacTerm>> = (0..6)
            .map(|i| {
                if i == 3 {
                    vec![MacTerm::Cc(&good_w, &low)]
                } else {
                    vec![MacTerm::Cc(&good_w, &good_x)]
                }
            })
            .collect();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.mac_rows_on(&pool, &rows)
        }));
        assert!(result.is_err(), "a level-mismatched row must panic through the pool");
        // the pool must still serve subsequent batches
        let out = engine.mac_rows_on(&pool, &rows[..1]);
        assert_eq!(client.decrypt_batch(&out[0], 2, 0), vec![2, 4]);
    }

    #[test]
    fn lazy_rows_count_one_relin_per_row() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 47);
        let ws: Vec<_> = (0..5).map(|i| client.encrypt_scalar(i as i64)).collect();
        let x = client.encrypt_batch(&[1, -1], 0);
        let row: Vec<MacTerm> = ws.iter().map(|w| MacTerm::Cc(w, &x)).collect();
        let before = engine.counter.snapshot();
        let _ = engine.mac_rows_many(&[row]);
        let lazy = engine.counter.snapshot().since(&before);
        assert_eq!((lazy.mult_cc, lazy.add_cc, lazy.relin), (5, 4, 1));
        // the per-term reference path pays one relin per MultCC
        let before = engine.counter.snapshot();
        for w in &ws {
            let mut t = w.clone();
            engine.mult_cc(&mut t, &x);
        }
        let reference = engine.counter.snapshot().since(&before);
        assert_eq!((reference.mult_cc, reference.relin), (5, 5));
    }

    #[test]
    fn engine_switch_quantizes_with_pre_shift() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 3, 44);
        // values stored at shift 4; deliver bits of v by pre-shifting the
        // remaining (frac − 4) bits.
        let vals = vec![9i64, -14, 100];
        let ct = client.encrypt_batch(&vals, 4);
        let pre = engine.frac_bits() - 4;
        let bits = engine.switch_to_bits(&ct, &[0, 1, 2], pre);
        // recompose through weighted ANDs with TRUE (identity) and return
        let truth = crate::tfhe::LweCiphertext::trivial(
            crate::tfhe::encode_bit(true),
            engine.gate_ck.params.n,
        );
        let lanes: Vec<LweCiphertext> = bits
            .iter()
            .map(|lane_bits| {
                let mut acc: Option<LweCiphertext> = None;
                for (i, b) in lane_bits.iter().enumerate() {
                    let w = engine.gate_and_weighted(b, &truth, crate::switch::extract::bit_position(i));
                    match &mut acc {
                        None => acc = Some(w),
                        Some(a) => a.add_assign(&w),
                    }
                }
                acc.unwrap()
            })
            .collect();
        let out = engine.switch_to_bgv(&lanes, &[0, 1, 2]);
        assert_eq!(client.decrypt_batch(&out, 3, 0), vals);
        let s = engine.counter.snapshot();
        assert_eq!(s.switch_b2t, 1);
        assert_eq!(s.switch_t2b, 1);
        assert_eq!(s.extract_pbs, 24);
        assert_eq!(s.act_gates, 24);
        assert_eq!(s.refresh, 1);
        assert_eq!(s.extract_lanes, 3);
        assert_eq!(s.repack_lanes, 3);
    }

    #[test]
    fn batched_switch_counts_like_the_serial_reference() {
        // switch_down_many/switch_up_many must account exactly like the
        // equivalent per-ciphertext serial calls, on both execution paths.
        let (mut engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 48);
        let a = client.encrypt_batch(&[1, -1], 0);
        let b = client.encrypt_batch(&[2, -2], 0);
        for serial in [false, true] {
            engine.serial_switch = serial;
            let before = engine.counter.snapshot();
            let bits = engine.switch_down_many(&[&a, &b], &[0, 1], engine.frac_bits());
            assert_eq!(bits.len(), 2);
            assert_eq!(bits[0].len(), 2);
            assert_eq!(bits[0][0].len(), 8);
            let d = engine.counter.snapshot().since(&before);
            assert_eq!(
                (d.switch_b2t, d.extract_lanes, d.extract_pbs),
                (2, 4, 32),
                "serial={serial}"
            );
            let lanes0 = vec![LweCiphertext::trivial(0, engine.gate_ext_dim()); 2];
            let lanes1 = vec![LweCiphertext::trivial(0, engine.gate_ext_dim()); 3];
            let p0 = [0usize, 1];
            let p1 = [0usize, 1, 2];
            let before = engine.counter.snapshot();
            let out = engine.switch_up_many(&[(&lanes0[..], &p0[..]), (&lanes1[..], &p1[..])]);
            assert_eq!(out.len(), 2);
            let d = engine.counter.snapshot().since(&before);
            assert_eq!((d.switch_t2b, d.refresh, d.repack_lanes), (2, 2, 5), "serial={serial}");
        }
    }
}
