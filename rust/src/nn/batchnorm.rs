//! Batch normalization, transfer-learning style: the statistics and affine
//! parameters are frozen from pre-training, so BN folds to a per-channel
//! plaintext affine `y = g·x + b` — one MultCP and one AddCP per ciphertext
//! (the paper's Table-4 "BN" rows).

use super::engine::GlyphEngine;
use super::layer::{bn_forward_ops, Layer, LayerPlanEntry, LayerState};
use super::tensor::EncTensor;
use crate::coordinator::scheduler::LayerKind;

/// Frozen affine BN over the channel dimension of a CHW tensor.
pub struct BnLayer {
    /// Per-channel quantized gain (8-bit) and bias (at gain scale).
    pub gain: Vec<i64>,
    pub bias: Vec<i64>,
    /// log2 of the gain's fixed-point scale (output shift grows by this).
    pub gain_shift: u32,
}

impl BnLayer {
    /// Fold float BN parameters (γ, β, μ, σ²) into the quantized affine.
    pub fn fold(gamma: &[f64], beta: &[f64], mean: &[f64], var: &[f64], gain_shift: u32) -> Self {
        let scale = 2f64.powi(gain_shift as i32);
        let mut gain = Vec::with_capacity(gamma.len());
        let mut bias = Vec::with_capacity(gamma.len());
        for c in 0..gamma.len() {
            let g = gamma[c] / (var[c] + 1e-5).sqrt();
            let b = beta[c] - g * mean[c];
            gain.push(((g * scale).round() as i64).clamp(-127, 127));
            bias.push((b * scale).round() as i64);
        }
        BnLayer { gain, bias, gain_shift }
    }

    pub fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        assert_eq!(x.shape.len(), 3);
        let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
        assert_eq!(c, self.gain.len());
        // packed-layout conv outputs anchor their batch at `lane_base + b`,
        // so the bias plaintext follows the payload lanes
        let batch_positions: Vec<usize> =
            x.order.positions(engine.batch).into_iter().map(|p| p + x.lane_base).collect();
        let mut cts = Vec::with_capacity(x.len());
        for ch in 0..c {
            // one frozen-weight build per channel, amortized over the h·w
            // positions (on FHE this is the evaluation-form lift; per-
            // position MultCP is then a pure pointwise pass)
            let g = engine.scalar_weight(self.gain[ch]);
            // bias must be added at the tensor's running scale: b·2^(x.shift);
            // built once per channel, reused across the h·w positions
            let b = engine.plain_at(self.bias[ch] << x.shift, &batch_positions);
            for y in 0..h {
                for xx in 0..w {
                    let mut t = x.chw(ch, y, xx).clone();
                    engine.mult_cp_w(&mut t, &g);
                    engine.add_plain_v(&mut t, &b);
                    cts.push(t);
                }
            }
        }
        EncTensor::new(cts, x.shape.clone(), x.order, x.shift + self.gain_shift)
            .with_lane_base(x.lane_base)
    }
}

impl Layer for BnLayer {
    fn plan_entry(&self, in_shape: &[usize], _batch: usize) -> LayerPlanEntry {
        assert_eq!(in_shape.len(), 3, "BN expects CHW");
        assert_eq!(in_shape[0], self.gain.len(), "BN channel mismatch");
        LayerPlanEntry {
            kind: LayerKind::BatchNorm,
            out_shape: in_shape.to_vec(),
            forward: bn_forward_ops(in_shape.iter().product()),
            error: None, // frozen affine BN folds into neighbours under TL
            gradient: None,
            out_packed: false,
        }
    }

    fn plan_entry_packed(
        &self,
        in_shape: &[usize],
        layout: &super::tensor::PackedLayout,
        in_packed: bool,
    ) -> LayerPlanEntry {
        // the packed conv hands BN per-pixel ciphertexts (batch at the
        // payload lanes), so the per-scalar counts hold verbatim
        assert!(!in_packed, "BN consumes per-pixel conv outputs");
        self.plan_entry(in_shape, layout.batch)
    }

    fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState) {
        (BnLayer::forward(self, x, engine), LayerState::None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::{EngineProfile, GlyphEngine};
    use crate::nn::tensor::PackOrder;

    #[test]
    fn affine_bn_matches_reference() {
        let (eng, mut client) = GlyphEngine::setup(EngineProfile::Test, 2, 910);
        let cts: Vec<_> = (0..4).map(|i| client.encrypt_batch(&[10 * (i as i64 + 1), -5], 0)).collect();
        let x = EncTensor::new(cts, vec![1, 2, 2], PackOrder::Forward, 0);
        let bn = BnLayer { gain: vec![3], bias: vec![7], gain_shift: 0 };
        let y = bn.forward(&x, &eng);
        assert_eq!(client.decrypt_batch(y.chw(0, 0, 0), 2, 0), vec![37, -8]);
        assert_eq!(client.decrypt_batch(y.chw(0, 1, 1), 2, 0), vec![127, -8]);
        let s = eng.counter.snapshot();
        assert_eq!(s.mult_cp, 4);
    }

    #[test]
    fn fold_produces_expected_affine() {
        let bn = BnLayer::fold(&[2.0], &[1.0], &[0.5], &[1.0 - 1e-5], 4);
        // g = 2/1 = 2 → 32 at shift 4; b = 1 − 2·0.5 = 0 → 0
        assert_eq!(bn.gain, vec![32]);
        assert_eq!(bn.bias, vec![0]);
    }
}
