//! The [`Layer`] trait: the uniform unit interface behind `nn::network`.
//!
//! Every network unit — FC, conv, batch-norm, pooling, ReLU, softmax, the
//! FHESGD sigmoid-TLU — implements the same four-method surface
//! (`plan_entry`, `forward`, `backward_error`, `gradients`/
//! `apply_gradients`). `plan_entry` reports the unit's scheduler kind,
//! output geometry and *exact* per-step homomorphic-op counts, which is how
//! `Network::compile` produces the executable `scheduler::Plan`: the op
//! totals of a compiled plan are asserted against live `OpCounter`
//! snapshots by the plan/execution consistency test.

use super::backend::Ct;
use super::engine::GlyphEngine;
use super::tensor::EncTensor;
use crate::coordinator::scheduler::{LayerKind, StepOps};
use crate::switch::SWITCH_BITS;

/// Per-layer forward state retained for the backward pass.
pub enum LayerState {
    /// Stateless unit.
    None,
    /// ReLU sign bits (the Algorithm-2 iReLU mask).
    Relu(super::activation::ReluState),
    /// Output-unit forward result (softmax distribution / sigmoid
    /// activations), consumed by the loss-derivative error step and by the
    /// sigmoid-derivative lookup.
    Output(EncTensor),
}

/// Gradient accumulator produced by a trainable layer: `grads[out][in]`.
pub type LayerGrads = Vec<Vec<Ct>>;

/// What a unit contributes to the compiled plan.
#[derive(Clone, Debug)]
pub struct LayerPlanEntry {
    pub kind: LayerKind,
    pub out_shape: Vec<usize>,
    /// Forward-step op counts for one mini-batch iteration.
    pub forward: StepOps,
    /// Error-step op counts (`None`: the unit never propagates an error).
    pub error: Option<StepOps>,
    /// Gradient-step op counts (`None`: frozen unit).
    pub gradient: Option<StepOps>,
}

/// The uniform unit interface. Implemented by `FcLayer`, `ConvLayer`,
/// `BnLayer`, `AvgPoolLayer`, `FlattenLayer`, `ReluLayer`, `SoftmaxLayer`
/// and the FHESGD `SigmoidTluLayer`.
pub trait Layer {
    /// Scheduler entry: kind, output geometry and exact op counts for a
    /// mini-batch of `batch` samples entering with `in_shape`.
    fn plan_entry(&self, in_shape: &[usize], batch: usize) -> LayerPlanEntry;

    /// Run the unit forward, returning the output tensor and whatever state
    /// the backward pass will need.
    fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState);

    /// Propagate the error through this unit. `delta` is the error arriving
    /// from above — for output units (softmax / output sigmoid) it is the
    /// reverse-packed one-hot label tensor, and the unit computes the
    /// loss derivative from its stored forward state.
    ///
    /// Units whose `plan_entry` reports `error: None` never appear in a
    /// compiled backward plan, so the default is unreachable.
    fn backward_error(
        &self,
        _delta: &EncTensor,
        _state: &LayerState,
        _engine: &GlyphEngine,
    ) -> EncTensor {
        unreachable!("unit emits no error step; backward truncates below the trainable head")
    }

    /// Weight gradients (`None` for non-trainable units).
    fn gradients(
        &self,
        _below: &EncTensor,
        _delta: &EncTensor,
        _engine: &GlyphEngine,
    ) -> Option<LayerGrads> {
        None
    }

    /// SGD update from a previous [`Layer::gradients`] result.
    fn apply_gradients(&mut self, _grads: &LayerGrads, _grad_shift: u32, _engine: &GlyphEngine) {}

    /// Whether this unit's error step computes a *loss derivative* from the
    /// label tensor (softmax / output sigmoid). `Network::train_step`
    /// refuses to train a network whose last unit is not an output unit —
    /// otherwise raw labels would silently flow backward as if they were an
    /// error signal.
    fn is_output_unit(&self) -> bool {
        false
    }

    /// Inspection downcast (weight snapshots in tests/examples).
    fn as_fc(&self) -> Option<&super::linear::FcLayer> {
        None
    }

    /// Mutable downcast (checkpoint restore overwrites FC weights in
    /// place).
    fn as_fc_mut(&mut self) -> Option<&mut super::linear::FcLayer> {
        None
    }
}

/// Shape-only CHW→vector adapter in front of the FC head (zero
/// homomorphic ops; exists so compiled CNN plans stay a linear walk).
pub struct FlattenLayer;

impl Layer for FlattenLayer {
    fn plan_entry(&self, in_shape: &[usize], _batch: usize) -> LayerPlanEntry {
        LayerPlanEntry {
            kind: LayerKind::Flatten,
            out_shape: vec![in_shape.iter().product()],
            forward: StepOps::default(),
            error: None,
            gradient: None,
        }
    }

    fn forward(&self, x: &EncTensor, _engine: &GlyphEngine) -> (EncTensor, LayerState) {
        let flat = EncTensor::new(x.cts.clone(), vec![x.len()], x.order, x.shift);
        (flat, LayerState::None)
    }
}

// ---------------------------------------------------------------------------
// Exact per-step op counts, shared between the unit `plan_entry` impls and
// the weight-free `NetworkBuilder::compile` path. Each formula mirrors the
// corresponding execution code 1:1 (see the cited functions).
// ---------------------------------------------------------------------------

const BITS: u64 = SWITCH_BITS as u64;

/// `FcLayer::forward`: out MACs of in terms each (acc add is `in−1`),
/// plus one AddCC per *encrypted* bias term (`enc_bias_terms`; plaintext
/// biases are free `add_plain`s). Both plan paths — the weight-free
/// `LayerSpec` compile and the unit's `plan_entry` — must call this one
/// formula so they can never drift.
pub fn fc_forward_ops(in_dim: usize, out_dim: usize, enc: bool, enc_bias_terms: usize) -> StepOps {
    let macs = (in_dim * out_dim) as u64;
    StepOps {
        mult_cc: if enc { macs } else { 0 },
        mult_cp: if enc { 0 } else { macs },
        add_cc: ((in_dim - 1) * out_dim) as u64 + enc_bias_terms as u64,
        ..Default::default()
    }
}

/// `FcLayer::backward_error`: in sums of out terms each.
pub fn fc_error_ops(in_dim: usize, out_dim: usize, enc: bool) -> StepOps {
    let macs = (in_dim * out_dim) as u64;
    StepOps {
        mult_cc: if enc { macs } else { 0 },
        mult_cp: if enc { 0 } else { macs },
        add_cc: ((out_dim - 1) * in_dim) as u64,
        ..Default::default()
    }
}

/// `FcLayer::gradients` + `apply_gradients`: one convolution-trick MultCC
/// per weight, then the per-weight requantization round trip through the
/// switch (1 B2T of one position = 1 lane extract + 8 extraction PBS,
/// 8 weighted gates, 1 T2B packing 1 lane, 1 SubCC).
pub fn fc_gradient_ops(in_dim: usize, out_dim: usize) -> StepOps {
    let w = (in_dim * out_dim) as u64;
    StepOps {
        mult_cc: w,
        add_cc: w,
        act_gates: w * BITS,
        extract_pbs: w * BITS,
        switch_b2t: w,
        switch_t2b: w,
        refresh: w,
        extract_lanes: w,
        repack_lanes: w,
        ..Default::default()
    }
}

/// `ConvLayer::forward`: `out_ch·oh·ow` outputs of `in_ch·k²` taps each.
pub fn conv_forward_ops(in_ch: usize, out_ch: usize, k: usize, oh: usize, ow: usize, enc: bool) -> StepOps {
    let outputs = (out_ch * oh * ow) as u64;
    let taps = (in_ch * k * k) as u64;
    StepOps {
        mult_cc: if enc { outputs * taps } else { 0 },
        mult_cp: if enc { 0 } else { outputs * taps },
        add_cc: outputs * (taps - 1),
        ..Default::default()
    }
}

/// `BnLayer::forward`: one MultCP per ciphertext (the AddCP is free).
pub fn bn_forward_ops(count: usize) -> StepOps {
    StepOps { mult_cp: count as u64, ..Default::default() }
}

/// `avg_pool2`: three AddCC per pooled output.
pub fn pool_forward_ops(out_count: usize) -> StepOps {
    StepOps { add_cc: (out_count * 3) as u64, ..Default::default() }
}

/// `activation::relu_layer`: per ciphertext one B2T (one lane extract and
/// 8 extraction PBS per lane), 7 weighted ANDs per lane (Algorithm 1 drops
/// the sign bit), one T2B packing every lane.
pub fn relu_forward_ops(cts: usize, batch: usize) -> StepOps {
    let c = cts as u64;
    let lanes = (cts * batch) as u64;
    StepOps {
        relu_values: c,
        act_gates: lanes * (BITS - 1),
        extract_pbs: lanes * BITS,
        switch_b2t: c,
        switch_t2b: c,
        refresh: c,
        extract_lanes: lanes,
        repack_lanes: lanes,
        ..Default::default()
    }
}

/// `activation::irelu_layer`: like the forward pass but all 8 bits are
/// masked (Algorithm 2 keeps the sign).
pub fn relu_error_ops(cts: usize, batch: usize) -> StepOps {
    let c = cts as u64;
    let lanes = (cts * batch) as u64;
    StepOps {
        relu_values: c,
        act_gates: lanes * BITS,
        extract_pbs: lanes * BITS,
        switch_b2t: c,
        switch_t2b: c,
        refresh: c,
        extract_lanes: lanes,
        repack_lanes: lanes,
        ..Default::default()
    }
}

/// `SoftmaxLayer::forward`: per ciphertext one B2T, `gates_per_lane`
/// bootstraps per lane (MUX trees + weighted recomposition; computed by
/// `SoftmaxUnit::plan_gates_per_lane` from the table constants), one T2B.
pub fn softmax_forward_ops(cts: usize, batch: usize, gates_per_lane: u64) -> StepOps {
    let c = cts as u64;
    let lanes = (cts * batch) as u64;
    StepOps {
        softmax_values: c,
        act_gates: lanes * gates_per_lane,
        extract_pbs: lanes * BITS,
        switch_b2t: c,
        switch_t2b: c,
        refresh: c,
        extract_lanes: lanes,
        repack_lanes: lanes,
        ..Default::default()
    }
}

/// Softmax error step = the quadratic-loss derivative (Eq. 6): one SubCC
/// per class.
pub fn softmax_error_ops(cts: usize) -> StepOps {
    StepOps { add_cc: cts as u64, ..Default::default() }
}

/// FHESGD sigmoid TLU unit: forward is one lookup (2 refresh-substituted
/// domain conversions) per neuron; the error step is one SubCC per class
/// for the output unit, else one derivative lookup + one MultCC per
/// neuron. Returns `(forward, error)`.
pub fn sigmoid_tlu_ops(cts: usize, output_unit: bool) -> (StepOps, StepOps) {
    let c = cts as u64;
    let forward = StepOps { tlu: c, refresh: 2 * c, ..Default::default() };
    let error = if output_unit {
        StepOps { add_cc: c, ..Default::default() }
    } else {
        StepOps { tlu: c, refresh: 2 * c, mult_cc: c, ..Default::default() }
    };
    (forward, error)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_ops_mirror_execution_formulas() {
        let f = fc_forward_ops(3, 4, true, 0);
        assert_eq!((f.mult_cc, f.add_cc), (12, 8));
        let biased = fc_forward_ops(3, 4, true, 4);
        assert_eq!(biased.add_cc, 12);
        let e = fc_error_ops(4, 2, true);
        assert_eq!((e.mult_cc, e.add_cc), (8, 4));
        let g = fc_gradient_ops(3, 4);
        assert_eq!((g.mult_cc, g.switch_b2t, g.act_gates), (12, 12, 96));
        assert_eq!((g.extract_lanes, g.repack_lanes), (12, 12));
        let frozen = fc_forward_ops(5, 2, false, 0);
        assert_eq!((frozen.mult_cc, frozen.mult_cp), (0, 10));
    }

    #[test]
    fn relu_ops_scale_with_batch() {
        let f = relu_forward_ops(4, 2);
        assert_eq!((f.switch_b2t, f.act_gates, f.extract_pbs), (4, 56, 64));
        assert_eq!((f.extract_lanes, f.repack_lanes), (8, 8));
        let e = relu_error_ops(4, 2);
        assert_eq!(e.act_gates, 64);
        assert_eq!((e.extract_lanes, e.repack_lanes), (8, 8));
    }
}
