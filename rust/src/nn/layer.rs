//! The [`Layer`] trait: the uniform unit interface behind `nn::network`.
//!
//! Every network unit — FC, conv, batch-norm, pooling, ReLU, softmax, the
//! FHESGD sigmoid-TLU — implements the same four-method surface
//! (`plan_entry`, `forward`, `backward_error`, `gradients`/
//! `apply_gradients`). `plan_entry` reports the unit's scheduler kind,
//! output geometry and *exact* per-step homomorphic-op counts, which is how
//! `Network::compile` produces the executable `scheduler::Plan`: the op
//! totals of a compiled plan are asserted against live `OpCounter`
//! snapshots by the plan/execution consistency test.

use super::backend::Ct;
use super::engine::GlyphEngine;
use super::tensor::{EncTensor, PackedLayout};
use crate::coordinator::scheduler::{LayerKind, StepOps};
use crate::switch::SWITCH_BITS;

/// Per-layer forward state retained for the backward pass.
pub enum LayerState {
    /// Stateless unit.
    None,
    /// ReLU sign bits (the Algorithm-2 iReLU mask).
    Relu(super::activation::ReluState),
    /// Output-unit forward result (softmax distribution / sigmoid
    /// activations), consumed by the loss-derivative error step and by the
    /// sigmoid-derivative lookup.
    Output(EncTensor),
}

/// Gradient accumulator produced by a trainable layer: `grads[out][in]`.
pub type LayerGrads = Vec<Vec<Ct>>;

/// What a unit contributes to the compiled plan.
#[derive(Clone, Debug)]
pub struct LayerPlanEntry {
    pub kind: LayerKind,
    pub out_shape: Vec<usize>,
    /// Forward-step op counts for one mini-batch iteration.
    pub forward: StepOps,
    /// Error-step op counts (`None`: the unit never propagates an error).
    pub error: Option<StepOps>,
    /// Gradient-step op counts (`None`: frozen unit).
    pub gradient: Option<StepOps>,
    /// Whether the unit's *forward output* tensor is a cross-sample SIMD
    /// block tensor (`EncTensor::is_packed`). Always `false` on the
    /// per-scalar plan path; under a packed layout the flat ReLU emits
    /// packed blocks while the FC/softmax stages emit per-neuron
    /// ciphertexts with the batch at strided payload lanes.
    pub out_packed: bool,
}

/// The uniform unit interface. Implemented by `FcLayer`, `ConvLayer`,
/// `BnLayer`, `AvgPoolLayer`, `FlattenLayer`, `ReluLayer`, `SoftmaxLayer`
/// and the FHESGD `SigmoidTluLayer`.
pub trait Layer {
    /// Scheduler entry: kind, output geometry and exact op counts for a
    /// mini-batch of `batch` samples entering with `in_shape`.
    fn plan_entry(&self, in_shape: &[usize], batch: usize) -> LayerPlanEntry;

    /// Scheduler entry under the cross-sample SIMD minibatch layout:
    /// `layout` is the engine's [`PackedLayout`] and `in_packed` says
    /// whether the unit's forward input arrives as packed blocks (versus
    /// per-scalar ciphertexts). Units without a packed execution path keep
    /// the panicking default — `Network::compile_units` only calls this
    /// when the engine runs packed, so an unsupported unit fails loudly at
    /// compile time rather than mis-counting at run time.
    fn plan_entry_packed(
        &self,
        _in_shape: &[usize],
        _layout: &PackedLayout,
        _in_packed: bool,
    ) -> LayerPlanEntry {
        panic!("this unit does not support the cross-sample packed minibatch layout")
    }

    /// Run the unit forward, returning the output tensor and whatever state
    /// the backward pass will need.
    fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState);

    /// Propagate the error through this unit. `delta` is the error arriving
    /// from above — for output units (softmax / output sigmoid) it is the
    /// reverse-packed one-hot label tensor, and the unit computes the
    /// loss derivative from its stored forward state.
    ///
    /// Units whose `plan_entry` reports `error: None` never appear in a
    /// compiled backward plan, so the default is unreachable.
    fn backward_error(
        &self,
        _delta: &EncTensor,
        _state: &LayerState,
        _engine: &GlyphEngine,
    ) -> EncTensor {
        unreachable!("unit emits no error step; backward truncates below the trainable head")
    }

    /// Weight gradients (`None` for non-trainable units).
    fn gradients(
        &self,
        _below: &EncTensor,
        _delta: &EncTensor,
        _engine: &GlyphEngine,
    ) -> Option<LayerGrads> {
        None
    }

    /// SGD update from a previous [`Layer::gradients`] result.
    fn apply_gradients(&mut self, _grads: &LayerGrads, _grad_shift: u32, _engine: &GlyphEngine) {}

    /// Whether this unit's error step computes a *loss derivative* from the
    /// label tensor (softmax / output sigmoid). `Network::train_step`
    /// refuses to train a network whose last unit is not an output unit —
    /// otherwise raw labels would silently flow backward as if they were an
    /// error signal.
    fn is_output_unit(&self) -> bool {
        false
    }

    /// Inspection downcast (weight snapshots in tests/examples).
    fn as_fc(&self) -> Option<&super::linear::FcLayer> {
        None
    }

    /// Mutable downcast (checkpoint restore overwrites FC weights in
    /// place).
    fn as_fc_mut(&mut self) -> Option<&mut super::linear::FcLayer> {
        None
    }

    /// Inspection downcast for packed-layout FC layers (weight readback in
    /// the packing conformance tests/benches).
    fn as_packed_fc(&self) -> Option<&super::linear::PackedFcLayer> {
        None
    }
}

/// Shape-only CHW→vector adapter in front of the FC head (zero
/// homomorphic ops; exists so compiled CNN plans stay a linear walk).
pub struct FlattenLayer;

impl Layer for FlattenLayer {
    fn plan_entry(&self, in_shape: &[usize], _batch: usize) -> LayerPlanEntry {
        LayerPlanEntry {
            kind: LayerKind::Flatten,
            out_shape: vec![in_shape.iter().product()],
            forward: StepOps::default(),
            error: None,
            gradient: None,
            out_packed: false,
        }
    }

    fn plan_entry_packed(
        &self,
        in_shape: &[usize],
        layout: &PackedLayout,
        in_packed: bool,
    ) -> LayerPlanEntry {
        // Under a packed CNN the flatten input is the per-pixel clean tensor
        // the CHW ReLU emits — shape-only either way.
        assert!(!in_packed, "flatten consumes the per-pixel tensor, not packed blocks");
        self.plan_entry(in_shape, layout.batch)
    }

    fn forward(&self, x: &EncTensor, _engine: &GlyphEngine) -> (EncTensor, LayerState) {
        let flat = EncTensor::new(x.cts.clone(), vec![x.len()], x.order, x.shift);
        (flat, LayerState::None)
    }
}

// ---------------------------------------------------------------------------
// Exact per-step op counts, shared between the unit `plan_entry` impls and
// the weight-free `NetworkBuilder::compile` path. Each formula mirrors the
// corresponding execution code 1:1 (see the cited functions).
// ---------------------------------------------------------------------------

const BITS: u64 = SWITCH_BITS as u64;

/// `FcLayer::forward`: out MACs of in terms each (acc add is `in−1`),
/// plus one AddCC per *encrypted* bias term (`enc_bias_terms`; plaintext
/// biases are free `add_plain`s). Both plan paths — the weight-free
/// `LayerSpec` compile and the unit's `plan_entry` — must call this one
/// formula so they can never drift.
pub fn fc_forward_ops(in_dim: usize, out_dim: usize, enc: bool, enc_bias_terms: usize) -> StepOps {
    let macs = (in_dim * out_dim) as u64;
    StepOps {
        mult_cc: if enc { macs } else { 0 },
        mult_cp: if enc { 0 } else { macs },
        add_cc: ((in_dim - 1) * out_dim) as u64 + enc_bias_terms as u64,
        ..Default::default()
    }
}

/// `FcLayer::backward_error`: in sums of out terms each.
pub fn fc_error_ops(in_dim: usize, out_dim: usize, enc: bool) -> StepOps {
    let macs = (in_dim * out_dim) as u64;
    StepOps {
        mult_cc: if enc { macs } else { 0 },
        mult_cp: if enc { 0 } else { macs },
        add_cc: ((out_dim - 1) * in_dim) as u64,
        ..Default::default()
    }
}

/// `FcLayer::gradients` + `apply_gradients`: one convolution-trick MultCC
/// per weight, then the per-weight requantization round trip through the
/// switch (1 B2T of one position = 1 lane extract + 8 extraction PBS,
/// 8 weighted gates, 1 T2B packing 1 lane, 1 SubCC).
pub fn fc_gradient_ops(in_dim: usize, out_dim: usize) -> StepOps {
    let w = (in_dim * out_dim) as u64;
    StepOps {
        mult_cc: w,
        add_cc: w,
        act_gates: w * BITS,
        extract_pbs: w * BITS,
        switch_b2t: w,
        switch_t2b: w,
        refresh: w,
        extract_lanes: w,
        repack_lanes: w,
        ..Default::default()
    }
}

/// `ConvLayer::forward`: `out_ch·oh·ow` outputs of `in_ch·k²` taps each.
pub fn conv_forward_ops(in_ch: usize, out_ch: usize, k: usize, oh: usize, ow: usize, enc: bool) -> StepOps {
    let outputs = (out_ch * oh * ow) as u64;
    let taps = (in_ch * k * k) as u64;
    StepOps {
        mult_cc: if enc { outputs * taps } else { 0 },
        mult_cp: if enc { 0 } else { outputs * taps },
        add_cc: outputs * (taps - 1),
        ..Default::default()
    }
}

/// Packed `ConvLayer::forward_packed`: the minibatch image arrives as
/// cross-sample SIMD blocks, so each output position MACs one anchored
/// kernel *polynomial* per distinct input block its taps touch (one MultCP
/// each, `distinct − 1` accumulator adds) instead of one scalar MultCP per
/// tap — the whole batch rides each product. The per-position block count
/// is a pure function of the tap geometry and the layout, mirrored 1:1 by
/// the execution's block grouping.
pub fn conv_forward_packed_ops(
    in_ch: usize,
    out_ch: usize,
    k: usize,
    in_h: usize,
    in_w: usize,
    layout: &PackedLayout,
) -> StepOps {
    let (oh, ow) = (in_h - k + 1, in_w - k + 1);
    let mut mult_cp = 0u64;
    let mut add_cc = 0u64;
    for y in 0..oh {
        for x in 0..ow {
            let mut blocks = std::collections::BTreeSet::new();
            for ic in 0..in_ch {
                for ky in 0..k {
                    for kx in 0..k {
                        let j = (ic * in_h + y + ky) * in_w + x + kx;
                        blocks.insert(j / layout.feats_per_ct);
                    }
                }
            }
            mult_cp += blocks.len() as u64;
            add_cc += (blocks.len() - 1) as u64;
        }
    }
    StepOps {
        mult_cp: mult_cp * out_ch as u64,
        add_cc: add_cc * out_ch as u64,
        ..Default::default()
    }
}

/// `BnLayer::forward`: one MultCP per ciphertext (the AddCP is free).
pub fn bn_forward_ops(count: usize) -> StepOps {
    StepOps { mult_cp: count as u64, ..Default::default() }
}

/// `avg_pool2`: three AddCC per pooled output.
pub fn pool_forward_ops(out_count: usize) -> StepOps {
    StepOps { add_cc: (out_count * 3) as u64, ..Default::default() }
}

/// `activation::relu_layer`: per ciphertext one B2T (one lane extract and
/// 8 extraction PBS per lane), 7 weighted ANDs per lane (Algorithm 1 drops
/// the sign bit), one T2B packing every lane.
pub fn relu_forward_ops(cts: usize, batch: usize) -> StepOps {
    let c = cts as u64;
    let lanes = (cts * batch) as u64;
    StepOps {
        relu_values: c,
        act_gates: lanes * (BITS - 1),
        extract_pbs: lanes * BITS,
        switch_b2t: c,
        switch_t2b: c,
        refresh: c,
        extract_lanes: lanes,
        repack_lanes: lanes,
        ..Default::default()
    }
}

/// `activation::irelu_layer`: like the forward pass but all 8 bits are
/// masked (Algorithm 2 keeps the sign).
pub fn relu_error_ops(cts: usize, batch: usize) -> StepOps {
    let c = cts as u64;
    let lanes = (cts * batch) as u64;
    StepOps {
        relu_values: c,
        act_gates: lanes * BITS,
        extract_pbs: lanes * BITS,
        switch_b2t: c,
        switch_t2b: c,
        refresh: c,
        extract_lanes: lanes,
        repack_lanes: lanes,
        ..Default::default()
    }
}

/// `SoftmaxLayer::forward`: per ciphertext one B2T, `gates_per_lane`
/// bootstraps per lane (MUX trees + weighted recomposition; computed by
/// `SoftmaxUnit::plan_gates_per_lane` from the table constants), one T2B.
pub fn softmax_forward_ops(cts: usize, batch: usize, gates_per_lane: u64) -> StepOps {
    let c = cts as u64;
    let lanes = (cts * batch) as u64;
    StepOps {
        softmax_values: c,
        act_gates: lanes * gates_per_lane,
        extract_pbs: lanes * BITS,
        switch_b2t: c,
        switch_t2b: c,
        refresh: c,
        extract_lanes: lanes,
        repack_lanes: lanes,
        ..Default::default()
    }
}

/// Softmax error step = the quadratic-loss derivative (Eq. 6): one SubCC
/// per class.
pub fn softmax_error_ops(cts: usize) -> StepOps {
    StepOps { add_cc: cts as u64, ..Default::default() }
}

/// FHESGD sigmoid TLU unit: forward is one lookup (2 refresh-substituted
/// domain conversions) per neuron; the error step is one SubCC per class
/// for the output unit, else one derivative lookup + one MultCC per
/// neuron. Returns `(forward, error)`.
pub fn sigmoid_tlu_ops(cts: usize, output_unit: bool) -> (StepOps, StepOps) {
    let c = cts as u64;
    let forward = StepOps { tlu: c, refresh: 2 * c, ..Default::default() };
    let error = if output_unit {
        StepOps { add_cc: c, ..Default::default() }
    } else {
        StepOps { tlu: c, refresh: 2 * c, mult_cc: c, ..Default::default() }
    };
    (forward, error)
}

// ---------------------------------------------------------------------------
// Packed-layout op formulas (cross-sample SIMD minibatch blocks). Like the
// per-scalar formulas above, each mirrors its execution path 1:1 — the plan
// consistency assertions hold exactly under packing too.
// ---------------------------------------------------------------------------

/// Pack-on-entry at a packed FC seam (`GlyphEngine::pack_clean_blocks`):
/// one monomial MultCP per input lane (uniformly including the `X^0`
/// anchors) and one AddCC folding every non-anchor lane into its block.
pub fn pack_entry_ops(features: usize, layout: &PackedLayout) -> StepOps {
    StepOps {
        mult_cp: features as u64,
        add_cc: (features - layout.blocks(features)) as u64,
        ..Default::default()
    }
}

/// Packed `FcLayer::forward`: one MAC row per output neuron over `B(in)`
/// packed-block terms (`B(in)−1` accumulator adds), one AddCC per
/// encrypted bias term, plus the pack-on-entry cost when the input arrives
/// per-scalar (the CNN flatten seam). Packed weights are ciphertext
/// blocks, so the MACs are MultCC.
pub fn fc_forward_packed_ops(
    in_dim: usize,
    out_dim: usize,
    layout: &PackedLayout,
    in_packed: bool,
    enc_bias_terms: usize,
) -> StepOps {
    let blocks = layout.blocks(in_dim);
    let mut ops =
        if in_packed { StepOps::default() } else { pack_entry_ops(in_dim, layout) };
    ops.mult_cc += (out_dim * blocks) as u64;
    ops.add_cc += (out_dim * (blocks - 1)) as u64 + enc_bias_terms as u64;
    ops
}

/// Packed `FcLayer::backward_error`: one MAC row per *input block* over the
/// `out` per-neuron reversed deltas (each term a packed weight block ×
/// reversed δ MultCC).
pub fn fc_error_packed_ops(in_dim: usize, out_dim: usize, layout: &PackedLayout) -> StepOps {
    let blocks = layout.blocks(in_dim);
    StepOps {
        mult_cc: (blocks * out_dim) as u64,
        add_cc: (blocks * (out_dim - 1)) as u64,
        ..Default::default()
    }
}

/// Packed `FcLayer::gradients` + `apply_gradients`: one convolution-trick
/// MultCC per (neuron, input block) — each product carries the `F`
/// batch-summed gradients of a whole weight block. Requantization extracts
/// every weight lane (`in·out` lanes, 8 PBS + 8 weighted gates each) from
/// the `out·B(in)` block products, repacks one T2B group per block at the
/// weight anchors, and applies one SubCC per weight-block ciphertext. When
/// `below` arrives per-scalar the layer re-packs it first.
pub fn fc_gradient_packed_ops(
    in_dim: usize,
    out_dim: usize,
    layout: &PackedLayout,
    below_packed: bool,
) -> StepOps {
    let blocks = (out_dim * layout.blocks(in_dim)) as u64;
    let w = (in_dim * out_dim) as u64;
    let mut ops =
        if below_packed { StepOps::default() } else { pack_entry_ops(in_dim, layout) };
    ops.mult_cc += blocks;
    ops.add_cc += blocks;
    ops.act_gates += w * BITS;
    ops.extract_pbs += w * BITS;
    ops.switch_b2t += blocks;
    ops.switch_t2b += blocks;
    ops.refresh += blocks;
    ops.extract_lanes += w;
    ops.repack_lanes += w;
    ops
}

/// Packed flat `activation::relu_layer`: the inputs are per-neuron MAC
/// outputs (batch at strided payload lanes), so extraction matches the
/// per-scalar pass — one B2T per neuron, 8 PBS and 7 weighted ANDs per
/// lane. The bootstrapped lanes then regroup into SIMD blocks: one T2B
/// group per packed *block* instead of per neuron.
pub fn relu_forward_packed_ops(features: usize, layout: &PackedLayout) -> StepOps {
    let f = features as u64;
    let lanes = (features * layout.batch) as u64;
    let out_blocks = layout.blocks(features) as u64;
    StepOps {
        relu_values: f,
        act_gates: lanes * (BITS - 1),
        extract_pbs: lanes * BITS,
        switch_b2t: f,
        switch_t2b: out_blocks,
        refresh: out_blocks,
        extract_lanes: lanes,
        repack_lanes: lanes,
        ..Default::default()
    }
}

/// Packed flat iReLU: packed-*reversed* blocks arrive from the FC error
/// step, so one B2T per block extracts every feature × sample lane at
/// once; the masked lanes regroup per neuron (one T2B group each) for the
/// layer below.
pub fn relu_error_packed_ops(features: usize, layout: &PackedLayout) -> StepOps {
    let f = features as u64;
    let lanes = (features * layout.batch) as u64;
    let in_blocks = layout.blocks(features) as u64;
    StepOps {
        relu_values: f,
        act_gates: lanes * BITS,
        extract_pbs: lanes * BITS,
        switch_b2t: in_blocks,
        switch_t2b: f,
        refresh: f,
        extract_lanes: lanes,
        repack_lanes: lanes,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_ops_mirror_execution_formulas() {
        let f = fc_forward_ops(3, 4, true, 0);
        assert_eq!((f.mult_cc, f.add_cc), (12, 8));
        let biased = fc_forward_ops(3, 4, true, 4);
        assert_eq!(biased.add_cc, 12);
        let e = fc_error_ops(4, 2, true);
        assert_eq!((e.mult_cc, e.add_cc), (8, 4));
        let g = fc_gradient_ops(3, 4);
        assert_eq!((g.mult_cc, g.switch_b2t, g.act_gates), (12, 12, 96));
        assert_eq!((g.extract_lanes, g.repack_lanes), (12, 12));
        let frozen = fc_forward_ops(5, 2, false, 0);
        assert_eq!((frozen.mult_cc, frozen.mult_cp), (0, 10));
    }

    #[test]
    fn relu_ops_scale_with_batch() {
        let f = relu_forward_ops(4, 2);
        assert_eq!((f.switch_b2t, f.act_gates, f.extract_pbs), (4, 56, 64));
        assert_eq!((f.extract_lanes, f.repack_lanes), (8, 8));
        let e = relu_error_ops(4, 2);
        assert_eq!(e.act_gates, 64);
        assert_eq!((e.extract_lanes, e.repack_lanes), (8, 8));
    }

    #[test]
    fn packed_fc_ops_amortize_the_macs_over_blocks() {
        // batch 8 in n = 256: stride 16, F = 8 → a 16-wide input spans 2
        // blocks; 8 output neurons MAC 2 block terms each.
        let layout = PackedLayout::for_ring(8, 256).unwrap();
        assert_eq!((layout.stride, layout.feats_per_ct), (16, 8));
        let f = fc_forward_packed_ops(16, 8, &layout, true, 0);
        assert_eq!((f.mult_cc, f.mult_cp, f.add_cc), (16, 0, 8));
        // per-scalar entry (CNN flatten seam): + 16 monomial MultCP and
        // 16 − 2 block-fold AddCC.
        let seam = fc_forward_packed_ops(16, 8, &layout, false, 0);
        assert_eq!((seam.mult_cc, seam.mult_cp, seam.add_cc), (16, 16, 8 + 14));
        let e = fc_error_packed_ops(16, 8, &layout);
        assert_eq!((e.mult_cc, e.add_cc), (16, 14));
        // gradients: 16 block products, all 128 weight lanes extracted.
        let g = fc_gradient_packed_ops(16, 8, &layout, true);
        assert_eq!((g.mult_cc, g.switch_b2t, g.switch_t2b, g.refresh), (16, 16, 16, 16));
        assert_eq!((g.extract_lanes, g.repack_lanes, g.act_gates), (128, 128, 1024));
        assert_eq!(g.add_cc, 16);
    }

    #[test]
    fn packed_relu_ops_regroup_into_blocks() {
        let layout = PackedLayout::for_ring(8, 256).unwrap();
        // 16 neurons: extraction is per neuron (16 B2T, 128 lanes), the
        // repack groups into 2 packed blocks.
        let f = relu_forward_packed_ops(16, &layout);
        assert_eq!((f.switch_b2t, f.switch_t2b, f.refresh), (16, 2, 2));
        assert_eq!((f.extract_lanes, f.repack_lanes), (128, 128));
        assert_eq!((f.act_gates, f.extract_pbs), (896, 1024));
        // iReLU runs the mirror image: 2 B2T, 16 T2B.
        let e = relu_error_packed_ops(16, &layout);
        assert_eq!((e.switch_b2t, e.switch_t2b, e.refresh), (2, 16, 16));
        assert_eq!((e.act_gates, e.extract_pbs), (1024, 1024));
    }
}
