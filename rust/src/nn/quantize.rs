//! Plain-side SWALP-style 8-bit quantization (paper §5.2: "We quantized the
//! inputs, weights and activations … with 8-bit by the training quantization
//! technique in SWALP").
//!
//! Scales are powers of two chosen per tensor from the max-abs statistic;
//! the encrypted pipeline then only ever needs shifts, which the switch's
//! digit extraction performs for free.

/// Quantize a float tensor to signed 8-bit with a power-of-two scale.
/// Returns (values, exponent) with `x ≈ v · 2^exponent`.
pub fn quantize_i8(xs: &[f64]) -> (Vec<i64>, i32) {
    let max = xs.iter().fold(0f64, |m, &x| m.max(x.abs()));
    if max == 0.0 {
        return (vec![0; xs.len()], 0);
    }
    // smallest e with max/2^e ≤ 127
    let e = (max / 127.0).log2().ceil() as i32;
    let scale = 2f64.powi(-e);
    let vs = xs
        .iter()
        .map(|&x| ((x * scale).round() as i64).clamp(-127, 127))
        .collect();
    (vs, e)
}

/// Dequantize.
pub fn dequantize(vs: &[i64], exponent: i32) -> Vec<f64> {
    let s = 2f64.powi(exponent);
    vs.iter().map(|&v| v as f64 * s).collect()
}

/// Re-quantize an i64 tensor (e.g. a 26-bit MAC result) to 8-bit by a
/// right-shift with round-to-nearest — the plaintext reference of what the
/// switch's digit extraction does.
pub fn requantize_shift(xs: &[i64], shift: u32) -> Vec<i64> {
    xs.iter()
        .map(|&x| {
            let r = (x + (1 << (shift - 1))) >> shift;
            // 8-bit two's complement wrap (the switch drops higher bits)
            ((r & 0xFF) as u8) as i8 as i64
        })
        .collect()
}

/// Choose the shift that brings `max_abs` into 8-bit range.
pub fn shift_for(max_abs: i64) -> u32 {
    let mut s = 0;
    let mut m = max_abs;
    while m > 127 {
        m >>= 1;
        s += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 - 50.0) * 0.37).collect();
        let (vs, e) = quantize_i8(&xs);
        let back = dequantize(&vs, e);
        let ulp = 2f64.powi(e);
        for (x, y) in xs.iter().zip(&back) {
            assert!((x - y).abs() <= ulp, "{x} vs {y}");
        }
        assert!(vs.iter().all(|&v| v.abs() <= 127));
    }

    #[test]
    fn zero_tensor() {
        let (vs, e) = quantize_i8(&[0.0; 8]);
        assert!(vs.iter().all(|&v| v == 0));
        assert_eq!(e, 0);
    }

    #[test]
    fn requantize_matches_switch_semantics() {
        // matches switch::extract::quantize_plain's round-to-nearest
        assert_eq!(requantize_shift(&[5 << 8, -(5i64 << 8), (5 << 8) + 200], 8), vec![5, -5, 6]);
    }

    #[test]
    fn shift_for_ranges() {
        assert_eq!(shift_for(100), 0);
        assert_eq!(shift_for(127), 0);
        assert_eq!(shift_for(128), 1);
        assert_eq!(shift_for(127 * 127 * 784), 17);
    }
}
