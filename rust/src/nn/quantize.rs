//! Plain-side SWALP-style 8-bit quantization (paper §5.2: "We quantized the
//! inputs, weights and activations … with 8-bit by the training quantization
//! technique in SWALP").
//!
//! Scales are powers of two chosen per tensor from the max-abs statistic;
//! the encrypted pipeline then only ever needs shifts, which the switch's
//! digit extraction performs for free.

/// Quantize a float tensor to signed 8-bit with a power-of-two scale.
/// Returns (values, exponent) with `x ≈ v · 2^exponent`.
pub fn quantize_i8(xs: &[f64]) -> (Vec<i64>, i32) {
    let max = xs.iter().fold(0f64, |m, &x| m.max(x.abs()));
    if max == 0.0 {
        return (vec![0; xs.len()], 0);
    }
    // smallest e with max/2^e ≤ 127
    let e = (max / 127.0).log2().ceil() as i32;
    let scale = 2f64.powi(-e);
    let vs = xs
        .iter()
        .map(|&x| ((x * scale).round() as i64).clamp(-127, 127))
        .collect();
    (vs, e)
}

/// Dequantize.
pub fn dequantize(vs: &[i64], exponent: i32) -> Vec<f64> {
    let s = 2f64.powi(exponent);
    vs.iter().map(|&v| v as f64 * s).collect()
}

/// Re-quantize an i64 tensor (e.g. a 26-bit MAC result) to 8-bit by a
/// right-shift with round-to-nearest — the plaintext reference of what the
/// switch's digit extraction does.
pub fn requantize_shift(xs: &[i64], shift: u32) -> Vec<i64> {
    xs.iter()
        .map(|&x| {
            let r = (x + (1 << (shift - 1))) >> shift;
            // 8-bit two's complement wrap (the switch drops higher bits)
            ((r & 0xFF) as u8) as i8 as i64
        })
        .collect()
}

/// One float weight matrix requantized into the 8-bit integer pipeline.
#[derive(Clone, Debug)]
pub struct ImportedLayer {
    /// `weights[out][in]`, every entry in −127..=127.
    pub weights: Vec<Vec<i64>>,
    /// Power-of-two scale: original ≈ quantized · 2^exponent.
    pub exponent: i32,
    /// The [`shift_for`]-chosen requantization shift a following activation
    /// must apply to bring this layer's worst-case MAC accumulator back to
    /// 8-bit range.
    pub act_shift: u32,
    /// Bits the worst-case signed accumulator (127·127·fan_in) occupies.
    pub acc_bits: u32,
}

/// Import externally-trained float weight matrices into the 8-bit integer
/// pipeline (per-layer SWALP-style power-of-two quantization), checking the
/// worst-case MAC accumulator of every layer against the plan's bit budget
/// — the accumulator-bit-width discipline of the TFHE inference line
/// (arXiv 2302.10906). `layers[l]` is `[out][in]`, `in_dim` the input
/// feature width, `acc_budget_bits` the plaintext-modulus bit budget (e.g.
/// `log2 t` = 26 on the MAC profile). A layer whose accumulator cannot fit
/// is refused with the layer index and required width named, instead of
/// silently wrapping mid-inference.
pub fn import_f64_weights(
    layers: &[Vec<Vec<f64>>],
    in_dim: usize,
    acc_budget_bits: u32,
) -> Result<Vec<ImportedLayer>, String> {
    if layers.is_empty() {
        return Err("no weight matrices to import".into());
    }
    let mut expect_in = in_dim;
    let mut out = Vec::with_capacity(layers.len());
    for (l, m) in layers.iter().enumerate() {
        if m.is_empty() || m[0].is_empty() {
            return Err(format!("layer {l}: empty weight matrix"));
        }
        let fan_in = m[0].len();
        if m.iter().any(|row| row.len() != fan_in) {
            return Err(format!("layer {l}: ragged weight matrix"));
        }
        if fan_in != expect_in {
            return Err(format!(
                "layer {l}: expects {fan_in} inputs but the layer below produces {expect_in}"
            ));
        }
        // per-tensor power-of-two scale off the max-abs statistic
        let flat: Vec<f64> = m.iter().flatten().copied().collect();
        let (vs, exponent) = quantize_i8(&flat);
        let weights: Vec<Vec<i64>> = vs.chunks(fan_in).map(|c| c.to_vec()).collect();
        // worst-case signed accumulator: |x| ≤ 127, |w| ≤ 127, fan_in terms
        let max_acc = 127i64 * 127 * fan_in as i64;
        let acc_bits = 64 - max_acc.leading_zeros() + 1; // + sign bit
        if acc_bits > acc_budget_bits {
            return Err(format!(
                "layer {l}: worst-case accumulator needs {acc_bits} bits \
                 (fan-in {fan_in}), plan budget is {acc_budget_bits} — \
                 the MAC would wrap mid-inference"
            ));
        }
        out.push(ImportedLayer { weights, exponent, act_shift: shift_for(max_acc), acc_bits });
        expect_in = m.len();
    }
    Ok(out)
}

/// Choose the shift that brings `max_abs` into 8-bit range.
pub fn shift_for(max_abs: i64) -> u32 {
    let mut s = 0;
    let mut m = max_abs;
    while m > 127 {
        m >>= 1;
        s += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 - 50.0) * 0.37).collect();
        let (vs, e) = quantize_i8(&xs);
        let back = dequantize(&vs, e);
        let ulp = 2f64.powi(e);
        for (x, y) in xs.iter().zip(&back) {
            assert!((x - y).abs() <= ulp, "{x} vs {y}");
        }
        assert!(vs.iter().all(|&v| v.abs() <= 127));
    }

    #[test]
    fn zero_tensor() {
        let (vs, e) = quantize_i8(&[0.0; 8]);
        assert!(vs.iter().all(|&v| v == 0));
        assert_eq!(e, 0);
    }

    #[test]
    fn requantize_matches_switch_semantics() {
        // matches switch::extract::quantize_plain's round-to-nearest
        assert_eq!(requantize_shift(&[5 << 8, -(5i64 << 8), (5 << 8) + 200], 8), vec![5, -5, 6]);
    }

    #[test]
    fn import_quantizes_each_layer_to_8bit() {
        // a 4-3-2 float MLP, values spread over different magnitudes
        let l0: Vec<Vec<f64>> = (0..3)
            .map(|j| (0..4).map(|i| (j as f64 - 1.0) * 0.8 + i as f64 * 0.13).collect())
            .collect();
        let l1: Vec<Vec<f64>> = (0..2).map(|j| (0..3).map(|i| (i + j) as f64 * 21.5 - 30.0).collect()).collect();
        let imported = import_f64_weights(&[l0.clone(), l1], 4, 26).unwrap();
        assert_eq!(imported.len(), 2);
        assert_eq!(imported[0].weights.len(), 3);
        assert_eq!(imported[0].weights[0].len(), 4);
        assert!(imported.iter().all(|il| il.weights.iter().flatten().all(|&w| w.abs() <= 127)));
        // dequantized weights approximate the originals within one ulp
        let ulp = 2f64.powi(imported[0].exponent);
        for (qrow, frow) in imported[0].weights.iter().zip(&l0) {
            for (&q, &x) in qrow.iter().zip(frow) {
                assert!((q as f64 * ulp - x).abs() <= ulp, "{q} vs {x}");
            }
        }
        // act_shift brings the worst-case accumulator back under 8 bits
        assert_eq!(imported[0].act_shift, shift_for(127 * 127 * 4));
    }

    #[test]
    fn import_refuses_accumulator_overflow() {
        // fan-in 784: accumulator needs ~24 magnitude bits; a 16-bit budget
        // must refuse with the layer and widths named
        let wide = vec![vec![0.5f64; 784]; 4];
        let err = import_f64_weights(&[wide], 784, 16).unwrap_err();
        assert!(err.contains("layer 0") && err.contains("16"), "{err}");
        // geometry chain mismatches are named too
        let l0 = vec![vec![0.1f64; 4]; 3];
        let l1 = vec![vec![0.1f64; 5]; 2]; // expects 5, gets 3
        let err = import_f64_weights(&[l0, l1], 4, 26).unwrap_err();
        assert!(err.contains("layer 1"), "{err}");
    }

    #[test]
    fn shift_for_ranges() {
        assert_eq!(shift_for(100), 0);
        assert_eq!(shift_for(127), 0);
        assert_eq!(shift_for(128), 1);
        assert_eq!(shift_for(127 * 127 * 784), 17);
    }
}
