//! Pluggable execution backends: the FHE path (`GlyphEngine`'s key
//! material) and the bit-exact plaintext mirror ([`ClearBackend`]).
//!
//! The clear backend executes every homomorphic op on plain `i64`/`u64`
//! lanes with semantics chosen so that each op's result equals
//! `decrypt(FHE(op))` *by construction*:
//!
//! * **BGV side** — a [`ClearCt`] is exactly the plaintext polynomial a BGV
//!   ciphertext encrypts, kept as canonical residues mod `t`. MultCC is the
//!   negacyclic polynomial product (sparse: only the populated batch lanes
//!   are convolved, so the gradient convolution trick costs `O(batch²)` per
//!   weight instead of `O(N²)`), MultCP scales by the weight scalar, AddCC
//!   adds coefficientwise — precisely BGV's plaintext homomorphism.
//! * **Switch down (BGV→TFHE)** — the delivered 8-bit two's-complement
//!   value is [`crate::switch::extract::quantize_plain`] of the pre-shifted
//!   coefficient: the top 8 bits of `m·2^pre mod t`, round-to-nearest (the
//!   half-window guard the real extraction adds). Because plaintexts are
//!   integers, every phase sits on the `2^(32−log2 t)` torus grid, at least
//!   a full grid step from any PBS decision boundary except at exact
//!   rounding ties — the same set on which the lattice path's own noise
//!   decides the bit, so the mirror is as faithful as the cryptography
//!   permits (the differential suite pins seeds, `GLYPH_PROP_SEED` replays).
//! * **TFHE side** — a [`Bit`] in clear mode carries the *exact noiseless
//!   torus phase* (`u32`) the gate pipeline would produce: gate bootstraps
//!   output exactly `±µ`, weighted ANDs exactly `{0, 2^pos}`, the MUX's two
//!   half-bootstraps recombine by the same wrapping arithmetic. All
//!   decisions mirror the sign test on phases whose margins (≥ 2^26) dwarf
//!   gate noise, so the booleans agree with the lattice path bit for bit.
//! * **Switch up (TFHE→BGV)** — the modulus raise reads the recomposed
//!   phase on the 2^24 grid exactly as `switch::repack::raise` does:
//!   `((phase + 2^23) >> 24) & 0xFF` as signed 8-bit.
//!
//! Gradient truncation (`∇ >> grad_shift`, via the switch round trip at the
//! batch-sum coefficient) and the SGD weight-update subtraction therefore
//! round identically on both backends, which is what the
//! `tests/backend_equivalence.rs` differential suite asserts byte-for-byte.

use crate::bgv::{BgvCiphertext, BgvParams, CachedPlaintext, Plaintext};
use crate::switch::extract::quantize_plain;
use crate::switch::{SWITCH_BITS, VALUE_POS};
use crate::tfhe::{decode_bit, LweCiphertext, TestPoly, MU_BIT};
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Clear BGV-side values
// ---------------------------------------------------------------------------

/// The plaintext polynomial a BGV ciphertext would encrypt: canonical
/// residues in `[0, t)`, stored sparsely (`coeffs.len() ≤ n`; coefficients
/// past the stored length are zero). Ring degree `n` and plaintext modulus
/// `t` ride along so every op is self-contained.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClearCt {
    pub n: usize,
    pub t: u64,
    pub coeffs: Vec<u64>,
}

/// Canonical residue of a signed value mod `t`.
#[inline]
pub fn canon(v: i64, t: u64) -> u64 {
    v.rem_euclid(t as i64) as u64
}

impl ClearCt {
    pub fn zero(n: usize, t: u64) -> Self {
        ClearCt { n, t, coeffs: Vec::new() }
    }

    /// From a plaintext (the clear analogue of encryption).
    pub fn from_plaintext(pt: &Plaintext, n: usize) -> Self {
        let t = pt.t;
        let mut c = ClearCt::zero(n, t);
        for (i, &v) in pt.coeffs.iter().enumerate() {
            if v != 0 {
                c.set(i, canon(v, t));
            }
        }
        c
    }

    /// Coefficient `i` as a canonical residue (0 past the stored length).
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        debug_assert!(i < self.n, "coefficient {i} outside the {}-slot ring", self.n);
        self.coeffs.get(i).copied().unwrap_or(0)
    }

    /// Set coefficient `i`, growing the stored prefix as needed.
    pub fn set(&mut self, i: usize, v: u64) {
        debug_assert!(i < self.n);
        if self.coeffs.len() <= i {
            self.coeffs.resize(i + 1, 0);
        }
        self.coeffs[i] = v % self.t;
    }

    /// Centered signed reads of the first `count` coefficients — exactly
    /// what decrypting the corresponding BGV ciphertext returns, including
    /// the decode-width validation (`Plaintext::try_decode_batch`'s rule).
    pub fn decode_batch(&self, count: usize) -> Vec<i64> {
        if count > self.n {
            panic!(
                "decode_batch: decode of {count} lanes exceeds the {} coefficients the ring holds",
                self.n
            );
        }
        (0..count).map(|i| Plaintext::center(self.get(i), self.t)).collect()
    }

    pub fn add_assign(&mut self, o: &ClearCt) {
        debug_assert_eq!(self.t, o.t);
        if self.coeffs.len() < o.coeffs.len() {
            self.coeffs.resize(o.coeffs.len(), 0);
        }
        for (a, &b) in self.coeffs.iter_mut().zip(&o.coeffs) {
            *a = (*a + b) % self.t;
        }
    }

    pub fn sub_assign(&mut self, o: &ClearCt) {
        debug_assert_eq!(self.t, o.t);
        if self.coeffs.len() < o.coeffs.len() {
            self.coeffs.resize(o.coeffs.len(), 0);
        }
        for (a, &b) in self.coeffs.iter_mut().zip(&o.coeffs) {
            *a = (*a + self.t - b) % self.t;
        }
    }

    /// Scale every coefficient by a signed scalar — multiplication by the
    /// constant polynomial `w` (a weight).
    pub fn scalar_mul_assign(&mut self, w: i64) {
        let t = self.t;
        let wu = canon(w, t) as u128;
        for a in self.coeffs.iter_mut() {
            *a = ((*a as u128 * wu) % t as u128) as u64;
        }
    }

    /// Negacyclic product mod `(X^n + 1, t)`, sparse over the populated
    /// coefficients of both operands (the gradient convolution trick only
    /// ever multiplies batch-width supports).
    pub fn mul_assign(&mut self, o: &ClearCt) {
        debug_assert_eq!(self.t, o.t);
        debug_assert_eq!(self.n, o.n);
        let t = self.t as u128;
        let n = self.n;
        let a: Vec<(usize, u64)> =
            self.coeffs.iter().enumerate().filter(|(_, &v)| v != 0).map(|(i, &v)| (i, v)).collect();
        let b: Vec<(usize, u64)> =
            o.coeffs.iter().enumerate().filter(|(_, &v)| v != 0).map(|(i, &v)| (i, v)).collect();
        let top = match (a.last(), b.last()) {
            (Some(&(ia, _)), Some(&(ib, _))) => (ia + ib).min(n - 1),
            _ => 0,
        };
        let mut out = vec![0u64; if a.is_empty() || b.is_empty() { 0 } else { top + 1 }];
        for &(i, av) in &a {
            for &(j, bv) in &b {
                let p = ((av as u128 * bv as u128) % t) as u64;
                let k = i + j;
                if k < n {
                    out[k] = (out[k] + p) % self.t;
                } else {
                    // X^n = −1 wrap
                    let k = k - n;
                    out[k] = (out[k] + self.t - p) % self.t;
                }
            }
        }
        self.coeffs = out;
    }
}

// ---------------------------------------------------------------------------
// Backend-polymorphic values
// ---------------------------------------------------------------------------

/// A BGV-side value under either backend. Layers and tensors hold these;
/// only `GlyphEngine`'s counted ops (and the codecs) look inside.
#[derive(Clone)]
pub enum Ct {
    Fhe(BgvCiphertext),
    Clear(ClearCt),
}

impl Ct {
    pub fn fhe(&self) -> &BgvCiphertext {
        match self {
            Ct::Fhe(ct) => ct,
            Ct::Clear(_) => panic!("expected an FHE ciphertext but found a clear-backend value"),
        }
    }

    pub fn fhe_mut(&mut self) -> &mut BgvCiphertext {
        match self {
            Ct::Fhe(ct) => ct,
            Ct::Clear(_) => panic!("expected an FHE ciphertext but found a clear-backend value"),
        }
    }

    pub fn clear(&self) -> &ClearCt {
        match self {
            Ct::Clear(c) => c,
            Ct::Fhe(_) => panic!("expected a clear-backend value but found an FHE ciphertext"),
        }
    }

    pub fn clear_mut(&mut self) -> &mut ClearCt {
        match self {
            Ct::Clear(c) => c,
            Ct::Fhe(_) => panic!("expected a clear-backend value but found an FHE ciphertext"),
        }
    }

    pub fn is_clear(&self) -> bool {
        matches!(self, Ct::Clear(_))
    }
}

/// A TFHE-side value under either backend. In clear mode it carries the
/// exact noiseless torus phase the gate pipeline would produce, so boolean
/// decisions and the weighted 2^24-grid recomposition mirror bit for bit.
#[derive(Clone, Debug)]
pub enum Bit {
    Fhe(LweCiphertext),
    Clear(u32),
}

impl Bit {
    pub fn fhe(&self) -> &LweCiphertext {
        match self {
            Bit::Fhe(c) => c,
            Bit::Clear(_) => panic!("expected an FHE LWE but found a clear-backend phase"),
        }
    }

    pub fn phase(&self) -> u32 {
        match self {
            Bit::Clear(p) => *p,
            Bit::Fhe(_) => panic!("expected a clear-backend phase but found an FHE LWE"),
        }
    }

    /// Plain LWE addition (recomposition sums weighted bits).
    pub fn add_assign(&mut self, o: &Bit) {
        match (self, o) {
            (Bit::Fhe(a), Bit::Fhe(b)) => a.add_assign(b),
            (Bit::Clear(a), Bit::Clear(b)) => *a = a.wrapping_add(*b),
            _ => panic!("cannot mix FHE and clear TFHE values"),
        }
    }

    /// Add a plaintext constant to the phase.
    pub fn add_constant(&mut self, mu: u32) {
        match self {
            Bit::Fhe(c) => c.add_constant(mu),
            Bit::Clear(p) => *p = p.wrapping_add(mu),
        }
    }
}

/// A frozen (plaintext) weight under either backend: the FHE path caches
/// the per-level NTT lifts once (scalar *and* polynomial weights ride the
/// same cache); the clear path keeps the scalar — or, for the packed
/// layouts' per-block weight polynomials, the full coefficient mirror.
#[derive(Clone)]
pub enum PlainWeight {
    Fhe(Arc<CachedPlaintext>),
    Clear(i64),
    /// Clear mirror of a polynomial plaintext weight (packed conv blocks).
    ClearPoly(Arc<ClearCt>),
}

impl PlainWeight {
    /// The weight scalar (inspection / snapshots).
    pub fn value(&self) -> i64 {
        match self {
            PlainWeight::Fhe(c) => c.pt.coeffs[0],
            PlainWeight::Clear(v) => *v,
            PlainWeight::ClearPoly(_) => {
                panic!("a polynomial weight block has no single scalar value")
            }
        }
    }

    pub fn fhe_cached(&self) -> &CachedPlaintext {
        match self {
            PlainWeight::Fhe(c) => c,
            PlainWeight::Clear(_) | PlainWeight::ClearPoly(_) => {
                panic!("expected an FHE weight cache but found a clear-backend weight")
            }
        }
    }
}

/// One term of a MAC row, backend-neutral: ciphertext×ciphertext or
/// ciphertext×plaintext-weight. `GlyphEngine::mac_rows_*` consumes these
/// and counts MultCC/MultCP per variant identically on both backends.
pub enum Term<'a> {
    Cc(&'a Ct, &'a Ct),
    Cp(&'a Ct, &'a PlainWeight),
}

/// A prebuilt plaintext summand (one value at a fixed position set) for
/// the free AddCP — built once per frozen bias/channel by
/// `GlyphEngine::plain_at` and reused across every ciphertext it is added
/// to, so the FHE path pays its ring-sized plaintext a single time.
pub enum PlainVector {
    Fhe(Plaintext),
    Clear { value: i64, positions: Vec<usize> },
}

// ---------------------------------------------------------------------------
// The clear backend
// ---------------------------------------------------------------------------

/// The plaintext execution backend: parameters only, no key material — setup
/// is instant and every op is integer arithmetic, so full epochs run in
/// seconds while remaining bit-identical to the decrypted FHE pipeline.
pub struct ClearBackend {
    pub params: BgvParams,
    /// Digit-extraction blind-rotation ring degree (the PBS model for the
    /// fast-softmax ablation mirrors the real ring's window grid).
    pub ext_big_n: usize,
}

impl ClearBackend {
    pub fn new(params: BgvParams, ext_big_n: usize) -> Self {
        ClearBackend { params, ext_big_n }
    }

    /// The 8-bit two's-complement value the switch delivers for canonical
    /// coefficient `mu` pre-shifted by `pre_shift` — `quantize_plain` of
    /// `mu·2^pre mod t` (top 8 bits, round-to-nearest).
    pub fn quantize(&self, mu: u64, pre_shift: u32) -> i64 {
        let t = self.params.t;
        let shifted = ((mu as u128) << pre_shift) % t as u128;
        quantize_plain(shifted as i64, t)
    }

    /// The modulus raise's read of a recomposed phase: signed 8-bit on the
    /// 2^24 grid, round-to-nearest (mirrors `switch::repack::raise`).
    pub fn raise_value(&self, phase: u32) -> i64 {
        let v = (phase.wrapping_add(1 << (VALUE_POS - 1)) >> VALUE_POS) & 0xFF;
        if v >= 128 {
            v as i64 - 256
        } else {
            v as i64
        }
    }

    /// Noiseless programmable bootstrap on an exact phase: the blind-rotate
    /// modulus switch to `Z_2N` (round-to-nearest) followed by the
    /// negacyclic test-polynomial read — exactly what
    /// `BootstrapKey::blind_rotate` computes on a trivial input.
    pub fn pbs_model(&self, phase: u32, tv: &TestPoly) -> u32 {
        let big_n = tv.coeffs.len();
        let n2 = 2 * big_n as u32;
        let log2n2 = n2.trailing_zeros();
        let shift = 32 - log2n2;
        let half = 1u32 << (shift - 1);
        let bar = (phase.wrapping_add(half) >> shift) & (n2 - 1);
        if (bar as usize) < big_n {
            tv.coeffs[bar as usize]
        } else {
            tv.coeffs[bar as usize - big_n].wrapping_neg()
        }
    }

    /// The two's-complement bits (MSB first) of a quantized value, as
    /// gate-encoded clear phases — what `switch_down` delivers per lane.
    pub fn value_bits(&self, v: i64) -> Vec<Bit> {
        let byte = (v & 0xFF) as u8;
        (0..SWITCH_BITS)
            .map(|k| Bit::Clear(crate::tfhe::encode_bit((byte >> (SWITCH_BITS - 1 - k)) & 1 == 1)))
            .collect()
    }

    // ---- exact noiseless gate mirrors --------------------------------------

    /// `bootstrap_sign(a + b − 1/8, mu)`: the AND-family linear part and
    /// sign decision on exact phases. All gate operands sit ≥ 2^26 from the
    /// sign boundary, so this equals the lattice gate's decision.
    pub fn and_phase(a: u32, b: u32, mu: u32) -> u32 {
        let lin = a.wrapping_add(b).wrapping_sub(MU_BIT);
        if decode_bit(lin) {
            mu
        } else {
            mu.wrapping_neg()
        }
    }

    /// Weighted AND: true lands exactly at `2^pos`, false at 0.
    pub fn and_weighted_phase(a: u32, b: u32, pos: u32) -> u32 {
        let mu = 1u32 << (pos - 1);
        Self::and_phase(a, b, mu).wrapping_add(mu)
    }

    /// The homomorphic MUX's two half-bootstraps + recentering, on exact
    /// phases (mirrors `TfheCloudKey::mux`).
    pub fn mux_phase(s: u32, d1: u32, d0: u32) -> u32 {
        let h = MU_BIT >> 1;
        let lin1 = s.wrapping_add(d1).wrapping_sub(MU_BIT);
        let t1 = if decode_bit(lin1) { h } else { h.wrapping_neg() };
        let lin0 = s.wrapping_neg().wrapping_add(d0).wrapping_sub(MU_BIT);
        let t0 = if decode_bit(lin0) { h } else { h.wrapping_neg() };
        t1.wrapping_add(t0).wrapping_add(h)
    }
}

// ---------------------------------------------------------------------------
// Codecs: the client-side encode/decode surface shared by both backends
// ---------------------------------------------------------------------------

/// Client-side encoding: what `ClientKeys` does with the secret key on the
/// FHE backend, and what [`ClearCodec`] does with plain arithmetic on the
/// clear backend. Model builders and the `Trainer` take `&mut dyn Codec` so
/// one code path serves both.
pub trait Codec {
    /// Encode a batch of 8-bit values at fixed-point scale `shift`.
    fn encrypt_batch(&mut self, values: &[i64], shift: u32) -> Ct;
    /// Encode a single weight scalar as a constant polynomial.
    fn encrypt_scalar(&mut self, w: i64) -> Ct;
    /// Decode a batch (optionally un-scaling by `shift`).
    fn decrypt_batch(&self, ct: &Ct, lanes: usize, shift: u32) -> Vec<i64>;
    /// Encode an explicit coefficient vector (values scaled by `shift`).
    /// The packed (cross-sample SIMD) layouts assemble their interleaved
    /// slot blocks — minibatch inputs via `PackedLayout::pack_columns`,
    /// weight blocks at `PackedLayout::weight_positions` — and encrypt the
    /// raw coefficients through this.
    fn encrypt_coeffs(&mut self, coeffs: &[i64], shift: u32) -> Ct;
    /// Decode arbitrary coefficient positions (un-scaling by `shift`) —
    /// the packed layouts' read-back counterpart of
    /// [`Codec::encrypt_coeffs`].
    fn decrypt_positions(&self, ct: &Ct, positions: &[usize], shift: u32) -> Vec<i64>;
}

/// The clear backend's codec: no keys, just the ring parameters. Encoding
/// validates exactly like `Plaintext::encode_batch` (descriptive errors on
/// over-capacity batches / out-of-range values).
pub struct ClearCodec {
    pub params: BgvParams,
}

impl Codec for ClearCodec {
    fn encrypt_batch(&mut self, values: &[i64], shift: u32) -> Ct {
        let scaled: Vec<i64> = values.iter().map(|&v| v << shift).collect();
        let pt = Plaintext::encode_batch(&scaled, &self.params);
        Ct::Clear(ClearCt::from_plaintext(&pt, self.params.n))
    }

    fn encrypt_scalar(&mut self, w: i64) -> Ct {
        let pt = Plaintext::encode_scalar(w, &self.params);
        Ct::Clear(ClearCt::from_plaintext(&pt, self.params.n))
    }

    fn decrypt_batch(&self, ct: &Ct, lanes: usize, shift: u32) -> Vec<i64> {
        ct.clear().decode_batch(lanes).into_iter().map(|v| v >> shift).collect()
    }

    fn encrypt_coeffs(&mut self, coeffs: &[i64], shift: u32) -> Ct {
        let scaled: Vec<i64> = coeffs.iter().map(|&v| v << shift).collect();
        let pt = Plaintext::encode_batch(&scaled, &self.params);
        Ct::Clear(ClearCt::from_plaintext(&pt, self.params.n))
    }

    fn decrypt_positions(&self, ct: &Ct, positions: &[usize], shift: u32) -> Vec<i64> {
        let c = ct.clear();
        positions.iter().map(|&p| Plaintext::center(c.get(p), c.t) >> shift).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bgv::BgvParams;

    fn p() -> BgvParams {
        BgvParams::test_params()
    }

    #[test]
    fn clear_ct_add_sub_scale_roundtrip() {
        let params = p();
        let mut a = ClearCt::from_plaintext(&Plaintext::encode_batch(&[5, -7, 0, 3], &params), params.n);
        let b = ClearCt::from_plaintext(&Plaintext::encode_batch(&[1, 2, -3], &params), params.n);
        a.add_assign(&b);
        assert_eq!(a.decode_batch(4), vec![6, -5, -3, 3]);
        a.sub_assign(&b);
        assert_eq!(a.decode_batch(4), vec![5, -7, 0, 3]);
        a.scalar_mul_assign(-4);
        assert_eq!(a.decode_batch(4), vec![-20, 28, 0, -12]);
    }

    #[test]
    fn negacyclic_mul_matches_convolution_trick() {
        // forward-packed x times reverse-packed δ leaves Σ x_b·δ_b at
        // coefficient batch−1 — the gradient reduction.
        let params = p();
        let x_vals = vec![3i64, -2, 5, 1];
        let mut d_vals = vec![2i64, 4, -1, 3];
        d_vals.reverse();
        let mut x = ClearCt::from_plaintext(&Plaintext::encode_batch(&x_vals, &params), params.n);
        let d = ClearCt::from_plaintext(&Plaintext::encode_batch(&d_vals, &params), params.n);
        x.mul_assign(&d);
        let want: i64 = [3 * 2, -2 * 4, 5 * -1, 1 * 3].iter().sum();
        assert_eq!(x.decode_batch(4)[3], want);
    }

    #[test]
    fn negacyclic_wrap_negates() {
        let params = p();
        let n = params.n;
        let mut a = ClearCt::zero(n, params.t);
        a.set(n - 1, 2);
        let mut b = ClearCt::zero(n, params.t);
        b.set(2, 3);
        a.mul_assign(&b);
        // X^(n−1)·3X² = 3·2·X^(n+1) = −6·X
        assert_eq!(a.decode_batch(2), vec![0, -6]);
    }

    #[test]
    fn quantize_matches_switch_reference() {
        let cb = ClearBackend::new(p(), 2048);
        let t = cb.params.t;
        let frac = t.trailing_zeros() - SWITCH_BITS;
        for v in [0i64, 5, -5, 127, -128] {
            let mu = canon(v << frac, t);
            assert_eq!(cb.quantize(mu, 0), v, "value {v}");
        }
        // sub-quantization residue rounds to nearest
        let mu = canon((5 << frac) + 200, t);
        assert_eq!(cb.quantize(mu, 0), 6);
        // pre-shift moves lower-scale values into the window
        let mu = canon(9 << 4, t);
        assert_eq!(cb.quantize(mu, frac - 4), 9);
    }

    #[test]
    fn raise_reads_the_weighted_grid() {
        let cb = ClearBackend::new(p(), 2048);
        for v in [0i64, 1, -1, 42, -42, 127, -128] {
            let phase = ((v as i64) << VALUE_POS) as u32;
            assert_eq!(cb.raise_value(phase), v, "value {v}");
        }
    }

    #[test]
    fn gate_phase_mirrors_are_boolean_exact() {
        use crate::tfhe::{decode_bit, encode_bit};
        for a in [false, true] {
            for b in [false, true] {
                let pa = encode_bit(a);
                let pb = encode_bit(b);
                assert_eq!(decode_bit(ClearBackend::and_phase(pa, pb, MU_BIT)), a && b);
                let w = ClearBackend::and_weighted_phase(pa, pb, 27);
                assert_eq!(w, if a && b { 1 << 27 } else { 0 });
                for s in [false, true] {
                    let m = ClearBackend::mux_phase(encode_bit(s), pa, pb);
                    assert_eq!(decode_bit(m), if s { a } else { b }, "s={s} a={a} b={b}");
                }
            }
        }
    }

    #[test]
    fn pbs_model_reads_windows_and_mirror() {
        let cb = ClearBackend::new(p(), 2048);
        let n = 512;
        let tv = TestPoly::from_fn(n, |w| ((w * 4 / n) as u32) << 28);
        for i in 0..4u32 {
            let phase = (i * 2 + 1) << 28; // mid-window of step i
            assert_eq!(cb.pbs_model(phase, &tv), i << 28, "window {i}");
        }
        // negative half mirrors negacyclically
        let tvc = TestPoly::constant(n, 1 << 29);
        assert_eq!(cb.pbs_model((3u32 << 29).wrapping_neg(), &tvc), (1u32 << 29).wrapping_neg());
    }

    #[test]
    fn clear_codec_roundtrip() {
        let mut codec = ClearCodec { params: p() };
        let vals = vec![1i64, -2, 3, -4];
        let ct = codec.encrypt_batch(&vals, 3);
        assert_eq!(codec.decrypt_batch(&ct, 4, 3), vals);
    }

    #[test]
    fn clear_codec_coeffs_roundtrip() {
        use crate::nn::tensor::PackedLayout;
        let mut codec = ClearCodec { params: p() };
        let layout = PackedLayout::for_ring(3, codec.params.n).unwrap();
        let cols = vec![vec![1i64, -2, 3], vec![4, -5, 6]];
        let blocks = layout.pack_columns(&cols, codec.params.n);
        let ct = codec.encrypt_coeffs(&blocks[0], 2);
        // feature k, sample b at k·stride + b, scaled by 2^2
        let pos = layout.block_positions(crate::nn::tensor::PackOrder::Forward, 2);
        assert_eq!(codec.decrypt_positions(&ct, &pos, 2), vec![1, -2, 3, 4, -5, 6]);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn clear_decode_past_ring_panics_like_the_fhe_path() {
        let params = p();
        let n = params.n;
        let ct = ClearCt::zero(n, params.t);
        let _ = ct.decode_batch(n + 1);
    }
}
