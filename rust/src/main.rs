//! `glyph` — the L3 coordinator CLI.
//!
//! Subcommands (hand-rolled parsing; the vendored crate set has no clap):
//!
//! * `info`                — parameters, profiles, artifact status
//! * `plan`                — print the MLP cryptosystem schedule (Table-3 Switch column)
//! * `microbench [--full]` — per-op latencies (Table 1, ours vs paper)
//! * `tables [--measured]` — regenerate Tables 2/3/4 (paper-calibrated by default)
//! * `train-mlp [--steps N] [--batch B]` — reduced-scale encrypted MLP training
//!
//! The `examples/` binaries are the full experiment drivers.

use glyph::coordinator::{cost, scheduler};
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::nn::tensor::{EncTensor, PackOrder};
use glyph::train::{GlyphMlp, MlpConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("info");
    let flag = |name: &str| args.iter().any(|a| a == name);
    let opt = |name: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };

    match cmd {
        "info" => {
            println!("Glyph reproduction — fast and accurate DNN training on encrypted data");
            println!("BGV (MAC profile): {:?}", glyph::bgv::BgvParams::mac_params().primes);
            println!("TFHE gate profile n=560 N=1024; extract profile N=4096");
            let have = std::path::Path::new("artifacts/mlp_train_step.hlo.txt").exists();
            println!("artifacts: {}", if have { "built" } else { "missing (run `make artifacts`)" });
            println!("threads available: {}", glyph::coordinator::max_threads());
        }
        "plan" => {
            let plan = scheduler::mlp_plan();
            println!("{:<16} {:<6} switch", "step", "system");
            for s in &plan.steps {
                println!("{:<16} {:<6?} {}", s.name, s.system, s.switch);
            }
            println!("switches: {} (valid: {})", plan.switch_count(), plan.validate());
        }
        "microbench" => {
            let test_scale = !flag("--full");
            eprintln!("measuring per-op latencies ({} profile)…", if test_scale { "test" } else { "default" });
            let ours = cost::OpLatencies::measure(test_scale);
            let paper = cost::OpLatencies::paper();
            println!("| op | ours (s) | paper (s) |");
            println!("|---|---|---|");
            println!("| MultCC | {:.6} | {:.3} |", ours.mult_cc, paper.mult_cc);
            println!("| MultCP | {:.6} | {:.3} |", ours.mult_cp, paper.mult_cp);
            println!("| AddCC | {:.6} | {:.4} |", ours.add_cc, paper.add_cc);
            println!("| TLU | {:.4} | {:.1} |", ours.tlu, paper.tlu);
            println!("| ReLU/value | {:.4} | {:.2} |", ours.relu_value, paper.relu_value);
            println!("| softmax/value | {:.4} | {:.2} |", ours.softmax_value, paper.softmax_value);
            println!("| switch B2T/value | {:.6} | {:.4} |", ours.switch_b2t_value, paper.switch_b2t_value);
            println!("| switch T2B/value | {:.6} | {:.4} |", ours.switch_t2b_value, paper.switch_t2b_value);
        }
        "tables" => {
            let lat = if flag("--measured") {
                eprintln!("measuring (this builds full-profile keys)…");
                cost::OpLatencies::measure(!flag("--full"))
            } else {
                cost::OpLatencies::paper()
            };
            let dims = [784, 128, 32, 10];
            println!("{}", cost::to_markdown("Table 2: FHESGD MLP (MNIST)", &cost::mlp_table(&dims, cost::Scheme::Fhesgd, &lat)));
            println!("{}", cost::to_markdown("Table 3: Glyph MLP (MNIST)", &cost::mlp_table(&dims, cost::Scheme::GlyphMlp, &lat)));
            println!("{}", cost::to_markdown("Table 4: Glyph CNN + TL (MNIST)", &cost::cnn_table(&cost::CnnShape::paper_mnist(), &lat)));
        }
        "train-mlp" => {
            let steps = opt("--steps", 2);
            let batch = opt("--batch", 4);
            eprintln!("encrypted MLP training, test profile, batch={batch}, steps={steps}");
            let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 20260710);
            let mut rng = glyph::math::GlyphRng::new(1);
            let mut mlp = GlyphMlp::new_random(MlpConfig::tiny(16, 8, 4), &mut client, &mut rng);
            let ds = glyph::data::synthetic_digits(batch * steps, 5, "cli");
            for step in 0..steps {
                // 4×4 center crop as 16 features
                let xs: Vec<Vec<i64>> = (0..16)
                    .map(|f| {
                        (0..batch)
                            .map(|b| {
                                let img = ds.image_i8(step * batch + b);
                                let (y, x) = (12 + f / 4, 12 + f % 4);
                                img[y * 28 + x]
                            })
                            .collect()
                    })
                    .collect();
                let x_cts = xs.iter().map(|v| client.encrypt_batch(v, 0)).collect();
                let x = EncTensor::new(x_cts, vec![16], PackOrder::Forward, 0);
                let labels: Vec<Vec<i64>> = (0..4)
                    .map(|k| {
                        let mut v: Vec<i64> = (0..batch)
                            .map(|b| if ds.labels[step * batch + b] % 4 == k as usize { 127 } else { 0 })
                            .collect();
                        v.reverse();
                        v
                    })
                    .collect();
                let lab_cts = labels.iter().map(|v| client.encrypt_batch(v, 0)).collect();
                let lab = EncTensor::new(lab_cts, vec![4], PackOrder::Reversed, 0);
                let t0 = std::time::Instant::now();
                mlp.train_step(&x, &lab, &engine);
                println!("step {step}: {:.2}s  {}", t0.elapsed().as_secs_f64(), engine.counter.snapshot());
            }
        }
        other => {
            eprintln!("unknown command {other}; see src/main.rs docs");
            std::process::exit(2);
        }
    }
    Ok(())
}
