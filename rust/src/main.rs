//! `glyph` — the L3 coordinator CLI.
//!
//! Subcommands (hand-rolled parsing; the vendored crate set has no clap):
//!
//! * `info`                — parameters, profiles, artifact status
//! * `plan [--cnn] [--dims a,b,c] [--batch N]`
//!                         — print the *compiled* cryptosystem schedule with
//!                           per-step op counts (Table-3 / Table-4 Switch
//!                           columns). `--cnn` compiles the transfer CNN;
//!                           `--dims` any MLP topology (shape-only compile,
//!                           no keys or weights are generated).
//! * `microbench [--full]` — per-op latencies (Table 1, ours vs paper)
//! * `tables [--measured]` — regenerate Tables 2/3/4 (paper-calibrated by default)
//! * `train-mlp [--backend clear|fhe] [--steps N] [--epochs E] [--batch B]
//!              [--dims a,b,c] [--samples M] [--dataset digits|mnist|cancer|svhn|cifar]`
//!                         — MLP training through the `NetworkBuilder` on the
//!                           selected execution backend. `--backend fhe`
//!                           (default) runs reduced-scale *encrypted* steps;
//!                           `--backend clear` runs the bit-exact plaintext
//!                           mirror, fast enough for full epochs + a test-
//!                           accuracy report (EXPERIMENTS.md §Backends).
//! * `infer [--model PATH] [--backend clear|fhe] [--packed] [--batch B]
//!          [--samples M] [--dims a,b,c] [--mode logits|argmax|topk] [--k K]
//!          [--seed S]`
//!                         — forward-only encrypted inference: a trained
//!                           model (`train-mlp --save-model`, or random
//!                           weights without `--model`) scores held-out
//!                           batches under a forward-only compiled plan
//!                           (zero backward steps), and the run fails if
//!                           live op counters drift from the plan's totals.
//!                           On FHE, `--seed` must be the training seed.
//! * `serve [--addr H:P] [--data-dir DIR] [--workers N]`
//!                         — the multi-tenant training job server
//!                           (EXPERIMENTS.md §Serving). With `--data-dir`,
//!                           jobs checkpoint every K steps and resume across
//!                           restarts.
//! * `submit | submit-infer | status | cancel | fetch-result | metrics |
//!    ping | shutdown`
//!                         — thin clients for a running server (all take
//!                           `--addr`; `status`/`cancel`/`fetch-result` take
//!                           `--id`). `submit` mirrors the train-mlp flags
//!                           plus `--tenant`, `--seed`, `--checkpoint-every`,
//!                           `--profile default|test`; `submit-infer` queues
//!                           a forward-only scoring job, optionally against
//!                           a completed training job's model (`--model-job`).
//!
//! The `examples/` binaries are the full experiment drivers.

use glyph::coordinator::cost;
use glyph::coordinator::metrics::OpSnapshot;
use glyph::coordinator::scheduler::Plan;
use glyph::data::Dataset;
use glyph::nn::backend::Codec;
use glyph::nn::engine::{EngineProfile, GlyphEngine};
use glyph::serve::{
    Fetched, InferSpec, JobBackend, JobSpec, RunningServer, ServeClient, ServeConfig,
};
use glyph::train::{
    CnnConfig, GlyphMlp, InferenceSession, MlpConfig, OutputMode, Predictions, Trainer,
};
use std::path::PathBuf;

const DEFAULT_ADDR: &str = "127.0.0.1:7421";

fn parse_dims(spec: &str) -> anyhow::Result<Vec<usize>> {
    let dims: Vec<usize> = spec
        .split(',')
        .map(|p| p.trim().parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("bad --dims {spec:?}: {e}"))?;
    if dims.len() < 2 || dims.iter().any(|&d| d == 0) {
        anyhow::bail!("--dims needs at least two nonzero widths, got {spec:?}");
    }
    Ok(dims)
}

/// The value following `--name`, if the flag is present. A missing value or
/// a value that fails to parse is an error — not silently the default
/// (`--epochs ten` used to train for 1 epoch without a word).
fn flag_value<T: std::str::FromStr>(args: &[String], name: &str) -> anyhow::Result<Option<T>>
where
    T::Err: std::fmt::Display,
{
    let Some(i) = args.iter().position(|a| a == name) else {
        return Ok(None);
    };
    let value = args
        .get(i + 1)
        .ok_or_else(|| anyhow::anyhow!("flag {name} requires a value"))?;
    if value.starts_with("--") {
        anyhow::bail!("flag {name} requires a value, got flag {value:?} instead");
    }
    value
        .parse::<T>()
        .map(Some)
        .map_err(|e| anyhow::anyhow!("bad {name} value {value:?}: {e}"))
}

fn print_plan(plan: &Plan) {
    println!(
        "{:<16} {:<6} {:<9} {:>10} {:>9} {:>10} {:>6} {:>7} {:>6} {:>6}",
        "step", "system", "switch", "MultCC", "MultCP", "AddCC", "TLU", "Gates", "B2T", "T2B"
    );
    for s in &plan.steps {
        println!(
            "{:<16} {:<6?} {:<9} {:>10} {:>9} {:>10} {:>6} {:>7} {:>6} {:>6}",
            s.name,
            s.system,
            s.switch,
            s.ops.mult_cc,
            s.ops.mult_cp,
            s.ops.add_cc,
            s.ops.tlu,
            s.ops.act_gates,
            s.ops.switch_b2t,
            s.ops.switch_t2b
        );
    }
    let t = plan.totals();
    println!(
        "{:<16} {:<6} {:<9} {:>10} {:>9} {:>10} {:>6} {:>7} {:>6} {:>6}",
        "Total", "", "", t.mult_cc, t.mult_cp, t.add_cc, t.tlu, t.act_gates, t.switch_b2t, t.switch_t2b
    );
    println!("switches: {} (valid: {})", plan.switch_count(), plan.validate());
}

fn print_status(st: &glyph::serve::JobStatus) {
    println!("job {} (tenant {}): {}", st.id, st.tenant, st.state.name());
    if !st.message.is_empty() {
        println!("  message: {}", st.message);
    }
    println!(
        "  epoch {}, step {}/{}, checkpoints {}, resumes {}",
        st.epoch, st.step, st.total_steps, st.checkpoints, st.resumes
    );
    if st.group != 0 {
        println!("  coalesced into batch group {}", st.group);
    }
    println!("  live ops:      {}", st.live_ops);
    println!("  predicted ops: {}", st.predicted_ops);
    println!(
        "  plan drift (predicted counters): {}",
        glyph::serve::metrics::op_drift(&st.live_ops, &st.predicted_ops)
    );
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("info");
    let flag = |name: &str| args.iter().any(|a| a == name);
    let opt_str = |name: &str| -> anyhow::Result<Option<String>> { flag_value(&args, name) };
    let opt = |name: &str, default: usize| -> anyhow::Result<usize> {
        Ok(flag_value(&args, name)?.unwrap_or(default))
    };
    let opt_u64 = |name: &str, default: u64| -> anyhow::Result<u64> {
        Ok(flag_value(&args, name)?.unwrap_or(default))
    };
    let req_id = || -> anyhow::Result<u64> {
        flag_value(&args, "--id")?.ok_or_else(|| anyhow::anyhow!("--id <job> is required"))
    };
    let addr = || -> anyhow::Result<String> {
        Ok(flag_value(&args, "--addr")?.unwrap_or_else(|| DEFAULT_ADDR.into()))
    };
    let connect = || -> anyhow::Result<ServeClient> {
        let addr = addr()?;
        ServeClient::connect(addr.as_str())
            .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))
    };

    match cmd {
        "info" => {
            println!("Glyph reproduction — fast and accurate DNN training on encrypted data");
            println!("BGV (MAC profile): {:?}", glyph::bgv::BgvParams::mac_params().primes);
            println!("TFHE gate profile n=560 N=1024; extract profile N=4096");
            let have = std::path::Path::new("artifacts/mlp_train_step.hlo.txt").exists();
            println!("artifacts: {}", if have { "built" } else { "missing (run `make artifacts`)" });
            println!("threads available: {}", glyph::coordinator::max_threads());
        }
        "plan" => {
            // paper mini-batch width unless overridden
            let batch = opt("--batch", 60)?;
            if flag("--cnn") {
                let config = CnnConfig::paper_mnist();
                let (c1, c2) = config.conv_channels;
                let bn1 = glyph::nn::batchnorm::BnLayer {
                    gain: vec![1; c1],
                    bias: vec![0; c1],
                    gain_shift: 0,
                };
                let bn2 = glyph::nn::batchnorm::BnLayer {
                    gain: vec![1; c2],
                    bias: vec![0; c2],
                    gain_shift: 0,
                };
                let plan = config
                    .builder(None, bn1, None, bn2)
                    .map_err(|e| anyhow::anyhow!("{e}"))?
                    .compile(batch)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                println!("compiled transfer-CNN schedule (paper MNIST shape, batch {batch}):");
                print_plan(&plan);
            } else {
                let config = match opt_str("--dims")? {
                    Some(spec) => MlpConfig::for_dims(parse_dims(&spec)?, 18, 8),
                    None => MlpConfig::paper_mlp(),
                };
                let plan = config
                    .builder()
                    .map_err(|e| anyhow::anyhow!("{e}"))?
                    .compile(batch)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                println!("compiled MLP schedule (dims {:?}, batch {batch}):", config.dims);
                print_plan(&plan);
            }
        }
        "microbench" => {
            let test_scale = !flag("--full");
            eprintln!("measuring per-op latencies ({} profile)…", if test_scale { "test" } else { "default" });
            let ours = cost::OpLatencies::measure(test_scale);
            let paper = cost::OpLatencies::paper();
            println!("| op | ours (s) | paper (s) |");
            println!("|---|---|---|");
            println!("| MultCC | {:.6} | {:.3} |", ours.mult_cc, paper.mult_cc);
            println!("| MultCP | {:.6} | {:.3} |", ours.mult_cp, paper.mult_cp);
            println!("| AddCC | {:.6} | {:.4} |", ours.add_cc, paper.add_cc);
            println!("| TLU | {:.4} | {:.1} |", ours.tlu, paper.tlu);
            println!("| ReLU/value | {:.4} | {:.2} |", ours.relu_value, paper.relu_value);
            println!("| softmax/value | {:.4} | {:.2} |", ours.softmax_value, paper.softmax_value);
            println!("| switch B2T/value | {:.6} | {:.4} |", ours.switch_b2t_value, paper.switch_b2t_value);
            println!("| switch T2B/value | {:.6} | {:.4} |", ours.switch_t2b_value, paper.switch_t2b_value);
        }
        "tables" => {
            let lat = if flag("--measured") {
                eprintln!("measuring (this builds full-profile keys)…");
                cost::OpLatencies::measure(!flag("--full"))
            } else {
                cost::OpLatencies::paper()
            };
            let dims = [784, 128, 32, 10];
            println!("{}", cost::to_markdown("Table 2: FHESGD MLP (MNIST)", &cost::mlp_table(&dims, cost::Scheme::Fhesgd, &lat)));
            println!("{}", cost::to_markdown("Table 3: Glyph MLP (MNIST)", &cost::mlp_table(&dims, cost::Scheme::GlyphMlp, &lat)));
            println!("{}", cost::to_markdown("Table 4: Glyph CNN + TL (MNIST)", &cost::cnn_table(&cost::CnnShape::paper_mnist(), &lat)));
        }
        "train-mlp" => {
            let backend = opt_str("--backend")?.unwrap_or_else(|| "fhe".into());
            let batch = opt("--batch", 4)?;
            let dims = match opt_str("--dims")? {
                Some(spec) => parse_dims(&spec)?,
                None => vec![16, 8, 4],
            };
            let classes = *dims
                .last()
                .ok_or_else(|| anyhow::anyhow!("--dims must name at least one layer width"))?;
            // fhe defaults stay reduced-scale; clear is fast enough for epochs
            let clear = match backend.as_str() {
                "clear" => true,
                "fhe" => false,
                other => anyhow::bail!("--backend must be `clear` or `fhe`, got {other:?}"),
            };
            let steps = opt("--steps", if clear { usize::MAX } else { 2 })?;
            let epochs = opt("--epochs", 1)?;
            let samples = opt("--samples", if clear { 512 } else { batch * 2 })?;
            let dataset = opt_str("--dataset")?.unwrap_or_else(|| "digits".into());
            let load = |train_split: bool, count: usize, seed: u64| -> anyhow::Result<Dataset> {
                Ok(match dataset.as_str() {
                    "digits" => glyph::data::synthetic_digits(count, seed, "cli"),
                    // the held-out split: real IDX files ignore the seed, so
                    // evaluation must read t10k, not a train-set prefix
                    "mnist" => glyph::data::mnist(train_split, count, seed),
                    "cancer" => glyph::data::synthetic_cancer(count, seed),
                    "svhn" => glyph::data::synthetic_svhn(count, seed),
                    "cifar" => glyph::data::synthetic_cifar(count, seed),
                    other => anyhow::bail!(
                        "--dataset must be digits|mnist|cancer|svhn|cifar, got {other:?}"
                    ),
                })
            };
            let train = load(true, samples, 5)?;
            let test = load(false, (samples / 4).max(batch), 99)?;
            eprintln!(
                "MLP training on the {backend} backend ({} profile), dims={dims:?}, \
                 batch={batch}, dataset={}",
                if clear { "default-shaped, keyless" } else { "test" },
                train.name
            );
            // the clear mirror needs no keys, so it runs the production-
            // shaped ring (t = 2^26) — full paper headroom for wide MACs;
            // the fhe path stays on the fast test profile
            let seed = opt_u64("--seed", 20260710)?;
            let (engine, mut codec): (GlyphEngine, Box<dyn Codec>) = if clear {
                let (e, c) = GlyphEngine::setup_clear(EngineProfile::Default, batch);
                (e, Box::new(c))
            } else {
                let (e, c) = GlyphEngine::setup(EngineProfile::Test, batch, seed);
                (e, Box::new(c))
            };
            let mut rng = glyph::math::GlyphRng::new(1);
            let config = MlpConfig::for_dims(dims, engine.frac_bits(), 3);
            let mlp = GlyphMlp::new_random(config, codec.as_mut(), &mut rng, &engine)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let mut trainer = Trainer::new(mlp.net, classes);
            let mut total_steps = 0u64;
            let mut total_seconds = 0.0f64;
            for epoch in 0..epochs {
                let stats = trainer
                    .train_steps(&train, steps, &engine, codec.as_mut())
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                total_steps += stats.steps as u64;
                total_seconds += stats.seconds;
                let acc = trainer
                    .evaluate(&test, test.len(), &engine, codec.as_mut())
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                println!(
                    "epoch {epoch}: {} samples in {:.2}s ({:.0} samples/s), test acc {:.3}",
                    stats.samples,
                    stats.seconds,
                    stats.samples_per_sec(),
                    acc
                );
            }
            println!("ops: {}", engine.counter.snapshot());
            // Persist the trained model as a checkpoint frame so
            // `glyph infer --model PATH --seed <same seed>` can serve it.
            if let Some(path) = opt_str("--save-model")? {
                let ckpt = glyph::wire::Checkpoint::capture(
                    &trainer.net,
                    &engine,
                    seed,
                    epochs as u64,
                    total_steps,
                    total_seconds,
                    None,
                )
                .map_err(|e| anyhow::anyhow!("{e}"))?;
                glyph::wire::write_atomic(&PathBuf::from(&path), &ckpt.to_wire())
                    .map_err(|e| anyhow::anyhow!("saving model to {path}: {e}"))?;
                println!("model saved to {path}");
            }
        }
        "infer" => {
            let backend = opt_str("--backend")?.unwrap_or_else(|| "fhe".into());
            let clear = match backend.as_str() {
                "clear" => true,
                "fhe" => false,
                other => anyhow::bail!("--backend must be `clear` or `fhe`, got {other:?}"),
            };
            let packed = flag("--packed");
            let batch = opt("--batch", 4)?;
            let dims = match opt_str("--dims")? {
                Some(spec) => parse_dims(&spec)?,
                None => vec![16, 8, 4],
            };
            let classes = *dims
                .last()
                .ok_or_else(|| anyhow::anyhow!("--dims must name at least one layer width"))?;
            let samples = opt("--samples", batch * 4)?;
            let batches = samples / batch;
            if batches == 0 {
                anyhow::bail!("--samples {samples} yields no full minibatch of {batch}");
            }
            // On FHE this must be the seed the model was *trained* under —
            // keygen derives from it, and the checkpoint's weight
            // ciphertexts only decrypt under the training key.
            let seed = opt_u64("--seed", 20260710)?;
            let softmax_bits = opt("--softmax-bits", 3)?;
            let dataset = opt_str("--dataset")?.unwrap_or_else(|| "digits".into());
            let mode = match opt_str("--mode")?.unwrap_or_else(|| "argmax".into()).as_str() {
                "logits" => OutputMode::Logits,
                "argmax" => OutputMode::Argmax,
                "topk" => OutputMode::TopK(opt("--k", 3)?),
                other => anyhow::bail!("--mode must be logits|argmax|topk, got {other:?}"),
            };
            let test = {
                let count = samples;
                match dataset.as_str() {
                    "digits" => glyph::data::synthetic_digits(count, 99, "cli"),
                    "mnist" => glyph::data::mnist(false, count, 99),
                    "cancer" => glyph::data::synthetic_cancer(count, 99),
                    "svhn" => glyph::data::synthetic_svhn(count, 99),
                    "cifar" => glyph::data::synthetic_cifar(count, 99),
                    other => anyhow::bail!(
                        "--dataset must be digits|mnist|cancer|svhn|cifar, got {other:?}"
                    ),
                }
            };
            let (engine, mut codec): (GlyphEngine, Box<dyn Codec>) = match (clear, packed) {
                (true, false) => {
                    let (e, c) = GlyphEngine::setup_clear(EngineProfile::Default, batch);
                    (e, Box::new(c))
                }
                (true, true) => {
                    let (e, c) = GlyphEngine::setup_clear_packed(EngineProfile::Default, batch);
                    (e, Box::new(c))
                }
                (false, false) => {
                    let (e, c) = GlyphEngine::setup(EngineProfile::Test, batch, seed);
                    (e, Box::new(c))
                }
                (false, true) => {
                    let (e, c) = GlyphEngine::setup_packed(EngineProfile::Test, batch, seed);
                    (e, Box::new(c))
                }
            };
            let config = MlpConfig::for_dims(dims.clone(), engine.frac_bits(), softmax_bits);
            let session = match opt_str("--model")? {
                Some(path) => {
                    if packed {
                        anyhow::bail!(
                            "--packed loads explicit weight matrices; checkpoints restore the \
                             unpacked layer path (drop --packed or --model)"
                        );
                    }
                    let bytes = std::fs::read(&path)
                        .map_err(|e| anyhow::anyhow!("reading model {path}: {e}"))?;
                    let ckpt = glyph::wire::Checkpoint::from_wire(&bytes, &engine)
                        .map_err(|e| anyhow::anyhow!("decoding model {path}: {e}"))?;
                    eprintln!(
                        "model {path}: trained {} steps ({:.2}s) under seed {}",
                        ckpt.step, ckpt.seconds, ckpt.job_seed
                    );
                    InferenceSession::from_checkpoint(config, &ckpt, seed, codec.as_mut(), &engine)
                        .map_err(|e| anyhow::anyhow!("{e}"))?
                }
                None => {
                    // no model: deterministic random weights (latency and
                    // plan-conformance probes)
                    let mut rng = glyph::math::GlyphRng::new(1);
                    let mlp = GlyphMlp::new_random(config, codec.as_mut(), &mut rng, &engine)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    InferenceSession::from_network(mlp.net, classes)
                }
            };
            eprintln!(
                "forward-only inference on the {backend} backend{}: dims={dims:?}, \
                 batch={batch}, {batches} batch(es) of {}",
                if packed { " (packed)" } else { "" },
                test.name
            );
            // The scoring contract: live counters must equal the forward-
            // only plan totals × batches exactly. Model build/load ops are
            // not part of it, so the counter starts clean here.
            engine.counter.store(&OpSnapshot::default());
            let t0 = std::time::Instant::now();
            let preds = session
                .predict(&test, batches * batch, mode, &engine, codec.as_mut())
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let seconds = t0.elapsed().as_secs_f64();
            match &preds {
                Predictions::Logits(rows) => {
                    for (i, row) in rows.iter().enumerate().take(16) {
                        println!("sample {i}: {row:?}");
                    }
                    if rows.len() > 16 {
                        println!("… {} more rows", rows.len() - 16);
                    }
                }
                Predictions::Argmax(labels) => {
                    let correct = labels
                        .iter()
                        .zip(&test.labels)
                        .filter(|&(&p, &l)| p == l % classes)
                        .count();
                    println!("predictions (first 16): {:?}", &labels[..labels.len().min(16)]);
                    println!(
                        "accuracy {:.3} over {} samples",
                        correct as f64 / labels.len().max(1) as f64,
                        labels.len()
                    );
                }
                Predictions::TopK(rows) => {
                    for (i, row) in rows.iter().enumerate().take(16) {
                        println!("sample {i}: {row:?}");
                    }
                    if rows.len() > 16 {
                        println!("… {} more rows", rows.len() - 16);
                    }
                }
            }
            let live = engine.counter.snapshot();
            let predicted = session.plan().totals().to_snapshot().scale(batches as u64);
            let drift = glyph::serve::metrics::op_drift(&live, &predicted);
            println!(
                "{} images in {seconds:.3}s ({:.1} images/s, {:.4}s/image amortized)",
                batches * batch,
                (batches * batch) as f64 / seconds.max(1e-9),
                seconds / (batches * batch) as f64
            );
            println!("ops: {live}");
            println!(
                "plan conformance: drift {drift} over predicted counters ({})",
                if drift == 0 { "live == forward plan totals exactly" } else { "MISMATCH" }
            );
            if drift != 0 {
                anyhow::bail!("live op counters drifted from the forward-only plan by {drift}");
            }
        }
        "serve" => {
            let config = ServeConfig {
                addr: addr()?,
                data_dir: opt_str("--data-dir")?.map(PathBuf::from),
                workers: opt("--workers", 1)?,
            };
            let persistent = config.data_dir.is_some();
            let server = RunningServer::start(config)
                .map_err(|e| anyhow::anyhow!("starting server: {e}"))?;
            // The smoke tests parse this exact line to learn the bound port.
            println!("glyph-serve listening on {}", server.addr());
            if !persistent {
                eprintln!("no --data-dir: jobs are memory-only (no checkpoints, no resume)");
            }
            server.wait();
            println!("glyph-serve stopped");
        }
        "submit" => {
            let backend = match opt_str("--backend")?.unwrap_or_else(|| "clear".into()).as_str() {
                "clear" => JobBackend::Clear,
                "fhe" => JobBackend::Fhe,
                other => anyhow::bail!("--backend must be `clear` or `fhe`, got {other:?}"),
            };
            let profile_default = if backend == JobBackend::Clear { "default" } else { "test" };
            let profile = match opt_str("--profile")?
                .unwrap_or_else(|| profile_default.into())
                .as_str()
            {
                "default" => EngineProfile::Default,
                "test" => EngineProfile::Test,
                other => anyhow::bail!("--profile must be `default` or `test`, got {other:?}"),
            };
            let dims = match opt_str("--dims")? {
                Some(spec) => parse_dims(&spec)?,
                None => vec![16, 8, 4],
            };
            let spec = JobSpec {
                tenant: opt_str("--tenant")?.unwrap_or_else(|| "cli".into()),
                backend,
                profile,
                dims: dims.into_iter().map(|d| d as u64).collect(),
                batch: opt_u64("--batch", 4)?,
                epochs: opt_u64("--epochs", 1)?,
                steps_per_epoch: opt_u64("--steps-per-epoch", 0)?,
                samples: opt_u64("--samples", 32)?,
                eval_samples: opt_u64("--eval-samples", 0)?,
                dataset: opt_str("--dataset")?.unwrap_or_else(|| "digits".into()),
                seed: opt_u64("--seed", 1)?,
                checkpoint_every: opt_u64("--checkpoint-every", 8)?,
                softmax_bits: opt_u64("--softmax-bits", 3)?,
            };
            spec.validate().map_err(|e| anyhow::anyhow!("bad job spec: {e}"))?;
            let id = connect()?.submit(&spec)?;
            println!("submitted job {id}");
        }
        "submit-infer" => {
            let backend = match opt_str("--backend")?.unwrap_or_else(|| "clear".into()).as_str() {
                "clear" => JobBackend::Clear,
                "fhe" => JobBackend::Fhe,
                other => anyhow::bail!("--backend must be `clear` or `fhe`, got {other:?}"),
            };
            let profile_default = if backend == JobBackend::Clear { "default" } else { "test" };
            let profile = match opt_str("--profile")?
                .unwrap_or_else(|| profile_default.into())
                .as_str()
            {
                "default" => EngineProfile::Default,
                "test" => EngineProfile::Test,
                other => anyhow::bail!("--profile must be `default` or `test`, got {other:?}"),
            };
            let dims = match opt_str("--dims")? {
                Some(spec) => parse_dims(&spec)?,
                None => vec![16, 8, 4],
            };
            let spec = InferSpec {
                tenant: opt_str("--tenant")?.unwrap_or_else(|| "cli".into()),
                backend,
                profile,
                dims: dims.into_iter().map(|d| d as u64).collect(),
                batch: opt_u64("--batch", 4)?,
                samples: opt_u64("--samples", 16)?,
                dataset: opt_str("--dataset")?.unwrap_or_else(|| "digits".into()),
                seed: opt_u64("--seed", 1)?,
                softmax_bits: opt_u64("--softmax-bits", 3)?,
                model_job: opt_u64("--model-job", 0)?,
                packed: flag("--packed"),
                coalesce: flag("--coalesce"),
            };
            spec.validate().map_err(|e| anyhow::anyhow!("bad infer spec: {e}"))?;
            let id = connect()?.submit_infer(&spec)?;
            println!("submitted infer job {id}");
        }
        "status" => {
            let st = connect()?.status(req_id()?)?;
            print_status(&st);
        }
        "cancel" => {
            let id = req_id()?;
            connect()?.cancel(id)?;
            println!("cancel requested for job {id}");
        }
        "fetch-result" => {
            let id = req_id()?;
            match connect()?.fetch(id)? {
                Fetched::Train(r) => {
                    println!(
                        "job {}: {} steps in {:.2}s, test accuracy {:.3}, resumes {}",
                        r.id, r.steps, r.seconds, r.accuracy, r.resumes
                    );
                    println!("  ops: {}", r.ops);
                    println!(
                        "  weights digest {:016x}, logits digest {:016x}",
                        r.weights_digest, r.logits_digest
                    );
                }
                Fetched::Infer(r) => {
                    println!(
                        "infer job {}: {} images in {} batches, {:.3}s \
                         ({:.4}s/image amortized), accuracy {:.3}",
                        r.id,
                        r.images,
                        r.batches,
                        r.seconds,
                        r.seconds / (r.images.max(1)) as f64,
                        r.accuracy
                    );
                    println!("  ops: {}", r.ops);
                    println!(
                        "  logits digest {:016x}, predictions digest {:016x}",
                        r.logits_digest, r.predictions_digest
                    );
                }
                Fetched::Cancelled => {
                    println!("job {id} was cancelled; no result will be produced");
                }
            }
        }
        "metrics" => {
            print!("{}", connect()?.metrics()?);
        }
        "ping" => {
            connect()?.ping()?;
            println!("pong");
        }
        "shutdown" => {
            connect()?.shutdown()?;
            println!("server shutting down");
        }
        other => {
            eprintln!("unknown command {other}; commands: info, plan, microbench, tables, train-mlp, infer,");
            eprintln!("  serve, submit, submit-infer, status, cancel, fetch-result, metrics, ping, shutdown");
            eprintln!("train-mlp flags: --backend clear|fhe (default fhe), --steps N, --epochs E,");
            eprintln!("  --batch B, --dims a,b,c, --samples M, --dataset digits|mnist|cancer|svhn|cifar,");
            eprintln!("  --seed S, --save-model PATH (persist the trained model for `infer`)");
            eprintln!("infer flags: --model PATH (default: random weights), --backend clear|fhe,");
            eprintln!("  --packed, --batch B, --samples M, --dims a,b,c, --dataset ...,");
            eprintln!("  --mode logits|argmax|topk, --k K, --seed S (FHE: the training seed)");
            eprintln!("serve flags: --addr H:P (default {DEFAULT_ADDR}), --data-dir DIR, --workers N");
            eprintln!("submit flags: train-mlp flags plus --tenant, --seed, --checkpoint-every K,");
            eprintln!("  --steps-per-epoch N, --eval-samples M, --softmax-bits B, --profile default|test");
            eprintln!("submit-infer flags: submit flags (no epochs/checkpoints) plus --model-job ID,");
            eprintln!("  --packed (SIMD layout; model-job 0 only), --coalesce (shared scoring lane)");
            std::process::exit(2);
        }
    }
    Ok(())
}
