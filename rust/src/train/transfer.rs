//! Transfer learning (paper §4.3, Figure 6, Tables 4/8): the convolutional
//! feature extractor is *frozen plaintext* (pre-trained on a public
//! dataset — SVHN for MNIST, CIFAR-10 for Skin-Cancer), so its MACs are
//! MultCP; only the two FC layers train on encrypted data.

use super::glyph::{GlyphMlp, MlpConfig};
use crate::nn::activation;
use crate::nn::batchnorm::BnLayer;
use crate::nn::conv::ConvLayer;
use crate::nn::engine::{ClientKeys, GlyphEngine};
use crate::nn::pool::avg_pool2;
use crate::nn::tensor::{EncTensor, PackOrder};
use crate::math::rng::GlyphRng;

/// CNN architecture (paper §5.2): two conv+BN+ReLU+pool stages, then the
/// trainable FC head.
#[derive(Clone, Debug)]
pub struct CnnConfig {
    pub in_shape: (usize, usize, usize), // C,H,W
    pub conv_channels: (usize, usize),
    pub kernel: usize,
    pub fc_hidden: usize,
    pub classes: usize,
    /// ReLU quantization shifts after each conv stage.
    pub conv_act_shifts: (u32, u32),
    pub head: MlpConfig,
}

impl CnnConfig {
    /// The paper's MNIST CNN: 28×28, 6/16 3×3 kernels, FC 84/10.
    pub fn paper_mnist() -> Self {
        CnnConfig {
            in_shape: (1, 28, 28),
            conv_channels: (6, 16),
            kernel: 3,
            fc_hidden: 84,
            classes: 10,
            conv_act_shifts: (10, 12),
            head: MlpConfig {
                dims: vec![16 * 5 * 5, 84, 10],
                act_shifts: vec![13, 11],
                err_shifts: vec![11, 9],
                grad_shift: 12,
                softmax_bits: 8,
            },
        }
    }

    /// The paper's Skin-Cancer CNN: 28×28×3, 64/96 3×3 kernels, FC 128/7.
    pub fn paper_cancer() -> Self {
        CnnConfig {
            in_shape: (3, 28, 28),
            conv_channels: (64, 96),
            kernel: 3,
            fc_hidden: 128,
            classes: 7,
            conv_act_shifts: (12, 13),
            head: MlpConfig {
                dims: vec![96 * 5 * 5, 128, 7],
                act_shifts: vec![14, 11],
                err_shifts: vec![11, 9],
                grad_shift: 12,
                softmax_bits: 8,
            },
        }
    }

    /// Tiny CNN for tests/demos: 14×14 input, 2/3 channels, FC 4/2.
    /// Shapes: 14 → conv3 → 12 → pool → 6 → conv3 → 4 → pool → 2; feat = 3·2·2.
    pub fn tiny() -> Self {
        let feat = 3 * 2 * 2;
        CnnConfig {
            in_shape: (1, 14, 14),
            conv_channels: (2, 3),
            kernel: 3,
            fc_hidden: 4,
            classes: 2,
            conv_act_shifts: (6, 7),
            head: MlpConfig {
                dims: vec![feat, 4, 2],
                act_shifts: vec![8, 7],
                err_shifts: vec![7, 7],
                grad_shift: 8,
                softmax_bits: 3,
            },
        }
    }
}

/// The Glyph CNN with a frozen feature extractor and a trainable head.
pub struct GlyphCnn {
    pub config: CnnConfig,
    pub conv1: ConvLayer,
    pub bn1: BnLayer,
    pub conv2: ConvLayer,
    pub bn2: BnLayer,
    pub head: GlyphMlp,
}

impl GlyphCnn {
    /// Build from pre-trained plaintext feature weights (8-bit) and random
    /// encrypted head weights. `features` = (conv1 kernels, bn1, conv2
    /// kernels, bn2) as produced by the L2 pre-training pipeline.
    pub fn new(
        config: CnnConfig,
        conv1_w: &[Vec<Vec<Vec<i64>>>],
        bn1: BnLayer,
        conv2_w: &[Vec<Vec<Vec<i64>>>],
        bn2: BnLayer,
        client: &mut ClientKeys,
        rng: &mut GlyphRng,
        engine: &GlyphEngine,
    ) -> Self {
        let conv1 = ConvLayer::new_plain(conv1_w, &engine.ctx.params, config.conv_act_shifts.0);
        let conv2 = ConvLayer::new_plain(conv2_w, &engine.ctx.params, config.conv_act_shifts.1);
        let head = GlyphMlp::new_random(config.head.clone(), client, rng);
        GlyphCnn { config, conv1, bn1, conv2, bn2, head }
    }

    /// Frozen forward: conv→BN→ReLU→pool twice, flatten.
    pub fn forward_features(&self, x: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        let c1 = self.conv1.forward(x, engine);
        let b1 = self.bn1.forward(&c1, engine);
        let (a1, _) = activation::relu_layer(engine, &b1, self.config.conv_act_shifts.0, PackOrder::Forward);
        let p1 = avg_pool2(&a1, engine);
        let c2 = self.conv2.forward(&p1, engine);
        let b2 = self.bn2.forward(&c2, engine);
        let (a2, _) = activation::relu_layer(engine, &b2, self.config.conv_act_shifts.1, PackOrder::Forward);
        let p2 = avg_pool2(&a2, engine);
        // flatten CHW → vector (packing order preserved)
        EncTensor::new(p2.cts, vec![p2.shape.iter().product()], p2.order, p2.shift)
    }

    /// One transfer-learning training step: frozen features + head SGD.
    /// Note the feature tensor carries a pooling shift; the head's first
    /// activation absorbs it (values stay 8-bit after the ReLU quantize).
    pub fn train_step(&mut self, x: &EncTensor, labels_rev: &EncTensor, engine: &GlyphEngine) {
        let feats = self.forward_features(x, engine);
        self.head.train_step(&feats, labels_rev, engine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::EngineProfile;

    #[test]
    fn tiny_cnn_feature_shapes_and_training() {
        let batch = 2;
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 4321);
        let mut rng = GlyphRng::new(7);
        let config = CnnConfig::tiny();
        // random plaintext feature weights
        let rand_kernels = |oc: usize, ic: usize, k: usize, rng: &mut GlyphRng| -> Vec<Vec<Vec<Vec<i64>>>> {
            (0..oc)
                .map(|_| {
                    (0..ic)
                        .map(|_| {
                            (0..k).map(|_| (0..k).map(|_| (rng.uniform_mod(7) as i64) - 3).collect()).collect()
                        })
                        .collect()
                })
                .collect()
        };
        let c1w = rand_kernels(2, 1, 3, &mut rng);
        let c2w = rand_kernels(3, 2, 3, &mut rng);
        let bn1 = BnLayer { gain: vec![1, 1], bias: vec![0, 0], gain_shift: 0 };
        let bn2 = BnLayer { gain: vec![1, 1, 1], bias: vec![0, 0, 0], gain_shift: 0 };
        let mut cnn = GlyphCnn::new(config, &c1w, bn1, &c2w, bn2, &mut client, &mut rng, &engine);

        // 14×14 input, batch 2
        let cts: Vec<_> = (0..14 * 14)
            .map(|i| client.encrypt_batch(&[(i % 11) as i64 - 5, (i % 7) as i64 - 3], 0))
            .collect();
        let x = EncTensor::new(cts, vec![1, 14, 14], PackOrder::Forward, 0);
        let feats = cnn.forward_features(&x, &engine);
        assert!(!feats.is_empty(), "feature vector must be non-empty: {:?}", feats.shape);
        assert_eq!(feats.len(), cnn.config.head.dims[0], "head input width must match features");

        // training step must move head weights without panicking
        let mut l0 = vec![127i64, 0];
        let mut l1 = vec![0i64, 127];
        l0.reverse();
        l1.reverse();
        let labels = EncTensor::new(
            vec![client.encrypt_batch(&l0, 0), client.encrypt_batch(&l1, 0)],
            vec![2],
            PackOrder::Reversed,
            0,
        );
        cnn.train_step(&x, &labels, &engine);
        let s = engine.counter.snapshot();
        assert!(s.mult_cp > 0, "frozen convs must use MultCP");
        assert!(s.mult_cc > 0, "head must use MultCC");
    }
}
