//! Transfer learning (paper §4.3, Figure 6, Tables 4/8) on the plan-driven
//! `Network` API: the convolutional feature extractor is *frozen plaintext*
//! (pre-trained on a public dataset — SVHN for MNIST, CIFAR-10 for
//! Skin-Cancer), so its MACs are MultCP; only the FC head trains on
//! encrypted data. The whole model is one `NetworkBuilder` chain
//! (`.conv_frozen(..).batchnorm(..).relu(..).avg_pool()…flatten().fc(..)`),
//! and the compiled plan's backward walk truncates at the head — exactly
//! the paper's Table-4 row set.

use super::glyph::MlpConfig;
use crate::math::rng::GlyphRng;
use crate::nn::backend::Codec;
use crate::nn::batchnorm::BnLayer;
use crate::nn::engine::GlyphEngine;
use crate::nn::layer::Layer;
use crate::nn::network::{Network, NetworkBuilder, NetworkError};
use crate::nn::tensor::EncTensor;

/// CNN architecture (paper §5.2): two conv+BN+ReLU+pool stages, then the
/// trainable FC head.
#[derive(Clone, Debug)]
pub struct CnnConfig {
    pub in_shape: (usize, usize, usize), // C,H,W
    pub conv_channels: (usize, usize),
    pub kernel: usize,
    pub fc_hidden: usize,
    pub classes: usize,
    /// ReLU quantization shifts after each conv stage.
    pub conv_act_shifts: (u32, u32),
    pub head: MlpConfig,
}

impl CnnConfig {
    /// The paper's MNIST CNN: 28×28, 6/16 3×3 kernels, FC 84/10.
    pub fn paper_mnist() -> Self {
        CnnConfig {
            in_shape: (1, 28, 28),
            conv_channels: (6, 16),
            kernel: 3,
            fc_hidden: 84,
            classes: 10,
            conv_act_shifts: (10, 12),
            head: MlpConfig {
                dims: vec![16 * 5 * 5, 84, 10],
                act_shifts: vec![13, 11],
                err_shifts: vec![11, 9],
                grad_shift: 12,
                softmax_bits: 8,
            },
        }
    }

    /// The paper's Skin-Cancer CNN: 28×28×3, 64/96 3×3 kernels, FC 128/7.
    pub fn paper_cancer() -> Self {
        CnnConfig {
            in_shape: (3, 28, 28),
            conv_channels: (64, 96),
            kernel: 3,
            fc_hidden: 128,
            classes: 7,
            conv_act_shifts: (12, 13),
            head: MlpConfig {
                dims: vec![96 * 5 * 5, 128, 7],
                act_shifts: vec![14, 11],
                err_shifts: vec![11, 9],
                grad_shift: 12,
                softmax_bits: 8,
            },
        }
    }

    /// Tiny CNN for tests/demos: 14×14 input, 2/3 channels, FC 4/2.
    /// Shapes: 14 → conv3 → 12 → pool → 6 → conv3 → 4 → pool → 2; feat = 3·2·2.
    pub fn tiny() -> Self {
        let feat = 3 * 2 * 2;
        CnnConfig {
            in_shape: (1, 14, 14),
            conv_channels: (2, 3),
            kernel: 3,
            fc_hidden: 4,
            classes: 2,
            conv_act_shifts: (6, 7),
            head: MlpConfig {
                dims: vec![feat, 4, 2],
                act_shifts: vec![8, 7],
                err_shifts: vec![7, 7],
                grad_shift: 8,
                softmax_bits: 3,
            },
        }
    }

    /// Flattened feature width after conv→pool→conv→pool.
    pub fn feature_width(&self) -> Result<usize, NetworkError> {
        let (_, h, w) = self.in_shape;
        let k = self.kernel;
        let step = |d: usize| -> Option<usize> {
            let c = d.checked_sub(k - 1)?; // valid conv
            if c < 2 {
                return None;
            }
            Some(c / 2) // 2×2 pool
        };
        match (step(h).and_then(step), step(w).and_then(step)) {
            (Some(fh), Some(fw)) if fh > 0 && fw > 0 => Ok(self.conv_channels.1 * fh * fw),
            _ => Err(NetworkError::Shape {
                unit: "cnn".into(),
                detail: format!(
                    "input {:?} too small for two {k}×{k} conv + 2×2 pool stages",
                    self.in_shape
                ),
            }),
        }
    }

    /// The frozen-feature chain (conv/BN/ReLU/pool ×2 + flatten) plus the
    /// trainable head. `conv1`/`conv2` may be `None` for a *shape-only*
    /// chain that compiles to a plan (the CLI `plan --cnn` path) but
    /// cannot be built.
    pub fn builder(
        &self,
        conv1: Option<Vec<Vec<Vec<Vec<i64>>>>>,
        bn1: BnLayer,
        conv2: Option<Vec<Vec<Vec<Vec<i64>>>>>,
        bn2: BnLayer,
    ) -> Result<NetworkBuilder, NetworkError> {
        self.head.validate()?;
        let feat = self.feature_width()?;
        if feat != self.head.dims[0] {
            return Err(NetworkError::Shape {
                unit: "cnn head".into(),
                detail: format!(
                    "flattened features are {feat} wide but head.dims[0] is {}",
                    self.head.dims[0]
                ),
            });
        }
        let (c, h, w) = self.in_shape;
        let mut b = NetworkBuilder::input_image(c, h, w);
        b = match conv1 {
            Some(ker) => b.conv_frozen(ker),
            None => b.conv_frozen_shape(self.conv_channels.0, self.kernel),
        };
        // frozen-stage ReLUs never run backward; reuse the act shift
        b = b
            .batchnorm(bn1)
            .relu(self.conv_act_shifts.0, self.conv_act_shifts.0)
            .avg_pool();
        b = match conv2 {
            Some(ker) => b.conv_frozen(ker),
            None => b.conv_frozen_shape(self.conv_channels.1, self.kernel),
        };
        b = b
            .batchnorm(bn2)
            .relu(self.conv_act_shifts.1, self.conv_act_shifts.1)
            .avg_pool()
            .flatten();
        Ok(self.head.append_to(b))
    }
}

/// The Glyph CNN with a frozen feature extractor and a trainable head.
pub struct GlyphCnn {
    pub config: CnnConfig,
    pub net: Network,
    /// Units up to and including the flatten adapter (the frozen features).
    feature_units: usize,
}

impl GlyphCnn {
    /// Build from pre-trained plaintext feature weights (8-bit) and random
    /// encrypted head weights. `conv1_w`/`conv2_w` are the L2 pre-training
    /// pipeline's kernels.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        config: CnnConfig,
        conv1_w: &[Vec<Vec<Vec<i64>>>],
        bn1: BnLayer,
        conv2_w: &[Vec<Vec<Vec<i64>>>],
        bn2: BnLayer,
        client: &mut dyn Codec,
        rng: &mut GlyphRng,
        engine: &GlyphEngine,
    ) -> Result<Self, NetworkError> {
        let builder = config.builder(Some(conv1_w.to_vec()), bn1, Some(conv2_w.to_vec()), bn2)?;
        let net = builder.build(client, rng, engine)?;
        let feature_units = net
            .units
            .iter()
            .position(|u| u.name == "Flatten")
            .expect("CNN chain always contains a flatten adapter")
            + 1;
        Ok(GlyphCnn { config, net, feature_units })
    }

    /// Frozen forward: conv→BN→ReLU→pool twice, flatten (the plan's prefix
    /// up to the trainable head).
    pub fn forward_features(&self, x: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        let mut cur: Option<EncTensor> = None;
        for u in &self.net.units[..self.feature_units] {
            let (out, _state) = u.layer.forward(cur.as_ref().unwrap_or(x), engine);
            cur = Some(out);
        }
        cur.expect("the feature extractor has at least one unit")
    }

    /// One transfer-learning training step, walking the compiled plan:
    /// frozen features forward-only, head SGD with backward truncation.
    pub fn train_step(&mut self, x: &EncTensor, labels_rev: &EncTensor, engine: &GlyphEngine) {
        self.net.train_step(x, labels_rev, engine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::EngineProfile;
    use crate::nn::tensor::PackOrder;

    #[test]
    fn tiny_cnn_feature_shapes_and_training() {
        let batch = 2;
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 4321);
        let mut rng = GlyphRng::new(7);
        let config = CnnConfig::tiny();
        // random plaintext feature weights
        let rand_kernels = |oc: usize, ic: usize, k: usize, rng: &mut GlyphRng| -> Vec<Vec<Vec<Vec<i64>>>> {
            (0..oc)
                .map(|_| {
                    (0..ic)
                        .map(|_| {
                            (0..k).map(|_| (0..k).map(|_| (rng.uniform_mod(7) as i64) - 3).collect()).collect()
                        })
                        .collect()
                })
                .collect()
        };
        let c1w = rand_kernels(2, 1, 3, &mut rng);
        let c2w = rand_kernels(3, 2, 3, &mut rng);
        let bn1 = BnLayer { gain: vec![1, 1], bias: vec![0, 0], gain_shift: 0 };
        let bn2 = BnLayer { gain: vec![1, 1, 1], bias: vec![0, 0, 0], gain_shift: 0 };
        let mut cnn =
            GlyphCnn::new(config, &c1w, bn1, &c2w, bn2, &mut client, &mut rng, &engine).unwrap();

        // the compiled plan never trains or back-propagates into the
        // frozen features
        assert!(cnn.net.plan.validate());
        assert!(!cnn.net.plan.steps.iter().any(|s| s.name.contains("Conv") && s.name.contains("gradient")));
        assert!(!cnn.net.plan.steps.iter().any(|s| s.name == "Act1-error"));
        assert!(cnn.net.plan.steps.iter().any(|s| s.name == "FC1-gradient"));

        // 14×14 input, batch 2
        let cts: Vec<_> = (0..14 * 14)
            .map(|i| client.encrypt_batch(&[(i % 11) as i64 - 5, (i % 7) as i64 - 3], 0))
            .collect();
        let x = EncTensor::new(cts, vec![1, 14, 14], PackOrder::Forward, 0);
        let feats = cnn.forward_features(&x, &engine);
        assert!(!feats.is_empty(), "feature vector must be non-empty: {:?}", feats.shape);
        assert_eq!(feats.len(), cnn.config.head.dims[0], "head input width must match features");

        // training step must move head weights without panicking
        let mut l0 = vec![127i64, 0];
        let mut l1 = vec![0i64, 127];
        l0.reverse();
        l1.reverse();
        let labels = EncTensor::new(
            vec![client.encrypt_batch(&l0, 0), client.encrypt_batch(&l1, 0)],
            vec![2],
            PackOrder::Reversed,
            0,
        );
        cnn.train_step(&x, &labels, &engine);
        let s = engine.counter.snapshot();
        assert!(s.mult_cp > 0, "frozen convs must use MultCP");
        assert!(s.mult_cc > 0, "head must use MultCC");
    }

    #[test]
    fn mismatched_head_width_is_a_descriptive_error() {
        let mut config = CnnConfig::tiny();
        config.head.dims[0] = 99;
        let bn1 = BnLayer { gain: vec![1, 1], bias: vec![0, 0], gain_shift: 0 };
        let bn2 = BnLayer { gain: vec![1, 1, 1], bias: vec![0, 0, 0], gain_shift: 0 };
        let err = config.builder(None, bn1, None, bn2).err().expect("must fail");
        let msg = err.to_string();
        assert!(msg.contains("99") && msg.contains("12"), "undiagnostic error: {msg}");
    }
}
