//! [`InferenceSession`] — forward-only encrypted inference over a trained
//! model (ROADMAP item 5: the volume workload of the paper's deployment
//! story).
//!
//! A session wraps a built [`Network`] whose compiled plan has been
//! replaced by [`Plan::forward_only`]: zero backward/gradient steps are
//! compiled at all, every layer is effectively frozen (nothing ever calls
//! `train_step`), and one batched forward pass costs exactly the
//! forward-only plan's totals — the same plan/execution consistency
//! contract training has, now priced for inference.
//!
//! Models come from three places:
//! * [`InferenceSession::from_checkpoint`] — a trained [`Checkpoint`]
//!   (PR 7 wire format): the network is rebuilt from the config, the
//!   trained weight ciphertexts restored geometry-checked, the plan
//!   swapped for its forward prefix. On FHE the engine must be keyed with
//!   the *training* seed or the weights will not decrypt.
//! * [`InferenceSession::from_weights`] — explicit 8-bit weight matrices,
//!   encrypted at build time. Under a packed engine this builds
//!   `PackedFcLayer`s, i.e. the cross-sample SIMD minibatch path — the
//!   batched-throughput configuration of the GPU-batching line
//!   (arXiv 1911.11377).
//! * [`InferenceSession::import_f64`] — externally-trained float weights
//!   requantized through [`crate::nn::quantize::import_f64_weights`], with
//!   the per-layer accumulator-width check against the engine's plaintext
//!   bit budget (arXiv 2302.10906).
//!
//! Outputs come in three modes ([`OutputMode`]): raw per-class logit rows,
//! per-sample argmax labels, or top-k (label, score) lists.

use crate::coordinator::scheduler::Plan;
use crate::data::{DataError, Dataset};
use crate::math::GlyphRng;
use crate::nn::backend::Codec;
use crate::nn::engine::GlyphEngine;
use crate::nn::network::{Network, NetworkBuilder, NetworkError};
use crate::nn::quantize::import_f64_weights;
use crate::train::{MlpConfig, Trainer};
use crate::wire::{Checkpoint, WireError};

/// Why an inference session could not be built or run.
#[derive(Debug)]
pub enum InferError {
    /// Topology/shift-schedule/build failures.
    Network(NetworkError),
    /// Checkpoint decode/restore failures.
    Wire(WireError),
    /// Dataset encode/decode failures.
    Data(DataError),
    /// Model import rejections (geometry, accumulator budget, seed).
    Import(String),
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::Network(e) => write!(f, "network build failed: {e}"),
            InferError::Wire(e) => write!(f, "model load failed: {e}"),
            InferError::Data(e) => write!(f, "dataset error: {e}"),
            InferError::Import(msg) => write!(f, "model import rejected: {msg}"),
        }
    }
}

impl std::error::Error for InferError {}

impl From<NetworkError> for InferError {
    fn from(e: NetworkError) -> Self {
        InferError::Network(e)
    }
}

impl From<WireError> for InferError {
    fn from(e: WireError) -> Self {
        InferError::Wire(e)
    }
}

impl From<DataError> for InferError {
    fn from(e: DataError) -> Self {
        InferError::Data(e)
    }
}

/// What a prediction call returns per sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputMode {
    /// Raw per-class logit rows.
    Logits,
    /// The argmax class label (ties break to the lowest label).
    Argmax,
    /// The k highest-scoring (label, score) pairs, best first.
    TopK(usize),
}

/// Decoded predictions for a scored window, in dataset order.
#[derive(Clone, Debug)]
pub enum Predictions {
    Logits(Vec<Vec<i64>>),
    Argmax(Vec<usize>),
    /// `rows[sample]` = (label, score) pairs, best first.
    TopK(Vec<Vec<(usize, i64)>>),
}

/// Per-sample argmax over logit rows (ties break to the lowest label —
/// the same convention the serve layer's accuracy scoring uses).
pub fn argmax_rows(rows: &[Vec<i64>]) -> Vec<usize> {
    rows.iter()
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by_key(|&(k, &v)| (v, std::cmp::Reverse(k)))
                .map(|(k, _)| k)
                .unwrap_or(0)
        })
        .collect()
}

/// Per-sample top-k (label, score) lists over logit rows, best first.
pub fn top_k_rows(rows: &[Vec<i64>], k: usize) -> Vec<Vec<(usize, i64)>> {
    rows.iter()
        .map(|row| {
            let mut scored: Vec<(usize, i64)> = row.iter().copied().enumerate().collect();
            // descending score, ascending label on ties
            scored.sort_by_key(|&(label, v)| (std::cmp::Reverse(v), label));
            scored.truncate(k.max(1).min(row.len()));
            scored
        })
        .collect()
}

/// A frozen, forward-only model ready to score encrypted minibatches.
pub struct InferenceSession {
    trainer: Trainer,
}

/// The MLP builder chain of `config`, with explicit (instead of random)
/// initial weights for every FC layer.
fn builder_with_weights(
    config: &MlpConfig,
    weights: Vec<Vec<Vec<i64>>>,
) -> Result<NetworkBuilder, InferError> {
    config.validate()?;
    let n_fc = config.dims.len() - 1;
    if weights.len() != n_fc {
        return Err(InferError::Import(format!(
            "{n_fc} FC layers need {n_fc} weight matrices, got {}",
            weights.len()
        )));
    }
    for (l, w) in weights.iter().enumerate() {
        let (out, inp) = (config.dims[l + 1], config.dims[l]);
        if w.len() != out || w.iter().any(|row| row.len() != inp) {
            return Err(InferError::Import(format!(
                "layer {l}: weights are {}×{}, config dims say {out}×{inp}",
                w.len(),
                w.first().map_or(0, Vec::len)
            )));
        }
    }
    let mut b = NetworkBuilder::input_vec(config.dims[0]).grad_shift(config.grad_shift);
    for (l, w) in weights.into_iter().enumerate() {
        b = b.fc_encrypted(w);
        if l + 1 < n_fc {
            b = b.relu(config.act_shifts[l], config.err_shifts[l]);
        } else {
            b = b.softmax(config.softmax_bits, config.act_shifts[l]);
        }
    }
    Ok(b)
}

impl InferenceSession {
    /// Freeze an already-built network for inference: its compiled plan is
    /// replaced by the forward-only prefix, so nothing backward is ever
    /// scheduled (and op predictions price exactly one forward pass).
    pub fn from_network(mut net: Network, classes: usize) -> InferenceSession {
        net.plan = net.plan.forward_only();
        InferenceSession { trainer: Trainer::new(net, classes) }
    }

    /// Load a trained [`Checkpoint`] into a freshly rebuilt network and
    /// freeze it. The engine/codec must reproduce the training run's key
    /// material (same profile; on FHE the same seed) — `expected_seed`
    /// guards that: a checkpoint whose `job_seed` differs is refused
    /// before any weight is touched, because its ciphertexts would
    /// silently decrypt to garbage under the wrong key.
    pub fn from_checkpoint(
        config: MlpConfig,
        ckpt: &Checkpoint,
        expected_seed: u64,
        codec: &mut dyn Codec,
        engine: &GlyphEngine,
    ) -> Result<InferenceSession, InferError> {
        if ckpt.job_seed != expected_seed {
            return Err(InferError::Import(format!(
                "model was trained under seed {}, this session is keyed for seed {expected_seed}",
                ckpt.job_seed
            )));
        }
        let classes = *config.dims.last().ok_or_else(|| {
            InferError::Import("config has no output layer width".into())
        })?;
        // the initial random draws are overwritten below, so any rng works
        let mut rng = GlyphRng::new(expected_seed ^ 0xb11d);
        let net = config.builder()?.build(codec, &mut rng, engine)?;
        let mut session = InferenceSession::from_network(net, classes);
        ckpt.restore_weights(&mut session.trainer.net)?;
        Ok(session)
    }

    /// Build a frozen model from explicit 8-bit weight matrices
    /// (`weights[l][out][in]`), encrypted through the codec. Under a
    /// packed engine this is the cross-sample SIMD minibatch path.
    pub fn from_weights(
        config: MlpConfig,
        weights: Vec<Vec<Vec<i64>>>,
        codec: &mut dyn Codec,
        engine: &GlyphEngine,
    ) -> Result<InferenceSession, InferError> {
        let classes = *config.dims.last().ok_or_else(|| {
            InferError::Import("config has no output layer width".into())
        })?;
        let b = builder_with_weights(&config, weights)?;
        let mut rng = GlyphRng::new(0x1f3a); // explicit init: no draws consumed
        let net = b.build(codec, &mut rng, engine)?;
        Ok(InferenceSession::from_network(net, classes))
    }

    /// Import an externally-trained float model: per-layer SWALP
    /// requantization into 8-bit with the accumulator-width check against
    /// the engine's plaintext bit budget, then [`Self::from_weights`].
    /// Returns the session and the per-layer quantization exponents.
    pub fn import_f64(
        float_weights: &[Vec<Vec<f64>>],
        softmax_bits: usize,
        codec: &mut dyn Codec,
        engine: &GlyphEngine,
    ) -> Result<(InferenceSession, Vec<i32>), InferError> {
        if float_weights.is_empty() {
            return Err(InferError::Import("no weight matrices to import".into()));
        }
        let in_dim = float_weights[0].first().map_or(0, Vec::len);
        let budget = engine.params().t.trailing_zeros();
        let imported =
            import_f64_weights(float_weights, in_dim, budget).map_err(InferError::Import)?;
        let mut dims = vec![in_dim];
        dims.extend(imported.iter().map(|il| il.weights.len()));
        let frac = engine.frac_bits();
        let config = MlpConfig::for_dims(dims, frac, softmax_bits);
        let exponents: Vec<i32> = imported.iter().map(|il| il.exponent).collect();
        let weights: Vec<Vec<Vec<i64>>> = imported.into_iter().map(|il| il.weights).collect();
        let session = InferenceSession::from_weights(config, weights, codec, engine)?;
        Ok((session, exponents))
    }

    /// The forward-only compiled plan (zero backward steps; totals price
    /// one batched forward pass exactly).
    pub fn plan(&self) -> &Plan {
        &self.trainer.net.plan
    }

    /// The frozen network (weight inspection, digests).
    pub fn net(&self) -> &Network {
        &self.trainer.net
    }

    /// Output-class count.
    pub fn classes(&self) -> usize {
        self.trainer.classes
    }

    /// Decoded per-class logit rows for (up to) `limit` samples, dataset
    /// order — byte-identical to what `Trainer::eval_scores` produces on
    /// the training path for the same weights.
    pub fn scores(
        &self,
        ds: &Dataset,
        limit: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<Vec<Vec<i64>>, InferError> {
        Ok(self.trainer.eval_scores(ds, limit, engine, codec)?)
    }

    /// Logit rows for `batches` minibatches starting at minibatch index
    /// `first` — the incremental entry point the serve worker uses to
    /// publish progress and honour cancellation between batches.
    pub fn scores_range(
        &self,
        ds: &Dataset,
        first: usize,
        batches: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<Vec<Vec<i64>>, InferError> {
        Ok(self.trainer.eval_scores_range(ds, first, batches, engine, codec)?)
    }

    /// One forward pass over caller-assembled slot columns (`cols[f][b]` =
    /// feature `f`, slot `b`, spanning the engine batch) with an explicit
    /// occupancy mask: one logit row per slot, vacant slots included. The
    /// coalesced serve scheduler uses this to score one shared batch filled
    /// with images from different jobs — each occupied slot's row is
    /// identical to what the same sample produces in a solo run, because
    /// the per-lane forward pipeline never mixes batch lanes.
    pub fn scores_slots(
        &self,
        cols: &[Vec<i64>],
        occupied: &[bool],
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<Vec<Vec<i64>>, InferError> {
        Ok(self.trainer.eval_scores_slots(cols, occupied, engine, codec)?)
    }

    /// Input feature width the frozen model expects.
    pub fn features(&self) -> usize {
        self.trainer.features
    }

    /// Score (up to) `limit` samples and shape the output per `mode`.
    pub fn predict(
        &self,
        ds: &Dataset,
        limit: usize,
        mode: OutputMode,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<Predictions, InferError> {
        let rows = self.scores(ds, limit, engine, codec)?;
        Ok(match mode {
            OutputMode::Logits => Predictions::Logits(rows),
            OutputMode::Argmax => Predictions::Argmax(argmax_rows(&rows)),
            OutputMode::TopK(k) => Predictions::TopK(top_k_rows(&rows, k)),
        })
    }

    /// Argmax accuracy against the dataset's labels over (up to) `limit`
    /// samples.
    pub fn accuracy(
        &self,
        ds: &Dataset,
        limit: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<f64, InferError> {
        Ok(self.trainer.evaluate(ds, limit, engine, codec)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::{EngineProfile, GlyphEngine};

    #[test]
    fn argmax_and_topk_shapes() {
        let rows = vec![vec![5i64, -2, 9], vec![3, 3, -1]];
        assert_eq!(argmax_rows(&rows), vec![2, 0]); // ties break low
        let tk = top_k_rows(&rows, 2);
        assert_eq!(tk[0], vec![(2, 9), (0, 5)]);
        assert_eq!(tk[1], vec![(0, 3), (1, 3)]);
        // k clamps to the class count, and to at least 1
        assert_eq!(top_k_rows(&rows, 99)[0].len(), 3);
        assert_eq!(top_k_rows(&rows, 0)[0].len(), 1);
    }

    #[test]
    fn session_compiles_zero_backward_steps() {
        let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
        let config = MlpConfig::tiny(4, 3, 2);
        let weights = vec![vec![vec![1i64; 4]; 3], vec![vec![2i64; 3]; 2]];
        let session =
            InferenceSession::from_weights(config, weights, &mut codec, &engine).unwrap();
        assert!(session.plan().validate());
        assert!(session
            .plan()
            .steps
            .iter()
            .all(|s| s.phase == crate::coordinator::scheduler::StepPhase::Forward));
    }

    #[test]
    fn from_weights_refuses_bad_geometry() {
        let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
        let config = MlpConfig::tiny(4, 3, 2);
        let weights = vec![vec![vec![1i64; 4]; 3]]; // one matrix for two FCs
        let err = InferenceSession::from_weights(config, weights, &mut codec, &engine)
            .err()
            .expect("must refuse");
        assert!(err.to_string().contains("2"), "{err}");
    }

    #[test]
    fn import_f64_builds_and_reports_exponents() {
        let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, 2);
        let l0: Vec<Vec<f64>> = (0..3).map(|j| (0..4).map(|i| (i + j) as f64 * 0.1).collect()).collect();
        let l1: Vec<Vec<f64>> = (0..2).map(|j| (0..3).map(|i| (i as f64 - j as f64) * 0.5).collect()).collect();
        let (session, exps) =
            InferenceSession::import_f64(&[l0, l1], 3, &mut codec, &engine).unwrap();
        assert_eq!(exps.len(), 2);
        assert_eq!(session.classes(), 2);
        let ds = crate::data::synthetic_digits(8, 3, "import-test");
        let acc = session.accuracy(&ds, 8, &engine, &mut codec).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }
}
