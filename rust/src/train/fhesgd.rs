//! The FHESGD baseline (Nandakumar et al., the paper's §2.5 comparison) on
//! the plan-driven `Network` API: the same BGV MAC structure as Glyph, but
//! every activation is a sigmoid evaluated with the bit-sliced BGV table
//! lookup — the 3–4-orders-of-magnitude imbalance of the paper's Table 2 /
//! Figure 2. The lookups are a [`SigmoidTluLayer`] unit (`Layer` trait), so
//! the baseline shares `Network::train_step`'s plan walk with Glyph.
//!
//! The homomorphic indicator-tree lookup (the dominant cost) is real and
//! measured; the value↔bit-slice domain conversions around it are performed
//! by the refresh authority, substituting HElib's digit-extraction
//! recryption (DESIGN.md §5). The baseline runs batch = 1 (its elementwise
//! ct×ct backward products require single-lane semantics under our
//! coefficient packing; FHESGD's slot packing amortized 60 lanes — the
//! substitution is charged in the cost model, not hidden).

use crate::bgv::lut::{LookupTable, LutCost};
use crate::bgv::{
    BgvCiphertext, BgvContext, BgvParams, BgvSecretKey, NoiseRefresher, Plaintext, RelinKey,
};
use crate::coordinator::scheduler::LayerKind;
use crate::math::rng::GlyphRng;
use crate::nn::backend::{ClearCt, Codec, Ct};
use crate::nn::engine::GlyphEngine;
use crate::nn::layer::{sigmoid_tlu_ops, Layer, LayerPlanEntry, LayerState};
use crate::nn::linear::FcLayer;
use crate::nn::network::{Network, NetworkBuilder, NetworkError};
use crate::nn::tensor::{EncTensor, PackOrder};
use std::sync::{Arc, Mutex};

/// The t = 2 bit-slice domain used by the lookup tables.
pub struct TluDomain {
    pub ctx: Arc<BgvContext>,
    pub sk: BgvSecretKey,
    pub rlk: RelinKey,
    pub rng: std::sync::Mutex<GlyphRng>,
}

impl TluDomain {
    pub fn new(test_scale: bool, seed: u64) -> Self {
        let params = if test_scale { BgvParams::test_tlu_params() } else { BgvParams::tlu_params() };
        let ctx = BgvContext::new(params);
        let mut rng = GlyphRng::new(seed);
        let sk = BgvSecretKey::generate(&ctx, &mut rng);
        let rlk = RelinKey::generate(&sk, &mut rng);
        TluDomain { ctx, sk, rlk, rng: std::sync::Mutex::new(rng) }
    }

    /// Encrypt the MSB-first bits of an 8-bit value (single lane).
    pub fn encrypt_bits(&self, value: i64, bits: usize) -> Vec<BgvCiphertext> {
        let byte = (value & 0xFF) as u64;
        let mut rng = self.rng.lock().unwrap();
        (0..bits)
            .rev()
            .map(|j| {
                let pt = Plaintext::encode_scalar(((byte >> j) & 1) as i64, &self.ctx.params);
                self.sk.encrypt(&pt, &mut rng)
            })
            .collect()
    }

    pub fn decrypt_bits(&self, bits: &[BgvCiphertext]) -> i64 {
        let mut v = 0u64;
        for ct in bits {
            v = (v << 1) | self.sk.decrypt(ct).coeffs[0].rem_euclid(2) as u64;
        }
        v as i64
    }
}

/// One table lookup on a single-lane MAC-domain ciphertext. FHE backend:
/// the authority converts the quantized value into the bit-slice domain
/// (HElib digit-extraction substitute), the indicator-tree lookup runs for
/// real, and the output bits are recomposed back. Clear backend: the same
/// quantize → table → recompose arithmetic on the plain coefficient — the
/// homomorphic lookup is exact, so the mirror is the table entry itself.
pub fn tlu_activate(
    domain: &TluDomain,
    table: &LookupTable,
    lut_cost: &Mutex<LutCost>,
    tlu_bits: usize,
    ct: &Ct,
    shift: u32,
    engine: &GlyphEngine,
) -> Ct {
    engine.counter.bump(&engine.counter.tlu, 1);
    engine.counter.bump(&engine.counter.refresh, 2); // the two domain conversions
    if engine.is_clear() {
        let params = engine.params();
        let m = ct.clear().decode_batch(1)[0];
        let v = (m >> shift) & ((1 << tlu_bits) - 1);
        // the homomorphic indicator tree computes exactly the table entry
        // truncated to the output width — mirror that read
        let out_v = (table.entries[v as usize] & ((1u64 << table.out_bits) - 1)) as i64;
        let pt = Plaintext::encode_scalar(out_v, params);
        return Ct::Clear(ClearCt::from_plaintext(&pt, params.n));
    }
    let fhe = engine.fhe();
    // authority opens the quantized value (substituted digit extraction)
    let m = fhe.auth.sk.decrypt(ct.fhe()).coeffs[0];
    let v = (m >> shift) & ((1 << tlu_bits) - 1);
    // REAL homomorphic lookup in the t=2 domain
    let bits = domain.encrypt_bits(v, tlu_bits);
    let (out_bits, cost) = table.evaluate(&bits, &domain.rlk, &domain.ctx);
    {
        let mut c = lut_cost.lock().unwrap();
        c.mult_cc += cost.mult_cc;
        c.add_cc += cost.add_cc;
        c.mod_switches += cost.mod_switches;
    }
    let out_v = domain.decrypt_bits(&out_bits);
    // recompose into the MAC domain (authority re-encryption)
    let pt = Plaintext::encode_scalar(out_v, &fhe.ctx.params);
    let trivial = BgvCiphertext::trivial(&pt, &fhe.ctx, fhe.ctx.top_level());
    Ct::Fhe(fhe.auth.refresh(&trivial))
}

/// The FHESGD sigmoid activation as a network unit: forward is one table
/// lookup per neuron; backward multiplies the incoming error by the
/// derivative lookup σ′ of the stored activation (the paper's `Act-error`
/// rows). The last layer (`output_unit`) instead computes the quadratic-
/// loss derivative δ = d − t directly.
///
/// Layer-boundary note for the PR 4 switch engine: unlike the Glyph
/// ReLU/softmax units, the FHESGD baseline never crosses into TFHE — its
/// entry conversion is the refresh-substituted domain hop inside
/// `tlu_activate` (2 refreshes per lookup, counted as `tlu`/`refresh`), so
/// there is no extract/repack traffic to batch here; the engine's
/// `switch_down_many`/`switch_up_many` lanes counters stay zero on this
/// path by design (asserted transitively by `plan_consistency.rs`).
pub struct SigmoidTluLayer {
    pub domain: Arc<TluDomain>,
    pub table: Arc<LookupTable>,
    pub deriv: Arc<LookupTable>,
    pub tlu_bits: usize,
    pub act_shift: u32,
    pub output_unit: bool,
    pub lut_cost: Arc<Mutex<LutCost>>,
}

impl Layer for SigmoidTluLayer {
    fn plan_entry(&self, in_shape: &[usize], _batch: usize) -> LayerPlanEntry {
        let cts: usize = in_shape.iter().product();
        let (forward, error) = sigmoid_tlu_ops(cts, self.output_unit);
        LayerPlanEntry {
            kind: LayerKind::SigmoidTlu,
            out_shape: in_shape.to_vec(),
            forward,
            error: Some(error),
            gradient: None,
            out_packed: false,
        }
    }

    fn forward(&self, u: &EncTensor, engine: &GlyphEngine) -> (EncTensor, LayerState) {
        assert_eq!(engine.batch, 1, "FHESGD baseline runs single-lane (see module docs)");
        let cts: Vec<Ct> = u
            .cts
            .iter()
            .map(|ct| {
                tlu_activate(
                    &self.domain,
                    &self.table,
                    &self.lut_cost,
                    self.tlu_bits,
                    ct,
                    self.act_shift,
                    engine,
                )
            })
            .collect();
        let a = EncTensor::new(cts, u.shape.to_vec(), u.order, 0);
        (a.clone(), LayerState::Output(a))
    }

    fn backward_error(
        &self,
        delta: &EncTensor,
        state: &LayerState,
        engine: &GlyphEngine,
    ) -> EncTensor {
        let acts = match state {
            LayerState::Output(a) => a,
            _ => unreachable!("sigmoid backward needs its forward activations"),
        };
        let cts: Vec<Ct> = if self.output_unit {
            // δ = d − t at the output (batch=1: forward == reversed packing)
            acts.cts
                .iter()
                .zip(&delta.cts)
                .map(|(d, t)| {
                    let mut e = d.clone();
                    engine.sub_cc(&mut e, t);
                    e
                })
                .collect()
        } else {
            // δ_u = err ⊗ σ'(u): derivative lookups then elementwise mult
            delta
                .cts
                .iter()
                .enumerate()
                .map(|(i, e)| {
                    let d_act = tlu_activate(
                        &self.domain,
                        &self.deriv,
                        &self.lut_cost,
                        self.tlu_bits,
                        &acts.cts[i],
                        0,
                        engine,
                    );
                    let mut m = e.clone();
                    engine.mult_cc(&mut m, &d_act);
                    m
                })
                .collect()
        };
        EncTensor::new(cts, delta.shape.to_vec(), PackOrder::Reversed, 0)
    }

    fn is_output_unit(&self) -> bool {
        self.output_unit
    }
}

/// The FHESGD MLP: FC layers + sigmoid TLU activations, built through the
/// `NetworkBuilder` with [`SigmoidTluLayer`] custom units.
pub struct FhesgdMlp {
    pub net: Network,
    pub dims: Vec<usize>,
    pub act_shifts: Vec<u32>,
    pub grad_shift: u32,
    /// Lookup bit-width (Figure 2 sweeps this).
    pub tlu_bits: usize,
    pub sigmoid: Arc<LookupTable>,
    pub sigmoid_deriv: Arc<LookupTable>,
    pub tlu: Arc<TluDomain>,
    /// Accumulated real lookup costs.
    pub lut_cost: Arc<Mutex<LutCost>>,
}

impl FhesgdMlp {
    #[allow(clippy::too_many_arguments)]
    pub fn new_random(
        dims: Vec<usize>,
        act_shifts: Vec<u32>,
        grad_shift: u32,
        tlu_bits: usize,
        client: &mut dyn Codec,
        rng: &mut GlyphRng,
        engine: &GlyphEngine,
        test_scale: bool,
    ) -> Result<Self, NetworkError> {
        let n_fc = dims.len() - 1;
        if act_shifts.len() != n_fc {
            return Err(NetworkError::ShiftSchedule {
                detail: format!(
                    "{} FC layers need {} act_shifts, got {}",
                    n_fc,
                    n_fc,
                    act_shifts.len()
                ),
            });
        }
        // sigmoid over b-bit inputs with 2 fraction bits in, (b−1) out
        let sigmoid = Arc::new(LookupTable::sigmoid(tlu_bits, 2, (tlu_bits - 1) as u32));
        // derivative table: σ' = σ(1−σ), same domain
        let sigmoid_deriv = Arc::new(LookupTable::new(tlu_bits, tlu_bits, move |v| {
            let half = 1i64 << (tlu_bits - 1);
            let sv = if (v as i64) >= half { v as i64 - (1i64 << tlu_bits) } else { v as i64 };
            let x = sv as f64 / 4.0;
            let s = 1.0 / (1.0 + (-x).exp());
            ((s * (1.0 - s)) * 2f64.powi((tlu_bits + 1) as i32)).round() as u64
        }));
        let tlu = Arc::new(TluDomain::new(test_scale, 0xf0e5));
        let lut_cost = Arc::new(Mutex::new(LutCost::default()));

        let mut b = NetworkBuilder::input_vec(dims[0]).grad_shift(grad_shift);
        for l in 0..n_fc {
            b = b.fc(dims[l + 1]);
            b = b.custom(Box::new(SigmoidTluLayer {
                domain: tlu.clone(),
                table: sigmoid.clone(),
                deriv: sigmoid_deriv.clone(),
                tlu_bits,
                act_shift: act_shifts[l],
                output_unit: l + 1 == n_fc,
                lut_cost: lut_cost.clone(),
            }));
        }
        let net = b.build(client, rng, engine)?;
        Ok(FhesgdMlp {
            net,
            dims,
            act_shifts,
            grad_shift,
            tlu_bits,
            sigmoid,
            sigmoid_deriv,
            tlu,
            lut_cost,
        })
    }

    /// One table lookup (compatibility shim over [`tlu_activate`]).
    pub fn tlu_activate(
        &self,
        ct: &Ct,
        table: &LookupTable,
        shift: u32,
        engine: &GlyphEngine,
    ) -> Ct {
        tlu_activate(&self.tlu, table, &self.lut_cost, self.tlu_bits, ct, shift, engine)
    }

    /// The trainable FC layers, bottom-up.
    pub fn fc_layers(&self) -> Vec<&FcLayer> {
        self.net.fc_layers()
    }

    /// One SGD step (batch = 1), walking the compiled plan. Backward
    /// activations use the derivative table (one TLU per neuron, the
    /// paper's `Act-error` rows).
    pub fn train_step(&mut self, x: &EncTensor, labels: &EncTensor, engine: &GlyphEngine) {
        assert_eq!(engine.batch, 1, "FHESGD baseline runs single-lane (see module docs)");
        self.net.train_step(x, labels, engine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::EngineProfile;

    #[test]
    fn sigmoid_tlu_activation_matches_table() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 1, 5000);
        let mut rng = GlyphRng::new(3);
        let mlp =
            FhesgdMlp::new_random(vec![2, 2], vec![0], 8, 4, &mut client, &mut rng, &engine, true)
                .unwrap();
        // value 5, no shift: table input 5
        let ct = client.encrypt_batch(&[5], 0);
        let out = mlp.tlu_activate(&ct, &mlp.sigmoid, 0, &engine);
        let got = client.decrypt_batch(&out, 1, 0)[0];
        assert_eq!(got, mlp.sigmoid.entries[5] as i64);
        let s = engine.counter.snapshot();
        assert_eq!(s.tlu, 1);
        let cost = mlp.lut_cost.lock().unwrap();
        assert_eq!(cost.mult_cc, 2 * ((1 << 4) - 1));
    }

    #[test]
    fn fhesgd_step_runs_and_counts_tlus() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 1, 5001);
        let mut rng = GlyphRng::new(4);
        let mut mlp = FhesgdMlp::new_random(
            vec![3, 4, 2],
            vec![8, 7],
            8,
            4,
            &mut client,
            &mut rng,
            &engine,
            true,
        )
        .unwrap();
        let x_cts = vec![
            client.encrypt_batch(&[40], 0),
            client.encrypt_batch(&[-20], 0),
            client.encrypt_batch(&[7], 0),
        ];
        let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
        let labels = EncTensor::new(
            vec![client.encrypt_batch(&[7], 0), client.encrypt_batch(&[0], 0)],
            vec![2],
            PackOrder::Reversed,
            0,
        );
        mlp.train_step(&x, &labels, &engine);
        let s = engine.counter.snapshot();
        // forward: 4+2 = 6 TLU; backward: 4 derivative TLUs
        assert_eq!(s.tlu, 10);
        assert!(s.mult_cc > 0);
        // no TFHE gates in the baseline's activations
        assert_eq!(s.act_gates, 8 * (4 * 3 + 2 * 4)); // only gradient requantization uses gates

        // the compiled plan predicts the TLU count exactly
        let t = mlp.net.plan.totals();
        assert_eq!(t.tlu, 10);
        assert_eq!(t.act_gates, s.act_gates);
    }
}
