//! The FHESGD baseline (Nandakumar et al., the paper's §2.5 comparison):
//! the same BGV MAC structure as Glyph, but every activation is a sigmoid
//! evaluated with the bit-sliced BGV table lookup — the 3–4-orders-of-
//! magnitude imbalance of the paper's Table 2 / Figure 2.
//!
//! The homomorphic indicator-tree lookup (the dominant cost) is real and
//! measured; the value↔bit-slice domain conversions around it are performed
//! by the refresh authority, substituting HElib's digit-extraction
//! recryption (DESIGN.md §5). The baseline runs batch = 1 (its elementwise
//! ct×ct backward products require single-lane semantics under our
//! coefficient packing; FHESGD's slot packing amortized 60 lanes — the
//! substitution is charged in the cost model, not hidden).

use crate::bgv::lut::{LookupTable, LutCost};
use crate::bgv::{BgvCiphertext, BgvContext, BgvParams, BgvSecretKey, NoiseRefresher, Plaintext, RelinKey};
use crate::nn::engine::{ClientKeys, GlyphEngine};
use crate::nn::linear::FcLayer;
use crate::nn::tensor::{EncTensor, PackOrder};
use crate::math::rng::GlyphRng;
use std::sync::Arc;

/// The t = 2 bit-slice domain used by the lookup tables.
pub struct TluDomain {
    pub ctx: Arc<BgvContext>,
    pub sk: BgvSecretKey,
    pub rlk: RelinKey,
    pub rng: std::sync::Mutex<GlyphRng>,
}

impl TluDomain {
    pub fn new(test_scale: bool, seed: u64) -> Self {
        let params = if test_scale { BgvParams::test_tlu_params() } else { BgvParams::tlu_params() };
        let ctx = BgvContext::new(params);
        let mut rng = GlyphRng::new(seed);
        let sk = BgvSecretKey::generate(&ctx, &mut rng);
        let rlk = RelinKey::generate(&sk, &mut rng);
        TluDomain { ctx, sk, rlk, rng: std::sync::Mutex::new(rng) }
    }

    /// Encrypt the MSB-first bits of an 8-bit value (single lane).
    pub fn encrypt_bits(&self, value: i64, bits: usize) -> Vec<BgvCiphertext> {
        let byte = (value & 0xFF) as u64;
        let mut rng = self.rng.lock().unwrap();
        (0..bits)
            .rev()
            .map(|j| {
                let pt = Plaintext::encode_scalar(((byte >> j) & 1) as i64, &self.ctx.params);
                self.sk.encrypt(&pt, &mut rng)
            })
            .collect()
    }

    pub fn decrypt_bits(&self, bits: &[BgvCiphertext]) -> i64 {
        let mut v = 0u64;
        for ct in bits {
            v = (v << 1) | self.sk.decrypt(ct).coeffs[0].rem_euclid(2) as u64;
        }
        v as i64
    }
}

/// The FHESGD MLP: FC layers + sigmoid TLU activations.
pub struct FhesgdMlp {
    pub layers: Vec<FcLayer>,
    pub dims: Vec<usize>,
    pub act_shifts: Vec<u32>,
    pub grad_shift: u32,
    /// Lookup bit-width (Figure 2 sweeps this).
    pub tlu_bits: usize,
    pub sigmoid: LookupTable,
    pub sigmoid_deriv: LookupTable,
    pub tlu: TluDomain,
    /// Accumulated real lookup costs.
    pub lut_cost: std::sync::Mutex<LutCost>,
}

impl FhesgdMlp {
    pub fn new_random(
        dims: Vec<usize>,
        act_shifts: Vec<u32>,
        grad_shift: u32,
        tlu_bits: usize,
        client: &mut ClientKeys,
        rng: &mut GlyphRng,
        test_scale: bool,
    ) -> Self {
        let mut layers = Vec::new();
        for l in 0..dims.len() - 1 {
            let init: Vec<Vec<i64>> = (0..dims[l + 1])
                .map(|_| (0..dims[l]).map(|_| (rng.uniform_mod(31) as i64) - 15).collect())
                .collect();
            layers.push(FcLayer::new_encrypted(&init, client, act_shifts[l.min(act_shifts.len() - 1)]));
        }
        // sigmoid over b-bit inputs with 2 fraction bits in, (b−1) out
        let sigmoid = LookupTable::sigmoid(tlu_bits, 2, (tlu_bits - 1) as u32);
        // derivative table: σ' = σ(1−σ), same domain
        let sigmoid_deriv = LookupTable::new(tlu_bits, tlu_bits, move |v| {
            let half = 1i64 << (tlu_bits - 1);
            let sv = if (v as i64) >= half { v as i64 - (1i64 << tlu_bits) } else { v as i64 };
            let x = sv as f64 / 4.0;
            let s = 1.0 / (1.0 + (-x).exp());
            ((s * (1.0 - s)) * 2f64.powi((tlu_bits + 1) as i32)).round() as u64
        });
        let tlu = TluDomain::new(test_scale, 0xf0e5);
        FhesgdMlp {
            layers,
            dims,
            act_shifts,
            grad_shift,
            tlu_bits,
            sigmoid,
            sigmoid_deriv,
            tlu,
            lut_cost: std::sync::Mutex::new(LutCost::default()),
        }
    }

    /// One table lookup on a single-lane MAC-domain ciphertext: the
    /// authority converts the quantized value into the bit-slice domain
    /// (HElib digit-extraction substitute), the indicator-tree lookup runs
    /// for real, and the output bits are recomposed back.
    pub fn tlu_activate(
        &self,
        ct: &BgvCiphertext,
        table: &LookupTable,
        shift: u32,
        engine: &GlyphEngine,
    ) -> BgvCiphertext {
        engine.counter.bump(&engine.counter.tlu, 1);
        engine.counter.bump(&engine.counter.refresh, 2); // the two domain conversions
        // authority opens the quantized value (substituted digit extraction)
        let m = engine.auth.sk.decrypt(ct).coeffs[0];
        let v = (m >> shift) & ((1 << self.tlu_bits) - 1);
        // REAL homomorphic lookup in the t=2 domain
        let bits = self.tlu.encrypt_bits(v, self.tlu_bits);
        let (out_bits, cost) = table.evaluate(&bits, &self.tlu.rlk, &self.tlu.ctx);
        {
            let mut c = self.lut_cost.lock().unwrap();
            c.mult_cc += cost.mult_cc;
            c.add_cc += cost.add_cc;
            c.mod_switches += cost.mod_switches;
        }
        let out_v = self.tlu.decrypt_bits(&out_bits);
        // recompose into the MAC domain (authority re-encryption)
        let pt = Plaintext::encode_scalar(out_v, &engine.ctx.params);
        let trivial = BgvCiphertext::trivial(&pt, &engine.ctx, engine.ctx.top_level());
        engine.auth.refresh(&trivial)
    }

    /// Forward pass (batch = 1): FC MACs + sigmoid lookups.
    pub fn forward(&self, x: &EncTensor, engine: &GlyphEngine) -> Vec<EncTensor> {
        assert_eq!(engine.batch, 1, "FHESGD baseline runs single-lane (see module docs)");
        let mut acts = vec![];
        let mut cur: Vec<BgvCiphertext> = x.cts.clone();
        for (l, fc) in self.layers.iter().enumerate() {
            let u = fc.forward(
                &EncTensor::new(cur.clone(), vec![fc.in_dim], PackOrder::Forward, 0),
                engine,
            );
            let shift = self.act_shifts[l.min(self.act_shifts.len() - 1)];
            let a: Vec<BgvCiphertext> =
                u.cts.iter().map(|ct| self.tlu_activate(ct, &self.sigmoid, shift, engine)).collect();
            acts.push(EncTensor::new(a.clone(), vec![fc.out_dim], PackOrder::Forward, 0));
            cur = a;
        }
        acts
    }

    /// One SGD step (batch = 1). Backward activations use the derivative
    /// table (one TLU per neuron, the paper's `Act-error` rows).
    pub fn train_step(&mut self, x: &EncTensor, labels: &EncTensor, engine: &GlyphEngine) {
        let acts = self.forward(x, engine);
        let n = self.layers.len();
        // δ = d − t at the output (batch=1: forward == reversed packing)
        let mut delta_cts: Vec<BgvCiphertext> = acts[n - 1]
            .cts
            .iter()
            .zip(&labels.cts)
            .map(|(d, t)| {
                let mut e = d.clone();
                engine.sub_cc(&mut e, t);
                e
            })
            .collect();
        let mut grads: Vec<Vec<Vec<BgvCiphertext>>> = vec![Vec::new(); n];
        for l in (0..n).rev() {
            let below: Vec<BgvCiphertext> =
                if l == 0 { x.cts.clone() } else { acts[l - 1].cts.clone() };
            let delta = EncTensor::new(delta_cts.clone(), vec![self.layers[l].out_dim], PackOrder::Reversed, 0);
            let below_t = EncTensor::new(below, vec![self.layers[l].in_dim], PackOrder::Forward, 0);
            grads[l] = self.layers[l].gradients(&below_t, &delta, engine);
            if l > 0 {
                let err = self.layers[l].backward_error(&delta, engine);
                // δ_u = err ⊗ σ'(u): derivative lookups then elementwise mult
                delta_cts = err
                    .cts
                    .iter()
                    .enumerate()
                    .map(|(i, e)| {
                        // σ'(u) looked up from the stored activation input
                        let d_act = self.tlu_activate(&acts[l - 1].cts[i], &self.sigmoid_deriv, 0, engine);
                        let mut m = e.clone();
                        engine.mult_cc(&mut m, &d_act);
                        m
                    })
                    .collect();
            }
        }
        for l in 0..n {
            self.layers[l].apply_gradients(&grads[l], self.grad_shift, engine);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::EngineProfile;

    #[test]
    fn sigmoid_tlu_activation_matches_table() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 1, 5000);
        let mut rng = GlyphRng::new(3);
        let mlp = FhesgdMlp::new_random(vec![2, 2], vec![0], 8, 4, &mut client, &mut rng, true);
        // value 5, no shift: table input 5
        let ct = client.encrypt_batch(&[5], 0);
        let out = mlp.tlu_activate(&ct, &mlp.sigmoid, 0, &engine);
        let got = client.decrypt_batch(&out, 1, 0)[0];
        assert_eq!(got, mlp.sigmoid.entries[5] as i64);
        let s = engine.counter.snapshot();
        assert_eq!(s.tlu, 1);
        let cost = mlp.lut_cost.lock().unwrap();
        assert_eq!(cost.mult_cc, 2 * ((1 << 4) - 1));
    }

    #[test]
    fn fhesgd_step_runs_and_counts_tlus() {
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, 1, 5001);
        let mut rng = GlyphRng::new(4);
        let mut mlp =
            FhesgdMlp::new_random(vec![3, 4, 2], vec![8, 7], 8, 4, &mut client, &mut rng, true);
        let x_cts = vec![
            client.encrypt_batch(&[40], 0),
            client.encrypt_batch(&[-20], 0),
            client.encrypt_batch(&[7], 0),
        ];
        let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
        let labels = EncTensor::new(
            vec![client.encrypt_batch(&[7], 0), client.encrypt_batch(&[0], 0)],
            vec![2],
            PackOrder::Reversed,
            0,
        );
        mlp.train_step(&x, &labels, &engine);
        let s = engine.counter.snapshot();
        // forward: 4+2 = 6 TLU; backward: 4 derivative TLUs
        assert_eq!(s.tlu, 10);
        assert!(s.mult_cc > 0);
        // no TFHE gates in the baseline's activations
        assert_eq!(s.act_gates, 8 * (4 * 3 + 2 * 4)); // only gradient requantization uses gates
    }
}
