//! The Glyph MLP trainer: the paper's Table-3 pipeline.
//!
//! Forward: FC (BGV MultCC) → switch → TFHE ReLU → switch → … → softmax.
//! Backward: isoftmax (BGV SubCC) → FC errors (BGV) → switch → iReLU →
//! switch → … ; gradients by the convolution-trick MultCC and SGD updates
//! re-quantized through the switch.

use crate::nn::activation::{self, ReluState, SoftmaxUnit};
use crate::nn::engine::{ClientKeys, GlyphEngine};
use crate::nn::linear::FcLayer;
use crate::nn::loss::quadratic_loss_delta;
use crate::nn::tensor::{EncTensor, PackOrder};
use crate::math::rng::GlyphRng;
use crate::tfhe::LweCiphertext;

/// Architecture and fixed-point schedule of a Glyph MLP.
#[derive(Clone, Debug)]
pub struct MlpConfig {
    /// Layer widths, e.g. [784, 128, 32, 10] (the paper's 3-layer MLP).
    pub dims: Vec<usize>,
    /// Activation quantization shift per hidden layer (drops the MAC scale
    /// back to 8-bit; ≈ log2(127·fan_in) − 7).
    pub act_shifts: Vec<u32>,
    /// Error-path quantization shift per hidden layer.
    pub err_shifts: Vec<u32>,
    /// Gradient/learning-rate shift (step = ∇ >> grad_shift).
    pub grad_shift: u32,
    /// Softmax lookup width (paper: 8; reduced in tests for speed).
    pub softmax_bits: usize,
}

impl MlpConfig {
    /// The paper's 3-layer MLP (784-128-32-10).
    pub fn paper_mlp() -> Self {
        MlpConfig {
            dims: vec![784, 128, 32, 10],
            act_shifts: vec![14, 11, 9],
            err_shifts: vec![11, 9, 9],
            grad_shift: 12,
            softmax_bits: 8,
        }
    }

    /// A tiny MLP for tests and reduced-scale demos.
    pub fn tiny(in_dim: usize, hidden: usize, out_dim: usize) -> Self {
        MlpConfig {
            dims: vec![in_dim, hidden, out_dim],
            act_shifts: vec![8, 7],
            err_shifts: vec![7, 7],
            grad_shift: 8,
            softmax_bits: 3,
        }
    }
}

/// The encrypted MLP.
pub struct GlyphMlp {
    pub config: MlpConfig,
    pub layers: Vec<FcLayer>,
    pub softmax: SoftmaxUnit,
}

impl GlyphMlp {
    /// Random 8-bit initial weights, encrypted under the client key.
    pub fn new_random(config: MlpConfig, client: &mut ClientKeys, rng: &mut GlyphRng) -> Self {
        let mut layers = Vec::new();
        for l in 0..config.dims.len() - 1 {
            let (fi, fo) = (config.dims[l], config.dims[l + 1]);
            let init: Vec<Vec<i64>> = (0..fo)
                .map(|_| (0..fi).map(|_| (rng.uniform_mod(31) as i64) - 15).collect())
                .collect();
            layers.push(FcLayer::new_encrypted(&init, client, config.act_shifts[l.min(config.act_shifts.len() - 1)]));
        }
        let softmax = SoftmaxUnit::logistic(config.softmax_bits, 4);
        GlyphMlp { config, layers, softmax }
    }

    /// Softmax layer: extract the top `softmax_bits` of each logit, run the
    /// Figure-4 MUX-tree unit per lane, and pack reverse-order for the loss.
    fn softmax_layer(&self, u: &EncTensor, engine: &GlyphEngine) -> EncTensor {
        let frac = engine.frac_bits();
        // logits quantized like activations: drop the last layer's shift
        let shift = *self.config.act_shifts.last().unwrap();
        let pre_shift = frac - shift;
        let in_positions = u.order.positions(engine.batch);
        let out_positions = PackOrder::Reversed.positions(engine.batch);
        let cts = u
            .cts
            .iter()
            .map(|ct| {
                let lanes_bits = engine.switch_to_bits(ct, &in_positions, pre_shift);
                // all lanes' MUX trees fan across the pool in one call
                let lane_slices: Vec<&[LweCiphertext]> = lanes_bits
                    .iter()
                    .map(|bits| &bits[..self.config.softmax_bits])
                    .collect();
                let outs = self.softmax.evaluate_mux_many(engine, &lane_slices);
                engine.switch_to_bgv(&outs, &out_positions)
            })
            .collect();
        EncTensor::new(cts, u.shape.clone(), PackOrder::Reversed, 0)
    }

    /// Forward pass: returns the layer activations (forward-packed; index 0
    /// is the input) plus the softmax output (reverse-packed) and the ReLU
    /// states for the backward pass.
    pub fn forward(
        &self,
        x: &EncTensor,
        engine: &GlyphEngine,
    ) -> (Vec<EncTensor>, EncTensor, Vec<ReluState>) {
        let mut acts: Vec<EncTensor> = Vec::with_capacity(self.layers.len());
        let mut states = Vec::new();
        let mut cur = x;
        let mut owned: Vec<EncTensor> = Vec::new();
        for (l, fc) in self.layers.iter().enumerate() {
            let u = fc.forward(cur, engine);
            if l + 1 < self.layers.len() {
                let (a, st) = activation::relu_layer(engine, &u, self.config.act_shifts[l], PackOrder::Forward);
                states.push(st);
                owned.push(a);
                cur = owned.last().unwrap();
            } else {
                let d = self.softmax_layer(&u, engine);
                acts = owned;
                return (acts, d, states);
            }
        }
        unreachable!("MLP needs at least one layer");
    }

    /// One encrypted SGD mini-batch step. `x` is forward-packed (shift 0),
    /// `labels_rev` is the reverse-packed one-hot targets (shift 0).
    pub fn train_step(&mut self, x: &EncTensor, labels_rev: &EncTensor, engine: &GlyphEngine) {
        let (hidden, d, states) = self.forward(x, engine);
        // δ for the last layer (paper Eq. 6, "Act-error" row: AddCC only).
        let mut delta = quadratic_loss_delta(&d, labels_rev, engine);
        // Walk layers backwards: gradient, then error for the layer below.
        let n_layers = self.layers.len();
        let mut grads: Vec<Vec<Vec<crate::bgv::BgvCiphertext>>> = vec![Vec::new(); n_layers];
        for l in (0..n_layers).rev() {
            let below: &EncTensor = if l == 0 { x } else { &hidden[l - 1] };
            grads[l] = self.layers[l].gradients(below, &delta, engine);
            if l > 0 {
                let err = self.layers[l].backward_error(&delta, engine);
                delta = activation::irelu_layer(engine, &err, &states[l - 1], self.config.err_shifts[l - 1]);
            }
        }
        for l in 0..n_layers {
            self.layers[l].apply_gradients(&grads[l], self.config.grad_shift, engine);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::EngineProfile;
    use crate::nn::linear::Weight;

    #[test]
    fn tiny_mlp_trains_one_step_and_moves_weights() {
        let batch = 2;
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 1234);
        let mut rng = GlyphRng::new(99);
        let config = MlpConfig::tiny(3, 4, 2);
        let mut mlp = GlyphMlp::new_random(config, &mut client, &mut rng);
        // snapshot initial weights
        let w_before: Vec<i64> = mlp
            .layers
            .iter()
            .flat_map(|l| {
                l.w.iter().flat_map(|row| {
                    row.iter().map(|w| match w {
                        Weight::Enc(ct) => client.decrypt_batch(ct, 1, 0)[0],
                        Weight::Plain(p) => p.coeffs[0],
                    })
                })
            })
            .collect();

        // inputs: 3 features × batch 2
        let x_cols = vec![vec![40i64, -20], vec![10, 30], vec![-5, 25]];
        let x_cts = x_cols.iter().map(|v| client.encrypt_batch(v, 0)).collect();
        let x = EncTensor::new(x_cts, vec![3], PackOrder::Forward, 0);
        // one-hot labels (reverse packed): class 0 for sample 0, class 1 for 1
        let mut l0 = vec![127i64, 0];
        let mut l1 = vec![0i64, 127];
        l0.reverse();
        l1.reverse();
        let lab_cts = vec![client.encrypt_batch(&l0, 0), client.encrypt_batch(&l1, 0)];
        let labels = EncTensor::new(lab_cts, vec![2], PackOrder::Reversed, 0);

        mlp.train_step(&x, &labels, &engine);

        let w_after: Vec<i64> = mlp
            .layers
            .iter()
            .flat_map(|l| {
                l.w.iter().flat_map(|row| {
                    row.iter().map(|w| match w {
                        Weight::Enc(ct) => client.decrypt_batch(ct, 1, 0)[0],
                        Weight::Plain(p) => p.coeffs[0],
                    })
                })
            })
            .collect();
        assert_eq!(w_before.len(), w_after.len());
        assert_ne!(w_before, w_after, "training must move at least one weight");
        // all weights stay 9-bit-ish (8-bit ± one 8-bit step)
        assert!(w_after.iter().all(|w| w.abs() <= 255), "{w_after:?}");

        let s = engine.counter.snapshot();
        assert!(s.mult_cc > 0 && s.act_gates > 0 && s.switch_b2t > 0 && s.switch_t2b > 0);
        // forward MACs: 3·4 + 4·2 = 20; backward error 4·2; gradients 20
        assert_eq!(s.mult_cc, 20 + 8 + 20);
    }
}
