//! The Glyph MLP (the paper's Table-3 pipeline) on the plan-driven
//! `Network` API.
//!
//! [`GlyphMlp`] is now a thin compatibility wrapper: [`MlpConfig`]
//! translates into a `NetworkBuilder` chain
//! (`.fc(128).relu(14, 11).fc(32).relu(11, 9).fc(10).softmax(8, 9)`), the
//! builder *validates* the shift schedule against the architecture (no
//! silent index clamping — mismatched `act_shifts`/`err_shifts` are a
//! descriptive [`NetworkError`]), and the built network executes by
//! walking its compiled `scheduler::Plan`: FC MACs on BGV, ReLU/softmax on
//! TFHE behind `switch_to_bits`/`switch_to_bgv` exactly at the plan's
//! switch boundaries, gradients re-quantized through the switch
//! (the `FC-gradient … BGV-TFHE` rows of Table 3).
//!
//! New topologies (deeper MLPs, different widths) need no new module —
//! they are one builder chain; this wrapper only preserves the historical
//! constructor surface for the examples, benches and CLI.

use crate::math::rng::GlyphRng;
use crate::nn::backend::Codec;
use crate::nn::engine::GlyphEngine;
use crate::nn::linear::FcLayer;
use crate::nn::network::{Network, NetworkBuilder, NetworkError};
use crate::nn::tensor::EncTensor;

/// Architecture and fixed-point schedule of a Glyph MLP.
#[derive(Clone, Debug)]
pub struct MlpConfig {
    /// Layer widths, e.g. [784, 128, 32, 10] (the paper's 3-layer MLP).
    pub dims: Vec<usize>,
    /// Activation quantization shift per FC layer (drops the MAC scale
    /// back to 8-bit; ≈ log2(127·fan_in) − 7). The last entry quantizes
    /// the softmax logits.
    pub act_shifts: Vec<u32>,
    /// Error-path quantization shift per hidden ReLU.
    pub err_shifts: Vec<u32>,
    /// Gradient/learning-rate shift (step = ∇ >> grad_shift).
    pub grad_shift: u32,
    /// Softmax lookup width (paper: 8; reduced in tests for speed).
    pub softmax_bits: usize,
}

impl MlpConfig {
    /// The paper's 3-layer MLP (784-128-32-10).
    pub fn paper_mlp() -> Self {
        MlpConfig {
            dims: vec![784, 128, 32, 10],
            act_shifts: vec![14, 11, 9],
            err_shifts: vec![11, 9, 9],
            grad_shift: 12,
            softmax_bits: 8,
        }
    }

    /// Derive a config for an arbitrary topology: per-layer activation
    /// shift ≈ log2(127·fan_in) − 7 (paper §4.1) clamped to the engine's
    /// fraction-bit budget `max_shift`; error shifts follow the activation
    /// shift of the layer above; gradient shift is the largest activation
    /// shift. Shared by the CLI's `--dims` path and the serve layer's job
    /// specs so both price and execute identically.
    pub fn for_dims(dims: Vec<usize>, max_shift: u32, softmax_bits: usize) -> Self {
        let act_shifts: Vec<u32> = dims[..dims.len().saturating_sub(1)]
            .iter()
            .map(|&fan_in| {
                (((127 * fan_in) as f64).log2().ceil() as u32)
                    .saturating_sub(7)
                    .clamp(1, max_shift)
            })
            .collect();
        let err_shifts: Vec<u32> =
            (0..act_shifts.len()).map(|l| act_shifts[(l + 1).min(act_shifts.len() - 1)]).collect();
        let grad_shift = act_shifts.iter().copied().max().unwrap_or(8).min(max_shift);
        MlpConfig { dims, act_shifts, err_shifts, grad_shift, softmax_bits }
    }

    /// A tiny MLP for tests and reduced-scale demos.
    pub fn tiny(in_dim: usize, hidden: usize, out_dim: usize) -> Self {
        MlpConfig {
            dims: vec![in_dim, hidden, out_dim],
            act_shifts: vec![8, 7],
            err_shifts: vec![7, 7],
            grad_shift: 8,
            softmax_bits: 3,
        }
    }

    /// Validate that the shift schedules match the layer count — the
    /// replacement for the old `act_shifts[l.min(len−1)]` clamping.
    pub fn validate(&self) -> Result<(), NetworkError> {
        if self.dims.len() < 2 {
            return Err(NetworkError::Topology {
                detail: format!("an MLP needs at least 2 dims, got {:?}", self.dims),
            });
        }
        let n_fc = self.dims.len() - 1;
        if self.act_shifts.len() != n_fc {
            return Err(NetworkError::ShiftSchedule {
                detail: format!(
                    "{} FC layers need {} act_shifts (one per layer, the last quantizing the softmax logits), got {}",
                    n_fc,
                    n_fc,
                    self.act_shifts.len()
                ),
            });
        }
        if self.err_shifts.len() < n_fc - 1 {
            return Err(NetworkError::ShiftSchedule {
                detail: format!(
                    "{} hidden ReLUs need at least {} err_shifts, got {}",
                    n_fc - 1,
                    n_fc - 1,
                    self.err_shifts.len()
                ),
            });
        }
        Ok(())
    }

    /// Append this config's FC/ReLU/softmax stack to an existing builder
    /// chain (the transfer CNN reuses this for its trainable head).
    /// Call [`Self::validate`] first.
    pub fn append_to(&self, mut b: NetworkBuilder) -> NetworkBuilder {
        let n_fc = self.dims.len() - 1;
        b = b.grad_shift(self.grad_shift);
        for l in 0..n_fc {
            b = b.fc(self.dims[l + 1]);
            if l + 1 < n_fc {
                b = b.relu(self.act_shifts[l], self.err_shifts[l]);
            } else {
                b = b.softmax(self.softmax_bits, self.act_shifts[l]);
            }
        }
        b
    }

    /// The equivalent `NetworkBuilder` chain.
    pub fn builder(&self) -> Result<NetworkBuilder, NetworkError> {
        self.validate()?;
        Ok(self.append_to(NetworkBuilder::input_vec(self.dims[0])))
    }
}

/// The encrypted MLP: a `Network` built from an [`MlpConfig`].
pub struct GlyphMlp {
    pub config: MlpConfig,
    pub net: Network,
}

impl GlyphMlp {
    /// Random 8-bit initial weights, encrypted under the client key. Fails
    /// with a descriptive error when the shift schedule does not match the
    /// layer count or exceeds the engine's fixed-point budget.
    pub fn new_random(
        config: MlpConfig,
        client: &mut dyn Codec,
        rng: &mut GlyphRng,
        engine: &GlyphEngine,
    ) -> Result<Self, NetworkError> {
        let net = config.builder()?.build(client, rng, engine)?;
        Ok(GlyphMlp { config, net })
    }

    /// The compiled schedule (Table-3 Switch column, with op counts).
    pub fn plan(&self) -> &crate::coordinator::scheduler::Plan {
        &self.net.plan
    }

    /// The FC layers, bottom-up (weight inspection in tests/examples).
    pub fn fc_layers(&self) -> Vec<&FcLayer> {
        self.net.fc_layers()
    }

    /// One encrypted SGD mini-batch step, walking the compiled plan. `x` is
    /// forward-packed (shift 0), `labels_rev` the reverse-packed one-hot
    /// targets (shift 0).
    pub fn train_step(&mut self, x: &EncTensor, labels_rev: &EncTensor, engine: &GlyphEngine) {
        self.net.train_step(x, labels_rev, engine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::engine::EngineProfile;
    use crate::nn::linear::Weight;

    fn weight_snapshot(mlp: &GlyphMlp, client: &crate::nn::engine::ClientKeys) -> Vec<i64> {
        mlp.fc_layers()
            .iter()
            .flat_map(|l| {
                l.w.iter().flat_map(|row| {
                    row.iter().map(|w| match w {
                        Weight::Enc(ct) => client.decrypt_batch(ct, 1, 0)[0],
                        Weight::Plain(p) => p.value(),
                    })
                })
            })
            .collect()
    }

    #[test]
    fn tiny_mlp_trains_one_step_and_moves_weights() {
        let batch = 2;
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 1234);
        let mut rng = GlyphRng::new(99);
        let config = MlpConfig::tiny(3, 4, 2);
        let mut mlp = GlyphMlp::new_random(config, &mut client, &mut rng, &engine).unwrap();
        let w_before = weight_snapshot(&mlp, &client);

        // inputs: 3 features × batch 2
        let x_cols = vec![vec![40i64, -20], vec![10, 30], vec![-5, 25]];
        let x_cts = x_cols.iter().map(|v| client.encrypt_batch(v, 0)).collect();
        let x = EncTensor::new(x_cts, vec![3], crate::nn::tensor::PackOrder::Forward, 0);
        // one-hot labels (reverse packed): class 0 for sample 0, class 1 for 1
        let mut l0 = vec![127i64, 0];
        let mut l1 = vec![0i64, 127];
        l0.reverse();
        l1.reverse();
        let lab_cts = vec![client.encrypt_batch(&l0, 0), client.encrypt_batch(&l1, 0)];
        let labels = EncTensor::new(lab_cts, vec![2], crate::nn::tensor::PackOrder::Reversed, 0);

        mlp.train_step(&x, &labels, &engine);

        let w_after = weight_snapshot(&mlp, &client);
        assert_eq!(w_before.len(), w_after.len());
        assert_ne!(w_before, w_after, "training must move at least one weight");
        // all weights stay 9-bit-ish (8-bit ± one 8-bit step)
        assert!(w_after.iter().all(|w| w.abs() <= 255), "{w_after:?}");

        let s = engine.counter.snapshot();
        assert!(s.mult_cc > 0 && s.act_gates > 0 && s.switch_b2t > 0 && s.switch_t2b > 0);
        // forward MACs: 3·4 + 4·2 = 20; backward error 4·2; gradients 20
        assert_eq!(s.mult_cc, 20 + 8 + 20);
    }

    #[test]
    fn mismatched_shift_schedule_is_an_error_not_a_clamp() {
        let batch = 2;
        let (engine, mut client) = GlyphEngine::setup(EngineProfile::Test, batch, 4321);
        let mut rng = GlyphRng::new(1);
        // 3 FC layers but only 2 act shifts: the old code clamped the index;
        // the builder must refuse with a descriptive error.
        let config = MlpConfig {
            dims: vec![6, 5, 4, 3],
            act_shifts: vec![8, 7],
            err_shifts: vec![7, 7],
            grad_shift: 8,
            softmax_bits: 3,
        };
        let err = GlyphMlp::new_random(config, &mut client, &mut rng, &engine)
            .err()
            .expect("mismatched schedule must fail");
        assert!(matches!(err, NetworkError::ShiftSchedule { .. }), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("3") && msg.contains("2"), "undiagnostic error: {msg}");
    }

    #[test]
    fn paper_config_builds_a_valid_plan() {
        let plan = MlpConfig::paper_mlp().builder().unwrap().compile(60).unwrap();
        assert!(plan.validate());
        // FC MACs of the paper MLP: forward + FC2/FC3 errors + gradients
        let t = plan.totals();
        let fwd = 784 * 128 + 128 * 32 + 32 * 10;
        let err = 128 * 32 + 32 * 10;
        assert_eq!(t.mult_cc as usize, fwd + err + fwd);
    }
}
