//! [`Trainer`]: the epoch-scale training loop over [`crate::data::Dataset`]
//! minibatches, backend-agnostic.
//!
//! The trainer walks a dataset in engine-width minibatches, encodes each
//! one through the backend's [`Codec`] (client-side encryption on FHE,
//! plain packing on the clear mirror), runs `Network::train_step`, and can
//! score test accuracy by decoding the output unit's distribution and
//! taking the per-sample argmax. On the clear backend a full MNIST-scale
//! epoch finishes in seconds, which is what makes the paper's *accuracy*
//! claims continuously testable in CI (`tests/accuracy_floor.rs`); on the
//! FHE backend the very same loop drives reduced-scale encrypted runs.
//!
//! Inputs narrower than the image are sampled evenly across the pixels
//! (`Dataset::minibatch`'s convention, shared with the CLI); labels are
//! one-hot rows at 127, reverse-packed for the loss derivative.

use crate::coordinator::metrics::OpSnapshot;
use crate::data::{DataError, Dataset};
use crate::nn::backend::Codec;
use crate::nn::engine::GlyphEngine;
use crate::nn::network::Network;
use crate::nn::tensor::{EncTensor, PackOrder};

/// What one [`Trainer::train_epoch`] did.
#[derive(Clone, Debug)]
pub struct EpochStats {
    /// Full minibatch steps executed (trailing partial batches are skipped —
    /// the engine's batch width is fixed at key generation).
    pub steps: usize,
    /// Samples consumed (`steps · batch`).
    pub samples: usize,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Live homomorphic-op counter delta across the epoch (identical on
    /// both backends; equals plan totals × steps).
    pub ops: OpSnapshot,
}

impl EpochStats {
    pub fn samples_per_sec(&self) -> f64 {
        self.samples as f64 / self.seconds.max(1e-12)
    }
}

/// The epoch loop around a built [`Network`].
pub struct Trainer {
    pub net: Network,
    /// Output-class count (the output unit's width).
    pub classes: usize,
    /// Input feature width (product of the network's input shape).
    pub features: usize,
}

impl Trainer {
    /// Wrap a built network. The input width and class count are read off
    /// the network's own geometry (`in_shape`, last plan step's unit).
    pub fn new(net: Network, classes: usize) -> Self {
        let features = net.in_shape.iter().product();
        Trainer { net, classes, features }
    }

    /// Encode one minibatch's inputs, forward-packed, through whichever
    /// codec the backend uses (evaluation needs no labels — on FHE every
    /// skipped label is a saved encryption). Packed engines interleave the
    /// whole minibatch into `B(features)` block ciphertexts instead of one
    /// ciphertext per feature — the cross-sample SIMD entry point.
    pub fn encode_inputs(
        &self,
        ds: &Dataset,
        start: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<EncTensor, DataError> {
        let (cols, _labels) = ds.minibatch(start, engine.batch, self.features)?;
        if let Some(layout) = engine.packed_layout() {
            let cts = layout
                .pack_columns(&cols, engine.params().n)
                .iter()
                .map(|coeffs| codec.encrypt_coeffs(coeffs, 0))
                .collect();
            return Ok(EncTensor::packed(
                cts,
                self.net.in_shape.clone(),
                PackOrder::Forward,
                0,
                layout.clone(),
            ));
        }
        let x_cts = cols.iter().map(|v| codec.encrypt_batch(v, 0)).collect();
        Ok(EncTensor::new(x_cts, self.net.in_shape.clone(), PackOrder::Forward, 0))
    }

    /// Encode caller-assembled forward-packed input columns
    /// (`cols[f][b]` = feature `f`, slot `b`; `cols[f].len()` must equal the
    /// engine batch) with an explicit slot-occupancy mask. This is the
    /// coalesced-serving entry point: the serve scheduler fills one engine
    /// batch with images from *different* jobs and leaves unclaimed slots
    /// vacant. Vacant slots encode as zero on both layouts, so each
    /// occupied slot's forward output is identical to what the same sample
    /// produces in any other slot assignment (the per-lane pipeline never
    /// mixes batch lanes).
    pub fn encode_slot_columns(
        &self,
        cols: &[Vec<i64>],
        occupied: &[bool],
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<EncTensor, DataError> {
        let batch = engine.batch;
        assert_eq!(occupied.len(), batch, "occupancy mask must cover the engine batch");
        assert!(
            cols.len() == self.features && cols.iter().all(|c| c.len() == batch),
            "slot columns must be features × batch"
        );
        if let Some(base) = engine.packed_layout() {
            let (layout, blocks) = base.pack_columns_masked(cols, occupied, engine.params().n);
            let cts = blocks.iter().map(|coeffs| codec.encrypt_coeffs(coeffs, 0)).collect();
            return Ok(EncTensor::packed(
                cts,
                self.net.in_shape.clone(),
                PackOrder::Forward,
                0,
                layout,
            ));
        }
        let x_cts = cols
            .iter()
            .map(|col| {
                let masked: Vec<i64> = col
                    .iter()
                    .zip(occupied)
                    .map(|(&v, &occ)| if occ { v } else { 0 })
                    .collect();
                codec.encrypt_batch(&masked, 0)
            })
            .collect();
        Ok(EncTensor::new(x_cts, self.net.in_shape.clone(), PackOrder::Forward, 0))
    }

    /// One forward pass over caller-assembled slot columns: one row of
    /// per-class logits per engine-batch slot, in slot order (vacant slots
    /// included — the caller owns the occupancy bookkeeping and discards
    /// them). The coalesced scheduler de-interleaves these rows back to
    /// the owning jobs.
    pub fn eval_scores_slots(
        &self,
        cols: &[Vec<i64>],
        occupied: &[bool],
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<Vec<Vec<i64>>, DataError> {
        let x = self.encode_slot_columns(cols, occupied, engine, codec)?;
        let pass = self.net.forward(&x, engine);
        Ok(self.decode_output_rows(pass.output(), engine, codec))
    }

    /// Encode one minibatch's reverse-packed one-hot labels (·127).
    pub fn encode_labels(
        &self,
        ds: &Dataset,
        start: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<EncTensor, DataError> {
        let batch = engine.batch;
        if start + batch > ds.len() {
            return Err(DataError::BatchOutOfRange { start, batch, len: ds.len() });
        }
        let lab_cts = (0..self.classes)
            .map(|k| {
                let mut v: Vec<i64> = ds.labels[start..start + batch]
                    .iter()
                    .map(|&l| if l % self.classes == k { 127 } else { 0 })
                    .collect();
                v.reverse();
                codec.encrypt_batch(&v, 0)
            })
            .collect();
        Ok(EncTensor::new(lab_cts, vec![self.classes], PackOrder::Reversed, 0))
    }

    /// Encode one full training minibatch: inputs + labels.
    pub fn encode_minibatch(
        &self,
        ds: &Dataset,
        start: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<(EncTensor, EncTensor), DataError> {
        let x = self.encode_inputs(ds, start, engine, codec)?;
        let lab = self.encode_labels(ds, start, engine, codec)?;
        Ok((x, lab))
    }

    /// One pass over the dataset in minibatch steps (trailing partial batch
    /// skipped). Returns wall-clock and exact op accounting.
    pub fn train_epoch(
        &mut self,
        ds: &Dataset,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<EpochStats, DataError> {
        self.train_steps(ds, ds.len() / engine.batch, engine, codec)
    }

    /// The first `steps` minibatches of the dataset.
    pub fn train_steps(
        &mut self,
        ds: &Dataset,
        steps: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<EpochStats, DataError> {
        self.train_range(ds, 0, steps, engine, codec)
    }

    /// `steps` minibatches starting at minibatch index `first` (sample
    /// offset `first · batch`). This is the resume entry point: a
    /// checkpointed run re-enters the epoch at its step cursor and replays
    /// the identical minibatch sequence.
    pub fn train_range(
        &mut self,
        ds: &Dataset,
        first: usize,
        steps: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<EpochStats, DataError> {
        let batch = engine.batch;
        let steps = steps.min((ds.len() / batch).saturating_sub(first));
        let before = engine.counter.snapshot();
        let t0 = std::time::Instant::now();
        for step in first..first + steps {
            let (x, lab) = self.encode_minibatch(ds, step * batch, engine, codec)?;
            self.net.train_step(&x, &lab, engine);
        }
        Ok(EpochStats {
            steps,
            samples: steps * batch,
            seconds: t0.elapsed().as_secs_f64(),
            ops: engine.counter.snapshot().since(&before),
        })
    }

    /// Decoded output scores for (up to) `limit` samples: one row of
    /// per-class logits per sample, in dataset order (lanes
    /// de-interleaved). The serve layer digests these rows to prove two
    /// runs produced byte-identical models; [`Self::evaluate`] argmaxes
    /// them.
    pub fn eval_scores(
        &self,
        ds: &Dataset,
        limit: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<Vec<Vec<i64>>, DataError> {
        let batch = engine.batch;
        let steps = (limit.min(ds.len())) / batch;
        if steps == 0 {
            return Err(DataError::BatchOutOfRange { start: 0, batch, len: ds.len().min(limit) });
        }
        self.eval_scores_range(ds, 0, steps, engine, codec)
    }

    /// [`Self::eval_scores`] over an explicit minibatch window: `steps`
    /// forward passes starting at minibatch index `first`. The inference
    /// session iterates this one batch at a time so a long scoring run can
    /// publish progress and honour cancellation between batches.
    pub fn eval_scores_range(
        &self,
        ds: &Dataset,
        first: usize,
        steps: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<Vec<Vec<i64>>, DataError> {
        let batch = engine.batch;
        let mut rows = Vec::with_capacity(steps * batch);
        for step in first..first + steps {
            let start = step * batch;
            let x = self.encode_inputs(ds, start, engine, codec)?;
            let pass = self.net.forward(&x, engine);
            rows.extend(self.decode_output_rows(pass.output(), engine, codec));
        }
        Ok(rows)
    }

    /// Decode a forward pass's output tensor into one per-class logit row
    /// per batch slot, slot order. Softmax heads repack reversed (sample b
    /// at coefficient batch−1−b); the FHESGD sigmoid head keeps forward
    /// packing (batch 1 in practice). Packed-layout FC outputs carry the
    /// batch at `lane_base + c`.
    fn decode_output_rows(
        &self,
        out: &EncTensor,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Vec<Vec<i64>> {
        let batch = engine.batch;
        let pos: Vec<usize> = (0..batch).map(|c| c + out.lane_base).collect();
        let scores: Vec<Vec<i64>> =
            out.cts.iter().map(|ct| codec.decrypt_positions(ct, &pos, 0)).collect();
        (0..batch)
            .map(|b| {
                let lane = match out.order {
                    PackOrder::Reversed => batch - 1 - b,
                    PackOrder::Forward => b,
                };
                scores.iter().map(|row| row[lane]).collect()
            })
            .collect()
    }

    /// Test accuracy over (up to) `limit` samples: forward pass per
    /// minibatch, decode the output unit's reverse-packed distribution,
    /// argmax per sample.
    pub fn evaluate(
        &self,
        ds: &Dataset,
        limit: usize,
        engine: &GlyphEngine,
        codec: &mut dyn Codec,
    ) -> Result<f64, DataError> {
        let rows = self.eval_scores(ds, limit, engine, codec)?;
        let mut correct = 0usize;
        for (i, row) in rows.iter().enumerate() {
            let mut best = (i64::MIN, 0usize);
            for (k, &v) in row.iter().enumerate() {
                if v > best.0 {
                    best = (v, k);
                }
            }
            if best.1 == ds.labels[i] % self.classes {
                correct += 1;
            }
        }
        Ok(correct as f64 / rows.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::GlyphRng;
    use crate::nn::engine::{EngineProfile, GlyphEngine};
    use crate::nn::network::NetworkBuilder;

    #[test]
    fn clear_trainer_runs_an_epoch_and_scores() {
        let batch = 4;
        let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, batch);
        let mut rng = GlyphRng::new(11);
        let net = NetworkBuilder::input_vec(16)
            .fc(8)
            .relu(8, 7)
            .fc(3)
            .softmax(3, 7)
            .grad_shift(8)
            .build(&mut codec, &mut rng, &engine)
            .unwrap();
        let mut trainer = Trainer::new(net, 3);
        assert_eq!(trainer.features, 16);
        let ds = crate::data::synthetic_digits(24, 5, "trainer-test");
        let stats = trainer.train_epoch(&ds, &engine, &mut codec).unwrap();
        assert_eq!(stats.steps, 6);
        assert_eq!(stats.samples, 24);
        assert!(stats.ops.mult_cc > 0 && stats.ops.act_gates > 0);
        // op accounting matches the compiled plan exactly, per step
        let totals = trainer.net.plan.totals();
        assert_eq!(stats.ops.mult_cc, totals.mult_cc * stats.steps as u64);
        assert_eq!(stats.ops.act_gates, totals.act_gates * stats.steps as u64);
        let acc = trainer.evaluate(&ds, 24, &engine, &mut codec).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn packed_clear_trainer_runs_an_epoch_and_scores() {
        let batch = 4;
        let (engine, mut codec) = GlyphEngine::setup_clear_packed(EngineProfile::Test, batch);
        let mut rng = GlyphRng::new(11);
        let net = NetworkBuilder::input_vec(16)
            .fc(8)
            .relu(8, 7)
            .fc(3)
            .softmax(3, 7)
            .grad_shift(8)
            .build(&mut codec, &mut rng, &engine)
            .unwrap();
        assert_eq!(net.packed_fc_units().len(), 2, "packed engines build packed FC layers");
        let mut trainer = Trainer::new(net, 3);
        let ds = crate::data::synthetic_digits(24, 5, "trainer-test");
        let stats = trainer.train_epoch(&ds, &engine, &mut codec).unwrap();
        assert_eq!(stats.steps, 6);
        assert_eq!(stats.samples, 24);
        // live op accounting matches the packed plan exactly, per step
        let totals = trainer.net.plan.totals();
        assert_eq!(stats.ops.mult_cc, totals.mult_cc * stats.steps as u64);
        assert_eq!(stats.ops.mult_cp, totals.mult_cp * stats.steps as u64);
        assert_eq!(stats.ops.add_cc, totals.add_cc * stats.steps as u64);
        assert_eq!(stats.ops.act_gates, totals.act_gates * stats.steps as u64);
        assert_eq!(stats.ops.switch_b2t, totals.switch_b2t * stats.steps as u64);
        assert_eq!(stats.ops.switch_t2b, totals.switch_t2b * stats.steps as u64);
        let acc = trainer.evaluate(&ds, 24, &engine, &mut codec).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn trainer_surfaces_dataset_errors() {
        let batch = 4;
        let (engine, mut codec) = GlyphEngine::setup_clear(EngineProfile::Test, batch);
        let mut rng = GlyphRng::new(12);
        let net = NetworkBuilder::input_vec(4)
            .fc(2)
            .softmax(3, 7)
            .build(&mut codec, &mut rng, &engine)
            .unwrap();
        let trainer = Trainer::new(net, 2);
        let empty = crate::data::Dataset {
            shape: (1, 28, 28),
            images: vec![],
            labels: vec![],
            classes: 2,
            name: "empty".into(),
        };
        let err = trainer.evaluate(&empty, 8, &engine, &mut codec).err().expect("must reject");
        assert!(err.to_string().contains("minibatch"), "{err}");
    }
}
