//! FHE training loops (paper §2.4, §4, §6).
//!
//! * [`glyph`] — the Glyph MLP: BGV MACs + TFHE ReLU/softmax via the
//!   cryptosystem switch (Tables 3/7).
//! * [`fhesgd`] — the FHESGD baseline: identical MAC structure but
//!   sigmoid activations through the bit-sliced BGV table lookup
//!   (Tables 2/6 and Figure 2's bit-width sweep).
//! * [`transfer`] — the Glyph CNN with transfer learning: frozen plaintext
//!   convolutions (MultCP), trainable encrypted FC head (Tables 4/8).

pub mod fhesgd;
pub mod glyph;
pub mod transfer;

pub use fhesgd::FhesgdMlp;
pub use glyph::{GlyphMlp, MlpConfig};
pub use transfer::{CnnConfig, GlyphCnn};
