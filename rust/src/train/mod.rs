//! FHE training loops (paper §2.4, §4, §6), all built on the plan-driven
//! `nn::network` API — each model is one `NetworkBuilder` chain whose
//! compiled `scheduler::Plan` drives execution.
//!
//! * [`glyph`] — the Glyph MLP: BGV MACs + TFHE ReLU/softmax via the
//!   cryptosystem switch (Tables 3/7).
//! * [`fhesgd`] — the FHESGD baseline: identical MAC structure but
//!   sigmoid activations through the bit-sliced BGV table lookup
//!   (Tables 2/6 and Figure 2's bit-width sweep).
//! * [`transfer`] — the Glyph CNN with transfer learning: frozen plaintext
//!   convolutions (MultCP), trainable encrypted FC head (Tables 4/8).
//! * [`infer`] — forward-only encrypted inference over trained models
//!   (`Plan::forward_only` + checkpoint/float-import model loading).

pub mod fhesgd;
pub mod glyph;
pub mod infer;
pub mod trainer;
pub mod transfer;

pub use fhesgd::{FhesgdMlp, SigmoidTluLayer, TluDomain};
pub use glyph::{GlyphMlp, MlpConfig};
pub use infer::{InferenceSession, InferError, OutputMode, Predictions};
pub use trainer::{EpochStats, Trainer};
pub use transfer::{CnnConfig, GlyphCnn};
