//! # Glyph — training DNNs on encrypted data (NeurIPS 2020 reproduction)
//!
//! Glyph trains neural networks on fully-homomorphically-encrypted data by
//! running nonlinear activations in the logic-friendly TFHE cryptosystem,
//! MAC-heavy layers in the vector-arithmetic-friendly BGV cryptosystem, and
//! homomorphically *switching* ciphertexts between the two at every layer
//! boundary. Transfer learning keeps convolution weights in plaintext so the
//! expensive ciphertext×ciphertext convolutions become ciphertext×plaintext.
//!
//! Crate layout (see DESIGN.md for the full inventory):
//!
//! * [`math`] — modular arithmetic, negacyclic NTT, torus FFT, RNS, RNG.
//! * [`tfhe`] — torus32 TFHE: LWE/TRLWE/TRGSW, bootstrapping, gates.
//! * [`bgv`] — RNS leveled BGV with batch-in-coefficients packing.
//! * [`switch`] — the BGV↔TFHE cryptosystem switch (the paper's §4.2).
//! * [`nn`] — encrypted NN layers (FC/conv/pool/BN, TFHE ReLU/softmax).
//! * [`train`] — FHE-SGD training loops: FHESGD baseline, Glyph, transfer.
//! * [`coordinator`] — scheduling, thread-pool execution, HOP metrics,
//!   calibrated cost model that regenerates the paper's tables.
//! * [`runtime`] — PJRT loader/executor for the AOT JAX/Pallas artifacts.
//! * [`data`] — dataset loaders and deterministic synthetic fallbacks.
//! * [`wire`] — versioned std-only binary codec for all durable state
//!   (keys, ciphertexts, plans, checkpoints).
//! * [`serve`] — the `glyph serve` multi-tenant training job service:
//!   TCP protocol, job queue/workers, resumable checkpoints, metrics.
//! * [`bench_util`] — the hand-rolled bench harness used by `cargo bench`.

pub mod bench_util;
pub mod bgv;
pub mod coordinator;
pub mod data;
pub mod math;
pub mod nn;
pub mod runtime;
pub mod serve;
pub mod switch;
pub mod tfhe;
pub mod train;
pub mod wire;
