//! Negacyclic number-theoretic transform over an NTT prime.
//!
//! Polynomials live in `Z_p[X]/(X^N + 1)` with `N` a power of two and
//! `p ≡ 1 (mod 2N)`. We use the fused ψ-twisted Cooley–Tukey / Gentleman–
//! Sande pair (Longa–Naehrig): the 2N-th root ψ is folded into the butterfly
//! tables so no separate pre/post-twist pass is needed. This is the single
//! hottest loop of the BGV side — every MultCC/MultCP is 2–3 NTTs plus a
//! pointwise pass (see EXPERIMENTS.md §Perf for the optimization log).

use super::modarith::{add_mod, inv_mod, mul_mod, root_of_unity, sub_mod};

/// Precomputed tables for one `(N, p)` pair.
#[derive(Clone)]
pub struct NttTable {
    pub n: usize,
    pub p: u64,
    /// ψ^bitrev(i): forward butterfly twiddles (ψ a primitive 2N-th root).
    psi_rev: Vec<u64>,
    /// ψ^{-bitrev(i)}: inverse butterfly twiddles.
    inv_psi_rev: Vec<u64>,
    /// Shoup-precomputed companions: floor(w * 2^64 / p) for fast mul.
    psi_rev_shoup: Vec<u64>,
    inv_psi_rev_shoup: Vec<u64>,
    /// N^{-1} mod p.
    inv_n: u64,
    inv_n_shoup: u64,
    /// Barrett constant floor(2^64 / p) for fast pointwise reduction.
    barrett: u64,
}

#[inline(always)]
fn shoup(w: u64, p: u64) -> u64 {
    (((w as u128) << 64) / p as u128) as u64
}

/// Barrett reduction of a 64-bit product modulo a < 2^32 prime:
/// `q = ⌊t·⌊2^64/p⌋ / 2^64⌋`, remainder corrected at most twice.
/// ~3× faster than the `u128 %` the compiler emits (EXPERIMENTS.md §Perf).
#[inline(always)]
fn barrett_mul(a: u64, b: u64, p: u64, barrett: u64) -> u64 {
    let t = a.wrapping_mul(b); // exact: a,b < 2^32
    let q = ((t as u128 * barrett as u128) >> 64) as u64;
    let mut r = t.wrapping_sub(q.wrapping_mul(p));
    while r >= p {
        r -= p;
    }
    r
}

/// Shoup modular multiplication: `a * w mod p` with precomputed
/// `w_shoup = floor(w * 2^64 / p)`. One u128 mul-high, no division.
#[inline(always)]
fn mul_shoup(a: u64, w: u64, w_shoup: u64, p: u64) -> u64 {
    let q = ((a as u128 * w_shoup as u128) >> 64) as u64;
    let r = a.wrapping_mul(w).wrapping_sub(q.wrapping_mul(p));
    if r >= p {
        r - p
    } else {
        r
    }
}

fn bit_reverse(x: usize, bits: u32) -> usize {
    x.reverse_bits() >> (usize::BITS - bits)
}

impl NttTable {
    /// Build tables; `p` must be prime with `p ≡ 1 (mod 2N)`.
    pub fn new(n: usize, p: u64) -> Self {
        assert!(n.is_power_of_two(), "N must be a power of two");
        assert_eq!((p - 1) % (2 * n as u64), 0, "p must be ≡ 1 mod 2N");
        let bits = n.trailing_zeros();
        let psi = root_of_unity(2 * n as u64, p);
        let inv_psi = inv_mod(psi, p);
        let mut psi_rev = vec![0u64; n];
        let mut inv_psi_rev = vec![0u64; n];
        let mut pw = 1u64;
        let mut ipw = 1u64;
        let mut psi_pows = vec![0u64; n];
        let mut inv_psi_pows = vec![0u64; n];
        for i in 0..n {
            psi_pows[i] = pw;
            inv_psi_pows[i] = ipw;
            pw = mul_mod(pw, psi, p);
            ipw = mul_mod(ipw, inv_psi, p);
        }
        for i in 0..n {
            let r = bit_reverse(i, bits);
            psi_rev[i] = psi_pows[r];
            inv_psi_rev[i] = inv_psi_pows[r];
        }
        let psi_rev_shoup = psi_rev.iter().map(|&w| shoup(w, p)).collect();
        let inv_psi_rev_shoup = inv_psi_rev.iter().map(|&w| shoup(w, p)).collect();
        let inv_n = inv_mod(n as u64, p);
        NttTable {
            n,
            p,
            psi_rev,
            inv_psi_rev,
            psi_rev_shoup,
            inv_psi_rev_shoup,
            inv_n,
            inv_n_shoup: shoup(inv_n, p),
            barrett: ((1u128 << 64) / p as u128) as u64,
        }
    }

    /// In-place forward negacyclic NTT (CT, DIT). Input in natural order,
    /// output in bit-reversed order (consumed only by `pointwise`+`inverse`).
    pub fn forward(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let p = self.p;
        let mut t = self.n;
        let mut m = 1usize;
        while m < self.n {
            t >>= 1;
            for i in 0..m {
                let w = self.psi_rev[m + i];
                let ws = self.psi_rev_shoup[m + i];
                let j1 = 2 * i * t;
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                    let u = *x;
                    let v = mul_shoup(*y, w, ws, p);
                    *x = add_mod(u, v, p);
                    *y = sub_mod(u, v, p);
                }
            }
            m <<= 1;
        }
    }

    /// In-place inverse negacyclic NTT (GS, DIF) incl. the 1/N scale.
    pub fn inverse(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let p = self.p;
        let mut t = 1usize;
        let mut m = self.n;
        while m > 1 {
            let h = m >> 1;
            for i in 0..h {
                let w = self.inv_psi_rev[h + i];
                let ws = self.inv_psi_rev_shoup[h + i];
                let j1 = 2 * i * t;
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                    let u = *x;
                    let v = *y;
                    *x = add_mod(u, v, p);
                    *y = mul_shoup(sub_mod(u, v, p), w, ws, p);
                }
            }
            t <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            *x = mul_shoup(*x, self.inv_n, self.inv_n_shoup, p);
        }
    }

    /// Pointwise product `a[i] * b[i] mod p` into `a` (Barrett-reduced).
    pub fn pointwise(&self, a: &mut [u64], b: &[u64]) {
        let p = self.p;
        let br = self.barrett;
        for (x, &y) in a.iter_mut().zip(b.iter()) {
            *x = barrett_mul(*x, y, p, br);
        }
    }

    /// Pointwise multiply-accumulate `acc[i] += a[i]*b[i] mod p`.
    pub fn pointwise_acc(&self, acc: &mut [u64], a: &[u64], b: &[u64]) {
        let p = self.p;
        let br = self.barrett;
        for i in 0..acc.len() {
            acc[i] = add_mod(acc[i], barrett_mul(a[i], b[i], p, br), p);
        }
    }

    /// Fused double multiply-accumulate `acc[i] += a[i]*b[i] + c[i]*d[i]
    /// mod p` — the cross-term `c0·o1 + c1·o0` of a BGV tensor MAC in one
    /// traversal instead of two `pointwise_acc` passes.
    pub fn pointwise_acc2(&self, acc: &mut [u64], a: &[u64], b: &[u64], c: &[u64], d: &[u64]) {
        let p = self.p;
        let br = self.barrett;
        for i in 0..acc.len() {
            let cross = add_mod(barrett_mul(a[i], b[i], p, br), barrett_mul(c[i], d[i], p, br), p);
            acc[i] = add_mod(acc[i], cross, p);
        }
    }

    /// Full negacyclic polynomial product (convenience; the hot paths keep
    /// operands in the NTT domain instead).
    pub fn negacyclic_mul(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let mut fa = a.to_vec();
        let mut fb = b.to_vec();
        self.forward(&mut fa);
        self.forward(&mut fb);
        self.pointwise(&mut fa, &fb);
        self.inverse(&mut fa);
        fa
    }
}

/// Schoolbook negacyclic product (reference oracle for tests).
pub fn negacyclic_mul_naive(a: &[u64], b: &[u64], p: u64) -> Vec<u64> {
    let n = a.len();
    let mut out = vec![0u64; n];
    for i in 0..n {
        if a[i] == 0 {
            continue;
        }
        for j in 0..n {
            let prod = mul_mod(a[i], b[j], p);
            let k = i + j;
            if k < n {
                out[k] = add_mod(out[k], prod, p);
            } else {
                out[k - n] = sub_mod(out[k - n], prod, p);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::GlyphRng;

    const P: u64 = 469762049; // 7 * 2^26 + 1

    #[test]
    fn roundtrip_identity() {
        let t = NttTable::new(256, P);
        let mut rng = GlyphRng::new(7);
        let a: Vec<u64> = (0..256).map(|_| rng.next_u64() % P).collect();
        let mut b = a.clone();
        t.forward(&mut b);
        t.inverse(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn matches_schoolbook() {
        for n in [8usize, 64, 256] {
            let t = NttTable::new(n, P);
            let mut rng = GlyphRng::new(n as u64);
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64() % P).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64() % P).collect();
            assert_eq!(t.negacyclic_mul(&a, &b), negacyclic_mul_naive(&a, &b, P), "n={n}");
        }
    }

    #[test]
    fn x_times_xn_minus_1_wraps_negatively() {
        // X * X^{N-1} = X^N = -1 in the negacyclic ring.
        let n = 64;
        let t = NttTable::new(n, P);
        let mut a = vec![0u64; n];
        a[1] = 1;
        let mut b = vec![0u64; n];
        b[n - 1] = 1;
        let c = t.negacyclic_mul(&a, &b);
        assert_eq!(c[0], P - 1);
        assert!(c[1..].iter().all(|&x| x == 0));
    }

    #[test]
    fn pointwise_acc_accumulates() {
        let t = NttTable::new(8, P);
        let mut acc = vec![1u64; 8];
        t.pointwise_acc(&mut acc, &[2; 8], &[3; 8]);
        assert!(acc.iter().all(|&x| x == 7));
    }

    #[test]
    fn pointwise_acc2_matches_two_single_accs() {
        let n = 64;
        let t = NttTable::new(n, P);
        let mut rng = GlyphRng::new(4242);
        let mk = |rng: &mut GlyphRng| (0..n).map(|_| rng.next_u64() % P).collect::<Vec<u64>>();
        let (a, b, c, d) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let mut fused = mk(&mut rng);
        let mut split = fused.clone();
        t.pointwise_acc2(&mut fused, &a, &b, &c, &d);
        t.pointwise_acc(&mut split, &a, &b);
        t.pointwise_acc(&mut split, &c, &d);
        assert_eq!(fused, split);
    }

    #[test]
    fn linearity_property() {
        // NTT(a + b) == NTT(a) + NTT(b) pointwise.
        let n = 128;
        let t = NttTable::new(n, P);
        let mut rng = GlyphRng::new(99);
        let a: Vec<u64> = (0..n).map(|_| rng.next_u64() % P).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.next_u64() % P).collect();
        let mut sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| add_mod(x, y, P)).collect();
        let (mut fa, mut fb) = (a, b);
        t.forward(&mut fa);
        t.forward(&mut fb);
        t.forward(&mut sum);
        for i in 0..n {
            assert_eq!(sum[i], add_mod(fa[i], fb[i], P));
        }
    }
}
