//! Negacyclic number-theoretic transform over an NTT prime.
//!
//! Polynomials live in `Z_p[X]/(X^N + 1)` with `N` a power of two and
//! `p ≡ 1 (mod 2N)`. We use the fused ψ-twisted Cooley–Tukey / Gentleman–
//! Sande pair (Longa–Naehrig): the 2N-th root ψ is folded into the butterfly
//! tables so no separate pre/post-twist pass is needed. This is the single
//! hottest loop of the BGV side — every MultCC/MultCP is 2–3 NTTs plus a
//! pointwise pass (see EXPERIMENTS.md §Perf for the optimization log).
//!
//! The butterfly/pointwise loops themselves live behind the pluggable
//! [`RingKernels`] layer (`math/kernels.rs`): a scalar reference and a
//! Harvey lazy-reduction vectorized set, selected at table construction
//! (`GLYPH_KERNELS`, or explicitly via [`NttTable::with_kernels`]). Both are
//! bit-identical; `tests/kernel_equivalence.rs` enforces it.

use super::kernels::{default_kernels, RingKernels};
use super::modarith::{
    add_mod, barrett_precompute, inv_mod, mul_mod, mul_shoup, root_of_unity, shoup_precompute,
    sub_mod,
};

/// Precomputed tables for one `(N, p)` pair.
#[derive(Clone)]
pub struct NttTable {
    pub n: usize,
    pub p: u64,
    /// ψ^bitrev(i): forward butterfly twiddles (ψ a primitive 2N-th root).
    psi_rev: Vec<u64>,
    /// ψ^{-bitrev(i)}: inverse butterfly twiddles.
    inv_psi_rev: Vec<u64>,
    /// Shoup-precomputed companions: floor(w * 2^64 / p) for fast mul.
    psi_rev_shoup: Vec<u64>,
    inv_psi_rev_shoup: Vec<u64>,
    /// N^{-1} mod p.
    inv_n: u64,
    inv_n_shoup: u64,
    /// Barrett constant floor(2^64 / p) for fast pointwise reduction.
    barrett: u64,
    /// Kernel set the hot loops dispatch through.
    kernels: &'static dyn RingKernels,
}

fn bit_reverse(x: usize, bits: u32) -> usize {
    x.reverse_bits() >> (usize::BITS - bits)
}

impl NttTable {
    /// Build tables with the process-default kernel set; `p` must be prime
    /// with `p ≡ 1 (mod 2N)`.
    pub fn new(n: usize, p: u64) -> Self {
        Self::with_kernels(n, p, default_kernels())
    }

    /// Build tables pinned to an explicit kernel set (conformance tests and
    /// benches compare scalar vs simd side by side this way).
    pub fn with_kernels(n: usize, p: u64, kernels: &'static dyn RingKernels) -> Self {
        assert!(n.is_power_of_two(), "N must be a power of two");
        assert_eq!((p - 1) % (2 * n as u64), 0, "p must be ≡ 1 mod 2N");
        let bits = n.trailing_zeros();
        let psi = root_of_unity(2 * n as u64, p);
        let inv_psi = inv_mod(psi, p);
        let mut psi_rev = vec![0u64; n];
        let mut inv_psi_rev = vec![0u64; n];
        // ψ^i by Shoup ladder: the per-step multiplicand is the constant ψ,
        // so table construction needs no `u128 %` divides beyond the two
        // companion precomputations (satellite of EXPERIMENTS.md §Perf).
        let psi_sh = shoup_precompute(psi, p);
        let inv_psi_sh = shoup_precompute(inv_psi, p);
        let mut pw = 1u64;
        let mut ipw = 1u64;
        let mut psi_pows = vec![0u64; n];
        let mut inv_psi_pows = vec![0u64; n];
        for i in 0..n {
            psi_pows[i] = pw;
            inv_psi_pows[i] = ipw;
            pw = mul_shoup(pw, psi, psi_sh, p);
            ipw = mul_shoup(ipw, inv_psi, inv_psi_sh, p);
        }
        for i in 0..n {
            let r = bit_reverse(i, bits);
            psi_rev[i] = psi_pows[r];
            inv_psi_rev[i] = inv_psi_pows[r];
        }
        let psi_rev_shoup = psi_rev.iter().map(|&w| shoup_precompute(w, p)).collect();
        let inv_psi_rev_shoup = inv_psi_rev.iter().map(|&w| shoup_precompute(w, p)).collect();
        let inv_n = inv_mod(n as u64, p);
        NttTable {
            n,
            p,
            psi_rev,
            inv_psi_rev,
            psi_rev_shoup,
            inv_psi_rev_shoup,
            inv_n,
            inv_n_shoup: shoup_precompute(inv_n, p),
            barrett: barrett_precompute(p),
            kernels,
        }
    }

    /// The kernel set this table dispatches through.
    #[inline]
    pub fn kernels(&self) -> &'static dyn RingKernels {
        self.kernels
    }

    /// Barrett constant `⌊2^64 / p⌋` (shared with callers that reduce by
    /// this limb outside the table's own passes, e.g. the relin digit lift).
    #[inline]
    pub fn barrett(&self) -> u64 {
        self.barrett
    }

    /// In-place forward negacyclic NTT (CT, DIT). Input in natural order,
    /// output in bit-reversed order (consumed only by `pointwise`+`inverse`).
    pub fn forward(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        self.kernels.ntt_forward(self.p, &self.psi_rev, &self.psi_rev_shoup, a);
    }

    /// In-place inverse negacyclic NTT (GS, DIF) incl. the 1/N scale.
    pub fn inverse(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        self.kernels.ntt_inverse(
            self.p,
            &self.inv_psi_rev,
            &self.inv_psi_rev_shoup,
            self.inv_n,
            self.inv_n_shoup,
            a,
        );
    }

    /// Pointwise product `a[i] * b[i] mod p` into `a` (Barrett-reduced).
    pub fn pointwise(&self, a: &mut [u64], b: &[u64]) {
        self.kernels.pointwise(self.p, self.barrett, a, b);
    }

    /// Pointwise multiply-accumulate `acc[i] += a[i]*b[i] mod p`.
    pub fn pointwise_acc(&self, acc: &mut [u64], a: &[u64], b: &[u64]) {
        self.kernels.pointwise_acc(self.p, self.barrett, acc, a, b);
    }

    /// Fused double multiply-accumulate `acc[i] += a[i]*b[i] + c[i]*d[i]
    /// mod p` — the cross-term `c0·o1 + c1·o0` of a BGV tensor MAC in one
    /// traversal instead of two `pointwise_acc` passes.
    pub fn pointwise_acc2(&self, acc: &mut [u64], a: &[u64], b: &[u64], c: &[u64], d: &[u64]) {
        self.kernels.pointwise_acc2(self.p, self.barrett, acc, a, b, c, d);
    }

    /// In-place `a[i] *= s mod p` with a Shoup-precomputed constant scalar.
    pub fn scalar_mul(&self, a: &mut [u64], s: u64, s_shoup: u64) {
        self.kernels.scalar_mul(self.p, s, s_shoup, a);
    }

    /// Full negacyclic polynomial product (convenience; the hot paths keep
    /// operands in the NTT domain instead).
    pub fn negacyclic_mul(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let mut fa = a.to_vec();
        let mut fb = b.to_vec();
        self.forward(&mut fa);
        self.forward(&mut fb);
        self.pointwise(&mut fa, &fb);
        self.inverse(&mut fa);
        fa
    }
}

/// Schoolbook negacyclic product (reference oracle for tests).
pub fn negacyclic_mul_naive(a: &[u64], b: &[u64], p: u64) -> Vec<u64> {
    let n = a.len();
    let mut out = vec![0u64; n];
    for i in 0..n {
        if a[i] == 0 {
            continue;
        }
        for j in 0..n {
            let prod = mul_mod(a[i], b[j], p);
            let k = i + j;
            if k < n {
                out[k] = add_mod(out[k], prod, p);
            } else {
                out[k - n] = sub_mod(out[k - n], prod, p);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::kernels::{scalar_kernels, simd_kernels};
    use crate::math::rng::GlyphRng;

    const P: u64 = 469762049; // 7 * 2^26 + 1

    #[test]
    fn roundtrip_identity() {
        let t = NttTable::new(256, P);
        let mut rng = GlyphRng::new(7);
        let a: Vec<u64> = (0..256).map(|_| rng.next_u64() % P).collect();
        let mut b = a.clone();
        t.forward(&mut b);
        t.inverse(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn matches_schoolbook() {
        for n in [8usize, 64, 256] {
            let t = NttTable::new(n, P);
            let mut rng = GlyphRng::new(n as u64);
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64() % P).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64() % P).collect();
            assert_eq!(t.negacyclic_mul(&a, &b), negacyclic_mul_naive(&a, &b, P), "n={n}");
        }
    }

    #[test]
    fn scalar_and_simd_tables_are_bit_identical() {
        for n in [8usize, 64, 512] {
            let ts = NttTable::with_kernels(n, P, scalar_kernels());
            let tv = NttTable::with_kernels(n, P, simd_kernels());
            let mut rng = GlyphRng::new(0xbeef ^ n as u64);
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64() % P).collect();
            let mut fs = a.clone();
            let mut fv = a.clone();
            ts.forward(&mut fs);
            tv.forward(&mut fv);
            assert_eq!(fs, fv, "forward n={n}");
            ts.inverse(&mut fs);
            tv.inverse(&mut fv);
            assert_eq!(fs, fv, "inverse n={n}");
            assert_eq!(fs, a, "roundtrip n={n}");
        }
    }

    #[test]
    fn x_times_xn_minus_1_wraps_negatively() {
        // X * X^{N-1} = X^N = -1 in the negacyclic ring.
        let n = 64;
        let t = NttTable::new(n, P);
        let mut a = vec![0u64; n];
        a[1] = 1;
        let mut b = vec![0u64; n];
        b[n - 1] = 1;
        let c = t.negacyclic_mul(&a, &b);
        assert_eq!(c[0], P - 1);
        assert!(c[1..].iter().all(|&x| x == 0));
    }

    #[test]
    fn pointwise_acc_accumulates() {
        let t = NttTable::new(8, P);
        let mut acc = vec![1u64; 8];
        t.pointwise_acc(&mut acc, &[2; 8], &[3; 8]);
        assert!(acc.iter().all(|&x| x == 7));
    }

    #[test]
    fn pointwise_acc2_matches_two_single_accs() {
        let n = 64;
        let t = NttTable::new(n, P);
        let mut rng = GlyphRng::new(4242);
        let mk = |rng: &mut GlyphRng| (0..n).map(|_| rng.next_u64() % P).collect::<Vec<u64>>();
        let (a, b, c, d) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let mut fused = mk(&mut rng);
        let mut split = fused.clone();
        t.pointwise_acc2(&mut fused, &a, &b, &c, &d);
        t.pointwise_acc(&mut split, &a, &b);
        t.pointwise_acc(&mut split, &c, &d);
        assert_eq!(fused, split);
    }

    #[test]
    fn linearity_property() {
        // NTT(a + b) == NTT(a) + NTT(b) pointwise.
        let n = 128;
        let t = NttTable::new(n, P);
        let mut rng = GlyphRng::new(99);
        let a: Vec<u64> = (0..n).map(|_| rng.next_u64() % P).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.next_u64() % P).collect();
        let mut sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| add_mod(x, y, P)).collect();
        let (mut fa, mut fb) = (a, b);
        t.forward(&mut fa);
        t.forward(&mut fb);
        t.forward(&mut sum);
        for i in 0..n {
            assert_eq!(sum[i], add_mod(fa[i], fb[i], P));
        }
    }
}
